//! Rollout walkthrough: signed artifact repository + zero-downtime model
//! swap, driven end to end over the real wire path.
//!
//!   cargo run --release --example rollout
//!
//! The example copies the committed artifacts into a scratch root, signs
//! the manifest in-process (the Rust half of `python -m compile.sign`),
//! self-hosts a `--require-signed` serving stack over it, and then walks
//! the rollout lifecycle: hello capabilities, hot `add-variant`, a tamper
//! + refused reload, and recovery — printing what the repository reports
//! at each step.
//!
//! Requires `make artifacts` (at minimum the sst2 dataset).

use std::path::{Path, PathBuf};

use powerbert::client::{PowerClient, RepoInfo};
use powerbert::coordinator::{Config, Coordinator, Input, Policy, Server, ServerHandle, Sla};
use powerbert::runtime::Manifest;
use powerbert::util::ed25519;
use powerbert::util::hash::to_hex;
use powerbert::workload::WorkloadGen;

/// Demo signing seed — a real deployment generates one with
/// `python -m compile.sign artifacts --gen-key` and keeps it off the box.
const SEED: [u8; 32] = [7u8; 32];

fn copy_tree(src: &Path, dst: &Path) {
    std::fs::create_dir_all(dst).expect("create scratch dir");
    for entry in std::fs::read_dir(src).expect("read artifacts") {
        let entry = entry.expect("dir entry");
        let to = dst.join(entry.file_name());
        if entry.path().is_dir() {
            copy_tree(&entry.path(), &to);
        } else {
            std::fs::copy(entry.path(), &to).expect("copy artifact file");
        }
    }
}

/// Digest + sign the scratch root at `revision` and publish the trusted key.
fn sign(root: &Path, revision: u64) {
    let mut m = Manifest::build(root, revision).expect("digest artifacts");
    m.sign_with(&SEED).expect("sign manifest");
    m.write(root).expect("write index.json");
    std::fs::write(root.join("signing.pub"), format!("{}\n", to_hex(&ed25519::public_key(&SEED))))
        .expect("write signing.pub");
}

fn repo_line(tag: &str, r: &RepoInfo) {
    println!(
        "  [{tag}] revision {} generation {} signed={} verified_files={} excluded={:?} datasets={:?}",
        r.revision, r.generation, r.signed, r.verified_files, r.excluded, r.datasets
    );
}

fn main() {
    powerbert::util::log::init();
    let src = powerbert::runtime::default_root();
    if !src.join("vocab.json").exists() {
        eprintln!("no artifacts at {} — run `make artifacts` first", src.display());
        std::process::exit(1);
    }

    // Scratch root: vocab + the bert baseline only. power-default arrives
    // later, as the rollout.
    let root: PathBuf =
        std::env::temp_dir().join(format!("powerbert-rollout-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&root);
    std::fs::create_dir_all(&root).expect("scratch root");
    std::fs::copy(src.join("vocab.json"), root.join("vocab.json")).expect("copy vocab");
    copy_tree(&src.join("sst2").join("bert"), &root.join("sst2").join("bert"));
    sign(&root, 1);
    println!("== scratch repository at {} (revision 1, signed) ==", root.display());

    // Self-host over the scratch root. --require-signed: an unsigned or
    // tampered bundle refuses to serve at all.
    let coordinator = Coordinator::start(Config {
        artifacts: root.clone(),
        policy: Policy::FastestAboveMetric,
        require_signed: true,
        ..Config::default()
    })
    .expect("coordinator");
    let server: ServerHandle =
        Server::bind("127.0.0.1:0", coordinator.client()).expect("bind").spawn().expect("spawn");
    let client = PowerClient::connect(server.addr()).expect("connect");

    let hello = client.fetch_hello().expect("hello");
    repo_line("hello", &hello.repo.clone().expect("repo capability"));
    println!("  variants: {:?}", hello.variants.get("sst2").map(|v| v.len()).unwrap_or(0));

    let vocab = coordinator.tokenizer().vocab.clone();
    let mut gen = WorkloadGen::new(&vocab, 42);
    let (text, _) = gen.sentence(14);
    let r = client
        .classify("sst2", Input::Text { a: text.clone(), b: None }, Sla::default())
        .expect("classify");
    println!("  baseline serves: label={} via {} in {}us", r.label, r.variant, r.total_us);

    // -- The rollout: drop power-default into the live root, re-sign at
    // revision 2, and announce it. In-flight requests finish on the old
    // snapshot; the swap happens off the hot path.
    println!("\n== add-variant: sst2/power-default at revision 2 ==");
    copy_tree(&src.join("sst2").join("power-default"), &root.join("sst2").join("power-default"));
    sign(&root, 2);
    let info = client.add_variant("sst2", "power-default").expect("add-variant");
    repo_line("add-variant", &info);
    let r = client
        .classify(
            "sst2",
            Input::Text { a: text.clone(), b: None },
            Sla { variant: Some("power-default".into()), ..Default::default() },
        )
        .expect("classify on rolled-out variant");
    println!("  rolled-out variant serves: label={} via {} in {}us", r.label, r.variant, r.total_us);

    // -- Tamper drill: flip one byte in the baseline weights. The next
    // reload hashes everything, refuses the dataset, names the file and
    // digests — and serving of everything else continues.
    println!("\n== tamper drill: one flipped byte in sst2/bert/weights.npz ==");
    let weights = root.join("sst2").join("bert").join("weights.npz");
    let mut bytes = std::fs::read(&weights).expect("read weights");
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0x01;
    std::fs::write(&weights, &bytes).expect("write tampered weights");
    match client.reload() {
        Ok(info) => repo_line("reload", &info),
        Err(e) => println!("  reload refused: {e}"),
    }
    match client.classify("sst2", Input::Text { a: text.clone(), b: None }, Sla::default()) {
        Ok(r) => println!("  post-tamper classify unexpectedly served via {}", r.variant),
        Err(e) => println!("  post-tamper classify refused (dataset excluded): {e}"),
    }

    // -- Recovery: restore the honest bytes and reload.
    println!("\n== recovery: restore the weights and reload ==");
    bytes[mid] ^= 0x01;
    std::fs::write(&weights, &bytes).expect("restore weights");
    let info = client.reload().expect("reload after restore");
    repo_line("reload", &info);
    let r = client
        .classify("sst2", Input::Text { a: text, b: None }, Sla::default())
        .expect("classify after recovery");
    println!("  healthy again: label={} via {}", r.label, r.variant);

    drop(client);
    let mut server = server;
    server.stop();
    coordinator.shutdown();
    let _ = std::fs::remove_dir_all(&root);
    println!("\nclean shutdown");
}
