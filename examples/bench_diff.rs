//! Perf-regression diff between two `BENCH_native.json` snapshots: the
//! committed baseline vs a freshly generated one. Used by the CI
//! `perf-diff` job to fail a PR that quietly slows a named section down.
//!
//! Default mode compares only **machine-independent ratios** — numbers
//! that survive a hardware change between the baseline's machine and the
//! runner:
//!
//!   kernels         naive_p50 / row_p50 (speedup over the naive GEMM,
//!                   recomputed within each file from its own naive rows)
//!   dispatch        vs_serial, plus the deterministic `chosen` path
//!   thread_scaling  speedup_vs_1t
//!   workers_sweep   speedup_vs_1w (coordinator throughput scaling)
//!   adaptive        tokens_ratio_vs_fixed (deterministic given the
//!                   committed artifacts — lower is better)
//!   ragged          speedup_vs_padded (ragged vs padded execution of the
//!                   same batch — higher is better), plus a hard floor:
//!                   a schema-4 snapshot must show ≥ 1.3x on at least one
//!                   threshold-0.6 mixed-demand batch (the tentpole
//!                   acceptance ratio)
//!
//! `--absolute` additionally compares raw p50 seconds in the `serve`,
//! `end_to_end` and `serve_sweep` sections — only meaningful when both
//! snapshots come from the same hardware.
//!
//! A section row regresses when its metric worsens by more than
//! `--threshold` percent (default 25). Rows present in only one snapshot
//! are reported but never fail the diff (sections grow across PRs).
//! Exit code: 0 clean, 1 regressions found, 2 usage/parse errors.
//!
//!   cargo run --release --example bench_diff -- \
//!       --old BENCH_native.json --new /tmp/BENCH_fresh.json

use std::collections::BTreeMap;

use powerbert::util::cli::Args;
use powerbert::util::json::Json;

/// One comparable row: identity key -> metric value.
type Rows = BTreeMap<String, f64>;

fn load(path: &str) -> Json {
    match Json::parse_file(std::path::Path::new(path)) {
        Ok(j) => j,
        Err(e) => {
            eprintln!("{path}: {e}");
            std::process::exit(2);
        }
    }
}

fn arr<'a>(root: &'a Json, section: &str) -> &'a [Json] {
    root.get(section).and_then(Json::as_arr).unwrap_or(&[])
}

fn s<'a>(row: &'a Json, key: &str) -> &'a str {
    row.get(key).and_then(Json::as_str).unwrap_or("?")
}

fn f(row: &Json, key: &str) -> Option<f64> {
    row.get(key).and_then(Json::as_f64)
}

/// kernels section: per (dataset, shape, precision) the naive row's p50
/// is the in-file baseline; every other row's metric is its speedup over
/// that. Higher is better.
fn kernel_ratios(root: &Json) -> Rows {
    let rows = arr(root, "kernels");
    let mut naive = BTreeMap::new();
    for r in rows {
        if s(r, "path") == "naive" {
            if let Some(p50) = f(r, "p50_s") {
                naive.insert(format!("{}/{}", s(r, "dataset"), s(r, "shape")), p50);
            }
        }
    }
    let mut out = Rows::new();
    for r in rows {
        if s(r, "path") == "naive" {
            continue;
        }
        let base = naive.get(&format!("{}/{}", s(r, "dataset"), s(r, "shape")));
        if let (Some(base), Some(p50)) = (base, f(r, "p50_s")) {
            let key = format!(
                "kernels {}/{} {} [{}/{}]",
                s(r, "dataset"),
                s(r, "shape"),
                s(r, "path"),
                s(r, "dispatch"),
                s(r, "precision"),
            );
            out.insert(key, base / p50.max(1e-12));
        }
    }
    out
}

/// dispatch section: vs_serial per (dataset, path). Higher is better.
fn dispatch_ratios(root: &Json) -> Rows {
    let mut out = Rows::new();
    for r in arr(root, "dispatch") {
        if let Some(v) = f(r, "vs_serial") {
            out.insert(format!("dispatch {}/{}", s(r, "dataset"), s(r, "path")), v);
        }
    }
    out
}

/// dispatch `chosen` path per (dataset, path) — deterministic given the
/// shape and the default floors, so any mismatch is a semantic change,
/// not noise.
fn dispatch_chosen(root: &Json) -> BTreeMap<String, String> {
    let mut out = BTreeMap::new();
    for r in arr(root, "dispatch") {
        if let Some(c) = r.get("chosen").and_then(Json::as_str) {
            out.insert(format!("{}/{}", s(r, "dataset"), s(r, "path")), c.to_string());
        }
    }
    out
}

/// thread_scaling: speedup_vs_1t per (dataset, precision, threads).
/// Higher is better.
fn scaling_ratios(root: &Json) -> Rows {
    let mut out = Rows::new();
    for r in arr(root, "thread_scaling") {
        let threads = f(r, "threads").unwrap_or(0.0) as u64;
        if let Some(v) = f(r, "speedup_vs_1t") {
            out.insert(
                format!(
                    "thread_scaling {}/{}@{}t",
                    s(r, "dataset"),
                    s(r, "precision"),
                    threads
                ),
                v,
            );
        }
    }
    out
}

/// workers_sweep: speedup_vs_1w per (dataset, workers). Higher is better.
fn workers_ratios(root: &Json) -> Rows {
    let mut out = Rows::new();
    for r in arr(root, "workers_sweep") {
        let workers = f(r, "workers").unwrap_or(0.0) as u64;
        if let Some(v) = f(r, "speedup_vs_1w") {
            out.insert(format!("workers_sweep {}@{}w", s(r, "dataset"), workers), v);
        }
    }
    out
}

/// adaptive: tokens_ratio_vs_fixed per (dataset, threshold). The ratio is
/// deterministic given the committed artifacts, so any drift is a semantic
/// change in the adaptive executor. Lower is better.
fn adaptive_ratios(root: &Json) -> Rows {
    let mut out = Rows::new();
    for r in arr(root, "adaptive") {
        if let (Some(t), Some(v)) = (f(r, "threshold"), f(r, "tokens_ratio_vs_fixed")) {
            out.insert(
                format!("adaptive {}/{}@t{t:.2}", s(r, "dataset"), s(r, "variant")),
                v,
            );
        }
    }
    out
}

/// ragged: speedup_vs_padded per (dataset, variant, threshold, batch).
/// Higher is better — the ratio measures ghost work the ragged path
/// eliminated on the identical batch.
fn ragged_ratios(root: &Json) -> Rows {
    let mut out = Rows::new();
    for r in arr(root, "ragged") {
        if let (Some(t), Some(b), Some(v)) =
            (f(r, "threshold"), f(r, "batch"), f(r, "speedup_vs_padded"))
        {
            out.insert(
                format!("ragged {}/{}@t{t:.2}b{}", s(r, "dataset"), s(r, "variant"), b as u64),
                v,
            );
        }
    }
    out
}

/// The tentpole acceptance floor: a schema-4 snapshot must contain at
/// least one threshold-0.6 ragged row at ≥ `floor` speedup over padded.
/// Returns the number of gate failures (0 or 1); pre-schema-4 snapshots
/// are exempt (the section did not exist yet).
fn ragged_gate(root: &Json, floor: f64) -> usize {
    if root.get("schema").and_then(Json::as_u64).unwrap_or(0) < 4 {
        return 0;
    }
    let best = arr(root, "ragged")
        .iter()
        .filter(|r| f(r, "threshold").map(|t| (t - 0.6).abs() < 1e-6).unwrap_or(false))
        .filter_map(|r| f(r, "speedup_vs_padded"))
        .fold(f64::NEG_INFINITY, f64::max);
    if best >= floor {
        println!("  ✓ ragged gate: best t=0.60 speedup {best:.2}x >= {floor:.2}x");
        0
    } else if best.is_finite() {
        println!("  ✗ ragged gate: best t=0.60 speedup {best:.2}x < {floor:.2}x");
        1
    } else {
        println!("  ✗ ragged gate: schema-4 snapshot has no threshold-0.6 ragged rows");
        1
    }
}

/// Absolute p50 seconds of a section, keyed by the given identity fields.
/// Lower is better.
fn absolute_p50(root: &Json, section: &str, keys: &[&str]) -> Rows {
    let mut out = Rows::new();
    for r in arr(root, section) {
        let id: Vec<String> = keys
            .iter()
            .map(|k| {
                r.get(k)
                    .map(|v| match v {
                        Json::Str(s) => s.clone(),
                        other => other.to_string(),
                    })
                    .unwrap_or_else(|| "?".into())
            })
            .collect();
        if let Some(v) = f(r, "p50_s") {
            out.insert(format!("{section} {}", id.join("/")), v);
        }
    }
    out
}

/// Compare one section. `higher_is_better` flips the regression
/// direction. Returns the number of regressions.
fn compare(old: &Rows, new: &Rows, threshold_pct: f64, higher_is_better: bool) -> usize {
    let mut regressions = 0;
    for (key, old_v) in old {
        let Some(new_v) = new.get(key) else {
            println!("  ~ {key}: only in baseline (skipped)");
            continue;
        };
        let change =
            if higher_is_better { old_v / new_v.max(1e-12) } else { new_v / old_v.max(1e-12) };
        let worse_pct = (change - 1.0) * 100.0;
        if worse_pct > threshold_pct {
            println!("  ✗ {key}: {old_v:.4} -> {new_v:.4} ({worse_pct:+.0}% worse)");
            regressions += 1;
        } else {
            println!("  ✓ {key}: {old_v:.4} -> {new_v:.4} ({worse_pct:+.0}%)");
        }
    }
    for key in new.keys() {
        if !old.contains_key(key) {
            println!("  + {key}: new row (no baseline)");
        }
    }
    regressions
}

fn main() {
    let args = Args::new("bench_diff", "perf-regression diff between two bench snapshots")
        .opt("old", Some("BENCH_native.json"), "baseline snapshot (the committed one)")
        .opt("new", None, "freshly generated snapshot to check")
        .opt("threshold", Some("25"), "percent worsening that fails a row")
        .flag("absolute", "also compare raw p50 seconds (same-hardware snapshots only)")
        .parse()
        .unwrap_or_else(|u| {
            eprintln!("{u}");
            std::process::exit(2)
        });
    let old_path = args.get("old").unwrap_or("BENCH_native.json").to_string();
    let Some(new_path) = args.get("new").map(String::from) else {
        eprintln!("--new is required");
        std::process::exit(2);
    };
    let threshold = args.get_f64("threshold").unwrap_or(25.0);
    let absolute = args.has("absolute");

    let old = load(&old_path);
    let new = load(&new_path);
    println!(
        "bench diff: {old_path} (schema {:?}) vs {new_path} (schema {:?}), threshold {threshold}%",
        old.get("schema").and_then(Json::as_u64),
        new.get("schema").and_then(Json::as_u64),
    );

    let mut regressions = 0;
    println!("\nkernels (speedup over naive, higher is better):");
    regressions += compare(&kernel_ratios(&old), &kernel_ratios(&new), threshold, true);
    println!("\ndispatch (vs serial, higher is better):");
    regressions += compare(&dispatch_ratios(&old), &dispatch_ratios(&new), threshold, true);
    let new_chosen = dispatch_chosen(&new);
    for (key, old_c) in dispatch_chosen(&old) {
        if let Some(new_c) = new_chosen.get(&key) {
            if *new_c != old_c {
                println!("  ✗ dispatch {key}: chosen path changed {old_c} -> {new_c}");
                regressions += 1;
            }
        }
    }
    println!("\nthread_scaling (speedup vs 1 thread, higher is better):");
    regressions += compare(&scaling_ratios(&old), &scaling_ratios(&new), threshold, true);
    println!("\nworkers_sweep (speedup vs 1 worker, higher is better):");
    regressions += compare(&workers_ratios(&old), &workers_ratios(&new), threshold, true);
    println!("\nadaptive (tokens processed vs fixed schedule, lower is better):");
    regressions += compare(&adaptive_ratios(&old), &adaptive_ratios(&new), threshold, false);
    println!("\nragged (speedup vs padded execution, higher is better):");
    regressions += compare(&ragged_ratios(&old), &ragged_ratios(&new), threshold, true);
    regressions += ragged_gate(&new, 1.3);

    if absolute {
        println!("\nserve p50 (seconds, lower is better):");
        regressions += compare(
            &absolute_p50(&old, "serve", &["dataset", "variant"]),
            &absolute_p50(&new, "serve", &["dataset", "variant"]),
            threshold,
            false,
        );
        println!("\nend_to_end p50 (seconds, lower is better):");
        regressions += compare(
            &absolute_p50(&old, "end_to_end", &["dataset", "variant", "precision"]),
            &absolute_p50(&new, "end_to_end", &["dataset", "variant", "precision"]),
            threshold,
            false,
        );
        println!("\nserve_sweep p50 (seconds, lower is better):");
        regressions += compare(
            &absolute_p50(&old, "serve_sweep", &["edge", "conns_target"]),
            &absolute_p50(&new, "serve_sweep", &["edge", "conns_target"]),
            threshold,
            false,
        );
    }

    if regressions > 0 {
        println!("\n{regressions} regression(s) beyond {threshold}%");
        std::process::exit(1);
    }
    println!("\nno regressions beyond {threshold}%");
}
