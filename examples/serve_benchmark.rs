//! End-to-end serving driver (the repo's E2E validation workload): starts
//! the full coordinator stack, replays an open-loop Poisson workload
//! against it at several request rates, and reports latency/throughput for
//! baseline BERT vs PoWER-BERT serving — the paper's inference-time claim
//! measured through the entire L3 path (tokenize -> route -> batch ->
//! PJRT execute), not just the kernel.
//!
//!   cargo run --release --example serve_benchmark [-- --rate 200 --secs 10]
//!
//! The run recorded in EXPERIMENTS.md §E2E uses the defaults.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use powerbert::coordinator::{BatchPolicy, Config, Coordinator, Input, Policy, Sla};
use powerbert::runtime::BackendKind;
use powerbert::util::cli::Args;
use powerbert::util::stats::Summary;
use powerbert::workload::WorkloadGen;

fn main() {
    powerbert::util::log::init();
    let args = Args::new("serve_benchmark", "open-loop serving benchmark")
        .opt("rate", Some("150"), "request rate per second")
        .opt("secs", Some("8"), "measurement duration per variant")
        .opt("dataset", Some("sst2"), "dataset to serve")
        .opt("workers", Some("1"), "executor pool size")
        .opt("backend", None, "inference backend (pjrt | native | auto)")
        .opt("seq-buckets", None, "comma-separated seq buckets (e.g. 16,32)")
        .parse()
        .unwrap_or_else(|u| {
            eprintln!("{u}");
            std::process::exit(2)
        });
    let rate: f64 = args.get_f64("rate").unwrap_or(150.0);
    let secs: f64 = args.get_f64("secs").unwrap_or(8.0);
    let dataset = args.get("dataset").unwrap_or("sst2").to_string();
    let workers = args.get_usize("workers").unwrap_or(1).max(1);
    let backend = match args.get("backend") {
        None => BackendKind::from_env(),
        Some(raw) => BackendKind::parse(raw).unwrap_or_else(|| {
            eprintln!("--backend: expected pjrt|native|auto, got {raw:?}");
            std::process::exit(2)
        }),
    };
    let seq_buckets = match (args.get("seq-buckets"), args.get_usize_list("seq-buckets")) {
        (Some(raw), None) if !raw.trim().is_empty() => {
            eprintln!("--seq-buckets: expected comma-separated integers, got {raw:?}");
            std::process::exit(2)
        }
        (_, list) => list.unwrap_or_default(),
    };

    let coordinator = Coordinator::start(Config {
        datasets: vec![dataset.clone()],
        policy: Policy::BestUnderLatency,
        batch: BatchPolicy { max_batch: 32, max_wait: Duration::from_millis(4) },
        workers,
        backend,
        seq_buckets,
        ..Config::default()
    })
    .unwrap_or_else(|e| {
        eprintln!("error: {e}\nhint: run `make artifacts`");
        std::process::exit(1)
    });

    let variants: Vec<String> = coordinator
        .router()
        .variants(&dataset)
        .into_iter()
        .filter(|m| m.variant == "bert" || m.variant == "power-default")
        .map(|m| m.variant.clone())
        .collect();

    println!(
        "open-loop Poisson load: {rate} req/s for {secs}s per variant ({backend} backend)\n"
    );
    let mut rows = Vec::new();
    for variant in &variants {
        let client = coordinator.client();
        let vocab = client.tokenizer().vocab.clone();
        let mut gen = WorkloadGen::new(&vocab, 99);
        // Warm the variant (lazy compile) outside the measurement window.
        let (wtext, _) = gen.sentence(18);
        let _ = client.classify(
            &dataset,
            Input::Text { a: wtext, b: None },
            Sla { variant: Some(variant.clone()), ..Default::default() },
        );
        let latencies: Arc<Mutex<Vec<f64>>> = Arc::new(Mutex::new(Vec::new()));
        let shed = Arc::new(AtomicUsize::new(0));
        let correct = Arc::new(AtomicUsize::new(0));
        let done = Arc::new(AtomicUsize::new(0));

        let t0 = Instant::now();
        let mut sent = 0usize;
        let mut waiters = Vec::new();
        while t0.elapsed().as_secs_f64() < secs {
            let (text, label) = gen.sentence(18);
            let sla = Sla { variant: Some(variant.clone()), ..Default::default() };
            let submit_t = Instant::now();
            match client.submit(&dataset, Input::Text { a: text, b: None }, sla) {
                Ok(rx) => {
                    sent += 1;
                    let latencies = latencies.clone();
                    let correct = correct.clone();
                    let done = done.clone();
                    waiters.push(std::thread::spawn(move || {
                        if let Ok(Ok(resp)) = rx.recv() {
                            latencies
                                .lock()
                                .unwrap()
                                .push(submit_t.elapsed().as_secs_f64() * 1e3);
                            if resp.label == label {
                                correct.fetch_add(1, Ordering::Relaxed);
                            }
                            done.fetch_add(1, Ordering::Relaxed);
                        }
                    }));
                }
                Err(_) => {
                    shed.fetch_add(1, Ordering::Relaxed);
                }
            }
            std::thread::sleep(gen.arrival_gap(rate));
        }
        for w in waiters {
            let _ = w.join();
        }
        let wall = t0.elapsed().as_secs_f64();
        let lat = latencies.lock().unwrap();
        let s = Summary::of(&lat);
        let n_done = done.load(Ordering::Relaxed);
        rows.push((
            variant.clone(),
            n_done as f64 / wall,
            s.clone(),
            shed.load(Ordering::Relaxed),
            correct.load(Ordering::Relaxed) as f64 / n_done.max(1) as f64,
        ));
        println!(
            "{variant:<15} sent={sent} done={n_done} shed={} tput={:.1} req/s  \
             lat p50/p90/p99 = {:.1}/{:.1}/{:.1} ms  acc={:.3}",
            shed.load(Ordering::Relaxed),
            n_done as f64 / wall,
            s.p50,
            s.p90,
            s.p99,
            correct.load(Ordering::Relaxed) as f64 / n_done.max(1) as f64,
        );
    }

    if rows.len() == 2 {
        let speedup = rows[0].2.p50 / rows[1].2.p50;
        println!(
            "\nPoWER-BERT p50 latency speedup over BERT at {rate} req/s: {:.2}x",
            speedup
        );
    }
    println!(
        "\npadding waste (executed/real tokens): {:.2}x over {} worker(s)",
        coordinator.metrics().total_padding_waste(),
        workers,
    );
    println!("\ncoordinator internals:\n{}", coordinator.metrics().report());
}
