//! End-to-end serving benchmark over the real wire (the repo's E2E
//! validation workload): starts the full stack — coordinator, executor
//! pool, TCP server — then drives it two ways per variant:
//!
//!   v1  a legacy line-protocol client, one request in flight (the v1
//!       dialect is synchronous by construction);
//!   v2  a single pipelined `PowerClient` connection holding `--depth`
//!       requests in flight, completions matched by id.
//!
//! The v2-vs-v1 throughput delta is the value of protocol multiplexing:
//! one pipelined connection keeps the (batch, seq) buckets of the dynamic
//! batcher full, where depth-1 traffic executes batches of one. Both
//! clients replay the same mixed-length synthetic workload (via the shared
//! `powerbert::bench::wire` drivers) and check ground-truth labels, so the
//! run also validates correctness of both dialects against one server
//! process.
//!
//! With `--sweep`, the run additionally holds a ladder of open
//! connections (idle peers plus one measured pipelined client) against
//! the selected `--edge` and reports reply p50/p99, accept-to-reply
//! latency, and server fd pressure at each rung — the headline scaling
//! claim of the epoll edge. `--json PATH` merges the sweep as a
//! `serve_sweep` section into an existing bench snapshot
//! (`BENCH_native.json`); all other sections of the file are preserved.
//!
//!   cargo run --release --example serve_benchmark [-- --secs 5 --depth 16]
//!   cargo run --release --example serve_benchmark -- --edge epoll \
//!       --sweep 100,1000,5000,10000 --json BENCH_native.json

use std::collections::BTreeMap;
use std::net::{SocketAddr, TcpStream};
use std::time::Instant;

use powerbert::bench::wire::{closed_loop_v1, closed_loop_v2, WireRun};
use powerbert::client::PowerClient;
use powerbert::coordinator::{
    BatchPolicy, Config, Coordinator, EdgeKind, Input, Policy, Server, Sla,
};
use powerbert::runtime::BackendKind;
use powerbert::tokenizer::Vocab;
use powerbert::util::cli::Args;
use powerbert::util::epoll::fd_limit;
use powerbert::util::json::Json;
use powerbert::util::stats::Summary;
use powerbert::workload::{LengthMix, WorkloadGen};

fn print_row(variant: &str, name: &str, r: &WireRun) {
    let s = r.latency_summary();
    println!(
        "{variant:<15} {name:<12} done={:<6} err={:<3} tput={:>8.1} req/s  \
         lat p50/p90/p99 = {:.1}/{:.1}/{:.1} ms  acc={:.3}",
        r.done,
        r.errors,
        r.throughput(),
        s.p50,
        s.p90,
        s.p99,
        r.accuracy(),
    );
}

fn main() {
    powerbert::util::log::init();
    let args = Args::new(
        "serve_benchmark",
        "closed-loop wire benchmark: v1 depth-1 vs pipelined v2 PowerClient",
    )
    .opt("secs", Some("5"), "measurement duration per client per variant")
    .opt("depth", Some("16"), "v2 pipeline depth (requests in flight)")
    .opt("dataset", Some("sst2"), "dataset to serve")
    .opt("workers", Some("1"), "executor pool size")
    .opt("backend", None, "inference backend (pjrt | native | auto)")
    .opt("seq-buckets", None, "comma-separated seq buckets (e.g. 16,32)")
    .opt("edge", Some("threads"), "server connection edge (threads | epoll)")
    .opt(
        "sweep",
        None,
        "comma-separated open-connection counts to hold while measuring \
         (e.g. 100,1000,5000,10000)",
    )
    .opt("sweep-secs", Some("2"), "measurement seconds per sweep rung")
    .opt(
        "json",
        None,
        "merge the sweep as a serve_sweep section into this snapshot file \
         (e.g. BENCH_native.json)",
    )
    .parse()
    .unwrap_or_else(|u| {
        eprintln!("{u}");
        std::process::exit(2)
    });
    let secs: f64 = args.get_f64("secs").unwrap_or(5.0);
    let depth = args.get_usize("depth").unwrap_or(16).max(1);
    let dataset = args.get("dataset").unwrap_or("sst2").to_string();
    let workers = args.get_usize("workers").unwrap_or(1).max(1);
    let backend = match args.get("backend") {
        None => BackendKind::from_env(),
        Some(raw) => BackendKind::parse(raw).unwrap_or_else(|| {
            eprintln!("--backend: expected pjrt|native|auto, got {raw:?}");
            std::process::exit(2)
        }),
    };
    let seq_buckets = match (args.get("seq-buckets"), args.get_usize_list("seq-buckets")) {
        (Some(raw), None) if !raw.trim().is_empty() => {
            eprintln!("--seq-buckets: expected comma-separated integers, got {raw:?}");
            std::process::exit(2)
        }
        (_, list) => list.unwrap_or_default(),
    };
    let edge = EdgeKind::parse(args.get("edge").unwrap_or("threads")).unwrap_or_else(|e| {
        eprintln!("--edge: {e}");
        std::process::exit(2)
    });
    let sweep = match (args.get("sweep"), args.get_usize_list("sweep")) {
        (Some(raw), None) if !raw.trim().is_empty() => {
            eprintln!("--sweep: expected comma-separated integers, got {raw:?}");
            std::process::exit(2)
        }
        (_, list) => list.unwrap_or_default(),
    };
    let sweep_secs: f64 = args.get_f64("sweep-secs").unwrap_or(2.0);
    let json_path = args.get("json").map(String::from);

    let mut coordinator = Coordinator::start(Config {
        datasets: vec![dataset.clone()],
        policy: Policy::BestUnderLatency,
        batch: BatchPolicy {
            max_batch: 32,
            max_wait: std::time::Duration::from_millis(4),
        },
        workers,
        backend,
        seq_buckets,
        ..Config::default()
    })
    .unwrap_or_else(|e| {
        eprintln!("error: {e}\nhint: run `make artifacts`");
        std::process::exit(1)
    });

    // The default 256-connection cap is a serving safety net, not a bench
    // limit: size it past the largest sweep rung so the edge itself is
    // what gets measured.
    let max_conns = sweep.iter().copied().max().unwrap_or(0).max(256) + 64;
    let server = Server::bind("127.0.0.1:0", coordinator.client())
        .expect("bind")
        .with_edge(edge)
        .with_max_connections(max_conns)
        .spawn()
        .expect("spawn");
    let addr = server.addr();

    let variants: Vec<String> = coordinator
        .router()
        .variants(&dataset)
        .into_iter()
        .filter(|m| m.variant == "bert" || m.variant == "power-default")
        .map(|m| m.variant.clone())
        .collect();

    let root = powerbert::runtime::default_root();
    let vocab = Vocab::load(&root.join("vocab.json")).expect("vocab");
    let mix = LengthMix::default();

    println!(
        "closed-loop wire benchmark: {secs}s per client per variant, v2 depth={depth} \
         ({backend} backend, {workers} worker(s), {} edge)\n",
        edge.as_str()
    );
    let warm_client = PowerClient::connect(addr).expect("warm connect");
    let mut rows = Vec::new();
    for variant in &variants {
        // Warm the variant (lazy load/compile) outside measurement.
        let mut gen = WorkloadGen::new(&vocab, 7);
        let (wtext, _) = gen.sentence(18);
        let _ = warm_client.classify(
            &dataset,
            Input::Text { a: wtext, b: None },
            Sla { variant: Some(variant.clone()), ..Default::default() },
        );

        let v1 = closed_loop_v1(addr, &dataset, variant, secs, &mix, &vocab, 99);
        let v2 = closed_loop_v2(addr, &dataset, variant, secs, depth, &mix, &vocab, 101);
        print_row(variant, "v1 depth-1", &v1);
        print_row(variant, &format!("v2 depth-{depth}"), &v2);
        println!(
            "{variant:<15} pipelining throughput gain: {:.2}x\n",
            v2.throughput() / v1.throughput().max(1e-9)
        );
        rows.push((variant.clone(), v1, v2));
    }

    if let Some((_, _, v2_power)) = rows.iter().find(|(v, _, _)| v == "power-default") {
        if let Some((_, _, v2_bert)) = rows.iter().find(|(v, _, _)| v == "bert") {
            println!(
                "PoWER-BERT pipelined throughput over BERT: {:.2}x",
                v2_power.throughput() / v2_bert.throughput().max(1e-9)
            );
        }
    }

    if !sweep.is_empty() {
        let sweep_variant = variants
            .iter()
            .find(|v| *v == "power-default")
            .or_else(|| variants.first())
            .cloned();
        if let Some(variant) = sweep_variant {
            let rows = connection_sweep(
                addr, &dataset, &variant, edge, &sweep, sweep_secs, depth, &vocab, &warm_client,
            );
            if let Some(path) = &json_path {
                merge_sweep(path, rows);
            }
        } else {
            eprintln!("--sweep: no routable variant to measure against");
        }
    }

    match warm_client.stats() {
        Ok(s) => println!(
            "\nserver stats: uptime {:.1}s  padding waste {:.2}x  connections {}/{}  \
             edge {}  fds {:?}/{:?}",
            s.uptime_secs,
            s.padding_waste,
            s.connections_current,
            s.connections_max,
            s.edge,
            s.fd_open,
            s.fd_limit,
        ),
        Err(e) => println!("\nstats error: {e}"),
    }
    drop(warm_client);

    println!("\ncoordinator internals:\n{}", coordinator.metrics().report());

    server.stop();
    coordinator.shutdown();
}

/// Hold a ladder of open connections and measure what the edge does under
/// each rung: `conns - 1` idle peers (open socket, no traffic — exactly
/// the load an event loop is supposed to make free) plus one pipelined v2
/// client doing real work. Per rung: reply p50/p99 from the measured
/// client, accept-to-reply latency (fresh `connect` + hello round trip,
/// sampled while the rung is held), and the server's own fd pressure from
/// `stats`.
///
/// Both socket ends live in this process, so each held connection costs
/// ~2 fds locally; rungs are clamped to the process rlimit with headroom
/// and the clamp is reported rather than silently applied.
#[allow(clippy::too_many_arguments)]
fn connection_sweep(
    addr: SocketAddr,
    dataset: &str,
    variant: &str,
    edge: EdgeKind,
    rungs: &[usize],
    secs: f64,
    depth: usize,
    vocab: &Vocab,
    stats_client: &PowerClient,
) -> Vec<Json> {
    const FD_HEADROOM: u64 = 256;
    const ACCEPT_SAMPLES: usize = 20;
    let budget = fd_limit().map(|l| (l.saturating_sub(FD_HEADROOM) / 2) as usize);
    let mix = LengthMix::default();
    let mut rows = Vec::new();
    println!(
        "\nconnection sweep — {} edge, {secs}s measured per rung, depth {depth}:",
        edge.as_str()
    );
    println!(
        "{:>8} {:>8} {:>10} {:>10} {:>12} {:>12} {:>10} {:>10}",
        "target", "held", "p50 ms", "p99 ms", "accept p50", "accept p99", "fd open", "req/s"
    );
    for &target in rungs {
        let held_target = match budget {
            Some(b) if target > b => {
                eprintln!(
                    "  (rung {target} clamped to {b}: process fd limit {:?} \
                     covers both socket ends)",
                    fd_limit()
                );
                b
            }
            _ => target,
        };
        // Idle peers. A connect that fails (kernel backlog, fd pressure)
        // ends the rung at however many sockets actually opened.
        let mut idle = Vec::with_capacity(held_target.saturating_sub(1));
        for i in 0..held_target.saturating_sub(1) {
            match TcpStream::connect(addr) {
                Ok(s) => idle.push(s),
                Err(e) => {
                    eprintln!("  (rung {target}: connect {i} failed: {e}; holding {})", idle.len());
                    break;
                }
            }
        }
        let held = idle.len() + 1;

        // Accept-to-reply under load: a fresh connection is not accepted
        // until the event loop gets to it, and its hello reply is the
        // first write it ever sees.
        let mut accept_s = Vec::with_capacity(ACCEPT_SAMPLES);
        for _ in 0..ACCEPT_SAMPLES {
            let t0 = Instant::now();
            match PowerClient::connect(addr) {
                Ok(c) => {
                    accept_s.push(t0.elapsed().as_secs_f64());
                    drop(c);
                }
                Err(e) => {
                    eprintln!("  (rung {target}: accept sample failed: {e})");
                    break;
                }
            }
        }
        let accept = if accept_s.is_empty() { Summary::of(&[0.0]) } else { Summary::of(&accept_s) };

        let run = closed_loop_v2(addr, dataset, variant, secs, depth, &mix, vocab, 7 + held as u64);
        let lat = run.latency_summary();
        let (fd_open, fd_lim) = match stats_client.stats() {
            Ok(s) => (s.fd_open, s.fd_limit),
            Err(_) => (None, None),
        };
        println!(
            "{target:>8} {held:>8} {:>10.2} {:>10.2} {:>9.2} ms {:>9.2} ms {:>10} {:>10.1}",
            lat.p50,
            lat.p99,
            accept.p50 * 1e3,
            accept.p99 * 1e3,
            fd_open.map(|v| v.to_string()).unwrap_or_else(|| "-".into()),
            run.throughput(),
        );
        let mut m = BTreeMap::new();
        m.insert("edge".to_string(), Json::Str(edge.as_str().to_string()));
        m.insert("dataset".to_string(), Json::Str(dataset.to_string()));
        m.insert("variant".to_string(), Json::Str(variant.to_string()));
        m.insert("conns_target".to_string(), Json::UInt(target as u64));
        m.insert("conns_held".to_string(), Json::UInt(held as u64));
        m.insert("depth".to_string(), Json::UInt(depth as u64));
        m.insert("requests".to_string(), Json::UInt(run.done as u64));
        m.insert("errors".to_string(), Json::UInt(run.errors as u64));
        m.insert("p50_s".to_string(), Json::Num(lat.p50 / 1e3));
        m.insert("p99_s".to_string(), Json::Num(lat.p99 / 1e3));
        m.insert("accept_to_reply_p50_s".to_string(), Json::Num(accept.p50));
        m.insert("accept_to_reply_p99_s".to_string(), Json::Num(accept.p99));
        m.insert("fd_open".to_string(), fd_open.map(Json::UInt).unwrap_or(Json::Null));
        m.insert("fd_limit".to_string(), fd_lim.map(Json::UInt).unwrap_or(Json::Null));
        m.insert("throughput_rps".to_string(), Json::Num(run.throughput()));
        rows.push(Json::Obj(m));
        drop(idle);
    }
    rows
}

/// Merge the sweep rows into a bench snapshot as its `serve_sweep`
/// section, preserving every other key (`benches/native.rs` owns the
/// rest of the file and symmetrically preserves `serve_sweep` when it
/// rewrites). A missing or unparsable file starts a minimal schema-2
/// snapshot instead of failing the bench.
fn merge_sweep(path: &str, rows: Vec<Json>) {
    let mut root = match Json::parse_file(std::path::Path::new(path)) {
        Ok(Json::Obj(m)) => m,
        _ => BTreeMap::new(),
    };
    root.entry("bench".to_string()).or_insert_with(|| Json::Str("native".to_string()));
    root.insert("schema".to_string(), Json::UInt(2));
    root.insert("serve_sweep".to_string(), Json::Arr(rows));
    match std::fs::write(path, Json::Obj(root).to_string_pretty() + "\n") {
        Ok(()) => println!("merged serve_sweep into {path}"),
        Err(e) => eprintln!("--json {path}: {e}"),
    }
}
