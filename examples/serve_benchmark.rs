//! End-to-end serving benchmark over the real wire (the repo's E2E
//! validation workload): starts the full stack — coordinator, executor
//! pool, TCP server — then drives it two ways per variant:
//!
//!   v1  a legacy line-protocol client, one request in flight (the v1
//!       dialect is synchronous by construction);
//!   v2  a single pipelined `PowerClient` connection holding `--depth`
//!       requests in flight, completions matched by id.
//!
//! The v2-vs-v1 throughput delta is the value of protocol multiplexing:
//! one pipelined connection keeps the (batch, seq) buckets of the dynamic
//! batcher full, where depth-1 traffic executes batches of one. Both
//! clients replay the same mixed-length synthetic workload (via the shared
//! `powerbert::bench::wire` drivers) and check ground-truth labels, so the
//! run also validates correctness of both dialects against one server
//! process.
//!
//!   cargo run --release --example serve_benchmark [-- --secs 5 --depth 16]

use powerbert::bench::wire::{closed_loop_v1, closed_loop_v2, WireRun};
use powerbert::client::PowerClient;
use powerbert::coordinator::{BatchPolicy, Config, Coordinator, Input, Policy, Server, Sla};
use powerbert::runtime::BackendKind;
use powerbert::tokenizer::Vocab;
use powerbert::util::cli::Args;
use powerbert::workload::{LengthMix, WorkloadGen};

fn print_row(variant: &str, name: &str, r: &WireRun) {
    let s = r.latency_summary();
    println!(
        "{variant:<15} {name:<12} done={:<6} err={:<3} tput={:>8.1} req/s  \
         lat p50/p90/p99 = {:.1}/{:.1}/{:.1} ms  acc={:.3}",
        r.done,
        r.errors,
        r.throughput(),
        s.p50,
        s.p90,
        s.p99,
        r.accuracy(),
    );
}

fn main() {
    powerbert::util::log::init();
    let args = Args::new(
        "serve_benchmark",
        "closed-loop wire benchmark: v1 depth-1 vs pipelined v2 PowerClient",
    )
    .opt("secs", Some("5"), "measurement duration per client per variant")
    .opt("depth", Some("16"), "v2 pipeline depth (requests in flight)")
    .opt("dataset", Some("sst2"), "dataset to serve")
    .opt("workers", Some("1"), "executor pool size")
    .opt("backend", None, "inference backend (pjrt | native | auto)")
    .opt("seq-buckets", None, "comma-separated seq buckets (e.g. 16,32)")
    .parse()
    .unwrap_or_else(|u| {
        eprintln!("{u}");
        std::process::exit(2)
    });
    let secs: f64 = args.get_f64("secs").unwrap_or(5.0);
    let depth = args.get_usize("depth").unwrap_or(16).max(1);
    let dataset = args.get("dataset").unwrap_or("sst2").to_string();
    let workers = args.get_usize("workers").unwrap_or(1).max(1);
    let backend = match args.get("backend") {
        None => BackendKind::from_env(),
        Some(raw) => BackendKind::parse(raw).unwrap_or_else(|| {
            eprintln!("--backend: expected pjrt|native|auto, got {raw:?}");
            std::process::exit(2)
        }),
    };
    let seq_buckets = match (args.get("seq-buckets"), args.get_usize_list("seq-buckets")) {
        (Some(raw), None) if !raw.trim().is_empty() => {
            eprintln!("--seq-buckets: expected comma-separated integers, got {raw:?}");
            std::process::exit(2)
        }
        (_, list) => list.unwrap_or_default(),
    };

    let mut coordinator = Coordinator::start(Config {
        datasets: vec![dataset.clone()],
        policy: Policy::BestUnderLatency,
        batch: BatchPolicy {
            max_batch: 32,
            max_wait: std::time::Duration::from_millis(4),
        },
        workers,
        backend,
        seq_buckets,
        ..Config::default()
    })
    .unwrap_or_else(|e| {
        eprintln!("error: {e}\nhint: run `make artifacts`");
        std::process::exit(1)
    });

    let server = Server::bind("127.0.0.1:0", coordinator.client())
        .expect("bind")
        .spawn()
        .expect("spawn");
    let addr = server.addr();

    let variants: Vec<String> = coordinator
        .router()
        .variants(&dataset)
        .into_iter()
        .filter(|m| m.variant == "bert" || m.variant == "power-default")
        .map(|m| m.variant.clone())
        .collect();

    let root = powerbert::runtime::default_root();
    let vocab = Vocab::load(&root.join("vocab.json")).expect("vocab");
    let mix = LengthMix::default();

    println!(
        "closed-loop wire benchmark: {secs}s per client per variant, v2 depth={depth} \
         ({backend} backend, {workers} worker(s))\n"
    );
    let warm_client = PowerClient::connect(addr).expect("warm connect");
    let mut rows = Vec::new();
    for variant in &variants {
        // Warm the variant (lazy load/compile) outside measurement.
        let mut gen = WorkloadGen::new(&vocab, 7);
        let (wtext, _) = gen.sentence(18);
        let _ = warm_client.classify(
            &dataset,
            Input::Text { a: wtext, b: None },
            Sla { variant: Some(variant.clone()), ..Default::default() },
        );

        let v1 = closed_loop_v1(addr, &dataset, variant, secs, &mix, &vocab, 99);
        let v2 = closed_loop_v2(addr, &dataset, variant, secs, depth, &mix, &vocab, 101);
        print_row(variant, "v1 depth-1", &v1);
        print_row(variant, &format!("v2 depth-{depth}"), &v2);
        println!(
            "{variant:<15} pipelining throughput gain: {:.2}x\n",
            v2.throughput() / v1.throughput().max(1e-9)
        );
        rows.push((variant.clone(), v1, v2));
    }

    if let Some((_, _, v2_power)) = rows.iter().find(|(v, _, _)| v == "power-default") {
        if let Some((_, _, v2_bert)) = rows.iter().find(|(v, _, _)| v == "bert") {
            println!(
                "PoWER-BERT pipelined throughput over BERT: {:.2}x",
                v2_power.throughput() / v2_bert.throughput().max(1e-9)
            );
        }
    }

    match warm_client.stats() {
        Ok(s) => println!(
            "\nserver stats: uptime {:.1}s  padding waste {:.2}x  connections {}/{}",
            s.uptime_secs, s.padding_waste, s.connections_current, s.connections_max
        ),
        Err(e) => println!("\nstats error: {e}"),
    }
    drop(warm_client);

    println!("\ncoordinator internals:\n{}", coordinator.metrics().report());

    server.stop();
    coordinator.shutdown();
}
