//! Figure 7 companion: measure the accuracy-vs-latency point of EVERY
//! exported variant of a dataset and print the Pareto table the router's
//! SLA policy operates on. (The paper-formatted bench lives in
//! `cargo bench --bench fig7`; this example is the interactive version.)
//!
//!   cargo run --release --example pareto_sweep -- --dataset cola

use powerbert::bench::{fmt_time, BenchConfig, Table, time_fn};
use powerbert::eval::Metric;
use powerbert::runtime::{default_root, Engine, Registry, TestSplit};
use powerbert::util::cli::Args;

fn main() {
    powerbert::util::log::init();
    let args = Args::new("pareto_sweep", "accuracy vs latency for all variants")
        .opt("dataset", Some("sst2"), "dataset to sweep")
        .opt("batch", Some("32"), "inference batch size")
        .parse()
        .unwrap_or_else(|u| {
            eprintln!("{u}");
            std::process::exit(2)
        });
    let dataset = args.get("dataset").unwrap_or("sst2");
    let batch = args.get_usize("batch").unwrap_or(32);

    let registry = Registry::scan(&default_root()).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(1)
    });
    let Some(ds) = registry.dataset(dataset) else {
        eprintln!("no artifacts for {dataset}");
        std::process::exit(1)
    };
    let split = TestSplit::load(&ds.test_npz()).expect("test split");
    let mut engine = Engine::new().expect("pjrt");
    let cfg = BenchConfig::from_env();

    let mut table = Table::new(
        &format!("{dataset}: accuracy vs inference time (batch {batch})"),
        &["variant", "kind", "metric", "batch latency", "ex/s", "agg word-vectors"],
    );
    for (vname, meta) in &ds.variants {
        if vname.ends_with("-debug") {
            continue;
        }
        let model = match engine.load(meta) {
            Ok(m) => m,
            Err(e) => {
                eprintln!("skip {vname}: {e}");
                continue;
            }
        };
        let seq = split.seq_len;
        let n = batch.min(split.n);
        let toks = &split.tokens[..n * seq];
        let segs = &split.segments[..n * seq];
        let s = time_fn(&cfg, || {
            model.infer(toks, segs, n).expect("infer");
        });
        // Full-split metric.
        let metric = Metric::parse(&meta.metric).unwrap_or(Metric::Accuracy);
        let mut outputs = Vec::new();
        let mut nc = meta.num_classes;
        let mut i = 0;
        while i < split.n {
            let m = batch.min(split.n - i);
            let l = model
                .infer(&split.tokens[i * seq..(i + m) * seq], &split.segments[i * seq..(i + m) * seq], m)
                .unwrap();
            nc = l.num_classes;
            outputs.extend_from_slice(&l.values);
            i += m;
        }
        let mv = metric.compute(&outputs, nc, &split.labels);
        table.row(vec![
            vname.clone(),
            meta.kind.clone(),
            format!("{mv:.4}"),
            fmt_time(s.p50),
            format!("{:.0}", n as f64 / s.p50),
            meta.aggregate_word_vectors().to_string(),
        ]);
    }
    table.print();
    println!("top-left of the paper's Figure 7 = high metric + low latency.");
}
