//! Quickstart: start the coordinator over the AOT artifacts, classify a few
//! sentences of the synthetic language, and show what PoWER-BERT eliminated.
//!
//!   cargo run --release --example quickstart
//!
//! Requires `make artifacts` (at minimum the sst2 dataset).

use powerbert::coordinator::{Config, Coordinator, Input, Policy, Sla};
use powerbert::workload::WorkloadGen;

fn main() {
    powerbert::util::log::init();
    let cfg = Config {
        datasets: vec!["sst2".into()],
        policy: Policy::FastestAboveMetric,
        ..Config::default()
    };
    let coordinator = match Coordinator::start(cfg) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}\nhint: run `make artifacts` first");
            std::process::exit(1);
        }
    };

    println!("== dataset stats (Table 1 analog) ==");
    for meta in coordinator.router().variants("sst2") {
        println!(
            "  sst2/{:<20} N={} classes={} aggregate word-vectors={}{}",
            meta.variant,
            meta.seq_len,
            meta.num_classes,
            meta.aggregate_word_vectors(),
            meta.retention
                .as_ref()
                .map(|r| format!("  retention={r:?}"))
                .unwrap_or_default()
        );
    }

    let vocab = coordinator.tokenizer().vocab.clone();
    let mut gen = WorkloadGen::new(&vocab, 42);
    println!("\n== classification under the default SLA (fastest within 1% of baseline) ==");
    let mut correct = 0;
    let n = 16;
    for i in 0..n {
        let (text, label) = gen.sentence(18);
        let resp = coordinator
            .classify("sst2", Input::Text { a: text.clone(), b: None }, Sla::default())
            .expect("classify");
        let ok = resp.label == label;
        correct += ok as usize;
        if i < 5 {
            println!(
                "  [{}] {:<60} -> {} (truth {}) via {} in {}us",
                if ok { "ok" } else { "XX" },
                text.chars().take(60).collect::<String>(),
                resp.label,
                label,
                resp.variant,
                resp.total_us
            );
        }
    }
    println!("  accuracy on fresh synthetic inputs: {correct}/{n}");

    println!("\n== explicit variant pinning (the paper's Table 2 comparison) ==");
    for variant in ["bert", "power-default"] {
        let (text, _) = gen.sentence(18);
        match coordinator.classify(
            "sst2",
            Input::Text { a: text, b: None },
            Sla { variant: Some(variant.into()), ..Default::default() },
        ) {
            Ok(r) => println!(
                "  {variant:<15} label={} exec={}us batch={}",
                r.label, r.exec_us, r.batch_size
            ),
            Err(e) => println!("  {variant:<15} error: {e}"),
        }
    }

    println!("\n== coordinator metrics ==");
    print!("{}", coordinator.metrics().report());
}
