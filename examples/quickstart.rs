//! Quickstart: drive a PoWER-BERT server through the typed `PowerClient`
//! — hello/capabilities, SLA-routed classification, explicit variant
//! pinning, a batch submission, and structured stats.
//!
//!   cargo run --release --example quickstart [-- --addr 127.0.0.1:7878]
//!
//! With `--addr` it connects to a running `powerbert serve`; without, it
//! self-hosts the full stack (coordinator + TCP server on an ephemeral
//! port) in-process and talks to itself over the real wire path.
//!
//! Requires `make artifacts` (at minimum the sst2 dataset).

use powerbert::client::PowerClient;
use powerbert::coordinator::{Config, Coordinator, Input, Policy, Server, ServerHandle, Sla};
use powerbert::tokenizer::Vocab;
use powerbert::util::cli::Args;
use powerbert::workload::WorkloadGen;

/// The in-process serving stack when no `--addr` was given. Field order
/// is drop order: the server stops before the coordinator drains.
struct SelfHost {
    server: ServerHandle,
    coordinator: Coordinator,
}

fn self_host() -> (PowerClient, SelfHost) {
    let coordinator = Coordinator::start(Config {
        datasets: vec!["sst2".into()],
        policy: Policy::FastestAboveMetric,
        ..Config::default()
    })
    .unwrap_or_else(|e| {
        eprintln!("error: {e}\nhint: run `make artifacts` first");
        std::process::exit(1);
    });
    let server = Server::bind("127.0.0.1:0", coordinator.client())
        .expect("bind")
        .spawn()
        .expect("spawn");
    let client = PowerClient::connect(server.addr()).expect("connect to self-hosted server");
    (client, SelfHost { server, coordinator })
}

fn main() {
    powerbert::util::log::init();
    let args = Args::new("quickstart", "PowerClient quickstart against a powerbert server")
        .opt("addr", None, "server address (default: self-host in-process)")
        .parse()
        .unwrap_or_else(|u| {
            eprintln!("{u}");
            std::process::exit(2)
        });

    let (client, stack) = match args.get("addr") {
        Some(addr) => (
            PowerClient::connect(addr).unwrap_or_else(|e| {
                eprintln!("connect {addr}: {e}");
                std::process::exit(1)
            }),
            None,
        ),
        None => {
            let (c, s) = self_host();
            (c, Some(s))
        }
    };

    let info = client.hello().clone();
    println!(
        "== hello: {} proto {} backend {} ({} datasets, cap {} connections) ==",
        info.server,
        info.proto,
        info.backend,
        info.datasets.len(),
        info.max_connections,
    );
    for (ds, variants) in &info.variants {
        for v in variants {
            println!(
                "  {ds}/{:<20} N={} classes={} aggregate word-vectors={}{}{}",
                v.variant,
                v.seq_len,
                v.num_classes,
                v.aggregate_word_vectors,
                v.dev_metric
                    .map(|m| format!("  {}={m:.4}", v.metric))
                    .unwrap_or_default(),
                v.retention
                    .as_ref()
                    .map(|r| format!("  retention={r:?}"))
                    .unwrap_or_default(),
            );
        }
    }
    let dataset = info.datasets.first().cloned().unwrap_or_else(|| "sst2".into());

    // The synthetic-language generator needs the shared vocabulary, which
    // lives next to the artifacts (clients and server read the same dir).
    let root = powerbert::runtime::default_root();
    let vocab = Vocab::load(&root.join("vocab.json")).unwrap_or_else(|e| {
        eprintln!("vocab: {e}\nhint: run `make artifacts` first");
        std::process::exit(1)
    });
    let mut gen = WorkloadGen::new(&vocab, 42);

    println!("\n== classification under the default SLA (fastest within 1% of baseline) ==");
    let mut correct = 0;
    let n = 16;
    for i in 0..n {
        let (text, label) = gen.sentence(18);
        let resp = client
            .classify(&dataset, Input::Text { a: text.clone(), b: None }, Sla::default())
            .expect("classify");
        let ok = resp.label == label;
        correct += ok as usize;
        if i < 5 {
            println!(
                "  [{}] {:<60} -> {} (truth {}) via {} in {}us",
                if ok { "ok" } else { "XX" },
                text.chars().take(60).collect::<String>(),
                resp.label,
                label,
                resp.variant,
                resp.total_us
            );
        }
    }
    println!("  accuracy on fresh synthetic inputs: {correct}/{n}");

    println!("\n== explicit variant pinning (the paper's Table 2 comparison) ==");
    for variant in ["bert", "power-default"] {
        let (text, _) = gen.sentence(18);
        match client.classify(
            &dataset,
            Input::Text { a: text, b: None },
            Sla { variant: Some(variant.into()), ..Default::default() },
        ) {
            Ok(r) => println!(
                "  {variant:<15} label={} exec={}us batch={}",
                r.label, r.exec_us, r.batch_size
            ),
            Err(e) => println!("  {variant:<15} error: {e}"),
        }
    }

    println!("\n== batch submission (one wire frame, batcher sees it as a unit) ==");
    let inputs: Vec<Input> = (0..8)
        .map(|_| {
            let (text, _) = gen.sentence(18);
            Input::Text { a: text, b: None }
        })
        .collect();
    match client.classify_batch(&dataset, inputs, &Sla::default()) {
        Ok(rs) => {
            let max_batch = rs.iter().map(|r| r.batch_size).max().unwrap_or(0);
            println!("  {} responses, largest executed batch: {max_batch}", rs.len());
        }
        Err(e) => println!("  batch error: {e}"),
    }

    println!("\n== structured stats ==");
    match client.stats() {
        Ok(s) => println!(
            "  uptime {:.1}s  padding waste {:.2}x  connections {}/{}",
            s.uptime_secs, s.padding_waste, s.connections_current, s.connections_max
        ),
        Err(e) => println!("  stats error: {e}"),
    }

    drop(client);
    if let Some(mut stack) = stack {
        stack.server.stop();
        stack.coordinator.shutdown();
    }
    println!("\nclean shutdown");
}
