//! Figure 8 (anecdotal examples): run sentences through the debug PoWER
//! artifact and print which words survive at every encoder — the paper's
//! progressive word-vector elimination, observed live from Rust.
//!
//!   cargo run --release --example anecdotes

use powerbert::runtime::{default_root, Engine, Registry};
use powerbert::tokenizer::{Tokenizer, Vocab};
use std::sync::Arc;

fn main() {
    powerbert::util::log::init();
    let root = default_root();
    let registry = Registry::scan(&root).unwrap_or_else(|e| {
        eprintln!("{e}");
        std::process::exit(1)
    });
    let Some(ds) = registry.dataset("sst2") else {
        eprintln!("sst2 artifacts missing — run `make artifacts`");
        std::process::exit(1)
    };
    let Some(meta) = ds.variant("power-default-debug") else {
        eprintln!("debug artifact missing — run `make artifacts`");
        std::process::exit(1)
    };
    let vocab = Arc::new(Vocab::load(&registry.vocab_path()).expect("vocab"));
    let tok = Tokenizer::new(vocab.clone());
    let mut engine = Engine::new().expect("pjrt");
    let model = engine.load(meta).expect("load debug artifact");

    // Sentences in the spirit of the paper's Figure 8: sparse sentiment
    // evidence among filler words; one with a negation flip.
    let sentences = [
        "filler_1 pos_3 filler_7 intens_0 pos_5 filler_2 neg_1 pos_8 filler_9",
        "filler_4 negation_0 pos_2 filler_3 neg_6 filler_8 neg_2 filler_5",
    ];
    let retention = meta.retention.clone().unwrap_or_default();
    println!("retention configuration: {retention:?}\n");

    for text in sentences {
        let enc = tok.encode(text, None, meta.seq_len);
        let (logits, kept) = model
            .infer_with_trace(&enc.tokens, &enc.segments, 1)
            .expect("trace");
        let pred = logits.argmax(0);
        println!("\"{text}\"");
        println!("  prediction: {} ({})", pred, if pred == 1 { "positive" } else { "negative" });
        for (j, _) in retention.iter().enumerate() {
            let row = &kept[j * meta.seq_len..(j + 1) * meta.seq_len];
            let words: Vec<String> = row
                .iter()
                .filter(|&&p| p >= 0)
                .map(|&p| {
                    let id = enc.tokens[p as usize];
                    vocab.word(id).to_string()
                })
                .collect();
            println!("  encoder {}: {}", j + 1, words.join(" "));
        }
        println!();
    }
    println!(
        "Reading the trace: stop-word fillers go first; later encoders keep\n\
         only sentiment carriers + CLS — the diffusion of information makes\n\
         the rest redundant (paper §4.2, Figure 8)."
    );
}
