"""Perf-analysis tooling: kernel VMEM/MXU model and HLO statistics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.hlo_stats import analyze_hlo_text, shape_elems
from compile.kernels.perf import (
    VMEM_BUDGET,
    attention_report,
    encoder_flops,
    ffn_report,
    model_reports,
    power_flop_reduction,
)


def test_kernel_vmem_within_budget_at_paper_scale():
    """The BlockSpec structure must translate to real TPU unchanged: every
    kernel's working set fits VMEM even at BERT_BASE scale (H=768, N=512)."""
    for r in model_reports(heads=12, n=512, d=64, h=768, i=3072):
        assert r.vmem_bytes < VMEM_BUDGET, f"{r.name}: {r.vmem_bytes} over budget"


def test_attention_vmem_scales_with_block():
    small = attention_report(4, 128, 16, bq=32)
    big = attention_report(4, 128, 16, bq=128)
    assert small.vmem_bytes < big.vmem_bytes


def test_mxu_util_improves_with_larger_tiles():
    a = ffn_report(128, 64, 256, bm=8)
    b = ffn_report(128, 64, 256, bm=128)
    assert b.mxu_util >= a.mxu_util


def test_encoder_flops_linear_in_n():
    f1 = encoder_flops(64, 64, 256)
    f2 = encoder_flops(128, 64, 256)
    # attention has an n^2 term, so slightly superlinear, but bounded by 4x.
    assert 1.9 < f2 / f1 < 4.0


def test_power_flop_reduction_matches_retention():
    # keeping half the word-vectors everywhere -> ~2x FLOP reduction
    red = power_flop_reduction([32] * 6, 64, 64, 256)
    assert 1.8 < red < 2.3


def test_paper_rte_reduction_is_plausible():
    ret = [153, 125, 111, 105, 85, 80, 72, 48, 35, 27, 22, 5]
    red = power_flop_reduction(ret, 256, 768, 3072)
    # paper reports 3.4x wall-clock on RTE; the structural FLOP ratio
    # should be in the same regime.
    assert 2.5 < red < 5.5, red


# ---------------------------------------------------------------------------
# HLO stats
# ---------------------------------------------------------------------------

def test_shape_elems():
    assert shape_elems("2,3,4") == 24
    assert shape_elems("") == 1


def test_analyze_counts_ops_and_flops():
    hlo = """
HloModule test
ENTRY main {
  %p0 = f32[8,16]{1,0} parameter(0)
  %p1 = f32[16,4]{1,0} parameter(1)
  %dot.1 = f32[8,4]{1,0} dot(f32[8,16]{1,0} %p0, f32[16,4]{1,0} %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = (f32[8,4]{1,0}) tuple(%dot.1)
}
"""
    st = analyze_hlo_text(hlo)
    assert st.ops["parameter"] == 2
    assert st.ops["dot"] == 1
    assert st.dot_flops == 2 * 8 * 4 * 16
    assert st.param_bytes == 4 * (8 * 16 + 16 * 4)


def test_analyze_real_export_if_present():
    """When artifacts exist, the PoWER graph must contain strictly fewer
    dot-FLOPs than the baseline — the paper's structural claim."""
    import os
    root = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts", "sst2")
    bert = os.path.join(root, "bert", "model.b8.hlo.txt")
    power = os.path.join(root, "power-default", "model.b8.hlo.txt")
    if not (os.path.exists(bert) and os.path.exists(power)):
        pytest.skip("artifacts not built")
    from compile.hlo_stats import analyze_file
    sb = analyze_file(bert)
    sp = analyze_file(power)
    assert sp.dot_flops < sb.dot_flops
    assert sb.dot_flops > 0
