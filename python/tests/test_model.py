"""L2 model semantics: extraction invariants, soft-extract behaviour,
retention derivation, variant construction."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import layers as L
from compile import model as M
from compile.config import BertConfig


def toy_batch(cfg, n=4, seq=16, seed=0):
    rng = np.random.default_rng(seed)
    tokens = rng.integers(4, cfg.vocab_size, size=(n, seq)).astype(np.int32)
    tokens[:, 0] = 2  # CLS
    # Variable-length: PAD the tails.
    for i in range(n):
        cut = rng.integers(seq // 2, seq)
        tokens[i, cut:] = 0
    segs = np.zeros((n, seq), dtype=np.int32)
    return jnp.asarray(tokens), jnp.asarray(segs)


def test_baseline_forward_shapes(tiny_cfg, tiny_params):
    tokens, segs = toy_batch(tiny_cfg)
    fwd = M.make_forward(tiny_cfg, use_pallas=False)
    logits, _ = fwd(tiny_params, tokens, segs)
    assert logits.shape == (4, tiny_cfg.num_classes)
    assert np.all(np.isfinite(np.asarray(logits)))


def test_pallas_and_ref_models_agree(tiny_cfg, tiny_params):
    """Whole-model cross-check: the exported (pallas) graph must equal the
    oracle (ref) graph numerically."""
    tokens, segs = toy_batch(tiny_cfg)
    out_ref, _ = M.make_forward(tiny_cfg, use_pallas=False)(tiny_params, tokens, segs)
    out_pal, _ = M.make_forward(tiny_cfg, use_pallas=True)(tiny_params, tokens, segs)
    np.testing.assert_allclose(np.asarray(out_ref), np.asarray(out_pal), atol=3e-5)


def test_extract_reduces_hidden_sizes(tiny_cfg, tiny_params):
    tokens, segs = toy_batch(tiny_cfg)
    retention = [12, 8, 4]
    fwd = M.make_forward(tiny_cfg, retention=retention, use_pallas=False, collect=True)
    logits, aux = fwd(tiny_params, tokens, segs)
    for j, h in enumerate(aux["hidden"]):
        assert h.shape[1] == retention[j], f"encoder {j}: {h.shape}"
    assert logits.shape == (4, tiny_cfg.num_classes)


def test_cls_always_survives(tiny_cfg, tiny_params):
    tokens, segs = toy_batch(tiny_cfg)
    fwd = M.make_forward(tiny_cfg, retention=[4, 2, 1], use_pallas=False, collect=True)
    _, aux = fwd(tiny_params, tokens, segs)
    for kept in aux["kept"]:
        # original position 0 (CLS) must be in every survivor set
        assert np.all(np.asarray(kept)[:, 0] == 0)


def test_extract_prefers_real_tokens_over_pad(tiny_cfg, tiny_params):
    tokens, segs = toy_batch(tiny_cfg)
    n_real = int(np.sum(np.asarray(tokens)[0] != 0))
    keep = min(8, n_real)
    fwd = M.make_forward(tiny_cfg, retention=[keep, keep, keep],
                         use_pallas=False, collect=True)
    _, aux = fwd(tiny_params, tokens, segs)
    kept0 = np.asarray(aux["kept"][0])[0]
    toks0 = np.asarray(tokens)[0]
    assert np.all(toks0[kept0] != 0), "PAD selected while real tokens remain"


def test_retention_monotone_enforced():
    masses = np.array([5.2, 7.9, 3.1])
    ret = M.derive_retention(masses, seq_len=16)
    assert ret == [6, 6, 4]
    assert all(a >= b for a, b in zip(ret, ret[1:]))


def test_retention_bounds():
    assert M.derive_retention(np.array([100.0, 0.0]), 8) == [8, 1]
    assert M.aggregate_word_vectors([3, 2, 1]) == 6


def test_static_strategies_fixed_positions():
    head = M.static_keep_indices("head", 16, 4, 0)
    assert list(head) == [0, 1, 2, 3]
    r1 = M.static_keep_indices("rand", 16, 4, 1)
    r2 = M.static_keep_indices("rand", 16, 4, 1)
    np.testing.assert_array_equal(r1, r2)  # deterministic per layer
    assert r1[0] == 0  # CLS pinned
    assert len(set(r1.tolist())) == 4


def test_strategy_changes_selection(tiny_cfg, tiny_params):
    tokens, segs = toy_batch(tiny_cfg, seed=3)
    out = {}
    for strat in ("attn", "head", "rand"):
        fwd = M.make_forward(tiny_cfg, retention=[8, 6, 4], strategy=strat,
                             use_pallas=False)
        logits, _ = fwd(tiny_params, tokens, segs)
        out[strat] = np.asarray(logits)
    assert not np.allclose(out["attn"], out["head"])
    assert not np.allclose(out["head"], out["rand"])


def test_soft_forward_mass_and_shapes(tiny_cfg, tiny_params):
    tokens, segs = toy_batch(tiny_cfg)
    seq = tokens.shape[1]
    fwd = M.make_soft_forward(tiny_cfg, use_pallas=False)
    r = jnp.full((tiny_cfg.num_layers, seq), 0.5)
    logits, mass = fwd(tiny_params, r, tokens, segs)
    assert logits.shape == (4, tiny_cfg.num_classes)
    assert mass.shape == (4, tiny_cfg.num_layers)
    np.testing.assert_allclose(np.asarray(mass), 0.5 * seq, atol=1e-4)


def test_soft_forward_r_ones_equals_baseline(tiny_cfg, tiny_params):
    tokens, segs = toy_batch(tiny_cfg)
    seq = tokens.shape[1]
    base, _ = M.make_forward(tiny_cfg, use_pallas=False)(tiny_params, tokens, segs)
    soft_fwd = M.make_soft_forward(tiny_cfg, use_pallas=False)
    soft, _ = soft_fwd(tiny_params, jnp.ones((tiny_cfg.num_layers, seq)), tokens, segs)
    np.testing.assert_allclose(np.asarray(base), np.asarray(soft), atol=2e-5)


def test_head_gates_zero_all_heads_changes_output(tiny_cfg, tiny_params):
    tokens, segs = toy_batch(tiny_cfg)
    fwd = M.make_forward(tiny_cfg, use_pallas=False, with_head_gates=True)
    ones = jnp.ones((tiny_cfg.num_layers, tiny_cfg.num_heads))
    half = ones.at[:, 0].set(0.0)
    a, _ = fwd(tiny_params, tokens, segs, ones)
    b, _ = fwd(tiny_params, tokens, segs, half)
    assert not np.allclose(np.asarray(a), np.asarray(b))


def test_albert_param_sharing():
    cfg = BertConfig(vocab_size=128, hidden_size=16, num_layers=4, num_heads=2,
                     ffn_size=32, max_len=16, share_params=True, embed_factor=8)
    params = L.init_params(jax.random.PRNGKey(1), cfg)
    assert len(params["layers"]) == 1
    assert params["embed"]["word"].shape == (128, 8)
    assert params["embed"]["word_proj"].shape == (8, 16)
    tokens = jnp.asarray(np.full((2, 16), 5, dtype=np.int32))
    segs = jnp.zeros((2, 16), jnp.int32)
    logits, _ = M.make_forward(cfg, use_pallas=False)(params, tokens, segs)
    assert logits.shape == (2, 2)


def test_regression_head():
    cfg = BertConfig(vocab_size=128, hidden_size=16, num_layers=2, num_heads=2,
                     ffn_size=32, max_len=16, num_classes=1)
    params = L.init_params(jax.random.PRNGKey(2), cfg)
    tokens = jnp.asarray(np.full((2, 16), 5, dtype=np.int32))
    logits, _ = M.make_forward(cfg, use_pallas=False)(params, tokens, jnp.zeros_like(tokens))
    assert logits.shape == (2, 1)
