"""Training machinery: losses, metrics, Adam, the three training loops, and
the baseline methods (distillation, head pruning)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import baselines as B
from compile import config as C
from compile import layers as L
from compile import model as M
from compile import train as T


# ---------------------------------------------------------------------------
# Metrics (mirrored in rust/src/eval — keep in sync)
# ---------------------------------------------------------------------------

def test_accuracy_f1_matthews():
    pred = np.array([1, 0, 1, 1])
    y = np.array([1, 0, 0, 1])
    assert T.accuracy(pred, y) == 0.75
    assert abs(T.f1_binary(pred, y) - 2 * 2 / (2 * 2 + 1 + 0)) < 1e-12
    assert -1.0 <= T.matthews(pred, y) <= 1.0
    assert T.matthews(y, y) == 1.0


def test_spearman_perfect():
    assert abs(T.spearman(np.array([1.0, 2.0, 3.0]), np.array([10.0, 20.0, 30.0])) - 1.0) < 1e-12
    assert abs(T.spearman(np.array([1.0, 2.0, 3.0]), np.array([3.0, 2.0, 1.0])) + 1.0) < 1e-12


def test_compute_metric_dispatch():
    out = np.array([[0.2, 0.8], [0.9, 0.1]])
    y = np.array([1, 0])
    assert T.compute_metric("accuracy", out, y) == 1.0
    assert T.compute_metric("f1", out, y) == 1.0
    with pytest.raises(ValueError):
        T.compute_metric("nope", out, y)


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def test_cross_entropy_uniform():
    logits = jnp.zeros((4, 3))
    labels = jnp.asarray([0, 1, 2, 0])
    assert abs(float(T.cross_entropy(logits, labels)) - np.log(3)) < 1e-5


def test_kl_soft_targets_zero_when_equal():
    logits = jnp.asarray(np.random.default_rng(0).normal(size=(4, 3)), jnp.float32)
    # KL(p||p) == H(p) - H(p) -> the soft-target CE equals entropy; against
    # itself the loss is minimal; test monotonicity instead of exact zero.
    same = float(T.kl_soft_targets(logits, logits))
    other = float(T.kl_soft_targets(logits + 3.0 * jnp.flip(logits, 1), logits))
    assert same < other


# ---------------------------------------------------------------------------
# Adam
# ---------------------------------------------------------------------------

def test_adam_reduces_quadratic():
    params = {"x": jnp.asarray([5.0, -3.0])}
    state = T.adam_init(params)
    for t in range(200):
        grads = {"x": 2 * params["x"]}
        params, state = T.adam_step(params, grads, state, lr=0.1)
    assert float(jnp.abs(params["x"]).max()) < 0.05


def test_adam_lr_mult_scales_updates():
    params = {"a": jnp.ones(()), "b": jnp.ones(())}
    state = T.adam_init(params)
    grads = {"a": jnp.ones(()), "b": jnp.ones(())}
    mult = {"a": 1.0, "b": 10.0}
    p2, _ = T.adam_step(params, grads, state, lr=0.01, lr_mult=mult)
    da = float(params["a"] - p2["a"])
    db = float(params["b"] - p2["b"])
    assert db > 5 * da


def test_lr_schedule_shape():
    lrs = [float(T.lr_schedule(jnp.asarray(float(s)), 100, 1.0, 0.1)) for s in range(100)]
    assert lrs[0] == 0.0
    assert max(lrs) == pytest.approx(1.0, rel=0.2)
    assert lrs[-1] < 0.05


def test_batches_cycle_and_shapes():
    rng = np.random.default_rng(0)
    xs = np.arange(10)
    got = list(T.batches(rng, (xs,), batch_size=4, steps=5))
    assert len(got) == 5
    assert all(g[0].shape == (4,) for g in got)


# ---------------------------------------------------------------------------
# Training loops (tiny end-to-end)
# ---------------------------------------------------------------------------

def test_classifier_training_reduces_loss(tiny_cfg, tiny_params, sst2_task, sst2_data):
    fwd = M.make_forward(tiny_cfg, use_pallas=False)
    tc = C.TrainConfig(steps=30, batch_size=8, lr=3e-3)
    _, losses = T.train_classifier(fwd, tiny_params, sst2_data, sst2_task, tc)
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_soft_extract_training_shrinks_mass(tiny_cfg, tiny_params, sst2_task, sst2_data):
    fwd_soft = M.make_soft_forward(tiny_cfg, use_pallas=False)
    seq = sst2_data[0].shape[1]
    r0 = jnp.ones((tiny_cfg.num_layers, seq))
    tc = C.TrainConfig(steps=25, batch_size=8, lr=1e-3, soft_extract_lr=5e-2,
                       lambda_reg=5e-3)
    _, r, _ = T.train_soft_extract(fwd_soft, tiny_params, r0, sst2_data, sst2_task, tc)
    r = np.asarray(r)
    assert np.all((r >= 0.0) & (r <= 1.0)), "projection onto [0,1] violated"
    masses = r.sum(axis=1)
    assert masses.sum() < tiny_cfg.num_layers * seq  # regularizer did shrink
    # Later encoders are penalized more (j-scaling) -> typically lighter;
    # with this few steps allow slack rather than strict ordering.
    assert masses[-1] <= masses[0] + 0.1 * seq


def test_distillation_runs_and_learns(tiny_cfg, tiny_params, sst2_task, sst2_data):
    s_cfg, s_params, losses = B.train_encoder_eliminated(
        "distil", tiny_params, None, tiny_cfg, 2, sst2_data, sst2_task,
        C.TrainConfig(steps=12, batch_size=8), use_pallas=False)
    assert s_cfg.num_layers == 2
    assert len(s_params["layers"]) == 2
    assert np.isfinite(losses).all()


def test_pkd_layer_map():
    m = B.pkd_layer_map(3, 6)
    assert len(m) == 3
    assert all(t < 6 for _, t in m)
    assert m[0][1] <= m[-1][1]


def test_head_importance_and_pruning(tiny_cfg, tiny_params, sst2_task, sst2_data):
    imp = B.head_importance(tiny_params, tiny_cfg, sst2_data, sst2_task,
                            batch_size=8, num_batches=2, use_pallas=False)
    assert imp.shape == (tiny_cfg.num_layers, tiny_cfg.num_heads)
    assert np.all(imp >= 0)
    gates = B.prune_heads(imp, keep_fraction=0.5)
    assert gates.sum() == round(0.5 * gates.size)
    assert np.all(gates.sum(axis=1) >= 1)  # every layer keeps a head


def test_bake_head_gates_zeroes_outputs(tiny_cfg, tiny_params):
    gates = np.ones((tiny_cfg.num_layers, tiny_cfg.num_heads))
    gates[0, 0] = 0.0
    baked = B.apply_head_gates_to_params(tiny_params, tiny_cfg, gates)
    d = tiny_cfg.head_dim
    assert np.allclose(np.asarray(baked["layers"][0]["wv"])[:, :d], 0.0)
    # Gated-forward and baked-forward agree.
    tokens = jnp.asarray(np.full((2, 8), 5, dtype=np.int32))
    segs = jnp.zeros_like(tokens)
    fwd_g = M.make_forward(tiny_cfg, use_pallas=False, with_head_gates=True)
    fwd = M.make_forward(tiny_cfg, use_pallas=False)
    a, _ = fwd_g(tiny_params, tokens, segs, jnp.asarray(gates))
    b, _ = fwd(baked, tokens, segs)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5)


def test_predict_all_pads_last_batch(tiny_cfg, tiny_params, sst2_data):
    fwd = M.make_forward(tiny_cfg, use_pallas=False)
    tok, seg, _ = sst2_data
    out = T.predict_all(fwd, tiny_params, tok[:10], seg[:10], batch_size=8)
    assert out.shape[0] == 10
