"""Shared fixtures: a tiny BertConfig + vocab + dataset so every test runs in
seconds on one CPU core. All tests use the same code paths as the full
pipeline (only scaled down)."""

import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import dataclasses

import jax
import numpy as np
import pytest

from compile import config as C
from compile import data as D
from compile import layers as L
from compile.tokenizer import build_vocab


@pytest.fixture(scope="session")
def tiny_cfg():
    return C.BertConfig(vocab_size=256, hidden_size=16, num_layers=3,
                        num_heads=2, ffn_size=32, max_len=32)


@pytest.fixture(scope="session")
def vocab(tiny_cfg):
    return build_vocab(tiny_cfg.vocab_size)


@pytest.fixture(scope="session")
def sst2_task():
    return dataclasses.replace(C.TASKS["sst2"], train_size=96, test_size=48,
                               seq_len=16)


@pytest.fixture(scope="session")
def sst2_data(sst2_task, vocab):
    return D.generate(sst2_task, vocab, "train")


@pytest.fixture(scope="session")
def tiny_params(tiny_cfg):
    return L.init_params(jax.random.PRNGKey(0), tiny_cfg)
