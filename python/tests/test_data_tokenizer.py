"""Synthetic task suite + tokenizer: label consistency (oracle checks),
determinism, shapes, vocabulary structure."""

import dataclasses

import numpy as np
import pytest

from compile import config as C
from compile import data as D
from compile.tokenizer import CLS_ID, PAD_ID, SEP_ID, Tokenizer, Vocab, build_vocab


def small(task_name, **kw):
    base = {"train_size": 64, "test_size": 32}
    base.update(kw)
    return dataclasses.replace(C.TASKS[task_name], **base)


# ---------------------------------------------------------------------------
# Vocab / tokenizer
# ---------------------------------------------------------------------------

def test_vocab_structure(vocab):
    assert vocab.words[PAD_ID] == "[PAD]"
    assert vocab.words[CLS_ID] == "[CLS]"
    for fam in ("pos", "neg", "negation", "entity", "relation", "filler"):
        s, e = vocab.families[fam]
        assert e > s
        assert all(vocab.words[i].startswith(fam) for i in range(s, e))


def test_vocab_roundtrip(tmp_path, vocab):
    p = tmp_path / "vocab.json"
    vocab.save(str(p))
    v2 = Vocab.load(str(p))
    assert v2.words == vocab.words
    assert v2.families == vocab.families


def test_tokenizer_single_layout(vocab):
    t = Tokenizer(vocab)
    ids, segs = t.encode(["filler_0", "filler_1"], None, 8)
    assert ids[0] == CLS_ID
    assert ids[3] == SEP_ID
    assert ids[4:] == [PAD_ID] * 4
    assert segs == [0] * 8


def test_tokenizer_pair_layout(vocab):
    t = Tokenizer(vocab)
    ids, segs = t.encode(["filler_0"], ["filler_1", "filler_2"], 8)
    assert ids[0] == CLS_ID
    assert segs == [0, 0, 0, 1, 1, 1, 0, 0]
    assert ids.count(SEP_ID) == 2


def test_tokenizer_truncation(vocab):
    t = Tokenizer(vocab)
    ids, _ = t.encode(["filler_0"] * 50, ["filler_1"] * 50, 16)
    assert len(ids) == 16
    assert ids.count(PAD_ID) == 0


def test_tokenizer_oov(vocab):
    t = Tokenizer(vocab)
    ids, _ = t.encode(["xyzzy"], None, 4)
    assert ids[1] == 1  # UNK


# ---------------------------------------------------------------------------
# Generators: determinism + shapes + oracle label checks
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", list(C.TASKS.keys()))
def test_generator_shapes_and_determinism(name, vocab):
    task = small(name)
    a1 = D.generate(task, vocab, "test")
    a2 = D.generate(task, vocab, "test")
    np.testing.assert_array_equal(a1[0], a2[0])
    np.testing.assert_array_equal(a1[2], a2[2])
    assert a1[0].shape == (32, task.seq_len)
    assert a1[0].dtype == np.int32
    # CLS at position 0 everywhere.
    assert np.all(a1[0][:, 0] == CLS_ID)


def test_splits_differ(vocab):
    task = small("sst2")
    tr = D.generate(task, vocab, "train")
    te = D.generate(task, vocab, "test")
    assert not np.array_equal(tr[0][: len(te[0])], te[0])


def test_sentiment_oracle_consistency(vocab):
    """Labels must be recoverable by the generative rule (clean data)."""
    task = small("sst2", test_size=256)
    tok, _, y = D.generate(task, vocab, "test")
    pos = set(vocab.family_ids("pos"))
    neg = set(vocab.family_ids("neg"))
    nega = set(vocab.family_ids("negation"))
    correct = 0
    for i in range(len(y)):
        score = 0
        ids = tok[i]
        for j, t in enumerate(ids):
            t = int(t)
            flip = j > 0 and int(ids[j - 1]) in nega
            if t in pos:
                score += -1 if flip else 1
            elif t in neg:
                score += 1 if flip else -1
        correct += (score > 0) == (y[i] == 1)
    assert correct / len(y) == 1.0


def test_nli_entailment_oracle(vocab):
    """For NLI: label=1 (entail) iff the hypothesis triple appears verbatim
    in the premise."""
    task = small("rte", test_size=128, seq_len=64)
    tok, segs, y = D.generate(task, vocab, "test")
    for i in range(len(y)):
        row = tok[i]
        seg = segs[i]
        hyp = [int(t) for t, s in zip(row, seg) if s == 1 and t > 3]
        prem = [int(t) for t, s in zip(row, seg) if s == 0 and t > 3]
        trip = tuple(hyp[:3])
        found = any(tuple(prem[j : j + 3]) == trip for j in range(len(prem) - 2))
        assert found == (y[i] == 1), f"row {i}"


def test_regression_labels_in_range(vocab):
    task = small("stsb")
    _, _, y = D.generate(task, vocab, "test")
    assert y.dtype == np.float32
    assert np.all((y >= 0.0) & (y <= 5.0))


def test_classes_are_balanced_enough(vocab):
    task = small("mnli-m", test_size=300)
    _, _, y = D.generate(task, vocab, "test")
    counts = np.bincount(y.astype(int), minlength=3)
    assert np.all(counts > 300 / 3 * 0.5), counts


def test_variable_lengths_have_padding(vocab):
    task = small("sst2", test_size=64)
    tok, _, _ = D.generate(task, vocab, "test")
    pad_counts = (tok == PAD_ID).sum(axis=1)
    assert pad_counts.max() > 0
    assert pad_counts.std() > 0  # lengths actually vary
