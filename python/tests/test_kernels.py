"""L1 correctness: Pallas kernels (interpret=True) vs pure-jnp oracles.

This is the CORE correctness signal of the compile path: if these pass, the
AOT-exported HLO computes what ref.py defines. Hypothesis sweeps shapes and
dtypes; fixed tests pin the paper-relevant invariants.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import PALLAS, REF

ATOL = 2e-5


def rand(rng, *shape):
    return jnp.asarray(rng.normal(size=shape), jnp.float32)


# ---------------------------------------------------------------------------
# mha_with_scores
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    heads=st.sampled_from([1, 2, 4]),
    n=st.sampled_from([4, 8, 16, 32]),
    d=st.sampled_from([4, 8, 16]),
    valid_frac=st.floats(0.3, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_mha_matches_ref(heads, n, d, valid_frac, seed):
    rng = np.random.default_rng(seed)
    q, k, v = rand(rng, heads, n, d), rand(rng, heads, n, d), rand(rng, heads, n, d)
    n_valid = max(1, int(valid_frac * n))
    mask = jnp.asarray((np.arange(n) < n_valid).astype(np.float32))
    ctx_p, sig_p = PALLAS.mha_with_scores(q, k, v, mask)
    ctx_r, sig_r = REF.mha_with_scores(q, k, v, mask)
    np.testing.assert_allclose(ctx_p, ctx_r, atol=ATOL)
    np.testing.assert_allclose(sig_p, sig_r, atol=ATOL)


def test_mha_blocked_grid_matches():
    rng = np.random.default_rng(0)
    q, k, v = (rand(rng, 4, 32, 8) for _ in range(3))
    mask = jnp.ones(32)
    ctx_full, sig_full = PALLAS.mha_with_scores(q, k, v, mask, block_q=32)
    ctx_blk, sig_blk = PALLAS.mha_with_scores(q, k, v, mask, block_q=8)
    np.testing.assert_allclose(ctx_full, ctx_blk, atol=ATOL)
    np.testing.assert_allclose(sig_full, sig_blk, atol=ATOL)


def test_sig_is_masked_column_sums():
    """Sig(w) = sum over heads and VALID query rows of A_h[w', w]."""
    rng = np.random.default_rng(1)
    q, k, v = (rand(rng, 2, 8, 4) for _ in range(3))
    mask = jnp.asarray([1, 1, 1, 1, 1, 0, 0, 0], jnp.float32)
    _, sig = PALLAS.mha_with_scores(q, k, v, mask)
    sig = np.asarray(sig)
    # PAD columns receive (almost) no attention -> near-zero significance.
    assert np.all(sig[5:] < 1e-3)
    # Valid columns: each valid row contributes a probability mass of 1
    # split over valid columns; 2 heads * 5 rows = total mass 10.
    assert abs(sig.sum() - 10.0) < 1e-2

def test_mha_rows_sum_to_one_property():
    """Softmax invariant: total significance mass == heads * valid rows."""
    rng = np.random.default_rng(2)
    for n_valid in [1, 3, 8]:
        q, k, v = (rand(rng, 3, 8, 4) for _ in range(3))
        mask = jnp.asarray((np.arange(8) < n_valid).astype(np.float32))
        _, sig = PALLAS.mha_with_scores(q, k, v, mask)
        assert abs(float(jnp.sum(sig)) - 3.0 * n_valid) < 1e-2


def test_mha_vmap_batches():
    rng = np.random.default_rng(3)
    qb, kb, vb = (rand(rng, 4, 2, 8, 4) for _ in range(3))
    mask = jnp.ones((4, 8))
    ctx, sig = jax.vmap(PALLAS.mha_with_scores)(qb, kb, vb, mask)
    ctx_r, sig_r = jax.vmap(REF.mha_with_scores)(qb, kb, vb, mask)
    np.testing.assert_allclose(ctx, ctx_r, atol=ATOL)
    np.testing.assert_allclose(sig, sig_r, atol=ATOL)


# ---------------------------------------------------------------------------
# ffn
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(
    n=st.sampled_from([4, 16, 32, 64]),
    h=st.sampled_from([8, 16]),
    i=st.sampled_from([16, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_ffn_matches_ref(n, h, i, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, n, h)
    w1, b1 = rand(rng, h, i) * 0.1, rand(rng, i) * 0.1
    w2, b2 = rand(rng, i, h) * 0.1, rand(rng, h) * 0.1
    np.testing.assert_allclose(
        PALLAS.ffn(x, w1, b1, w2, b2), REF.ffn(x, w1, b1, w2, b2), atol=ATOL)


def test_ffn_row_blocking_invariance():
    rng = np.random.default_rng(4)
    x = rand(rng, 32, 8)
    w1, b1, w2, b2 = rand(rng, 8, 16), rand(rng, 16), rand(rng, 16, 8), rand(rng, 8)
    full = PALLAS.ffn(x, w1, b1, w2, b2, block_rows=32)
    blocked = PALLAS.ffn(x, w1, b1, w2, b2, block_rows=8)
    np.testing.assert_allclose(full, blocked, atol=ATOL)


# ---------------------------------------------------------------------------
# layernorm_residual
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(
    n=st.sampled_from([1, 4, 16, 64]),
    h=st.sampled_from([8, 16, 64]),
    seed=st.integers(0, 2**31 - 1),
)
def test_layernorm_matches_ref(n, h, seed):
    rng = np.random.default_rng(seed)
    x, res = rand(rng, n, h), rand(rng, n, h)
    g, b = rand(rng, h), rand(rng, h)
    np.testing.assert_allclose(
        PALLAS.layernorm_residual(x, res, g, b),
        REF.layernorm_residual(x, res, g, b), atol=ATOL)


def test_layernorm_output_is_normalized():
    rng = np.random.default_rng(5)
    x, res = rand(rng, 8, 32), rand(rng, 8, 32)
    out = PALLAS.layernorm_residual(x, res, jnp.ones(32), jnp.zeros(32))
    out = np.asarray(out)
    np.testing.assert_allclose(out.mean(axis=-1), 0.0, atol=1e-5)
    np.testing.assert_allclose(out.std(axis=-1), 1.0, atol=1e-2)


# ---------------------------------------------------------------------------
# soft_extract
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(n=st.sampled_from([2, 8, 32]), h=st.sampled_from([4, 16]),
       seed=st.integers(0, 2**31 - 1))
def test_soft_extract_matches_ref(n, h, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, n, h)
    ranks = jnp.asarray(rng.permutation(n).astype(np.int32))
    r = jnp.asarray(rng.random(n), jnp.float32)
    np.testing.assert_allclose(
        PALLAS.soft_extract(x, ranks, r), REF.soft_extract(x, ranks, r), atol=ATOL)


def test_soft_extract_all_ones_is_identity():
    rng = np.random.default_rng(6)
    x = rand(rng, 8, 4)
    ranks = jnp.asarray(rng.permutation(8).astype(np.int32))
    np.testing.assert_allclose(PALLAS.soft_extract(x, ranks, jnp.ones(8)), x, atol=1e-7)


def test_soft_extract_grad_flows_to_r():
    """The configuration search trains r through this multiply."""
    rng = np.random.default_rng(7)
    x = rand(rng, 6, 4)
    ranks = jnp.asarray(rng.permutation(6).astype(np.int32))

    def loss(r):
        return jnp.sum(PALLAS.soft_extract(x, ranks, r) ** 2)

    g = jax.grad(loss)(jnp.full((6,), 0.5))
    assert np.all(np.abs(np.asarray(g)) > 0)
