"""Word-level tokenizer over the synthetic vocabulary.

Mirrored exactly by the Rust implementation in ``rust/src/tokenizer`` —
both sides load the same ``artifacts/vocab.json``. Keep the two in sync:
whitespace-split words, exact-match lookup, OOV -> [UNK], [CLS] prepended,
[SEP] between segments and after the last one, [PAD] to ``seq_len``.
"""

from __future__ import annotations

import json
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

PAD, UNK, CLS, SEP = "[PAD]", "[UNK]", "[CLS]", "[SEP]"
PAD_ID, UNK_ID, CLS_ID, SEP_ID = 0, 1, 2, 3
SPECIALS = [PAD, UNK, CLS, SEP]


@dataclass
class Vocab:
    words: List[str]
    families: Dict[str, Tuple[int, int]]  # family -> [start, end) id range

    def __post_init__(self):
        self.index = {w: i for i, w in enumerate(self.words)}

    def __len__(self) -> int:
        return len(self.words)

    def id(self, word: str) -> int:
        return self.index.get(word, UNK_ID)

    def family_ids(self, family: str) -> range:
        s, e = self.families[family]
        return range(s, e)

    def family_of(self, token_id: int) -> Optional[str]:
        for fam, (s, e) in self.families.items():
            if s <= token_id < e:
                return fam
        return None

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump({"words": self.words, "families": {k: list(v) for k, v in self.families.items()}}, f)

    @staticmethod
    def load(path: str) -> "Vocab":
        with open(path) as f:
            d = json.load(f)
        return Vocab(d["words"], {k: (v[0], v[1]) for k, v in d["families"].items()})


# Family mix (fractions of the non-special vocab budget). The synthetic
# language needs: sentiment-bearing words, negations that flip them,
# entities/relations for NLI-style tasks, word classes for the grammar
# (CoLA-analog) task, and a large mass of filler so that label evidence is
# sparse — the property that makes attention-based word-vector selection
# (Attn-WS) genuinely better than positional heuristics (Head-WS).
_FAMILY_MIX = [
    ("pos", 0.06),
    ("neg", 0.06),
    ("negation", 0.01),
    ("intens", 0.02),
    ("entity", 0.22),
    ("relation", 0.03),
    ("noun", 0.08),
    ("verb", 0.08),
    ("adj", 0.06),
    ("query", 0.01),
    ("filler", 0.37),
]


def build_vocab(vocab_size: int) -> Vocab:
    budget = vocab_size - len(SPECIALS)
    assert budget >= 100, "vocab too small for the synthetic language"
    words = list(SPECIALS)
    families: Dict[str, Tuple[int, int]] = {}
    sizes = {fam: max(2, int(frac * budget)) for fam, frac in _FAMILY_MIX}
    # Give any rounding slack to filler.
    slack = budget - sum(sizes.values())
    sizes["filler"] += slack
    for fam, _ in _FAMILY_MIX:
        start = len(words)
        words.extend(f"{fam}_{i}" for i in range(sizes[fam]))
        families[fam] = (start, len(words))
    assert len(words) == vocab_size
    return Vocab(words, families)


class Tokenizer:
    """Encodes text (or pre-split word lists) into fixed-length id arrays."""

    def __init__(self, vocab: Vocab):
        self.vocab = vocab

    def encode(
        self,
        segment_a: Sequence[str] | str,
        segment_b: Optional[Sequence[str] | str] = None,
        seq_len: int = 64,
    ) -> Tuple[List[int], List[int]]:
        """Returns (token_ids, segment_ids), both of length ``seq_len``.

        Layout: [CLS] a... [SEP] (b... [SEP])? [PAD]*
        Truncates segments right-first to fit, like BERT's simple strategy.
        """
        a = segment_a.split() if isinstance(segment_a, str) else list(segment_a)
        b = (segment_b.split() if isinstance(segment_b, str) else list(segment_b)) if segment_b is not None else None
        n_special = 2 + (1 if b is not None else 0)
        # Truncate the longer segment first until the pair fits.
        if b is None:
            a = a[: seq_len - n_special]
        else:
            while len(a) + len(b) > seq_len - n_special:
                if len(a) >= len(b):
                    a = a[:-1]
                else:
                    b = b[:-1]
        ids = [CLS_ID] + [self.vocab.id(w) for w in a] + [SEP_ID]
        segs = [0] * len(ids)
        if b is not None:
            ids += [self.vocab.id(w) for w in b] + [SEP_ID]
            segs += [1] * (len(b) + 1)
        pad = seq_len - len(ids)
        ids += [PAD_ID] * pad
        segs += [0] * pad
        return ids, segs

    def decode(self, ids: Sequence[int], skip_special: bool = True) -> List[str]:
        out = []
        for i in ids:
            w = self.vocab.words[i] if 0 <= int(i) < len(self.vocab.words) else UNK
            if skip_special and w in SPECIALS:
                continue
            out.append(w)
        return out
