"""Incremental training + export pipeline:  python -m compile.pipeline

Orchestrates, per dataset:
  1. fine-tune baseline BERT            -> artifacts/<ds>/bert/
  2. PoWER 3-step training (paper §3.4) -> artifacts/<ds>/power-default/
  3. (pareto datasets) lambda sweep + DistilBERT/PKD/Head-Prune baselines
  4. (GLUE) ALBERT and PoWER-ALBERT
  5. (sst2) Table-4 selection-strategy ablation + debug/anecdote artifact
and writes artifacts/index.json for the Rust registry.

Every (dataset, variant) step is skipped when its artifact already exists
with a matching config hash, so the pipeline is safely re-runnable and can
be extended incrementally (`make artifacts` is a cheap no-op when fresh).

Checkpoints (trained weights, reusable across variants) live in
checkpoints/; only AOT artifacts + test splits land in artifacts/.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import aot
from . import baselines as B
from . import data as D
from . import layers as L
from . import model as M
from . import train as T
from .config import GLUE_TASKS, TASKS, BertConfig, ReproProfile, TaskSpec, config_hash, get_profile
from .params_io import load_params, save_params
from .tokenizer import Vocab, build_vocab

# Bumped when the AOT exporter changes without a training change: lets the
# pipeline re-export from checkpoints instead of retraining.
EXPORT_VERSION = 3

# Kernel path for AOT export. The Pallas kernels (use_pallas=True) are the
# TPU-targeted implementation, verified against the pure-jnp oracles in
# pytest; interpret=True lowering scalarizes their grids into loops that
# XLA *CPU* executes ~6x slower at batch>=8 (EXPERIMENTS.md SPerf L2), so
# CPU artifacts are exported through the numerically-identical oracle path.
EXPORT_USE_PALLAS = False

ART = os.environ.get("POWERBERT_ARTIFACTS", os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
CKPT = os.environ.get("POWERBERT_CHECKPOINTS", os.path.join(os.path.dirname(__file__), "..", "..", "checkpoints"))


def log(msg: str) -> None:
    print(f"[pipeline {time.strftime('%H:%M:%S')}] {msg}", flush=True)


class Pipeline:
    def __init__(self, profile: ReproProfile):
        self.prof = profile
        os.makedirs(ART, exist_ok=True)
        os.makedirs(CKPT, exist_ok=True)
        self.vocab = self._ensure_vocab()
        self._data_cache: Dict = {}

    # -- shared ---------------------------------------------------------

    def _ensure_vocab(self) -> Vocab:
        path = os.path.join(ART, "vocab.json")
        if os.path.exists(path):
            return Vocab.load(path)
        v = build_vocab(self.prof.bert.vocab_size)
        v.save(path)
        log(f"vocab ({len(v)} words) -> {path}")
        return v

    def task(self, name: str) -> TaskSpec:
        t = TASKS[name]
        s = self.prof.data_scale
        if s != 1.0:
            t = dataclasses.replace(t, train_size=max(64, int(t.train_size * s)),
                                    test_size=max(64, int(t.test_size * s)))
        return t

    def cfg_for(self, task: TaskSpec, **kw) -> BertConfig:
        return dataclasses.replace(self.prof.bert, num_classes=task.num_classes,
                                   max_len=max(self.prof.bert.max_len, task.seq_len), **kw)

    def tc_for(self, task: TaskSpec, tc):
        """Scale a TrainConfig for long-sequence datasets: smaller batches
        and fewer steps keep the single-core wall time bounded."""
        if task.seq_len >= 128:
            # N=128 steps cost ~4x the N=32 ones on this single core; halve
            # both batch and steps to keep the full-suite wall time bounded.
            return dataclasses.replace(tc, batch_size=max(8, tc.batch_size // 4),
                                       steps=max(40, int(tc.steps * 0.5)))
        return tc

    def data(self, task: TaskSpec, split: str):
        key = (task.name, split)
        if key not in self._data_cache:
            self._data_cache[key] = D.generate(task, self.vocab, split)
        return self._data_cache[key]

    def _fresh(self, out_dir: str, chash: str) -> bool:
        meta = os.path.join(out_dir, "meta.json")
        if not os.path.exists(meta):
            return False
        try:
            with open(meta) as f:
                return json.load(f).get("config_hash") == chash
        except Exception:
            return False

    def export(self, ds: str, variant: str, fwd, params, cfg, task, extra_meta: Dict):
        out_dir = os.path.join(ART, ds, variant)
        meta = {
            "dataset": ds, "variant": variant, "metric": task.metric,
            "task": task.task, "paper_seq_len": task.paper_seq_len,
            "config_hash": extra_meta.pop("config_hash"), **extra_meta,
        }
        seq_buckets = sorted({
            max(8, int(task.seq_len * f)) for f in self.prof.seq_bucket_fracs
        } - {task.seq_len})
        aot.export_variant(out_dir, fwd, params, cfg, task.seq_len,
                           self.prof.batch_sizes, meta, seq_buckets=seq_buckets)
        log(f"exported {ds}/{variant}")

    def ensure_test_split(self, ds: str, task: TaskSpec):
        path = os.path.join(ART, ds, "test.npz")
        if os.path.exists(path):
            # Guard against stale splits from a different profile scale.
            try:
                with np.load(path) as z:
                    if z["tokens"].shape == (task.test_size, task.seq_len):
                        return
            except Exception:
                pass
        tok, sg, y = self.data(task, "test")
        aot.export_test_split(os.path.join(ART, ds), tok, sg, y)

    # -- steps ------------------------------------------------------------

    def baseline(self, ds: str, albert: bool = False):
        """Fine-tuned baseline (BERT or ALBERT)."""
        task = self.task(ds)
        name = "albert" if albert else "bert"
        cfg = self.cfg_for(task, share_params=albert,
                           embed_factor=16 if albert else 0)
        ft = self.tc_for(task, self.prof.finetune)
        train_hash = config_hash(cfg, task, ft)
        chash = f"{train_hash}-v{EXPORT_VERSION}"
        out_dir = os.path.join(ART, ds, name)
        ckpt = os.path.join(CKPT, ds, f"{name}.npz")
        self.ensure_test_split(ds, task)
        if self._fresh(out_dir, chash):
            return cfg, load_params(ckpt), None
        # Re-export fast path: training inputs unchanged, exporter bumped.
        meta_p = os.path.join(out_dir, "meta.json")
        if os.path.exists(ckpt) and os.path.exists(meta_p):
            try:
                with open(meta_p) as f:
                    old = json.load(f)
            except Exception:
                old = {}
            if old.get("train_hash") == train_hash or old.get("config_hash") == train_hash:
                params = load_params(ckpt)
                dev = old.get("dev_metric")
                log(f"{ds}: re-exporting {name} (exporter v{EXPORT_VERSION})")
                self.export(ds, name, M.make_forward(cfg, use_pallas=EXPORT_USE_PALLAS),
                            params, cfg, task,
                            {"config_hash": chash, "train_hash": train_hash,
                             "dev_metric": dev, "kind": name})
                return cfg, params, dev
        log(f"{ds}: fine-tuning {name} ...")
        # mnli-mm evaluates the mnli-m model on a shifted test distribution
        # (like the paper's matched/mismatched split) — reuse its weights.
        if ds == "mnli-mm":
            src = os.path.join(CKPT, "mnli-m", f"{name}.npz")
            if os.path.exists(src):
                params = load_params(src)
                fwd = M.make_forward(cfg, use_pallas=EXPORT_USE_PALLAS)
                dev = T.evaluate(M.make_forward(cfg, use_pallas=False), params,
                                 self.data(task, "test"), task)
                os.makedirs(os.path.dirname(ckpt), exist_ok=True)
                save_params(ckpt, params)
                self.export(ds, name, fwd, params, cfg, task,
                            {"config_hash": chash, "train_hash": train_hash,
                             "dev_metric": dev, "kind": name})
                return cfg, params, dev
        params = L.init_params(jax.random.PRNGKey(task.seed), cfg)
        fwd_train = M.make_forward(cfg, use_pallas=False)
        params, _ = T.train_classifier(fwd_train, params, self.data(task, "train"),
                                       task, ft)
        dev = T.evaluate(fwd_train, params, self.data(task, "test"), task)
        log(f"{ds}: {name} dev {task.metric} = {dev:.4f}")
        os.makedirs(os.path.dirname(ckpt), exist_ok=True)
        save_params(ckpt, params)
        self.export(ds, name, M.make_forward(cfg, use_pallas=EXPORT_USE_PALLAS), params, cfg,
                    task, {"config_hash": chash, "train_hash": train_hash,
                           "dev_metric": dev, "kind": name})
        return cfg, params, dev

    def power(self, ds: str, lam: float, variant: str, albert: bool = False,
              base=None, export_debug: bool = False):
        """PoWER 3-step training for one lambda; exports the artifact."""
        task = self.task(ds)
        cfg, params, _ = base if base is not None else self.baseline(ds, albert)
        sc = self.tc_for(task, dataclasses.replace(self.prof.config_search, lambda_reg=lam))
        rt = self.tc_for(task, self.prof.retrain)
        train_hash = config_hash(cfg, task, sc, rt)
        chash = f"{train_hash}-v{EXPORT_VERSION}"
        out_dir = os.path.join(ART, ds, variant)
        if self._fresh(out_dir, chash):
            return
        ckpt = os.path.join(CKPT, ds, f"{variant}.npz")
        meta_p = os.path.join(out_dir, "meta.json")
        # Re-export fast path: same training inputs, newer exporter version.
        if os.path.exists(ckpt) and os.path.exists(meta_p):
            try:
                with open(meta_p) as f:
                    old = json.load(f)
            except Exception:
                old = {}
            if old.get("train_hash") == train_hash and old.get("retention"):
                retention = old["retention"]
                p3 = load_params(ckpt)
                dev = old.get("dev_metric")
                log(f"{ds}: re-exporting {variant} (exporter v{EXPORT_VERSION})")
                fwd_ex = M.make_forward(cfg, retention=retention, use_pallas=EXPORT_USE_PALLAS)
                self.export(ds, variant, fwd_ex, p3, cfg, task, {
                    "config_hash": chash, "train_hash": train_hash,
                    "dev_metric": dev, "kind": "power",
                    "retention": retention, "lambda": lam,
                    "aggregate_word_vectors": int(sum(retention)),
                    "baseline_word_vectors": int(cfg.num_layers * task.seq_len),
                })
                if export_debug:
                    self._export_debug(ds, variant, cfg, task, p3, retention, chash)
                return
        log(f"{ds}: PoWER config-search (lambda={lam}) ...")
        fwd_soft = M.make_soft_forward(cfg, use_pallas=False)
        r0 = jnp.ones((cfg.num_layers, task.seq_len))
        p2, r, _ = T.train_soft_extract(fwd_soft, params, r0,
                                        self.data(task, "train"), task, sc)
        masses = np.asarray(jnp.sum(jnp.clip(r, 0, 1), axis=1))
        retention = M.derive_retention(masses, task.seq_len)
        log(f"{ds}: retention {retention} "
            f"(agg {sum(retention)}/{cfg.num_layers * task.seq_len})")
        fwd_ex_train = M.make_forward(cfg, retention=retention, use_pallas=False)
        p3, _ = T.train_classifier(fwd_ex_train, p2, self.data(task, "train"),
                                   task, rt)
        dev = T.evaluate(fwd_ex_train, p3, self.data(task, "test"), task)
        log(f"{ds}: {variant} dev {task.metric} = {dev:.4f}")
        save_params(ckpt, p3)
        fwd_ex = M.make_forward(cfg, retention=retention, use_pallas=EXPORT_USE_PALLAS)
        self.export(ds, variant, fwd_ex, p3, cfg, task, {
            "config_hash": chash, "train_hash": train_hash,
            "dev_metric": dev, "kind": "power",
            "retention": retention, "lambda": lam,
            "aggregate_word_vectors": int(sum(retention)),
            "baseline_word_vectors": int(cfg.num_layers * task.seq_len),
        })
        if export_debug:
            self._export_debug(ds, variant, cfg, task, p3, retention, chash)

    def _export_debug(self, ds, variant, cfg, task, p3, retention, chash):
        """Debug artifact: also emits kept-position traces (Figure 8)."""
        out_dbg = os.path.join(ART, ds, f"{variant}-debug")
        fwd_dbg = M.make_forward(cfg, retention=retention,
                                 use_pallas=EXPORT_USE_PALLAS, collect=True)
        os.makedirs(out_dbg, exist_ok=True)
        from .params_io import flatten_params
        named = flatten_params(p3)
        np.savez(os.path.join(out_dbg, "weights.npz"), **dict(named))
        text = aot.lower_infer_fn(fwd_dbg, p3, 1, task.seq_len, extra_outputs=True)
        with open(os.path.join(out_dbg, "model.b1.hlo.txt"), "w") as f:
            f.write(text)
        with open(os.path.join(out_dbg, "meta.json"), "w") as f:
            json.dump({"dataset": ds, "variant": f"{variant}-debug",
                       "kind": "power-debug", "seq_len": task.seq_len,
                       "batch_sizes": [1], "hlo": {"1": "model.b1.hlo.txt"},
                       "weights": "weights.npz", "retention": retention,
                       "num_layers": cfg.num_layers,
                       "num_classes": cfg.num_classes,
                       "param_order": [n for n, _ in named],
                       "metric": task.metric,
                       "config_hash": chash}, f, indent=1)
        log(f"exported {ds}/{variant}-debug")

    def long_seq(self, ds: str = "sst2", seq_len: int = 256):
        """Long-sequence PoWER cell — the regime where elimination (and
        per-request adaptive retention) pays most. Trains a fresh variant at
        ``seq_len`` (the committed position tables stop at max_len, so the
        standard bundles cannot simply be re-lowered longer) and exports it
        with an hlo_grid of {seq_len, 64, 32}: long requests route to the
        256 cell while short ones reuse the standard buckets."""
        base_task = self.task(ds)
        task = dataclasses.replace(base_task, seq_len=seq_len,
                                   seed=base_task.seed + 7)
        variant = "power-long"
        cfg = self.cfg_for(task)
        # The evidence tokens are ~8x sparser at N=256 than at N=32 (the
        # generator plants a fixed 3-6 signal words per sentence), so the
        # budget needs several epochs over the full train split before the
        # classifier finds them; tc_for's long-sequence step halving
        # under-trains badly here. The model is narrow enough that even the
        # scaled budget stays in minutes.
        lam = self.prof.pareto_lambdas[len(self.prof.pareto_lambdas) // 2]
        ft = dataclasses.replace(self.prof.finetune, batch_size=8,
                                 steps=max(600, self.prof.finetune.steps * 8))
        sc = dataclasses.replace(self.prof.config_search, batch_size=8, lambda_reg=lam,
                                 steps=max(400, self.prof.config_search.steps * 6))
        rt = dataclasses.replace(self.prof.retrain, batch_size=8,
                                 steps=max(400, self.prof.retrain.steps * 6))
        train_hash = config_hash(cfg, task, ft, sc, rt)
        chash = f"{train_hash}-v{EXPORT_VERSION}"
        out_dir = os.path.join(ART, ds, variant)
        if self._fresh(out_dir, chash):
            return
        ckpt = os.path.join(CKPT, ds, f"{variant}.npz")
        # Long splits are generated fresh (the committed test.npz stays the
        # dataset's canonical 32-wide dev set); the cache key is name-based,
        # so bypass it.
        train_data = D.generate(task, self.vocab, "train")
        test_data = D.generate(task, self.vocab, "test")
        meta_p = os.path.join(out_dir, "meta.json")
        if os.path.exists(ckpt) and os.path.exists(meta_p):
            try:
                with open(meta_p) as f:
                    old = json.load(f)
            except Exception:
                old = {}
            if old.get("train_hash") == train_hash and old.get("retention"):
                retention = old["retention"]
                p3 = load_params(ckpt)
                log(f"{ds}: re-exporting {variant} (exporter v{EXPORT_VERSION})")
                self._export_long(ds, variant, cfg, task, p3, retention, lam,
                                  chash, train_hash, old.get("dev_metric"))
                return
        log(f"{ds}: fine-tuning long-seq baseline (N={seq_len}) ...")
        params = L.init_params(jax.random.PRNGKey(task.seed), cfg)
        fwd_train = M.make_forward(cfg, use_pallas=False)
        params, _ = T.train_classifier(fwd_train, params, train_data, task, ft)
        log(f"{ds}: PoWER config-search (lambda={lam}, N={seq_len}) ...")
        fwd_soft = M.make_soft_forward(cfg, use_pallas=False)
        r0 = jnp.ones((cfg.num_layers, task.seq_len))
        p2, r, _ = T.train_soft_extract(fwd_soft, params, r0, train_data, task, sc)
        masses = np.asarray(jnp.sum(jnp.clip(r, 0, 1), axis=1))
        retention = M.derive_retention(masses, task.seq_len)
        log(f"{ds}: long-seq retention {retention} "
            f"(agg {sum(retention)}/{cfg.num_layers * task.seq_len})")
        fwd_ex_train = M.make_forward(cfg, retention=retention, use_pallas=False)
        p3, _ = T.train_classifier(fwd_ex_train, p2, train_data, task, rt)
        dev = T.evaluate(fwd_ex_train, p3, test_data, task)
        log(f"{ds}: {variant} dev {task.metric} = {dev:.4f}")
        os.makedirs(os.path.dirname(ckpt), exist_ok=True)
        save_params(ckpt, p3)
        self._export_long(ds, variant, cfg, task, p3, retention, lam,
                          chash, train_hash, dev)

    def _export_long(self, ds, variant, cfg, task, p3, retention, lam,
                     chash, train_hash, dev):
        fwd_ex = M.make_forward(cfg, retention=retention, use_pallas=EXPORT_USE_PALLAS)
        out_dir = os.path.join(ART, ds, variant)
        meta = {
            "dataset": ds, "variant": variant, "metric": task.metric,
            "task": task.task, "paper_seq_len": task.paper_seq_len,
            "config_hash": chash, "train_hash": train_hash,
            "dev_metric": dev, "kind": "power",
            "retention": retention, "lambda": lam,
            "aggregate_word_vectors": int(sum(retention)),
            "baseline_word_vectors": int(cfg.num_layers * task.seq_len),
        }
        aot.export_variant(out_dir, fwd_ex, p3, cfg, task.seq_len,
                           self.prof.batch_sizes, meta,
                           seq_buckets=[32, 64])
        log(f"exported {ds}/{variant}")

    def encoder_eliminated(self, ds: str, kind: str, keep_layers: int):
        """DistilBERT / BERT-PKD baseline point."""
        task = self.task(ds)
        variant = f"{kind}{keep_layers}"
        cfg, params, _ = self.baseline(ds)
        tc = self.tc_for(task, self.prof.retrain)
        chash = config_hash(cfg, task, tc, keep_layers)
        if self._fresh(os.path.join(ART, ds, variant), chash):
            return
        log(f"{ds}: training {variant} ...")
        s_cfg, s_params, _ = B.train_encoder_eliminated(
            kind, params, None, cfg, keep_layers, self.data(task, "train"),
            task, tc, use_pallas=False)
        fwd = M.make_forward(s_cfg, use_pallas=False)
        dev = T.evaluate(fwd, s_params, self.data(task, "test"), task)
        log(f"{ds}: {variant} dev {task.metric} = {dev:.4f}")
        self.export(ds, variant, M.make_forward(s_cfg, use_pallas=EXPORT_USE_PALLAS),
                    s_params, s_cfg, task,
                    {"config_hash": chash, "dev_metric": dev, "kind": kind,
                     "kept_layers": keep_layers})

    def head_pruned(self, ds: str, keep_fraction: float):
        task = self.task(ds)
        variant = f"headprune{int(keep_fraction * 100)}"
        cfg, params, _ = self.baseline(ds)
        tc = self.tc_for(task, dataclasses.replace(
            self.prof.retrain, steps=max(50, self.prof.retrain.steps // 2)))
        chash = config_hash(cfg, task, tc, keep_fraction)
        if self._fresh(os.path.join(ART, ds, variant), chash):
            return
        log(f"{ds}: training {variant} ...")
        pruned, gates, _ = B.train_head_pruned(params, cfg, keep_fraction,
                                               self.data(task, "train"), task,
                                               tc, use_pallas=False)
        fwd = M.make_forward(cfg, use_pallas=False)
        dev = T.evaluate(fwd, pruned, self.data(task, "test"), task)
        log(f"{ds}: {variant} dev {task.metric} = {dev:.4f} "
            f"(heads kept {int(gates.sum())}/{gates.size})")
        self.export(ds, variant, M.make_forward(cfg, use_pallas=EXPORT_USE_PALLAS), pruned,
                    cfg, task, {"config_hash": chash, "dev_metric": dev,
                                "kind": "headprune",
                                "keep_fraction": keep_fraction,
                                "heads_kept": int(gates.sum())})

    def strategy_ablation(self, ds: str = "sst2"):
        """Table 4: Head-WS vs Rand-WS vs Attn-WS on a fixed retention
        config (the paper's (64,32,16,...) scaled to our N and L)."""
        task = self.task(ds)
        cfg, params, _ = self.baseline(ds)
        n = task.seq_len
        # Elimination must bite from the first encoder (before attention has
        # diffused the evidence) for the strategy gap to be observable —
        # analog of the paper's (64,32,16,...) at their N=128 SST-2 scale.
        fixed = [n // 2, n // 4] + [n // 8] * (cfg.num_layers - 2)
        fixed = M.derive_retention(np.array(fixed, dtype=float), n)
        for strategy in ("attn", "head", "rand"):
            variant = f"power-{strategy}ws"
            tc = self.tc_for(task, self.prof.retrain)
            chash = f"{config_hash(cfg, task, tc, tuple(fixed), strategy)}-v{EXPORT_VERSION}zs"
            if self._fresh(os.path.join(ART, ds, variant), chash):
                continue
            log(f"{ds}: ablation {variant} retention={fixed}")
            fwd_tr = M.make_forward(cfg, retention=fixed, strategy=strategy,
                                    use_pallas=False)
            p, _ = T.train_classifier(fwd_tr, params, self.data(task, "train"), task, tc)
            dev = T.evaluate(fwd_tr, p, self.data(task, "test"), task)
            log(f"{ds}: {variant} dev {task.metric} = {dev:.4f}")
            self.export(ds, variant,
                        M.make_forward(cfg, retention=fixed, strategy=strategy,
                                       use_pallas=EXPORT_USE_PALLAS),
                        p, cfg, task,
                        {"config_hash": chash, "dev_metric": dev,
                         "kind": f"power-{strategy}ws", "retention": fixed,
                         "strategy": strategy})
            # Zero-shot variant: extraction strategy applied to the frozen
            # fine-tuned baseline with NO re-training — isolates the scoring
            # function's value (the paper's Attn-WS gap depends on limited
            # adaptation; see EXPERIMENTS.md Table 4 discussion).
            fwd_zs = M.make_forward(cfg, retention=fixed, strategy=strategy,
                                    use_pallas=False)
            dev_zs = T.evaluate(fwd_zs, params, self.data(task, "test"), task)
            log(f"{ds}: {variant}-zeroshot dev {task.metric} = {dev_zs:.4f}")
            self.export(ds, f"{variant}-zeroshot",
                        M.make_forward(cfg, retention=fixed, strategy=strategy,
                                       use_pallas=EXPORT_USE_PALLAS),
                        params, cfg, task,
                        {"config_hash": chash, "dev_metric": dev_zs,
                         "kind": f"power-{strategy}ws-zeroshot",
                         "retention": fixed, "strategy": strategy})

    # -- index ------------------------------------------------------------

    def write_index(self):
        index: Dict[str, Dict] = {"profile": self.prof.name, "datasets": {}}
        # Preserve the manifest revision across rebuilds; the stale digest
        # map and signature are dropped (re-stamped by compile.sign below).
        idx_p = os.path.join(ART, "index.json")
        if os.path.exists(idx_p):
            with open(idx_p) as f:
                prev = json.load(f)
            if "revision" in prev:
                index["revision"] = prev["revision"]
        for ds in sorted(os.listdir(ART)):
            ds_dir = os.path.join(ART, ds)
            if not os.path.isdir(ds_dir) or ds == "analysis":
                continue
            variants = {}
            for v in sorted(os.listdir(ds_dir)):
                meta_p = os.path.join(ds_dir, v, "meta.json")
                if os.path.exists(meta_p):
                    with open(meta_p) as f:
                        m = json.load(f)
                    variants[v] = {"kind": m.get("kind"), "metric": m.get("metric"),
                                   "dev_metric": m.get("dev_metric"),
                                   "seq_len": m.get("seq_len"),
                                   "retention": m.get("retention")}
            if variants:
                t = TASKS.get(ds)
                index["datasets"][ds] = {
                    "variants": variants,
                    "task": t.task if t else None,
                    "num_classes": t.num_classes if t else None,
                    "seq_len": t.seq_len if t else None,
                    "paper_seq_len": t.paper_seq_len if t else None,
                    "test": "test.npz" if os.path.exists(os.path.join(ds_dir, "test.npz")) else None,
                }
        with open(os.path.join(ART, "index.json"), "w") as f:
            json.dump(index, f, indent=1)
        log("index.json updated")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--profile", default="full", choices=["quick", "full"])
    ap.add_argument("--datasets", default=None,
                    help="comma list; default = profile's dataset set")
    ap.add_argument("--stages", default="core",
                    help="comma list of: core, pareto, albert, ablation, long, all")
    args = ap.parse_args()

    prof = get_profile(args.profile)
    pipe = Pipeline(prof)
    datasets = args.datasets.split(",") if args.datasets else list(prof.datasets)
    stages = set(args.stages.split(","))
    if "all" in stages:
        stages = {"core", "pareto", "albert", "ablation", "long"}

    # Default lambda for the Table-2 "<1% accuracy loss" operating point; the
    # pareto sweep refines it for the Figure-7 datasets.
    default_lambda = prof.pareto_lambdas[len(prof.pareto_lambdas) // 2]

    if "core" in stages:
        for ds in datasets:
            base = pipe.baseline(ds)
            pipe.power(ds, default_lambda, "power-default", base=base,
                       export_debug=(ds == "sst2"))
            pipe.write_index()

    if "long" in stages and "sst2" in datasets:
        pipe.long_seq("sst2")
        pipe.write_index()

    if "ablation" in stages and "sst2" in datasets:
        pipe.strategy_ablation("sst2")
        pipe.write_index()

    if "pareto" in stages:
        for ds in [d for d in prof.pareto_datasets if d in datasets]:
            base = pipe.baseline(ds)
            for lam in prof.pareto_lambdas:
                pipe.power(ds, lam, f"power-l{lam:g}", base=base)
            # Paper keeps {3,4,6} of 12 encoders; scaled to our depth.
            L_ = prof.bert.num_layers
            for k in sorted({max(1, L_ // 3), L_ // 2, max(2, 2 * L_ // 3)}):
                pipe.encoder_eliminated(ds, "distil", k)
                pipe.encoder_eliminated(ds, "pkd", k)
            for frac in (0.25, 0.5, 0.75):
                pipe.head_pruned(ds, frac)
            pipe.write_index()

    if "albert" in stages:
        for ds in [d for d in GLUE_TASKS if d in datasets]:
            base = pipe.baseline(ds, albert=True)
            pipe.power(ds, default_lambda, "albert-power", albert=True, base=base)
            pipe.write_index()

    pipe.write_index()

    # Golden-logit fixtures for the Rust native backend: reference logits
    # per variant over each dataset's test split (parity asserted at 1e-4
    # by rust/tests/native_backend.rs).
    from . import golden
    golden.main(ART)

    # Stamp per-file digests + manifest signature so the Rust serving side
    # verifies the bundle at load (skipped when no key has been generated).
    if os.path.exists(os.path.join(ART, "signing.key")):
        from . import sign
        sign.main([ART])
    log("done")


if __name__ == "__main__":
    main()
