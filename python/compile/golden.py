"""Golden-logit fixtures for the Rust native backend.

For every exported variant that carries weights, run the reference (pure-jnp
oracle) forward over the dataset's committed test split and save the logits
to ``artifacts/<dataset>/golden.npz`` as ``<variant>/logits`` — the parity
contract the Rust native backend's tests assert against (within 1e-4).

The BertConfig is reconstructed from the exported weight shapes + meta.json,
so the fixture stays correct even if the training profile changes: whatever
was exported is what gets goldened.

Usage:  python -m compile.golden [artifacts_dir]
"""

from __future__ import annotations

import json
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M
from .config import BertConfig
from .params_io import unflatten_params


def cfg_from_export(weights: dict, meta: dict) -> BertConfig:
    """Reconstruct the architecture purely from exported artifacts."""
    vocab, embed = weights["embed/word"].shape
    hidden = weights["embed/ln_g"].shape[0]
    max_len = weights["embed/pos"].shape[0]
    ffn = weights["layers/0/w1"].shape[1]
    return BertConfig(
        vocab_size=vocab,
        hidden_size=hidden,
        num_layers=int(meta["num_layers"]),
        num_heads=int(meta["num_heads"]),
        ffn_size=ffn,
        max_len=max_len,
        num_classes=int(meta.get("num_classes", 2)),
        type_vocab=weights["embed/type"].shape[0],
        embed_factor=0 if embed == hidden else embed,
    )


def golden_for_dataset(ds_dir: str) -> dict:
    """Compute ``{variant}/logits`` arrays for one dataset directory."""
    test = np.load(os.path.join(ds_dir, "test.npz"))
    tokens = jnp.asarray(test["tokens"], dtype=jnp.int32)
    segs = jnp.asarray(test["segs"], dtype=jnp.int32)
    out = {}
    for variant in sorted(os.listdir(ds_dir)):
        vdir = os.path.join(ds_dir, variant)
        meta_path = os.path.join(vdir, "meta.json")
        wpath = os.path.join(vdir, "weights.npz")
        if not (os.path.isdir(vdir) and os.path.exists(meta_path) and os.path.exists(wpath)):
            continue
        with open(meta_path) as f:
            meta = json.load(f)
        if "num_heads" not in meta:
            # Debug bundles reuse their parent's architecture fields.
            parent = meta_path.replace(f"{variant}/", f"{variant.removesuffix('-debug')}/")
            if os.path.exists(parent) and parent != meta_path:
                with open(parent) as f:
                    meta = {**json.load(f), **meta}
            else:
                continue
        z = np.load(wpath)
        weights = {k: z[k] for k in z.files}
        cfg = cfg_from_export(weights, meta)
        params = unflatten_params(weights)
        retention = meta.get("retention")
        fwd = jax.jit(
            M.make_forward(cfg, retention=retention, use_pallas=False)
        )
        logits, _ = fwd(params, tokens, segs)
        out[f"{variant}/logits"] = np.asarray(logits, dtype=np.float32)
        print(f"  {variant}: logits {out[f'{variant}/logits'].shape}")
    return out


def main(root: str) -> None:
    for ds in sorted(os.listdir(root)):
        ds_dir = os.path.join(root, ds)
        if not os.path.isdir(ds_dir) or not os.path.exists(os.path.join(ds_dir, "test.npz")):
            continue
        print(f"golden: {ds}")
        arrays = golden_for_dataset(ds_dir)
        if arrays:
            np.savez(os.path.join(ds_dir, "golden.npz"), **arrays)
            print(f"  wrote {os.path.join(ds_dir, 'golden.npz')}")


if __name__ == "__main__":
    main(sys.argv[1] if len(sys.argv) > 1 else "artifacts")
