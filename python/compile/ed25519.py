"""Pure-python ed25519 (RFC 8032) for artifact-manifest signing.

Mirrors ``rust/src/util/ed25519.rs``: the exporter signs the manifest at
``python -m compile.sign`` time and the Rust server verifies on every
load. Standard library only (``hashlib`` for SHA-512 + bigints) — the
build container is offline.

Not constant-time; intended for artifact signing where the committed dev
key is not a secret. Deployments supply their own seed file.
"""

from __future__ import annotations

import hashlib

P = 2**255 - 19
L = 2**252 + 27742317777372353535851937790883648493
D = (-121665 * pow(121666, P - 2, P)) % P
SQRT_M1 = pow(2, (P - 1) // 4, P)

# Base point: y = 4/5, x recovered with the even root.
_BY = (4 * pow(5, P - 2, P)) % P


def _recover_x(y: int, sign: int) -> int | None:
    x2 = (y * y - 1) * pow(D * y * y + 1, P - 2, P) % P
    if x2 == 0:
        return None if sign else 0
    x = pow(x2, (P + 3) // 8, P)
    if (x * x - x2) % P != 0:
        x = x * SQRT_M1 % P
    if (x * x - x2) % P != 0:
        return None
    if x & 1 != sign:
        x = P - x
    return x


_BX = _recover_x(_BY, 0)
BASE = (_BX, _BY, 1, _BX * _BY % P)
IDENTITY = (0, 1, 1, 0)


def _add(p, q):
    x1, y1, z1, t1 = p
    x2, y2, z2, t2 = q
    a = (y1 - x1) * (y2 - x2) % P
    b = (y1 + x1) * (y2 + x2) % P
    c = 2 * t1 * t2 * D % P
    d = 2 * z1 * z2 % P
    e, f, g, h = b - a, d - c, d + c, b + a
    return (e * f % P, g * h % P, f * g % P, e * h % P)


def _scalar_mul(point, s: int):
    r = IDENTITY
    while s:
        if s & 1:
            r = _add(r, point)
        point = _add(point, point)
        s >>= 1
    return r


def _compress(point) -> bytes:
    x, y, z, _ = point
    zi = pow(z, P - 2, P)
    x, y = x * zi % P, y * zi % P
    return (y | ((x & 1) << 255)).to_bytes(32, "little")


def _decompress(b: bytes):
    if len(b) != 32:
        return None
    enc = int.from_bytes(b, "little")
    y = enc & ((1 << 255) - 1)
    if y >= P:
        return None
    x = _recover_x(y, enc >> 255)
    if x is None:
        return None
    return (x, y, 1, x * y % P)


def _expand(seed: bytes):
    h = hashlib.sha512(seed).digest()
    a = int.from_bytes(h[:32], "little")
    a &= (1 << 254) - 8
    a |= 1 << 254
    return a, h[32:]


def public_key(seed: bytes) -> bytes:
    """32-byte public key for a 32-byte seed."""
    if len(seed) != 32:
        raise ValueError(f"seed must be 32 bytes, got {len(seed)}")
    a, _ = _expand(seed)
    return _compress(_scalar_mul(BASE, a))


def sign(seed: bytes, msg: bytes) -> bytes:
    """64-byte signature R || S over ``msg``."""
    a, prefix = _expand(seed)
    pub = _compress(_scalar_mul(BASE, a))
    r = int.from_bytes(hashlib.sha512(prefix + msg).digest(), "little") % L
    r_enc = _compress(_scalar_mul(BASE, r))
    k = int.from_bytes(hashlib.sha512(r_enc + pub + msg).digest(), "little") % L
    s = (r + k * a) % L
    return r_enc + s.to_bytes(32, "little")


def verify(public: bytes, msg: bytes, sig: bytes) -> bool:
    """True iff ``sig`` is a valid signature over ``msg`` by ``public``."""
    if len(sig) != 64 or len(public) != 32:
        return False
    r_enc, s_bytes = sig[:32], sig[32:]
    s = int.from_bytes(s_bytes, "little")
    if s >= L:
        return False
    a = _decompress(public)
    r = _decompress(r_enc)
    if a is None or r is None:
        return False
    k = int.from_bytes(hashlib.sha512(r_enc + public + msg).digest(), "little") % L
    lhs = _compress(_scalar_mul(BASE, s))
    rhs = _compress(_add(r, _scalar_mul(a, k)))
    return lhs == rhs


def _self_test() -> None:
    # RFC 8032 section 7.1, TEST 1-3.
    vectors = [
        (
            "9d61b19deffd5a60ba844af492ec2cc44449c5697b326919703bac031cae7f60",
            "d75a980182b10ab7d54bfed3c964073a0ee172f3daa62325af021a68f707511a",
            b"",
            "e5564300c360ac729086e2cc806e828a84877f1eb8e5d974d873e06522490155"
            "5fb8821590a33bacc61e39701cf9b46bd25bf5f0595bbe24655141438e7a100b",
        ),
        (
            "4ccd089b28ff96da9db6c346ec114e0f5b8a319f35aba624da8cf6ed4fb8a6fb",
            "3d4017c3e843895a92b70aa74d1b7ebc9c982ccf2ec4968cc0cd55f12af4660c",
            b"\x72",
            "92a009a9f0d4cab8720e820b5f642540a2b27b5416503f8fb3762223ebdb69da"
            "085ac1e43e15996e458f3613d0f11d8c387b2eaeb4302aeeb00d291612bb0c00",
        ),
        (
            "c5aa8df43f9f837bedb7442f31dcb7b166d38535076f094b85ce3a2e0b4458f7",
            "fc51cd8e6218a1a38da47ed00230f0580816ed13ba3303ac5deb911548908025",
            b"\xaf\x82",
            "6291d657deec24024827e69c3abe01a30ce548a284743a445e3680d7db5ac3ac"
            "18ff9b538d16f290ae67f760984dc6594a7c15e9716ed28dc027beceea1ec40a",
        ),
    ]
    for seed_hex, pub_hex, msg, sig_hex in vectors:
        seed = bytes.fromhex(seed_hex)
        assert public_key(seed).hex() == pub_hex
        sig = sign(seed, msg)
        assert sig.hex() == sig_hex
        assert verify(bytes.fromhex(pub_hex), msg, sig)
        assert not verify(bytes.fromhex(pub_hex), msg + b"x", sig)
    bad = bytearray(sign(bytes.fromhex(vectors[0][0]), b"m"))
    bad[3] ^= 1
    assert not verify(bytes.fromhex(vectors[0][1]), b"m", bytes(bad))
    print("ed25519 self-test ok")


if __name__ == "__main__":
    _self_test()
