"""Stamp and sign the artifact manifest (``artifacts/index.json``).

Walks the artifact root, records a sha256 digest + size for every file,
and signs the canonical manifest bytes with ed25519 so the Rust serving
side (``runtime::repo``) can refuse tampered or truncated bundles at
load time.

The signature covers the *canonical bytes*, not the JSON text::

    powerbert-manifest-v1\\n
    revision <N>\\n
    <relpath> <sha256hex> <size>\\n      # one line per file, byte order

which is exactly what ``Manifest::signing_bytes`` produces in Rust —
the JSON formatting itself is never load-bearing.

Usage::

    python -m compile.sign artifacts --gen-key      # once: create keypair
    python -m compile.sign artifacts                # digest + sign (rev+1)
    python -m compile.sign artifacts --revision 7   # explicit revision
    python -m compile.sign artifacts --verify       # re-hash + check sig

Run from ``python/``. Depends only on the standard library (hashlib) and
the vendored ``compile.ed25519``.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import secrets
import sys
from pathlib import Path

from . import ed25519

DOMAIN = "powerbert-manifest-v1"


def manifest_skips(name: str) -> bool:
    """Root-level names the manifest never covers (mirrors Rust)."""
    return (
        name == "index.json"
        or name.startswith("signing.")
        or name == "analysis"
        or name == "__pycache__"
        or name.startswith(".")
    )


def walk_files(root: Path) -> dict[str, dict]:
    """Digest every artifact file under ``root``, '/'-separated relpaths."""
    files: dict[str, dict] = {}

    def recurse(dirpath: Path, rel: str) -> None:
        for entry in sorted(dirpath.iterdir(), key=lambda p: p.name):
            name = entry.name
            if rel == "" and manifest_skips(name):
                continue
            if name.startswith(".") or name == "__pycache__":
                continue
            sub = f"{rel}/{name}" if rel else name
            if entry.is_dir():
                recurse(entry, sub)
            else:
                h = hashlib.sha256()
                with entry.open("rb") as f:
                    for chunk in iter(lambda: f.read(1 << 20), b""):
                        h.update(chunk)
                files[sub] = {
                    "sha256": h.hexdigest(),
                    "size": entry.stat().st_size,
                }

    recurse(root, "")
    return files


def signing_bytes(revision: int, files: dict[str, dict]) -> bytes:
    lines = [f"{DOMAIN}\n", f"revision {revision}\n"]
    # Byte order, matching Rust's BTreeMap iteration over the relpaths.
    for rel in sorted(files, key=lambda s: s.encode()):
        fd = files[rel]
        lines.append(f"{rel} {fd['sha256']} {fd['size']}\n")
    return "".join(lines).encode()


def load_manifest(root: Path) -> dict:
    path = root / "index.json"
    if not path.exists():
        return {}
    return json.loads(path.read_text())


def write_manifest(root: Path, doc: dict) -> None:
    path = root / "index.json"
    path.write_text(json.dumps(doc, indent=1, sort_keys=False) + "\n")


def read_seed(path: Path) -> bytes:
    seed = bytes.fromhex(path.read_text().strip())
    if len(seed) != 32:
        raise SystemExit(f"{path}: expected a 32-byte hex seed, got {len(seed)} bytes")
    return seed


def cmd_gen_key(root: Path) -> int:
    key_path = root / "signing.key"
    pub_path = root / "signing.pub"
    if key_path.exists():
        print(f"refusing to overwrite existing {key_path}", file=sys.stderr)
        return 1
    seed = secrets.token_bytes(32)
    key_path.write_text(seed.hex() + "\n")
    pub_path.write_text(ed25519.public_key(seed).hex() + "\n")
    print(f"wrote {key_path} and {pub_path}")
    return 0


def cmd_verify(root: Path) -> int:
    doc = load_manifest(root)
    files = doc.get("files")
    if not isinstance(files, dict):
        print("manifest has no files map (unsigned legacy bundle)", file=sys.stderr)
        return 1
    disk = walk_files(root)
    bad = 0
    for rel, fd in sorted(files.items()):
        got = disk.get(rel)
        if got is None:
            print(f"MISSING {rel}", file=sys.stderr)
            bad += 1
        elif got["sha256"] != fd["sha256"] or got["size"] != fd["size"]:
            print(
                f"MISMATCH {rel}: expected sha256 {fd['sha256']} ({fd['size']} bytes), "
                f"actual sha256 {got['sha256']} ({got['size']} bytes)",
                file=sys.stderr,
            )
            bad += 1
    for rel in sorted(set(disk) - set(files)):
        print(f"UNLISTED {rel}", file=sys.stderr)
        bad += 1
    sig = doc.get("signature")
    if sig is None:
        print("manifest is not signed", file=sys.stderr)
        bad += 1
    else:
        msg = signing_bytes(int(doc.get("revision", 0)), files)
        ok = ed25519.verify(
            bytes.fromhex(sig["public_key"]), msg, bytes.fromhex(sig["signature"])
        )
        if not ok:
            print("SIGNATURE does not verify", file=sys.stderr)
            bad += 1
    if bad:
        print(f"verify FAILED ({bad} problems)", file=sys.stderr)
        return 1
    print(f"verify OK: revision {doc.get('revision', 0)}, {len(files)} files, signed")
    return 0


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m compile.sign", description=__doc__.split("\n", 1)[0]
    )
    ap.add_argument("root", nargs="?", default="artifacts", help="artifact root")
    ap.add_argument("--revision", type=int, help="manifest revision (default: previous + 1)")
    ap.add_argument("--key", help="ed25519 seed file (default <root>/signing.key)")
    ap.add_argument("--gen-key", action="store_true", help="generate a keypair and exit")
    ap.add_argument("--verify", action="store_true", help="check digests + signature, no write")
    args = ap.parse_args(argv)

    root = Path(args.root)
    if not root.is_dir():
        print(f"{root}: not a directory", file=sys.stderr)
        return 2
    if args.gen_key:
        return cmd_gen_key(root)
    if args.verify:
        return cmd_verify(root)

    key_path = Path(args.key) if args.key else root / "signing.key"
    if not key_path.exists():
        print(
            f"{key_path}: no signing key (run --gen-key first, or pass --key)",
            file=sys.stderr,
        )
        return 2
    seed = read_seed(key_path)

    doc = load_manifest(root)
    revision = args.revision if args.revision is not None else int(doc.get("revision", 0)) + 1
    files = walk_files(root)
    doc["revision"] = revision
    doc["files"] = {rel: files[rel] for rel in sorted(files, key=lambda s: s.encode())}
    sig = ed25519.sign(seed, signing_bytes(revision, files))
    doc["signature"] = {
        "algorithm": "ed25519",
        "public_key": ed25519.public_key(seed).hex(),
        "signature": sig.hex(),
    }
    write_manifest(root, doc)
    print(f"signed {root / 'index.json'}: revision {revision}, {len(files)} files")
    return 0


if __name__ == "__main__":
    sys.exit(main())
