"""Analysis experiments from the paper that live on the Python side:

* Figure 2 — mean pairwise cosine similarity of word-vectors per encoder
  (diffusion of information).
* Figure 5 — mutual information between the baseline model's predictions and
  a model that eliminates the k-th-highest-scored word at encoder j.
* §3.1 CLS study — accuracy when classifying from a non-CLS position.
* Figure 8 — anecdotal progressive-elimination traces (which words survive
  at each encoder) — the data is also exported for examples/anecdotes.rs.

Each writes a small JSON report under artifacts/analysis/ that EXPERIMENTS.md
and the Rust examples consume.

Run:  python -m compile.analysis --fig2 --fig5 --cls-study --anecdotes
"""

from __future__ import annotations

import argparse
import json
import os
from typing import Dict, List, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from . import data as D
from . import layers as L
from . import model as M
from . import train as T
from .config import TASKS, BertConfig, get_profile
from .params_io import load_params
from .tokenizer import Tokenizer, Vocab


def cosine_similarity_by_encoder(params, cfg: BertConfig, tokens, segs,
                                 batch_size: int = 32) -> List[float]:
    """Figure 2: for each encoder, the cosine similarity between all pairs of
    its output word-vectors, averaged over pairs and inputs (valid positions
    only — PAD vectors would inflate the similarity)."""
    fwd = M.make_forward(cfg, use_pallas=False, collect=True)
    sums = np.zeros(cfg.num_layers)
    counts = np.zeros(cfg.num_layers)
    fwd_j = jax.jit(lambda p, t, s: fwd(p, t, s)[1]["hidden"])
    for i in range(0, tokens.shape[0] - batch_size + 1, batch_size):
        tok = tokens[i : i + batch_size]
        sg = segs[i : i + batch_size]
        hidden = fwd_j(params, tok, sg)
        mask = (tok != 0)
        for j, h in enumerate(hidden):              # h: [B, N, H]
            h = np.asarray(h)
            norm = h / (np.linalg.norm(h, axis=-1, keepdims=True) + 1e-8)
            gram = norm @ norm.transpose(0, 2, 1)   # [B, N, N]
            m = mask.astype(np.float64)
            pair_mask = m[:, :, None] * m[:, None, :]
            np.einsum("bii->bi", pair_mask)[:] = 0.0  # exclude self-pairs
            sums[j] += float((gram * pair_mask).sum())
            counts[j] += float(pair_mask.sum())
    return list(sums / np.maximum(counts, 1.0))


def mutual_information(px_y: np.ndarray) -> float:
    """MI from a joint-count table (natural log, as in the paper)."""
    p = px_y / px_y.sum()
    px = p.sum(axis=1, keepdims=True)
    py = p.sum(axis=0, keepdims=True)
    with np.errstate(divide="ignore", invalid="ignore"):
        t = p * np.log(p / (px * py))
    return float(np.nansum(t))


def mi_single_elimination(params, cfg: BertConfig, tokens, segs,
                          encoders: Sequence[int], ks: Sequence[int],
                          batch_size: int = 32) -> Dict[str, Dict[str, float]]:
    """Figure 5: MI(X; Y_k) where X = baseline predictions and Y_k =
    predictions of a model that eliminates only the k-th-highest-scored
    word-vector at encoder j (CLS excluded from elimination)."""
    base_fwd = M.make_forward(cfg, use_pallas=False)
    base_pred = T.predict_all(base_fwd, params, tokens, segs, batch_size).argmax(-1)
    n = tokens.shape[1]
    results: Dict[str, Dict[str, float]] = {}
    for j in encoders:
        row: Dict[str, float] = {}
        for k in ks:
            if k >= n:
                continue
            # Retention config: full everywhere except encoder j, where we
            # keep all but one; the eliminated one is the k-th by score.
            # Implemented via a dedicated forward below.
            pred = _predict_eliminate_one(params, cfg, tokens, segs, j, k, batch_size)
            joint = np.zeros((cfg.num_classes, cfg.num_classes))
            for a, b in zip(base_pred, pred):
                joint[int(a), int(b)] += 1
            row[str(k)] = mutual_information(joint)
        results[str(j)] = row
    return results


def _predict_eliminate_one(params, cfg, tokens, segs, enc_j, k, batch_size):
    """Forward that removes exactly the k-th-highest-scored word-vector
    (k is 0-based among non-CLS positions) at encoder ``enc_j``."""
    from .kernels import get_kernels
    kernels = get_kernels(False)

    def one(tok, sg):
        mask = (tok != 0).astype(jnp.float32)
        x = L.embed(params, cfg, tok, sg)
        for j in range(cfg.num_layers):
            layer = L.layer_at(params, cfg, j)
            x1, sig = L.attn_half(layer, cfg, kernels, x, mask)
            if j == enc_j:
                scores = M.selection_scores(sig, mask)
                n_cur = x1.shape[0]
                # Keep everything except the word with the (k+1)-th highest
                # score (order[0] is CLS, pinned, never the victim).
                _, order = jax.lax.top_k(scores, n_cur)
                idx = jnp.sort(jnp.concatenate([order[: k + 1], order[k + 2 :]]))
                x1 = x1[idx]
                mask = mask[idx]
            x = L.ffn_half(layer, cfg, kernels, x1)
        return L.pool_and_classify(params, cfg, kernels, x)

    fwd = jax.jit(jax.vmap(one))
    outs = []
    nb = tokens.shape[0] // batch_size
    for i in range(nb):
        o = np.asarray(fwd(tokens[i * batch_size : (i + 1) * batch_size],
                           segs[i * batch_size : (i + 1) * batch_size]))
        outs.append(o)
    return np.concatenate(outs).argmax(-1)


def cls_position_study(params, cfg: BertConfig, tokens, segs, labels,
                       metric: str, positions: Sequence[int]) -> Dict[str, float]:
    """§3.1: classify from word position p instead of CLS (no retraining of
    the encoder stack; the pooler/head simply reads position p)."""
    from .kernels import get_kernels
    kernels = get_kernels(False)

    def make(pos):
        def one(tok, sg):
            mask = (tok != 0).astype(jnp.float32)
            x = L.embed(params, cfg, tok, sg)
            for j in range(cfg.num_layers):
                layer = L.layer_at(params, cfg, j)
                x1, _ = L.attn_half(layer, cfg, kernels, x, mask)
                x = L.ffn_half(layer, cfg, kernels, x1)
            xn = kernels.layernorm_residual(x, jnp.zeros_like(x),
                                            params["final_ln"]["g"],
                                            params["final_ln"]["b"], cfg.ln_eps)
            pooled = jnp.tanh(xn[pos] @ params["pooler"]["w"] + params["pooler"]["b"])
            return pooled @ params["head"]["w"] + params["head"]["b"]
        return lambda p, t, s: (jax.vmap(one)(t, s), None)

    import dataclasses
    task = dataclasses.replace(TASKS["sst2"], metric=metric)
    out = {}
    for pos in positions:
        out[str(pos)] = T.evaluate(make(pos), params, (tokens, segs, labels), task)
    return out


def anecdote_traces(params, cfg: BertConfig, vocab: Vocab, retention,
                    sentences: List[List[str]], seq_len: int) -> List[Dict]:
    """Figure 8: per-encoder surviving words for hand-picked sentences."""
    tok = Tokenizer(vocab)
    fwd = M.make_forward(cfg, retention=retention, use_pallas=False, collect=True)
    out = []
    for words in sentences:
        ids, sg = tok.encode(words, None, seq_len)
        logits, aux = jax.jit(fwd)(params,
                                   jnp.asarray([ids], jnp.int32),
                                   jnp.asarray([sg], jnp.int32))
        trace = []
        for j, kept in enumerate(aux["kept"]):
            positions = [int(p) for p in np.asarray(kept)[0]]
            toks = [vocab.words[ids[p]] if ids[p] < len(vocab.words) else "?" for p in positions]
            trace.append({"encoder": j + 1, "positions": positions, "tokens": toks})
        out.append({
            "sentence": words,
            "prediction": int(np.asarray(logits).argmax()),
            "trace": trace,
        })
    return out


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--profile", default="full")
    ap.add_argument("--dataset", default="sst2")
    ap.add_argument("--fig2", action="store_true")
    ap.add_argument("--fig5", action="store_true")
    ap.add_argument("--cls-study", action="store_true")
    ap.add_argument("--anecdotes", action="store_true")
    ap.add_argument("--artifacts", default="../artifacts")
    ap.add_argument("--checkpoints", default="../checkpoints")
    args = ap.parse_args()

    prof = get_profile(args.profile)
    cfg = prof.bert
    task = TASKS[args.dataset]
    import dataclasses
    cfg = dataclasses.replace(cfg, num_classes=task.num_classes, max_len=max(cfg.max_len, task.seq_len))
    vocab = Vocab.load(os.path.join(args.artifacts, "vocab.json"))
    params = load_params(os.path.join(args.checkpoints, args.dataset, "bert.npz"))
    tokens, segs, labels = D.generate(task, vocab, "test")
    os.makedirs(os.path.join(args.artifacts, "analysis"), exist_ok=True)

    def dump(name, obj):
        path = os.path.join(args.artifacts, "analysis", name)
        with open(path, "w") as f:
            json.dump(obj, f, indent=1)
        print(f"wrote {path}")

    if args.fig2:
        cos = cosine_similarity_by_encoder(params, cfg, tokens, segs)
        dump("fig2_cosine.json", {"dataset": args.dataset, "cosine_by_encoder": cos})
    if args.fig5:
        L_ = cfg.num_layers
        encoders = sorted(set([0, L_ // 4, L_ // 2, 3 * L_ // 4]))
        ks = [0, 1, 2, 4, 8, 12, 16, 24, 30]
        mi = mi_single_elimination(params, cfg, tokens[:256], segs[:256], encoders, ks)
        dump("fig5_mutual_information.json",
             {"dataset": args.dataset, "mi": mi,
              "note": "encoders are 0-based; paper plots j=1,3,6,9 of 12"})
    if args.cls_study:
        res = cls_position_study(params, cfg, tokens, segs, labels, task.metric,
                                 positions=[0, 1, 2, 4, 8, 12])
        dump("cls_position_study.json", {"dataset": args.dataset, "metric_by_position": res})
    if args.anecdotes:
        meta_p = os.path.join(args.artifacts, args.dataset, "power-default", "meta.json")
        with open(meta_p) as f:
            retention = json.load(f)["retention"]
        power = load_params(os.path.join(args.checkpoints, args.dataset, "power-default.npz"))
        sentences = [
            "filler_1 pos_3 filler_7 intens_0 pos_5 filler_2 neg_1 pos_8 filler_9".split(),
            "filler_4 negation_0 pos_2 filler_3 neg_6 filler_8 neg_2 filler_5".split(),
        ]
        traces = anecdote_traces(power, cfg, vocab, retention, sentences, task.seq_len)
        dump("fig8_anecdotes.json", {"dataset": args.dataset, "examples": traces})


if __name__ == "__main__":
    main()
