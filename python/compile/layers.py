"""Parameter initialization and encoder building blocks (L2).

The model is a dict-pytree BERT. Every encoder is split in two halves so the
PoWER extract / soft-extract layer can be inserted *between the self-attention
module and the feed-forward network*, exactly where the paper places it
(§3.2, Figure 4):

    attn_half:  x -> x + proj(MHA(LN(x)))  and the significance scores
    [extract / soft-extract here]
    ffn_half:   y -> y + FFN(LN(y))

Residual placement is pre-LN (final LN before the pooler): the original
post-LN BERT only trains from scratch with very careful warmup at depth 12,
while pre-LN is stable — and PoWER-BERT's mechanism (attention-derived
significance, extract layers between attention and FFN) is unchanged by it.
"""

from __future__ import annotations

from typing import Dict, List

import jax
import jax.numpy as jnp

from .config import BertConfig

Params = Dict


def _dense_init(key, shape, scale=0.02):
    return scale * jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)


def init_layer(key, cfg: BertConfig) -> Params:
    ks = jax.random.split(key, 6)
    H, I = cfg.hidden_size, cfg.ffn_size
    return {
        "wq": _dense_init(ks[0], (H, H)), "bq": jnp.zeros((H,)),
        "wk": _dense_init(ks[1], (H, H)), "bk": jnp.zeros((H,)),
        "wv": _dense_init(ks[2], (H, H)), "bv": jnp.zeros((H,)),
        "wo": _dense_init(ks[3], (H, H)), "bo": jnp.zeros((H,)),
        "ln1_g": jnp.ones((H,)), "ln1_b": jnp.zeros((H,)),
        "w1": _dense_init(ks[4], (H, I)), "b1": jnp.zeros((I,)),
        "w2": _dense_init(ks[5], (I, H)), "b2": jnp.zeros((H,)),
        "ln2_g": jnp.ones((H,)), "ln2_b": jnp.zeros((H,)),
    }


def init_params(key, cfg: BertConfig) -> Params:
    n_layer_params = 1 if cfg.share_params else cfg.num_layers
    keys = jax.random.split(key, n_layer_params + 4)
    H = cfg.hidden_size
    E = cfg.embed_factor if cfg.embed_factor > 0 else H
    embed = {
        "word": _dense_init(keys[0], (cfg.vocab_size, E)),
        "pos": _dense_init(keys[1], (cfg.max_len, H)),
        "type": _dense_init(keys[2], (cfg.type_vocab, H)),
        "ln_g": jnp.ones((H,)), "ln_b": jnp.zeros((H,)),
    }
    if cfg.embed_factor > 0:
        embed["word_proj"] = _dense_init(keys[3], (E, H))
    params = {
        "embed": embed,
        "layers": [init_layer(k, cfg) for k in keys[4 : 4 + n_layer_params]],
        "final_ln": {"g": jnp.ones((H,)), "b": jnp.zeros((H,))},
        "pooler": {"w": _dense_init(keys[-1], (H, H)), "b": jnp.zeros((H,))},
        "head": {"w": _dense_init(keys[-1], (H, max(cfg.num_classes, 1))),
                 "b": jnp.zeros((max(cfg.num_classes, 1),))},
    }
    return params


def layer_at(params: Params, cfg: BertConfig, j: int) -> Params:
    """Encoder j's weights — index 0 for ALBERT-style shared parameters."""
    return params["layers"][0 if cfg.share_params else j]


def embed(params: Params, cfg: BertConfig, tokens: jnp.ndarray,
          segs: jnp.ndarray) -> jnp.ndarray:
    """Embedding lookup for one example. tokens, segs: i32 [N] -> [N, H]."""
    e = params["embed"]
    w = e["word"][tokens]
    if cfg.embed_factor > 0:
        w = w @ e["word_proj"]
    x = w + e["pos"][: tokens.shape[0]] + e["type"][segs]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + cfg.ln_eps) * e["ln_g"] + e["ln_b"]


def attn_half(layer: Params, cfg: BertConfig, kernels, x: jnp.ndarray,
              mask: jnp.ndarray, head_gates: jnp.ndarray | None = None):
    """Self-attention module of one encoder, one example.

    x: [n, H]; mask: [n] -> (x1 [n, H], sig [n]).
    ``head_gates``: optional [A] multiplier on each head's context — the
    Head-Prune baseline sets entries to 0 (Michel et al. gates).
    """
    n, H = x.shape
    A, d = cfg.num_heads, cfg.head_dim
    zeros = jnp.zeros_like(x)
    h = kernels.layernorm_residual(x, zeros, layer["ln1_g"], layer["ln1_b"], cfg.ln_eps)

    def proj(w, b):
        return (h @ w + b).reshape(n, A, d).transpose(1, 0, 2)  # [A, n, d]

    q, k, v = proj(layer["wq"], layer["bq"]), proj(layer["wk"], layer["bk"]), proj(layer["wv"], layer["bv"])
    ctx, sig = kernels.mha_with_scores(q, k, v, mask)            # [A,n,d], [n]
    if head_gates is not None:
        ctx = ctx * head_gates[:, None, None]
    ctx = ctx.transpose(1, 0, 2).reshape(n, H)
    x1 = x + ctx @ layer["wo"] + layer["bo"]
    return x1, sig


def ffn_half(layer: Params, cfg: BertConfig, kernels, x1: jnp.ndarray) -> jnp.ndarray:
    """FFN module of one encoder, one example. x1: [n, H] -> [n, H]."""
    h = kernels.layernorm_residual(x1, jnp.zeros_like(x1), layer["ln2_g"], layer["ln2_b"], cfg.ln_eps)
    return x1 + kernels.ffn(h, layer["w1"], layer["b1"], layer["w2"], layer["b2"])


def pool_and_classify(params: Params, cfg: BertConfig, kernels, x: jnp.ndarray) -> jnp.ndarray:
    """Final prediction from the CLS vector (position 0). x: [n, H] -> [C]."""
    x = kernels.layernorm_residual(x, jnp.zeros_like(x), params["final_ln"]["g"],
                                   params["final_ln"]["b"], cfg.ln_eps)
    pooled = jnp.tanh(x[0] @ params["pooler"]["w"] + params["pooler"]["b"])
    return pooled @ params["head"]["w"] + params["head"]["b"]
