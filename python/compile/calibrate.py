"""Offline adaptive-retention calibration (committed pareto.json tables).

Replicates the native runtime's adaptive executor (rust/src/runtime/
adaptive.rs + native.rs) at batch size 1 — the composition-independent
semantics: with one example per batch the batch-max rule degenerates to the
example's own demanded k, so the sweep below is exactly what any serving
batch composition is bounded by.

For each threshold t the forward keeps, at encoder j,

    keep_j = min(schedule[j], demanded_k(sig_j, mask_j, t))

where demanded_k is the smallest k whose cumulative (descending) masked
significance mass reaches t of the row's total — bit-identical decision
rule to the Rust side (f32 scores, f64 accumulation, PAD excluded,
degenerate rows demand 1). Selection then runs the unchanged CLS/PAD-pinned
top-k (`keep_indices` tie-break: descending score, ascending index).

The output is the schema-1 Pareto table the coordinator router loads:

    {"schema": 1, "dataset": ..., "variant": ..., "metric": ...,
     "examples": N, "points": [{"threshold", "metric", "mean_tokens",
                                "est_latency_us"}, ...]}

`est_latency_us` here is a deterministic linear-in-tokens estimate (the
committed tables must not depend on the calibration machine); the Rust
`eval --calibrate-pareto` path measures real wall time instead. Both are
documented as relative numbers — the router's named tiers select on
metric and mean_tokens only.

Usage:
    python -m compile.calibrate --dataset sst2 --variant power-default
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path
from typing import List, Optional, Sequence, Tuple

import numpy as np

from . import layers as L
from .config import BertConfig
from .kernels import get_kernels
from .model import BIG
from .params_io import load_params

DEFAULT_THRESHOLDS = (1.0, 0.98, 0.95, 0.9, 0.8, 0.6)

# Deterministic latency model for the committed tables: a fixed per-request
# overhead (embedding + pooler) plus a per-word-vector encoder cost. Units
# are microseconds but only ratios are meaningful.
LATENCY_BASE_US = 30.0
LATENCY_PER_TOKEN_US = 1.5


def demanded_k(sig: np.ndarray, mask: np.ndarray, threshold: float) -> int:
    """Mirror of rust/src/runtime/adaptive.rs::demanded_k."""
    n = int(sig.shape[0])
    if n == 0:
        return 1
    if threshold >= 1.0:
        return n
    real = np.maximum(sig[mask > 0].astype(np.float32), np.float32(0.0))
    total = float(np.sum(real, dtype=np.float64))
    if real.size == 0 or total <= 0.0 or threshold <= 0.0:
        return 1
    desc = np.sort(real)[::-1]
    target = float(np.float32(threshold)) * total
    cum = np.cumsum(desc, dtype=np.float64)
    hit = np.nonzero(cum >= target)[0]
    if hit.size:
        return int(hit[0]) + 1
    return max(int(real.size), 1)


def keep_index_set(sig: np.ndarray, mask: np.ndarray, keep: int) -> np.ndarray:
    """Mirror of native.rs::keep_indices — CLS pinned on top, PAD sunk,
    ties broken by ascending position, kept set in original order."""
    scores = np.where(mask > 0, sig, np.float32(-1.0)).astype(np.float32)
    scores[0] = np.float32(BIG)
    order = np.argsort(-scores, kind="stable")
    return np.sort(order[:keep])


def forward_adaptive(
    params,
    cfg: BertConfig,
    kernels,
    tokens: np.ndarray,
    segs: np.ndarray,
    retention: Optional[Sequence[int]],
    threshold: Optional[float],
) -> Tuple[np.ndarray, int]:
    """One example, eager (dynamic shapes) — returns (logits, tokens
    processed: Σ over encoders of the surviving width after extraction)."""
    import jax.numpy as jnp

    mask = (tokens != 0).astype(np.float32)
    x = L.embed(params, cfg, jnp.asarray(tokens), jnp.asarray(segs))
    processed = 0
    for j in range(cfg.num_layers):
        layer = L.layer_at(params, cfg, j)
        x1, sig = L.attn_half(layer, cfg, kernels, x, jnp.asarray(mask))
        if retention is not None:
            keep = max(int(retention[j]), 1)
            if threshold is not None:
                sig_np = np.asarray(sig, dtype=np.float32)
                keep = min(keep, demanded_k(sig_np, mask, threshold))
            if keep < x1.shape[0]:
                idx = keep_index_set(np.asarray(sig, dtype=np.float32), mask, keep)
                x1 = x1[jnp.asarray(idx)]
                mask = mask[idx]
        processed += int(x1.shape[0])
        x = L.ffn_half(layer, cfg, kernels, x1)
    logits = L.pool_and_classify(params, cfg, kernels, x)
    return np.asarray(logits, dtype=np.float32), processed


def metric_value(kind: str, logits: np.ndarray, labels: np.ndarray) -> float:
    """Mirror of rust/src/eval/mod.rs (argmax: first strictly-greater wins,
    which is np.argmax's first-occurrence rule)."""
    pred = np.argmax(logits, axis=1)
    lab = labels.astype(np.int64)
    if kind == "accuracy":
        return float(np.mean(pred == lab))
    if kind == "matthews":
        tp = float(np.sum((pred == 1) & (lab == 1)))
        tn = float(np.sum((pred == 0) & (lab == 0)))
        fp = float(np.sum((pred == 1) & (lab == 0)))
        fn = float(np.sum((pred == 0) & (lab == 1)))
        denom = np.sqrt((tp + fp) * (tp + fn) * (tn + fp) * (tn + fn))
        return float((tp * tn - fp * fn) / denom) if denom > 0 else 0.0
    raise ValueError(f"unsupported calibration metric {kind!r}")


def effective_threshold(t: float) -> Optional[float]:
    """Mirror of RetentionPolicy::threshold / infer_adaptive_at filtering:
    only thresholds in the open interval (0, 1) leave the fixed path."""
    return t if 0.0 < t < 1.0 else None


def calibrate(artifact_dir: Path, thresholds: Sequence[float]):
    meta = json.loads((artifact_dir / "meta.json").read_text())
    params = load_params(str(artifact_dir / meta["weights"]))
    data = np.load(artifact_dir.parent / "test.npz")
    tokens, segs, labels = data["tokens"], data["segs"], data["labels"]
    word = np.asarray(params["embed"]["word"])
    w1 = np.asarray(params["layers"][0]["w1"])
    pos = np.asarray(params["embed"]["pos"])
    cfg = BertConfig(
        vocab_size=word.shape[0],
        hidden_size=meta["hidden_size"],
        num_layers=meta["num_layers"],
        num_heads=meta["num_heads"],
        ffn_size=w1.shape[1],
        max_len=pos.shape[0],
        num_classes=meta["num_classes"],
    )
    kernels = get_kernels(use_pallas=False)
    retention = meta.get("retention")
    if retention is None:
        raise SystemExit("calibration requires a PoWER variant (retention schedule)")

    n = tokens.shape[0]
    points = []
    fixed_logits = None
    report = []
    for t in sorted(set(float(x) for x in thresholds), reverse=True):
        logits = np.zeros((n, meta["num_classes"]), dtype=np.float32)
        total_tokens = 0
        for i in range(n):
            logits[i], proc = forward_adaptive(
                params, cfg, kernels, tokens[i], segs[i],
                retention, effective_threshold(t),
            )
            total_tokens += proc
        m = metric_value(meta["metric"], logits, labels)
        mean_tokens = total_tokens / n
        if fixed_logits is None:
            fixed_logits = logits  # highest threshold first == fixed path
        flips = int(np.sum(np.argmax(logits, 1) != np.argmax(fixed_logits, 1)))
        margins = np.sort(logits, axis=1)
        min_margin = float(np.min(margins[:, -1] - margins[:, -2]))
        points.append({
            "threshold": t,
            "metric": m,
            "mean_tokens": mean_tokens,
            "est_latency_us": LATENCY_BASE_US + LATENCY_PER_TOKEN_US * mean_tokens,
        })
        report.append((t, m, mean_tokens, flips, min_margin))
    doc = {
        "schema": 1,
        "dataset": meta["dataset"],
        "variant": meta["variant"],
        "metric": meta["metric"],
        "examples": n,
        "points": points,
    }
    return doc, report


def select_balanced(points: List[dict]) -> dict:
    """Mirror of ParetoTable::balanced for the printed summary."""
    full = next((p for p in points if p["threshold"] >= 1.0), None)
    floor = full["metric"] if full else max(p["metric"] for p in points)
    ok = [p for p in points if p["metric"] >= floor]
    return min(ok, key=lambda p: (p["mean_tokens"], -p["threshold"]))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--dataset", required=True)
    ap.add_argument("--variant", default="power-default")
    ap.add_argument("--artifacts", default=str(Path(__file__).resolve().parents[2] / "artifacts"))
    ap.add_argument("--thresholds", default=",".join(str(t) for t in DEFAULT_THRESHOLDS))
    ap.add_argument("--out", default=None, help="output path (default <variant dir>/pareto.json)")
    args = ap.parse_args()

    artifact_dir = Path(args.artifacts) / args.dataset / args.variant
    thresholds = [float(t) for t in args.thresholds.split(",") if t.strip()]
    doc, report = calibrate(artifact_dir, thresholds)

    print(f"{doc['dataset']}/{doc['variant']} ({doc['metric']}, {doc['examples']} examples)")
    print("  threshold   metric  mean_tokens  flips_vs_full  min_margin")
    for t, m, mt, flips, margin in report:
        print(f"  {t:9.3f}  {m:7.4f}  {mt:11.3f}  {flips:13d}  {margin:10.4f}")
    bal = select_balanced(doc["points"])
    fast = min(doc["points"], key=lambda p: (p["mean_tokens"], -p["metric"]))
    print(f"  balanced -> threshold {bal['threshold']:.3f} "
          f"(metric {bal['metric']:.4f}, {bal['mean_tokens']:.1f} tokens)")
    print(f"  fastest  -> threshold {fast['threshold']:.3f} "
          f"(metric {fast['metric']:.4f}, {fast['mean_tokens']:.1f} tokens)")

    out = Path(args.out) if args.out else artifact_dir / "pareto.json"
    out.write_text(json.dumps(doc, indent=1) + "\n")
    print(f"wrote {out}")


if __name__ == "__main__":
    main()
