"""Configuration dataclasses for the PoWER-BERT reproduction.

Two reproduction profiles exist (`quick` for tests/CI, `full` for the
EXPERIMENTS.md numbers). Both run the identical code path; `quick` only
shrinks model depth, data size and step counts.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple


@dataclass(frozen=True)
class BertConfig:
    """Architecture of the (scaled-down) BERT used throughout.

    The paper uses BERT_BASE: L=12, H=768, A=12, FFN=3072. We keep the
    topology (notably all 12 encoders, so retention configurations have the
    paper's length) and scale the width to stay trainable on one CPU core.
    """

    vocab_size: int = 1024
    hidden_size: int = 64          # H (paper: 768)
    num_layers: int = 6            # L (paper: 12; halved for the CPU budget —
                                   #    retention configs have 6 entries)
    num_heads: int = 4             # A (paper: 12)
    ffn_size: int = 256            # 4*H, as in the paper
    max_len: int = 128             # maximum N supported by position table
    num_classes: int = 2           # output classes (1 => regression)
    type_vocab: int = 2            # segment embeddings (sentence A/B)
    # ALBERT-style variant knobs
    share_params: bool = False     # share encoder weights across layers
    embed_factor: int = 0          # >0 => factorized embedding vocab->E->H
    dropout: float = 0.1
    ln_eps: float = 1e-6

    @property
    def head_dim(self) -> int:
        assert self.hidden_size % self.num_heads == 0
        return self.hidden_size // self.num_heads

    @property
    def is_regression(self) -> bool:
        return self.num_classes == 1


@dataclass(frozen=True)
class TaskSpec:
    """One synthetic dataset mirroring a row of the paper's Table 1."""

    name: str                      # e.g. "sst2"
    task: str                      # ACCEPTABILITY | NLI | SIMILARITY | ...
    num_classes: int               # 1 => regression (STS-B analog)
    seq_len: int                   # N after padding (scaled from the paper)
    paper_seq_len: int             # N the paper used
    metric: str                    # accuracy | f1 | matthews | spearman
    pair: bool                     # two-segment input (premise [SEP] hypothesis)
    train_size: int = 2048
    test_size: int = 512
    seed: int = 0


@dataclass(frozen=True)
class TrainConfig:
    """Hyper-parameters for one training phase (paper §4.1 ranges)."""

    steps: int = 300
    batch_size: int = 16
    lr: float = 5e-4               # scaled-width model trains with larger lr
    soft_extract_lr: float = 1e-2  # paper: higher lr for retention params
    warmup_frac: float = 0.1
    weight_decay: float = 0.01
    lambda_reg: float = 3e-4       # paper's regularizer range [1e-4, 1e-3]
    eval_every: int = 0            # 0 => only at end
    seed: int = 0


@dataclass(frozen=True)
class ReproProfile:
    """Scale knobs binding everything together."""

    name: str
    bert: BertConfig
    finetune: TrainConfig
    config_search: TrainConfig
    retrain: TrainConfig
    datasets: Tuple[str, ...]
    pareto_datasets: Tuple[str, ...]
    pareto_lambdas: Tuple[float, ...]
    batch_sizes: Tuple[int, ...] = (1, 8, 32)  # compiled batch sizes per artifact
    # Extra seq buckets per artifact as fractions of the task seq_len (the
    # serving side batches by true token count and executes short requests
    # at the smallest compiled bucket that fits). () disables the grid.
    seq_bucket_fracs: Tuple[float, ...] = (0.5,)
    data_scale: float = 1.0        # multiplies train/test sizes


# ---------------------------------------------------------------------------
# The synthetic task suite (Table 1 analog).
#
# Sequence lengths are scaled: paper 64 -> 32, 128 -> 64, 256/512 -> 128.
# Task types, class counts and metrics match the paper's Table 1/2.
# ---------------------------------------------------------------------------

TASKS: Dict[str, TaskSpec] = {
    s.name: s
    for s in [
        TaskSpec("sst2", "SENTIMENT", 2, 32, 64, "accuracy", False, train_size=8192, seed=15),
        TaskSpec("cola", "ACCEPTABILITY", 2, 32, 64, "matthews", False, train_size=8192, seed=11),
        TaskSpec("stsb", "SIMILARITY", 1, 32, 64, "spearman", True, train_size=8192, seed=19),
        TaskSpec("mrpc", "PARAPHRASE", 2, 64, 128, "f1", True, train_size=6144, seed=14),
        TaskSpec("qqp", "SIMILARITY", 2, 64, 128, "f1", True, train_size=6144, seed=13),
        TaskSpec("mnli-m", "NLI", 3, 64, 128, "accuracy", True, train_size=6144, seed=16),
        TaskSpec("mnli-mm", "NLI", 3, 64, 128, "accuracy", True, train_size=6144, seed=17),
        TaskSpec("qnli", "QA_NLI", 2, 64, 128, "accuracy", True, train_size=6144, seed=18),
        TaskSpec("rte", "NLI", 2, 128, 256, "accuracy", True, train_size=4096, seed=12),
        TaskSpec("imdb", "SENTIMENT", 2, 128, 512, "accuracy", False, train_size=4096, seed=20),
        TaskSpec("race", "QA", 2, 128, 512, "accuracy", True, train_size=4096, seed=21),
    ]
}

GLUE_TASKS: Tuple[str, ...] = (
    "cola", "rte", "qqp", "mrpc", "sst2", "mnli-m", "mnli-mm", "qnli", "stsb",
)

# The paper's Figure 7 shows six datasets; the single-CPU-core budget here
# limits the sweep to the two the paper highlights in its headline numbers
# (CoLA) plus SST-2 (the dataset used for all of the paper's case studies).
PARETO_TASKS: Tuple[str, ...] = ("cola", "sst2")


def quick_profile() -> ReproProfile:
    bert = BertConfig(vocab_size=512, hidden_size=32, num_layers=4,
                      num_heads=2, ffn_size=64, max_len=64)
    tc = TrainConfig(steps=60, batch_size=16, eval_every=0)
    return ReproProfile(
        name="quick",
        bert=bert,
        finetune=tc,
        config_search=dataclasses.replace(tc, steps=40),
        retrain=dataclasses.replace(tc, steps=40),
        datasets=("sst2", "cola"),
        pareto_datasets=("sst2",),
        pareto_lambdas=(1e-4, 1e-3),
        batch_sizes=(1, 8),
        data_scale=0.25,
    )


def full_profile() -> ReproProfile:
    bert = BertConfig()
    return ReproProfile(
        name="full",
        bert=bert,
        finetune=TrainConfig(steps=320, batch_size=32, lr=1e-3),
        config_search=TrainConfig(steps=160, batch_size=32, lr=1e-3),
        retrain=TrainConfig(steps=200, batch_size=32, lr=1e-3),
        datasets=tuple(TASKS.keys()),
        pareto_datasets=PARETO_TASKS,
        pareto_lambdas=(1e-4, 3e-4, 1e-3),
        batch_sizes=(1, 8, 32),
    )


def get_profile(name: str) -> ReproProfile:
    if name == "quick":
        return quick_profile()
    if name == "full":
        return full_profile()
    raise ValueError(f"unknown profile {name!r}")


def config_hash(*objs) -> str:
    """Stable hash of dataclass configs — used for artifact staleness checks."""

    def enc(o):
        if dataclasses.is_dataclass(o) and not isinstance(o, type):
            return {"__cls__": type(o).__name__, **dataclasses.asdict(o)}
        raise TypeError(o)

    blob = json.dumps(objs, default=enc, sort_keys=True).encode()
    return hashlib.sha256(blob).hexdigest()[:16]
