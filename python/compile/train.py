"""Training: hand-rolled Adam, losses, metrics, and the training loops used
by every phase (fine-tune, configuration-search, re-train, distillation).

No optax/flax in this environment — the optimizer is a ~40-line Adam with
decoupled weight decay, linear warmup/decay, and a per-leaf learning-rate
multiplier tree (the paper trains the soft-extract retention parameters with
a much higher learning rate than the BERT weights, §4.1).
"""

from __future__ import annotations

import functools
from typing import Callable, Dict, Iterable, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .config import TaskSpec, TrainConfig

Pytree = object


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None].astype(jnp.int32), axis=-1))


def mse(pred: jnp.ndarray, target: jnp.ndarray) -> jnp.ndarray:
    return jnp.mean(jnp.square(pred.squeeze(-1) - target))


def task_loss(logits: jnp.ndarray, labels: jnp.ndarray, num_classes: int) -> jnp.ndarray:
    if num_classes == 1:
        return mse(logits, labels)
    return cross_entropy(logits, labels)


def kl_soft_targets(student_logits, teacher_logits, temperature=2.0):
    """Distillation soft-target loss (Hinton et al.), used by DistilBERT/PKD."""
    t = temperature
    p_t = jax.nn.softmax(teacher_logits / t, axis=-1)
    logp_s = jax.nn.log_softmax(student_logits / t, axis=-1)
    return -jnp.mean(jnp.sum(p_t * logp_s, axis=-1)) * t * t


# ---------------------------------------------------------------------------
# Metrics (numpy; mirrored in rust/src/eval for the benches)
# ---------------------------------------------------------------------------

def accuracy(pred: np.ndarray, y: np.ndarray) -> float:
    return float(np.mean(pred == y))


def f1_binary(pred: np.ndarray, y: np.ndarray) -> float:
    tp = float(np.sum((pred == 1) & (y == 1)))
    fp = float(np.sum((pred == 1) & (y == 0)))
    fn = float(np.sum((pred == 0) & (y == 1)))
    denom = 2 * tp + fp + fn
    return 2 * tp / denom if denom > 0 else 0.0


def matthews(pred: np.ndarray, y: np.ndarray) -> float:
    tp = float(np.sum((pred == 1) & (y == 1)))
    tn = float(np.sum((pred == 0) & (y == 0)))
    fp = float(np.sum((pred == 1) & (y == 0)))
    fn = float(np.sum((pred == 0) & (y == 1)))
    denom = np.sqrt((tp + fp) * (tp + fn) * (tn + fp) * (tn + fn))
    return (tp * tn - fp * fn) / denom if denom > 0 else 0.0


def _ranks(x: np.ndarray) -> np.ndarray:
    order = np.argsort(x)
    ranks = np.empty_like(order, dtype=np.float64)
    ranks[order] = np.arange(len(x))
    return ranks


def spearman(pred: np.ndarray, y: np.ndarray) -> float:
    rp, ry = _ranks(pred), _ranks(y)
    rp, ry = rp - rp.mean(), ry - ry.mean()
    denom = np.sqrt(np.sum(rp**2) * np.sum(ry**2))
    return float(np.sum(rp * ry) / denom) if denom > 0 else 0.0


def compute_metric(metric: str, outputs: np.ndarray, labels: np.ndarray) -> float:
    """outputs: logits [n, C] (classification) or [n, 1] (regression)."""
    if metric == "spearman":
        return spearman(outputs[:, 0], labels)
    pred = outputs.argmax(axis=-1)
    if metric == "accuracy":
        return accuracy(pred, labels)
    if metric == "f1":
        return f1_binary(pred, labels)
    if metric == "matthews":
        return matthews(pred, labels)
    raise ValueError(metric)


# ---------------------------------------------------------------------------
# Adam with decoupled weight decay and per-leaf lr multipliers
# ---------------------------------------------------------------------------

def adam_init(params: Pytree):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params), "t": jnp.zeros((), jnp.int32)}


def lr_schedule(step, total_steps, base_lr, warmup_frac):
    warm = max(1, int(total_steps * warmup_frac))
    lr = jnp.where(
        step < warm,
        base_lr * step / warm,
        base_lr * jnp.maximum(0.0, (total_steps - step) / max(1, total_steps - warm)),
    )
    return lr


def adam_step(params, grads, state, *, lr, lr_mult: Optional[Pytree] = None,
              weight_decay=0.0, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    mhat_scale = 1.0 / (1 - b1 ** t.astype(jnp.float32))
    vhat_scale = 1.0 / (1 - b2 ** t.astype(jnp.float32))

    if lr_mult is None:
        lr_mult = jax.tree.map(lambda _: 1.0, params)

    def upd(p, m_, v_, mult):
        step_ = lr * mult * (m_ * mhat_scale) / (jnp.sqrt(v_ * vhat_scale) + eps)
        if weight_decay > 0:
            step_ = step_ + lr * mult * weight_decay * p
        return p - step_

    new_params = jax.tree.map(upd, params, m, v, lr_mult)
    return new_params, {"m": m, "v": v, "t": t}


# ---------------------------------------------------------------------------
# Data iteration
# ---------------------------------------------------------------------------

def batches(rng: np.random.Generator, arrays: Tuple[np.ndarray, ...],
            batch_size: int, steps: int):
    """Yields ``steps`` shuffled batches, reshuffling each epoch."""
    n = arrays[0].shape[0]
    idx = rng.permutation(n)
    at = 0
    for _ in range(steps):
        if at + batch_size > n:
            idx = rng.permutation(n)
            at = 0
        sel = idx[at : at + batch_size]
        at += batch_size
        yield tuple(a[sel] for a in arrays)


# ---------------------------------------------------------------------------
# Training loops
# ---------------------------------------------------------------------------

def train_classifier(fwd: Callable, params: Pytree, data, task: TaskSpec,
                     tc: TrainConfig, extra_loss: Optional[Callable] = None,
                     lr_mult: Optional[Pytree] = None) -> Pytree:
    """Generic supervised loop.

    fwd(params, tokens, segs) -> (logits, aux).
    extra_loss(params, aux) -> scalar added to the task loss (regularizers,
    distillation terms get their own loops below).
    """
    tokens, segs, labels = data
    state = adam_init(params)
    rng = np.random.default_rng(tc.seed)

    @jax.jit
    def step_fn(params, state, t, tok, sg, y):
        def loss_fn(p):
            logits, aux = fwd(p, tok, sg)
            loss = task_loss(logits, y, task.num_classes)
            if extra_loss is not None:
                loss = loss + extra_loss(p, aux)
            return loss
        loss, grads = jax.value_and_grad(loss_fn)(params)
        lr = lr_schedule(t, tc.steps, tc.lr, tc.warmup_frac)
        params, state = adam_step(params, grads, state, lr=lr,
                                  lr_mult=lr_mult, weight_decay=tc.weight_decay)
        return params, state, loss

    losses = []
    for t, (tok, sg, y) in enumerate(batches(rng, (tokens, segs, labels), tc.batch_size, tc.steps)):
        params, state, loss = step_fn(params, state, jnp.asarray(t, jnp.float32), tok, sg, y)
        losses.append(float(loss))
    return params, losses


def train_soft_extract(fwd_soft: Callable, params: Pytree, r0: jnp.ndarray,
                       data, task: TaskSpec, tc: TrainConfig) -> Tuple[Pytree, jnp.ndarray, List[float]]:
    """Configuration-search phase (paper §3.4 step 2).

    Minimizes  L(theta, r) + lambda * sum_j j * mass(j; r)  with r in [0,1]
    (projected after each step), retention params trained at
    ``tc.soft_extract_lr`` while BERT weights use ``tc.lr``.
    """
    tokens, segs, labels = data
    trainable = (params, r0)
    state = adam_init(trainable)
    rng = np.random.default_rng(tc.seed)
    L = r0.shape[0]
    j_scale = jnp.arange(1, L + 1, dtype=jnp.float32)  # paper scales mass by encoder index

    lr_mult = (jax.tree.map(lambda _: 1.0, params), tc.soft_extract_lr / tc.lr)

    @jax.jit
    def step_fn(trainable, state, t, tok, sg, y):
        def loss_fn(tr):
            p, r = tr
            logits, mass = fwd_soft(p, r, tok, sg)
            base = task_loss(logits, y, task.num_classes)
            reg = jnp.sum(j_scale * jnp.mean(mass, axis=0))
            return base + tc.lambda_reg * reg, (base, reg)
        (loss, _), grads = jax.value_and_grad(loss_fn, has_aux=True)(trainable)
        lr = lr_schedule(t, tc.steps, tc.lr, tc.warmup_frac)
        trainable, state = adam_step(trainable, grads, state, lr=lr,
                                     lr_mult=lr_mult, weight_decay=0.0)
        p, r = trainable
        trainable = (p, jnp.clip(r, 0.0, 1.0))  # projection onto [0,1]
        return trainable, state, loss

    losses = []
    for t, (tok, sg, y) in enumerate(batches(rng, (tokens, segs, labels), tc.batch_size, tc.steps)):
        trainable, state, loss = step_fn(trainable, state, jnp.asarray(t, jnp.float32), tok, sg, y)
        losses.append(float(loss))
    params, r = trainable
    return params, r, losses


def train_distilled(student_fwd: Callable, student_params: Pytree,
                    teacher_fwd: Callable, teacher_params: Pytree,
                    data, task: TaskSpec, tc: TrainConfig,
                    alpha: float = 0.5, temperature: float = 2.0,
                    pkd_layer_map: Optional[List[Tuple[int, int]]] = None,
                    pkd_beta: float = 10.0) -> Pytree:
    """DistilBERT-style (and, with ``pkd_layer_map``, BERT-PKD-style) training.

    loss = alpha * CE(student, y) + (1-alpha) * KL(student || teacher)
           [+ pkd_beta * mean ||norm(CLS_s^i) - norm(CLS_t^j)||^2]
    """
    tokens, segs, labels = data
    state = adam_init(student_params)
    rng = np.random.default_rng(tc.seed)

    @jax.jit
    def step_fn(params, state, t, tok, sg, y):
        t_logits, t_aux = teacher_fwd(teacher_params, tok, sg)

        def loss_fn(p):
            s_logits, s_aux = student_fwd(p, tok, sg)
            loss = alpha * task_loss(s_logits, y, task.num_classes)
            loss = loss + (1 - alpha) * kl_soft_targets(s_logits, t_logits, temperature)
            if pkd_layer_map is not None:
                pkd = 0.0
                for si, ti in pkd_layer_map:
                    cs = s_aux["hidden"][si][:, 0, :]
                    ct = t_aux["hidden"][ti][:, 0, :]
                    cs = cs / (jnp.linalg.norm(cs, axis=-1, keepdims=True) + 1e-8)
                    ct = ct / (jnp.linalg.norm(ct, axis=-1, keepdims=True) + 1e-8)
                    pkd = pkd + jnp.mean(jnp.sum(jnp.square(cs - ct), axis=-1))
                loss = loss + pkd_beta * pkd / max(1, len(pkd_layer_map))
            return loss

        loss, grads = jax.value_and_grad(loss_fn)(params)
        lr = lr_schedule(t, tc.steps, tc.lr, tc.warmup_frac)
        params, state = adam_step(params, grads, state, lr=lr, weight_decay=tc.weight_decay)
        return params, state, loss

    losses = []
    for t, (tok, sg, y) in enumerate(batches(rng, (tokens, segs, labels), tc.batch_size, tc.steps)):
        student_params, state, loss = step_fn(student_params, state, jnp.asarray(t, jnp.float32), tok, sg, y)
        losses.append(float(loss))
    return student_params, losses


# ---------------------------------------------------------------------------
# Evaluation
# ---------------------------------------------------------------------------

def predict_all(fwd: Callable, params: Pytree, tokens, segs,
                batch_size: int = 64) -> np.ndarray:
    outs = []
    n = tokens.shape[0]
    fwd_j = jax.jit(lambda p, t, s: fwd(p, t, s)[0])
    for i in range(0, n, batch_size):
        tok, sg = tokens[i : i + batch_size], segs[i : i + batch_size]
        pad = 0
        if tok.shape[0] < batch_size:
            pad = batch_size - tok.shape[0]
            tok = np.pad(tok, ((0, pad), (0, 0)))
            sg = np.pad(sg, ((0, pad), (0, 0)))
        o = np.asarray(fwd_j(params, tok, sg))
        outs.append(o[: batch_size - pad])
    return np.concatenate(outs, axis=0)


def evaluate(fwd: Callable, params: Pytree, data, task: TaskSpec,
             batch_size: int = 64) -> float:
    tokens, segs, labels = data
    outputs = predict_all(fwd, params, tokens, segs, batch_size)
    return compute_metric(task.metric, outputs, labels)
