"""Flatten/unflatten the dict-pytree params to named arrays.

The naming scheme ("embed/word", "layers/3/wq", ...) is the contract between
the AOT exporter (weights.npz + meta.json param order) and the Rust runtime,
which feeds the arrays back as PJRT parameters in exactly this order.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

import jax.numpy as jnp
import numpy as np


def flatten_params(params) -> List[Tuple[str, np.ndarray]]:
    out: List[Tuple[str, np.ndarray]] = []

    def walk(prefix: str, node):
        if isinstance(node, dict):
            for k in sorted(node):
                walk(f"{prefix}/{k}" if prefix else k, node[k])
        elif isinstance(node, (list, tuple)):
            for i, v in enumerate(node):
                walk(f"{prefix}/{i}", v)
        else:
            out.append((prefix, np.asarray(node)))

    walk("", params)
    return out


def unflatten_params(named: Dict[str, np.ndarray]):
    """Inverse of :func:`flatten_params` (integer path segments -> lists)."""
    root: Dict = {}
    for name, arr in named.items():
        parts = name.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = jnp.asarray(arr)

    def fix(node):
        if not isinstance(node, dict):
            return node
        keys = list(node.keys())
        if keys and all(k.isdigit() for k in keys):
            return [fix(node[str(i)]) for i in range(len(keys))]
        return {k: fix(v) for k, v in node.items()}

    return fix(root)


def save_params(path: str, params) -> None:
    np.savez(path, **{name: arr for name, arr in flatten_params(params)})


def load_params(path: str):
    with np.load(path) as z:
        return unflatten_params({k: z[k] for k in z.files})
