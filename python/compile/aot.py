"""AOT export: lower inference graphs to HLO *text* + weights.npz + meta.json.

HLO text (not a serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the HLO text parser
reassigns ids, so text round-trips cleanly. See /opt/xla-example/gen_hlo.py.

Artifact layout per (dataset, variant):
    model.b{B}.hlo.txt        one compiled graph per batch size B (full seq)
    model.s{S}.b{B}.hlo.txt   extra (batch, seq) grid cells, S < seq_len
    weights.npz               named parameter arrays
    meta.json                 kind, shapes, param order, retention, metrics

Sequence buckets: the serving side batches requests by true token count, so
each variant may also be lowered at shorter sequence lengths. meta.json then
carries ``hlo_grid: {seq: {batch: file}}`` alongside the legacy flat
``hlo`` map (the full-seq row); retention entries >= the bucket length
simply skip elimination at that encoder (model.encoder_forward).

Graph signature (the Rust runtime contract):
    parameters: (tokens i32[B,N], segs i32[B,N], w_0, ..., w_k)
    result:     1-tuple (logits f32[B,C])
and for debug variants a 2-tuple (logits, kept_positions i32[B,L,topN]).
"""

from __future__ import annotations

import json
import os
from typing import Callable, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M
from .config import BertConfig
from .params_io import flatten_params


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-reassignment-safe)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_infer_fn(fwd: Callable, params, batch: int, seq_len: int,
                   extra_outputs: bool = False) -> str:
    """Lower ``fwd(params, tokens, segs)`` to HLO text with weights as
    parameters (tokens/segs first, then the flattened weights)."""
    named = flatten_params(params)
    names = [n for n, _ in named]
    arrs = [a for _, a in named]

    import jax.tree_util as jtu
    # Rebuild the params pytree inside the traced fn from the flat list so
    # the lowered module's parameters are exactly [tokens, segs, *weights].
    treedef = jtu.tree_structure(params)
    flat_ref, _ = jtu.tree_flatten(params)
    # flatten_params sorts dict keys — jax's tree_flatten also sorts dict
    # keys, and list order is preserved by both, so the orders agree; assert.
    assert len(flat_ref) == len(arrs)
    for a, b in zip(flat_ref, arrs):
        assert a.shape == b.shape, "param order mismatch between flatteners"

    def infer(tokens, segs, *weights):
        p = jtu.tree_unflatten(treedef, list(weights))
        logits, aux = fwd(p, tokens, segs)
        if extra_outputs:
            # Per-encoder surviving original positions (Figure 8 trace),
            # right-padded with -1 to the full N so the output is rectangular.
            padded = [
                jnp.pad(k, ((0, 0), (0, seq_len - k.shape[1])), constant_values=-1)
                for k in aux["kept"]
            ]
            kept = jnp.stack(padded, axis=1).astype(jnp.int32)  # [B, L, N]
            return (logits, kept)
        return (logits,)

    specs = [
        jax.ShapeDtypeStruct((batch, seq_len), jnp.int32),
        jax.ShapeDtypeStruct((batch, seq_len), jnp.int32),
    ] + [jax.ShapeDtypeStruct(a.shape, a.dtype) for a in arrs]
    lowered = jax.jit(infer).lower(*specs)
    return to_hlo_text(lowered)


def export_variant(out_dir: str, fwd: Callable, params, cfg: BertConfig,
                   seq_len: int, batch_sizes: Sequence[int],
                   meta: Dict,
                   seq_buckets: Optional[Sequence[int]] = None) -> Dict:
    """Writes the full artifact for one model variant; returns its meta.

    ``seq_buckets``: extra sequence lengths (< seq_len) to lower each batch
    size at, forming the (batch, seq) execution grid the Rust pool serves
    short requests from without full-length padding.
    """
    os.makedirs(out_dir, exist_ok=True)
    named = flatten_params(params)
    np.savez(os.path.join(out_dir, "weights.npz"),
             **{n: a for n, a in named})
    hlo_files = {}
    for b in batch_sizes:
        text = lower_infer_fn(fwd, params, b, seq_len)
        fname = f"model.b{b}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        hlo_files[str(b)] = fname
    hlo_grid = {str(seq_len): dict(hlo_files)}
    for s in sorted(set(int(s) for s in (seq_buckets or []))):
        if s >= seq_len or s < 8:
            continue
        row = {}
        for b in batch_sizes:
            text = lower_infer_fn(fwd, params, b, s)
            fname = f"model.s{s}.b{b}.hlo.txt"
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(text)
            row[str(b)] = fname
        hlo_grid[str(s)] = row
    meta = dict(meta)
    meta.update({
        "seq_len": seq_len,
        "batch_sizes": list(batch_sizes),
        "hlo": hlo_files,
        "weights": "weights.npz",
        "param_order": [n for n, _ in named],
        "hidden_size": cfg.hidden_size,
        "num_layers": cfg.num_layers,
        "num_heads": cfg.num_heads,
        "num_classes": cfg.num_classes,
    })
    if len(hlo_grid) > 1:
        meta["hlo_grid"] = hlo_grid
    with open(os.path.join(out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    return meta


def export_test_split(out_dir: str, tokens: np.ndarray, segs: np.ndarray,
                      labels: np.ndarray) -> None:
    """Test split consumed by the Rust eval/bench side (Literal::read_npz)."""
    os.makedirs(out_dir, exist_ok=True)
    np.savez(os.path.join(out_dir, "test.npz"),
             tokens=tokens.astype(np.int32),
             segs=segs.astype(np.int32),
             labels=np.asarray(labels, dtype=np.float32))
