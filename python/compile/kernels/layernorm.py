"""Pallas kernel: fused residual-add + LayerNorm.

BERT applies `LayerNorm(x + residual)` after both the attention projection
and the FFN. Fusing the add with the normalization saves one full [N, H]
HBM round-trip per use (two per encoder). Row-tiled grid; each step
normalizes a [bm, H] tile entirely in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ln_kernel(x_ref, res_ref, gamma_ref, beta_ref, o_ref, *, eps):
    y = x_ref[...] + res_ref[...]
    mu = jnp.mean(y, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(y - mu), axis=-1, keepdims=True)
    o_ref[...] = (y - mu) / jnp.sqrt(var + eps) * gamma_ref[...][None, :] + beta_ref[...][None, :]


def _pick_block(n: int, target: int) -> int:
    for b in range(min(n, target), 0, -1):
        if n % b == 0:
            return b
    return n


@functools.partial(jax.jit, static_argnames=("eps", "block_rows"))
def layernorm_residual(x: jnp.ndarray, res: jnp.ndarray, gamma: jnp.ndarray,
                       beta: jnp.ndarray, eps: float = 1e-6,
                       block_rows: int = 128) -> jnp.ndarray:
    """LayerNorm(x + res) * gamma + beta.  x, res: [N, H]."""
    n, hdim = x.shape
    bm = _pick_block(n, block_rows)
    return pl.pallas_call(
        functools.partial(_ln_kernel, eps=eps),
        grid=(n // bm,),
        in_specs=[
            pl.BlockSpec((bm, hdim), lambda i: (i, 0)),
            pl.BlockSpec((bm, hdim), lambda i: (i, 0)),
            pl.BlockSpec((hdim,), lambda i: (0,)),
            pl.BlockSpec((hdim,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, hdim), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, hdim), x.dtype),
        interpret=True,
    )(x, res, gamma, beta)
