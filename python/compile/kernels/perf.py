"""L1 kernel performance model: VMEM footprint + MXU-utilization estimates.

interpret=True gives CPU-numpy timings that say nothing about TPU behaviour,
so kernel optimization is *structural*: per BlockSpec we bound the VMEM
working set (must fit the ~16 MiB/core budget with double-buffering) and
estimate MXU utilization from the matmul shapes (the systolic array is
128x128; tiles below that waste lanes). These numbers are reported in
DESIGN.md §Perf / EXPERIMENTS.md §Perf and are the kernel-level acceptance
criteria for this reproduction.

Run:  python -m compile.kernels.perf            # table for the default cfg
      python -m compile.kernels.perf --paper    # paper-scale BERT_BASE
"""

from __future__ import annotations

import argparse
from dataclasses import dataclass
from typing import List

F32 = 4  # bytes
VMEM_BUDGET = 16 * 1024 * 1024  # per-core VMEM, bytes
MXU = 128  # systolic array dimension


@dataclass
class KernelReport:
    name: str
    grid: str
    vmem_bytes: int
    flops_per_step: int
    mxu_util: float          # fraction of MXU lanes used by the dominant matmul
    notes: str

    @property
    def vmem_frac(self) -> float:
        return self.vmem_bytes / VMEM_BUDGET


def _mxu_util(m: int, k: int, n: int) -> float:
    """Utilization of a (m,k)x(k,n) matmul on a 128x128 systolic array:
    lanes are wasted when m or n are below 128 (k streams through)."""
    return min(m, MXU) * min(n, MXU) / (MXU * MXU)


def attention_report(heads: int, n: int, d: int, bq: int) -> KernelReport:
    """mha_with_scores: grid (heads, n/bq); per step the q tile, full K/V
    panels, the [bq, n] probability tile, ctx tile and sig accumulator are
    VMEM-resident (double-buffered inputs)."""
    vmem = (
        2 * bq * d * F32        # q tile (double-buffered)
        + 2 * 2 * n * d * F32   # K and V panels
        + bq * n * F32          # logits/probs tile
        + bq * d * F32          # ctx tile
        + 2 * n * F32           # mask + sig
    )
    flops = 2 * bq * n * d + 2 * bq * n * d + 3 * bq * n  # QK^T + PV + softmax
    # Dominant matmuls: (bq,d)x(d,n) and (bq,n)x(n,d).
    util = max(_mxu_util(bq, d, n), _mxu_util(bq, n, d))
    return KernelReport(
        name=f"mha_with_scores h={heads} n={n} d={d} bq={bq}",
        grid=f"({heads}, {n // bq})",
        vmem_bytes=vmem,
        flops_per_step=flops,
        mxu_util=util,
        notes="scores fused: saves one n^2/head HBM re-read vs two-pass",
    )


def ffn_report(n: int, h: int, i: int, bm: int, bi: int = 512) -> KernelReport:
    """Column-tiled FFN: per (row, column) grid step only a [H, bi] W1 slab,
    a [bi, H] W2 slab and the [bm, bi] activation slab are resident; the
    output tile is revisited across column tiles (accumulation)."""
    bi = min(bi, i)
    vmem = (
        2 * bm * h * F32            # x tile (double-buffered)
        + 2 * (h * bi + bi * h) * F32  # W1/W2 column slabs (double-buffered)
        + (bi + h) * F32            # bias slabs
        + bm * bi * F32             # activation slab (never leaves VMEM)
        + bm * h * F32              # out tile (revisited accumulator)
    )
    flops = 2 * bm * h * bi * 2
    util = max(_mxu_util(bm, h, bi), _mxu_util(bm, bi, h))
    return KernelReport(
        name=f"ffn n={n} H={h} I={i} bm={bm} bi={bi}",
        grid=f"({n // bm}, {i // bi})",
        vmem_bytes=vmem,
        flops_per_step=flops,
        mxu_util=util,
        notes="[bm,bi] activation stays in VMEM; column tiling fits BERT_BASE",
    )


def layernorm_report(n: int, h: int, bm: int) -> KernelReport:
    vmem = (3 * bm * h + 2 * h) * F32
    return KernelReport(
        name=f"layernorm_residual n={n} H={h} bm={bm}",
        grid=f"({n // bm},)",
        vmem_bytes=vmem,
        flops_per_step=8 * bm * h,
        mxu_util=0.0,
        notes="VPU-bound; fused residual-add saves one [n,H] HBM round-trip",
    )


def model_reports(heads: int, n: int, d: int, h: int, i: int,
                  bq: int = 128, bm: int = 128) -> List[KernelReport]:
    bq = min(bq, n)
    bm = min(bm, n)
    return [
        attention_report(heads, n, d, bq),
        ffn_report(n, h, i, bm),
        layernorm_report(n, h, bm),
    ]


def encoder_flops(n: int, h: int, i: int) -> int:
    """Total FLOPs of one encoder over n word-vectors (the paper's cost
    model: compute per encoder is linear in retained word-vectors, §4.2)."""
    qkv_proj = 3 * 2 * n * h * h
    attn = 2 * 2 * n * n * h
    out_proj = 2 * n * h * h
    ffn = 2 * 2 * n * h * i
    return qkv_proj + attn + out_proj + ffn


def power_flop_reduction(retention: List[int], seq_len: int, h: int, i: int) -> float:
    """FLOP ratio baseline/power for a retention configuration."""
    base = sum(encoder_flops(seq_len, h, i) for _ in retention)
    # Encoder j runs attention at the *input* width, FFN at the output width;
    # approximating both at the retained width is within a few percent.
    power = sum(encoder_flops(r, h, i) for r in retention)
    return base / power


def render(reports: List[KernelReport]) -> str:
    out = [f"{'kernel':<44} {'grid':<10} {'VMEM':>10} {'%bud':>6} {'MXU':>5}  notes"]
    for r in reports:
        out.append(
            f"{r.name:<44} {r.grid:<10} {r.vmem_bytes / 1024:>8.1f}KB "
            f"{100 * r.vmem_frac:>5.1f}% {100 * r.mxu_util:>4.0f}%  {r.notes}"
        )
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--paper", action="store_true",
                    help="paper-scale BERT_BASE (H=768, A=12, N=128)")
    args = ap.parse_args()
    if args.paper:
        reports = model_reports(heads=12, n=128, d=64, h=768, i=3072)
    else:
        reports = model_reports(heads=4, n=128, d=16, h=64, i=256)
    print(render(reports))
    ret = [153, 125, 111, 105, 85, 80, 72, 48, 35, 27, 22, 5]  # paper's RTE config
    print(f"\npaper RTE retention FLOP reduction (H=768): "
          f"{power_flop_reduction(ret, 256, 768, 3072):.2f}x (paper reports 3.4x wall-clock)")


if __name__ == "__main__":
    main()
