"""Pure-jnp oracles for every Pallas kernel.

These are the correctness ground truth: pytest asserts the Pallas
implementations (interpret=True) match these to tight tolerances across
shape/dtype sweeps (see python/tests/). They are also usable as a drop-in
slow path (`use_pallas=False` in the L2 model) to cross-check whole-model
numerics.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def mha_with_scores(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    mask: jnp.ndarray):
    """Multi-head attention + PoWER significance scores, one example.

    Args:
      q, k, v: [heads, N, d] projected query/key/value.
      mask:    [N] 1.0 for valid positions, 0.0 for PAD.

    Returns:
      ctx: [heads, N, d] attention output per head.
      sig: [N] significance scores  Sig(w) = sum_h sum_{w' valid} A_h[w', w]
           (paper §3.2, attention *column* sums aggregated over heads).
    """
    d = q.shape[-1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, q.dtype))
    logits = jnp.einsum("hqd,hkd->hqk", q, k) * scale
    logits = jnp.where(mask[None, None, :] > 0, logits, jnp.asarray(-1e9, q.dtype))
    probs = jax.nn.softmax(logits, axis=-1)
    # Exclude PAD query rows from the column sums: a PAD row's attention
    # distribution is meaningless and must not contribute significance.
    probs_for_sig = probs * mask[None, :, None]
    sig = jnp.sum(probs_for_sig, axis=(0, 1))
    ctx = jnp.einsum("hqk,hkd->hqd", probs, v)
    return ctx, sig


def ffn(x: jnp.ndarray, w1: jnp.ndarray, b1: jnp.ndarray,
        w2: jnp.ndarray, b2: jnp.ndarray) -> jnp.ndarray:
    """Position-wise feed-forward: GELU(x@w1+b1)@w2+b2.  x: [N, H]."""
    h = jax.nn.gelu(x @ w1 + b1[None, :], approximate=True)
    return h @ w2 + b2[None, :]


def layernorm_residual(x: jnp.ndarray, res: jnp.ndarray,
                       gamma: jnp.ndarray, beta: jnp.ndarray,
                       eps: float = 1e-6) -> jnp.ndarray:
    """LayerNorm(x + res) over the last dim.  x, res: [N, H]."""
    y = x + res
    mu = jnp.mean(y, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(y - mu), axis=-1, keepdims=True)
    return (y - mu) / jnp.sqrt(var + eps) * gamma[None, :] + beta[None, :]


def soft_extract(x: jnp.ndarray, ranks: jnp.ndarray, r: jnp.ndarray) -> jnp.ndarray:
    """Soft-extract (paper §3.3): multiply word-vector i by r[rank(i)].

    x: [N, H]; ranks: i32 [N] — sorted position of each word-vector by
    significance score (0 = most significant); r: [N] retention params.
    """
    return x * r[ranks][:, None]
