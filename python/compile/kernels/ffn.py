"""Pallas kernel: fused position-wise feed-forward network.

Computes GELU(x@W1+b1)@W2+b2 with both matmuls and the activation fused in a
single VMEM-resident pass over a (row, intermediate-column) tile — the
[bm, bi] activation slab never round-trips to HBM (in the unfused L2 graph
the whole [N, 4H] tensor would be written and re-read per encoder).

Grid: (row tiles, intermediate-column tiles). GELU is applied per-column
slab (it is elementwise over the intermediate dimension, so column tiling is
exact), and the output tile is *revisited* across the column grid dimension,
accumulating partial products — the standard Pallas reduction pattern.

The column tiling is what makes the kernel viable at paper scale: BERT_BASE
(H=768, I=3072) weight panels are 2 x 9.4MB, which busts the ~16MB VMEM
budget if held whole; with bi=512 the working set is ~3.3MB
(see compile/kernels/perf.py and the §Perf log in EXPERIMENTS.md).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ffn_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    """One (row-tile, column-tile) grid step with output accumulation."""
    i = pl.program_id(1)
    x = x_ref[...]                                     # [bm, H]
    h = jnp.dot(x, w1_ref[...], preferred_element_type=jnp.float32) + b1_ref[...][None, :]
    h = jax.nn.gelu(h, approximate=True)               # [bm, bi]
    partial = jnp.dot(h, w2_ref[...], preferred_element_type=jnp.float32)  # [bm, H]

    @pl.when(i == 0)
    def _init():
        o_ref[...] = b2_ref[...][None, :] + partial

    @pl.when(i != 0)
    def _acc():
        o_ref[...] += partial


def _pick_block(n: int, target: int) -> int:
    for b in range(min(n, target), 0, -1):
        if n % b == 0:
            return b
    return n


@functools.partial(jax.jit, static_argnames=("block_rows", "block_i"))
def ffn(x: jnp.ndarray, w1: jnp.ndarray, b1: jnp.ndarray,
        w2: jnp.ndarray, b2: jnp.ndarray, block_rows: int = 128,
        block_i: int = 512) -> jnp.ndarray:
    """x: [N, H]; w1: [H, I]; b1: [I]; w2: [I, H]; b2: [H] -> [N, H]."""
    n, hdim = x.shape
    idim = w1.shape[1]
    bm = _pick_block(n, block_rows)
    bi = _pick_block(idim, block_i)
    return pl.pallas_call(
        _ffn_kernel,
        grid=(n // bm, idim // bi),
        in_specs=[
            pl.BlockSpec((bm, hdim), lambda r, i: (r, 0)),
            pl.BlockSpec((hdim, bi), lambda r, i: (0, i)),
            pl.BlockSpec((bi,), lambda r, i: (i,)),
            pl.BlockSpec((bi, hdim), lambda r, i: (i, 0)),
            pl.BlockSpec((hdim,), lambda r, i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, hdim), lambda r, i: (r, 0)),  # revisited over i
        out_shape=jax.ShapeDtypeStruct((n, hdim), x.dtype),
        interpret=True,
    )(x, w1, b1, w2, b2)
