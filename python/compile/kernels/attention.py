"""Pallas kernel: fused multi-head attention + PoWER significance scores.

This is the paper's compute hot-spot (the N^2 attention) fused with its
scoring contribution (attention-column sums, §3.2). Computing the scores
inside the same kernel means the [N, N] probability matrix of each head is
consumed while still VMEM-resident — a naive two-pass implementation would
re-read A_h from HBM once per head just to take column sums.

Hardware adaptation (the paper benchmarked CUDA/K80): the grid iterates over
(head, query-row-block); each step holds one [bq, d] query tile plus the full
[N, d] K/V panels in VMEM and performs two MXU matmuls (QK^T and P·V). The
significance accumulator lives in the output block that every grid step
revisits, exploiting Pallas' sequential-grid revisiting semantics instead of
an atomics-style reduction (which TPU does not offer).

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO with identical numerics.

VMEM footprint per grid step (f32), N=128, d=16, bq=128:
  q tile 8KB + K 8KB + V 8KB + logits 64KB + ctx 8KB + sig 0.5KB ~= 97KB
well under the ~16MB VMEM budget; at paper scale (N=512, d=64, bq=128)
the same shape is ~1.4MB — still comfortably resident, so the kernel
structure translates to real TPU unchanged.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_kernel(q_ref, k_ref, v_ref, mask_ref, ctx_ref, sig_ref, *, scale):
    """One (head, query-block) grid step."""
    h = pl.program_id(0)
    q = q_ref[...]            # [bq, d]
    k = k_ref[...]            # [N, d]
    v = v_ref[...]            # [N, d]
    mask = mask_ref[...]      # [N]

    logits = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    logits = jnp.where(mask[None, :] > 0, logits, -1e9)
    # Numerically-stable row softmax, all in-registers/VMEM.
    m = jnp.max(logits, axis=-1, keepdims=True)
    e = jnp.exp(logits - m)
    p = e / jnp.sum(e, axis=-1, keepdims=True)   # [bq, N]

    ctx_ref[...] = jnp.dot(p, v, preferred_element_type=jnp.float32)

    # Column sums over valid query rows only (PAD rows carry no significance).
    qmask = mask_ref[...]  # same [N] mask; slice the rows of this block
    bq = q.shape[0]
    row0 = pl.program_id(1) * bq
    rows = jax.lax.dynamic_slice(qmask, (row0,), (bq,)) if qmask.shape[0] != bq else qmask
    col_sum = jnp.sum(p * rows[:, None], axis=0)  # [N]

    # The sig output block is revisited by every grid step: initialize on the
    # first step, accumulate afterwards (sequential TPU grid semantics).
    @pl.when(jnp.logical_and(h == 0, pl.program_id(1) == 0))
    def _init():
        sig_ref[...] = jnp.zeros_like(sig_ref)

    sig_ref[...] += col_sum


def _pick_block(n: int, target: int) -> int:
    """Largest divisor of n that is <= target (keeps blocks aligned)."""
    for b in range(min(n, target), 0, -1):
        if n % b == 0:
            return b
    return n


@functools.partial(jax.jit, static_argnames=("block_q",))
def mha_with_scores(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                    mask: jnp.ndarray, block_q: int = 128):
    """Fused MHA + significance scores for one example.

    Args / returns exactly as :func:`compile.kernels.ref.mha_with_scores`:
    q, k, v: [heads, N, d]; mask: [N] -> (ctx [heads, N, d], sig [N]).
    """
    heads, n, d = q.shape
    bq = _pick_block(n, block_q)
    grid = (heads, n // bq)
    scale = 1.0 / (d ** 0.5)

    kernel = functools.partial(_attn_kernel, scale=scale)
    ctx, sig = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, bq, d), lambda h, i: (h, i, 0)),   # q tile
            pl.BlockSpec((None, n, d), lambda h, i: (h, 0, 0)),    # K panel
            pl.BlockSpec((None, n, d), lambda h, i: (h, 0, 0)),    # V panel
            pl.BlockSpec((n,), lambda h, i: (0,)),                 # mask
        ],
        out_specs=[
            pl.BlockSpec((None, bq, d), lambda h, i: (h, i, 0)),   # ctx tile
            pl.BlockSpec((n,), lambda h, i: (0,)),                 # sig (revisited)
        ],
        out_shape=[
            jax.ShapeDtypeStruct((heads, n, d), q.dtype),
            jax.ShapeDtypeStruct((n,), q.dtype),
        ],
        interpret=True,
    )(q, k, v, mask)
    return ctx, sig
