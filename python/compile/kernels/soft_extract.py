"""Pallas kernel: soft-extract layer (paper §3.3, training path only).

Multiplies word-vector i by the retention parameter of its *sorted score
position*: out[i, :] = r[rank[i]] * x[i, :]. The rank permutation is computed
at the JAX level (sorting is an XLA strength and not profitably tiled at
these sizes); the kernel fuses the gather r[rank] with the broadcast
multiply so the gated activations are produced in one VMEM pass.

Differentiability note: gradients flow to `r` through the multiply (the
gather of `r` by integer ranks is differentiable in r), exactly what the
configuration-search training needs. Ranks are stop-gradient by nature.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _soft_extract_kernel(x_ref, ranks_ref, r_ref, o_ref):
    gate = r_ref[...][ranks_ref[...]]          # [N] gather in VMEM
    o_ref[...] = x_ref[...] * gate[:, None]


def _soft_extract_call(x, ranks, r):
    n, hdim = x.shape
    return pl.pallas_call(
        _soft_extract_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((n, hdim), lambda i: (0, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((n,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((n, hdim), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, hdim), x.dtype),
        interpret=True,
    )(x, ranks, r)


# The in-kernel gather has no reverse-mode rule under interpret mode, so the
# VJP is supplied explicitly (it is exact and cheap):
#   d/dx   = g * r[ranks]            (the same kernel, applied to g)
#   d/dr_k = sum_{i: ranks[i]=k} <g_i, x_i>   (segment-sum of row dots)
@jax.custom_vjp
def soft_extract(x: jnp.ndarray, ranks: jnp.ndarray, r: jnp.ndarray) -> jnp.ndarray:
    """x: [N, H]; ranks: i32 [N]; r: [N] -> [N, H]."""
    return _soft_extract_call(x, ranks, r)


def _fwd(x, ranks, r):
    return _soft_extract_call(x, ranks, r), (x, ranks, r)


def _bwd(res, g):
    x, ranks, r = res
    dx = _soft_extract_call(g, ranks, r)
    rowdot = jnp.sum(g * x, axis=-1)
    dr = jnp.zeros_like(r).at[ranks].add(rowdot)
    return dx, None, dr


soft_extract.defvjp(_fwd, _bwd)
soft_extract = jax.jit(soft_extract)
