"""L1 Pallas kernels (interpret=True) and their pure-jnp oracles.

``get_kernels(use_pallas)`` returns a namespace with a uniform interface so
the L2 model can be built against either implementation; the AOT export uses
the Pallas path, tests cross-check both.
"""

from types import SimpleNamespace

from . import ref as _ref
from .attention import mha_with_scores as mha_with_scores_pallas
from .ffn import ffn as ffn_pallas
from .layernorm import layernorm_residual as layernorm_residual_pallas
from .soft_extract import soft_extract as soft_extract_pallas

PALLAS = SimpleNamespace(
    mha_with_scores=mha_with_scores_pallas,
    ffn=ffn_pallas,
    layernorm_residual=layernorm_residual_pallas,
    soft_extract=soft_extract_pallas,
)

REF = SimpleNamespace(
    mha_with_scores=_ref.mha_with_scores,
    ffn=_ref.ffn,
    layernorm_residual=_ref.layernorm_residual,
    soft_extract=_ref.soft_extract,
)


def get_kernels(use_pallas: bool = True):
    return PALLAS if use_pallas else REF
