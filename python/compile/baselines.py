"""Baseline inference-time-reduction methods the paper compares against
(§4.1): DistilBERT and BERT-PKD (encoder elimination via distillation) and
Head-Prune (attention-head pruning, Michel et al. 2019).

Each produces a standard inference model (a BertConfig + params, possibly
with head gates) that the AOT exporter treats identically to the others.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import layers as L
from . import model as M
from . import train as T
from .config import BertConfig, TaskSpec, TrainConfig


# ---------------------------------------------------------------------------
# Encoder-elimination students (DistilBERT / BERT-PKD)
# ---------------------------------------------------------------------------

def student_config(cfg: BertConfig, num_layers: int) -> BertConfig:
    return dataclasses.replace(cfg, num_layers=num_layers)


def init_student_from_teacher(teacher_params, cfg: BertConfig,
                              num_layers: int) -> Dict:
    """DistilBERT-style init: copy embeddings/pooler/head and every
    ceil(L/k)-th encoder from the teacher."""
    Lt = len(teacher_params["layers"])
    take = np.linspace(0, Lt - 1, num_layers).round().astype(int)
    return {
        "embed": jax.tree.map(lambda x: x, teacher_params["embed"]),
        "layers": [jax.tree.map(lambda x: x, teacher_params["layers"][i]) for i in take],
        "final_ln": jax.tree.map(lambda x: x, teacher_params["final_ln"]),
        "pooler": jax.tree.map(lambda x: x, teacher_params["pooler"]),
        "head": jax.tree.map(lambda x: x, teacher_params["head"]),
    }


def pkd_layer_map(student_layers: int, teacher_layers: int) -> List[Tuple[int, int]]:
    """PKD-skip mapping: student layer i supervises from evenly spaced
    teacher layers (excluding the last, which the KL term covers)."""
    ts = np.linspace(0, teacher_layers - 2, student_layers).round().astype(int)
    return [(i, int(t)) for i, t in enumerate(ts)]


def train_encoder_eliminated(kind: str, teacher_params, teacher_fwd,
                             cfg: BertConfig, num_layers: int, data,
                             task: TaskSpec, tc: TrainConfig,
                             use_pallas: bool = True):
    """Train a ``num_layers``-encoder student. kind: "distil" | "pkd".

    Returns (student_cfg, student_params).
    """
    s_cfg = student_config(cfg, num_layers)
    s_params = init_student_from_teacher(teacher_params, s_cfg, num_layers)
    collect = kind == "pkd"
    s_fwd = M.make_forward(s_cfg, use_pallas=use_pallas, collect=collect)
    t_fwd = M.make_forward(cfg, use_pallas=use_pallas, collect=collect)
    layer_map = pkd_layer_map(num_layers, cfg.num_layers) if kind == "pkd" else None
    s_params, losses = T.train_distilled(
        s_fwd, s_params, t_fwd, teacher_params, data, task, tc,
        pkd_layer_map=layer_map)
    return s_cfg, s_params, losses


# ---------------------------------------------------------------------------
# Head-Prune (Michel et al.): importance = E |d loss / d gate| at gate=1,
# prune the globally least important heads, then fine-tune briefly.
# ---------------------------------------------------------------------------

def head_importance(params, cfg: BertConfig, data, task: TaskSpec,
                    batch_size: int = 32, num_batches: int = 8,
                    use_pallas: bool = True, seed: int = 0) -> np.ndarray:
    """Returns [L, A] head-importance scores."""
    fwd = M.make_forward(cfg, use_pallas=use_pallas, with_head_gates=True)
    tokens, segs, labels = data
    gates = jnp.ones((cfg.num_layers, cfg.num_heads))

    @jax.jit
    def grad_fn(g, tok, sg, y):
        def loss_fn(g_):
            logits, _ = fwd(params, tok, sg, g_)
            return T.task_loss(logits, y, task.num_classes)
        return jax.grad(loss_fn)(g)

    rng = np.random.default_rng(seed)
    acc = np.zeros((cfg.num_layers, cfg.num_heads))
    for tok, sg, y in T.batches(rng, (tokens, segs, labels), batch_size, num_batches):
        acc += np.abs(np.asarray(grad_fn(gates, tok, sg, y)))
    return acc / num_batches


def prune_heads(importance: np.ndarray, keep_fraction: float,
                min_heads_per_layer: int = 1) -> np.ndarray:
    """Globally prune to ``keep_fraction`` of heads; each layer keeps at
    least ``min_heads_per_layer`` (an encoder with zero heads is degenerate).
    Returns a {0,1} gate matrix [L, A]."""
    LL, A = importance.shape
    n_keep = max(LL * min_heads_per_layer, int(round(keep_fraction * LL * A)))
    gates = np.zeros((LL, A))
    # Guarantee per-layer minimum first...
    for l in range(LL):
        top = np.argsort(-importance[l])[:min_heads_per_layer]
        gates[l, top] = 1.0
    # ...then fill the rest globally by importance.
    flat = [(-importance[l, a], l, a) for l in range(LL) for a in range(A) if gates[l, a] == 0]
    for _, l, a in sorted(flat):
        if gates.sum() >= n_keep:
            break
        gates[l, a] = 1.0
    return gates


def apply_head_gates_to_params(params, cfg: BertConfig, gates: np.ndarray) -> Dict:
    """Bake {0,1} gates into the value/output projections so the pruned model
    needs no gate input at inference (dead heads produce exact zeros)."""
    out = jax.tree.map(lambda x: x, params)
    d = cfg.head_dim
    for j, layer in enumerate(out["layers"]):
        g = np.repeat(gates[j], d)  # [H]
        layer["wv"] = layer["wv"] * g[None, :]
        layer["bv"] = layer["bv"] * g
    return out


def train_head_pruned(teacher_params, cfg: BertConfig, keep_fraction: float,
                      data, task: TaskSpec, tc: TrainConfig,
                      use_pallas: bool = True):
    """Full Head-Prune pipeline: importance -> prune -> fine-tune."""
    imp = head_importance(teacher_params, cfg, data, task, use_pallas=use_pallas)
    gates = prune_heads(imp, keep_fraction)
    fwd_g = M.make_forward(cfg, use_pallas=use_pallas, with_head_gates=True)
    gates_j = jnp.asarray(gates)
    fwd = lambda p, t, s: fwd_g(p, t, s, gates_j)
    params, losses = T.train_classifier(fwd, teacher_params, data, task, tc)
    pruned = apply_head_gates_to_params(params, cfg, gates)
    return pruned, gates, losses
