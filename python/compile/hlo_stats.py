"""L2 perf analysis: static op/FLOP/byte statistics of exported HLO text.

Parses the HLO modules the Rust runtime actually executes and reports, per
variant: instruction counts by opcode, dot-product FLOPs, parameter bytes,
and intermediate bytes — verifying (a) the PoWER artifacts really contain
proportionally less compute (the paper's claim is structural, not a runtime
trick), and (b) fusion opportunities aren't lost (no duplicate transcendental
blowups).

Run:  python -m compile.hlo_stats [--dataset sst2]
"""

from __future__ import annotations

import argparse
import json
import os
import re
from collections import Counter
from dataclasses import dataclass, field
from typing import Dict, List, Optional

SHAPE_RE = re.compile(r"(f32|s32|pred|f16|bf16|s64|u32|u8)\[([0-9,]*)\]")
INSTR_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\)|[\w\[\]{},: ]+?))\s*([a-z\-]+)\(([^)]*)\)")


def shape_elems(dims: str) -> int:
    if not dims:
        return 1
    n = 1
    for d in dims.split(","):
        n *= int(d)
    return n


@dataclass
class HloStats:
    path: str
    ops: Counter = field(default_factory=Counter)
    dot_flops: int = 0
    param_bytes: int = 0
    total_intermediate_elems: int = 0

    @property
    def total_ops(self) -> int:
        return sum(self.ops.values())


def analyze_hlo_text(text: str, path: str = "<mem>") -> HloStats:
    st = HloStats(path=path)
    # First pass: symbol table name -> dims (operand shapes are not inline
    # in the HLO text; dots reference prior instructions by name).
    shapes_by_name: Dict[str, List[int]] = {}
    lines = text.splitlines()
    for line in lines:
        m = INSTR_RE.match(line)
        if not m:
            continue
        name, shape_txt, _, _ = m.groups()
        sm = SHAPE_RE.search(shape_txt)
        if sm:
            shapes_by_name[name] = [int(x) for x in sm.group(2).split(",") if x]
    for line in lines:
        m = INSTR_RE.match(line)
        if not m:
            continue
        name, shape_txt, op, operands_txt = m.groups()
        st.ops[op] += 1
        sm = SHAPE_RE.search(shape_txt)
        out_dims = [int(x) for x in sm.group(2).split(",") if x] if sm else []
        out_elems = 1
        for d in out_dims:
            out_elems *= d
        st.total_intermediate_elems += out_elems
        if op == "parameter":
            st.param_bytes += 4 * out_elems
        elif op == "dot":
            # Operand may be "name" or "f32[8,16]{1,0} %name" (shape-annotated,
            # with commas inside the shape) — take the first token that
            # resolves in the symbol table.
            lhs_dims: List[int] = []
            for tok in re.findall(r"%?[\w.\-]+", operands_txt):
                dims = shapes_by_name.get(tok.lstrip("%"))
                if dims is not None:
                    lhs_dims = dims
                    break
            cdim = re.search(r"lhs_contracting_dims=\{(\d+)", line)
            k = 1
            if lhs_dims:
                idx = int(cdim.group(1)) if cdim else len(lhs_dims) - 1
                if idx < len(lhs_dims):
                    k = lhs_dims[idx]
            st.dot_flops += 2 * out_elems * k
    return st


def analyze_file(path: str) -> HloStats:
    with open(path) as f:
        return analyze_hlo_text(f.read(), path)


def compare_variants(art_root: str, dataset: str, batch: int = 32) -> List[Dict]:
    """Stats for every variant of a dataset (batch-`batch` graph)."""
    rows = []
    ds_dir = os.path.join(art_root, dataset)
    for variant in sorted(os.listdir(ds_dir)):
        meta_p = os.path.join(ds_dir, variant, "meta.json")
        if not os.path.exists(meta_p):
            continue
        with open(meta_p) as f:
            meta = json.load(f)
        hlo_name = meta.get("hlo", {}).get(str(batch))
        if not hlo_name:
            continue
        st = analyze_file(os.path.join(ds_dir, variant, hlo_name))
        rows.append({
            "variant": variant,
            "kind": meta.get("kind"),
            "ops": st.total_ops,
            "dot_gflops": st.dot_flops / 1e9,
            "param_mb": st.param_bytes / 1e6,
            "retention": meta.get("retention"),
            "agg_wv": meta.get("aggregate_word_vectors"),
        })
    return rows


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--dataset", default="sst2")
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--artifacts", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    args = ap.parse_args()
    rows = compare_variants(args.artifacts, args.dataset, args.batch)
    base = next((r for r in rows if r["variant"] == "bert"), None)
    print(f"{'variant':<20} {'kind':<10} {'ops':>6} {'dot GFLOP':>10} {'vs bert':>8} {'agg wv':>7}")
    for r in rows:
        rel = f"{r['dot_gflops'] / base['dot_gflops']:.2f}x" if base and base["dot_gflops"] else "-"
        print(f"{r['variant']:<20} {str(r['kind']):<10} {r['ops']:>6} "
              f"{r['dot_gflops']:>10.3f} {rel:>8} {str(r['agg_wv'] or '-'):>7}")
    if base:
        for r in rows:
            if r["kind"] == "power" and r["agg_wv"]:
                structural = r["dot_gflops"] / base["dot_gflops"]
                wv_ratio = r["agg_wv"] / (base.get("agg_wv") or 1) if base.get("agg_wv") else None
                print(f"\n{r['variant']}: dot-FLOP ratio {structural:.2f} — "
                      f"the compiled graph does proportionally less work (paper Fig. 1).")


if __name__ == "__main__":
    main()
