"""L2: the BERT forward passes — baseline, PoWER extract (inference),
PoWER soft-extract (configuration search), and the word-vector-selection
ablation strategies (Head-WS / Rand-WS / Attn-WS).

All forwards are written per-example and vmapped, so per-example dynamic
word-vector selection (Attn-WS) is expressed with static shapes: encoder j
outputs exactly ``l_j`` word-vectors, which is what makes the AOT-compiled
HLO do strictly less work (the paper's Figure 1).
"""

from __future__ import annotations

import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import layers as L
from .config import BertConfig
from .kernels import get_kernels

BIG = 1e6  # score pin for CLS (never eliminated, paper §3.4)


# ---------------------------------------------------------------------------
# Score post-processing and selection strategies
# ---------------------------------------------------------------------------

def selection_scores(sig: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Turn raw significance into selection scores: CLS pinned on top,
    PAD pinned to the bottom (below any real word's score >= 0)."""
    s = jnp.where(mask > 0, sig, -1.0)
    return s.at[0].set(BIG)


def topk_keep_indices(scores: jnp.ndarray, keep: int) -> jnp.ndarray:
    """Indices of the ``keep`` highest-scored positions, in original order
    (ascending index), so relative word order is preserved.

    Scores are stop-gradiented: selection is a discrete decision; gradients
    flow through the selected activations only (and this environment's
    jaxlib rejects the batched gather that sort's JVP would emit).

    Implemented with argsort (lowers to the standard `sort` HLO) rather than
    ``lax.top_k``: jax emits the newer ``topk(..., largest=true)`` custom op
    which the Rust side's XLA 0.5.1 HLO-text parser rejects.
    """
    order = jnp.argsort(-jax.lax.stop_gradient(scores))
    return jnp.sort(order[:keep])


def static_keep_indices(strategy: str, n_in: int, keep: int, layer_idx: int,
                        seed: int = 1234) -> np.ndarray:
    """Table-4 ablation strategies: fixed positions for the whole dataset.

    Head-WS keeps the first ``keep`` positions (maximizing expected PAD
    removal); Rand-WS keeps a fixed random subset. Both always keep 0 (CLS).
    """
    if strategy == "head":
        return np.arange(keep, dtype=np.int32)
    if strategy == "rand":
        rng = np.random.default_rng(seed + layer_idx)
        rest = 1 + rng.permutation(n_in - 1)[: keep - 1]
        return np.sort(np.concatenate([[0], rest])).astype(np.int32)
    raise ValueError(strategy)


# ---------------------------------------------------------------------------
# Forward passes (single example; vmap at the public entry points)
# ---------------------------------------------------------------------------

def _forward_one(params, cfg: BertConfig, kernels, tokens, segs,
                 retention: Optional[Sequence[int]],
                 strategy: str = "attn",
                 head_gates: Optional[jnp.ndarray] = None,
                 collect: bool = False):
    """Shared forward. retention=None -> baseline (no elimination).

    Returns (logits, aux) where aux optionally carries per-encoder hidden
    states / scores / kept-index traces (analysis, distillation, Figure 8).
    """
    mask = (tokens != 0).astype(jnp.float32)
    x = L.embed(params, cfg, tokens, segs)
    aux: Dict = {"hidden": [], "sig": [], "kept": []}
    # Track original positions of surviving word-vectors (Figure 8 trace).
    positions = jnp.arange(tokens.shape[0], dtype=jnp.int32)

    for j in range(cfg.num_layers):
        layer = L.layer_at(params, cfg, j)
        gates = head_gates[j] if head_gates is not None else None
        x1, sig = L.attn_half(layer, cfg, kernels, x, mask, gates)
        if retention is not None and retention[j] < x1.shape[0]:
            keep = int(retention[j])
            if strategy == "attn":
                idx = topk_keep_indices(selection_scores(sig, mask), keep)
            else:
                idx = jnp.asarray(
                    static_keep_indices(strategy, x1.shape[0], keep, j))
            x1 = x1[idx]
            mask = mask[idx]
            positions = positions[idx]
        x = L.ffn_half(layer, cfg, kernels, x1)
        if collect:
            aux["hidden"].append(x)
            aux["sig"].append(sig)
            aux["kept"].append(positions)
    logits = L.pool_and_classify(params, cfg, kernels, x)
    return logits, aux


def _soft_forward_one(params, r_params, cfg: BertConfig, kernels, tokens, segs):
    """Configuration-search forward with soft-extract layers (paper §3.3).

    r_params: [L, N] retention parameters (clipped to [0,1] here).
    Returns (logits, mass [L]) with mass(j) = sum_k clip(r_j)[k].
    """
    mask = (tokens != 0).astype(jnp.float32)
    x = L.embed(params, cfg, tokens, segs)
    masses = []
    r_clip = jnp.clip(r_params, 0.0, 1.0)
    for j in range(cfg.num_layers):
        layer = L.layer_at(params, cfg, j)
        x1, sig = L.attn_half(layer, cfg, kernels, x, mask)
        scores = jax.lax.stop_gradient(selection_scores(sig, mask))
        # rank 0 = most significant; all word-vectors in sorted position k
        # are multiplied by the same r_j[k]. Ranks are a discrete decision:
        # gradients reach r only through the soft-extract multiply.
        order = jnp.argsort(-scores)
        ranks = jnp.argsort(order).astype(jnp.int32)
        x1 = kernels.soft_extract(x1, ranks, r_clip[j])
        masses.append(jnp.sum(r_clip[j]))
        x = L.ffn_half(layer, cfg, kernels, x1)
    logits = L.pool_and_classify(params, cfg, kernels, x)
    return logits, jnp.stack(masses)


# ---------------------------------------------------------------------------
# Public, batched entry points
# ---------------------------------------------------------------------------

def make_forward(cfg: BertConfig,
                 retention: Optional[Sequence[int]] = None,
                 strategy: str = "attn",
                 use_pallas: bool = True,
                 collect: bool = False,
                 with_head_gates: bool = False):
    """Builds ``f(params, tokens [B,N], segs [B,N]) -> (logits, aux)``.

    retention: monotone keep-counts per encoder, or None for the baseline.
    strategy: "attn" (Attn-WS) | "head" (Head-WS) | "rand" (Rand-WS).
    """
    kernels = get_kernels(use_pallas)
    if retention is not None:
        retention = tuple(int(v) for v in retention)
        assert len(retention) == cfg.num_layers

    if with_head_gates:
        def fwd(params, tokens, segs, head_gates):
            f = functools.partial(_forward_one, params, cfg, kernels,
                                  retention=retention, strategy=strategy,
                                  head_gates=head_gates, collect=collect)
            return jax.vmap(f)(tokens, segs)
        return fwd

    def fwd(params, tokens, segs):
        f = functools.partial(_forward_one, params, cfg, kernels,
                              retention=retention, strategy=strategy,
                              collect=collect)
        return jax.vmap(f)(tokens, segs)
    return fwd


def make_soft_forward(cfg: BertConfig, use_pallas: bool = True):
    """Builds ``f(params, r [L,N], tokens, segs) -> (logits, mass [B,L])``."""
    kernels = get_kernels(use_pallas)

    def fwd(params, r_params, tokens, segs):
        return jax.vmap(
            lambda t, s: _soft_forward_one(params, r_params, cfg, kernels, t, s)
        )(tokens, segs)
    return fwd


def derive_retention(masses: np.ndarray, seq_len: int) -> List[int]:
    """Paper §3.3: l_j = ceil(mass(j)), made monotone non-increasing and
    bounded by [1, N]. ``masses``: [L] learned aggregate mass per encoder."""
    cfg = []
    prev = seq_len
    for m in masses:
        l = int(np.ceil(float(m)))
        l = max(1, min(l, prev))
        cfg.append(l)
        prev = l
    return cfg


def aggregate_word_vectors(retention: Sequence[int]) -> int:
    """Total word-vectors processed across encoders (paper's RTE example:
    baseline 12*256=3072 vs PoWER sum=868)."""
    return int(sum(retention))
