"""Synthetic task suite mirroring the paper's Table 1.

Each generator produces (tokens, segment_ids, labels) numpy arrays for a
:class:`~compile.config.TaskSpec`. The generators are designed so that:

* label evidence is carried by a *sparse, position-random* subset of tokens
  (so attention-based selection Attn-WS beats positional Head-WS — Table 4);
* inputs have *variable length* and are padded to N (so some elimination is
  "free" PAD removal, like the paper's real datasets);
* tasks require *contextual composition* (negation flips, premise/hypothesis
  matching), not bag-of-words lookups, so the encoder stack is load-bearing.

All generators are deterministic in (task.seed, split).
"""

from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from .config import TaskSpec
from .tokenizer import Tokenizer, Vocab

Arrays = Tuple[np.ndarray, np.ndarray, np.ndarray]  # tokens, segs, labels


def _rng(task: TaskSpec, split: str) -> np.random.Generator:
    return np.random.default_rng(abs(hash((task.seed, split))) % (2**32))


def _words(rng, vocab: Vocab, family: str, n: int) -> List[str]:
    ids = vocab.family_ids(family)
    return [vocab.words[i] for i in rng.choice(ids, size=n)]


def _fill(rng, vocab: Vocab, n: int) -> List[str]:
    return _words(rng, vocab, "filler", n)


def _scatter(rng, base: List[str], inserts: List[List[str]]) -> List[str]:
    """Insert each multi-word chunk at a random position of ``base``,
    keeping every chunk contiguous (insertion points are chosen in the base
    only, so one chunk can never split another — splitting a
    "negation + sentiment-word" pair would silently mislabel the example)."""
    points = sorted((int(rng.integers(0, len(base) + 1)) for _ in inserts), reverse=True)
    out = list(base)
    for chunk, pos in zip(inserts, points):
        out[pos:pos] = chunk
    return out


def _content_len(rng, task: TaskSpec, lo_frac=0.35, hi_frac=0.95) -> int:
    budget = task.seq_len - (3 if task.pair else 2)
    return int(rng.integers(max(4, int(lo_frac * budget)), max(5, int(hi_frac * budget))))


# ---------------------------------------------------------------------------
# Sentiment (SST-2 / IMDB analogs)
# ---------------------------------------------------------------------------

def _gen_sentiment(task: TaskSpec, vocab: Vocab, rng, n: int) -> List[Tuple]:
    rows = []
    for _ in range(n):
        label = int(rng.integers(0, 2))
        length = _content_len(rng, task)
        n_signal = int(rng.integers(3, 6))
        # Keep a clear majority margin (>= ceil(signal/2)) so the label is
        # recoverable; single-word margins made the task needlessly noisy.
        n_minority = int(rng.integers(0, max(1, n_signal // 2)))
        chunks = []
        for i in range(n_signal + n_minority):
            # Majority polarity determines the label; negation flips a word's
            # effective polarity, so surface family != evidence.
            target_pos = (i >= n_minority) == (label == 1)
            if rng.random() < 0.2:
                # negated word of opposite surface polarity
                fam = "neg" if target_pos else "pos"
                chunk = _words(rng, vocab, "negation", 1) + _words(rng, vocab, fam, 1)
            else:
                fam = "pos" if target_pos else "neg"
                chunk = _words(rng, vocab, fam, 1)
            if rng.random() < 0.2:
                chunk = _words(rng, vocab, "intens", 1) + chunk
            chunks.append(chunk)
        n_sig_tokens = sum(len(c) for c in chunks)
        base = _fill(rng, vocab, max(1, length - n_sig_tokens))
        sent = _scatter(rng, base, chunks)
        rows.append((sent, None, label))
    return rows


# ---------------------------------------------------------------------------
# Acceptability (CoLA analog): the grammar is an alternating pattern of
# (adj? noun verb) clauses; corruption (swap / duplicate verb) makes the
# sentence unacceptable. Matthews correlation metric, like the paper.
# ---------------------------------------------------------------------------

def _gen_acceptability(task: TaskSpec, vocab: Vocab, rng, n: int) -> List[Tuple]:
    rows = []
    for _ in range(n):
        label = int(rng.integers(0, 2))
        clauses = int(rng.integers(2, max(3, (task.seq_len - 2) // 4)))
        sent: List[str] = []
        for _ in range(clauses):
            if rng.random() < 0.4:
                sent += _words(rng, vocab, "adj", 1)
            sent += _words(rng, vocab, "noun", 1) + _words(rng, vocab, "verb", 1)
        if label == 0:  # corrupt
            kind = rng.random()
            i = int(rng.integers(0, len(sent) - 1))
            if kind < 0.5:
                sent[i], sent[i + 1] = sent[i + 1], sent[i]
                if vocab.family_of(vocab.id(sent[i])) == vocab.family_of(vocab.id(sent[i + 1])):
                    sent.insert(i, _words(rng, vocab, "verb", 1)[0])  # force violation
            else:
                sent.insert(i, sent[i])  # duplicated word
        rows.append((sent, None, label))
    return rows


# ---------------------------------------------------------------------------
# NLI (RTE / MNLI / QNLI analogs): premise = fact triples "e1 rel e2"
# scattered in filler; hypothesis = one triple. entail: present; contradict:
# same (e1, rel) but different e2; neutral: unrelated entities.
# ---------------------------------------------------------------------------

def _gen_nli(task: TaskSpec, vocab: Vocab, rng, n: int, classes: int) -> List[Tuple]:
    ents = list(vocab.family_ids("entity"))
    rels = list(vocab.family_ids("relation"))
    rows = []
    for _ in range(n):
        label = int(rng.integers(0, classes))
        n_facts = int(rng.integers(2, 5))
        facts = []
        used_e = rng.choice(ents, size=2 * n_facts + 2, replace=False)
        for i in range(n_facts):
            e1, e2 = int(used_e[2 * i]), int(used_e[2 * i + 1])
            r = int(rng.choice(rels))
            facts.append((e1, r, e2))
        f = facts[int(rng.integers(0, n_facts))]
        if label == 1:  # entailment
            hyp = f
        elif label == 0:  # contradiction / not-entail
            e_alt = int(used_e[-1])
            hyp = (f[0], f[1], e_alt)
        else:  # neutral (3-class only)
            e_new1, e_new2 = int(used_e[-1]), int(used_e[-2])
            hyp = (e_new1, int(rng.choice(rels)), e_new2)
        chunks = [[vocab.words[a], vocab.words[r], vocab.words[b]] for a, r, b in facts]
        length = _content_len(rng, task, 0.4, 0.9)
        base = _fill(rng, vocab, max(1, length - 3 * len(chunks) - 3))
        prem = _scatter(rng, base, chunks)
        hyp_words = [vocab.words[hyp[0]], vocab.words[hyp[1]], vocab.words[hyp[2]]]
        rows.append((prem, hyp_words, label))
    return rows


# ---------------------------------------------------------------------------
# Similarity / paraphrase (QQP / MRPC / STS-B analogs).
# ---------------------------------------------------------------------------

def _gen_pair_overlap(task: TaskSpec, vocab: Vocab, rng, n: int, regression: bool) -> List[Tuple]:
    rows = []
    for _ in range(n):
        budget = (task.seq_len - 3) // 2
        la = int(rng.integers(max(4, budget // 3), max(5, budget)))
        a = _words(rng, vocab, "noun", max(1, la // 3)) + _fill(rng, vocab, la - max(1, la // 3))
        rng.shuffle(a)
        if regression:
            frac = float(rng.random())
        else:
            label = int(rng.integers(0, 2))
            frac = float(rng.uniform(0.65, 1.0)) if label == 1 else float(rng.uniform(0.0, 0.35))
        keep = int(round(frac * len(a)))
        idx = rng.permutation(len(a))[:keep]
        b = [a[i] for i in sorted(idx)]
        b += _fill(rng, vocab, len(a) - keep)
        rng.shuffle(b)
        y = 5.0 * frac if regression else label
        rows.append((a, b, y))
    return rows


# ---------------------------------------------------------------------------
# QA (RACE analog): passage of fact triples; candidate answer for a query —
# binary "supported / unsupported", mirroring RACE's per-choice scoring
# (the paper scores 4 choices and reports 2 classes; we keep 2 classes).
# ---------------------------------------------------------------------------

def _gen_qa(task: TaskSpec, vocab: Vocab, rng, n: int) -> List[Tuple]:
    ents = list(vocab.family_ids("entity"))
    rels = list(vocab.family_ids("relation"))
    rows = []
    for _ in range(n):
        label = int(rng.integers(0, 2))
        n_facts = int(rng.integers(3, 7))
        used_e = rng.choice(ents, size=2 * n_facts + 1, replace=False)
        facts = [(int(used_e[2 * i]), int(rng.choice(rels)), int(used_e[2 * i + 1]))
                 for i in range(n_facts)]
        q = facts[int(rng.integers(0, n_facts))]
        answer = q[2] if label == 1 else int(used_e[-1])
        chunks = [[vocab.words[a], vocab.words[r], vocab.words[b]] for a, r, b in facts]
        length = _content_len(rng, task, 0.4, 0.9)
        base = _fill(rng, vocab, max(1, length - 3 * len(chunks) - 4))
        passage = _scatter(rng, base, chunks)
        query = _words(rng, vocab, "query", 1) + [vocab.words[q[0]], vocab.words[q[1]], vocab.words[answer]]
        rows.append((passage, query, label))
    return rows


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------

def generate_rows(task: TaskSpec, vocab: Vocab, split: str, n: int) -> List[Tuple]:
    rng = _rng(task, split)
    t = task.task
    if t == "SENTIMENT":
        return _gen_sentiment(task, vocab, rng, n)
    if t == "ACCEPTABILITY":
        return _gen_acceptability(task, vocab, rng, n)
    if t in ("NLI", "QA_NLI"):
        return _gen_nli(task, vocab, rng, n, task.num_classes if task.num_classes > 1 else 2)
    if t in ("SIMILARITY", "PARAPHRASE"):
        return _gen_pair_overlap(task, vocab, rng, n, regression=task.num_classes == 1)
    if t == "QA":
        return _gen_qa(task, vocab, rng, n)
    raise ValueError(f"unknown task type {t}")


def generate(task: TaskSpec, vocab: Vocab, split: str, n: Optional[int] = None) -> Arrays:
    """Materialize a split as (tokens i32[n,N], segs i32[n,N], labels)."""
    n = n if n is not None else (task.train_size if split == "train" else task.test_size)
    tok = Tokenizer(vocab)
    rows = generate_rows(task, vocab, split, n)
    tokens = np.zeros((n, task.seq_len), dtype=np.int32)
    segs = np.zeros((n, task.seq_len), dtype=np.int32)
    labels = np.zeros((n,), dtype=np.float32 if task.num_classes == 1 else np.int32)
    for i, (a, b, y) in enumerate(rows):
        ids, sg = tok.encode(a, b, task.seq_len)
        tokens[i], segs[i] = ids, sg
        labels[i] = y
    return tokens, segs, labels
