//! Table 2: PoWER-BERT vs BERT_BASE — test metric, inference time, speedup —
//! across the task suite, measured end-to-end through the PJRT runtime.
//! Paper reference columns printed alongside for shape comparison
//! (absolute times differ: paper = K80 GPU batch 128; here = CPU PJRT).

use powerbert::bench::paper::{measure_variant, PAPER_TABLE2, TABLE_ORDER};
use powerbert::bench::{fmt_time, BenchConfig, Table};
use powerbert::runtime::{default_root, Engine, Registry};

fn main() {
    powerbert::util::log::init();
    let registry = match Registry::scan(&default_root()) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("{e}");
            return;
        }
    };
    let mut engine = Engine::new().expect("pjrt");
    let cfg = BenchConfig::from_env();
    let batch = 32;

    let mut table = Table::new(
        "Table 2 — PoWER-BERT vs BERT (this testbed: CPU PJRT, batch 32 | paper: K80, batch 128)",
        &[
            "dataset", "metric", "BERT", "PoWER", "delta", "BERT ms", "PoWER ms",
            "speedup", "paper speedup", "agg wv (B->P)",
        ],
    );
    let mut gmean_num = 0.0;
    let mut n_rows = 0;
    for ds_name in TABLE_ORDER {
        let Some(ds) = registry.dataset(ds_name) else { continue };
        let Some(b) = measure_variant(&mut engine, ds, "bert", batch, &cfg) else { continue };
        let Some(p) = measure_variant(&mut engine, ds, "power-default", batch, &cfg) else {
            continue;
        };
        let speedup = b.latency.p50 / p.latency.p50;
        let paper = PAPER_TABLE2.iter().find(|r| r.0 == *ds_name);
        let paper_speedup = paper.map(|r| r.3 / r.4).unwrap_or(f64::NAN);
        table.row(vec![
            ds_name.to_string(),
            b.metric_name.clone(),
            format!("{:.4}", b.metric),
            format!("{:.4}", p.metric),
            format!("{:+.4}", p.metric - b.metric),
            fmt_time(b.latency.p50),
            fmt_time(p.latency.p50),
            format!("{speedup:.2}x"),
            format!("{paper_speedup:.1}x"),
            format!("{}->{}", b.aggregate_word_vectors, p.aggregate_word_vectors),
        ]);
        gmean_num += speedup.ln();
        n_rows += 1;
    }
    table.print();
    if n_rows > 0 {
        println!(
            "geometric-mean speedup over {n_rows} datasets: {:.2}x (paper range: 2.0x-4.5x per dataset)",
            (gmean_num / n_rows as f64).exp()
        );
    }
}
