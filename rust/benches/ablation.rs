//! Ablation benches beyond the paper's tables (DESIGN.md §4 extras):
//!  1. batch-size scaling — how the PoWER speedup varies with batch size
//!     (the paper reports batch 128 only);
//!  2. retention-depth sensitivity — per-variant latency vs aggregate
//!     word-vector count across the lambda sweep (linearity check of the
//!     paper's cost model: time ~ word-vectors processed);
//!  3. SLA routing policies — measured behaviour of the three router
//!     policies on the same workload.

use powerbert::bench::paper::measure_variant;
use powerbert::bench::{fmt_time, BenchConfig, Table};
use powerbert::coordinator::{BatchPolicy, Config, Coordinator, Input, Policy, Sla};
use powerbert::runtime::{default_root, Engine, Registry};
use powerbert::workload::WorkloadGen;
use std::time::Duration;

fn main() {
    powerbert::util::log::init();
    let registry = match Registry::scan(&default_root()) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("{e}");
            return;
        }
    };
    let mut engine = Engine::new().expect("pjrt");
    let cfg = BenchConfig::from_env();

    // 1. batch scaling on sst2.
    if let Some(ds) = registry.dataset("sst2") {
        let mut t = Table::new(
            "Ablation 1 — PoWER speedup vs batch size (sst2)",
            &["batch", "BERT", "PoWER", "speedup"],
        );
        for batch in [1usize, 8, 32] {
            let Some(b) = measure_variant(&mut engine, ds, "bert", batch, &cfg) else { continue };
            let Some(p) = measure_variant(&mut engine, ds, "power-default", batch, &cfg) else {
                continue;
            };
            t.row(vec![
                batch.to_string(),
                fmt_time(b.latency.p50),
                fmt_time(p.latency.p50),
                format!("{:.2}x", b.latency.p50 / p.latency.p50),
            ]);
        }
        t.print();
    }

    // 2. latency vs aggregate word-vectors across every power variant.
    let mut t = Table::new(
        "Ablation 2 — latency vs aggregate word-vectors (cost-model linearity)",
        &["dataset", "variant", "agg wv", "batch latency", "us per word-vector"],
    );
    for (ds_name, ds) in &registry.datasets {
        for vname in ds.variants.keys() {
            if !(vname == "bert" || vname.starts_with("power-l") || vname == "power-default") {
                continue;
            }
            if vname.ends_with("-debug") {
                continue;
            }
            if let Some(p) = measure_variant(&mut engine, ds, vname, 32, &cfg) {
                t.row(vec![
                    ds_name.clone(),
                    vname.clone(),
                    p.aggregate_word_vectors.to_string(),
                    fmt_time(p.latency.p50),
                    format!(
                        "{:.2}",
                        p.latency.p50 * 1e6 / (p.aggregate_word_vectors as f64 * p.batch as f64)
                    ),
                ]);
            }
        }
    }
    t.print();
    drop(engine);

    // 3. router policy behaviour on one workload.
    if registry.dataset("sst2").is_some() {
        let mut t = Table::new(
            "Ablation 3 — SLA routing policies (sst2, 64 requests each)",
            &["policy", "variant chosen", "mean total us"],
        );
        for (name, policy, sla) in [
            ("fixed bert", Policy::Fixed("bert".into()), Sla::default()),
            ("fastest-above-metric (1% floor)", Policy::FastestAboveMetric, Sla::default()),
            (
                "best-under-latency 2ms",
                Policy::BestUnderLatency,
                Sla { max_latency_ms: Some(2.0), ..Default::default() },
            ),
        ] {
            let coordinator = Coordinator::start(Config {
                datasets: vec!["sst2".into()],
                policy,
                batch: BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) },
                preload: true,
                ..Config::default()
            })
            .expect("coordinator");
            let vocab = coordinator.tokenizer().vocab.clone();
            let mut gen = WorkloadGen::new(&vocab, 7);
            let mut variants = std::collections::BTreeMap::new();
            let mut total_us = 0u64;
            let n = 64;
            for _ in 0..n {
                let (text, _) = gen.sentence(18);
                if let Ok(r) =
                    coordinator.classify("sst2", Input::Text { a: text, b: None }, sla.clone())
                {
                    *variants.entry(r.variant).or_insert(0) += 1;
                    total_us += r.total_us;
                }
            }
            let chosen = variants
                .iter()
                .map(|(v, c)| format!("{v}:{c}"))
                .collect::<Vec<_>>()
                .join(" ");
            t.row(vec![name.to_string(), chosen, format!("{}", total_us / n)]);
        }
        t.print();
    }
}
