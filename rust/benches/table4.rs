//! Table 4: word-vector selection ablation on SST-2 — Head-WS vs Rand-WS vs
//! Attn-WS at a fixed retention configuration. Accuracy on the full test
//! split and on the long-input subset (the paper filters length > 16; we
//! filter > N/2, the same "longer than the retention budget" idea).
//! Inference time is also shown: near-identical across strategies by
//! construction (same retention config), which the bench reports.

use powerbert::bench::paper::PAPER_TABLE4;
use powerbert::bench::{fmt_time, time_fn, BenchConfig, Table};
use powerbert::eval::Metric;
use powerbert::runtime::{default_root, Engine, Registry, TestSplit};

fn main() {
    powerbert::util::log::init();
    let registry = match Registry::scan(&default_root()) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("{e}");
            return;
        }
    };
    let Some(ds) = registry.dataset("sst2") else {
        println!("sst2 artifacts missing");
        return;
    };
    let split = TestSplit::load(&ds.test_npz()).expect("split");
    let seq = split.seq_len;
    let threshold = seq / 2;
    let long_idx: Vec<usize> = (0..split.n)
        .filter(|&i| split.row(i).0.iter().filter(|&&t| t != 0).count() > threshold)
        .collect();

    let mut engine = Engine::new().expect("pjrt");
    let cfg = BenchConfig::from_env();
    let batch = 32;
    let mut table = Table::new(
        &format!(
            "Table 4 — selection strategies on SST-2 (paper all-set: 85.4 / 85.7 / 88.3; long subset n={})",
            long_idx.len()
        ),
        &["strategy", "accuracy (all)", "accuracy (long)", "batch latency", "paper (all)"],
    );

    let mut latencies = Vec::new();
    // Retrained rows mirror the paper's protocol; the -zeroshot rows apply
    // the strategy to the frozen baseline (no re-training), isolating the
    // scoring function (see EXPERIMENTS.md Table 4 discussion).
    for (variant, paper_name) in [
        ("power-headws", "Head-WS"),
        ("power-randws", "Rand-WS"),
        ("power-attnws", "Attn-WS"),
        ("power-headws-zeroshot", "Head-WS (zero-shot)"),
        ("power-randws-zeroshot", "Rand-WS (zero-shot)"),
        ("power-attnws-zeroshot", "Attn-WS (zero-shot)"),
    ] {
        let Some(meta) = ds.variant(variant) else {
            println!("({variant} not exported yet — run the ablation stage)");
            continue;
        };
        let model = match engine.load(meta) {
            Ok(m) => m,
            Err(e) => {
                println!("({variant} failed to load: {e:#})");
                continue;
            }
        };
        let metric = Metric::parse(&meta.metric).unwrap_or(Metric::Accuracy);
        let mut outputs = Vec::new();
        let mut nc = meta.num_classes;
        let mut i = 0;
        while i < split.n {
            let m = batch.min(split.n - i);
            let l = model
                .infer(
                    &split.tokens[i * seq..(i + m) * seq],
                    &split.segments[i * seq..(i + m) * seq],
                    m,
                )
                .expect("infer");
            nc = l.num_classes;
            outputs.extend_from_slice(&l.values);
            i += m;
        }
        let acc_all = metric.compute(&outputs, nc, &split.labels);
        let long_out: Vec<f32> = long_idx
            .iter()
            .flat_map(|&i| outputs[i * nc..(i + 1) * nc].to_vec())
            .collect();
        let long_lab: Vec<f32> = long_idx.iter().map(|&i| split.labels[i]).collect();
        let acc_long = if long_idx.is_empty() {
            f64::NAN
        } else {
            metric.compute(&long_out, nc, &long_lab)
        };
        let n = batch.min(split.n);
        let lat = time_fn(&cfg, || {
            model
                .infer(&split.tokens[..n * seq], &split.segments[..n * seq], n)
                .expect("infer");
        });
        latencies.push(lat.p50);
        let paper = PAPER_TABLE4
            .iter()
            .find(|(n, _)| paper_name.starts_with(n))
            .map(|(_, v)| *v);
        table.row(vec![
            paper_name.to_string(),
            format!("{acc_all:.4}"),
            format!("{acc_long:.4}"),
            fmt_time(lat.p50),
            paper.map(|v| format!("{v}%")).unwrap_or_default(),
        ]);
    }
    table.print();
    if latencies.len() >= 2 {
        let min = latencies.iter().cloned().fold(f64::MAX, f64::min);
        let max = latencies.iter().cloned().fold(0.0f64, f64::max);
        println!(
            "latency spread across strategies: {:.1}% (paper: identical — same word-vector count)",
            (max - min) / min * 100.0
        );
    }
}
