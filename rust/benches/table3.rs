//! Table 3: PoWER applied over ALBERT (parameter sharing + factorized
//! embedding) — shows word-vector elimination composes with parameter
//! compression, the paper's §4.2 "Accelerating ALBERT" claim.

use powerbert::bench::paper::{measure_variant, PAPER_TABLE3, TABLE_ORDER};
use powerbert::bench::{fmt_time, BenchConfig, Table};
use powerbert::runtime::{default_root, Engine, Registry};

fn main() {
    powerbert::util::log::init();
    let registry = match Registry::scan(&default_root()) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("{e}");
            return;
        }
    };
    let mut engine = Engine::new().expect("pjrt");
    let cfg = BenchConfig::from_env();
    let batch = 32;

    let mut table = Table::new(
        "Table 3 — PoWER-ALBERT vs ALBERT (this testbed | paper: K80 batch 128)",
        &[
            "dataset", "metric", "ALBERT", "PoWER", "delta", "ALBERT ms", "PoWER ms",
            "speedup", "paper speedup",
        ],
    );
    let mut any = false;
    for ds_name in TABLE_ORDER {
        let Some(ds) = registry.dataset(ds_name) else { continue };
        let Some(a) = measure_variant(&mut engine, ds, "albert", batch, &cfg) else { continue };
        let Some(p) = measure_variant(&mut engine, ds, "albert-power", batch, &cfg) else {
            continue;
        };
        let speedup = a.latency.p50 / p.latency.p50;
        let paper = PAPER_TABLE3.iter().find(|r| r.0 == *ds_name);
        let paper_speedup = paper.map(|r| r.3 / r.4).unwrap_or(f64::NAN);
        table.row(vec![
            ds_name.to_string(),
            a.metric_name.clone(),
            format!("{:.4}", a.metric),
            format!("{:.4}", p.metric),
            format!("{:+.4}", p.metric - a.metric),
            fmt_time(a.latency.p50),
            fmt_time(p.latency.p50),
            format!("{speedup:.2}x"),
            format!("{paper_speedup:.1}x"),
        ]);
        any = true;
    }
    if !any {
        println!("no ALBERT artifacts yet — run the pipeline's `albert` stage");
    }
    table.print();
}
