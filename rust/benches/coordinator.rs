//! Coordinator (L3) benchmarks: the serving-layer overhead on top of model
//! execution. Measures (a) closed-loop single-request latency through the
//! full submit->tokenize->route->batch->execute->reply path vs raw engine
//! execution, (b) throughput under concurrent load at several batcher
//! settings, and (c) the execution pool: throughput vs worker count and the
//! padding-waste reduction from seq-bucketed batching on a mixed-length
//! workload. L3 must not be the bottleneck (paper's contribution is the
//! model-side reduction; the coordinator exists to exploit it under load).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use powerbert::bench::{fmt_time, time_fn, BenchConfig, Table};
use powerbert::coordinator::{BatchPolicy, Config, Coordinator, Input, Policy, Server, Sla};
use powerbert::runtime::{default_root, Engine, Registry, TestSplit};
use powerbert::workload::{LengthMix, WorkloadGen};

fn main() {
    powerbert::util::log::init();
    let root = default_root();
    let registry = match Registry::scan(&root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("{e}");
            return;
        }
    };
    let Some(ds) = registry.dataset("sst2") else {
        println!("sst2 artifacts missing");
        return;
    };
    let Some(meta) = ds.variant("bert") else { return };
    let cfg = BenchConfig::from_env();

    // (a) raw engine single-example execution time (the floor).
    let mut engine = Engine::new().expect("pjrt");
    let model = engine.load(meta).expect("load");
    let split = TestSplit::load(&ds.test_npz()).expect("split");
    let seq = split.seq_len;
    let raw = time_fn(&cfg, || {
        model.infer(&split.tokens[..seq], &split.segments[..seq], 1).expect("infer");
    });
    drop(engine);

    // (b) coordinator closed-loop single request (includes tokenize+route+
    // batch wait+channel hops). max_wait=0 so the batcher never holds it.
    let coordinator = Coordinator::start(Config {
        datasets: vec!["sst2".into()],
        policy: Policy::Fixed("bert".into()),
        batch: BatchPolicy { max_batch: 1, max_wait: Duration::from_millis(0) },
        ..Config::default()
    })
    .expect("coordinator");
    let vocab = coordinator.tokenizer().vocab.clone();
    let mut gen = WorkloadGen::new(&vocab, 5);
    let (text, _) = gen.sentence(18);
    // Warm: first request pays the lazy compile; excluded from timing.
    coordinator
        .classify("sst2", Input::Text { a: text.clone(), b: None }, Sla::default())
        .expect("warmup");
    let closed = time_fn(&cfg, || {
        coordinator
            .classify("sst2", Input::Text { a: text.clone(), b: None }, Sla::default())
            .expect("classify");
    });

    let mut t = Table::new(
        "Coordinator overhead — single request (batch=1)",
        &["path", "p50", "p99", "overhead vs raw"],
    );
    t.row(vec![
        "raw engine".into(),
        fmt_time(raw.p50),
        fmt_time(raw.p99),
        "-".into(),
    ]);
    t.row(vec![
        "full coordinator".into(),
        fmt_time(closed.p50),
        fmt_time(closed.p99),
        format!("{:+.0}us ({:.1}%)", (closed.p50 - raw.p50) * 1e6, (closed.p50 / raw.p50 - 1.0) * 100.0),
    ]);
    t.print();
    drop(coordinator);

    // (c) throughput under concurrent closed-loop clients x batcher policy.
    let mut t2 = Table::new(
        "Dynamic batching throughput (16 closed-loop clients, sst2/bert)",
        &["max_batch", "max_wait", "req/s", "mean occupancy", "p99 latency"],
    );
    for (max_batch, wait_ms) in [(1usize, 0u64), (8, 2), (32, 4), (32, 10)] {
        let coordinator = Coordinator::start(Config {
            datasets: vec!["sst2".into()],
            policy: Policy::Fixed("bert".into()),
            batch: BatchPolicy {
                max_batch,
                max_wait: Duration::from_millis(wait_ms),
            },
            ..Config::default()
        })
        .expect("coordinator");
        {
            // Warm the lazily-loaded variant before the measurement window.
            let vocab = coordinator.tokenizer().vocab.clone();
            let mut g = WorkloadGen::new(&vocab, 9);
            let (text, _) = g.sentence(18);
            coordinator
                .classify("sst2", Input::Text { a: text, b: None }, Sla::default())
                .expect("warmup");
        }
        let done = Arc::new(AtomicUsize::new(0));
        let t0 = Instant::now();
        let dur = Duration::from_secs(4);
        let mut handles = Vec::new();
        for c in 0..16 {
            let client = coordinator.client();
            let done = done.clone();
            let vocab = client.tokenizer().vocab.clone();
            handles.push(std::thread::spawn(move || {
                let mut gen = WorkloadGen::new(&vocab, 1000 + c);
                while t0.elapsed() < dur {
                    let (text, _) = gen.sentence(18);
                    if client
                        .classify("sst2", Input::Text { a: text, b: None }, Sla::default())
                        .is_ok()
                    {
                        done.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }));
        }
        for h in handles {
            let _ = h.join();
        }
        let wall = t0.elapsed().as_secs_f64();
        let stats = coordinator.metrics().snapshot("sst2/bert").unwrap();
        t2.row(vec![
            max_batch.to_string(),
            format!("{wait_ms}ms"),
            format!("{:.1}", done.load(Ordering::Relaxed) as f64 / wall),
            format!("{:.1}", stats.mean_batch_occupancy()),
            format!("{}us", stats.total.quantile_us(0.99)),
        ]);
    }
    t2.print();
    println!("dynamic batching should raise req/s and occupancy together; p99 grows with max_wait.");

    // (d) the execution pool on a mixed-length workload: throughput vs
    // worker count, and padding waste (executed tokens / real tokens) with
    // the batcher padding everything to seq_len vs seq-bucketed batching.
    let seq_len = meta.seq_len;
    let buckets: Vec<usize> = [seq_len / 4, seq_len / 2]
        .into_iter()
        .filter(|&b| b >= 8)
        .collect();
    let mut t3 = Table::new(
        "Execution pool — mixed-length workload (16 closed-loop clients)",
        &["workers", "seq buckets", "req/s", "padding waste", "worker busy%"],
    );
    for (workers, bucketed) in [(1usize, false), (1, true), (2, false), (2, true), (4, true)] {
        let seq_buckets = if bucketed { buckets.clone() } else { Vec::new() };
        let coordinator = Coordinator::start(Config {
            datasets: vec!["sst2".into()],
            policy: Policy::Fixed("bert".into()),
            batch: BatchPolicy { max_batch: 32, max_wait: Duration::from_millis(4) },
            workers,
            seq_buckets,
            ..Config::default()
        })
        .expect("coordinator");
        {
            let vocab = coordinator.tokenizer().vocab.clone();
            let mut g = WorkloadGen::new(&vocab, 21);
            // Warm both length regimes so lazy compiles stay out of the window.
            for _ in 0..4 {
                let (text, _, _) = g.mixed_sentence(&LengthMix::default());
                let _ = coordinator.classify("sst2", Input::Text { a: text, b: None }, Sla::default());
            }
        }
        let done = Arc::new(AtomicUsize::new(0));
        let t0 = Instant::now();
        let dur = Duration::from_secs(4);
        let mut handles = Vec::new();
        for c in 0..16 {
            let client = coordinator.client();
            let done = done.clone();
            let vocab = client.tokenizer().vocab.clone();
            handles.push(std::thread::spawn(move || {
                let mut gen = WorkloadGen::new(&vocab, 3000 + c);
                let mix = LengthMix::default();
                while t0.elapsed() < dur {
                    let (text, _, _) = gen.mixed_sentence(&mix);
                    if client
                        .classify("sst2", Input::Text { a: text, b: None }, Sla::default())
                        .is_ok()
                    {
                        done.fetch_add(1, Ordering::Relaxed);
                    }
                }
            }));
        }
        for h in handles {
            let _ = h.join();
        }
        let wall = t0.elapsed().as_secs_f64();
        let metrics = coordinator.metrics();
        let waste = metrics.total_padding_waste();
        let busy: f64 = {
            let ws = metrics.worker_snapshot();
            if ws.is_empty() {
                0.0
            } else {
                100.0 * ws.iter().map(|w| w.busy_us as f64 / 1e6).sum::<f64>()
                    / (workers as f64 * wall)
            }
        };
        t3.row(vec![
            workers.to_string(),
            if bucketed { format!("{buckets:?}") } else { "off".into() },
            format!("{:.1}", done.load(Ordering::Relaxed) as f64 / wall),
            format!("{waste:.2}x"),
            format!("{busy:.0}%"),
        ]);
        drop(coordinator);
    }
    t3.print();
    println!(
        "more workers should raise req/s until cores saturate; seq buckets should cut\n\
         padding waste (executed/real tokens) — the serving-side analog of the paper's\n\
         word-vector elimination."
    );

    // (e) wire protocol: one v1 connection (depth-1 by construction) vs one
    // pipelined protocol-v2 PowerClient connection at several depths —
    // the serving value of multiplexing at equal connection counts.
    let coordinator = Coordinator::start(Config {
        datasets: vec!["sst2".into()],
        policy: Policy::Fixed("bert".into()),
        batch: BatchPolicy { max_batch: 32, max_wait: Duration::from_millis(4) },
        ..Config::default()
    })
    .expect("coordinator");
    let server = Server::bind("127.0.0.1:0", coordinator.client())
        .expect("bind")
        .spawn()
        .expect("spawn");
    let addr = server.addr();
    {
        let vocab = coordinator.tokenizer().vocab.clone();
        let mut g = WorkloadGen::new(&vocab, 33);
        let (text, _) = g.sentence(18);
        let _ = coordinator.classify("sst2", Input::Text { a: text, b: None }, Sla::default());
    }
    let vocab = coordinator.tokenizer().vocab.clone();
    let secs = 3.0;
    let mix = LengthMix::default();
    let mut t4 = Table::new(
        "Wire protocol — one connection, closed loop (sst2/bert)",
        &["client", "req/s", "p99 latency"],
    );
    let v1 = powerbert::bench::wire::closed_loop_v1(addr, "sst2", "bert", secs, &mix, &vocab, 71);
    t4.row(vec![
        "v1 depth-1".into(),
        format!("{:.1}", v1.throughput()),
        format!("{:.1}ms", v1.latency_summary().p99),
    ]);
    for depth in [4usize, 16, 64] {
        let r = powerbert::bench::wire::closed_loop_v2(
            addr,
            "sst2",
            "bert",
            secs,
            depth,
            &mix,
            &vocab,
            100 + depth as u64,
        );
        t4.row(vec![
            format!("v2 depth-{depth}"),
            format!("{:.1}", r.throughput()),
            format!("{:.1}ms", r.latency_summary().p99),
        ]);
    }
    t4.print();
    println!(
        "pipelining should raise req/s monotonically with depth at equal connection\n\
         counts — depth-1 pays the full batcher deadline + round-trip per request."
    );
    server.stop();
    drop(coordinator);
}
