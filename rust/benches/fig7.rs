//! Figure 7: accuracy-vs-inference-time Pareto curves — PoWER-BERT (lambda
//! sweep) against DistilBERT / BERT-PKD (encoder elimination) and Head-Prune,
//! per dataset. Prints one series per method with (latency, metric) points,
//! top-left best, exactly the data behind the paper's figure.

use powerbert::bench::paper::{measure_variant, Point};
use powerbert::bench::{fmt_time, BenchConfig, Table};
use powerbert::runtime::{default_root, Engine, Registry};

fn main() {
    powerbert::util::log::init();
    let registry = match Registry::scan(&default_root()) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("{e}");
            return;
        }
    };
    let mut engine = Engine::new().expect("pjrt");
    let cfg = BenchConfig::from_env();
    let batch = 32;

    for (ds_name, ds) in &registry.datasets {
        // Only datasets that actually have a pareto sweep.
        let has_sweep = ds.variants.keys().any(|v| v.starts_with("power-l"));
        if !has_sweep {
            continue;
        }
        let mut series: Vec<(&str, Vec<Point>)> = vec![
            ("PoWER-BERT", Vec::new()),
            ("DistilBERT", Vec::new()),
            ("BERT-PKD", Vec::new()),
            ("Head-Prune", Vec::new()),
            ("BERT (baseline)", Vec::new()),
        ];
        for vname in ds.variants.keys() {
            if vname.ends_with("-debug") || vname.ends_with("ws") {
                continue;
            }
            let idx = if vname.starts_with("power") {
                0
            } else if vname.starts_with("distil") {
                1
            } else if vname.starts_with("pkd") {
                2
            } else if vname.starts_with("headprune") {
                3
            } else if vname == "bert" {
                4
            } else {
                continue;
            };
            if let Some(p) = measure_variant(&mut engine, ds, vname, batch, &cfg) {
                series[idx].1.push(p);
            }
        }
        let mut table = Table::new(
            &format!("Figure 7 — {ds_name}: accuracy vs inference time (top-left best)"),
            &["method", "variant", "batch latency", "metric", "agg word-vectors"],
        );
        for (method, points) in &mut series {
            points.sort_by(|a, b| a.latency.p50.partial_cmp(&b.latency.p50).unwrap());
            for p in points.iter() {
                table.row(vec![
                    method.to_string(),
                    p.variant.clone(),
                    fmt_time(p.latency.p50),
                    format!("{:.4}", p.metric),
                    p.aggregate_word_vectors.to_string(),
                ]);
            }
        }
        table.print();

        // Dominance summary: at the fastest PoWER point, how much accuracy
        // does the best same-or-slower baseline give up? (paper: up to 16%
        // on CoLA, 6% on RTE)
        let power = &series[0].1;
        if let Some(pw) = power.iter().max_by(|a, b| a.metric.partial_cmp(&b.metric).unwrap()) {
            let mut best_baseline: Option<&Point> = None;
            for (_, pts) in series[1..4].iter() {
                for p in pts {
                    if p.latency.p50 <= pw.latency.p50 * 1.1 {
                        if best_baseline.map(|b| p.metric > b.metric).unwrap_or(true) {
                            best_baseline = Some(p);
                        }
                    }
                }
            }
            if let Some(bb) = best_baseline {
                println!(
                    "at comparable latency ({} vs {}), PoWER metric {:.4} vs best baseline ({}) {:.4} -> gain {:+.1} points",
                    fmt_time(pw.latency.p50),
                    fmt_time(bb.latency.p50),
                    pw.metric,
                    bb.variant,
                    bb.metric,
                    (pw.metric - bb.metric) * 100.0
                );
            }
        }
    }
}
