//! Native backend bench: the kernel layer and the end-to-end forward.
//!
//! Sections per dataset:
//! 1. **kernels** — the blocked, packed `matmul_bias` against the naive
//!    reference on the bundle's real GEMM shapes (QKV projection, FFN up,
//!    FFN down), single-threaded, in GFLOP/s — old-vs-new for the exact
//!    loops the forward pass runs, plus per-call allocation bytes (the
//!    naive path allocates its output; the blocked path is
//!    allocation-free);
//! 2. **thread scaling** — the same blocked kernel on the FFN-up shape at
//!    1/2/4 intra-op threads;
//! 3. **dispatch (small shape)** — serial vs per-call scoped spawns vs
//!    the persistent pool on a batch=1, 64-row slice of the FFN-up shape:
//!    the regime where spawn cost used to dominate. Reports p50 latency,
//!    allocation bytes/call and thread spawns/call for each path;
//! 4. **bert vs power** — wall-clock speedup vs the retention config plus
//!    the measured per-layer word-vector counts (the paper's Figure 1
//!    quantity, counted by the executor rather than derived from
//!    meta.json).
//!
//!   cargo bench --bench native [PB_BENCH_ITERS=40]

use powerbert::bench::{fmt_time, paper::measure, time_fn, BenchConfig, Table};
use powerbert::runtime::kernels::gemm::{matmul_bias_ref, PackedGemm};
use powerbert::runtime::kernels::{thread_spawns, KernelConfig, KernelExec};
use powerbert::runtime::{
    default_root, ArtifactStore, BackendKind, Engine, Registry, TestSplit, VariantMeta,
};
use powerbert::testutil::alloc;
use powerbert::util::prng::Rng;

// Count every heap allocation so the kernels table can report bytes/call
// — the steady-state claim, measured rather than asserted.
#[global_allocator]
static ALLOC: alloc::CountingAlloc = alloc::CountingAlloc::new();

fn main() {
    powerbert::util::log::init();
    let cfg = BenchConfig::from_env();
    let registry = match Registry::scan(&default_root()) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("SKIP native bench: {e}");
            return;
        }
    };

    for (ds_name, ds) in &registry.datasets {
        if let Some(meta) = ds.variant("bert").or_else(|| ds.variants.values().next()) {
            if let Err(e) = bench_kernels(ds_name, meta, &cfg) {
                eprintln!("  ({ds_name} kernel bench failed: {e:#})");
            }
        }
        bench_end_to_end(ds_name, ds, &cfg);
    }
}

/// Allocation bytes + thread spawns of one `f()` call.
fn cost_of_call(f: &mut dyn FnMut()) -> (u64, u64) {
    let before_alloc = alloc::snapshot();
    let before_spawns = thread_spawns();
    f();
    let da = alloc::snapshot().since(&before_alloc);
    (da.bytes, thread_spawns() - before_spawns)
}

/// Old-vs-new on the bundle's real GEMM shapes (plus per-call allocation
/// bytes), thread scaling on the FFN-up shape, and the dispatch-path
/// comparison on the small shape the spawn cost used to dominate. `rows`
/// is a full batch at full width (8 × seq) — the shape the first encoder
/// runs before elimination shrinks it.
fn bench_kernels(ds_name: &str, meta: &VariantMeta, cfg: &BenchConfig) -> anyhow::Result<()> {
    let store = ArtifactStore::new();
    let art = store.fetch(meta)?;
    let h = meta.hidden_size;
    let take = |name: &str| -> anyhow::Result<(Vec<usize>, Vec<f32>)> {
        let (dims, data) = art
            .weight(name)
            .ok_or_else(|| anyhow::anyhow!("weights.npz missing {name}"))?;
        Ok((dims.to_vec(), data.to_vec()))
    };
    let (_, wq) = take("layers/0/wq")?;
    let (w1_dims, w1) = take("layers/0/w1")?;
    let ffn = w1_dims[1];
    let (_, w2) = take("layers/0/w2")?;
    let rows = 8 * meta.seq_len;

    let mut rng = Rng::new(0xBE7C);
    let shapes: [(&str, usize, usize, &[f32]); 3] =
        [("qkv proj", h, h, &wq), ("ffn up", h, ffn, &w1), ("ffn down", ffn, h, &w2)];
    let mut table = Table::new(
        &format!("native kernels — {ds_name}: blocked+packed vs naive matmul_bias (1 thread)"),
        &[
            "shape",
            "n x k x m",
            "naive",
            "blocked",
            "GFLOP/s (naive -> blocked)",
            "speedup",
            "alloc B/call (naive -> blocked)",
        ],
    );
    let single = KernelExec::new(KernelConfig::default().with_threads(1));
    let mut ffn_speedup = None;
    for (name, k, m, w) in shapes {
        let x: Vec<f32> = (0..rows * k).map(|_| (rng.f64() * 2.0 - 1.0) as f32).collect();
        let bias: Vec<f32> = (0..m).map(|_| (rng.f64() * 2.0 - 1.0) as f32).collect();
        let naive = time_fn(cfg, || {
            std::hint::black_box(matmul_bias_ref(&x, rows, k, w, m, &bias));
        });
        let (naive_bytes, _) = cost_of_call(&mut || {
            std::hint::black_box(matmul_bias_ref(&x, rows, k, w, m, &bias));
        });
        let packed = PackedGemm::pack(w, k, m);
        let mut out = vec![0f32; rows * m];
        let blocked = time_fn(cfg, || {
            packed.matmul_bias(&x, rows, &bias, &single, &mut out);
            std::hint::black_box(&out);
        });
        let (blocked_bytes, _) = cost_of_call(&mut || {
            packed.matmul_bias(&x, rows, &bias, &single, &mut out);
            std::hint::black_box(&out);
        });
        let flops = (2 * rows * k * m) as f64;
        let speedup = naive.p50 / blocked.p50;
        if name == "ffn up" {
            ffn_speedup = Some(speedup);
        }
        table.row(vec![
            name.to_string(),
            format!("{rows} x {k} x {m}"),
            fmt_time(naive.p50),
            fmt_time(blocked.p50),
            format!("{:.2} -> {:.2}", flops / naive.p50 / 1e9, flops / blocked.p50 / 1e9),
            format!("{speedup:.2}x"),
            format!("{naive_bytes} -> {blocked_bytes}"),
        ]);
    }
    table.print();
    if let Some(s) = ffn_speedup {
        // The acceptance number: single-thread blocked-vs-naive on the
        // bundle's FFN shape.
        println!("ffn-shape single-thread speedup (blocked vs naive): {s:.2}x");
    }

    let mut scaling = Table::new(
        &format!("native kernels — {ds_name}: blocked matmul thread scaling (ffn up shape)"),
        &["threads", "p50", "GFLOP/s", "vs 1 thread"],
    );
    let x: Vec<f32> = (0..rows * h).map(|_| (rng.f64() * 2.0 - 1.0) as f32).collect();
    let bias: Vec<f32> = (0..ffn).map(|_| (rng.f64() * 2.0 - 1.0) as f32).collect();
    let packed = PackedGemm::pack(&w1, h, ffn);
    let mut out = vec![0f32; rows * ffn];
    let flops = (2 * rows * h * ffn) as f64;
    let mut base = None;
    for threads in [1usize, 2, 4] {
        // mc small enough that `rows` splits across every thread count.
        let exec = KernelExec::new(KernelConfig { threads, kc: 256, mc: 16 });
        let t = time_fn(cfg, || {
            packed.matmul_bias(&x, rows, &bias, &exec, &mut out);
            std::hint::black_box(&out);
        });
        if threads == 1 {
            base = Some(t.p50);
        }
        let rel = base.map(|b| format!("{:.2}x", b / t.p50)).unwrap_or_else(|| "-".into());
        scaling.row(vec![
            threads.to_string(),
            fmt_time(t.p50),
            format!("{:.2}", flops / t.p50 / 1e9),
            rel,
        ]);
    }
    scaling.print();

    bench_dispatch(ds_name, &w1, h, ffn, cfg);
    Ok(())
}

/// Dispatch-path comparison on the small shape the per-call spawn cost
/// used to dominate: batch=1 × 64 rows (the seq-64 bucket) of the FFN-up
/// GEMM, split at mc=16 so two lanes genuinely share the work. Serial vs
/// per-call scoped spawns vs the persistent pool — the pooled line should
/// sit at (or below) serial and clearly below scoped.
fn bench_dispatch(ds_name: &str, w1: &[f32], h: usize, ffn: usize, cfg: &BenchConfig) {
    const DISPATCH_ROWS: usize = 64; // batch=1 at a seq-64 bucket
    const DISPATCH_THREADS: usize = 2;
    let mut rng = Rng::new(0xD15F);
    let x: Vec<f32> = (0..DISPATCH_ROWS * h).map(|_| (rng.f64() * 2.0 - 1.0) as f32).collect();
    let bias: Vec<f32> = (0..ffn).map(|_| (rng.f64() * 2.0 - 1.0) as f32).collect();
    let packed = PackedGemm::pack(w1, h, ffn);
    let mut out = vec![0f32; DISPATCH_ROWS * ffn];
    let kcfg = KernelConfig { threads: DISPATCH_THREADS, kc: 256, mc: 16 };
    let serial_exec = KernelExec::new(kcfg.clone().with_threads(1));
    // Built once — the pool's workers are parked between calls, exactly
    // as an EngineWorker holds them for its lifetime.
    let pooled_exec = KernelExec::new(kcfg.clone());

    let mut table = Table::new(
        &format!(
            "native kernels — {ds_name}: dispatch on the small shape \
             (batch=1, {DISPATCH_ROWS} rows x {h} x {ffn}, {DISPATCH_THREADS} threads)"
        ),
        &["path", "p50", "alloc B/call", "spawns/call", "vs serial"],
    );

    let serial = time_fn(cfg, || {
        packed.matmul_bias(&x, DISPATCH_ROWS, &bias, &serial_exec, &mut out);
        std::hint::black_box(&out);
    });
    let (serial_bytes, serial_spawns) = cost_of_call(&mut || {
        packed.matmul_bias(&x, DISPATCH_ROWS, &bias, &serial_exec, &mut out);
        std::hint::black_box(&out);
    });
    table.row(vec![
        "serial (1 thread)".into(),
        fmt_time(serial.p50),
        serial_bytes.to_string(),
        serial_spawns.to_string(),
        "1.00x".into(),
    ]);

    let scoped = time_fn(cfg, || {
        packed.matmul_bias_scoped(&x, DISPATCH_ROWS, &bias, &kcfg, &mut out);
        std::hint::black_box(&out);
    });
    let (scoped_bytes, scoped_spawns) = cost_of_call(&mut || {
        packed.matmul_bias_scoped(&x, DISPATCH_ROWS, &bias, &kcfg, &mut out);
        std::hint::black_box(&out);
    });
    table.row(vec![
        "scoped spawns (old)".into(),
        fmt_time(scoped.p50),
        scoped_bytes.to_string(),
        scoped_spawns.to_string(),
        format!("{:.2}x", serial.p50 / scoped.p50),
    ]);

    let pooled = time_fn(cfg, || {
        packed.matmul_bias(&x, DISPATCH_ROWS, &bias, &pooled_exec, &mut out);
        std::hint::black_box(&out);
    });
    let (pooled_bytes, pooled_spawns) = cost_of_call(&mut || {
        packed.matmul_bias(&x, DISPATCH_ROWS, &bias, &pooled_exec, &mut out);
        std::hint::black_box(&out);
    });
    table.row(vec![
        "kernel pool (new)".into(),
        fmt_time(pooled.p50),
        pooled_bytes.to_string(),
        pooled_spawns.to_string(),
        format!("{:.2}x", serial.p50 / pooled.p50),
    ]);
    table.print();
    println!(
        "small-shape dispatch: pooled spawns 0 threads/call vs scoped's \
         per-call spawns — the pool pays its {DISPATCH_THREADS} spawns once at worker start"
    );
}

/// bert vs power end-to-end on the native backend: metric, latency,
/// speedup-vs-retention, measured word-vectors per layer, arena footprint.
fn bench_end_to_end(ds_name: &str, ds: &powerbert::runtime::DatasetArtifacts, cfg: &BenchConfig) {
    let split = match TestSplit::load(&ds.test_npz()) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("SKIP {ds_name}: {e:#}");
            return;
        }
    };
    let mut engine = Engine::with_backend(BackendKind::Native).expect("native engine");
    let mut table = Table::new(
        &format!("native backend — {ds_name}: metric / latency / word-vectors per layer"),
        &["variant", "metric", "batch", "p50", "speedup", "wv/layer (measured)", "arena peak"],
    );
    let mut bert_p50 = None;
    for vname in ["bert", "power-default"] {
        let Some(meta) = ds.variant(vname) else { continue };
        let model = match engine.load(meta) {
            Ok(m) => m,
            Err(e) => {
                eprintln!("  ({ds_name}/{vname} native load failed: {e:#})");
                continue;
            }
        };
        // Per-layer counts of one timed batch: snapshot the cumulative
        // telemetry around a single infer.
        let n = 8.min(split.n);
        let seq = split.seq_len;
        let before = model.layer_tokens().unwrap_or_default();
        model
            .infer(&split.tokens[..n * seq], &split.segments[..n * seq], n)
            .expect("infer");
        let after = model.layer_tokens().unwrap_or_default();
        let per_layer: Vec<u64> = after
            .iter()
            .zip(before.iter())
            .map(|(a, b)| (a - b) / n as u64)
            .collect();

        let point = match measure(&mut engine, meta, &split, 32, cfg) {
            Ok(p) => p,
            Err(e) => {
                eprintln!("  ({ds_name}/{vname} failed: {e:#})");
                continue;
            }
        };
        if vname == "bert" {
            bert_p50 = Some(point.latency.p50);
        }
        let speedup = bert_p50
            .map(|b| format!("{:.2}x", b / point.latency.p50))
            .unwrap_or_else(|| "-".into());
        let arena = model
            .memory_stats()
            .map(|m| {
                let kib = m.arena_peak_bytes as f64 / 1024.0;
                format!("{kib:.1} KiB / {} bucket(s)", m.arena_buckets)
            })
            .unwrap_or_else(|| "-".into());
        table.row(vec![
            vname.to_string(),
            format!("{:.4}", point.metric),
            point.batch.to_string(),
            fmt_time(point.latency.p50),
            speedup,
            format!("{per_layer:?} (Σ {})", per_layer.iter().sum::<u64>()),
            arena,
        ]);
    }
    if !table.rows.is_empty() {
        table.print();
    }
}
