//! Native backend bench: BERT vs PoWER on the pure-Rust forward pass —
//! wall-clock speedup vs the retention config, and the measured per-layer
//! word-vector counts (the paper's Figure 1 quantity, counted by the
//! executor rather than derived from meta.json).
//!
//!   cargo bench --bench native [PB_BENCH_ITERS=40]

use powerbert::bench::{fmt_time, paper::measure, BenchConfig, Table};
use powerbert::runtime::{default_root, BackendKind, Engine, Registry, TestSplit};

fn main() {
    powerbert::util::log::init();
    let cfg = BenchConfig::from_env();
    let registry = match Registry::scan(&default_root()) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("SKIP native bench: {e}");
            return;
        }
    };

    for (ds_name, ds) in &registry.datasets {
        let split = match TestSplit::load(&ds.test_npz()) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("SKIP {ds_name}: {e:#}");
                continue;
            }
        };
        let mut engine = Engine::with_backend(BackendKind::Native).expect("native engine");
        let mut table = Table::new(
            &format!("native backend — {ds_name}: metric / latency / word-vectors per layer"),
            &["variant", "metric", "batch", "p50", "speedup", "wv/layer (measured)"],
        );
        let mut bert_p50 = None;
        for vname in ["bert", "power-default"] {
            let Some(meta) = ds.variant(vname) else { continue };
            let model = match engine.load(meta) {
                Ok(m) => m,
                Err(e) => {
                    eprintln!("  ({ds_name}/{vname} native load failed: {e:#})");
                    continue;
                }
            };
            // Per-layer counts of one timed batch: snapshot the cumulative
            // telemetry around a single infer.
            let n = 8.min(split.n);
            let seq = split.seq_len;
            let before = model.layer_tokens().unwrap_or_default();
            model
                .infer(&split.tokens[..n * seq], &split.segments[..n * seq], n)
                .expect("infer");
            let after = model.layer_tokens().unwrap_or_default();
            let per_layer: Vec<u64> = after
                .iter()
                .zip(before.iter())
                .map(|(a, b)| (a - b) / n as u64)
                .collect();

            let point = match measure(&mut engine, meta, &split, 32, &cfg) {
                Ok(p) => p,
                Err(e) => {
                    eprintln!("  ({ds_name}/{vname} failed: {e:#})");
                    continue;
                }
            };
            if vname == "bert" {
                bert_p50 = Some(point.latency.p50);
            }
            let speedup = bert_p50
                .map(|b| format!("{:.2}x", b / point.latency.p50))
                .unwrap_or_else(|| "-".into());
            table.row(vec![
                vname.to_string(),
                format!("{:.4}", point.metric),
                point.batch.to_string(),
                fmt_time(point.latency.p50),
                speedup,
                format!("{per_layer:?} (Σ {})", per_layer.iter().sum::<u64>()),
            ]);
        }
        if !table.rows.is_empty() {
            table.print();
        }
    }
}
