//! Native backend bench: the kernel layer and the end-to-end forward.
//!
//! Three sections per dataset:
//! 1. **kernels** — the blocked, packed `matmul_bias` against the naive
//!    reference on the bundle's real GEMM shapes (QKV projection, FFN up,
//!    FFN down), single-threaded, in GFLOP/s — old-vs-new for the exact
//!    loops the forward pass runs;
//! 2. **thread scaling** — the same blocked kernel on the FFN-up shape at
//!    1/2/4 intra-op threads;
//! 3. **bert vs power** — wall-clock speedup vs the retention config plus
//!    the measured per-layer word-vector counts (the paper's Figure 1
//!    quantity, counted by the executor rather than derived from
//!    meta.json).
//!
//!   cargo bench --bench native [PB_BENCH_ITERS=40]

use powerbert::bench::{fmt_time, paper::measure, time_fn, BenchConfig, Table};
use powerbert::runtime::kernels::gemm::{matmul_bias_ref, PackedGemm};
use powerbert::runtime::kernels::KernelConfig;
use powerbert::runtime::{
    default_root, ArtifactStore, BackendKind, Engine, Registry, TestSplit, VariantMeta,
};
use powerbert::util::prng::Rng;

fn main() {
    powerbert::util::log::init();
    let cfg = BenchConfig::from_env();
    let registry = match Registry::scan(&default_root()) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("SKIP native bench: {e}");
            return;
        }
    };

    for (ds_name, ds) in &registry.datasets {
        if let Some(meta) = ds.variant("bert").or_else(|| ds.variants.values().next()) {
            if let Err(e) = bench_kernels(ds_name, meta, &cfg) {
                eprintln!("  ({ds_name} kernel bench failed: {e:#})");
            }
        }
        bench_end_to_end(ds_name, ds, &cfg);
    }
}

/// Old-vs-new on the bundle's real GEMM shapes, plus thread scaling on the
/// FFN-up shape. `rows` is a full batch at full width (8 × seq) — the
/// shape the first encoder runs before elimination shrinks it.
fn bench_kernels(ds_name: &str, meta: &VariantMeta, cfg: &BenchConfig) -> anyhow::Result<()> {
    let store = ArtifactStore::new();
    let art = store.fetch(meta)?;
    let h = meta.hidden_size;
    let take = |name: &str| -> anyhow::Result<(Vec<usize>, Vec<f32>)> {
        let (dims, data) = art
            .weight(name)
            .ok_or_else(|| anyhow::anyhow!("weights.npz missing {name}"))?;
        Ok((dims.to_vec(), data.to_vec()))
    };
    let (_, wq) = take("layers/0/wq")?;
    let (w1_dims, w1) = take("layers/0/w1")?;
    let ffn = w1_dims[1];
    let (_, w2) = take("layers/0/w2")?;
    let rows = 8 * meta.seq_len;

    let mut rng = Rng::new(0xBE7C);
    let shapes: [(&str, usize, usize, &[f32]); 3] =
        [("qkv proj", h, h, &wq), ("ffn up", h, ffn, &w1), ("ffn down", ffn, h, &w2)];
    let mut table = Table::new(
        &format!("native kernels — {ds_name}: blocked+packed vs naive matmul_bias (1 thread)"),
        &["shape", "n x k x m", "naive", "blocked", "GFLOP/s (naive -> blocked)", "speedup"],
    );
    let single = KernelConfig::default().with_threads(1);
    let mut ffn_speedup = None;
    for (name, k, m, w) in shapes {
        let x: Vec<f32> = (0..rows * k).map(|_| (rng.f64() * 2.0 - 1.0) as f32).collect();
        let bias: Vec<f32> = (0..m).map(|_| (rng.f64() * 2.0 - 1.0) as f32).collect();
        let naive = time_fn(cfg, || {
            std::hint::black_box(matmul_bias_ref(&x, rows, k, w, m, &bias));
        });
        let packed = PackedGemm::pack(w, k, m);
        let mut out = vec![0f32; rows * m];
        let blocked = time_fn(cfg, || {
            packed.matmul_bias(&x, rows, &bias, &single, &mut out);
            std::hint::black_box(&out);
        });
        let flops = (2 * rows * k * m) as f64;
        let speedup = naive.p50 / blocked.p50;
        if name == "ffn up" {
            ffn_speedup = Some(speedup);
        }
        table.row(vec![
            name.to_string(),
            format!("{rows} x {k} x {m}"),
            fmt_time(naive.p50),
            fmt_time(blocked.p50),
            format!("{:.2} -> {:.2}", flops / naive.p50 / 1e9, flops / blocked.p50 / 1e9),
            format!("{speedup:.2}x"),
        ]);
    }
    table.print();
    if let Some(s) = ffn_speedup {
        // The acceptance number: single-thread blocked-vs-naive on the
        // bundle's FFN shape.
        println!("ffn-shape single-thread speedup (blocked vs naive): {s:.2}x");
    }

    let mut scaling = Table::new(
        &format!("native kernels — {ds_name}: blocked matmul thread scaling (ffn up shape)"),
        &["threads", "p50", "GFLOP/s", "vs 1 thread"],
    );
    let x: Vec<f32> = (0..rows * h).map(|_| (rng.f64() * 2.0 - 1.0) as f32).collect();
    let bias: Vec<f32> = (0..ffn).map(|_| (rng.f64() * 2.0 - 1.0) as f32).collect();
    let packed = PackedGemm::pack(&w1, h, ffn);
    let mut out = vec![0f32; rows * ffn];
    let flops = (2 * rows * h * ffn) as f64;
    let mut base = None;
    for threads in [1usize, 2, 4] {
        // mc small enough that `rows` splits across every thread count.
        let kcfg = KernelConfig { threads, kc: 256, mc: 16 };
        let t = time_fn(cfg, || {
            packed.matmul_bias(&x, rows, &bias, &kcfg, &mut out);
            std::hint::black_box(&out);
        });
        if threads == 1 {
            base = Some(t.p50);
        }
        let rel = base.map(|b| format!("{:.2}x", b / t.p50)).unwrap_or_else(|| "-".into());
        scaling.row(vec![
            threads.to_string(),
            fmt_time(t.p50),
            format!("{:.2}", flops / t.p50 / 1e9),
            rel,
        ]);
    }
    scaling.print();
    Ok(())
}

/// bert vs power end-to-end on the native backend: metric, latency,
/// speedup-vs-retention, measured word-vectors per layer.
fn bench_end_to_end(ds_name: &str, ds: &powerbert::runtime::DatasetArtifacts, cfg: &BenchConfig) {
    let split = match TestSplit::load(&ds.test_npz()) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("SKIP {ds_name}: {e:#}");
            return;
        }
    };
    let mut engine = Engine::with_backend(BackendKind::Native).expect("native engine");
    let mut table = Table::new(
        &format!("native backend — {ds_name}: metric / latency / word-vectors per layer"),
        &["variant", "metric", "batch", "p50", "speedup", "wv/layer (measured)"],
    );
    let mut bert_p50 = None;
    for vname in ["bert", "power-default"] {
        let Some(meta) = ds.variant(vname) else { continue };
        let model = match engine.load(meta) {
            Ok(m) => m,
            Err(e) => {
                eprintln!("  ({ds_name}/{vname} native load failed: {e:#})");
                continue;
            }
        };
        // Per-layer counts of one timed batch: snapshot the cumulative
        // telemetry around a single infer.
        let n = 8.min(split.n);
        let seq = split.seq_len;
        let before = model.layer_tokens().unwrap_or_default();
        model
            .infer(&split.tokens[..n * seq], &split.segments[..n * seq], n)
            .expect("infer");
        let after = model.layer_tokens().unwrap_or_default();
        let per_layer: Vec<u64> = after
            .iter()
            .zip(before.iter())
            .map(|(a, b)| (a - b) / n as u64)
            .collect();

        let point = match measure(&mut engine, meta, &split, 32, cfg) {
            Ok(p) => p,
            Err(e) => {
                eprintln!("  ({ds_name}/{vname} failed: {e:#})");
                continue;
            }
        };
        if vname == "bert" {
            bert_p50 = Some(point.latency.p50);
        }
        let speedup = bert_p50
            .map(|b| format!("{:.2}x", b / point.latency.p50))
            .unwrap_or_else(|| "-".into());
        table.row(vec![
            vname.to_string(),
            format!("{:.4}", point.metric),
            point.batch.to_string(),
            fmt_time(point.latency.p50),
            speedup,
            format!("{per_layer:?} (Σ {})", per_layer.iter().sum::<u64>()),
        ]);
    }
    if !table.rows.is_empty() {
        table.print();
    }
}
