//! Native backend bench: the kernel layer and the end-to-end forward.
//!
//! Sections per dataset:
//! 1. **kernels** — `matmul_bias` on the bundle's real GEMM shapes (QKV
//!    projection, FFN up, FFN down), single-threaded, in GFLOP/s. Every
//!    row is self-describing: dispatch path (serial / scoped / pooled),
//!    weight precision (f32 / int8) and the ISA the kernel actually ran
//!    on (`scalar` or `avx2+fma`, runtime-detected). Rows cover the naive
//!    reference, the forced-scalar blocked oracle, the dispatched blocked
//!    kernel (SIMD when built with `--features simd` on a capable host)
//!    and the int8 quantized-weight kernel — plus per-call allocation
//!    bytes (the blocked paths are allocation-free);
//! 2. **thread scaling** — the dispatched kernel on the FFN-up shape at
//!    1/2/4 intra-op threads, for both precisions;
//! 3. **dispatch (small shape)** — serial vs per-call scoped spawns vs
//!    the persistent pool on a batch=1, 64-row slice of the FFN-up shape:
//!    the regime where spawn cost used to dominate. Reports p50 latency,
//!    allocation bytes/call and thread spawns/call for each path;
//! 4. **bert vs power** — wall-clock speedup vs the retention config plus
//!    the measured per-layer word-vector counts (the paper's Figure 1
//!    quantity, counted by the executor rather than derived from
//!    meta.json), at both weight precisions;
//! 5. **adaptive** — per-threshold mean word-vectors processed (batch-1
//!    over the committed test split, the composition-independent number)
//!    and batch-1 latency: the dial `eval --calibrate-pareto` calibrates.
//!    The tokens ratio vs the fixed schedule is deterministic, so
//!    `bench_diff` can hold it;
//! 6. **ragged** — padded vs ragged execution on the same mixed-demand
//!    batch of committed examples (thresholds 0.6/0.8/0.95, batch 8/32,
//!    power-default plus the seq-256 power-long bundle where present):
//!    the speedup column is the acceptance ratio `perf-diff` gates;
//! 7. **serve** — closed-loop p50/p99 through the in-process coordinator
//!    client on the native backend;
//! 8. **workers sweep** — closed-loop throughput at 1/2/4 coordinator
//!    workers, reported as speedup over 1 worker (the remaining snapshot
//!    gap ROADMAP names).
//!
//!   cargo bench --bench native [PB_BENCH_ITERS=40] -- [--json PATH]
//!
//! `--json PATH` additionally writes every row as a machine-readable
//! snapshot (the committed `BENCH_native.json` at the repo root is one);
//! the text tables are unchanged. The snapshot carries no timestamp so
//! refreshes diff cleanly.

use std::collections::BTreeMap;
use std::time::Instant;

use powerbert::bench::{fmt_time, paper::measure, time_fn, BenchConfig, Table};
use powerbert::coordinator::{BatchPolicy, Config, Coordinator, Input, Policy, Sla};
use powerbert::runtime::kernels::gemm::{matmul_bias_ref, PackedGemm, PackedGemmI8};
use powerbert::runtime::kernels::{thread_spawns, KernelConfig, KernelExec};
use powerbert::runtime::{
    active_isa, default_root, simd_active, ArtifactStore, BackendKind, Engine, Precision, Registry,
    TestSplit, VariantMeta,
};
use powerbert::testutil::alloc;
use powerbert::util::json::Json;
use powerbert::util::prng::Rng;
use powerbert::util::stats::Summary;

// Count every heap allocation so the kernels table can report bytes/call
// — the steady-state claim, measured rather than asserted.
#[global_allocator]
static ALLOC: alloc::CountingAlloc = alloc::CountingAlloc::new();

/// Machine-readable snapshot accumulator, written when `--json PATH` is
/// passed. Section vectors mirror the printed tables row for row.
#[derive(Default)]
struct Snapshot {
    kernels: Vec<Json>,
    thread_scaling: Vec<Json>,
    dispatch: Vec<Json>,
    end_to_end: Vec<Json>,
    adaptive: Vec<Json>,
    ragged: Vec<Json>,
    serve: Vec<Json>,
    workers_sweep: Vec<Json>,
}

fn jobj(pairs: Vec<(&str, Json)>) -> Json {
    let mut m = BTreeMap::new();
    for (k, v) in pairs {
        m.insert(k.to_string(), v);
    }
    Json::Obj(m)
}

fn jstr(s: &str) -> Json {
    Json::Str(s.to_string())
}

impl Snapshot {
    fn write(self, path: &str, cfg: &BenchConfig) {
        // `serve_sweep` is produced by the serve_benchmark example (it
        // needs a real TCP edge and thousands of sockets), which merges
        // its section into this same snapshot file. Rewriting the file
        // here must not drop it.
        let prior_sweep = Json::parse_file(std::path::Path::new(path))
            .ok()
            .and_then(|j| j.get("serve_sweep").cloned())
            .unwrap_or(Json::Arr(Vec::new()));
        let root = jobj(vec![
            ("bench", jstr("native")),
            ("schema", Json::UInt(4)),
            ("isa", jstr(active_isa())),
            ("simd_active", Json::Bool(simd_active())),
            ("measure_iters", Json::UInt(cfg.measure_iters as u64)),
            ("warmup_iters", Json::UInt(cfg.warmup_iters as u64)),
            ("kernels", Json::Arr(self.kernels)),
            ("thread_scaling", Json::Arr(self.thread_scaling)),
            ("dispatch", Json::Arr(self.dispatch)),
            ("end_to_end", Json::Arr(self.end_to_end)),
            ("adaptive", Json::Arr(self.adaptive)),
            ("ragged", Json::Arr(self.ragged)),
            ("serve", Json::Arr(self.serve)),
            ("workers_sweep", Json::Arr(self.workers_sweep)),
            ("serve_sweep", prior_sweep),
        ]);
        match std::fs::write(path, root.to_string_pretty() + "\n") {
            Ok(()) => println!("\nwrote bench snapshot to {path}"),
            Err(e) => eprintln!("--json {path}: {e}"),
        }
    }
}

fn main() {
    powerbert::util::log::init();
    let cfg = BenchConfig::from_env();
    let mut json_path = None;
    let mut args = std::env::args().skip(1);
    while let Some(a) = args.next() {
        if a == "--json" {
            json_path = args.next();
            if json_path.is_none() {
                eprintln!("--json requires a path");
                std::process::exit(2);
            }
        }
    }
    let registry = match Registry::scan(&default_root()) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("SKIP native bench: {e}");
            return;
        }
    };

    let mut snap = Snapshot::default();
    for (ds_name, ds) in &registry.datasets {
        if let Some(meta) = ds.variant("bert").or_else(|| ds.variants.values().next()) {
            if let Err(e) = bench_kernels(ds_name, meta, &cfg, &mut snap) {
                eprintln!("  ({ds_name} kernel bench failed: {e:#})");
            }
        }
        bench_end_to_end(ds_name, ds, &cfg, &mut snap);
        bench_adaptive(ds_name, ds, &cfg, &mut snap);
        bench_ragged(ds_name, ds, &cfg, &mut snap);
    }
    bench_serve(&registry, &cfg, &mut snap);
    bench_workers_sweep(&registry, &cfg, &mut snap);
    if let Some(path) = json_path {
        snap.write(&path, &cfg);
    }
}

/// Allocation bytes + thread spawns of one `f()` call.
fn cost_of_call(f: &mut dyn FnMut()) -> (u64, u64) {
    let before_alloc = alloc::snapshot();
    let before_spawns = thread_spawns();
    f();
    let da = alloc::snapshot().since(&before_alloc);
    (da.bytes, thread_spawns() - before_spawns)
}

/// One kernel-table row: print + snapshot, self-describing
/// (dispatch / precision / ISA), with GFLOP/s and alloc bytes/call.
#[allow(clippy::too_many_arguments)]
fn kernel_row(
    table: &mut Table,
    snap: &mut Snapshot,
    ds_name: &str,
    shape: (&str, usize, usize, usize),
    path: &str,
    dispatch: &str,
    precision: &str,
    isa: &str,
    t: &Summary,
    naive_p50: f64,
    alloc_bytes: u64,
) {
    let (name, n, k, m) = shape;
    let flops = (2 * n * k * m) as f64;
    table.row(vec![
        name.to_string(),
        format!("{n} x {k} x {m}"),
        format!("{path} [{dispatch}/{precision}/{isa}]"),
        fmt_time(t.p50),
        format!("{:.2}", flops / t.p50 / 1e9),
        format!("{:.2}x", naive_p50 / t.p50),
        alloc_bytes.to_string(),
    ]);
    snap.kernels.push(jobj(vec![
        ("dataset", jstr(ds_name)),
        ("shape", jstr(name)),
        ("n", Json::UInt(n as u64)),
        ("k", Json::UInt(k as u64)),
        ("m", Json::UInt(m as u64)),
        ("path", jstr(path)),
        ("dispatch", jstr(dispatch)),
        ("precision", jstr(precision)),
        ("isa", jstr(isa)),
        ("threads", Json::UInt(1)),
        ("p50_s", Json::Num(t.p50)),
        ("gflops", Json::Num(flops / t.p50 / 1e9)),
        ("alloc_bytes_per_call", Json::UInt(alloc_bytes)),
    ]));
}

/// Kernel sections: per-shape path comparison (naive / scalar oracle /
/// dispatched f32 / dispatched int8), thread scaling per precision, and
/// the dispatch-path comparison on the small shape. `rows` is a full
/// batch at full width (8 × seq) — the shape the first encoder runs
/// before elimination shrinks it.
fn bench_kernels(
    ds_name: &str,
    meta: &VariantMeta,
    cfg: &BenchConfig,
    snap: &mut Snapshot,
) -> anyhow::Result<()> {
    let store = ArtifactStore::new();
    let art = store.fetch(meta)?;
    let h = meta.hidden_size;
    let take = |name: &str| -> anyhow::Result<(Vec<usize>, Vec<f32>)> {
        let (dims, data) = art
            .weight(name)
            .ok_or_else(|| anyhow::anyhow!("weights.npz missing {name}"))?;
        Ok((dims.to_vec(), data.to_vec()))
    };
    let (_, wq) = take("layers/0/wq")?;
    let (w1_dims, w1) = take("layers/0/w1")?;
    let ffn = w1_dims[1];
    let (_, w2) = take("layers/0/w2")?;
    let rows = 8 * meta.seq_len;

    let mut rng = Rng::new(0xBE7C);
    let shapes: [(&str, usize, usize, &[f32]); 3] =
        [("qkv proj", h, h, &wq), ("ffn up", h, ffn, &w1), ("ffn down", ffn, h, &w2)];
    let mut table = Table::new(
        &format!("native kernels — {ds_name}: matmul_bias paths (1 thread)"),
        &[
            "shape",
            "n x k x m",
            "path [dispatch/precision/isa]",
            "p50",
            "GFLOP/s",
            "vs naive",
            "alloc B/call",
        ],
    );
    let single = KernelExec::new(KernelConfig::default().with_threads(1));
    // Acceptance ratios on the FFN-up shape (blocked/naive, simd/scalar,
    // int8/f32), reported below the table.
    let mut ffn_ratios = None;
    for (name, k, m, w) in shapes {
        let x: Vec<f32> = (0..rows * k).map(|_| (rng.f64() * 2.0 - 1.0) as f32).collect();
        let bias: Vec<f32> = (0..m).map(|_| (rng.f64() * 2.0 - 1.0) as f32).collect();
        let shape = (name, rows, k, m);

        let naive = time_fn(cfg, || {
            std::hint::black_box(matmul_bias_ref(&x, rows, k, w, m, &bias));
        });
        let (naive_bytes, _) = cost_of_call(&mut || {
            std::hint::black_box(matmul_bias_ref(&x, rows, k, w, m, &bias));
        });
        kernel_row(
            &mut table, snap, ds_name, shape, "naive", "serial", "f32", "scalar", &naive,
            naive.p50, naive_bytes,
        );

        let packed = PackedGemm::pack(w, k, m);
        let mut out = vec![0f32; rows * m];
        let scalar = time_fn(cfg, || {
            packed.matmul_bias_scalar(&x, rows, &bias, single.config().kc, &mut out);
            std::hint::black_box(&out);
        });
        let (scalar_bytes, _) = cost_of_call(&mut || {
            packed.matmul_bias_scalar(&x, rows, &bias, single.config().kc, &mut out);
            std::hint::black_box(&out);
        });
        kernel_row(
            &mut table, snap, ds_name, shape, "blocked-scalar", "serial", "f32", "scalar",
            &scalar, naive.p50, scalar_bytes,
        );

        let blocked = time_fn(cfg, || {
            packed.matmul_bias(&x, rows, &bias, &single, &mut out);
            std::hint::black_box(&out);
        });
        let (blocked_bytes, _) = cost_of_call(&mut || {
            packed.matmul_bias(&x, rows, &bias, &single, &mut out);
            std::hint::black_box(&out);
        });
        kernel_row(
            &mut table, snap, ds_name, shape, "blocked", "serial", "f32", active_isa(), &blocked,
            naive.p50, blocked_bytes,
        );

        let qpacked = PackedGemmI8::pack(w, k, m);
        let int8 = time_fn(cfg, || {
            qpacked.matmul_bias(&x, rows, &bias, &single, &mut out);
            std::hint::black_box(&out);
        });
        let (int8_bytes, _) = cost_of_call(&mut || {
            qpacked.matmul_bias(&x, rows, &bias, &single, &mut out);
            std::hint::black_box(&out);
        });
        kernel_row(
            &mut table, snap, ds_name, shape, "blocked", "serial", "int8", active_isa(), &int8,
            naive.p50, int8_bytes,
        );

        if name == "ffn up" {
            ffn_ratios = Some((naive.p50 / blocked.p50, scalar.p50 / blocked.p50, blocked.p50 / int8.p50));
        }
    }
    table.print();
    if let Some((vs_naive, vs_scalar, int8_vs_f32)) = ffn_ratios {
        // The acceptance numbers, single-threaded on the bundle's FFN
        // shape: dispatched-vs-naive, dispatched-vs-scalar-oracle (the
        // SIMD speedup when AVX2+FMA is active), int8-vs-f32.
        println!("ffn-shape single-thread: blocked vs naive {vs_naive:.2}x");
        println!(
            "ffn-shape single-thread: dispatched ({}) vs scalar oracle {vs_scalar:.2}x",
            active_isa()
        );
        println!("ffn-shape single-thread: int8 vs f32 (same dispatch) {int8_vs_f32:.2}x");
    }

    let mut scaling = Table::new(
        &format!("native kernels — {ds_name}: matmul thread scaling (ffn up shape)"),
        &["precision", "threads", "dispatch", "p50", "GFLOP/s", "vs 1 thread"],
    );
    let x: Vec<f32> = (0..rows * h).map(|_| (rng.f64() * 2.0 - 1.0) as f32).collect();
    let bias: Vec<f32> = (0..ffn).map(|_| (rng.f64() * 2.0 - 1.0) as f32).collect();
    let fp = PackedGemm::pack(&w1, h, ffn);
    let qp = PackedGemmI8::pack(&w1, h, ffn);
    let mut out = vec![0f32; rows * ffn];
    let flops = (2 * rows * h * ffn) as f64;
    for precision in [Precision::F32, Precision::Int8] {
        let mut base = None;
        for threads in [1usize, 2, 4] {
            // mc small enough that `rows` splits across every thread count;
            // the fallback floor is disabled so each row measures the path
            // its label claims, not the dispatcher's pick.
            let exec = KernelExec::new(KernelConfig {
                threads,
                kc: 256,
                mc: 16,
                precision,
                min_parallel_flops: 0,
                ..KernelConfig::default()
            });
            let t = time_fn(cfg, || {
                match precision {
                    Precision::F32 => fp.matmul_bias(&x, rows, &bias, &exec, &mut out),
                    Precision::Int8 => qp.matmul_bias(&x, rows, &bias, &exec, &mut out),
                }
                std::hint::black_box(&out);
            });
            if threads == 1 {
                base = Some(t.p50);
            }
            let dispatch = if threads == 1 { "serial" } else { "pooled" };
            let rel = base.map(|b| b / t.p50).unwrap_or(1.0);
            scaling.row(vec![
                precision.to_string(),
                threads.to_string(),
                dispatch.to_string(),
                fmt_time(t.p50),
                format!("{:.2}", flops / t.p50 / 1e9),
                format!("{rel:.2}x"),
            ]);
            snap.thread_scaling.push(jobj(vec![
                ("dataset", jstr(ds_name)),
                ("shape", jstr("ffn up")),
                ("n", Json::UInt(rows as u64)),
                ("k", Json::UInt(h as u64)),
                ("m", Json::UInt(ffn as u64)),
                ("precision", jstr(precision.as_str())),
                ("isa", jstr(active_isa())),
                ("threads", Json::UInt(threads as u64)),
                ("dispatch", jstr(dispatch)),
                ("p50_s", Json::Num(t.p50)),
                ("gflops", Json::Num(flops / t.p50 / 1e9)),
                ("speedup_vs_1t", Json::Num(rel)),
            ]));
        }
    }
    scaling.print();

    bench_dispatch(ds_name, &w1, h, ffn, cfg, snap);
    Ok(())
}

/// Dispatch-path comparison on the small shape the per-call spawn cost
/// used to dominate: batch=1 × 64 rows (the seq-64 bucket) of the FFN-up
/// GEMM, split at mc=16 so two lanes genuinely share the work. Serial vs
/// per-call scoped spawns vs the persistent pool — the pooled line should
/// sit at (or below) serial and clearly below scoped.
fn bench_dispatch(
    ds_name: &str,
    w1: &[f32],
    h: usize,
    ffn: usize,
    cfg: &BenchConfig,
    snap: &mut Snapshot,
) {
    const DISPATCH_ROWS: usize = 64; // batch=1 at a seq-64 bucket
    const DISPATCH_THREADS: usize = 2;
    let mut rng = Rng::new(0xD15F);
    let x: Vec<f32> = (0..DISPATCH_ROWS * h).map(|_| (rng.f64() * 2.0 - 1.0) as f32).collect();
    let bias: Vec<f32> = (0..ffn).map(|_| (rng.f64() * 2.0 - 1.0) as f32).collect();
    let packed = PackedGemm::pack(w1, h, ffn);
    let mut out = vec![0f32; DISPATCH_ROWS * ffn];
    // The floor is disabled on the measured configs: each row must time
    // the path its label names even where production dispatch would skip
    // it. What production *would* pick is the "chosen" column, computed
    // against the default `min_parallel_flops` floors.
    let kcfg = KernelConfig {
        threads: DISPATCH_THREADS,
        kc: 256,
        mc: 16,
        min_parallel_flops: 0,
        ..KernelConfig::default()
    };
    let serial_exec = KernelExec::new(kcfg.clone().with_threads(1));
    // Built once — the pool's workers are parked between calls, exactly
    // as an EngineWorker holds them for its lifetime.
    let pooled_exec = KernelExec::new(kcfg.clone());
    let prod_cfg =
        KernelConfig { threads: DISPATCH_THREADS, kc: 256, mc: 16, ..KernelConfig::default() };
    let tasks = DISPATCH_ROWS.div_ceil(prod_cfg.mc.max(1));
    let flops = powerbert::runtime::kernels::gemm_flops(DISPATCH_ROWS, h, ffn);
    let pooled_chosen = KernelExec::new(prod_cfg.clone()).chosen_path(tasks, flops);
    let scoped_chosen =
        if powerbert::runtime::kernels::scoped_threads_for_work(&prod_cfg, tasks, flops) <= 1 {
            "serial"
        } else {
            "scoped"
        };

    let mut table = Table::new(
        &format!(
            "native kernels — {ds_name}: dispatch on the small shape \
             (batch=1, {DISPATCH_ROWS} rows x {h} x {ffn}, {DISPATCH_THREADS} threads, \
             f32/{})",
            active_isa()
        ),
        &["path", "p50", "alloc B/call", "spawns/call", "vs serial", "chosen"],
    );

    let mut dispatch_row = |table: &mut Table,
                            snap: &mut Snapshot,
                            label: &str,
                            dispatch: &str,
                            t: &Summary,
                            bytes: u64,
                            spawns: u64,
                            serial_p50: f64,
                            chosen: &str| {
        table.row(vec![
            label.to_string(),
            fmt_time(t.p50),
            bytes.to_string(),
            spawns.to_string(),
            format!("{:.2}x", serial_p50 / t.p50),
            chosen.to_string(),
        ]);
        snap.dispatch.push(jobj(vec![
            ("dataset", jstr(ds_name)),
            ("path", jstr(dispatch)),
            ("chosen", jstr(chosen)),
            ("precision", jstr("f32")),
            ("isa", jstr(active_isa())),
            (
                "threads",
                Json::UInt(if dispatch == "serial" { 1 } else { DISPATCH_THREADS as u64 }),
            ),
            ("p50_s", Json::Num(t.p50)),
            ("alloc_bytes_per_call", Json::UInt(bytes)),
            ("spawns_per_call", Json::UInt(spawns)),
            ("vs_serial", Json::Num(serial_p50 / t.p50)),
        ]));
    };

    let serial = time_fn(cfg, || {
        packed.matmul_bias(&x, DISPATCH_ROWS, &bias, &serial_exec, &mut out);
        std::hint::black_box(&out);
    });
    let (serial_bytes, serial_spawns) = cost_of_call(&mut || {
        packed.matmul_bias(&x, DISPATCH_ROWS, &bias, &serial_exec, &mut out);
        std::hint::black_box(&out);
    });
    let serial_p50 = serial.p50;
    dispatch_row(
        &mut table, snap, "serial (1 thread)", "serial", &serial, serial_bytes, serial_spawns,
        serial_p50, "serial",
    );

    let scoped = time_fn(cfg, || {
        packed.matmul_bias_scoped(&x, DISPATCH_ROWS, &bias, &kcfg, &mut out);
        std::hint::black_box(&out);
    });
    let (scoped_bytes, scoped_spawns) = cost_of_call(&mut || {
        packed.matmul_bias_scoped(&x, DISPATCH_ROWS, &bias, &kcfg, &mut out);
        std::hint::black_box(&out);
    });
    dispatch_row(
        &mut table, snap, "scoped spawns (old)", "scoped", &scoped, scoped_bytes, scoped_spawns,
        serial_p50, scoped_chosen,
    );

    let pooled = time_fn(cfg, || {
        packed.matmul_bias(&x, DISPATCH_ROWS, &bias, &pooled_exec, &mut out);
        std::hint::black_box(&out);
    });
    let (pooled_bytes, pooled_spawns) = cost_of_call(&mut || {
        packed.matmul_bias(&x, DISPATCH_ROWS, &bias, &pooled_exec, &mut out);
        std::hint::black_box(&out);
    });
    dispatch_row(
        &mut table, snap, "kernel pool (new)", "pooled", &pooled, pooled_bytes, pooled_spawns,
        serial_p50, pooled_chosen,
    );
    table.print();
    println!(
        "small-shape dispatch: pooled spawns 0 threads/call vs scoped's \
         per-call spawns — the pool pays its {DISPATCH_THREADS} spawns once at worker start"
    );
    println!(
        "small-shape dispatch: production floors pick scoped={scoped_chosen} \
         pooled={pooled_chosen} for this {:.2} MFLOP shape (min_parallel_flops={}, \
         scoped floor={})",
        flops as f64 / 1e6,
        prod_cfg.min_parallel_flops,
        powerbert::runtime::kernels::SCOPED_SPAWN_FLOPS,
    );
}

/// bert vs power end-to-end on the native backend at both weight
/// precisions: metric, latency, speedup-vs-retention, measured
/// word-vectors per layer, arena footprint.
fn bench_end_to_end(
    ds_name: &str,
    ds: &powerbert::runtime::DatasetArtifacts,
    cfg: &BenchConfig,
    snap: &mut Snapshot,
) {
    let split = match TestSplit::load(&ds.test_npz()) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("SKIP {ds_name}: {e:#}");
            return;
        }
    };
    let mut table = Table::new(
        &format!("native backend — {ds_name}: metric / latency / word-vectors per layer"),
        &[
            "variant",
            "precision/isa",
            "metric",
            "batch",
            "p50",
            "speedup",
            "wv/layer (measured)",
            "arena peak",
        ],
    );
    for precision in [Precision::F32, Precision::Int8] {
        let kernel = KernelConfig::default().with_precision(precision);
        let mut engine = Engine::with_backend_config(BackendKind::Native, kernel)
            .expect("native engine");
        let mut bert_p50 = None;
        for vname in ["bert", "power-default"] {
            let Some(meta) = ds.variant(vname) else { continue };
            let model = match engine.load(meta) {
                Ok(m) => m,
                Err(e) => {
                    eprintln!("  ({ds_name}/{vname} native load failed: {e:#})");
                    continue;
                }
            };
            // Per-layer counts of one timed batch: snapshot the cumulative
            // telemetry around a single infer.
            let n = 8.min(split.n);
            let seq = split.seq_len;
            let before = model.layer_tokens().unwrap_or_default();
            model
                .infer(&split.tokens[..n * seq], &split.segments[..n * seq], n)
                .expect("infer");
            let after = model.layer_tokens().unwrap_or_default();
            let per_layer: Vec<u64> = after
                .iter()
                .zip(before.iter())
                .map(|(a, b)| (a - b) / n as u64)
                .collect();

            let point = match measure(&mut engine, meta, &split, 32, cfg) {
                Ok(p) => p,
                Err(e) => {
                    eprintln!("  ({ds_name}/{vname} failed: {e:#})");
                    continue;
                }
            };
            if vname == "bert" {
                bert_p50 = Some(point.latency.p50);
            }
            let speedup = bert_p50
                .map(|b| format!("{:.2}x", b / point.latency.p50))
                .unwrap_or_else(|| "-".into());
            let mem = model.memory_stats();
            let (arena, tier) = mem
                .map(|m| {
                    let kib = m.arena_peak_bytes as f64 / 1024.0;
                    (
                        format!("{kib:.1} KiB / {} bucket(s)", m.arena_buckets),
                        format!("{}/{}", m.precision, m.isa),
                    )
                })
                .unwrap_or_else(|| ("-".into(), precision.to_string()));
            table.row(vec![
                vname.to_string(),
                tier,
                format!("{:.4}", point.metric),
                point.batch.to_string(),
                fmt_time(point.latency.p50),
                speedup,
                format!("{per_layer:?} (Σ {})", per_layer.iter().sum::<u64>()),
                arena,
            ]);
            snap.end_to_end.push(jobj(vec![
                ("dataset", jstr(ds_name)),
                ("variant", jstr(vname)),
                ("precision", jstr(precision.as_str())),
                ("isa", jstr(active_isa())),
                ("metric", Json::Num(point.metric)),
                ("batch", Json::UInt(point.batch as u64)),
                ("p50_s", Json::Num(point.latency.p50)),
                ("p99_s", Json::Num(point.latency.p99)),
                ("examples_per_sec", Json::Num(point.examples_per_sec)),
                (
                    "arena_peak_bytes",
                    Json::UInt(mem.map(|m| m.arena_peak_bytes).unwrap_or(0)),
                ),
                (
                    "arena_buckets",
                    Json::UInt(mem.map(|m| m.arena_buckets).unwrap_or(0)),
                ),
                (
                    "wv_per_layer",
                    Json::Arr(per_layer.iter().map(|&v| Json::UInt(v)).collect()),
                ),
            ]));
        }
    }
    if !table.rows.is_empty() {
        table.print();
    }
}

/// Closed-loop throughput at 1/2/4 coordinator workers on the first
/// dataset (sst2 when present): `workers * 4` blocking client threads
/// drive the pool flat out, and the row reports total req/s plus the
/// speedup over the 1-worker row — the machine-independent ratio
/// `bench_diff` preserves.
fn bench_workers_sweep(registry: &Registry, cfg: &BenchConfig, snap: &mut Snapshot) {
    let Some(ds_name) = registry
        .datasets
        .keys()
        .find(|k| k.as_str() == "sst2")
        .or_else(|| registry.datasets.keys().next())
        .cloned()
    else {
        return;
    };
    let ds = ds_name.as_str();
    let mut table = Table::new(
        &format!("native serve — {ds}: closed-loop throughput vs workers (power-default)"),
        &["workers", "clients", "requests", "req/s", "vs 1 worker"],
    );
    let mut base_rps = None;
    for workers in [1usize, 2, 4] {
        let c = match Coordinator::start(Config {
            policy: Policy::Fixed("power-default".into()),
            batch: BatchPolicy { max_batch: 8, max_wait: std::time::Duration::from_millis(1) },
            workers,
            backend: BackendKind::Native,
            ..Config::default()
        }) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("SKIP workers sweep: {e:#}");
                return;
            }
        };
        let client = c.client();
        let vocab = client.tokenizer().vocab.clone();
        let clients = workers * 4;
        let per_client = (cfg.measure_iters * 2).max(40);
        // Warm the variant onto every worker before the timed window.
        let mut warm = powerbert::workload::WorkloadGen::new(&vocab, 7);
        for _ in 0..cfg.warmup_iters.max(4) {
            let (text, _) = warm.sentence(12);
            let _ = client.classify(ds, Input::Text { a: text, b: None }, Sla::default());
        }
        let t0 = Instant::now();
        std::thread::scope(|s| {
            for t in 0..clients {
                let client = client.clone();
                let vocab = vocab.clone();
                s.spawn(move || {
                    let mut gen = powerbert::workload::WorkloadGen::new(&vocab, 17 + t as u64);
                    for _ in 0..per_client {
                        let (text, _) = gen.sentence(12);
                        let _ =
                            client.classify(ds, Input::Text { a: text, b: None }, Sla::default());
                    }
                });
            }
        });
        let elapsed = t0.elapsed().as_secs_f64();
        let total = (clients * per_client) as u64;
        let rps = total as f64 / elapsed.max(1e-9);
        if workers == 1 {
            base_rps = Some(rps);
        }
        let rel = base_rps.map(|b| rps / b.max(1e-9)).unwrap_or(1.0);
        table.row(vec![
            workers.to_string(),
            clients.to_string(),
            total.to_string(),
            format!("{rps:.1}"),
            format!("{rel:.2}x"),
        ]);
        snap.workers_sweep.push(jobj(vec![
            ("dataset", jstr(ds)),
            ("variant", jstr("power-default")),
            ("workers", Json::UInt(workers as u64)),
            ("clients", Json::UInt(clients as u64)),
            ("requests", Json::UInt(total)),
            ("throughput_rps", Json::Num(rps)),
            ("speedup_vs_1w", Json::Num(rel)),
        ]));
        drop(c);
    }
    table.print();
}

/// Adaptive retention sweep on power-default: per-threshold mean
/// word-vectors processed and batch-1 latency. Batch-1 makes the tokens
/// number composition-independent (the batch-max rule degenerates to the
/// example's own demanded k), so the `tokens_ratio_vs_fixed` column is
/// deterministic given the committed artifacts — `bench_diff` holds it.
fn bench_adaptive(
    ds_name: &str,
    ds: &powerbert::runtime::DatasetArtifacts,
    cfg: &BenchConfig,
    snap: &mut Snapshot,
) {
    let Some(meta) = ds.variant("power-default") else { return };
    let split = match TestSplit::load(&ds.test_npz()) {
        Ok(s) => s,
        Err(_) => return,
    };
    let mut engine = match Engine::with_backend_config(BackendKind::Native, KernelConfig::default())
    {
        Ok(e) => e,
        Err(e) => {
            eprintln!("SKIP adaptive bench: {e:#}");
            return;
        }
    };
    let model = match engine.load(meta) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("  ({ds_name}/power-default native load failed: {e:#})");
            return;
        }
    };
    if !model.supports_adaptive() {
        return;
    }
    let n = split.n.min(64);
    let seq = split.seq_len;
    let mut table = Table::new(
        &format!(
            "native adaptive — {ds_name}/power-default: word-vectors vs threshold \
             (batch=1, {n} examples)"
        ),
        &["threshold", "mean wv/example", "vs fixed", "p50/example"],
    );
    let mut fixed_mean = None;
    for t in [1.0f32, 0.95, 0.8, 0.6] {
        let thr = (t < 1.0).then_some(t);
        let mut total = 0u64;
        let mut ok = true;
        for i in 0..n {
            let rows = &split.tokens[i * seq..(i + 1) * seq];
            let segs = &split.segments[i * seq..(i + 1) * seq];
            match model.infer_adaptive_at(rows, segs, 1, seq, thr) {
                Ok((_, Some(per_row))) => total += per_row.iter().sum::<u64>(),
                Ok((_, None)) | Err(_) => {
                    ok = false;
                    break;
                }
            }
        }
        if !ok {
            eprintln!("  ({ds_name} adaptive sweep failed at t={t})");
            return;
        }
        let mean = total as f64 / n as f64;
        if thr.is_none() {
            fixed_mean = Some(mean);
        }
        let ratio = fixed_mean.map(|f| mean / f.max(1e-12)).unwrap_or(1.0);
        let lat = time_fn(cfg, || {
            std::hint::black_box(
                model
                    .infer_adaptive_at(&split.tokens[..seq], &split.segments[..seq], 1, seq, thr)
                    .ok(),
            );
        });
        table.row(vec![
            if thr.is_none() { "fixed (1.0)".into() } else { format!("{t:.2}") },
            format!("{mean:.1}"),
            format!("{ratio:.3}x"),
            fmt_time(lat.p50),
        ]);
        snap.adaptive.push(jobj(vec![
            ("dataset", jstr(ds_name)),
            ("variant", jstr("power-default")),
            ("threshold", Json::Num(t as f64)),
            ("examples", Json::UInt(n as u64)),
            ("mean_tokens", Json::Num(mean)),
            ("tokens_ratio_vs_fixed", Json::Num(ratio)),
            ("p50_s", Json::Num(lat.p50)),
        ]));
    }
    table.print();
}

/// Padded vs ragged execution on the same mixed-demand batch: the first
/// `batch` committed test examples (their natural length mix is the
/// demand mix), two engines differing only in the `ragged` flag, timed on
/// identical inputs at each threshold. The `speedup_vs_padded` column is
/// the acceptance ratio `perf-diff` gates (≥ 1.3x at threshold 0.6):
/// ragged compute is Σ kept tokens, padded compute is batch × the widest
/// example's demand, so the gap *is* the eliminated ghost work. Covers
/// power-default on every dataset plus the seq-256 power-long bundle.
fn bench_ragged(
    ds_name: &str,
    ds: &powerbert::runtime::DatasetArtifacts,
    cfg: &BenchConfig,
    snap: &mut Snapshot,
) {
    let split = match TestSplit::load(&ds.test_npz()) {
        Ok(s) => s,
        Err(_) => return,
    };
    for vname in ["power-default", "power-long"] {
        let Some(meta) = ds.variant(vname) else { continue };
        let engine_with = |ragged: bool| {
            Engine::with_backend_config(
                BackendKind::Native,
                KernelConfig::default().with_ragged(ragged),
            )
        };
        let (mut ragged_eng, mut padded_eng) = match (engine_with(true), engine_with(false)) {
            (Ok(r), Ok(p)) => (r, p),
            (Err(e), _) | (_, Err(e)) => {
                eprintln!("SKIP ragged bench: {e:#}");
                return;
            }
        };
        let (ragged, padded) = match (ragged_eng.load(meta), padded_eng.load(meta)) {
            (Ok(r), Ok(p)) => (r, p),
            (Err(e), _) | (_, Err(e)) => {
                eprintln!("  ({ds_name}/{vname} native load failed: {e:#})");
                continue;
            }
        };
        if !ragged.supports_adaptive() {
            continue;
        }
        let seq = split.seq_len;
        let mut table = Table::new(
            &format!("native ragged — {ds_name}/{vname}: padded vs ragged (seq {seq}, f32)"),
            &["threshold", "batch", "padded p50", "ragged p50", "speedup"],
        );
        for t in [0.6f32, 0.8, 0.95] {
            for batch in [8usize, 32] {
                let n = batch.min(split.n);
                if n == 0 {
                    continue;
                }
                let toks = &split.tokens[..n * seq];
                let segs = &split.segments[..n * seq];
                // Same committed rows, same threshold — only the
                // execution shape differs.
                let pad = time_fn(cfg, || {
                    let r = padded.infer_adaptive_at(toks, segs, n, seq, Some(t));
                    std::hint::black_box(r.ok());
                });
                let rag = time_fn(cfg, || {
                    let r = ragged.infer_adaptive_at(toks, segs, n, seq, Some(t));
                    std::hint::black_box(r.ok());
                });
                let speedup = pad.p50 / rag.p50.max(1e-12);
                table.row(vec![
                    format!("{t:.2}"),
                    n.to_string(),
                    fmt_time(pad.p50),
                    fmt_time(rag.p50),
                    format!("{speedup:.2}x"),
                ]);
                snap.ragged.push(jobj(vec![
                    ("dataset", jstr(ds_name)),
                    ("variant", jstr(vname)),
                    ("precision", jstr("f32")),
                    ("isa", jstr(active_isa())),
                    ("threshold", Json::Num(t as f64)),
                    ("batch", Json::UInt(n as u64)),
                    ("seq", Json::UInt(split.seq_len as u64)),
                    ("padded_p50_s", Json::Num(pad.p50)),
                    ("ragged_p50_s", Json::Num(rag.p50)),
                    ("speedup_vs_padded", Json::Num(speedup)),
                ]));
            }
        }
        table.print();
    }
}

/// Closed-loop serve latency through the in-process coordinator client:
/// one coordinator (native backend, fixed power-default routing), one
/// blocking client issuing single requests — the per-request p50/p99 a
/// v1 caller would see, minus the TCP hop.
fn bench_serve(registry: &Registry, cfg: &BenchConfig, snap: &mut Snapshot) {
    if registry.datasets.is_empty() {
        return;
    }
    let c = match Coordinator::start(Config {
        policy: Policy::Fixed("power-default".into()),
        batch: BatchPolicy { max_batch: 8, max_wait: std::time::Duration::from_millis(1) },
        workers: 1,
        backend: BackendKind::Native,
        ..Config::default()
    }) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("SKIP serve bench: {e:#}");
            return;
        }
    };
    let client = c.client();
    let vocab = client.tokenizer().vocab.clone();
    let mut table = Table::new(
        "native serve — closed-loop coordinator client (workers=1, power-default)",
        &["dataset", "requests", "p50", "p99", "req/s"],
    );
    for ds_name in registry.datasets.keys() {
        let mut gen = powerbert::workload::WorkloadGen::new(&vocab, 11);
        let requests = (cfg.measure_iters * 2).max(40);
        let mut latencies = Vec::with_capacity(requests);
        let mut ok = true;
        for i in 0..requests + cfg.warmup_iters {
            let (text, _label) = gen.sentence(12);
            let t0 = Instant::now();
            if client
                .classify(ds_name, Input::Text { a: text, b: None }, Sla::default())
                .is_err()
            {
                ok = false;
                break;
            }
            if i >= cfg.warmup_iters {
                latencies.push(t0.elapsed().as_secs_f64());
            }
        }
        if !ok || latencies.is_empty() {
            eprintln!("  (serve bench on {ds_name} failed)");
            continue;
        }
        let s = Summary::of(&latencies);
        table.row(vec![
            ds_name.clone(),
            latencies.len().to_string(),
            fmt_time(s.p50),
            fmt_time(s.p99),
            format!("{:.1}", 1.0 / s.p50),
        ]);
        snap.serve.push(jobj(vec![
            ("dataset", jstr(ds_name)),
            ("variant", jstr("power-default")),
            ("requests", Json::UInt(latencies.len() as u64)),
            ("p50_s", Json::Num(s.p50)),
            ("p99_s", Json::Num(s.p99)),
            ("throughput_rps", Json::Num(1.0 / s.p50)),
        ]));
    }
    if !table.rows.is_empty() {
        table.print();
    }
}
