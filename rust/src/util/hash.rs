//! Dependency-free SHA-256 / SHA-512 with a streaming [`HashingReader`].
//!
//! The artifact repository (`runtime/repo.rs`) digests every bundle file
//! as it loads — `weights.npz` is hashed *while* it is read into the
//! parse buffer, never buffered twice — and the manifest signature
//! (`util/ed25519.rs`) hashes with SHA-512 per RFC 8032. Like
//! `util/npz.rs` and `util/json.rs`, this module vendors the primitive
//! instead of pulling a crate: the container builds offline.
//!
//! The round constants are not embedded as literal tables (80 u64
//! magic numbers are exactly the kind of thing that rots silently);
//! they are derived at first use from their FIPS 180-4 definition —
//! the fractional bits of the square/cube roots of the first primes —
//! using exact integer root extraction, then pinned by known-answer
//! tests against the published vectors.

use std::cmp::Ordering;
use std::io::{self, Read};
use std::path::Path;
use std::sync::OnceLock;

// ---------------------------------------------------------------------------
// FIPS 180-4 constant derivation: frac(p^(1/root)) to `bits` bits, exact.
// ---------------------------------------------------------------------------

fn primes(n: usize) -> Vec<u64> {
    let mut out = Vec::with_capacity(n);
    let mut cand = 2u64;
    while out.len() < n {
        if out.iter().all(|p| cand % p != 0) {
            out.push(cand);
        }
        cand += 1;
    }
    out
}

/// Little-endian limb multiply (schoolbook; operands are tiny).
fn mul_limbs(a: &[u64], b: &[u64]) -> Vec<u64> {
    let mut out = vec![0u64; a.len() + b.len()];
    for (i, &x) in a.iter().enumerate() {
        let mut carry = 0u128;
        for (j, &y) in b.iter().enumerate() {
            let t = out[i + j] as u128 + x as u128 * y as u128 + carry;
            out[i + j] = t as u64;
            carry = t >> 64;
        }
        out[i + b.len()] = carry as u64;
    }
    out
}

fn cmp_limbs(a: &[u64], b: &[u64]) -> Ordering {
    let hi = a.len().max(b.len());
    for i in (0..hi).rev() {
        let x = a.get(i).copied().unwrap_or(0);
        let y = b.get(i).copied().unwrap_or(0);
        match x.cmp(&y) {
            Ordering::Equal => {}
            ord => return ord,
        }
    }
    Ordering::Equal
}

/// `floor(prime^(1/root) * 2^bits)` truncated to the low 64 bits, i.e.
/// the first `bits` fractional bits of the root (the integer part falls
/// off the top). Exact integer binary search — no floating point.
fn root_frac(prime: u64, root: u32, bits: u32) -> u64 {
    let shift = (root * bits) as usize;
    let mut target = vec![0u64; shift / 64 + 2];
    let v = (prime as u128) << (shift % 64);
    target[shift / 64] |= v as u64;
    target[shift / 64 + 1] |= (v >> 64) as u64;
    let mut y: u128 = 0;
    for bit in (0..=(bits + 4)).rev() {
        let cand = y | (1u128 << bit);
        let limbs = [cand as u64, (cand >> 64) as u64];
        let mut pow: Vec<u64> = vec![1];
        for _ in 0..root {
            pow = mul_limbs(&pow, &limbs);
        }
        if cmp_limbs(&pow, &target) != Ordering::Greater {
            y = cand;
        }
    }
    y as u64
}

fn k256() -> &'static [u32; 64] {
    static K: OnceLock<[u32; 64]> = OnceLock::new();
    K.get_or_init(|| {
        let ps = primes(64);
        let mut k = [0u32; 64];
        for (i, &p) in ps.iter().enumerate() {
            k[i] = root_frac(p, 3, 32) as u32;
        }
        k
    })
}

fn h256() -> &'static [u32; 8] {
    static H: OnceLock<[u32; 8]> = OnceLock::new();
    H.get_or_init(|| {
        let ps = primes(8);
        let mut h = [0u32; 8];
        for (i, &p) in ps.iter().enumerate() {
            h[i] = root_frac(p, 2, 32) as u32;
        }
        h
    })
}

fn k512() -> &'static [u64; 80] {
    static K: OnceLock<[u64; 80]> = OnceLock::new();
    K.get_or_init(|| {
        let ps = primes(80);
        let mut k = [0u64; 80];
        for (i, &p) in ps.iter().enumerate() {
            k[i] = root_frac(p, 3, 64);
        }
        k
    })
}

fn h512() -> &'static [u64; 8] {
    static H: OnceLock<[u64; 8]> = OnceLock::new();
    H.get_or_init(|| {
        let ps = primes(8);
        let mut h = [0u64; 8];
        for (i, &p) in ps.iter().enumerate() {
            h[i] = root_frac(p, 2, 64);
        }
        h
    })
}

// ---------------------------------------------------------------------------
// SHA-256
// ---------------------------------------------------------------------------

/// Incremental SHA-256.
#[derive(Clone)]
pub struct Sha256 {
    state: [u32; 8],
    buf: [u8; 64],
    buf_len: usize,
    total: u64,
}

impl Default for Sha256 {
    fn default() -> Self {
        Self::new()
    }
}

impl Sha256 {
    pub fn new() -> Self {
        Sha256 { state: *h256(), buf: [0; 64], buf_len: 0, total: 0 }
    }

    pub fn update(&mut self, mut data: &[u8]) {
        self.total = self.total.wrapping_add(data.len() as u64);
        if self.buf_len > 0 {
            let take = data.len().min(64 - self.buf_len);
            self.buf[self.buf_len..self.buf_len + take].copy_from_slice(&data[..take]);
            self.buf_len += take;
            data = &data[take..];
            if self.buf_len == 64 {
                let block = self.buf;
                self.compress(&block);
                self.buf_len = 0;
            }
        }
        while data.len() >= 64 {
            let (block, rest) = data.split_at(64);
            self.compress(block.try_into().unwrap());
            data = rest;
        }
        self.buf[..data.len()].copy_from_slice(data);
        self.buf_len = data.len();
    }

    pub fn finalize(mut self) -> [u8; 32] {
        let bits = self.total.wrapping_mul(8);
        self.update(&[0x80]);
        while self.buf_len != 56 {
            self.update(&[0]);
        }
        // Length is excluded from `total` accounting by going through
        // update: total no longer matters once `bits` is latched.
        let mut block = self.buf;
        block[56..64].copy_from_slice(&bits.to_be_bytes());
        self.compress(&block);
        let mut out = [0u8; 32];
        for (i, w) in self.state.iter().enumerate() {
            out[4 * i..4 * i + 4].copy_from_slice(&w.to_be_bytes());
        }
        out
    }

    fn compress(&mut self, block: &[u8; 64]) {
        let k = k256();
        let mut w = [0u32; 64];
        for (i, c) in block.chunks_exact(4).enumerate() {
            w[i] = u32::from_be_bytes(c.try_into().unwrap());
        }
        for t in 16..64 {
            let s0 = w[t - 15].rotate_right(7) ^ w[t - 15].rotate_right(18) ^ (w[t - 15] >> 3);
            let s1 = w[t - 2].rotate_right(17) ^ w[t - 2].rotate_right(19) ^ (w[t - 2] >> 10);
            w[t] = w[t - 16]
                .wrapping_add(s0)
                .wrapping_add(w[t - 7])
                .wrapping_add(s1);
        }
        let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut h] = self.state;
        for t in 0..64 {
            let s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
            let ch = (e & f) ^ (!e & g);
            let t1 = h
                .wrapping_add(s1)
                .wrapping_add(ch)
                .wrapping_add(k[t])
                .wrapping_add(w[t]);
            let s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
            let maj = (a & b) ^ (a & c) ^ (b & c);
            let t2 = s0.wrapping_add(maj);
            h = g;
            g = f;
            f = e;
            e = d.wrapping_add(t1);
            d = c;
            c = b;
            b = a;
            a = t1.wrapping_add(t2);
        }
        for (s, v) in self.state.iter_mut().zip([a, b, c, d, e, f, g, h]) {
            *s = s.wrapping_add(v);
        }
    }
}

/// One-shot SHA-256.
pub fn sha256(data: &[u8]) -> [u8; 32] {
    let mut h = Sha256::new();
    h.update(data);
    h.finalize()
}

/// One-shot SHA-256, lowercase hex.
pub fn sha256_hex(data: &[u8]) -> String {
    to_hex(&sha256(data))
}

// ---------------------------------------------------------------------------
// SHA-512
// ---------------------------------------------------------------------------

/// Incremental SHA-512 (the hash inside ed25519 per RFC 8032).
#[derive(Clone)]
pub struct Sha512 {
    state: [u64; 8],
    buf: [u8; 128],
    buf_len: usize,
    total: u128,
}

impl Default for Sha512 {
    fn default() -> Self {
        Self::new()
    }
}

impl Sha512 {
    pub fn new() -> Self {
        Sha512 { state: *h512(), buf: [0; 128], buf_len: 0, total: 0 }
    }

    pub fn update(&mut self, mut data: &[u8]) {
        self.total = self.total.wrapping_add(data.len() as u128);
        if self.buf_len > 0 {
            let take = data.len().min(128 - self.buf_len);
            self.buf[self.buf_len..self.buf_len + take].copy_from_slice(&data[..take]);
            self.buf_len += take;
            data = &data[take..];
            if self.buf_len == 128 {
                let block = self.buf;
                self.compress(&block);
                self.buf_len = 0;
            }
        }
        while data.len() >= 128 {
            let (block, rest) = data.split_at(128);
            self.compress(block.try_into().unwrap());
            data = rest;
        }
        self.buf[..data.len()].copy_from_slice(data);
        self.buf_len = data.len();
    }

    pub fn finalize(mut self) -> [u8; 64] {
        let bits = self.total.wrapping_mul(8);
        self.update(&[0x80]);
        while self.buf_len != 112 {
            self.update(&[0]);
        }
        let mut block = self.buf;
        block[112..128].copy_from_slice(&bits.to_be_bytes());
        self.compress(&block);
        let mut out = [0u8; 64];
        for (i, w) in self.state.iter().enumerate() {
            out[8 * i..8 * i + 8].copy_from_slice(&w.to_be_bytes());
        }
        out
    }

    fn compress(&mut self, block: &[u8; 128]) {
        let k = k512();
        let mut w = [0u64; 80];
        for (i, c) in block.chunks_exact(8).enumerate() {
            w[i] = u64::from_be_bytes(c.try_into().unwrap());
        }
        for t in 16..80 {
            let s0 = w[t - 15].rotate_right(1) ^ w[t - 15].rotate_right(8) ^ (w[t - 15] >> 7);
            let s1 = w[t - 2].rotate_right(19) ^ w[t - 2].rotate_right(61) ^ (w[t - 2] >> 6);
            w[t] = w[t - 16]
                .wrapping_add(s0)
                .wrapping_add(w[t - 7])
                .wrapping_add(s1);
        }
        let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut h] = self.state;
        for t in 0..80 {
            let s1 = e.rotate_right(14) ^ e.rotate_right(18) ^ e.rotate_right(41);
            let ch = (e & f) ^ (!e & g);
            let t1 = h
                .wrapping_add(s1)
                .wrapping_add(ch)
                .wrapping_add(k[t])
                .wrapping_add(w[t]);
            let s0 = a.rotate_right(28) ^ a.rotate_right(34) ^ a.rotate_right(39);
            let maj = (a & b) ^ (a & c) ^ (b & c);
            let t2 = s0.wrapping_add(maj);
            h = g;
            g = f;
            f = e;
            e = d.wrapping_add(t1);
            d = c;
            c = b;
            b = a;
            a = t1.wrapping_add(t2);
        }
        for (s, v) in self.state.iter_mut().zip([a, b, c, d, e, f, g, h]) {
            *s = s.wrapping_add(v);
        }
    }
}

/// One-shot SHA-512.
pub fn sha512(data: &[u8]) -> [u8; 64] {
    let mut h = Sha512::new();
    h.update(data);
    h.finalize()
}

// ---------------------------------------------------------------------------
// Hex
// ---------------------------------------------------------------------------

/// Lowercase hex encoding.
pub fn to_hex(bytes: &[u8]) -> String {
    let mut s = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        s.push(char::from_digit((b >> 4) as u32, 16).unwrap());
        s.push(char::from_digit((b & 0xf) as u32, 16).unwrap());
    }
    s
}

/// Hex decoding (case-insensitive; even length required).
pub fn from_hex(s: &str) -> Result<Vec<u8>, String> {
    if s.len() % 2 != 0 {
        return Err(format!("odd-length hex string ({} chars)", s.len()));
    }
    let b = s.as_bytes();
    let mut out = Vec::with_capacity(b.len() / 2);
    for pair in b.chunks_exact(2) {
        let hi = (pair[0] as char).to_digit(16);
        let lo = (pair[1] as char).to_digit(16);
        match (hi, lo) {
            (Some(h), Some(l)) => out.push(((h << 4) | l) as u8),
            _ => return Err(format!("invalid hex byte {:?}", pair)),
        }
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Streaming-hash reader
// ---------------------------------------------------------------------------

/// A reader that SHA-256-digests every byte as it passes through, so a
/// file is hashed in the same pass that loads it — never buffered twice.
pub struct HashingReader<R: Read> {
    inner: R,
    hasher: Sha256,
    count: u64,
}

impl<R: Read> HashingReader<R> {
    pub fn new(inner: R) -> Self {
        HashingReader { inner, hasher: Sha256::new(), count: 0 }
    }

    /// Digest (lowercase hex) and byte count of everything read so far.
    pub fn finalize(self) -> (String, u64) {
        (to_hex(&self.hasher.finalize()), self.count)
    }
}

impl<R: Read> Read for HashingReader<R> {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        let n = self.inner.read(buf)?;
        self.hasher.update(&buf[..n]);
        self.count += n as u64;
        Ok(n)
    }
}

/// Expected digest of one artifact file, as recorded by the repository
/// manifest. `name` is the manifest-relative path — every mismatch error
/// names the offending file plus both digests.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ExpectedDigest {
    pub name: String,
    pub sha256: String,
    pub size: u64,
}

impl ExpectedDigest {
    /// Compare an observed digest/size against the manifest record.
    pub fn check(&self, got_sha256: &str, got_size: u64) -> Result<(), String> {
        if got_size != self.size {
            return Err(format!(
                "digest mismatch for {}: expected {} bytes (sha256 {}), got {} bytes",
                self.name, self.size, self.sha256, got_size
            ));
        }
        if got_sha256 != self.sha256 {
            return Err(format!(
                "digest mismatch for {}: expected sha256 {}, actual sha256 {}",
                self.name, self.sha256, got_sha256
            ));
        }
        Ok(())
    }
}

/// Streaming digest of a file in fixed-size chunks (no whole-file buffer):
/// `(sha256 hex, size in bytes)`.
pub fn hash_file(path: &Path) -> io::Result<(String, u64)> {
    let mut r = HashingReader::new(std::fs::File::open(path)?);
    let mut chunk = [0u8; 64 * 1024];
    loop {
        let n = r.read(&mut chunk)?;
        if n == 0 {
            break;
        }
    }
    Ok(r.finalize())
}

/// Read a whole file through the hashing reader: one buffer, digested as
/// it fills. Returns `(bytes, sha256 hex, size)`.
pub fn read_file_hashed(path: &Path) -> io::Result<(Vec<u8>, String, u64)> {
    let f = std::fs::File::open(path)?;
    let hint = f.metadata().map(|m| m.len() as usize).unwrap_or(0);
    let mut r = HashingReader::new(f);
    let mut buf = Vec::with_capacity(hint.min(1 << 30));
    r.read_to_end(&mut buf)?;
    let (hex, size) = r.finalize();
    Ok((buf, hex, size))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derived_constants_match_fips() {
        assert_eq!(k256()[0], 0x428a2f98);
        assert_eq!(k256()[63], 0xc67178f2);
        assert_eq!(h256()[0], 0x6a09e667);
        assert_eq!(h256()[7], 0x5be0cd19);
        assert_eq!(k512()[0], 0x428a2f98d728ae22);
        assert_eq!(k512()[79], 0x6c44198c4a475817);
        assert_eq!(h512()[0], 0x6a09e667f3bcc908);
    }

    #[test]
    fn sha256_known_answers() {
        assert_eq!(
            sha256_hex(b""),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
        assert_eq!(
            sha256_hex(b"abc"),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
        assert_eq!(
            sha256_hex(b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq"),
            "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1"
        );
        // One million 'a's, streamed in awkward chunk sizes.
        let mut h = Sha256::new();
        let chunk = [b'a'; 997];
        let mut left = 1_000_000usize;
        while left > 0 {
            let n = left.min(chunk.len());
            h.update(&chunk[..n]);
            left -= n;
        }
        assert_eq!(
            to_hex(&h.finalize()),
            "cdc76e5c9914fb9281a1c7e284d73e67f1809a48a497200e046d39ccc7112cd0"
        );
    }

    #[test]
    fn sha512_known_answers() {
        assert_eq!(
            to_hex(&sha512(b"abc")),
            "ddaf35a193617abacc417349ae20413112e6fa4e89a97ea20a9eeee64b55d39a\
             2192992a274fc1a836ba3c23a3feebbd454d4423643ce80e2a9ac94fa54ca49f"
                .replace(char::is_whitespace, "")
        );
        assert_eq!(
            to_hex(&sha512(b"")),
            "cf83e1357eefb8bdf1542850d66d8007d620e4050b5715dc83f4a921d36ce9ce\
             47d0d13c5d85f2b0ff8318d2877eec2f63b931bd47417a81a538327af927da3e"
                .replace(char::is_whitespace, "")
        );
    }

    #[test]
    fn hex_roundtrip_and_errors() {
        assert_eq!(from_hex("00ff10").unwrap(), vec![0, 255, 16]);
        assert_eq!(to_hex(&[0, 255, 16]), "00ff10");
        assert!(from_hex("0").is_err());
        assert!(from_hex("zz").is_err());
    }

    #[test]
    fn hashing_reader_matches_one_shot() {
        let data: Vec<u8> = (0..10_000u32).map(|i| (i * 7 + 3) as u8).collect();
        let mut r = HashingReader::new(&data[..]);
        let mut out = Vec::new();
        r.read_to_end(&mut out).unwrap();
        let (hex, n) = r.finalize();
        assert_eq!(out, data);
        assert_eq!(n, data.len() as u64);
        assert_eq!(hex, sha256_hex(&data));
    }
}
