//! Dependency-free ed25519 (RFC 8032) — the signature over the artifact
//! repository manifest (`runtime/repo.rs`).
//!
//! Scope: artifact-manifest signing and verification only. The
//! implementation favours obvious correctness over speed — field
//! exponentiation and scalar reduction are plain square-and-multiply and
//! binary long division — and is **not constant-time**. That is the right
//! trade-off here: verification hashes public data, and the committed dev
//! signing key is not a secret (deployments supply their own key to
//! `python -m compile.sign` and pass the public half via `--trusted-key`).
//! Pinned by the RFC 8032 test vectors below; the Python exporter
//! (`python/compile/ed25519.py`) implements the same scheme and the two
//! are cross-checked in CI by verifying the python-signed committed
//! manifest here.

use crate::util::hash::sha512;
use std::sync::OnceLock;

// ---------------------------------------------------------------------------
// Field arithmetic mod p = 2^255 - 19, radix-51 limbs.
// ---------------------------------------------------------------------------

const MASK51: u64 = (1 << 51) - 1;

/// `p - 2` (inversion exponent), little-endian bytes.
const PM2: [u8; 32] = {
    let mut e = [0xffu8; 32];
    e[0] = 0xeb;
    e[31] = 0x7f;
    e
};
/// `(p - 5) / 8 = 2^252 - 3` (square-root exponent), little-endian bytes.
const P58: [u8; 32] = {
    let mut e = [0xffu8; 32];
    e[0] = 0xfd;
    e[31] = 0x0f;
    e
};
/// `(p - 1) / 4 = 2^253 - 5`: `2^((p-1)/4)` is a square root of -1.
const PM14: [u8; 32] = {
    let mut e = [0xffu8; 32];
    e[0] = 0xfb;
    e[31] = 0x1f;
    e
};

#[derive(Clone, Copy, Debug)]
struct Fe([u64; 5]);

impl Fe {
    const ZERO: Fe = Fe([0; 5]);
    const ONE: Fe = Fe([1, 0, 0, 0, 0]);

    fn from_u64(v: u64) -> Fe {
        Fe([v & MASK51, v >> 51, 0, 0, 0])
    }

    /// Little-endian 32 bytes; bit 255 ignored (it carries the point's
    /// x-sign in the encoding).
    fn from_bytes(b: &[u8; 32]) -> Fe {
        let le = |r: std::ops::Range<usize>| {
            let mut v = [0u8; 8];
            v.copy_from_slice(&b[r]);
            u64::from_le_bytes(v)
        };
        Fe([
            le(0..8) & MASK51,
            (le(6..14) >> 3) & MASK51,
            (le(12..20) >> 6) & MASK51,
            (le(19..27) >> 1) & MASK51,
            (le(24..32) >> 12) & MASK51,
        ])
    }

    fn carry(mut self) -> Fe {
        let f = &mut self.0;
        for i in 0..4 {
            let c = f[i] >> 51;
            f[i] &= MASK51;
            f[i + 1] += c;
        }
        let c = f[4] >> 51;
        f[4] &= MASK51;
        f[0] += 19 * c;
        self
    }

    /// Fully reduced canonical little-endian encoding.
    fn to_bytes(self) -> [u8; 32] {
        let mut f = self.carry().carry().0;
        // f < 2p here; subtract p when f >= p by adding 19 and checking
        // the carry off bit 255.
        let mut q = (f[0] + 19) >> 51;
        for limb in f.iter().take(5).skip(1) {
            q = (limb + q) >> 51;
        }
        f[0] += 19 * q;
        for i in 0..4 {
            let c = f[i] >> 51;
            f[i] &= MASK51;
            f[i + 1] += c;
        }
        f[4] &= MASK51;
        let mut out = [0u8; 32];
        let words = [
            f[0] | (f[1] << 51),
            (f[1] >> 13) | (f[2] << 38),
            (f[2] >> 26) | (f[3] << 25),
            (f[3] >> 39) | (f[4] << 12),
        ];
        for (i, w) in words.iter().enumerate() {
            out[8 * i..8 * i + 8].copy_from_slice(&w.to_le_bytes());
        }
        out
    }

    fn add(self, o: Fe) -> Fe {
        let mut f = self.0;
        for i in 0..5 {
            f[i] += o.0[i];
        }
        Fe(f).carry()
    }

    fn sub(self, o: Fe) -> Fe {
        // self + 2p - o keeps every limb non-negative.
        const TWO_P: [u64; 5] = [
            0xfffffffffffda,
            0xffffffffffffe,
            0xffffffffffffe,
            0xffffffffffffe,
            0xffffffffffffe,
        ];
        let mut f = self.0;
        for i in 0..5 {
            f[i] = f[i] + TWO_P[i] - o.0[i];
        }
        Fe(f).carry()
    }

    fn neg(self) -> Fe {
        Fe::ZERO.sub(self)
    }

    fn mul(self, o: Fe) -> Fe {
        let a: Vec<u128> = self.0.iter().map(|&x| x as u128).collect();
        let b: Vec<u128> = o.0.iter().map(|&x| x as u128).collect();
        let mut t = [0u128; 5];
        t[0] = a[0] * b[0] + 19 * (a[1] * b[4] + a[2] * b[3] + a[3] * b[2] + a[4] * b[1]);
        t[1] = a[0] * b[1] + a[1] * b[0] + 19 * (a[2] * b[4] + a[3] * b[3] + a[4] * b[2]);
        t[2] = a[0] * b[2] + a[1] * b[1] + a[2] * b[0] + 19 * (a[3] * b[4] + a[4] * b[3]);
        t[3] = a[0] * b[3] + a[1] * b[2] + a[2] * b[1] + a[3] * b[0] + 19 * (a[4] * b[4]);
        t[4] = a[0] * b[4] + a[1] * b[3] + a[2] * b[2] + a[3] * b[1] + a[4] * b[0];
        let mut r = [0u64; 5];
        let mut c: u128 = 0;
        for i in 0..5 {
            let v = t[i] + c;
            r[i] = (v as u64) & MASK51;
            c = v >> 51;
        }
        r[0] += 19 * (c as u64);
        Fe(r).carry()
    }

    fn square(self) -> Fe {
        self.mul(self)
    }

    fn pow(self, e: &[u8; 32]) -> Fe {
        let mut r = Fe::ONE;
        for i in (0..256).rev() {
            r = r.square();
            if (e[i / 8] >> (i % 8)) & 1 == 1 {
                r = r.mul(self);
            }
        }
        r
    }

    fn invert(self) -> Fe {
        self.pow(&PM2)
    }

    fn is_negative(self) -> bool {
        self.to_bytes()[0] & 1 == 1
    }

    fn is_zero(self) -> bool {
        self.to_bytes() == [0u8; 32]
    }

    fn eq(self, o: Fe) -> bool {
        self.to_bytes() == o.to_bytes()
    }
}

// ---------------------------------------------------------------------------
// Curve points: extended twisted Edwards coordinates (X, Y, Z, T).
// ---------------------------------------------------------------------------

struct Consts {
    d: Fe,
    d2: Fe,
    sqrtm1: Fe,
    base: Point,
}

fn consts() -> &'static Consts {
    static C: OnceLock<Consts> = OnceLock::new();
    C.get_or_init(|| {
        // d = -121665 / 121666 mod p.
        let d = Fe::from_u64(121665).neg().mul(Fe::from_u64(121666).invert());
        let sqrtm1 = Fe::from_u64(2).pow(&PM14);
        // Base point: y = 4/5, x recovered with even ("positive") sign.
        let y = Fe::from_u64(4).mul(Fe::from_u64(5).invert());
        let base = decompress_with(&y.to_bytes(), d, sqrtm1)
            .expect("ed25519 base point must decompress");
        Consts { d, d2: d.add(d), sqrtm1, base }
    })
}

#[derive(Clone, Copy)]
struct Point {
    x: Fe,
    y: Fe,
    z: Fe,
    t: Fe,
}

impl Point {
    const IDENTITY: Point = Point { x: Fe::ZERO, y: Fe::ONE, z: Fe::ONE, t: Fe::ZERO };

    /// add-2008-hwcd-3 (complete for a = -1 twisted Edwards).
    fn add(&self, q: &Point) -> Point {
        let a = self.y.sub(self.x).mul(q.y.sub(q.x));
        let b = self.y.add(self.x).mul(q.y.add(q.x));
        let c = self.t.mul(q.t).mul(consts().d2);
        let zz = self.z.mul(q.z);
        let d = zz.add(zz);
        let e = b.sub(a);
        let f = d.sub(c);
        let g = d.add(c);
        let h = b.add(a);
        Point { x: e.mul(f), y: g.mul(h), z: f.mul(g), t: e.mul(h) }
    }

    fn double(&self) -> Point {
        let a = self.x.square();
        let b = self.y.square();
        let c2 = self.z.square();
        let c = c2.add(c2);
        let h = a.add(b);
        let e = h.sub(self.x.add(self.y).square());
        let g = a.sub(b);
        let f = c.add(g);
        Point { x: e.mul(f), y: g.mul(h), z: f.mul(g), t: e.mul(h) }
    }

    /// Double-and-add over the 256-bit little-endian scalar.
    fn scalar_mul(&self, scalar: &[u8; 32]) -> Point {
        let mut r = Point::IDENTITY;
        for i in (0..256).rev() {
            r = r.double();
            if (scalar[i / 8] >> (i % 8)) & 1 == 1 {
                r = r.add(self);
            }
        }
        r
    }

    fn compress(&self) -> [u8; 32] {
        let zi = self.z.invert();
        let x = self.x.mul(zi);
        let y = self.y.mul(zi);
        let mut b = y.to_bytes();
        b[31] |= (x.is_negative() as u8) << 7;
        b
    }
}

fn decompress_with(b: &[u8; 32], d: Fe, sqrtm1: Fe) -> Option<Point> {
    let sign = (b[31] >> 7) == 1;
    let y = Fe::from_bytes(b);
    let y2 = y.square();
    let u = y2.sub(Fe::ONE);
    let v = y2.mul(d).add(Fe::ONE);
    // Candidate root: x = u * v^3 * (u * v^7)^((p-5)/8).
    let v3 = v.square().mul(v);
    let v7 = v3.square().mul(v);
    let mut x = u.mul(v7).pow(&P58).mul(u).mul(v3);
    let vxx = v.mul(x.square());
    if !vxx.eq(u) {
        if vxx.eq(u.neg()) {
            x = x.mul(sqrtm1);
        } else {
            return None;
        }
    }
    if x.is_zero() && sign {
        return None;
    }
    if x.is_negative() != sign {
        x = x.neg();
    }
    Some(Point { x, y, z: Fe::ONE, t: x.mul(y) })
}

fn decompress(b: &[u8; 32]) -> Option<Point> {
    let c = consts();
    decompress_with(b, c.d, c.sqrtm1)
}

// ---------------------------------------------------------------------------
// Scalar arithmetic mod L = 2^252 + 27742317777372353535851937790883648493.
// ---------------------------------------------------------------------------

const L: [u64; 4] = [0x5812631a5cf5d3ed, 0x14def9dea2f79cd6, 0, 0x1000000000000000];

fn u256_cmp(a: &[u64; 4], b: &[u64; 4]) -> std::cmp::Ordering {
    for i in (0..4).rev() {
        match a[i].cmp(&b[i]) {
            std::cmp::Ordering::Equal => {}
            ord => return ord,
        }
    }
    std::cmp::Ordering::Equal
}

fn u256_sub(a: &mut [u64; 4], b: &[u64; 4]) {
    let mut borrow = 0u64;
    for i in 0..4 {
        let (v, b1) = a[i].overflowing_sub(b[i]);
        let (v, b2) = v.overflowing_sub(borrow);
        a[i] = v;
        borrow = (b1 | b2) as u64;
    }
}

/// 512-bit little-endian limbs mod L via binary long division: r stays
/// `< L < 2^253`, so the shift never overflows 256 bits.
fn mod_l(wide: &[u64; 8]) -> [u64; 4] {
    let mut r = [0u64; 4];
    for i in (0..512).rev() {
        let mut carry = (wide[i / 64] >> (i % 64)) & 1;
        for limb in r.iter_mut() {
            let top = *limb >> 63;
            *limb = (*limb << 1) | carry;
            carry = top;
        }
        if u256_cmp(&r, &L) != std::cmp::Ordering::Less {
            u256_sub(&mut r, &L);
        }
    }
    r
}

fn limbs_from_le(bytes: &[u8]) -> Vec<u64> {
    bytes
        .chunks(8)
        .map(|c| {
            let mut v = [0u8; 8];
            v[..c.len()].copy_from_slice(c);
            u64::from_le_bytes(v)
        })
        .collect()
}

fn limbs_to_le32(limbs: &[u64; 4]) -> [u8; 32] {
    let mut out = [0u8; 32];
    for (i, l) in limbs.iter().enumerate() {
        out[8 * i..8 * i + 8].copy_from_slice(&l.to_le_bytes());
    }
    out
}

/// 64-byte little-endian value reduced mod L.
fn sc_reduce(h: &[u8; 64]) -> [u8; 32] {
    let limbs = limbs_from_le(h);
    let wide: [u64; 8] = limbs.try_into().unwrap();
    limbs_to_le32(&mod_l(&wide))
}

/// `(a * b + c) mod L` over 32-byte little-endian scalars.
fn sc_muladd(a: &[u8; 32], b: &[u8; 32], c: &[u8; 32]) -> [u8; 32] {
    let al = limbs_from_le(a);
    let bl = limbs_from_le(b);
    let mut wide = [0u64; 8];
    for (i, &x) in al.iter().enumerate() {
        let mut carry = 0u128;
        for (j, &y) in bl.iter().enumerate() {
            let t = wide[i + j] as u128 + x as u128 * y as u128 + carry;
            wide[i + j] = t as u64;
            carry = t >> 64;
        }
        wide[i + 4] = carry as u64;
    }
    let cl = limbs_from_le(c);
    let mut carry = 0u128;
    for i in 0..8 {
        let t = wide[i] as u128 + cl.get(i).copied().unwrap_or(0) as u128 + carry;
        wide[i] = t as u64;
        carry = t >> 64;
    }
    limbs_to_le32(&mod_l(&wide))
}

fn sc_in_range(s: &[u8; 32]) -> bool {
    let limbs: [u64; 4] = limbs_from_le(s).try_into().unwrap();
    u256_cmp(&limbs, &L) == std::cmp::Ordering::Less
}

// ---------------------------------------------------------------------------
// Public API
// ---------------------------------------------------------------------------

/// Secret scalar + prefix from the 32-byte seed (RFC 8032 §5.1.5).
fn expand_seed(seed: &[u8; 32]) -> ([u8; 32], [u8; 32]) {
    let h = sha512(seed);
    let mut a = [0u8; 32];
    a.copy_from_slice(&h[..32]);
    a[0] &= 248;
    a[31] &= 127;
    a[31] |= 64;
    let mut prefix = [0u8; 32];
    prefix.copy_from_slice(&h[32..]);
    (a, prefix)
}

/// Public key for a 32-byte seed.
pub fn public_key(seed: &[u8; 32]) -> [u8; 32] {
    let (a, _) = expand_seed(seed);
    consts().base.scalar_mul(&a).compress()
}

/// Sign `msg` with the 32-byte seed; returns the 64-byte signature `R || S`.
pub fn sign(seed: &[u8; 32], msg: &[u8]) -> [u8; 64] {
    let (a, prefix) = expand_seed(seed);
    let a_pub = consts().base.scalar_mul(&a).compress();
    let mut h = crate::util::hash::Sha512::new();
    h.update(&prefix);
    h.update(msg);
    let r = sc_reduce(&h.finalize());
    let r_point = consts().base.scalar_mul(&r).compress();
    let mut h = crate::util::hash::Sha512::new();
    h.update(&r_point);
    h.update(&a_pub);
    h.update(msg);
    let k = sc_reduce(&h.finalize());
    let s = sc_muladd(&k, &a, &r);
    let mut sig = [0u8; 64];
    sig[..32].copy_from_slice(&r_point);
    sig[32..].copy_from_slice(&s);
    sig
}

/// Verify a 64-byte signature over `msg` against a 32-byte public key.
pub fn verify(public: &[u8; 32], msg: &[u8], sig: &[u8; 64]) -> Result<(), String> {
    let mut r_bytes = [0u8; 32];
    r_bytes.copy_from_slice(&sig[..32]);
    let mut s = [0u8; 32];
    s.copy_from_slice(&sig[32..]);
    if !sc_in_range(&s) {
        return Err("signature scalar S out of range".into());
    }
    let a = decompress(public).ok_or("public key is not a valid curve point")?;
    let r = decompress(&r_bytes).ok_or("signature R is not a valid curve point")?;
    let mut h = crate::util::hash::Sha512::new();
    h.update(&r_bytes);
    h.update(public);
    h.update(msg);
    let k = sc_reduce(&h.finalize());
    // Unbatched RFC 8032 check: [S]B == R + [k]A, compared in affine
    // encoding.
    let lhs = consts().base.scalar_mul(&s).compress();
    let rhs = r.add(&a.scalar_mul(&k)).compress();
    if lhs == rhs {
        Ok(())
    } else {
        Err("signature does not verify".into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::hash::{from_hex, to_hex};

    fn seed32(hex: &str) -> [u8; 32] {
        from_hex(hex).unwrap().try_into().unwrap()
    }

    // RFC 8032 §7.1 TEST 1–3.
    const V1_SEED: &str = "9d61b19deffd5a60ba844af492ec2cc44449c5697b326919703bac031cae7f60";
    const V1_PUB: &str = "d75a980182b10ab7d54bfed3c964073a0ee172f3daa62325af021a68f707511a";
    const V1_SIG: &str = "e5564300c360ac729086e2cc806e828a84877f1eb8e5d974d873e065224901555fb8821590a33bacc61e39701cf9b46bd25bf5f0595bbe24655141438e7a100b";
    const V2_SEED: &str = "4ccd089b28ff96da9db6c346ec114e0f5b8a319f35aba624da8cf6ed4fb8a6fb";
    const V2_PUB: &str = "3d4017c3e843895a92b70aa74d1b7ebc9c982ccf2ec4968cc0cd55f12af4660c";
    const V2_SIG: &str = "92a009a9f0d4cab8720e820b5f642540a2b27b5416503f8fb3762223ebdb69da085ac1e43e15996e458f3613d0f11d8c387b2eaeb4302aeeb00d291612bb0c00";
    const V3_SEED: &str = "c5aa8df43f9f837bedb7442f31dcb7b166d38535076f094b85ce3a2e0b4458f7";
    const V3_PUB: &str = "fc51cd8e6218a1a38da47ed00230f0580816ed13ba3303ac5deb911548908025";
    const V3_SIG: &str = "6291d657deec24024827e69c3abe01a30ce548a284743a445e3680d7db5ac3ac18ff9b538d16f290ae67f760984dc6594a7c15e9716ed28dc027beceea1ec40a";

    #[test]
    fn rfc8032_vectors() {
        for (seed, pk, msg, sig) in [
            (V1_SEED, V1_PUB, &b""[..], V1_SIG),
            (V2_SEED, V2_PUB, &b"\x72"[..], V2_SIG),
            (V3_SEED, V3_PUB, &b"\xaf\x82"[..], V3_SIG),
        ] {
            let seed = seed32(seed);
            assert_eq!(to_hex(&public_key(&seed)), pk);
            let s = sign(&seed, msg);
            assert_eq!(to_hex(&s), sig);
            let pk: [u8; 32] = from_hex(pk).unwrap().try_into().unwrap();
            verify(&pk, msg, &s).unwrap();
        }
    }

    #[test]
    fn tampering_fails_verification() {
        let seed = seed32(V3_SEED);
        let pk = public_key(&seed);
        let msg = b"artifact manifest revision 7";
        let sig = sign(&seed, msg);
        verify(&pk, msg, &sig).unwrap();
        // Flip one bit anywhere in the signature.
        for i in [0usize, 17, 31, 32, 48, 63] {
            let mut bad = sig;
            bad[i] ^= 1;
            assert!(verify(&pk, msg, &bad).is_err(), "bit flip at byte {i} accepted");
        }
        // Flip one bit in the message.
        let mut bad_msg = msg.to_vec();
        bad_msg[3] ^= 0x20;
        assert!(verify(&pk, &bad_msg, &sig).is_err());
        // Wrong key.
        let other = public_key(&seed32(V1_SEED));
        assert!(verify(&other, msg, &sig).is_err());
    }

    #[test]
    fn sign_verify_roundtrip_misc_seeds() {
        for i in 0u8..4 {
            let mut seed = [i; 32];
            seed[0] = i.wrapping_mul(37).wrapping_add(1);
            let pk = public_key(&seed);
            let msg = vec![i; 100 + i as usize * 13];
            let sig = sign(&seed, &msg);
            verify(&pk, &msg, &sig).unwrap();
        }
    }
}
