//! Deterministic PRNG (SplitMix64 + xoshiro256**) — the `rand` crate is not
//! in the offline vendor set. Used by the workload generators, the batcher's
//! jitter injection in benches, and the property-testing harness.

/// xoshiro256** seeded via SplitMix64 — fast, high-quality, reproducible.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the xoshiro state.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in `[0, n)`. Uses rejection sampling to avoid modulo bias.
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0);
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return v % n;
            }
        }
    }

    /// Uniform integer in `[lo, hi)`.
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// True with probability `p`.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Exponentially distributed with mean `mean` (Poisson inter-arrivals
    /// for the serving workload generator).
    pub fn exp(&mut self, mean: f64) -> f64 {
        let u = 1.0 - self.f64(); // (0, 1]
        -mean * u.ln()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)`.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(3);
        let mut sum = 0.0;
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn exp_mean() {
        let mut r = Rng::new(11);
        let mean = 4.0;
        let sum: f64 = (0..20_000).map(|_| r.exp(mean)).sum();
        let m = sum / 20_000.0;
        assert!((m - mean).abs() < 0.2, "mean {m}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<usize> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(9);
        let s = r.sample_indices(20, 10);
        let mut d = s.clone();
        d.sort();
        d.dedup();
        assert_eq!(d.len(), 10);
    }
}
