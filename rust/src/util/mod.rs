//! From-scratch substrates: the offline vendor set ships only the `xla`
//! crate's dependency closure, so JSON, CLI parsing, PRNG, statistics,
//! logging and npz/npy IO are implemented here.

pub mod cli;
pub mod ed25519;
pub mod epoll;
pub mod hash;
pub mod json;
pub mod log;
pub mod npz;
pub mod prng;
pub mod signal;
pub mod stats;
