//! SIGINT/SIGTERM shutdown flag (libc crate is not vendored; the two
//! symbols needed are declared directly against the platform libc).
//!
//! The handler only sets an atomic — the one operation that is
//! unconditionally async-signal-safe. Callers poll
//! [`shutdown_requested`] from a normal thread and run their actual
//! teardown (wake the accept loop, drain the coordinator) there.

use std::sync::atomic::{AtomicBool, Ordering};

static SIGNALED: AtomicBool = AtomicBool::new(false);

extern "C" fn on_signal(_signum: i32) {
    SIGNALED.store(true, Ordering::SeqCst);
}

#[cfg(unix)]
extern "C" {
    /// `sighandler_t signal(int signum, sighandler_t handler)` — the
    /// return value (previous handler) is opaque here and ignored.
    fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
}

#[cfg(unix)]
const SIGINT: i32 = 2;
#[cfg(unix)]
const SIGTERM: i32 = 15;

/// Install the flag-setting handler for SIGINT and SIGTERM. Idempotent.
/// On non-unix targets this is a no-op (the flag then never trips and
/// shutdown happens by process kill, as before).
pub fn install_shutdown_handler() {
    #[cfg(unix)]
    unsafe {
        signal(SIGINT, on_signal);
        signal(SIGTERM, on_signal);
    }
}

/// True once a shutdown signal has been received.
pub fn shutdown_requested() -> bool {
    SIGNALED.load(Ordering::SeqCst)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn handler_sets_flag() {
        // Call the handler directly — raising a real SIGINT would tear
        // down the whole test harness.
        on_signal(2);
        assert!(shutdown_requested());
    }
}
