//! Descriptive statistics + latency histograms for the bench harness and
//! the coordinator's metrics (criterion/hdrhistogram are not vendored).

/// Summary of a sample of measurements (times in seconds or any unit).
#[derive(Debug, Clone, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "empty sample");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>()
            / (n.max(2) - 1) as f64;
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 0.50),
            p90: percentile_sorted(&sorted, 0.90),
            p99: percentile_sorted(&sorted, 0.99),
        }
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (sorted[hi] - sorted[lo]) * (pos - lo as f64)
    }
}

/// Log-bucketed latency histogram: ~4% relative precision from 1us to ~18h,
/// constant memory, O(1) record. Good enough for p50/p90/p99 reporting.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    buckets: Vec<u64>,
    count: u64,
    sum_us: u64,
    max_us: u64,
}

const BUCKETS_PER_OCTAVE: usize = 16;
const NUM_BUCKETS: usize = 1024; // exact below 16us, ~6% buckets to 2^63 us

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        LatencyHistogram { buckets: vec![0; NUM_BUCKETS], count: 0, sum_us: 0, max_us: 0 }
    }

    fn bucket_of(us: u64) -> usize {
        // Exact buckets below 16; one bucket per 1/16 octave above.
        if us < 16 {
            return us as usize;
        }
        let log2 = 63 - us.leading_zeros() as usize;
        let frac = ((us >> (log2 - 4)) & 0xF) as usize;
        (16 + (log2 - 4) * BUCKETS_PER_OCTAVE + frac).min(NUM_BUCKETS - 1)
    }

    fn bucket_value(i: usize) -> u64 {
        if i < 16 {
            return i as u64;
        }
        let log2 = (i - 16) / BUCKETS_PER_OCTAVE + 4;
        let frac = ((i - 16) % BUCKETS_PER_OCTAVE) as u64;
        (1u64 << log2) + (frac << (log2 - 4))
    }

    pub fn record_us(&mut self, us: u64) {
        self.buckets[Self::bucket_of(us)] += 1;
        self.count += 1;
        self.sum_us += us;
        self.max_us = self.max_us.max(us);
    }

    pub fn record(&mut self, d: std::time::Duration) {
        self.record_us(d.as_micros() as u64);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }

    pub fn max_us(&self) -> u64 {
        self.max_us
    }

    /// Approximate quantile in microseconds.
    pub fn quantile_us(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let target = (q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64;
        let mut seen = 0;
        for (i, c) in self.buckets.iter().enumerate() {
            seen += c;
            if seen >= target.max(1) {
                return Self::bucket_value(i);
            }
        }
        self.max_us
    }

    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += b;
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
        self.max_us = self.max_us.max(other.max_us);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basics() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let v = [0.0, 10.0];
        assert!((percentile_sorted(&v, 0.5) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_quantiles_are_close() {
        let mut h = LatencyHistogram::new();
        for us in 1..=10_000u64 {
            h.record_us(us);
        }
        let p50 = h.quantile_us(0.5) as f64;
        assert!((p50 - 5000.0).abs() / 5000.0 < 0.10, "p50 {p50}");
        let p99 = h.quantile_us(0.99) as f64;
        assert!((p99 - 9900.0).abs() / 9900.0 < 0.10, "p99 {p99}");
    }

    #[test]
    fn histogram_merge() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        a.record_us(100);
        b.record_us(200);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert!(a.mean_us() > 100.0);
    }

    #[test]
    fn bucket_roundtrip_monotone() {
        let mut prev = 0;
        for us in [0u64, 1, 2, 3, 10, 100, 1000, 123456, 10_000_000] {
            let b = LatencyHistogram::bucket_of(us);
            assert!(b >= prev, "bucket must be monotone in value");
            prev = b;
            let v = LatencyHistogram::bucket_value(b);
            if us > 4 {
                let rel = (v as f64 - us as f64).abs() / us as f64;
                assert!(rel < 0.07, "us={us} v={v} rel={rel}");
            }
        }
    }
}
