//! Readiness primitives for the event-driven serving edge: `epoll` and
//! `eventfd`, declared directly against the platform libc (the libc crate
//! is not vendored — same zero-dependency stance as [`super::signal`]).
//!
//! The surface is deliberately tiny: [`Epoll`] registers raw fds with a
//! `u64` token and level-triggered interest, [`EventFd`] is the cross-
//! thread wakeup the executor pool rings when a completion is ready for a
//! connection the loop owns, and [`fd_limit`]/[`open_fds`] are the
//! fd-pressure gauges the `stats` command reports. On non-Linux targets
//! everything compiles but [`Epoll::new`] fails with `Unsupported` — the
//! serving edge falls back to `--edge threads` there.

#[cfg(target_os = "linux")]
pub use linux::{Epoll, EventFd};

/// Readable readiness (level-triggered).
pub const EPOLLIN: u32 = 0x001;
/// Writable readiness.
pub const EPOLLOUT: u32 = 0x004;
/// Error condition (always reported, never needs registering).
pub const EPOLLERR: u32 = 0x008;
/// Peer hung up.
pub const EPOLLHUP: u32 = 0x010;
/// Peer shut down its write half (reported on Linux ≥ 2.6.17).
pub const EPOLLRDHUP: u32 = 0x2000;

/// One readiness notification: `events` is a mask of the `EPOLL*` bits,
/// `data` the token the fd was registered with. Field order and the
/// x86-64 packing quirk match the kernel ABI.
#[cfg_attr(target_arch = "x86_64", repr(C, packed))]
#[cfg_attr(not(target_arch = "x86_64"), repr(C))]
#[derive(Clone, Copy, Default)]
pub struct EpollEvent {
    pub events: u32,
    pub data: u64,
}

impl EpollEvent {
    /// Copy out the token (the struct may be packed; direct field refs of
    /// packed structs are unaligned).
    pub fn token(&self) -> u64 {
        let d = self.data;
        d
    }

    /// Copy out the readiness mask.
    pub fn mask(&self) -> u32 {
        let e = self.events;
        e
    }
}

#[cfg(target_os = "linux")]
mod linux {
    use super::EpollEvent;
    use std::io;
    use std::os::unix::io::RawFd;

    // Declared against glibc/musl directly; all of these set errno, which
    // `io::Error::last_os_error()` reads back.
    extern "C" {
        fn epoll_create1(flags: i32) -> i32;
        fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
        fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout: i32) -> i32;
        fn eventfd(initval: u32, flags: i32) -> i32;
        fn close(fd: i32) -> i32;
        fn read(fd: i32, buf: *mut u8, count: usize) -> isize;
        fn write(fd: i32, buf: *const u8, count: usize) -> isize;
    }

    const EPOLL_CLOEXEC: i32 = 0o2000000;
    const EPOLL_CTL_ADD: i32 = 1;
    const EPOLL_CTL_DEL: i32 = 2;
    const EPOLL_CTL_MOD: i32 = 3;
    const EFD_CLOEXEC: i32 = 0o2000000;
    const EFD_NONBLOCK: i32 = 0o4000;

    /// An epoll instance. Interest is level-triggered: a readable fd keeps
    /// reporting `EPOLLIN` until drained, so the loop can stop reading a
    /// connection (backpressure) and pick the buffered bytes up later.
    pub struct Epoll {
        fd: RawFd,
    }

    impl Epoll {
        pub fn new() -> io::Result<Epoll> {
            let fd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
            if fd < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(Epoll { fd })
        }

        fn ctl(&self, op: i32, fd: RawFd, token: u64, interest: u32) -> io::Result<()> {
            let mut ev = EpollEvent { events: interest, data: token };
            let rc = unsafe { epoll_ctl(self.fd, op, fd, &mut ev) };
            if rc < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(())
        }

        /// Register `fd` with the given interest mask and token.
        pub fn add(&self, fd: RawFd, token: u64, interest: u32) -> io::Result<()> {
            self.ctl(EPOLL_CTL_ADD, fd, token, interest)
        }

        /// Change a registered fd's interest mask.
        pub fn modify(&self, fd: RawFd, token: u64, interest: u32) -> io::Result<()> {
            self.ctl(EPOLL_CTL_MOD, fd, token, interest)
        }

        /// Deregister `fd`. Closing an fd deregisters it implicitly, but
        /// only once every duplicate is closed — explicit removal keeps the
        /// bookkeeping exact.
        pub fn delete(&self, fd: RawFd) -> io::Result<()> {
            self.ctl(EPOLL_CTL_DEL, fd, 0, 0)
        }

        /// Wait for readiness, filling `events`; `timeout_ms < 0` blocks
        /// indefinitely. A signal interruption reports as zero events
        /// rather than an error — callers loop anyway.
        pub fn wait(&self, events: &mut [EpollEvent], timeout_ms: i32) -> io::Result<usize> {
            let n = unsafe {
                epoll_wait(self.fd, events.as_mut_ptr(), events.len() as i32, timeout_ms)
            };
            if n < 0 {
                let e = io::Error::last_os_error();
                if e.kind() == io::ErrorKind::Interrupted {
                    return Ok(0);
                }
                return Err(e);
            }
            Ok(n as usize)
        }
    }

    impl Drop for Epoll {
        fn drop(&mut self) {
            unsafe { close(self.fd) };
        }
    }

    /// A nonblocking eventfd: any thread may [`EventFd::wake`] it; the
    /// event loop registers it for `EPOLLIN` and [`EventFd::drain`]s on
    /// wakeup. The counter semantics collapse any number of wakes into one
    /// readiness report — exactly the coalescing a completion pump wants.
    pub struct EventFd {
        fd: RawFd,
    }

    impl EventFd {
        pub fn new() -> io::Result<EventFd> {
            let fd = unsafe { eventfd(0, EFD_CLOEXEC | EFD_NONBLOCK) };
            if fd < 0 {
                return Err(io::Error::last_os_error());
            }
            Ok(EventFd { fd })
        }

        pub fn raw_fd(&self) -> RawFd {
            self.fd
        }

        /// Ring the fd. Infallible by design: the only failure mode of a
        /// nonblocking eventfd write is a saturated counter (EAGAIN), and
        /// a saturated counter is already awake.
        pub fn wake(&self) {
            let one: u64 = 1;
            unsafe { write(self.fd, (&one as *const u64).cast(), 8) };
        }

        /// Reset the counter so the next `wake` reports readiness again.
        pub fn drain(&self) {
            let mut buf = [0u8; 8];
            unsafe { read(self.fd, buf.as_mut_ptr(), 8) };
        }
    }

    impl Drop for EventFd {
        fn drop(&mut self) {
            unsafe { close(self.fd) };
        }
    }

    // eventfd writes/reads are plain syscalls on an owned fd.
    unsafe impl Send for EventFd {}
    unsafe impl Sync for EventFd {}
}

/// Soft limit on open fds (`RLIMIT_NOFILE`), the denominator of the
/// fd-pressure gauge. `None` where the platform offers no answer.
pub fn fd_limit() -> Option<u64> {
    #[cfg(target_os = "linux")]
    {
        #[repr(C)]
        struct Rlimit {
            cur: u64,
            max: u64,
        }
        extern "C" {
            fn getrlimit(resource: i32, rlim: *mut Rlimit) -> i32;
        }
        const RLIMIT_NOFILE: i32 = 7;
        let mut r = Rlimit { cur: 0, max: 0 };
        if unsafe { getrlimit(RLIMIT_NOFILE, &mut r) } == 0 {
            return Some(r.cur);
        }
        None
    }
    #[cfg(not(target_os = "linux"))]
    None
}

/// Open fds of this process, counted from `/proc/self/fd`. `None` off
/// Linux or when procfs is unavailable.
pub fn open_fds() -> Option<u64> {
    #[cfg(target_os = "linux")]
    {
        // The read_dir handle itself is one of the counted fds; subtract it.
        std::fs::read_dir("/proc/self/fd")
            .ok()
            .map(|d| d.count().saturating_sub(1) as u64)
    }
    #[cfg(not(target_os = "linux"))]
    None
}

#[cfg(all(test, target_os = "linux"))]
mod tests {
    use super::*;

    #[test]
    fn eventfd_wakes_epoll_and_coalesces() {
        let ep = Epoll::new().expect("epoll");
        let ef = EventFd::new().expect("eventfd");
        ep.add(ef.raw_fd(), 42, EPOLLIN).expect("add");

        let mut events = [EpollEvent::default(); 4];
        // Nothing rung yet: a zero-timeout wait reports no readiness.
        assert_eq!(ep.wait(&mut events, 0).expect("wait"), 0);

        // Multiple wakes coalesce into one readiness report.
        ef.wake();
        ef.wake();
        ef.wake();
        let n = ep.wait(&mut events, 1000).expect("wait");
        assert_eq!(n, 1);
        assert_eq!(events[0].token(), 42);
        assert!(events[0].mask() & EPOLLIN != 0);

        // Drained: readiness is gone until the next wake.
        ef.drain();
        assert_eq!(ep.wait(&mut events, 0).expect("wait"), 0);
        ef.wake();
        assert_eq!(ep.wait(&mut events, 1000).expect("wait"), 1);
    }

    #[test]
    fn modify_and_delete_interest() {
        let ep = Epoll::new().expect("epoll");
        let ef = EventFd::new().expect("eventfd");
        ep.add(ef.raw_fd(), 7, EPOLLIN).expect("add");
        ef.wake();

        // Interest masked off: a pending readable fd stops reporting.
        ep.modify(ef.raw_fd(), 7, 0).expect("modify");
        let mut events = [EpollEvent::default(); 4];
        assert_eq!(ep.wait(&mut events, 0).expect("wait"), 0);

        // Interest restored: level-triggered readiness reappears.
        ep.modify(ef.raw_fd(), 7, EPOLLIN).expect("modify");
        assert_eq!(ep.wait(&mut events, 1000).expect("wait"), 1);

        ep.delete(ef.raw_fd()).expect("delete");
        assert_eq!(ep.wait(&mut events, 0).expect("wait"), 0);
    }

    #[test]
    fn fd_gauges_report() {
        let limit = fd_limit().expect("rlimit on linux");
        let open = open_fds().expect("procfs on linux");
        assert!(limit > 0);
        assert!(open > 0, "at least stdio is open");
        assert!(open <= limit);
    }
}
