//! Tiny declarative CLI argument parser (clap is not vendored).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args and
//! auto-generated `--help`.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
struct Spec {
    name: String,
    help: String,
    takes_value: bool,
    default: Option<String>,
}

/// Declarative argument set: `Args::new("cmd").opt(...).flag(...).parse()`.
#[derive(Debug, Clone)]
pub struct Args {
    program: String,
    about: String,
    specs: Vec<Spec>,
    positional_help: Vec<(String, String)>,
}

#[derive(Debug, Clone)]
pub struct Parsed {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn new(program: &str, about: &str) -> Args {
        Args { program: program.into(), about: about.into(), specs: Vec::new(), positional_help: Vec::new() }
    }

    /// `--name <value>` option with an optional default.
    pub fn opt(mut self, name: &str, default: Option<&str>, help: &str) -> Self {
        self.specs.push(Spec {
            name: name.into(),
            help: help.into(),
            takes_value: true,
            default: default.map(String::from),
        });
        self
    }

    /// Boolean `--name` flag.
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.specs.push(Spec { name: name.into(), help: help.into(), takes_value: false, default: None });
        self
    }

    pub fn positional(mut self, name: &str, help: &str) -> Self {
        self.positional_help.push((name.into(), help.into()));
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {}", self.program, self.about, self.program);
        for (p, _) in &self.positional_help {
            s.push_str(&format!(" <{p}>"));
        }
        s.push_str(" [OPTIONS]\n\nOPTIONS:\n");
        for spec in &self.specs {
            let arg = if spec.takes_value {
                format!("--{} <v>", spec.name)
            } else {
                format!("--{}", spec.name)
            };
            let def = spec
                .default
                .as_ref()
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("  {arg:<24} {}{def}\n", spec.help));
        }
        for (p, h) in &self.positional_help {
            s.push_str(&format!("  <{p:<22}> {h}\n"));
        }
        s
    }

    pub fn parse_from<I: IntoIterator<Item = String>>(&self, argv: I) -> Result<Parsed, String> {
        let mut values = BTreeMap::new();
        let mut flags = Vec::new();
        let mut positional = Vec::new();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if a == "--help" || a == "-h" {
                return Err(self.usage());
            }
            if let Some(rest) = a.strip_prefix("--") {
                let (key, inline) = match rest.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| format!("unknown option --{key}\n\n{}", self.usage()))?;
                if spec.takes_value {
                    let v = match inline {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| format!("--{key} requires a value"))?,
                    };
                    values.insert(key, v);
                } else {
                    if inline.is_some() {
                        return Err(format!("--{key} takes no value"));
                    }
                    flags.push(key);
                }
            } else {
                positional.push(a);
            }
        }
        for spec in &self.specs {
            if spec.takes_value && !values.contains_key(&spec.name) {
                if let Some(d) = &spec.default {
                    values.insert(spec.name.clone(), d.clone());
                }
            }
        }
        Ok(Parsed { values, flags, positional })
    }

    pub fn parse(&self) -> Result<Parsed, String> {
        self.parse_from(std::env::args().skip(1))
    }
}

impl Parsed {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(String::as_str)
    }

    pub fn get_usize(&self, name: &str) -> Option<usize> {
        self.get(name).and_then(|v| v.parse().ok())
    }

    pub fn get_f64(&self, name: &str) -> Option<f64> {
        self.get(name).and_then(|v| v.parse().ok())
    }

    /// Comma-separated usize list, e.g. `--seq-buckets 16,32,64`. Empty
    /// string (or an unset option) yields None; a malformed element yields
    /// None so callers can reject rather than silently drop it.
    pub fn get_usize_list(&self, name: &str) -> Option<Vec<usize>> {
        let raw = self.get(name)?.trim();
        if raw.is_empty() {
            return None;
        }
        raw.split(',')
            .map(|p| p.trim().parse::<usize>().ok())
            .collect::<Option<Vec<usize>>>()
    }

    pub fn has(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args() -> Args {
        Args::new("t", "test")
            .opt("batch", Some("8"), "batch size")
            .opt("name", None, "a name")
            .flag("verbose", "more output")
            .positional("cmd", "subcommand")
    }

    fn sv(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_apply() {
        let p = args().parse_from(sv(&[])).unwrap();
        assert_eq!(p.get("batch"), Some("8"));
        assert_eq!(p.get("name"), None);
    }

    #[test]
    fn parses_forms() {
        let p = args()
            .parse_from(sv(&["serve", "--batch", "32", "--name=x", "--verbose"]))
            .unwrap();
        assert_eq!(p.get_usize("batch"), Some(32));
        assert_eq!(p.get("name"), Some("x"));
        assert!(p.has("verbose"));
        assert_eq!(p.positional, vec!["serve"]);
    }

    #[test]
    fn usize_list_parses_and_rejects_garbage() {
        let a = Args::new("t", "test").opt("seq-buckets", None, "buckets");
        let p = a.parse_from(sv(&["--seq-buckets", "16,32, 64"])).unwrap();
        assert_eq!(p.get_usize_list("seq-buckets"), Some(vec![16, 32, 64]));
        let p = a.parse_from(sv(&["--seq-buckets", "16,nope"])).unwrap();
        assert_eq!(p.get_usize_list("seq-buckets"), None);
        let p = a.parse_from(sv(&[])).unwrap();
        assert_eq!(p.get_usize_list("seq-buckets"), None);
    }

    #[test]
    fn unknown_rejected() {
        assert!(args().parse_from(sv(&["--nope"])).is_err());
    }

    #[test]
    fn help_is_usage() {
        let e = args().parse_from(sv(&["--help"])).unwrap_err();
        assert!(e.contains("USAGE"));
        assert!(e.contains("--batch"));
    }
}
