//! Leveled stderr logger with a process-relative timestamp.
//! Controlled by the `POWERBERT_LOG` env var: error|warn|info|debug (default info).

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
}

static LEVEL: AtomicU8 = AtomicU8::new(2);
static START: OnceLock<Instant> = OnceLock::new();

pub fn init() {
    START.get_or_init(Instant::now);
    if let Ok(v) = std::env::var("POWERBERT_LOG") {
        let lvl = match v.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "debug" => Level::Debug,
            _ => Level::Info,
        };
        LEVEL.store(lvl as u8, Ordering::Relaxed);
    }
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    (l as u8) <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(l: Level, target: &str, msg: &str) {
    if !enabled(l) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed();
    let tag = match l {
        Level::Error => "ERROR",
        Level::Warn => "WARN ",
        Level::Info => "INFO ",
        Level::Debug => "DEBUG",
    };
    eprintln!("[{:9.3}s {} {}] {}", t.as_secs_f64(), tag, target, msg);
}

#[macro_export]
macro_rules! info {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Info, $target, &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! warnln {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Warn, $target, &format!($($arg)*))
    };
}

#[macro_export]
macro_rules! debugln {
    ($target:expr, $($arg:tt)*) => {
        $crate::util::log::log($crate::util::log::Level::Debug, $target, &format!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_gating() {
        init();
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info);
    }
}
