//! Pure-Rust `.npz` / `.npy` reader — no zip or numpy crates in the offline
//! vendor set, and (since the native backend) no XLA runtime either.
//!
//! Scope is exactly what `np.savez` (uncompressed) emits and the artifact
//! contract needs: stored (method 0) zip members holding little-endian
//! C-order `.npy` arrays of f32/f64/i32/i64. Deflated members and Fortran
//! order are rejected with a clear error rather than mis-read. Zip64 size /
//! offset extensions (numpy writes members with `force_zip64`) are handled.

use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

/// Element payload of one array, in file dtype.
#[derive(Debug, Clone)]
pub enum NpyData {
    F32(Vec<f32>),
    F64(Vec<f64>),
    I32(Vec<i32>),
    I64(Vec<i64>),
}

impl NpyData {
    pub fn len(&self) -> usize {
        match self {
            NpyData::F32(v) => v.len(),
            NpyData::F64(v) => v.len(),
            NpyData::I32(v) => v.len(),
            NpyData::I64(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Lossy widening/narrowing view as f32 (weights are stored as f32;
    /// this tolerates f64 exports).
    pub fn to_f32(&self) -> Vec<f32> {
        match self {
            NpyData::F32(v) => v.clone(),
            NpyData::F64(v) => v.iter().map(|&x| x as f32).collect(),
            NpyData::I32(v) => v.iter().map(|&x| x as f32).collect(),
            NpyData::I64(v) => v.iter().map(|&x| x as f32).collect(),
        }
    }

    /// View as i32 (token / segment / kept-position arrays).
    pub fn to_i32(&self) -> Vec<i32> {
        match self {
            NpyData::F32(v) => v.iter().map(|&x| x as i32).collect(),
            NpyData::F64(v) => v.iter().map(|&x| x as i32).collect(),
            NpyData::I32(v) => v.clone(),
            NpyData::I64(v) => v.iter().map(|&x| x as i32).collect(),
        }
    }
}

/// One named array out of an npz archive.
#[derive(Debug, Clone)]
pub struct NpzEntry {
    /// Member name with the `.npy` suffix stripped (numpy's key).
    pub name: String,
    pub dims: Vec<usize>,
    pub data: NpyData,
}

/// Bounds-checked slice at `off..off+len` — hostile offsets near
/// `usize::MAX` must error, not overflow in the index arithmetic.
fn rd_slice<'a>(b: &'a [u8], off: usize, len: usize) -> Result<&'a [u8]> {
    off.checked_add(len)
        .and_then(|end| b.get(off..end))
        .ok_or_else(|| anyhow!("npz: truncated at offset {off}"))
}

fn rd_u16(b: &[u8], off: usize) -> Result<u16> {
    let s = rd_slice(b, off, 2)?;
    Ok(u16::from_le_bytes([s[0], s[1]]))
}

fn rd_u32(b: &[u8], off: usize) -> Result<u32> {
    let s = rd_slice(b, off, 4)?;
    Ok(u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
}

fn rd_u64(b: &[u8], off: usize) -> Result<u64> {
    let s = rd_slice(b, off, 8)?;
    Ok(u64::from_le_bytes([
        s[0], s[1], s[2], s[3], s[4], s[5], s[6], s[7],
    ]))
}

/// Last occurrence of `sig` in `b`, searching backwards.
fn rfind_sig(b: &[u8], sig: [u8; 4]) -> Option<usize> {
    if b.len() < 4 {
        return None;
    }
    (0..=b.len() - 4).rev().find(|&i| b[i..i + 4] == sig)
}

/// Read every array of an uncompressed npz archive.
pub fn read_npz(path: &Path) -> Result<Vec<NpzEntry>> {
    read_npz_checked(path, None)
}

/// Read an npz archive, digesting the bytes *as they stream in* when the
/// repository manifest supplies an expected digest: the same buffer the
/// parser consumes is hashed while it fills (via
/// [`crate::util::hash::HashingReader`]) — never buffered twice. A
/// size or sha256 mismatch refuses the archive before any parsing,
/// naming the offending file and both digests.
pub fn read_npz_checked(
    path: &Path,
    expected: Option<&crate::util::hash::ExpectedDigest>,
) -> Result<Vec<NpzEntry>> {
    let bytes = match expected {
        None => std::fs::read(path).with_context(|| format!("read {}", path.display()))?,
        Some(exp) => {
            let (bytes, digest, size) = crate::util::hash::read_file_hashed(path)
                .with_context(|| format!("read {}", path.display()))?;
            exp.check(&digest, size).map_err(|e| anyhow!(e))?;
            bytes
        }
    };
    parse_npz(&bytes).with_context(|| format!("parse {}", path.display()))
}

/// Parse an in-memory uncompressed npz archive. Public so hostile-bytes
/// property tests can drive the parser without touching the filesystem;
/// any malformed input must produce an error, never a panic or a partial
/// result.
pub fn parse_npz(b: &[u8]) -> Result<Vec<NpzEntry>> {
    // End-of-central-directory record -> central directory walk. The EOCD
    // comment is empty for numpy archives, so the record sits at the tail;
    // scanning backwards also tolerates a short trailing comment.
    let eocd = rfind_sig(b, [0x50, 0x4b, 0x05, 0x06])
        .ok_or_else(|| anyhow!("npz: no end-of-central-directory record (not a zip?)"))?;
    let mut n_entries = rd_u16(b, eocd + 10)? as u64;
    let mut cd_off = rd_u32(b, eocd + 16)? as u64;
    if n_entries == 0xFFFF || cd_off == 0xFFFF_FFFF {
        // Zip64: the EOCD64 record carries the real values.
        let eocd64 = rfind_sig(b, [0x50, 0x4b, 0x06, 0x06])
            .ok_or_else(|| anyhow!("npz: zip64 sizes but no EOCD64 record"))?;
        n_entries = rd_u64(b, eocd64 + 32)?;
        cd_off = rd_u64(b, eocd64 + 48)?;
    }

    // A central-directory entry is at least 46 bytes, so a claimed count
    // beyond len/46 is hostile — reject it instead of trusting it with a
    // Vec::with_capacity (a zip64 count is attacker-controlled 64 bits).
    if n_entries > (b.len() / 46 + 1) as u64 {
        bail!(
            "npz: central directory claims {n_entries} entries but the archive \
             holds {} bytes",
            b.len()
        );
    }
    let mut entries = Vec::new();
    let mut pos = usize::try_from(cd_off).map_err(|_| anyhow!("npz: central directory offset {cd_off} out of range"))?;
    for _ in 0..n_entries {
        if rd_u32(b, pos)? != 0x0201_4b50 {
            bail!("npz: bad central-directory signature at {pos}");
        }
        let method = rd_u16(b, pos + 10)?;
        let mut usize_ = rd_u32(b, pos + 24)? as u64;
        let name_len = rd_u16(b, pos + 28)? as usize;
        let extra_len = rd_u16(b, pos + 30)? as usize;
        let comment_len = rd_u16(b, pos + 32)? as usize;
        let mut lho = rd_u32(b, pos + 42)? as u64;
        let name_bytes =
            rd_slice(b, pos + 46, name_len).context("npz: truncated member name")?;
        let name = String::from_utf8_lossy(name_bytes).to_string();
        // Zip64 extra field (id 0x0001): 64-bit values for exactly those
        // header fields that saturated, in usize/csize/offset order.
        if usize_ == 0xFFFF_FFFF || lho == 0xFFFF_FFFF {
            let csize = rd_u32(b, pos + 20)? as u64;
            let mut e = pos + 46 + name_len;
            let extra_end = e + extra_len;
            while e + 4 <= extra_end {
                let id = rd_u16(b, e)?;
                let sz = rd_u16(b, e + 2)? as usize;
                if id == 0x0001 {
                    let mut f = e + 4;
                    if usize_ == 0xFFFF_FFFF {
                        usize_ = rd_u64(b, f)?;
                        f += 8;
                    }
                    if csize == 0xFFFF_FFFF {
                        f += 8;
                    }
                    if lho == 0xFFFF_FFFF {
                        lho = rd_u64(b, f)?;
                    }
                    break;
                }
                e += 4 + sz;
            }
        }
        if method != 0 {
            bail!(
                "npz member {name:?} uses compression method {method}; only stored \
                 members are supported — write with np.savez, not np.savez_compressed"
            );
        }
        // Local header gives the data offset (its name/extra lengths can
        // differ from the central copy).
        let l = usize::try_from(lho)
            .map_err(|_| anyhow!("npz: local header offset {lho} out of range"))?;
        if rd_u32(b, l)? != 0x0403_4b50 {
            bail!("npz: bad local-header signature for {name:?}");
        }
        let l_name = rd_u16(b, l + 26)? as usize;
        let l_extra = rd_u16(b, l + 28)? as usize;
        let data_off = l + 30 + l_name + l_extra;
        let member_len = usize::try_from(usize_)
            .map_err(|_| anyhow!("npz: member {name:?} claims {usize_} bytes"))?;
        let data = rd_slice(b, data_off, member_len)
            .map_err(|_| anyhow!("npz: member {name:?} data out of bounds"))?;
        let (dims, payload) = parse_npy(data).with_context(|| format!("npz member {name:?}"))?;
        entries.push(NpzEntry {
            name: name.strip_suffix(".npy").unwrap_or(&name).to_string(),
            dims,
            data: payload,
        });
        pos += 46 + name_len + extra_len + comment_len;
    }
    Ok(entries)
}

/// Parse one `.npy` payload (version 1.x/2.x header, C order).
fn parse_npy(b: &[u8]) -> Result<(Vec<usize>, NpyData)> {
    if b.len() < 10 || &b[..6] != b"\x93NUMPY" {
        bail!("not an npy payload");
    }
    let major = b[6];
    let (header_len, header_start) = match major {
        1 => (rd_u16(b, 8)? as usize, 10),
        2 | 3 => (rd_u32(b, 8)? as usize, 12),
        v => bail!("unsupported npy version {v}"),
    };
    let header = header_start
        .checked_add(header_len)
        .and_then(|end| b.get(header_start..end))
        .ok_or_else(|| anyhow!("npy: truncated header"))?;
    let header = std::str::from_utf8(header).context("npy header not utf-8")?;
    let descr = dict_str_value(header, "descr")
        .ok_or_else(|| anyhow!("npy header missing descr: {header}"))?;
    if header.contains("'fortran_order': True") {
        bail!("npy: fortran_order arrays are not supported");
    }
    let dims = parse_shape(header)?;
    // Hostile shapes like (usize::MAX, 2) must not overflow the element
    // count (debug panic / silent wrap in release).
    let count: usize = dims
        .iter()
        .try_fold(1usize, |acc, &d| acc.checked_mul(d))
        .ok_or_else(|| anyhow!("npy: shape {dims:?} overflows the element count"))?;
    let data = &b[header_start + header_len..];
    let payload = match descr.as_str() {
        "<f4" => NpyData::F32(read_scalars(data, count, f32::from_le_bytes)?),
        "<f8" => NpyData::F64(read_scalars(data, count, f64::from_le_bytes)?),
        "<i4" => NpyData::I32(read_scalars(data, count, i32::from_le_bytes)?),
        "<i8" => NpyData::I64(read_scalars(data, count, i64::from_le_bytes)?),
        other => bail!("npy dtype {other:?} not supported (need <f4/<f8/<i4/<i8)"),
    };
    Ok((dims, payload))
}

/// `'key': 'value'` lookup inside the npy header dict literal.
fn dict_str_value(header: &str, key: &str) -> Option<String> {
    let pat = format!("'{key}':");
    let at = header.find(&pat)? + pat.len();
    let rest = header[at..].trim_start();
    let rest = rest.strip_prefix('\'')?;
    let end = rest.find('\'')?;
    Some(rest[..end].to_string())
}

/// `'shape': (128, 32),` -> [128, 32]. `()` is a scalar (one element).
fn parse_shape(header: &str) -> Result<Vec<usize>> {
    let at = header
        .find("'shape':")
        .ok_or_else(|| anyhow!("npy header missing shape: {header}"))?;
    let rest = &header[at..];
    let open = rest.find('(').ok_or_else(|| anyhow!("npy shape: no '('"))?;
    let close = rest[open..]
        .find(')')
        .ok_or_else(|| anyhow!("npy shape: no ')'"))?
        + open;
    rest[open + 1..close]
        .split(',')
        .map(str::trim)
        .filter(|p| !p.is_empty())
        .map(|p| p.parse::<usize>().map_err(|_| anyhow!("npy shape: bad dim {p:?}")))
        .collect()
}

fn read_scalars<T, const W: usize>(
    data: &[u8],
    count: usize,
    decode: fn([u8; W]) -> T,
) -> Result<Vec<T>> {
    let need = count
        .checked_mul(W)
        .ok_or_else(|| anyhow!("npy: {count} elements of width {W} overflow"))?;
    let data = data
        .get(..need)
        .ok_or_else(|| anyhow!("npy: payload holds {} bytes, need {need}", data.len()))?;
    Ok(data
        .chunks_exact(W)
        .map(|c| decode(c.try_into().expect("chunk width")))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    /// Hand-roll a stored zip holding one npy member (crc is not checked).
    fn fake_npz(name: &str, npy: &[u8]) -> Vec<u8> {
        let mut out = Vec::new();
        let name_b = name.as_bytes();
        // local header
        out.extend_from_slice(&0x0403_4b50u32.to_le_bytes());
        out.extend_from_slice(&[20, 0, 0, 0, 0, 0, 0, 0, 0, 0]); // ver, flags, method, time, date
        out.extend_from_slice(&0u32.to_le_bytes()); // crc
        out.extend_from_slice(&(npy.len() as u32).to_le_bytes()); // csize
        out.extend_from_slice(&(npy.len() as u32).to_le_bytes()); // usize
        out.extend_from_slice(&(name_b.len() as u16).to_le_bytes());
        out.extend_from_slice(&0u16.to_le_bytes()); // extra len
        out.extend_from_slice(name_b);
        out.extend_from_slice(npy);
        let cd_off = out.len();
        // central directory entry
        out.extend_from_slice(&0x0201_4b50u32.to_le_bytes());
        out.extend_from_slice(&[20, 0, 20, 0, 0, 0, 0, 0, 0, 0, 0, 0]); // vers/flags/method/dates
        out.extend_from_slice(&0u32.to_le_bytes()); // crc
        out.extend_from_slice(&(npy.len() as u32).to_le_bytes());
        out.extend_from_slice(&(npy.len() as u32).to_le_bytes());
        out.extend_from_slice(&(name_b.len() as u16).to_le_bytes());
        out.extend_from_slice(&[0u8; 8]); // extra, comment, disk, int attrs
        out.extend_from_slice(&0u32.to_le_bytes()); // ext attrs
        out.extend_from_slice(&0u32.to_le_bytes()); // local header offset
        out.extend_from_slice(name_b);
        let cd_size = out.len() - cd_off;
        // EOCD
        out.extend_from_slice(&0x0605_4b50u32.to_le_bytes());
        out.extend_from_slice(&[0, 0, 0, 0]); // disk numbers
        out.extend_from_slice(&1u16.to_le_bytes());
        out.extend_from_slice(&1u16.to_le_bytes());
        out.extend_from_slice(&(cd_size as u32).to_le_bytes());
        out.extend_from_slice(&(cd_off as u32).to_le_bytes());
        out.extend_from_slice(&0u16.to_le_bytes()); // comment len
        out
    }

    fn fake_npy_f32(dims: &[usize], values: &[f32]) -> Vec<u8> {
        let shape = dims
            .iter()
            .map(|d| format!("{d},"))
            .collect::<Vec<_>>()
            .join(" ");
        let mut header = format!(
            "{{'descr': '<f4', 'fortran_order': False, 'shape': ({shape}), }}"
        );
        while (header.len() + 11) % 16 != 0 {
            header.push(' ');
        }
        header.push('\n');
        let mut out = Vec::new();
        out.extend_from_slice(b"\x93NUMPY\x01\x00");
        out.extend_from_slice(&(header.len() as u16).to_le_bytes());
        out.extend_from_slice(header.as_bytes());
        for v in values {
            out.extend_from_slice(&v.to_le_bytes());
        }
        out
    }

    #[test]
    fn roundtrips_hand_rolled_archive() {
        let npy = fake_npy_f32(&[2, 3], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let zip = fake_npz("w.npy", &npy);
        let dir = std::env::temp_dir().join(format!("pb-npz-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.npz");
        std::fs::File::create(&path)
            .unwrap()
            .write_all(&zip)
            .unwrap();
        let entries = read_npz(&path).unwrap();
        assert_eq!(entries.len(), 1);
        assert_eq!(entries[0].name, "w");
        assert_eq!(entries[0].dims, vec![2, 3]);
        assert_eq!(entries[0].data.to_f32(), vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn scalar_shape_parses() {
        assert_eq!(parse_shape("{'shape': (), }").unwrap(), Vec::<usize>::new());
        assert_eq!(parse_shape("{'shape': (7,), }").unwrap(), vec![7]);
        assert_eq!(parse_shape("{'shape': (128, 32), }").unwrap(), vec![128, 32]);
    }

    #[test]
    fn garbage_is_rejected() {
        assert!(parse_npy(b"not numpy at all").is_err());
        let dir = std::env::temp_dir();
        let path = dir.join(format!("pb-npz-bad-{}", std::process::id()));
        std::fs::write(&path, b"PK garbage without directory").unwrap();
        assert!(read_npz(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn committed_artifacts_parse_if_present() {
        let root = crate::runtime::default_root().join("sst2");
        let test = root.join("test.npz");
        if !test.exists() {
            eprintln!("SKIP: no committed artifacts for npz smoke test");
            return;
        }
        let entries = read_npz(&test).unwrap();
        let names: Vec<&str> = entries.iter().map(|e| e.name.as_str()).collect();
        assert!(names.contains(&"tokens"));
        assert!(names.contains(&"segs"));
        assert!(names.contains(&"labels"));
        let tokens = entries.iter().find(|e| e.name == "tokens").unwrap();
        assert_eq!(tokens.dims.len(), 2);
        assert_eq!(tokens.data.len(), tokens.dims[0] * tokens.dims[1]);
    }
}
