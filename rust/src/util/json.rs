//! Minimal but complete JSON parser + writer.
//!
//! serde is not part of the offline vendor set, so artifact manifests
//! (`meta.json`, `index.json`, `vocab.json`) and the wire protocol are
//! handled by this module. Supports the full JSON grammar (objects,
//! arrays, strings with escapes, numbers, bool, null). Non-negative
//! integers are kept exact as `UInt` (protocol request ids are u64 and
//! must not round-trip through f64); every other number is an f64 `Num`.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    /// Non-negative integer, kept exact (f64 loses precision above 2^53).
    UInt(u64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// `UInt` and `Num` compare numerically (`UInt(5) == Num(5.0)`): which
/// variant a number parses into is a precision detail, not a semantic one.
/// The cross comparison is exact — equal only when both denote the same
/// real number — so distinct u64 ids above 2^53 never collide with a
/// rounded f64 and equality stays transitive.
impl PartialEq for Json {
    fn eq(&self, other: &Self) -> bool {
        match (self, other) {
            (Json::Null, Json::Null) => true,
            (Json::Bool(a), Json::Bool(b)) => a == b,
            (Json::Str(a), Json::Str(b)) => a == b,
            (Json::Arr(a), Json::Arr(b)) => a == b,
            (Json::Obj(a), Json::Obj(b)) => a == b,
            (Json::UInt(a), Json::UInt(b)) => a == b,
            (Json::Num(a), Json::Num(b)) => a == b,
            (Json::UInt(a), Json::Num(b)) | (Json::Num(b), Json::UInt(a)) => {
                // Both directions must hold: `a as f64` alone rounds ids
                // above 2^53 onto nearby floats they do not equal.
                *a as f64 == *b && *b as u64 == *a
            }
            _ => false,
        }
    }
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: src.as_bytes(), at: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.at != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    pub fn parse_file(path: &std::path::Path) -> Result<Json, JsonError> {
        let text = std::fs::read_to_string(path).map_err(|e| JsonError {
            msg: format!("read {}: {e}", path.display()),
            offset: 0,
        })?;
        Json::parse(&text)
    }

    // -- typed accessors --------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            Json::UInt(u) => Some(*u as f64),
            _ => None,
        }
    }

    /// Exact unsigned integer value: `UInt` verbatim, or a `Num` that is a
    /// non-negative whole number small enough for f64 to have kept exact
    /// (below 2^53).
    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::UInt(u) => Some(*u),
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n < 9007199254740992.0 => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj.str_at("key")` with a descriptive error, for manifest parsing.
    pub fn str_at(&self, key: &str) -> Result<&str, JsonError> {
        self.get(key)
            .and_then(Json::as_str)
            .ok_or_else(|| JsonError { msg: format!("missing string field {key:?}"), offset: 0 })
    }

    pub fn usize_at(&self, key: &str) -> Result<usize, JsonError> {
        self.get(key)
            .and_then(Json::as_usize)
            .ok_or_else(|| JsonError { msg: format!("missing numeric field {key:?}"), offset: 0 })
    }

    // -- writer ------------------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, 0, true);
        s
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::UInt(u) => out.push_str(&format!("{u}")),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, e) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&" ".repeat(indent + 1));
                    }
                    e.write(out, indent + 1, pretty);
                }
                if pretty && !v.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent));
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if pretty {
                        out.push('\n');
                        out.push_str(&" ".repeat(indent + 1));
                    }
                    write_escaped(out, k);
                    out.push_str(": ");
                    v.write(out, indent + 1, pretty);
                }
                if pretty && !m.is_empty() {
                    out.push('\n');
                    out.push_str(&" ".repeat(indent));
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s, 0, false);
        f.write_str(&s)
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug, Clone)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    at: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.at }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.at).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.at += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.at += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.bump() == Some(c) {
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.at..].starts_with(word.as_bytes()) {
            self.at += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.at;
        if self.peek() == Some(b'-') {
            self.at += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.at += 1;
        }
        if self.peek() == Some(b'.') {
            self.at += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.at += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.at += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.at += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.at += 1;
            }
        }
        let text = std::str::from_utf8(&self.b[start..self.at]).unwrap();
        // Plain non-negative integers stay exact: f64 silently rounds
        // anything above 2^53, and protocol ids are full-range u64.
        if !text.starts_with('-') && !text.contains(['.', 'e', 'E']) {
            if let Ok(u) = text.parse::<u64>() {
                return Ok(Json::UInt(u));
            }
        }
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'n') => s.push('\n'),
                    Some(b'r') => s.push('\r'),
                    Some(b't') => s.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                            code = code * 16
                                + (c as char).to_digit(16).ok_or_else(|| self.err("bad \\u"))?;
                        }
                        // Surrogate pairs for non-BMP characters.
                        if (0xD800..0xDC00).contains(&code) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone surrogate"));
                            }
                            let mut low = 0u32;
                            for _ in 0..4 {
                                let c = self.bump().ok_or_else(|| self.err("bad \\u"))?;
                                low = low * 16
                                    + (c as char).to_digit(16).ok_or_else(|| self.err("bad \\u"))?;
                            }
                            code = 0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00);
                        }
                        s.push(char::from_u32(code).ok_or_else(|| self.err("bad codepoint"))?);
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) => {
                    // Re-decode UTF-8 multibyte sequences.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let len = if c >= 0xF0 {
                            4
                        } else if c >= 0xE0 {
                            3
                        } else {
                            2
                        };
                        let start = self.at - 1;
                        self.at = start + len;
                        let chunk = self
                            .b
                            .get(start..start + len)
                            .ok_or_else(|| self.err("truncated utf-8"))?;
                        s.push_str(
                            std::str::from_utf8(chunk).map_err(|_| self.err("bad utf-8"))?,
                        );
                    }
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.at += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(v)),
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.at += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(m)),
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -12.5e2 ").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, {"b": "x"}, null], "c": false}"#).unwrap();
        assert_eq!(v.get("c"), Some(&Json::Bool(false)));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].get("b").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn parses_unicode_escapes() {
        assert_eq!(Json::parse(r#""Aé""#).unwrap(), Json::Str("Aé".into()));
        // surrogate pair (U+1F600)
        assert_eq!(Json::parse(r#""😀""#).unwrap(), Json::Str("😀".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrips() {
        let src = r#"{"name": "power-default", "retention": [32, 16, 8, 8, 8, 8], "dev": 0.914, "nested": {"deep": [true, null]}}"#;
        let v = Json::parse(src).unwrap();
        let out = v.to_string();
        assert_eq!(Json::parse(&out).unwrap(), v);
        let pretty = v.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn u64_ids_survive_exactly() {
        // 2^53 + 1 is the first integer f64 cannot represent.
        let big = "9007199254740993";
        let v = Json::parse(big).unwrap();
        assert_eq!(v, Json::UInt(9007199254740993));
        assert_eq!(v.as_u64(), Some(9007199254740993));
        assert_eq!(v.to_string(), big);
        let max = Json::parse("18446744073709551615").unwrap();
        assert_eq!(max.as_u64(), Some(u64::MAX));
        assert_eq!(max.to_string(), "18446744073709551615");
        // Floats and negatives never masquerade as exact ids...
        assert_eq!(Json::parse("1.5").unwrap().as_u64(), None);
        assert_eq!(Json::parse("-3").unwrap().as_u64(), None);
        // ...but a small whole Num still qualifies.
        assert_eq!(Json::Num(42.0).as_u64(), Some(42));
        assert_eq!(Json::Num(9.1e15).as_u64(), None);
    }

    #[test]
    fn uint_and_num_compare_numerically() {
        assert_eq!(Json::UInt(5), Json::Num(5.0));
        assert_eq!(Json::parse("7").unwrap(), Json::Num(7.0));
        assert_ne!(Json::UInt(5), Json::Num(5.5));
        assert_ne!(Json::UInt(5), Json::Str("5".into()));
        // Exactness above 2^53: a rounded float is NOT the id next to it.
        assert_ne!(Json::UInt(9007199254740993), Json::Num(9007199254740992.0));
        assert_eq!(Json::UInt(9007199254740992), Json::Num(9007199254740992.0));
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse(r#""héllo wörld — 中文""#).unwrap();
        assert_eq!(v.as_str(), Some("héllo wörld — 中文"));
    }
}
