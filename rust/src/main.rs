//! powerbert CLI — leader entrypoint.
//!
//! Subcommands:
//!   serve     start the TCP serving front-end (wire protocol v2 + v1
//!             compat). SIGINT/SIGTERM stops accepting, drains the
//!             coordinator, and prints the final metrics report; the same
//!             numbers are available live via the v2 {"cmd":"stats"}
//!             protocol message (structured JSON).
//!   eval      run a dataset's test split through a variant, print metrics
//!   info      list artifacts / variants / retention configs
//!   verify    hash every manifest-listed artifact against its recorded
//!             digest (and check the signature); nonzero exit on any
//!             mismatch — the CI tamper smoke and the pre-deploy check

use std::path::PathBuf;

use powerbert::coordinator::{BatchPolicy, Config, Coordinator, EdgeKind, Policy, Server};
use powerbert::runtime::{
    default_root, BackendKind, Engine, KernelConfig, Precision, Registry, Repo, RepoPolicy,
    TestSplit,
};
use powerbert::util::cli::Args;
use powerbert::eval::Metric;

fn main() {
    powerbert::util::log::init();
    let args = Args::new(
        "powerbert",
        "PoWER-BERT serving coordinator (ICML 2020 reproduction)",
    )
    .positional("command", "serve | eval | info | verify")
    .opt("artifacts", None, "artifacts directory (default: ./artifacts)")
    .opt("addr", Some("127.0.0.1:7878"), "serve: listen address")
    .opt("datasets", None, "serve: comma-separated dataset allowlist")
    .opt("policy", Some("fastest-above-metric"), "serve: routing policy (fixed:<variant> | best-under-latency | fastest-above-metric)")
    .opt("max-batch", Some("32"), "serve: dynamic batcher max batch")
    .opt("max-wait-ms", Some("5"), "serve: dynamic batcher max wait")
    .opt("backend", None, "serve/eval: inference backend (pjrt | native | auto; default $POWERBERT_BACKEND or auto)")
    .opt("kernel-threads", None, "serve/eval: native kernel threads per op, sizing each worker's persistent kernel pool (0 = one per core; default $POWERBERT_KERNEL_THREADS or 1)")
    .opt("kernel-kc", None, "serve/eval: native kernel depth-block size (default $POWERBERT_KERNEL_KC or 256)")
    .opt("kernel-mc", None, "serve/eval: native kernel row-block size (default $POWERBERT_KERNEL_MC or 64)")
    .opt("precision", None, "serve/eval: native weight precision (f32 | int8; default $POWERBERT_KERNEL_PRECISION or f32)")
    .opt("ragged", None, "serve/eval: ragged per-example execution (on = compute \u{3a3} kept tokens | off = padded batch-max oracle; default $POWERBERT_KERNEL_RAGGED or on)")
    .opt("workers", Some("1"), "serve: executor pool size (one backend instance each)")
    .opt("seq-buckets", None, "serve: comma-separated seq buckets for length-aware batching (e.g. 16,32,64)")
    .opt("max-connections", None, "serve: concurrent connection cap (default 256)")
    .opt("edge", Some("threads"), "serve: connection edge (threads = thread-per-connection fallback | epoll = event loop, Linux only)")
    .opt("dataset", None, "eval: dataset name")
    .opt("variant", Some("bert"), "eval: variant name")
    .opt("batch", Some("32"), "eval: batch size")
    .opt("thresholds", None, "eval: comma-separated attention-mass thresholds for --calibrate-pareto (default 1.0,0.98,0.95,0.9,0.8,0.6)")
    .opt("pareto-out", None, "eval: output path for the calibrated Pareto table (default <variant dir>/pareto.json)")
    .flag("calibrate-pareto", "eval: sweep adaptive thresholds over the test split and write the accuracy-vs-tokens Pareto table the router serves SLAs from")
    .flag("preload", "serve: load all variants at startup")
    .opt("trusted-key", None, "serve/verify: path to the trusted ed25519 public key (default <artifacts>/signing.pub)")
    .flag("require-signed", "serve/verify: refuse artifacts unless the manifest signature verifies and covers every file on disk");

    let parsed = match args.parse() {
        Ok(p) => p,
        Err(usage) => {
            eprintln!("{usage}");
            std::process::exit(2);
        }
    };
    let root = parsed
        .get("artifacts")
        .map(PathBuf::from)
        .unwrap_or_else(default_root);

    let cmd = parsed.positional.first().map(String::as_str).unwrap_or("info");
    let code = match cmd {
        "serve" => cmd_serve(&parsed, root),
        "eval" => cmd_eval(&parsed, root),
        "info" => cmd_info(root),
        "verify" => cmd_verify(&parsed, root),
        other => {
            eprintln!("unknown command {other:?} (expected serve|eval|info|verify)");
            2
        }
    };
    std::process::exit(code);
}

/// Backend selection: explicit `--backend` wins, then `$POWERBERT_BACKEND`,
/// then auto (PJRT with native fallback). `Err` carries the usage message.
fn parse_backend(parsed: &powerbert::util::cli::Parsed) -> Result<BackendKind, String> {
    match parsed.get("backend") {
        None => Ok(BackendKind::from_env()),
        Some(raw) => BackendKind::parse(raw)
            .ok_or_else(|| format!("--backend: expected pjrt|native|auto, got {raw:?}")),
    }
}

/// Kernel tuning: explicit `--kernel-*` flags override `$POWERBERT_KERNEL_*`
/// env vars, which override the built-in defaults.
fn parse_kernel(parsed: &powerbert::util::cli::Parsed) -> Result<KernelConfig, String> {
    let mut k = KernelConfig::from_env();
    if let Some(t) = parsed.get_usize("kernel-threads") {
        k.threads = t;
    }
    if let Some(kc) = parsed.get_usize("kernel-kc") {
        k.kc = kc.max(1);
    }
    if let Some(mc) = parsed.get_usize("kernel-mc") {
        k.mc = mc.max(1);
    }
    if let Some(raw) = parsed.get("precision") {
        k.precision = Precision::parse(raw)
            .ok_or_else(|| format!("--precision: expected f32|int8, got {raw:?}"))?;
    }
    if let Some(raw) = parsed.get("ragged") {
        k.ragged = match raw.to_ascii_lowercase().as_str() {
            "on" | "1" | "true" | "yes" => true,
            "off" | "0" | "false" | "no" => false,
            _ => return Err(format!("--ragged: expected on|off, got {raw:?}")),
        };
    }
    Ok(k)
}

fn parse_policy(s: &str) -> Policy {
    if let Some(v) = s.strip_prefix("fixed:") {
        Policy::Fixed(v.to_string())
    } else if s == "best-under-latency" {
        Policy::BestUnderLatency
    } else {
        Policy::FastestAboveMetric
    }
}

fn cmd_serve(parsed: &powerbert::util::cli::Parsed, root: PathBuf) -> i32 {
    let backend = match parse_backend(parsed) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let kernel = match parse_kernel(parsed) {
        Ok(k) => k,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let cfg = Config {
        artifacts: root,
        datasets: parsed
            .get("datasets")
            .map(|d| d.split(',').map(String::from).collect())
            .unwrap_or_default(),
        policy: parse_policy(parsed.get("policy").unwrap_or_default()),
        batch: BatchPolicy {
            max_batch: parsed.get_usize("max-batch").unwrap_or(32),
            max_wait: std::time::Duration::from_millis(
                parsed.get_usize("max-wait-ms").unwrap_or(5) as u64,
            ),
        },
        preload: parsed.has("preload"),
        workers: parsed.get_usize("workers").unwrap_or(1).max(1),
        backend,
        kernel,
        seq_buckets: match (parsed.get("seq-buckets"), parsed.get_usize_list("seq-buckets")) {
            (Some(raw), None) if !raw.trim().is_empty() => {
                eprintln!("--seq-buckets: expected comma-separated integers, got {raw:?}");
                return 2;
            }
            (_, list) => list.unwrap_or_default(),
        },
        require_signed: parsed.has("require-signed"),
        trusted_key: parsed.get("trusted-key").map(PathBuf::from),
        ..Config::default()
    };
    let mut coordinator = match Coordinator::start(cfg) {
        Ok(c) => c,
        Err(e) => {
            eprintln!("failed to start coordinator: {e}");
            return 1;
        }
    };
    let addr = parsed.get("addr").unwrap_or("127.0.0.1:7878");
    let server = match Server::bind(addr, coordinator.client()) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("bind {addr}: {e}");
            return 1;
        }
    };
    let server = match parsed.get_usize("max-connections") {
        Some(n) => server.with_max_connections(n),
        None => server,
    };
    let server = match EdgeKind::parse(parsed.get("edge").unwrap_or("threads")) {
        Ok(edge) => server.with_edge(edge),
        Err(e) => {
            eprintln!("--edge: {e}");
            return 2;
        }
    };

    // SIGINT/SIGTERM: the handler only flips an atomic; this watcher turns
    // the flip into a stop-flag store plus a wake-up connection so the
    // blocking accept loop actually returns.
    powerbert::util::signal::install_shutdown_handler();
    let stop = server.stop_handle();
    let local = match server.local_addr() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("local_addr: {e}");
            return 1;
        }
    };
    std::thread::spawn(move || loop {
        if powerbert::util::signal::shutdown_requested() {
            Server::shutdown(local, &stop);
            return;
        }
        std::thread::sleep(std::time::Duration::from_millis(100));
    });

    if let Err(e) = server.run() {
        eprintln!("server error: {e}");
        return 1;
    }
    drop(server); // release the accept socket + the server's Client clone

    // Drain what is already queued, bounded: a lingering idle connection
    // holds a Client clone and would otherwise block the join forever.
    eprintln!("shutdown signal received; draining coordinator");
    let metrics = coordinator.metrics();
    let (done_tx, done_rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        coordinator.shutdown();
        let _ = done_tx.send(());
    });
    if done_rx.recv_timeout(std::time::Duration::from_secs(10)).is_err() {
        eprintln!("drain timed out (connections still open?); exiting without full drain");
    }
    println!("== final metrics ==");
    print!("{}", metrics.report());
    0
}

fn cmd_eval(parsed: &powerbert::util::cli::Parsed, root: PathBuf) -> i32 {
    let Some(dataset) = parsed.get("dataset") else {
        eprintln!("--dataset required");
        return 2;
    };
    let variant = parsed.get("variant").unwrap_or("bert");
    let batch = parsed.get_usize("batch").unwrap_or(32);
    let registry = match Registry::scan(&root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    let Some(ds) = registry.dataset(dataset) else {
        eprintln!("dataset {dataset} not in artifacts");
        return 1;
    };
    let Some(meta) = ds.variant(variant) else {
        eprintln!(
            "variant {variant} not found; have: {:?}",
            ds.variants.keys().collect::<Vec<_>>()
        );
        return 1;
    };
    let backend = match parse_backend(parsed) {
        Ok(b) => b,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let kernel = match parse_kernel(parsed) {
        Ok(k) => k,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let mut engine = match Engine::with_backend_config(backend, kernel) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("backend {backend}: {e:#}");
            return 1;
        }
    };
    let model = match engine.load(meta) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("load: {e:#}");
            return 1;
        }
    };
    let split = match TestSplit::load(&ds.test_npz()) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("test split: {e}");
            return 1;
        }
    };
    let metric = Metric::parse(&meta.metric).unwrap_or(Metric::Accuracy);
    if parsed.has("calibrate-pareto") {
        return cmd_calibrate(parsed, meta, &model, &split, metric);
    }
    let t0 = std::time::Instant::now();
    let mut outputs: Vec<f32> = Vec::new();
    let mut num_classes = meta.num_classes;
    let seq = split.seq_len;
    let mut i = 0;
    while i < split.n {
        let n = batch.min(split.n - i);
        let toks = &split.tokens[i * seq..(i + n) * seq];
        let segs = &split.segments[i * seq..(i + n) * seq];
        match model.infer(toks, segs, n) {
            Ok(l) => {
                num_classes = l.num_classes;
                outputs.extend_from_slice(&l.values);
            }
            Err(e) => {
                eprintln!("infer: {e}");
                return 1;
            }
        }
        i += n;
    }
    let secs = t0.elapsed().as_secs_f64();
    let m = metric.compute(&outputs, num_classes, &split.labels);
    println!(
        "{dataset}/{variant} [{}]: {} = {:.4} over {} examples in {:.2}s ({:.1} ex/s)",
        model.backend_name(),
        meta.metric,
        m,
        split.n,
        secs,
        split.n as f64 / secs
    );
    0
}

/// `eval --calibrate-pareto`: sweep attention-mass thresholds over the
/// committed test split and write the machine-readable Pareto table
/// (`pareto.json`, schema 1) that the router maps request SLAs onto.
///
/// Each threshold runs at batch 1 so an example's executed kept-set is
/// exactly its own demanded k — the table is independent of batch
/// composition and reproducible run to run. `est_latency_us` is measured
/// on the calibration machine (treat as relative); the router's named
/// tiers select on metric and mean tokens only.
fn cmd_calibrate(
    parsed: &powerbert::util::cli::Parsed,
    meta: &powerbert::runtime::VariantMeta,
    model: &powerbert::runtime::LoadedModel,
    split: &TestSplit,
    metric: Metric,
) -> i32 {
    use powerbert::runtime::adaptive::{ParetoPoint, ParetoTable};
    use powerbert::util::json::Json;

    if !model.supports_adaptive() {
        eprintln!(
            "{}/{} cannot adapt: adaptive retention needs the native backend \
             and a retention schedule (got backend {:?}, retention {})",
            meta.dataset,
            meta.variant,
            model.backend_name(),
            if meta.retention.is_some() { "present" } else { "absent" },
        );
        return 1;
    }
    let thresholds: Vec<f64> = match parsed.get("thresholds") {
        None => vec![1.0, 0.98, 0.95, 0.9, 0.8, 0.6],
        Some(raw) => {
            let mut ts = Vec::new();
            for part in raw.split(',') {
                match part.trim().parse::<f64>() {
                    Ok(t) if t > 0.0 && t <= 1.0 => ts.push(t),
                    _ => {
                        eprintln!("--thresholds: expected numbers in (0, 1], got {part:?}");
                        return 2;
                    }
                }
            }
            ts
        }
    };
    let seq = split.seq_len;
    let mut points = Vec::with_capacity(thresholds.len());
    for &t in &thresholds {
        let thr = (t < 1.0).then_some(t as f32);
        let mut outputs: Vec<f32> = Vec::with_capacity(split.n * meta.num_classes);
        let mut num_classes = meta.num_classes;
        let mut tokens_total: u64 = 0;
        let t0 = std::time::Instant::now();
        for i in 0..split.n {
            let toks = &split.tokens[i * seq..(i + 1) * seq];
            let segs = &split.segments[i * seq..(i + 1) * seq];
            match model.infer_adaptive_at(toks, segs, 1, seq, thr) {
                Ok((l, per_row)) => {
                    num_classes = l.num_classes;
                    outputs.extend_from_slice(&l.values);
                    tokens_total += per_row.and_then(|v| v.first().copied()).unwrap_or(0);
                }
                Err(e) => {
                    eprintln!("infer at threshold {t}: {e:#}");
                    return 1;
                }
            }
        }
        let us = t0.elapsed().as_micros() as f64;
        let m = metric.compute(&outputs, num_classes, &split.labels);
        let mean_tokens = tokens_total as f64 / split.n as f64;
        println!(
            "threshold {t:.3}: {} = {m:.4}, mean tokens {mean_tokens:.1}, \
             {:.0} us/example",
            meta.metric,
            us / split.n as f64,
        );
        points.push(ParetoPoint {
            threshold: t,
            metric: m,
            mean_tokens,
            est_latency_us: us / split.n as f64,
        });
    }
    let table = ParetoTable::new(points);
    if let (Some(full), Some(bal), Some(fast)) = (table.full(), table.balanced(), table.fastest()) {
        println!(
            "operating points: full={:.3} balanced={:.3} ({:.1} vs {:.1} tokens) fast={:.3}",
            full.threshold, bal.threshold, bal.mean_tokens, full.mean_tokens, fast.threshold,
        );
    }
    let mut doc = std::collections::BTreeMap::new();
    doc.insert("schema".to_string(), Json::UInt(1));
    doc.insert("dataset".to_string(), Json::Str(meta.dataset.clone()));
    doc.insert("variant".to_string(), Json::Str(meta.variant.clone()));
    doc.insert("metric".to_string(), Json::Str(meta.metric.clone()));
    doc.insert("examples".to_string(), Json::UInt(split.n as u64));
    doc.insert("points".to_string(), table.points_json());
    let out = parsed
        .get("pareto-out")
        .map(PathBuf::from)
        .unwrap_or_else(|| meta.dir.join("pareto.json"));
    let mut body = Json::Obj(doc).to_string();
    body.push('\n');
    if let Err(e) = std::fs::write(&out, body) {
        eprintln!("write {}: {e}", out.display());
        return 1;
    }
    println!("wrote {} ({} points)", out.display(), table.points.len());
    0
}

/// `verify`: open the artifact repository exactly like `serve` would
/// (hash every manifest-listed file, check the signature) and report the
/// outcome. Exit 0 only when everything verified and nothing was excluded.
fn cmd_verify(parsed: &powerbert::util::cli::Parsed, root: PathBuf) -> i32 {
    let policy = RepoPolicy {
        require_signed: parsed.has("require-signed"),
        trusted_key: parsed.get("trusted-key").map(PathBuf::from),
        datasets: Vec::new(),
    };
    let repo = match Repo::open(&root, policy) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("verify failed: {e}");
            return 1;
        }
    };
    let snap = repo.snapshot();
    println!(
        "artifacts root: {} (revision {}, {})",
        root.display(),
        snap.revision,
        if snap.signed { "signed" } else { "unsigned" },
    );
    println!("verified files: {}", snap.verified_files);
    for f in &snap.failures {
        eprintln!("FAILED {}: {}", f.path, f.error);
    }
    for d in &snap.excluded_datasets {
        eprintln!("EXCLUDED dataset {d}");
    }
    println!(
        "datasets served: {:?}",
        snap.registry.datasets.keys().collect::<Vec<_>>()
    );
    if snap.failures.is_empty() && snap.excluded_datasets.is_empty() {
        0
    } else {
        1
    }
}

fn cmd_info(root: PathBuf) -> i32 {
    let registry = match Registry::scan(&root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("{e}");
            return 1;
        }
    };
    println!("artifacts root: {}", registry.root.display());
    for (name, ds) in &registry.datasets {
        println!("\n{name}:");
        for (vname, v) in &ds.variants {
            let dev = v
                .dev_metric
                .map(|d| format!("{d:.4}"))
                .unwrap_or_else(|| "-".into());
            let ret = v
                .retention
                .as_ref()
                .map(|r| format!("{r:?}"))
                .unwrap_or_else(|| "-".into());
            println!(
                "  {vname:<18} kind={:<10} {}={} N={} buckets={:?} agg-wv={} retention={}",
                v.kind,
                v.metric,
                dev,
                v.seq_len,
                v.batch_sizes,
                v.aggregate_word_vectors(),
                ret
            );
        }
    }
    0
}
