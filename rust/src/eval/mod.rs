//! Evaluation metrics, mirrored from `python/compile/train.py` so Tables 2-4
//! are regenerated end-to-end from Rust (inference through the PJRT engine,
//! metric computation here).

/// Classification / regression metric kinds used across the task suite.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Metric {
    Accuracy,
    F1,
    Matthews,
    Spearman,
}

impl Metric {
    pub fn parse(s: &str) -> Option<Metric> {
        match s {
            "accuracy" => Some(Metric::Accuracy),
            "f1" => Some(Metric::F1),
            "matthews" => Some(Metric::Matthews),
            "spearman" => Some(Metric::Spearman),
            _ => None,
        }
    }

    /// Compute the metric from per-row outputs.
    /// `outputs` is row-major [n, num_classes] (num_classes == 1 => regression).
    pub fn compute(&self, outputs: &[f32], num_classes: usize, labels: &[f32]) -> f64 {
        let n = labels.len();
        assert_eq!(outputs.len(), n * num_classes);
        match self {
            Metric::Spearman => {
                let pred: Vec<f64> = (0..n).map(|i| outputs[i * num_classes] as f64).collect();
                let lab: Vec<f64> = labels.iter().map(|&x| x as f64).collect();
                spearman(&pred, &lab)
            }
            _ => {
                let pred: Vec<u32> = (0..n).map(|i| argmax(&outputs[i * num_classes..(i + 1) * num_classes])).collect();
                let lab: Vec<u32> = labels.iter().map(|&x| x as u32).collect();
                match self {
                    Metric::Accuracy => accuracy(&pred, &lab),
                    Metric::F1 => f1_binary(&pred, &lab),
                    Metric::Matthews => matthews(&pred, &lab),
                    Metric::Spearman => unreachable!(),
                }
            }
        }
    }
}

pub fn argmax(row: &[f32]) -> u32 {
    let mut best = 0;
    for (i, v) in row.iter().enumerate() {
        if *v > row[best] {
            best = i;
        }
    }
    best as u32
}

pub fn accuracy(pred: &[u32], labels: &[u32]) -> f64 {
    assert_eq!(pred.len(), labels.len());
    if pred.is_empty() {
        return 0.0;
    }
    let hit = pred.iter().zip(labels).filter(|(a, b)| a == b).count();
    hit as f64 / pred.len() as f64
}

fn counts(pred: &[u32], labels: &[u32]) -> (f64, f64, f64, f64) {
    let mut tp = 0.0;
    let mut tn = 0.0;
    let mut fp = 0.0;
    let mut fnn = 0.0;
    for (&p, &y) in pred.iter().zip(labels) {
        match (p, y) {
            (1, 1) => tp += 1.0,
            (0, 0) => tn += 1.0,
            (1, 0) => fp += 1.0,
            (0, 1) => fnn += 1.0,
            _ => {}
        }
    }
    (tp, tn, fp, fnn)
}

/// Binary F1 with class 1 as positive (paper: QQP, MRPC).
pub fn f1_binary(pred: &[u32], labels: &[u32]) -> f64 {
    let (tp, _tn, fp, fnn) = counts(pred, labels);
    let denom = 2.0 * tp + fp + fnn;
    if denom > 0.0 {
        2.0 * tp / denom
    } else {
        0.0
    }
}

/// Matthews correlation coefficient (paper: CoLA).
pub fn matthews(pred: &[u32], labels: &[u32]) -> f64 {
    let (tp, tn, fp, fnn) = counts(pred, labels);
    let denom = ((tp + fp) * (tp + fnn) * (tn + fp) * (tn + fnn)).sqrt();
    if denom > 0.0 {
        (tp * tn - fp * fnn) / denom
    } else {
        0.0
    }
}

fn ranks(xs: &[f64]) -> Vec<f64> {
    let mut idx: Vec<usize> = (0..xs.len()).collect();
    idx.sort_by(|&a, &b| xs[a].partial_cmp(&xs[b]).unwrap());
    let mut r = vec![0.0; xs.len()];
    for (rank, &i) in idx.iter().enumerate() {
        r[i] = rank as f64;
    }
    r
}

/// Spearman rank correlation (paper: STS-B).
pub fn spearman(pred: &[f64], labels: &[f64]) -> f64 {
    let rp = ranks(pred);
    let ry = ranks(labels);
    let n = pred.len() as f64;
    let mp = rp.iter().sum::<f64>() / n;
    let my = ry.iter().sum::<f64>() / n;
    let mut num = 0.0;
    let mut dp = 0.0;
    let mut dy = 0.0;
    for i in 0..pred.len() {
        let a = rp[i] - mp;
        let b = ry[i] - my;
        num += a * b;
        dp += a * a;
        dy += b * b;
    }
    let denom = (dp * dy).sqrt();
    if denom > 0.0 {
        num / denom
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_basic() {
        assert_eq!(accuracy(&[1, 0, 1], &[1, 1, 1]), 2.0 / 3.0);
    }

    #[test]
    fn f1_perfect_and_zero() {
        assert_eq!(f1_binary(&[1, 0, 1], &[1, 0, 1]), 1.0);
        assert_eq!(f1_binary(&[0, 0], &[0, 0]), 0.0);
    }

    #[test]
    fn matthews_range() {
        // perfect prediction -> 1.0
        assert!((matthews(&[1, 0, 1, 0], &[1, 0, 1, 0]) - 1.0).abs() < 1e-12);
        // inverted prediction -> -1.0
        assert!((matthews(&[0, 1, 0, 1], &[1, 0, 1, 0]) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn spearman_monotone() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let up = [10.0, 20.0, 30.0, 40.0];
        let down = [4.0, 3.0, 2.0, 1.0];
        assert!((spearman(&x, &up) - 1.0).abs() < 1e-12);
        assert!((spearman(&x, &down) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn metric_compute_dispatch() {
        // 3 rows, 2 classes
        let outputs = [0.1, 0.9, 0.8, 0.2, 0.3, 0.7];
        let labels = [1.0, 0.0, 1.0];
        let acc = Metric::Accuracy.compute(&outputs, 2, &labels);
        assert!((acc - 1.0).abs() < 1e-12);
    }

    #[test]
    fn metric_parse() {
        assert_eq!(Metric::parse("f1"), Some(Metric::F1));
        assert_eq!(Metric::parse("nope"), None);
    }
}
