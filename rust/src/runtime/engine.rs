//! PJRT execution engine: loads HLO-text artifacts, keeps model weights
//! resident as device buffers, and runs batched inference.
//!
//! Split for the multi-worker execution pool:
//! * [`ArtifactStore`] — host half, `Send + Sync`: weights read from npz
//!   once (plain f32 tensors in lowered parameter order) plus the validated
//!   `(batch, seq)` HLO grid. Shared by every worker behind an `Arc`.
//! * [`EngineWorker`] — device half, pinned to one thread: PJRT client,
//!   compiled executables and device-resident weight buffers. PJRT objects
//!   are not `Send`, so each worker owns its own and only host artifacts
//!   cross threads.
//! * [`Engine`] — the seed's single-worker facade (CLI eval, benches): one
//!   store + one worker behind the original `new`/`load`/`get` API.
//!
//! Weights are transferred to the device ONCE per worker at load, and every
//! request then goes through `execute_b`, so the hot path moves only the
//! (tokens, segments) batch — this is the Rust analog of the paper's
//! "model stays on the GPU" serving setup. Executables are compiled per
//! `(batch, seq)` cell: the serving layer picks the smallest cell that fits
//! so padded word-vectors — the very thing PoWER-BERT eliminates inside the
//! model — are not re-introduced at the batch boundary.

use std::collections::{BTreeMap, HashMap};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Context, Result};
use xla::{FromRawBytes, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

use super::artifact::VariantMeta;
use crate::tokenizer::PAD_ID;

/// One compiled (batch, seq) cell of a variant.
struct Compiled {
    exe: PjRtLoadedExecutable,
}

/// Smallest compiled cell that fits `n` rows of `seq` tokens. `cells` must
/// be ascending `(seq, batch)` pairs; the search prefers the narrowest seq
/// bucket, then the smallest batch bucket within it (falling through to
/// wider seq rows when no batch there fits). Returns `(batch, seq)`.
pub fn pick_cell(cells: &[(usize, usize)], n: usize, seq: usize) -> Option<(usize, usize)> {
    cells
        .iter()
        .find(|&&(s, b)| s >= seq && b >= n)
        .map(|&(s, b)| (b, s))
}

/// Host-resident half of a loaded variant (weights + validated HLO paths).
pub struct ModelArtifact {
    pub meta: VariantMeta,
    /// (dims, f32 data) per parameter, lowered order.
    weights: Vec<(Vec<usize>, Vec<f32>)>,
    /// Ascending (seq, batch) -> HLO text path.
    hlo: BTreeMap<(usize, usize), PathBuf>,
}

impl ModelArtifact {
    fn load(meta: &VariantMeta) -> Result<ModelArtifact> {
        // Weights as named literals -> host tensors, reordered to match the
        // lowered module's parameter order from meta.json.
        let named: Vec<(String, Literal)> = Literal::read_npz(meta.weights_path(), &())
            .with_context(|| format!("read {}", meta.weights_path().display()))?;
        let mut by_name: HashMap<String, Literal> = named.into_iter().collect();
        let mut weights = Vec::with_capacity(meta.param_order.len());
        for name in &meta.param_order {
            let lit = by_name
                .remove(name)
                .ok_or_else(|| anyhow!("weights.npz missing param {name}"))?;
            let shape = lit.array_shape()?;
            let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
            let data: Vec<f32> = lit.to_vec()?;
            weights.push((dims, data));
        }
        let mut hlo = BTreeMap::new();
        for (batch, seq) in meta.grid_cells() {
            let path = meta
                .grid_path(batch, seq)
                .ok_or_else(|| anyhow!("grid cell (b{batch}, s{seq}) has no HLO file"))?;
            if !path.exists() {
                bail!("HLO file {} missing for cell (b{batch}, s{seq})", path.display());
            }
            hlo.insert((seq, batch), path);
        }
        if hlo.is_empty() {
            bail!("variant {}/{} has no HLO files", meta.dataset, meta.variant);
        }
        Ok(ModelArtifact { meta: meta.clone(), weights, hlo })
    }
}

/// Thread-safe store of host artifacts, shared by all workers: the weights
/// npz is read and validated once per variant, however many workers serve it.
#[derive(Default)]
pub struct ArtifactStore {
    models: Mutex<HashMap<String, Arc<ModelArtifact>>>,
}

impl ArtifactStore {
    pub fn new() -> ArtifactStore {
        ArtifactStore::default()
    }

    fn key(dataset: &str, variant: &str) -> String {
        format!("{dataset}/{variant}")
    }

    /// Host artifact for a variant, loading (and caching) it on first use.
    /// The lock is not held across the npz read, so workers loading
    /// *different* variants proceed in parallel; two racing loads of the
    /// same variant both succeed and the first insert wins (the loser's
    /// copy is dropped — wasted IO, never wrong data).
    pub fn fetch(&self, meta: &VariantMeta) -> Result<Arc<ModelArtifact>> {
        let key = Self::key(&meta.dataset, &meta.variant);
        if let Some(m) = self.models.lock().unwrap().get(&key) {
            return Ok(m.clone());
        }
        let t0 = std::time::Instant::now();
        let art = Arc::new(ModelArtifact::load(meta)?);
        crate::info!(
            "store",
            "loaded host artifact {key} ({} params, {} cells) in {:.2}s",
            art.weights.len(),
            art.hlo.len(),
            t0.elapsed().as_secs_f64()
        );
        let mut models = self.models.lock().unwrap();
        Ok(models.entry(key).or_insert(art).clone())
    }

    pub fn loaded(&self) -> Vec<String> {
        let mut v: Vec<String> = self.models.lock().unwrap().keys().cloned().collect();
        v.sort();
        v
    }
}

/// A loaded model variant on one worker: compiled executables (one per
/// (batch, seq) cell) plus device-resident weights in lowered order.
pub struct LoadedModel {
    pub meta: VariantMeta,
    /// Ascending (seq, batch) -> executable.
    compiled: BTreeMap<(usize, usize), Compiled>,
    weights: Vec<PjRtBuffer>,
    client: Arc<PjRtClient>,
}

/// Output of one forward execution.
#[derive(Debug, Clone)]
pub struct Logits {
    /// Row-major [batch, num_classes].
    pub values: Vec<f32>,
    pub batch: usize,
    pub num_classes: usize,
}

impl Logits {
    pub fn row(&self, i: usize) -> &[f32] {
        &self.values[i * self.num_classes..(i + 1) * self.num_classes]
    }

    pub fn argmax(&self, i: usize) -> usize {
        let r = self.row(i);
        // total_cmp: NaN logits (a poisoned model is a serving reality)
        // must not panic the executor; NaN sorts below every real value.
        r.iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(j, _)| j)
            .unwrap_or(0)
    }
}

impl LoadedModel {
    /// Largest compiled batch size across all seq buckets.
    pub fn max_batch(&self) -> usize {
        self.compiled.keys().map(|&(_, b)| b).max().unwrap_or(1)
    }

    /// Ascending (seq, batch) cells as (batch, seq) pairs.
    pub fn cells(&self) -> Vec<(usize, usize)> {
        self.compiled.keys().map(|&(s, b)| (b, s)).collect()
    }

    /// Smallest compiled (batch, seq) cell that fits `n` rows of `seq`
    /// tokens; `None` when `n` exceeds every compiled batch bucket.
    pub fn cell_for(&self, n: usize, seq: usize) -> Option<(usize, usize)> {
        let cells: Vec<(usize, usize)> = self.compiled.keys().copied().collect();
        pick_cell(&cells, n, seq)
    }

    /// Smallest compiled batch bucket that fits `n` rows at the full
    /// sequence length (`None` when `n` is too large for every bucket).
    pub fn bucket_for(&self, n: usize) -> Option<usize> {
        self.cell_for(n, self.meta.seq_len).map(|(b, _)| b)
    }

    /// Distinct compiled batch sizes, ascending.
    pub fn batch_sizes(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self.compiled.keys().map(|&(_, b)| b).collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Distinct compiled seq buckets, ascending.
    pub fn seq_buckets(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self.compiled.keys().map(|&(s, _)| s).collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Run a forward pass over rows of the full sequence length (the seed's
    /// original entry point — byte-identical on single-seq bundles).
    pub fn infer(&self, tokens: &[i32], segments: &[i32], n: usize) -> Result<Logits> {
        self.infer_at(tokens, segments, n, self.meta.seq_len)
    }

    /// Run a forward pass. `tokens`/`segments` are row-major [n, seq]; the
    /// smallest compiled (batch, seq) cell that fits is chosen, rows are
    /// padded to its batch bucket and columns to its seq bucket. Errors
    /// (rather than silently truncating) when `n` exceeds every compiled
    /// batch bucket or `seq` every compiled seq bucket.
    pub fn infer_at(&self, tokens: &[i32], segments: &[i32], n: usize, seq: usize) -> Result<Logits> {
        if n == 0 {
            bail!("infer: empty batch");
        }
        if tokens.len() != n * seq || segments.len() != n * seq {
            bail!("infer: expected {}x{} tokens, got {}", n, seq, tokens.len());
        }
        let (bucket, seq_bucket) = self.cell_for(n, seq).ok_or_else(|| {
            anyhow!(
                "infer: batch of {n} rows at seq {seq} fits no compiled cell of {}/{} \
                 (max batch {}, seq buckets {:?}) — split the batch upstream",
                self.meta.dataset,
                self.meta.variant,
                self.max_batch(),
                self.seq_buckets(),
            )
        })?;
        let c = self
            .compiled
            .get(&(seq_bucket, bucket))
            .ok_or_else(|| anyhow!("no compiled cell (b{bucket}, s{seq_bucket})"))?;

        // Pad rows to the batch bucket and columns to the seq bucket. NOTE:
        // inputs go through buffer_from_host_buffer (synchronous copy,
        // kImmutableOnlyDuringCall) — buffer_from_host_literal is an async
        // copy that may outlive the source Literal and segfault.
        let dims = [bucket, seq_bucket];
        let (tok_buf, seg_buf) = if n == bucket && seq == seq_bucket {
            (
                self.client.buffer_from_host_buffer(tokens, &dims, None)?,
                self.client.buffer_from_host_buffer(segments, &dims, None)?,
            )
        } else {
            let (t, s) = pad_rows(tokens, segments, n, seq, bucket, seq_bucket);
            (
                self.client.buffer_from_host_buffer(&t, &dims, None)?,
                self.client.buffer_from_host_buffer(&s, &dims, None)?,
            )
        };

        let mut args: Vec<&PjRtBuffer> = Vec::with_capacity(2 + self.weights.len());
        args.push(&tok_buf);
        args.push(&seg_buf);
        args.extend(self.weights.iter());

        let result = c.exe.execute_b(&args)?;
        let out = result[0][0].to_literal_sync()?;
        let mut tuple = out.to_tuple()?;
        let logits_lit = tuple
            .drain(..1)
            .next()
            .ok_or_else(|| anyhow!("empty result tuple"))?;
        let all: Vec<f32> = logits_lit.to_vec()?;
        let num_classes = all.len() / bucket;
        Ok(Logits {
            values: all[..n * num_classes].to_vec(),
            batch: n,
            num_classes,
        })
    }

    /// Debug variants: returns (logits, kept positions [n, L, N] as i32).
    /// Debug bundles are compiled at the full sequence length only.
    pub fn infer_with_trace(&self, tokens: &[i32], segments: &[i32], n: usize)
        -> Result<(Logits, Vec<i32>)> {
        let seq = self.meta.seq_len;
        if tokens.len() != n * seq || segments.len() != n * seq {
            bail!("infer_with_trace: expected {}x{} tokens, got {}", n, seq, tokens.len());
        }
        let (bucket, seq_bucket) = self.cell_for(n, seq).ok_or_else(|| {
            anyhow!(
                "infer_with_trace: batch of {n} rows exceeds the largest compiled bucket {}",
                self.max_batch()
            )
        })?;
        let c = self
            .compiled
            .get(&(seq_bucket, bucket))
            .ok_or_else(|| anyhow!("no compiled cell (b{bucket}, s{seq_bucket})"))?;
        let (t, s) = pad_rows(tokens, segments, n, seq, bucket, seq_bucket);
        let dims = [bucket, seq_bucket];
        let tok_buf = self.client.buffer_from_host_buffer(&t, &dims, None)?;
        let seg_buf = self.client.buffer_from_host_buffer(&s, &dims, None)?;
        let mut args: Vec<&PjRtBuffer> = vec![&tok_buf, &seg_buf];
        args.extend(self.weights.iter());
        let result = c.exe.execute_b(&args)?;
        let out = result[0][0].to_literal_sync()?;
        let tuple = out.to_tuple()?;
        if tuple.len() != 2 {
            bail!("debug artifact must return (logits, kept), got {}-tuple", tuple.len());
        }
        let logits: Vec<f32> = tuple[0].to_vec()?;
        let kept: Vec<i32> = tuple[1].to_vec()?;
        let num_classes = logits.len() / bucket;
        Ok((
            Logits { values: logits[..n * num_classes].to_vec(), batch: n, num_classes },
            kept,
        ))
    }
}

/// Pad `n` rows of `seq` tokens/segments out to a [bucket, seq_bucket]
/// rectangle: PAD tokens on the right of each row, PAD rows at the bottom.
fn pad_rows(
    tokens: &[i32],
    segments: &[i32],
    n: usize,
    seq: usize,
    bucket: usize,
    seq_bucket: usize,
) -> (Vec<i32>, Vec<i32>) {
    let mut t = vec![PAD_ID; bucket * seq_bucket];
    let mut s = vec![0i32; bucket * seq_bucket];
    for i in 0..n {
        t[i * seq_bucket..i * seq_bucket + seq].copy_from_slice(&tokens[i * seq..(i + 1) * seq]);
        s[i * seq_bucket..i * seq_bucket + seq].copy_from_slice(&segments[i * seq..(i + 1) * seq]);
    }
    (t, s)
}

/// One worker of the execution pool: owns a PJRT client plus the device
/// state (compiled cells, weight buffers) for every variant it has served.
/// Not `Send` — it lives and dies on its executor thread; host artifacts
/// come from the shared [`ArtifactStore`].
pub struct EngineWorker {
    id: usize,
    client: Arc<PjRtClient>,
    store: Arc<ArtifactStore>,
    models: HashMap<String, Arc<LoadedModel>>,
}

impl EngineWorker {
    pub fn new(id: usize, store: Arc<ArtifactStore>) -> Result<EngineWorker> {
        let client = Arc::new(PjRtClient::cpu().context("create PJRT CPU client")?);
        Ok(EngineWorker { id, client, store, models: HashMap::new() })
    }

    pub fn id(&self) -> usize {
        self.id
    }

    pub fn client(&self) -> &Arc<PjRtClient> {
        &self.client
    }

    pub fn store(&self) -> &Arc<ArtifactStore> {
        &self.store
    }

    /// Compile every (batch, seq) cell of a variant on this worker and
    /// upload its weights to this worker's device.
    pub fn load(&mut self, meta: &VariantMeta) -> Result<Arc<LoadedModel>> {
        let key = ArtifactStore::key(&meta.dataset, &meta.variant);
        if let Some(m) = self.models.get(&key) {
            return Ok(m.clone());
        }
        let art = self.store.fetch(meta)?;
        let t0 = std::time::Instant::now();
        // Synchronous host->device copy (see note in `infer_at`): raw f32
        // data + dims instead of the async literal path.
        let mut weights = Vec::with_capacity(art.weights.len());
        for (dims, data) in &art.weights {
            weights.push(self.client.buffer_from_host_buffer(data, dims, None)?);
        }
        let mut compiled = BTreeMap::new();
        for (&(seq, batch), path) in &art.hlo {
            let exe = self.compile_hlo(path)?;
            compiled.insert((seq, batch), Compiled { exe });
        }
        let model = Arc::new(LoadedModel {
            meta: art.meta.clone(),
            compiled,
            weights,
            client: self.client.clone(),
        });
        crate::info!(
            "engine",
            "worker {} loaded {key} ({} params, {} cells) in {:.2}s",
            self.id,
            model.weights.len(),
            model.compiled.len(),
            t0.elapsed().as_secs_f64()
        );
        self.models.insert(key, model.clone());
        Ok(model)
    }

    fn compile_hlo(&self, path: &Path) -> Result<PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parse {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        Ok(self.client.compile(&comp)?)
    }

    pub fn get(&self, dataset: &str, variant: &str) -> Option<Arc<LoadedModel>> {
        self.models.get(&ArtifactStore::key(dataset, variant)).cloned()
    }

    pub fn loaded(&self) -> Vec<String> {
        let mut v: Vec<String> = self.models.keys().cloned().collect();
        v.sort();
        v
    }
}

/// Single-worker facade over the pool pieces — the seed's original API for
/// the CLI `eval` path, benches and examples.
pub struct Engine {
    store: Arc<ArtifactStore>,
    worker: EngineWorker,
}

impl Engine {
    pub fn new() -> Result<Engine> {
        let store = Arc::new(ArtifactStore::new());
        let worker = EngineWorker::new(0, store.clone())?;
        Ok(Engine { store, worker })
    }

    pub fn client(&self) -> &Arc<PjRtClient> {
        self.worker.client()
    }

    pub fn store(&self) -> &Arc<ArtifactStore> {
        &self.store
    }

    /// Compile all (batch, seq) cells of a variant and upload its weights.
    pub fn load(&mut self, meta: &VariantMeta) -> Result<Arc<LoadedModel>> {
        self.worker.load(meta)
    }

    pub fn get(&self, dataset: &str, variant: &str) -> Option<Arc<LoadedModel>> {
        self.worker.get(dataset, variant)
    }

    pub fn loaded(&self) -> Vec<String> {
        self.worker.loaded()
    }
}

/// Test-split arrays read from `test.npz`.
pub struct TestSplit {
    pub tokens: Vec<i32>,
    pub segments: Vec<i32>,
    pub labels: Vec<f32>,
    pub n: usize,
    pub seq_len: usize,
}

impl TestSplit {
    pub fn load(path: &Path) -> Result<TestSplit> {
        let named = Literal::read_npz(path, &())
            .with_context(|| format!("read {}", path.display()))?;
        let mut tokens = None;
        let mut segments = None;
        let mut labels = None;
        let mut shape = (0usize, 0usize);
        for (name, lit) in named {
            match name.as_str() {
                "tokens" => {
                    let s = lit.array_shape()?;
                    shape = (s.dims()[0] as usize, s.dims()[1] as usize);
                    tokens = Some(lit.to_vec::<i32>()?);
                }
                "segs" => segments = Some(lit.to_vec::<i32>()?),
                "labels" => labels = Some(lit.to_vec::<f32>()?),
                _ => {}
            }
        }
        Ok(TestSplit {
            tokens: tokens.ok_or_else(|| anyhow!("test.npz missing tokens"))?,
            segments: segments.ok_or_else(|| anyhow!("test.npz missing segs"))?,
            labels: labels.ok_or_else(|| anyhow!("test.npz missing labels"))?,
            n: shape.0,
            seq_len: shape.1,
        })
    }

    pub fn row(&self, i: usize) -> (&[i32], &[i32]) {
        let s = self.seq_len;
        (&self.tokens[i * s..(i + 1) * s], &self.segments[i * s..(i + 1) * s])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_ignores_nan() {
        // Row 0 has a NaN — must not panic, and the NaN must never win.
        let l = Logits {
            values: vec![f32::NAN, 0.2, 0.9, 0.7, 0.1, 0.3],
            batch: 2,
            num_classes: 3,
        };
        assert_eq!(l.argmax(0), 2);
        assert_eq!(l.argmax(1), 0);
        // An all-NaN row settles on a valid index rather than panicking.
        let all_nan = Logits { values: vec![f32::NAN; 3], batch: 1, num_classes: 3 };
        assert!(all_nan.argmax(0) < 3);
    }

    #[test]
    fn pick_cell_prefers_narrow_seq_then_small_batch() {
        // Grid: seq 16 with batches {1, 8}, seq 64 with batches {1, 8, 32}.
        let cells = vec![(16, 1), (16, 8), (64, 1), (64, 8), (64, 32)];
        assert_eq!(pick_cell(&cells, 1, 10), Some((1, 16)));
        assert_eq!(pick_cell(&cells, 5, 16), Some((8, 16)));
        // Batch 20 fits no seq-16 bucket -> falls through to the 64 row.
        assert_eq!(pick_cell(&cells, 20, 10), Some((32, 64)));
        assert_eq!(pick_cell(&cells, 8, 40), Some((8, 64)));
        // Oversize in either dimension: no cell.
        assert_eq!(pick_cell(&cells, 33, 10), None);
        assert_eq!(pick_cell(&cells, 1, 100), None);
    }

    #[test]
    fn pad_rows_pads_columns_and_rows() {
        let tokens = vec![2, 5, 3, 2, 6, 3];
        let segs = vec![0, 0, 0, 0, 1, 1];
        let (t, s) = pad_rows(&tokens, &segs, 2, 3, 4, 5);
        assert_eq!(t.len(), 20);
        assert_eq!(&t[0..5], &[2, 5, 3, PAD_ID, PAD_ID]);
        assert_eq!(&t[5..10], &[2, 6, 3, PAD_ID, PAD_ID]);
        assert!(t[10..].iter().all(|&x| x == PAD_ID));
        assert_eq!(&s[5..10], &[0, 1, 1, 0, 0]);
        assert!(s[10..].iter().all(|&x| x == 0));
    }
}
