//! PJRT execution engine: loads HLO-text artifacts, keeps model weights
//! resident as device buffers, and runs batched inference.
//!
//! Weights are transferred to the device ONCE at load (`PjRtBuffer::read_npz`)
//! and every request then goes through `execute_b`, so the hot path moves only
//! the (tokens, segments) batch — this is the Rust analog of the paper's
//! "model stays on the GPU" serving setup.

use std::collections::{BTreeMap, HashMap};
use std::path::Path;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};
use xla::{FromRawBytes, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

use super::artifact::VariantMeta;

/// One compiled batch-size bucket of a variant.
struct Compiled {
    exe: PjRtLoadedExecutable,
}

/// A loaded model variant: compiled executables (one per batch size) plus
/// device-resident weights in the lowered parameter order.
pub struct LoadedModel {
    pub meta: VariantMeta,
    compiled: BTreeMap<usize, Compiled>,
    weights: Vec<PjRtBuffer>,
    client: Arc<PjRtClient>,
}

/// Output of one forward execution.
#[derive(Debug, Clone)]
pub struct Logits {
    /// Row-major [batch, num_classes].
    pub values: Vec<f32>,
    pub batch: usize,
    pub num_classes: usize,
}

impl Logits {
    pub fn row(&self, i: usize) -> &[f32] {
        &self.values[i * self.num_classes..(i + 1) * self.num_classes]
    }

    pub fn argmax(&self, i: usize) -> usize {
        let r = self.row(i);
        r.iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(j, _)| j)
            .unwrap_or(0)
    }
}

impl LoadedModel {
    /// Largest compiled batch size.
    pub fn max_batch(&self) -> usize {
        self.compiled.keys().max().copied().unwrap_or(1)
    }

    /// Smallest compiled batch size that fits `n` rows (or the max bucket).
    pub fn bucket_for(&self, n: usize) -> usize {
        self.compiled
            .keys()
            .copied()
            .find(|&b| b >= n)
            .unwrap_or_else(|| self.max_batch())
    }

    pub fn batch_sizes(&self) -> Vec<usize> {
        self.compiled.keys().copied().collect()
    }

    /// Run a forward pass. `tokens`/`segments` are row-major [n, seq_len]
    /// with n <= the chosen bucket; rows are zero-padded up to the bucket.
    pub fn infer(&self, tokens: &[i32], segments: &[i32], n: usize) -> Result<Logits> {
        let seq = self.meta.seq_len;
        if tokens.len() != n * seq || segments.len() != n * seq {
            bail!("infer: expected {}x{} tokens, got {}", n, seq, tokens.len());
        }
        let bucket = self.bucket_for(n);
        let c = self
            .compiled
            .get(&bucket)
            .ok_or_else(|| anyhow!("no compiled bucket {bucket}"))?;

        // Pad the batch to the bucket size with PAD rows. NOTE: inputs go
        // through buffer_from_host_buffer (synchronous copy,
        // kImmutableOnlyDuringCall) — buffer_from_host_literal is an async
        // copy that may outlive the source Literal and segfault.
        let dims = [bucket, seq];
        let (tok_buf, seg_buf) = if n == bucket {
            (
                self.client.buffer_from_host_buffer(tokens, &dims, None)?,
                self.client.buffer_from_host_buffer(segments, &dims, None)?,
            )
        } else {
            let mut t = tokens.to_vec();
            let mut s = segments.to_vec();
            t.resize(bucket * seq, 0);
            s.resize(bucket * seq, 0);
            (
                self.client.buffer_from_host_buffer(&t, &dims, None)?,
                self.client.buffer_from_host_buffer(&s, &dims, None)?,
            )
        };

        let mut args: Vec<&PjRtBuffer> = Vec::with_capacity(2 + self.weights.len());
        args.push(&tok_buf);
        args.push(&seg_buf);
        args.extend(self.weights.iter());

        let result = c.exe.execute_b(&args)?;
        let out = result[0][0].to_literal_sync()?;
        let mut tuple = out.to_tuple()?;
        let logits_lit = tuple
            .drain(..1)
            .next()
            .ok_or_else(|| anyhow!("empty result tuple"))?;
        let all: Vec<f32> = logits_lit.to_vec()?;
        let num_classes = all.len() / bucket;
        Ok(Logits {
            values: all[..n * num_classes].to_vec(),
            batch: n,
            num_classes,
        })
    }

    /// Debug variants: returns (logits, kept positions [n, L, N] as i32).
    pub fn infer_with_trace(&self, tokens: &[i32], segments: &[i32], n: usize)
        -> Result<(Logits, Vec<i32>)> {
        let seq = self.meta.seq_len;
        let bucket = self.bucket_for(n);
        let c = self
            .compiled
            .get(&bucket)
            .ok_or_else(|| anyhow!("no compiled bucket {bucket}"))?;
        let mut t = tokens.to_vec();
        let mut s = segments.to_vec();
        t.resize(bucket * seq, 0);
        s.resize(bucket * seq, 0);
        let dims = [bucket, seq];
        let tok_buf = self.client.buffer_from_host_buffer(&t, &dims, None)?;
        let seg_buf = self.client.buffer_from_host_buffer(&s, &dims, None)?;
        let mut args: Vec<&PjRtBuffer> = vec![&tok_buf, &seg_buf];
        args.extend(self.weights.iter());
        let result = c.exe.execute_b(&args)?;
        let out = result[0][0].to_literal_sync()?;
        let tuple = out.to_tuple()?;
        if tuple.len() != 2 {
            bail!("debug artifact must return (logits, kept), got {}-tuple", tuple.len());
        }
        let logits: Vec<f32> = tuple[0].to_vec()?;
        let kept: Vec<i32> = tuple[1].to_vec()?;
        let num_classes = logits.len() / bucket;
        Ok((
            Logits { values: logits[..n * num_classes].to_vec(), batch: n, num_classes },
            kept,
        ))
    }
}

/// The engine owns the PJRT client and the set of loaded models.
pub struct Engine {
    client: Arc<PjRtClient>,
    models: HashMap<String, Arc<LoadedModel>>,
}

impl Engine {
    pub fn new() -> Result<Engine> {
        let client = Arc::new(PjRtClient::cpu().context("create PJRT CPU client")?);
        Ok(Engine { client, models: HashMap::new() })
    }

    pub fn client(&self) -> &Arc<PjRtClient> {
        &self.client
    }

    fn key(dataset: &str, variant: &str) -> String {
        format!("{dataset}/{variant}")
    }

    /// Compile all batch-size buckets of a variant and upload its weights.
    pub fn load(&mut self, meta: &VariantMeta) -> Result<Arc<LoadedModel>> {
        let key = Self::key(&meta.dataset, &meta.variant);
        if let Some(m) = self.models.get(&key) {
            return Ok(m.clone());
        }
        let t0 = std::time::Instant::now();

        // Weights as named literals -> device buffers, reordered to match
        // the lowered module's parameter order from meta.json.
        let named: Vec<(String, Literal)> =
            Literal::read_npz(meta.weights_path(), &())
                .with_context(|| format!("read {}", meta.weights_path().display()))?;
        let mut by_name: HashMap<String, Literal> = named.into_iter().collect();
        let mut weights = Vec::with_capacity(meta.param_order.len());
        for name in &meta.param_order {
            let lit = by_name
                .remove(name)
                .ok_or_else(|| anyhow!("weights.npz missing param {name}"))?;
            // Synchronous host->device copy (see note in `infer`): raw f32
            // data + dims instead of the async literal path.
            let shape = lit.array_shape()?;
            let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
            let data: Vec<f32> = lit.to_vec()?;
            weights.push(self.client.buffer_from_host_buffer(&data, &dims, None)?);
        }

        let mut compiled = BTreeMap::new();
        for (&batch, file) in &meta.hlo {
            let path = meta.dir.join(file);
            let exe = self.compile_hlo(&path)?;
            compiled.insert(batch, Compiled { exe });
        }
        if compiled.is_empty() {
            bail!("variant {key} has no HLO files");
        }
        let model = Arc::new(LoadedModel {
            meta: meta.clone(),
            compiled,
            weights,
            client: self.client.clone(),
        });
        crate::info!(
            "engine",
            "loaded {key} ({} params, {} buckets) in {:.2}s",
            model.weights.len(),
            model.compiled.len(),
            t0.elapsed().as_secs_f64()
        );
        self.models.insert(key, model.clone());
        Ok(model)
    }

    fn compile_hlo(&self, path: &Path) -> Result<PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parse {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        Ok(self.client.compile(&comp)?)
    }

    pub fn get(&self, dataset: &str, variant: &str) -> Option<Arc<LoadedModel>> {
        self.models.get(&Self::key(dataset, variant)).cloned()
    }

    pub fn loaded(&self) -> Vec<String> {
        let mut v: Vec<String> = self.models.keys().cloned().collect();
        v.sort();
        v
    }
}

/// Test-split arrays read from `test.npz`.
pub struct TestSplit {
    pub tokens: Vec<i32>,
    pub segments: Vec<i32>,
    pub labels: Vec<f32>,
    pub n: usize,
    pub seq_len: usize,
}

impl TestSplit {
    pub fn load(path: &Path) -> Result<TestSplit> {
        let named = Literal::read_npz(path, &())
            .with_context(|| format!("read {}", path.display()))?;
        let mut tokens = None;
        let mut segments = None;
        let mut labels = None;
        let mut shape = (0usize, 0usize);
        for (name, lit) in named {
            match name.as_str() {
                "tokens" => {
                    let s = lit.array_shape()?;
                    shape = (s.dims()[0] as usize, s.dims()[1] as usize);
                    tokens = Some(lit.to_vec::<i32>()?);
                }
                "segs" => segments = Some(lit.to_vec::<i32>()?),
                "labels" => labels = Some(lit.to_vec::<f32>()?),
                _ => {}
            }
        }
        Ok(TestSplit {
            tokens: tokens.ok_or_else(|| anyhow!("test.npz missing tokens"))?,
            segments: segments.ok_or_else(|| anyhow!("test.npz missing segs"))?,
            labels: labels.ok_or_else(|| anyhow!("test.npz missing labels"))?,
            n: shape.0,
            seq_len: shape.1,
        })
    }

    pub fn row(&self, i: usize) -> (&[i32], &[i32]) {
        let s = self.seq_len;
        (&self.tokens[i * s..(i + 1) * s], &self.segments[i * s..(i + 1) * s])
    }
}
