//! Execution engine: host artifact store + backend-agnostic workers.
//!
//! Split for the multi-worker execution pool:
//! * [`ArtifactStore`] — host half, `Send + Sync`: weights read from npz
//!   once (plain f32 tensors in lowered parameter order, via the pure-Rust
//!   `util::npz` reader — no XLA involved) plus the validated `(batch,
//!   seq)` HLO grid. Shared by every worker behind an `Arc`.
//! * [`EngineWorker`] — backend half, pinned to one thread: resolves a
//!   [`BackendKind`] into loaded models. The `pjrt` backend owns a PJRT
//!   client and device buffers (not `Send`); the `native` backend runs the
//!   pure-Rust forward pass. `auto` prefers PJRT and falls back to native
//!   when the XLA runtime is unavailable (e.g. the vendored stub), so the
//!   stack serves real logits on any machine.
//! * [`Engine`] — the seed's single-worker facade (CLI eval, benches): one
//!   store + one worker behind the original `new`/`load`/`get` API.

use std::collections::{BTreeMap, HashMap};
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Context, Result};

use super::artifact::VariantMeta;
use super::backend::{BackendKind, LoadedModel};
use super::kernels::{KernelConfig, KernelExec};
use super::native::NativeBackend;
use super::pjrt::PjrtBackend;
use crate::util::npz;

/// Host-resident half of a loaded variant (weights + validated HLO paths).
pub struct ModelArtifact {
    pub meta: VariantMeta,
    /// (dims, f32 data) per parameter, lowered order.
    weights: Vec<(Vec<usize>, Vec<f32>)>,
    /// Ascending (seq, batch) -> HLO text path.
    hlo: BTreeMap<(usize, usize), PathBuf>,
}

impl ModelArtifact {
    fn load(meta: &VariantMeta) -> Result<ModelArtifact> {
        // Weights -> host tensors, reordered to match the lowered module's
        // parameter order from meta.json. When the bundle ships a signed
        // manifest, the npz bytes are streaming-hashed as they are read
        // and refused on digest mismatch (the error names the file and
        // both digests) — tampered weights never reach a worker.
        let entries = npz::read_npz_checked(&meta.weights_path(), meta.weights_check.as_ref())
            .with_context(|| format!("read {}", meta.weights_path().display()))?;
        let mut by_name: HashMap<String, npz::NpzEntry> =
            entries.into_iter().map(|e| (e.name.clone(), e)).collect();
        let mut weights = Vec::with_capacity(meta.param_order.len());
        for name in &meta.param_order {
            let e = by_name
                .remove(name)
                .ok_or_else(|| anyhow!("weights.npz missing param {name}"))?;
            let data = e.data.to_f32();
            weights.push((e.dims, data));
        }
        let mut hlo = BTreeMap::new();
        for (batch, seq) in meta.grid_cells() {
            let path = meta
                .grid_path(batch, seq)
                .ok_or_else(|| anyhow!("grid cell (b{batch}, s{seq}) has no HLO file"))?;
            if !path.exists() {
                bail!("HLO file {} missing for cell (b{batch}, s{seq})", path.display());
            }
            hlo.insert((seq, batch), path);
        }
        if hlo.is_empty() {
            bail!("variant {}/{} has no HLO files", meta.dataset, meta.variant);
        }
        Ok(ModelArtifact { meta: meta.clone(), weights, hlo })
    }

    /// Parameters as (dims, data), in lowered order.
    pub fn weights(&self) -> &[(Vec<usize>, Vec<f32>)] {
        &self.weights
    }

    /// Parameter lookup by exported name (e.g. "layers/2/wq").
    pub fn weight(&self, name: &str) -> Option<(&[usize], &[f32])> {
        self.meta
            .param_order
            .iter()
            .position(|n| n == name)
            .map(|i| (&self.weights[i].0[..], &self.weights[i].1[..]))
    }

    /// Validated (seq, batch) -> HLO text path map.
    pub fn hlo(&self) -> &BTreeMap<(usize, usize), PathBuf> {
        &self.hlo
    }
}

/// Thread-safe store of host artifacts, shared by all workers: the weights
/// npz is read and validated once per variant, however many workers serve it.
#[derive(Default)]
pub struct ArtifactStore {
    models: Mutex<HashMap<String, Arc<ModelArtifact>>>,
}

impl ArtifactStore {
    pub fn new() -> ArtifactStore {
        ArtifactStore::default()
    }

    pub(crate) fn key(dataset: &str, variant: &str) -> String {
        format!("{dataset}/{variant}")
    }

    /// Host artifact for a variant, loading (and caching) it on first use.
    /// The lock is not held across the npz read, so workers loading
    /// *different* variants proceed in parallel; two racing loads of the
    /// same variant both succeed and the first insert wins (the loser's
    /// copy is dropped — wasted IO, never wrong data).
    pub fn fetch(&self, meta: &VariantMeta) -> Result<Arc<ModelArtifact>> {
        let key = Self::key(&meta.dataset, &meta.variant);
        if let Some(m) = self.models.lock().unwrap().get(&key) {
            return Ok(m.clone());
        }
        let t0 = std::time::Instant::now();
        let art = Arc::new(ModelArtifact::load(meta)?);
        crate::info!(
            "store",
            "loaded host artifact {key} ({} params, {} cells) in {:.2}s",
            art.weights.len(),
            art.hlo.len(),
            t0.elapsed().as_secs_f64()
        );
        let mut models = self.models.lock().unwrap();
        Ok(models.entry(key).or_insert(art).clone())
    }

    pub fn loaded(&self) -> Vec<String> {
        let mut v: Vec<String> = self.models.lock().unwrap().keys().cloned().collect();
        v.sort();
        v
    }

    /// Already-loaded artifact for a `dataset/variant` key, if any.
    pub fn cached(&self, key: &str) -> Option<Arc<ModelArtifact>> {
        self.models.lock().unwrap().get(key).cloned()
    }

    /// Adopt a host artifact loaded elsewhere — the repository carry-over
    /// path moves unchanged variants from the old snapshot's store into
    /// the new one without re-reading their weights.
    pub fn adopt(&self, key: String, art: Arc<ModelArtifact>) {
        self.models.lock().unwrap().insert(key, art);
    }
}

/// One worker of the execution pool: resolves the configured backend into
/// loaded models and keeps them warm for every variant it has served.
/// Not `Send` — it lives and dies on its executor thread (PJRT state is
/// thread-pinned); host artifacts come from the shared [`ArtifactStore`].
pub struct EngineWorker {
    id: usize,
    kind: BackendKind,
    /// `None` when the selection is native-only, or `auto` could not
    /// create a PJRT client at all.
    pjrt: Option<PjrtBackend>,
    kernel: KernelConfig,
    /// Created eagerly for `native` workers (steady state from worker
    /// start), lazily on the first fallback load for `auto`, and never
    /// for pure-`pjrt` workers — so a PJRT deployment doesn't park a
    /// kernel pool it can never dispatch to.
    native: Option<NativeBackend>,
    store: Arc<ArtifactStore>,
    /// `key -> (host artifact, backend model)`. The artifact `Arc` is the
    /// cache tag: after a repository snapshot swap the store hands out a
    /// *different* `Arc` for a changed variant, which misses `ptr_eq` and
    /// forces a rebuild — workers re-pin on their next batch boundary
    /// without any explicit invalidation message.
    models: HashMap<String, (Arc<ModelArtifact>, Arc<LoadedModel>)>,
}

impl EngineWorker {
    /// Worker on the session-default backend (`$POWERBERT_BACKEND` or auto).
    pub fn new(id: usize, store: Arc<ArtifactStore>) -> Result<EngineWorker> {
        EngineWorker::with_backend(id, store, BackendKind::from_env())
    }

    /// Worker on an explicit backend, with the session-default kernel
    /// config (`$POWERBERT_KERNEL_*` or defaults).
    pub fn with_backend(
        id: usize,
        store: Arc<ArtifactStore>,
        kind: BackendKind,
    ) -> Result<EngineWorker> {
        EngineWorker::with_config(id, store, kind, KernelConfig::from_env())
    }

    /// Worker on an explicit backend and kernel config. The kernel config
    /// only tunes the native path (block sizes, intra-op threads); PJRT
    /// ignores it. For a `native` worker, `kernel.threads > 1` spawns the
    /// worker's persistent kernel pool here, once — every parallel kernel
    /// call for the rest of the worker's life dispatches to those parked
    /// threads (`auto` workers spawn it on their first native fallback
    /// load instead, and pure-`pjrt` workers never do). The pool is
    /// joined when the last model sharing it drops (after coordinator
    /// drain has flushed this worker's backlog).
    pub fn with_config(
        id: usize,
        store: Arc<ArtifactStore>,
        kind: BackendKind,
        kernel: KernelConfig,
    ) -> Result<EngineWorker> {
        let pjrt = match kind {
            BackendKind::Native => None,
            BackendKind::Pjrt => Some(PjrtBackend::new()?),
            BackendKind::Auto => match PjrtBackend::new() {
                Ok(b) => Some(b),
                Err(e) => {
                    crate::warnln!(
                        "engine",
                        "worker {id}: no PJRT client ({e:#}); native backend only"
                    );
                    None
                }
            },
        };
        let native = matches!(kind, BackendKind::Native)
            .then(|| NativeBackend::with_config(kernel.clone()));
        Ok(EngineWorker {
            id,
            kind,
            pjrt,
            kernel,
            native,
            store,
            models: HashMap::new(),
        })
    }

    /// The native backend, created on first use (see the field docs for
    /// when that happens per [`BackendKind`]).
    fn native_backend(&mut self) -> &NativeBackend {
        if self.native.is_none() {
            self.native = Some(NativeBackend::with_config(self.kernel.clone()));
        }
        self.native.as_ref().expect("just initialized")
    }

    pub fn id(&self) -> usize {
        self.id
    }

    /// The configured backend selection (not necessarily what `auto`
    /// resolved to — see [`LoadedModel::backend_name`] for the outcome).
    pub fn backend(&self) -> BackendKind {
        self.kind
    }

    pub fn store(&self) -> &Arc<ArtifactStore> {
        &self.store
    }

    /// The steady-state kernel execution resources (config + persistent
    /// pool) this worker's native models dispatch to; `None` until the
    /// native backend exists (pure-PJRT workers never create it).
    pub fn kernel_exec(&self) -> Option<&Arc<KernelExec>> {
        self.native.as_ref().map(|n| n.exec())
    }

    /// Load a variant on this worker's backend: compile + upload (pjrt) or
    /// bind the weights into the pure-Rust forward pass (native). Uses the
    /// worker's own construction-time store.
    pub fn load(&mut self, meta: &VariantMeta) -> Result<Arc<LoadedModel>> {
        let store = self.store.clone();
        self.load_from(&store, meta)
    }

    /// Load a variant resolving host artifacts through an explicit store —
    /// the batch path passes the store pinned by the job's repository
    /// snapshot, so a hot-swap re-pins this worker at its next batch.
    pub fn load_from(
        &mut self,
        store: &Arc<ArtifactStore>,
        meta: &VariantMeta,
    ) -> Result<Arc<LoadedModel>> {
        let key = ArtifactStore::key(&meta.dataset, &meta.variant);
        let art = store.fetch(meta)?;
        if let Some((cached_art, model)) = self.models.get(&key) {
            if Arc::ptr_eq(cached_art, &art) {
                return Ok(model.clone());
            }
        }
        let t0 = std::time::Instant::now();
        let model = match self.kind {
            BackendKind::Native => self.native_backend().load(&art)?,
            BackendKind::Pjrt => {
                let backend = self
                    .pjrt
                    .as_ref()
                    .ok_or_else(|| anyhow!("worker {} has no PJRT client", self.id))?;
                backend.load(&art)?
            }
            BackendKind::Auto => {
                // Per-variant fallback: one variant's broken HLO must not
                // latch PJRT off for variants that would compile fine (the
                // stub fails instantly anyway, so retrying is cheap).
                let via_pjrt = self.pjrt.as_ref().map(|backend| backend.load(&art));
                match via_pjrt {
                    Some(Ok(m)) => m,
                    Some(Err(e)) => {
                        crate::info!(
                            "engine",
                            "worker {}: PJRT unavailable for {key} ({e:#}); \
                             falling back to the native backend",
                            self.id
                        );
                        self.native_backend().load(&art)?
                    }
                    None => self.native_backend().load(&art)?,
                }
            }
        };
        let model = Arc::new(model);
        // Planned arena footprint (native): largest per-cell slab this
        // worker will hold resident for the variant, known before any
        // request runs.
        let arena_note = model
            .arena_cells()
            .iter()
            .map(|&(_, bytes)| bytes)
            .max()
            .map(|peak| format!(", arena ≤ {:.1} KiB/bucket", peak as f64 / 1024.0))
            .unwrap_or_default();
        crate::info!(
            "engine",
            "worker {} loaded {key} on {} ({} params, {} cells{arena_note}) in {:.2}s",
            self.id,
            model.backend_name(),
            art.weights.len(),
            model.cells().len(),
            t0.elapsed().as_secs_f64()
        );
        self.models.insert(key, (art, model.clone()));
        Ok(model)
    }

    pub fn get(&self, dataset: &str, variant: &str) -> Option<Arc<LoadedModel>> {
        self.models.get(&ArtifactStore::key(dataset, variant)).map(|(_, m)| m.clone())
    }

    pub fn loaded(&self) -> Vec<String> {
        let mut v: Vec<String> = self.models.keys().cloned().collect();
        v.sort();
        v
    }
}

/// Single-worker facade over the pool pieces — the seed's original API for
/// the CLI `eval` path, benches and examples.
pub struct Engine {
    store: Arc<ArtifactStore>,
    worker: EngineWorker,
}

impl Engine {
    /// Engine on the session-default backend (`$POWERBERT_BACKEND` or auto).
    pub fn new() -> Result<Engine> {
        Engine::with_backend(BackendKind::from_env())
    }

    pub fn with_backend(kind: BackendKind) -> Result<Engine> {
        Engine::with_backend_config(kind, KernelConfig::from_env())
    }

    /// Engine with an explicit backend and kernel config — what the bench
    /// and parity tests use to pin thread counts and block sizes.
    pub fn with_backend_config(kind: BackendKind, kernel: KernelConfig) -> Result<Engine> {
        let store = Arc::new(ArtifactStore::new());
        let worker = EngineWorker::with_config(0, store.clone(), kind, kernel)?;
        Ok(Engine { store, worker })
    }

    pub fn store(&self) -> &Arc<ArtifactStore> {
        &self.store
    }

    pub fn backend(&self) -> BackendKind {
        self.worker.backend()
    }

    /// The worker's steady-state kernel execution resources (`None` until
    /// the native backend exists — see [`EngineWorker::kernel_exec`]).
    pub fn kernel_exec(&self) -> Option<&Arc<KernelExec>> {
        self.worker.kernel_exec()
    }

    /// Load a variant on the configured backend.
    pub fn load(&mut self, meta: &VariantMeta) -> Result<Arc<LoadedModel>> {
        self.worker.load(meta)
    }

    pub fn get(&self, dataset: &str, variant: &str) -> Option<Arc<LoadedModel>> {
        self.worker.get(dataset, variant)
    }

    pub fn loaded(&self) -> Vec<String> {
        self.worker.loaded()
    }
}

/// Test-split arrays read from `test.npz`.
pub struct TestSplit {
    pub tokens: Vec<i32>,
    pub segments: Vec<i32>,
    pub labels: Vec<f32>,
    pub n: usize,
    pub seq_len: usize,
}

impl TestSplit {
    pub fn load(path: &Path) -> Result<TestSplit> {
        TestSplit::load_checked(path, None)
    }

    /// Load with an optional repository digest: the npz bytes are hashed
    /// as they stream in and refused on mismatch (see
    /// [`DatasetArtifacts::test_check`](super::DatasetArtifacts)).
    pub fn load_checked(
        path: &Path,
        check: Option<&crate::util::hash::ExpectedDigest>,
    ) -> Result<TestSplit> {
        let entries = npz::read_npz_checked(path, check)?;
        let mut tokens = None;
        let mut segments = None;
        let mut labels = None;
        let mut shape = (0usize, 0usize);
        for e in entries {
            match e.name.as_str() {
                "tokens" => {
                    if e.dims.len() != 2 {
                        bail!("test.npz tokens: shape {:?}, expected rank 2", e.dims);
                    }
                    shape = (e.dims[0], e.dims[1]);
                    tokens = Some(e.data.to_i32());
                }
                "segs" => segments = Some(e.data.to_i32()),
                "labels" => labels = Some(e.data.to_f32()),
                _ => {}
            }
        }
        Ok(TestSplit {
            tokens: tokens.ok_or_else(|| anyhow!("test.npz missing tokens"))?,
            segments: segments.ok_or_else(|| anyhow!("test.npz missing segs"))?,
            labels: labels.ok_or_else(|| anyhow!("test.npz missing labels"))?,
            n: shape.0,
            seq_len: shape.1,
        })
    }

    pub fn row(&self, i: usize) -> (&[i32], &[i32]) {
        let s = self.seq_len;
        (&self.tokens[i * s..(i + 1) * s], &self.segments[i * s..(i + 1) * s])
    }
}
