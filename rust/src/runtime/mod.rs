//! Runtime layer: artifact registry, pluggable inference backends, model
//! loading and batched execution. Python is never on this path — the Rust
//! binary is self-contained once `make artifacts` has produced the AOT
//! bundle, and with the `native` backend it needs no XLA runtime either.
//!
//! Execution is split into a shared, `Send` [`ArtifactStore`] (parsed
//! manifests + host weights) and per-thread [`EngineWorker`]s that resolve
//! a [`BackendKind`] — `pjrt` (compiled HLO on an XLA device, non-`Send`),
//! `native` (pure-Rust PoWER-BERT forward pass with progressive word-vector
//! elimination) or `auto` (PJRT with native fallback). [`Engine`] is the
//! single-worker facade.
//!
//! The native path executes in **steady state**: each worker owns a
//! persistent [`kernels::pool::KernelPool`] (via [`KernelExec`]) and
//! per-bucket [`arena::ForwardArena`] scratch slabs planned from the
//! retention schedule, so the per-request hot path neither spawns threads
//! nor allocates after warmup.

pub mod adaptive;
pub mod arena;
pub mod artifact;
pub mod backend;
pub mod engine;
pub mod kernels;
pub mod native;
pub mod pjrt;
pub mod repo;

pub use adaptive::{demanded_k, ParetoPoint, ParetoTable, RetentionPolicy};
pub use arena::{ArenaDims, ArenaPlan, ForwardArena};
pub use artifact::{default_root, DatasetArtifacts, Registry, VariantMeta};
pub use repo::{Checks, FileDigest, FileStatus, Manifest, Repo, RepoPolicy, RepoSnapshot};
pub use backend::{
    BackendKind, CellExecutor, CellPlan, ExecOutput, LoadedModel, Logits, MemoryStats,
};
pub use engine::{ArtifactStore, Engine, EngineWorker, ModelArtifact, TestSplit};
pub use kernels::{active_isa, simd_active, KernelConfig, KernelExec, Precision};
pub use native::{NativeBackend, NativeModel};
pub use pjrt::PjrtBackend;
