//! Runtime layer: PJRT client wrapper, artifact registry, model loading and
//! batched execution. Python is never on this path — the Rust binary is
//! self-contained once `make artifacts` has produced the AOT bundle.
//!
//! Execution is split into a shared, `Send` [`ArtifactStore`] (parsed
//! manifests + host weights) and per-thread [`EngineWorker`]s that own the
//! non-`Send` PJRT state — the coordinator runs one worker per executor
//! thread against the one store. [`Engine`] is the single-worker facade.

pub mod artifact;
pub mod engine;

pub use artifact::{default_root, DatasetArtifacts, Registry, VariantMeta};
pub use engine::{ArtifactStore, Engine, EngineWorker, LoadedModel, Logits, TestSplit};
