//! Runtime layer: PJRT client wrapper, artifact registry, model loading and
//! batched execution. Python is never on this path — the Rust binary is
//! self-contained once `make artifacts` has produced the AOT bundle.

pub mod artifact;
pub mod engine;

pub use artifact::{default_root, DatasetArtifacts, Registry, VariantMeta};
pub use engine::{Engine, LoadedModel, Logits, TestSplit};
