//! PJRT backend: compiles the exported HLO text through an XLA PJRT client
//! and keeps model weights resident as device buffers.
//!
//! This is the seed's execution path, now behind the [`CellExecutor`]
//! abstraction. PJRT objects are not `Send`, so a backend instance (and
//! every model it loads) is pinned to its worker thread; host artifacts
//! come from the shared `ArtifactStore`. Weights are transferred to the
//! device ONCE per worker at load, and every request then moves only the
//! (tokens, segments) batch — the Rust analog of the paper's "model stays
//! on the GPU" serving setup.
//!
//! With the vendored `xla` stub, compilation returns `Unavailable`; the
//! `auto` backend selection catches that and falls back to the native
//! backend instead.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};
use xla::{PjRtBuffer, PjRtClient, PjRtLoadedExecutable};

use super::backend::{CellExecutor, CellPlan, ExecOutput, LoadedModel};
use super::engine::ModelArtifact;

/// A PJRT client wrapper that loads artifacts into compiled executables.
pub struct PjrtBackend {
    client: Arc<PjRtClient>,
}

impl PjrtBackend {
    pub fn new() -> Result<PjrtBackend> {
        let client = Arc::new(PjRtClient::cpu().context("create PJRT CPU client")?);
        Ok(PjrtBackend { client })
    }

    pub fn client(&self) -> &Arc<PjRtClient> {
        &self.client
    }

    /// Compile every (batch, seq) cell of a variant on this worker's client
    /// and upload its weights to the device.
    pub fn load(&self, art: &ModelArtifact) -> Result<LoadedModel> {
        // Synchronous host->device copy (see note in `execute`): raw f32
        // data + dims instead of the async literal path.
        let mut weights = Vec::new();
        for (dims, data) in art.weights() {
            weights.push(self.client.buffer_from_host_buffer(data, dims, None)?);
        }
        let mut compiled = BTreeMap::new();
        for ((seq, batch), path) in art.hlo() {
            let exe = self.compile_hlo(path)?;
            compiled.insert((*seq, *batch), exe);
        }
        if compiled.is_empty() {
            bail!(
                "variant {}/{} has no HLO files",
                art.meta.dataset,
                art.meta.variant
            );
        }
        let cells: Vec<(usize, usize)> = compiled.keys().copied().collect();
        let exec = PjrtModel { client: self.client.clone(), compiled, weights };
        Ok(LoadedModel::new(
            art.meta.clone(),
            "pjrt",
            CellPlan::Grid(cells),
            Box::new(exec),
        ))
    }

    fn compile_hlo(&self, path: &Path) -> Result<PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parse {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        Ok(self.client.compile(&comp)?)
    }
}

/// One variant on one PJRT device: executables per (batch, seq) cell plus
/// device-resident weights in lowered parameter order.
struct PjrtModel {
    client: Arc<PjRtClient>,
    /// Ascending (seq, batch) -> executable.
    compiled: BTreeMap<(usize, usize), PjRtLoadedExecutable>,
    weights: Vec<PjRtBuffer>,
}

impl CellExecutor for PjrtModel {
    fn execute(
        &self,
        tokens: &[i32],
        segments: &[i32],
        batch: usize,
        seq: usize,
        _want_trace: bool,
        // Adaptive retention is a native-backend capability: the compiled
        // HLO bakes its schedule in, so the threshold is ignored here and
        // the scheduler falls back to fixed-schedule execution.
        _threshold: Option<f32>,
    ) -> Result<ExecOutput> {
        let exe = self
            .compiled
            .get(&(seq, batch))
            .ok_or_else(|| anyhow!("no compiled cell (b{batch}, s{seq})"))?;
        // NOTE: inputs go through buffer_from_host_buffer (synchronous
        // copy, kImmutableOnlyDuringCall) — buffer_from_host_literal is an
        // async copy that may outlive the source and segfault.
        let dims = [batch, seq];
        let tok_buf = self.client.buffer_from_host_buffer(tokens, &dims, None)?;
        let seg_buf = self.client.buffer_from_host_buffer(segments, &dims, None)?;
        let mut args: Vec<&PjRtBuffer> = Vec::with_capacity(2 + self.weights.len());
        args.push(&tok_buf);
        args.push(&seg_buf);
        args.extend(self.weights.iter());
        let result = exe.execute_b(&args)?;
        let out = result[0][0].to_literal_sync()?;
        let tuple = out.to_tuple()?;
        if tuple.is_empty() {
            bail!("empty result tuple");
        }
        let logits: Vec<f32> = tuple[0].to_vec()?;
        // Debug bundles return (logits, kept_positions i32[B, L, N]).
        let kept = if tuple.len() >= 2 {
            Some(tuple[1].to_vec::<i32>()?)
        } else {
            None
        };
        if logits.is_empty() || logits.len() % batch != 0 {
            bail!("logits of {} values for batch {batch}", logits.len());
        }
        Ok(ExecOutput { num_classes: logits.len() / batch, logits, kept, tokens_per_row: None })
    }
}
