//! Signed artifact repository + zero-downtime rollout snapshots.
//!
//! `artifacts/index.json` doubles as the repository **manifest**: alongside
//! the exporter's `profile`/`datasets` keys it may carry a `revision`
//! counter, a `files` map of per-file sha256 digests + sizes, and an
//! ed25519 `signature` over a canonical serialization of those digests
//! (`python -m compile.sign` stamps all three at export time; the
//! committed dev keypair lives at `artifacts/signing.key[.pub]`).
//!
//! [`Repo`] owns the serving side: [`Repo::open`] builds an immutable
//! [`RepoSnapshot`] — manifest verified, every listed file streaming-hashed,
//! datasets with a failing file excluded, registry scanned with digest
//! [`Checks`] attached so weights are re-verified as they load — and
//! [`Repo::reload`] builds a *new* snapshot off the hot path, then swaps it
//! in atomically. In-flight requests pin their snapshot `Arc` at routing
//! time and complete against the old store; new requests route to the new
//! one. A failed reload leaves the current snapshot untouched (that is the
//! zero-downtime contract: verification failures never take serving down).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::artifact::Registry;
use super::engine::ArtifactStore;
use crate::util::ed25519;
use crate::util::hash::{self, ExpectedDigest};
use crate::util::json::Json;

/// Domain-separation prefix of the canonical signing bytes. Bumping the
/// manifest schema bumps this string, invalidating old signatures.
pub const MANIFEST_DOMAIN: &str = "powerbert-manifest-v1";

/// Files the manifest never covers: the manifest itself, the signing
/// keypair next to it, derived analysis output, and editor/VCS droppings.
pub fn manifest_skips(name: &str) -> bool {
    name == "index.json"
        || name.starts_with("signing.")
        || name == "analysis"
        || name == "__pycache__"
        || name.starts_with('.')
}

/// Digest record of one artifact file, as stored in the manifest.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FileDigest {
    pub sha256: String,
    pub size: u64,
}

/// The manifest's `signature` block (all fields lowercase hex).
#[derive(Debug, Clone)]
pub struct Signature {
    pub algorithm: String,
    pub public_key: String,
    pub signature: String,
}

/// Parsed `index.json`. `extra` preserves the exporter's keys (`profile`,
/// `datasets`, ...) verbatim so re-signing never loses them.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub revision: u64,
    /// '/'-separated root-relative path -> digest. `None` for legacy
    /// manifests that predate the repository layer (nothing is checked).
    pub files: Option<BTreeMap<String, FileDigest>>,
    pub signature: Option<Signature>,
    pub extra: BTreeMap<String, Json>,
}

impl Manifest {
    /// Parse `<root>/index.json`. `Ok(None)` when the file does not exist
    /// (unmanaged bundle); `Err` when it exists but cannot be parsed — a
    /// corrupt manifest must read as tampering, not as "no checks".
    pub fn load(root: &Path) -> Result<Option<Manifest>, String> {
        let path = root.join("index.json");
        if !path.exists() {
            return Ok(None);
        }
        let j = Json::parse_file(&path)
            .map_err(|e| format!("manifest {}: {e}", path.display()))?;
        Manifest::from_json(&j).map(Some).map_err(|e| format!("manifest {}: {e}", path.display()))
    }

    pub fn from_json(j: &Json) -> Result<Manifest, String> {
        let obj = j.as_obj().ok_or("not a JSON object")?;
        let revision = j.get("revision").and_then(Json::as_u64).unwrap_or(0);
        let files = match j.get("files") {
            None => None,
            Some(f) => {
                let fo = f.as_obj().ok_or("\"files\" is not an object")?;
                let mut map = BTreeMap::new();
                for (rel, entry) in fo {
                    let sha256 = entry
                        .get("sha256")
                        .and_then(Json::as_str)
                        .ok_or_else(|| format!("files[{rel}] missing sha256"))?
                        .to_string();
                    let size = entry
                        .get("size")
                        .and_then(Json::as_u64)
                        .ok_or_else(|| format!("files[{rel}] missing size"))?;
                    map.insert(rel.clone(), FileDigest { sha256, size });
                }
                Some(map)
            }
        };
        let signature = match j.get("signature") {
            None => None,
            Some(s) => Some(Signature {
                algorithm: s
                    .get("algorithm")
                    .and_then(Json::as_str)
                    .unwrap_or("ed25519")
                    .to_string(),
                public_key: s
                    .get("public_key")
                    .and_then(Json::as_str)
                    .ok_or("signature missing public_key")?
                    .to_string(),
                signature: s
                    .get("signature")
                    .and_then(Json::as_str)
                    .ok_or("signature missing signature")?
                    .to_string(),
            }),
        };
        let extra = obj
            .iter()
            .filter(|(k, _)| !matches!(k.as_str(), "revision" | "files" | "signature"))
            .map(|(k, v)| (k.clone(), v.clone()))
            .collect();
        Ok(Manifest { revision, files, signature, extra })
    }

    /// Canonical bytes the signature covers: a domain line, the revision,
    /// then one `<relpath> <sha256> <size>` line per file in byte order.
    /// Both the Rust verifier and `python -m compile.sign` produce exactly
    /// these bytes, so the JSON formatting itself is never load-bearing.
    pub fn signing_bytes(revision: u64, files: &BTreeMap<String, FileDigest>) -> Vec<u8> {
        let mut out = format!("{MANIFEST_DOMAIN}\nrevision {revision}\n").into_bytes();
        for (rel, fd) in files {
            out.extend_from_slice(format!("{rel} {} {}\n", fd.sha256, fd.size).as_bytes());
        }
        out
    }

    /// Verify the manifest signature. When `trusted` is given, the
    /// manifest's embedded key must equal it (an attacker who re-signs with
    /// their own key must not pass); otherwise the embedded key verifies
    /// only internal consistency. Returns the key that verified.
    pub fn verify_signature(&self, trusted: Option<&[u8; 32]>) -> Result<[u8; 32], String> {
        let sig = self.signature.as_ref().ok_or("manifest is not signed")?;
        let files = self.files.as_ref().ok_or("signed manifest has no files map")?;
        if sig.algorithm != "ed25519" {
            return Err(format!("unsupported signature algorithm {}", sig.algorithm));
        }
        let key = parse_key(&sig.public_key, "manifest public_key")?;
        if let Some(t) = trusted {
            if *t != key {
                return Err(format!(
                    "manifest public key {} does not match the trusted key {}",
                    sig.public_key,
                    hash::to_hex(t)
                ));
            }
        }
        let raw = hash::from_hex(&sig.signature)
            .map_err(|e| format!("manifest signature: {e}"))?;
        let sig64: [u8; 64] =
            raw.try_into().map_err(|_| "manifest signature is not 64 bytes".to_string())?;
        let msg = Manifest::signing_bytes(self.revision, files);
        ed25519::verify(&key, &msg, &sig64)
            .map_err(|e| format!("manifest signature invalid: {e}"))?;
        Ok(key)
    }

    /// Digest every file under `root` (skipping [`manifest_skips`] names at
    /// any depth) into a fresh manifest — the Rust half of what
    /// `python -m compile.sign` does, used by tests and the rollout example.
    pub fn build(root: &Path, revision: u64) -> Result<Manifest, String> {
        let extra = match Manifest::load(root)? {
            Some(m) => m.extra,
            None => BTreeMap::new(),
        };
        let mut files = BTreeMap::new();
        walk(root, &mut PathBuf::new(), &mut files)?;
        Ok(Manifest { revision, files: Some(files), signature: None, extra })
    }

    /// Sign with a 32-byte ed25519 seed (replaces any prior signature).
    pub fn sign_with(&mut self, seed: &[u8; 32]) -> Result<(), String> {
        let files = self.files.as_ref().ok_or("cannot sign a manifest with no files map")?;
        let msg = Manifest::signing_bytes(self.revision, files);
        self.signature = Some(Signature {
            algorithm: "ed25519".to_string(),
            public_key: hash::to_hex(&ed25519::public_key(seed)),
            signature: hash::to_hex(&ed25519::sign(seed, &msg)),
        });
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        let mut obj = self.extra.clone();
        obj.insert("revision".to_string(), Json::UInt(self.revision));
        if let Some(files) = &self.files {
            let mut fo = BTreeMap::new();
            for (rel, fd) in files {
                let mut e = BTreeMap::new();
                e.insert("sha256".to_string(), Json::Str(fd.sha256.clone()));
                e.insert("size".to_string(), Json::UInt(fd.size));
                fo.insert(rel.clone(), Json::Obj(e));
            }
            obj.insert("files".to_string(), Json::Obj(fo));
        }
        if let Some(sig) = &self.signature {
            let mut s = BTreeMap::new();
            s.insert("algorithm".to_string(), Json::Str(sig.algorithm.clone()));
            s.insert("public_key".to_string(), Json::Str(sig.public_key.clone()));
            s.insert("signature".to_string(), Json::Str(sig.signature.clone()));
            obj.insert("signature".to_string(), Json::Obj(s));
        }
        Json::Obj(obj)
    }

    /// Write `<root>/index.json` (pretty-printed, trailing newline).
    pub fn write(&self, root: &Path) -> Result<(), String> {
        let path = root.join("index.json");
        let text = format!("{}\n", self.to_json().to_string_pretty());
        std::fs::write(&path, text).map_err(|e| format!("write {}: {e}", path.display()))
    }
}

fn walk(
    root: &Path,
    rel: &mut PathBuf,
    out: &mut BTreeMap<String, FileDigest>,
) -> Result<(), String> {
    let dir = root.join(&*rel);
    let mut entries: Vec<_> = std::fs::read_dir(&dir)
        .map_err(|e| format!("read dir {}: {e}", dir.display()))?
        .collect::<Result<_, _>>()
        .map_err(|e| format!("read dir {}: {e}", dir.display()))?;
    entries.sort_by_key(|e| e.file_name());
    for entry in entries {
        let name = entry.file_name().to_string_lossy().to_string();
        if rel.as_os_str().is_empty() && manifest_skips(&name) {
            continue;
        }
        if name.starts_with('.') || name == "__pycache__" {
            continue;
        }
        rel.push(&name);
        let path = entry.path();
        if path.is_dir() {
            walk(root, rel, out)?;
        } else {
            let (sha256, size) = hash::hash_file(&path)
                .map_err(|e| format!("hash {}: {e}", path.display()))?;
            out.insert(rel_str(rel), FileDigest { sha256, size });
        }
        rel.pop();
    }
    Ok(())
}

/// '/'-separated form of a relative path (manifest keys are
/// platform-independent).
fn rel_str(rel: &Path) -> String {
    rel.iter().map(|c| c.to_string_lossy()).collect::<Vec<_>>().join("/")
}

fn parse_key(hex: &str, what: &str) -> Result<[u8; 32], String> {
    let raw = hash::from_hex(hex.trim()).map_err(|e| format!("{what}: {e}"))?;
    raw.try_into().map_err(|_| format!("{what} is not 32 bytes"))
}

/// Read an ed25519 key (public or seed) from a hex file.
pub fn read_key_file(path: &Path) -> Result<[u8; 32], String> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| format!("read key {}: {e}", path.display()))?;
    parse_key(&text, &format!("key {}", path.display()))
}

/// Digest lookups handed to the artifact loaders: a loader that reads a
/// manifest-listed file checks it against the recorded digest *as it
/// loads* (the npz path streams bytes through [`hash::HashingReader`], so
/// nothing is buffered twice).
#[derive(Debug, Clone)]
pub struct Checks {
    root: PathBuf,
    files: Arc<BTreeMap<String, FileDigest>>,
}

impl Checks {
    /// Checks for `<root>/index.json`, or `None` when the manifest is
    /// missing or carries no `files` map (legacy bundle: nothing checked).
    pub fn load(root: &Path) -> Result<Option<Checks>, String> {
        Ok(Manifest::load(root)?.and_then(|m| Checks::from_manifest(root, &m)))
    }

    pub fn from_manifest(root: &Path, manifest: &Manifest) -> Option<Checks> {
        manifest.files.clone().map(|files| Checks {
            root: root.to_path_buf(),
            files: Arc::new(files),
        })
    }

    fn rel_of(&self, path: &Path) -> Option<String> {
        path.strip_prefix(&self.root).ok().map(rel_str)
    }

    /// The manifest record for an absolute path under the artifacts root,
    /// or `None` when the file is not listed (loaders then read unchecked —
    /// `--require-signed` closes that gap with a coverage check instead).
    pub fn expected(&self, path: &Path) -> Option<ExpectedDigest> {
        let rel = self.rel_of(path)?;
        self.files.get(&rel).map(|fd| ExpectedDigest {
            name: rel,
            sha256: fd.sha256.clone(),
            size: fd.size,
        })
    }

    /// Streaming-hash `path` and compare against its manifest record.
    /// `Ok(())` when the file is unlisted.
    pub fn verify(&self, path: &Path) -> Result<(), String> {
        let Some(exp) = self.expected(path) else { return Ok(()) };
        let (sha, size) =
            hash::hash_file(path).map_err(|e| format!("read {}: {e}", path.display()))?;
        exp.check(&sha, size)
    }
}

/// One verification failure: the offending manifest path plus the digest
/// mismatch detail (expected/actual).
#[derive(Debug, Clone)]
pub struct FileStatus {
    pub path: String,
    pub error: String,
}

/// Policy knobs for [`Repo::open`] (CLI: `--require-signed`,
/// `--trusted-key`, `--datasets`).
#[derive(Debug, Clone, Default)]
pub struct RepoPolicy {
    /// Refuse to serve unless the manifest is signed by the trusted key
    /// and every file on disk is covered by a verified digest.
    pub require_signed: bool,
    /// Path of a hex ed25519 public key; defaults to `<root>/signing.pub`.
    /// The manifest's embedded key must match — never trusted on its own.
    pub trusted_key: Option<PathBuf>,
    /// Dataset allowlist (empty = serve everything that verifies).
    pub datasets: Vec<String>,
}

/// One immutable, verified view of the artifacts root. Jobs pin the `Arc`
/// at routing time; workers resolve metadata and weights through it, so a
/// concurrent [`Repo::reload`] never mixes two revisions inside one batch.
pub struct RepoSnapshot {
    /// Manifest revision (0 for unmanaged bundles).
    pub revision: u64,
    /// Monotonic swap counter (1 = startup snapshot). Unlike `revision`
    /// this is guaranteed to change on every successful reload.
    pub generation: u64,
    /// True when the manifest signature verified against the trusted key.
    pub signed: bool,
    /// Number of manifest-listed files that hashed clean.
    pub verified_files: usize,
    /// Per-file verification failures (the datasets they belong to are
    /// excluded from `registry`).
    pub failures: Vec<FileStatus>,
    /// Datasets dropped because one of their files failed verification.
    pub excluded_datasets: Vec<String>,
    pub registry: Registry,
    pub store: Arc<ArtifactStore>,
    files: Option<BTreeMap<String, FileDigest>>,
}

/// Digest entries under `<dataset>/<variant>/`, for carry-over comparison
/// between snapshots.
fn variant_entries(
    files: &Option<BTreeMap<String, FileDigest>>,
    dataset: &str,
    variant: &str,
) -> Vec<(String, FileDigest)> {
    let prefix = format!("{dataset}/{variant}/");
    files
        .as_ref()
        .map(|files| {
            files
                .iter()
                .filter(|(rel, _)| rel.starts_with(&prefix))
                .map(|(rel, fd)| (rel.clone(), fd.clone()))
                .collect()
        })
        .unwrap_or_default()
}

/// The live repository: current snapshot + atomic swap.
pub struct Repo {
    root: PathBuf,
    policy: RepoPolicy,
    current: Mutex<Arc<RepoSnapshot>>,
    generation: AtomicU64,
}

impl Repo {
    /// Open the repository and build + verify the startup snapshot.
    pub fn open(root: &Path, policy: RepoPolicy) -> Result<Repo, String> {
        let snap = build_snapshot(root, &policy, 1, None)?;
        Ok(Repo {
            root: root.to_path_buf(),
            policy,
            current: Mutex::new(Arc::new(snap)),
            generation: AtomicU64::new(1),
        })
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    pub fn policy(&self) -> &RepoPolicy {
        &self.policy
    }

    /// The current snapshot (cheap: one lock + `Arc` clone).
    pub fn snapshot(&self) -> Arc<RepoSnapshot> {
        self.current.lock().unwrap().clone()
    }

    /// Re-read the root, verify, and atomically swap the snapshot in.
    /// Unchanged variants (identical digest sets) carry their loaded host
    /// artifacts over, so a reload only re-reads what actually changed.
    /// On error the current snapshot stays — serving is never interrupted
    /// by a failed rollout.
    pub fn reload(&self) -> Result<Arc<RepoSnapshot>, String> {
        let prev = self.snapshot();
        let generation = self.generation.fetch_add(1, Ordering::SeqCst) + 1;
        let snap = Arc::new(build_snapshot(&self.root, &self.policy, generation, Some(&prev))?);
        crate::info!(
            "repo",
            "swapped in revision {} (generation {}, {} datasets, {} excluded)",
            snap.revision,
            snap.generation,
            snap.registry.datasets.len(),
            snap.excluded_datasets.len()
        );
        *self.current.lock().unwrap() = snap.clone();
        Ok(snap)
    }
}

fn build_snapshot(
    root: &Path,
    policy: &RepoPolicy,
    generation: u64,
    prev: Option<&RepoSnapshot>,
) -> Result<RepoSnapshot, String> {
    let manifest = Manifest::load(root)?;

    // Trusted key: explicit path wins, else `<root>/signing.pub` if present.
    let trusted = match &policy.trusted_key {
        Some(p) => Some(read_key_file(p)?),
        None => {
            let p = root.join("signing.pub");
            if p.exists() { Some(read_key_file(&p)?) } else { None }
        }
    };

    // Signature gate. A *present but invalid* signature is always fatal —
    // that is tampering, not a legacy bundle. `--require-signed` further
    // demands that a valid signature exists at all.
    let mut signed = false;
    if let Some(m) = &manifest {
        if m.signature.is_some() {
            m.verify_signature(trusted.as_ref())?;
            signed = true;
        }
    }
    if policy.require_signed {
        if !signed {
            return Err(format!(
                "--require-signed: {} has no valid manifest signature (run `python -m compile.sign`)",
                root.join("index.json").display()
            ));
        }
        if trusted.is_none() {
            return Err(
                "--require-signed: no trusted key (pass --trusted-key or add signing.pub)".into(),
            );
        }
    }

    let files = manifest.as_ref().and_then(|m| m.files.clone());

    // `--require-signed` coverage: every file on disk must be listed, or an
    // attacker could smuggle in unverified extras next to signed ones.
    if policy.require_signed {
        let listed = files.as_ref().expect("signature verified implies files");
        let mut on_disk = BTreeMap::new();
        walk_names(root, &mut PathBuf::new(), &mut on_disk)?;
        for rel in on_disk.keys() {
            if !listed.contains_key(rel) {
                return Err(format!(
                    "--require-signed: {rel} exists on disk but is not covered by the signed manifest"
                ));
            }
        }
    }

    // Streaming-hash every listed file. Failures under `<dataset>/...`
    // exclude that dataset; a failure on a shared root file (vocab.json)
    // is fatal because every dataset depends on it.
    let mut failures = Vec::new();
    let mut verified_files = 0usize;
    let mut bad_datasets: Vec<String> = Vec::new();
    if let Some(files) = &files {
        for (rel, fd) in files {
            let path = root.join(rel.replace('/', std::path::MAIN_SEPARATOR_STR));
            let exp =
                ExpectedDigest { name: rel.clone(), sha256: fd.sha256.clone(), size: fd.size };
            let res = match hash::hash_file(&path) {
                Ok((sha, size)) => exp.check(&sha, size),
                Err(e) => Err(format!("missing or unreadable {rel}: {e}")),
            };
            match res {
                Ok(()) => verified_files += 1,
                Err(error) => {
                    crate::warnln!("repo", "verification failed: {error}");
                    match rel.split_once('/') {
                        Some((ds, _)) => {
                            if !bad_datasets.iter().any(|d| d == ds) {
                                bad_datasets.push(ds.to_string());
                            }
                        }
                        None => {
                            return Err(format!(
                                "verification failed for shared artifact: {error}"
                            ))
                        }
                    }
                    failures.push(FileStatus { path: rel.clone(), error });
                }
            }
        }
    }

    let checks = match (&manifest, &files) {
        (Some(m), Some(_)) => Checks::from_manifest(root, m),
        _ => None,
    };
    let mut registry = Registry::scan_with(root, checks.as_ref())?;

    let mut excluded_datasets = Vec::new();
    for ds in &bad_datasets {
        if registry.datasets.remove(ds).is_some() || files_mention_dataset(&files, ds) {
            excluded_datasets.push(ds.clone());
        }
    }
    if !policy.datasets.is_empty() {
        registry.datasets.retain(|name, _| policy.datasets.iter().any(|d| d == name));
    }

    // Carry over host artifacts whose digest sets are unchanged — the swap
    // then only re-reads weights that actually changed on disk.
    let store = Arc::new(ArtifactStore::new());
    if let Some(prev) = prev {
        for ds in registry.datasets.values() {
            for v in ds.variants.keys() {
                let old = variant_entries(&prev.files, &ds.name, v);
                let new = variant_entries(&files, &ds.name, v);
                if !new.is_empty() && old == new {
                    let key = ArtifactStore::key(&ds.name, v);
                    if let Some(art) = prev.store.cached(&key) {
                        store.adopt(key, art);
                    }
                }
            }
        }
    }

    Ok(RepoSnapshot {
        revision: manifest.as_ref().map(|m| m.revision).unwrap_or(0),
        generation,
        signed,
        verified_files,
        failures,
        excluded_datasets,
        registry,
        store,
        files,
    })
}

fn files_mention_dataset(files: &Option<BTreeMap<String, FileDigest>>, ds: &str) -> bool {
    let prefix = format!("{ds}/");
    files
        .as_ref()
        .is_some_and(|f| f.keys().any(|rel| rel.starts_with(&prefix)))
}

fn walk_names(
    root: &Path,
    rel: &mut PathBuf,
    out: &mut BTreeMap<String, ()>,
) -> Result<(), String> {
    let dir = root.join(&*rel);
    for entry in std::fs::read_dir(&dir).map_err(|e| format!("read dir {}: {e}", dir.display()))? {
        let entry = entry.map_err(|e| e.to_string())?;
        let name = entry.file_name().to_string_lossy().to_string();
        if rel.as_os_str().is_empty() && manifest_skips(&name) {
            continue;
        }
        if name.starts_with('.') || name == "__pycache__" {
            continue;
        }
        rel.push(&name);
        if entry.path().is_dir() {
            walk_names(root, rel, out)?;
        } else {
            out.insert(rel_str(rel), ());
        }
        rel.pop();
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    // RFC 8032 TEST 1 seed — a fixed dev key for unit fixtures.
    const SEED: [u8; 32] = seed();

    const fn seed() -> [u8; 32] {
        let mut s = [0u8; 32];
        let hex = *b"9d61b19deffd5a60ba844af492ec2cc44449c5697b326919703bac031cae7f60";
        let mut i = 0;
        while i < 32 {
            s[i] = hexval(hex[2 * i]) * 16 + hexval(hex[2 * i + 1]);
            i += 1;
        }
        s
    }

    const fn hexval(c: u8) -> u8 {
        if c.is_ascii_digit() {
            c - b'0'
        } else {
            c - b'a' + 10
        }
    }

    fn tmpdir(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("pb-repo-{name}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn signing_bytes_are_canonical() {
        let mut files = BTreeMap::new();
        files.insert("b/x".to_string(), FileDigest { sha256: "aa".into(), size: 2 });
        files.insert("a".to_string(), FileDigest { sha256: "ff".into(), size: 1 });
        let bytes = Manifest::signing_bytes(7, &files);
        assert_eq!(
            String::from_utf8(bytes).unwrap(),
            "powerbert-manifest-v1\nrevision 7\na ff 1\nb/x aa 2\n"
        );
    }

    #[test]
    fn build_sign_write_load_verify_roundtrip() {
        let root = tmpdir("roundtrip");
        std::fs::write(root.join("vocab.json"), b"{}").unwrap();
        std::fs::create_dir_all(root.join("ds/v")).unwrap();
        std::fs::write(root.join("ds/v/meta.json"), b"{\"x\":1}").unwrap();
        let mut m = Manifest::build(&root, 3).unwrap();
        m.sign_with(&SEED).unwrap();
        m.write(&root).unwrap();

        let loaded = Manifest::load(&root).unwrap().unwrap();
        assert_eq!(loaded.revision, 3);
        let files = loaded.files.as_ref().unwrap();
        assert_eq!(files.len(), 2);
        assert!(files.contains_key("vocab.json"));
        assert!(files.contains_key("ds/v/meta.json"));
        let trusted = ed25519::public_key(&SEED);
        loaded.verify_signature(Some(&trusted)).unwrap();
        // Wrong trusted key must refuse even though the embedded key verifies.
        let wrong = [9u8; 32];
        assert!(loaded.verify_signature(Some(&wrong)).unwrap_err().contains("trusted key"));
    }

    #[test]
    fn checks_name_the_offending_file_and_digests() {
        let root = tmpdir("checks");
        std::fs::write(root.join("vocab.json"), b"{}").unwrap();
        std::fs::create_dir_all(root.join("ds")).unwrap();
        std::fs::write(root.join("ds/payload.bin"), b"hello world").unwrap();
        let m = Manifest::build(&root, 1).unwrap();
        m.write(&root).unwrap();

        let checks = Checks::load(&root).unwrap().unwrap();
        checks.verify(&root.join("ds/payload.bin")).unwrap();
        checks.verify(&root.join("unlisted.txt")).unwrap(); // unlisted = unchecked

        // Flip one byte; the error must name the file and both digests.
        let want = m.files.as_ref().unwrap()["ds/payload.bin"].sha256.clone();
        std::fs::write(root.join("ds/payload.bin"), b"hellp world").unwrap();
        let err = checks.verify(&root.join("ds/payload.bin")).unwrap_err();
        assert!(err.contains("ds/payload.bin"), "{err}");
        assert!(err.contains(&want), "{err}");
        assert!(err.contains("expected sha256"), "{err}");
    }

    #[test]
    fn tampered_manifest_signature_is_fatal() {
        let root = tmpdir("sigtamper");
        std::fs::write(root.join("vocab.json"), b"{}").unwrap();
        let mut m = Manifest::build(&root, 1).unwrap();
        m.sign_with(&SEED).unwrap();
        // Mutate a digest after signing: signature no longer covers it.
        m.files.as_mut().unwrap().insert(
            "vocab.json".to_string(),
            FileDigest { sha256: "0".repeat(64), size: 2 },
        );
        m.write(&root).unwrap();
        let loaded = Manifest::load(&root).unwrap().unwrap();
        let err = loaded.verify_signature(None).unwrap_err();
        assert!(err.contains("signature invalid"), "{err}");
    }
}
