//! Artifact registry: discovers and describes the AOT bundle produced by
//! `make artifacts` (`python -m compile.pipeline`).
//!
//! Layout contract (see python/compile/aot.py):
//!   artifacts/vocab.json
//!   artifacts/index.json
//!   artifacts/<dataset>/test.npz
//!   artifacts/<dataset>/<variant>/{model.b{B}.hlo.txt, weights.npz, meta.json}
//!
//! A variant is compiled at one or more (batch, seq) cells. Legacy bundles
//! carry a flat `"hlo": {batch: file}` map (every executable at the full
//! `seq_len`); newer bundles may add `"hlo_grid": {seq: {batch: file}}` with
//! extra sequence buckets. Both are normalized into `VariantMeta::grid`.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use super::repo::Checks;
use crate::util::hash::ExpectedDigest;
use crate::util::json::Json;

/// Parsed `meta.json` of one model variant.
#[derive(Debug, Clone)]
pub struct VariantMeta {
    pub dataset: String,
    pub variant: String,
    /// "bert" | "power" | "albert" | "distil" | "pkd" | "headprune" | ...
    pub kind: String,
    pub metric: String,
    pub seq_len: usize,
    pub num_layers: usize,
    pub num_classes: usize,
    /// Model width / head count (0 when an old manifest omits them; the
    /// native backend requires both, the PJRT path never reads them).
    pub hidden_size: usize,
    pub num_heads: usize,
    pub batch_sizes: Vec<usize>,
    /// batch size -> HLO file name (legacy single-seq map, kept for tools
    /// that only care about the full-`seq_len` row of the grid).
    pub hlo: BTreeMap<usize, String>,
    /// seq bucket -> batch size -> HLO file name. Always contains at least
    /// the `seq_len` row (populated from `hlo` when no grid is declared).
    pub grid: BTreeMap<usize, BTreeMap<usize, String>>,
    pub weights: String,
    pub param_order: Vec<String>,
    /// PoWER retention configuration (absent for non-PoWER variants).
    pub retention: Option<Vec<usize>>,
    pub dev_metric: Option<f64>,
    /// Calibrated accuracy–latency frontier (`<dir>/pareto.json`, emitted
    /// by `eval --calibrate-pareto`; absent until a variant is calibrated).
    /// The router maps request SLAs to adaptive operating points from it.
    pub pareto: Option<crate::runtime::adaptive::ParetoTable>,
    /// Manifest digest of the weights file, when the bundle ships a signed
    /// repository manifest: the engine streaming-hashes `weights.npz` as it
    /// loads and refuses on mismatch. `None` = legacy bundle, unchecked.
    pub weights_check: Option<ExpectedDigest>,
    pub dir: PathBuf,
}

impl VariantMeta {
    pub fn parse(dir: &Path) -> Result<VariantMeta, String> {
        VariantMeta::parse_with(dir, None)
    }

    /// Parse `meta.json` with optional repository digest [`Checks`]:
    /// `meta.json` and `pareto.json` are verified here (a mismatch refuses
    /// the variant, naming the file and both digests) and the weights
    /// digest is attached for the engine to verify at load time.
    pub fn parse_with(dir: &Path, checks: Option<&Checks>) -> Result<VariantMeta, String> {
        if let Some(c) = checks {
            c.verify(&dir.join("meta.json"))?;
        }
        let j = Json::parse_file(&dir.join("meta.json")).map_err(|e| e.to_string())?;
        let mut hlo = BTreeMap::new();
        if let Some(o) = j.get("hlo").and_then(Json::as_obj) {
            for (k, v) in o {
                let b: usize = k.parse().map_err(|_| format!("bad batch key {k}"))?;
                hlo.insert(b, v.as_str().unwrap_or_default().to_string());
            }
        }
        let seq_len = j.usize_at("seq_len").map_err(|e| e.to_string())?;
        let mut grid: BTreeMap<usize, BTreeMap<usize, String>> = BTreeMap::new();
        if let Some(o) = j.get("hlo_grid").and_then(Json::as_obj) {
            for (sk, row) in o {
                let s: usize = sk.parse().map_err(|_| format!("bad seq key {sk}"))?;
                let mut batches = BTreeMap::new();
                if let Some(r) = row.as_obj() {
                    for (bk, v) in r {
                        let b: usize = bk.parse().map_err(|_| format!("bad batch key {bk}"))?;
                        batches.insert(b, v.as_str().unwrap_or_default().to_string());
                    }
                }
                if !batches.is_empty() {
                    grid.insert(s, batches);
                }
            }
        }
        // The flat map is the full-seq row; merge rather than overwrite so a
        // grid may refine it with extra cells at the same seq.
        if !hlo.is_empty() {
            grid.entry(seq_len).or_default().extend(hlo.clone());
        }
        let retention = j.get("retention").and_then(Json::as_arr).map(|a| {
            a.iter().filter_map(Json::as_usize).collect::<Vec<_>>()
        });
        let param_order = j
            .get("param_order")
            .and_then(Json::as_arr)
            .map(|a| a.iter().filter_map(|v| v.as_str().map(String::from)).collect())
            .unwrap_or_default();
        let weights =
            j.get("weights").and_then(Json::as_str).unwrap_or("weights.npz").to_string();
        let weights_check = checks.and_then(|c| c.expected(&dir.join(&weights)));
        Ok(VariantMeta {
            dataset: j.str_at("dataset").map_err(|e| e.to_string())?.to_string(),
            variant: j.str_at("variant").map_err(|e| e.to_string())?.to_string(),
            kind: j.get("kind").and_then(Json::as_str).unwrap_or("unknown").to_string(),
            metric: j.str_at("metric").map_err(|e| e.to_string())?.to_string(),
            seq_len,
            num_layers: j.get("num_layers").and_then(Json::as_usize).unwrap_or(0),
            num_classes: j.get("num_classes").and_then(Json::as_usize).unwrap_or(2),
            hidden_size: j.get("hidden_size").and_then(Json::as_usize).unwrap_or(0),
            num_heads: j.get("num_heads").and_then(Json::as_usize).unwrap_or(0),
            batch_sizes: j
                .get("batch_sizes")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_usize).collect())
                .unwrap_or_default(),
            hlo,
            grid,
            weights,
            param_order,
            retention,
            dev_metric: j.get("dev_metric").and_then(Json::as_f64),
            pareto: {
                let p = dir.join("pareto.json");
                if p.exists() {
                    // A *tampered* table is a refusal (digest named in the
                    // error) — routing on attacker-chosen operating points
                    // is worse than not serving the variant.
                    if let Some(c) = checks {
                        c.verify(&p)?;
                    }
                    match crate::runtime::adaptive::ParetoTable::load(&p) {
                        Ok(t) => Some(t),
                        Err(e) => {
                            // A merely *malformed* table must not take the
                            // variant down — it only disables adaptive
                            // routing.
                            crate::warnln!("registry", "ignoring {}: {e:#}", p.display());
                            None
                        }
                    }
                } else {
                    None
                }
            },
            weights_check,
            dir: dir.to_path_buf(),
        })
    }

    pub fn hlo_path(&self, batch: usize) -> Option<PathBuf> {
        self.hlo.get(&batch).map(|f| self.dir.join(f))
    }

    /// Path of the executable compiled at one (batch, seq) cell.
    pub fn grid_path(&self, batch: usize, seq: usize) -> Option<PathBuf> {
        self.grid
            .get(&seq)
            .and_then(|row| row.get(&batch))
            .map(|f| self.dir.join(f))
    }

    /// Compiled sequence buckets, ascending (always includes `seq_len` for
    /// a well-formed bundle).
    pub fn seq_buckets(&self) -> Vec<usize> {
        self.grid.keys().copied().collect()
    }

    /// All compiled (batch, seq) cells, ascending by (seq, batch).
    pub fn grid_cells(&self) -> Vec<(usize, usize)> {
        self.grid
            .iter()
            .flat_map(|(&s, row)| row.keys().map(move |&b| (b, s)))
            .collect()
    }

    /// Smallest compiled seq bucket that fits `need` tokens (falls back to
    /// the largest bucket when nothing fits — the engine then truncates
    /// nothing; oversized inputs are rejected upstream at encode time).
    pub fn seq_bucket_for(&self, need: usize) -> usize {
        self.grid
            .keys()
            .copied()
            .find(|&s| s >= need)
            .or_else(|| self.grid.keys().max().copied())
            .unwrap_or(self.seq_len)
    }

    pub fn weights_path(&self) -> PathBuf {
        self.dir.join(&self.weights)
    }

    /// Total word-vectors processed across encoders (the paper's aggregate;
    /// e.g. RTE: BERT 12*256=3072 vs PoWER 868).
    pub fn aggregate_word_vectors(&self) -> usize {
        match &self.retention {
            Some(r) => r.iter().sum(),
            None => self.num_layers * self.seq_len,
        }
    }
}

/// One dataset's artifacts: test split + variants.
#[derive(Debug, Clone)]
pub struct DatasetArtifacts {
    pub name: String,
    pub dir: PathBuf,
    pub variants: BTreeMap<String, VariantMeta>,
    /// Manifest digest of `test.npz` (verified as the split loads).
    pub test_check: Option<ExpectedDigest>,
}

impl DatasetArtifacts {
    pub fn test_npz(&self) -> PathBuf {
        self.dir.join("test.npz")
    }

    pub fn variant(&self, name: &str) -> Option<&VariantMeta> {
        self.variants.get(name)
    }
}

/// Registry over the whole artifacts directory.
#[derive(Debug, Clone)]
pub struct Registry {
    pub root: PathBuf,
    pub datasets: BTreeMap<String, DatasetArtifacts>,
}

impl Registry {
    /// Scan `root` for datasets and variants (ignores incomplete dirs).
    /// Digest checks come from `<root>/index.json` automatically when it
    /// carries a `files` manifest; a corrupt manifest fails the scan.
    pub fn scan(root: &Path) -> Result<Registry, String> {
        let checks = Checks::load(root)?;
        Registry::scan_with(root, checks.as_ref())
    }

    /// Scan with explicit digest checks (`None` = unchecked legacy scan).
    pub fn scan_with(root: &Path, checks: Option<&Checks>) -> Result<Registry, String> {
        if !root.is_dir() {
            return Err(format!("artifacts directory {} not found — run `make artifacts`", root.display()));
        }
        let mut datasets = BTreeMap::new();
        for entry in std::fs::read_dir(root).map_err(|e| e.to_string())? {
            let entry = entry.map_err(|e| e.to_string())?;
            let path = entry.path();
            if !path.is_dir() || path.file_name().is_some_and(|n| n == "analysis") {
                continue;
            }
            let name = entry.file_name().to_string_lossy().to_string();
            let mut variants = BTreeMap::new();
            for v in std::fs::read_dir(&path).map_err(|e| e.to_string())? {
                let vdir = v.map_err(|e| e.to_string())?.path();
                if vdir.is_dir() && vdir.join("meta.json").exists() {
                    match VariantMeta::parse_with(&vdir, checks) {
                        Ok(m) => {
                            variants.insert(m.variant.clone(), m);
                        }
                        Err(e) => {
                            crate::warnln!("registry", "skipping {}: {e}", vdir.display());
                        }
                    }
                }
            }
            if !variants.is_empty() {
                let test_check = checks.and_then(|c| c.expected(&path.join("test.npz")));
                datasets.insert(
                    name.clone(),
                    DatasetArtifacts { name, dir: path, variants, test_check },
                );
            }
        }
        Ok(Registry { root: root.to_path_buf(), datasets })
    }

    pub fn dataset(&self, name: &str) -> Option<&DatasetArtifacts> {
        self.datasets.get(name)
    }

    pub fn vocab_path(&self) -> PathBuf {
        self.root.join("vocab.json")
    }

    /// All (dataset, variant) pairs of a given kind.
    pub fn by_kind(&self, kind: &str) -> Vec<&VariantMeta> {
        self.datasets
            .values()
            .flat_map(|d| d.variants.values())
            .filter(|v| v.kind == kind)
            .collect()
    }
}

/// Default artifacts dir: $POWERBERT_ARTIFACTS or ./artifacts.
pub fn default_root() -> PathBuf {
    std::env::var("POWERBERT_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}
