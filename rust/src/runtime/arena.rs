//! Preplanned scratch arenas for the native forward pass.
//!
//! `NativeModel::forward_batch` used to allocate every transient buffer —
//! QKV projections, attention context, significance scores, the FFN
//! activation, extraction targets — as a fresh `Vec` per layer per call.
//! None of that cost shrinks with elimination, so on small `(batch, seq)`
//! buckets the allocator could rival the arithmetic. This module replaces
//! all of it with **one reusable slab per `(batch, seq)` bucket**, planned
//! once from quantities known a priori:
//!
//! * the widths `n_j` every layer runs at follow deterministically from
//!   the retention schedule (`n_0 = seq`, then
//!   `n_j = min(n_{j-1}, max(retention[j], 1))` — elimination never grows
//!   a batch), so
//! * the peak bytes of a bucket are computable **at model-load time**, and
//! * within a forward pass the live region of the slab shrinks layer by
//!   layer exactly as elimination does — the arena's occupancy curve *is*
//!   the paper's word-vector curve.
//!
//! An [`ArenaPlan`] records the region layout; a [`ForwardArena`] owns the
//! backing slabs and hands the forward pass a set of disjoint named
//! [`Regions`] carved by `split_at_mut` — no per-call allocation, no
//! unsafe. Regions are returned **dirty**: every consumer fully overwrites
//! the prefix it uses (a property `tests/prop_kernels.rs` and the
//! back-to-back determinism tests in `tests/native_backend.rs` pin down).
//!
//! # Peak-bytes formula
//!
//! With `B = batch`, `S = seq`, `h = hidden`, `H = heads`, `F = ffn`,
//! `L = lanes` (kernel pool size) and `P = max_j n_j^post` (the widest
//! post-extraction layer):
//!
//! ```text
//! f32s = B·S·(7h + 2)            x, hx, q, k, v, ctx, proj; mask, sig
//!      + B·P·F                   FFN activation
//!      + [lanes > 1] · B·S·h     private attention head slabs
//!      + B·H·S (or S serial)     per-head significance partials
//!      + L·S                     per-lane softmax rows
//!      + 2·B·h + S               pooler tails + top-k scores
//! i32s = B·S + S + (B + 1)      surviving positions + top-k order
//!                                + ragged row offsets
//! peak_bytes = 4 · (f32s + i32s)
//! ```
//!
//! For a power variant `P = max(retention[0], 1)` (clamped by `S`); for a
//! bert variant `P = S`. The committed sst2 quick bundle at its (8, 32)
//! execution chunk plans ~330 KiB; a BERT-base-scale export at (8, 128)
//! plans tens of MiB — either way a constant per worker per bucket,
//! instead of per-layer churn.
//!
//! # Sum-of-kept bound (ragged execution)
//!
//! The same plan serves both the padded and the **ragged** forward path.
//! Under ragged execution (see `docs/ARCHITECTURE.md` § "Ragged
//! execution") layer `j`'s live rows are `Σ_b kept_{b,j}` — each
//! example's *own* width, compacted to a row-offset ragged layout in the
//! `row_offsets` region. Every per-example width is clamped by the
//! schedule (`kept_{b,j} ≤ min(n_{j-1}, max(retention[j], 1))`), so the
//! sum-of-kept occupancy is bounded by `B · n_j` per layer and `B · P ·
//! F` for the FFN region — the rectangular plan above is exactly the
//! ragged path's worst case (realized when every example demands the
//! full schedule width), and shrinks below it whenever adaptive
//! thresholds let examples drop word-vectors early.
//!
//! The formula is precision-independent: under `--precision int8` the
//! weight panels are quantized **at pack time** inside `PackedLinear`
//! (resident model bytes shrink ~4×) while activations and every scratch
//! region stay f32, so the arena needs no i8 slabs and no plan change.

use super::kernels::KernelConfig;

/// The model-architecture inputs of an [`ArenaPlan`] — everything about
/// buffer sizing that is not per-request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ArenaDims {
    pub hidden: usize,
    pub heads: usize,
    /// Widest FFN across layers (layers share one slab region).
    pub ffn: usize,
    pub layers: usize,
}

/// Region layout of one `(batch, seq)` bucket's arena, planned from the
/// retention schedule. All lengths are in elements, not bytes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArenaPlan {
    pub batch: usize,
    pub seq: usize,
    /// Kernel-pool lanes the attention scratch is provisioned for.
    pub lanes: usize,
    // f32 regions, in carve order.
    x: usize,
    mask: usize,
    sig: usize,
    hx: usize,
    q: usize,
    k: usize,
    v: usize,
    ctx: usize,
    proj: usize,
    a1: usize,
    attn_ctx: usize,
    attn_sig: usize,
    attn_probs: usize,
    cls: usize,
    pooled: usize,
    topk_scores: usize,
    // i32 regions, in carve order.
    positions: usize,
    topk_order: usize,
    row_offsets: usize,
}

impl ArenaPlan {
    /// Plan a `(batch, seq)` bucket for a model with `dims` and the given
    /// retention schedule, provisioning attention scratch for `lanes`
    /// kernel-pool lanes.
    pub fn plan(
        dims: &ArenaDims,
        retention: Option<&[usize]>,
        batch: usize,
        seq: usize,
        lanes: usize,
    ) -> ArenaPlan {
        let h = dims.hidden;
        let lanes = lanes.max(1);
        // Post-extraction width per layer: n_j = min(n_{j-1}, keep_j).
        // The FFN region must fit the widest of them.
        let mut n = seq;
        let mut post_max = 0usize;
        for j in 0..dims.layers {
            if let Some(keep) = retention.and_then(|r| r.get(j)).copied() {
                let keep = keep.max(1);
                if keep < n {
                    n = keep;
                }
            }
            post_max = post_max.max(n);
        }
        if dims.layers == 0 {
            post_max = seq;
        }
        let rows = batch * seq;
        ArenaPlan {
            batch,
            seq,
            lanes,
            x: rows * h,
            mask: rows,
            sig: rows,
            hx: rows * h,
            q: rows * h,
            k: rows * h,
            v: rows * h,
            ctx: rows * h,
            proj: rows * h,
            a1: batch * post_max * dims.ffn,
            // Private head slabs exist only on the pooled path; the serial
            // path folds per head through the sig region's first row.
            attn_ctx: if lanes > 1 { rows * h } else { 0 },
            attn_sig: if lanes > 1 { batch * dims.heads * seq } else { seq },
            attn_probs: lanes * seq,
            cls: batch * h,
            pooled: batch * h,
            topk_scores: seq,
            positions: rows,
            topk_order: seq,
            // Ragged prefix-sum row offsets: batch + 1 entries.
            row_offsets: batch + 1,
        }
    }

    /// Total f32 elements in the slab.
    pub fn f32_len(&self) -> usize {
        self.x
            + self.mask
            + self.sig
            + self.hx
            + self.q
            + self.k
            + self.v
            + self.ctx
            + self.proj
            + self.a1
            + self.attn_ctx
            + self.attn_sig
            + self.attn_probs
            + self.cls
            + self.pooled
            + self.topk_scores
    }

    /// Total i32 elements in the slab.
    pub fn i32_len(&self) -> usize {
        self.positions + self.topk_order + self.row_offsets
    }

    /// The bucket's steady-state footprint: what one warm arena holds
    /// resident, and the number `stats` reports per worker.
    pub fn peak_bytes(&self) -> u64 {
        4 * (self.f32_len() as u64 + self.i32_len() as u64)
    }
}

/// Named mutable views over one arena, pairwise disjoint. Lifetimes tie
/// every region to one `&mut ForwardArena` borrow, so a forward pass
/// cannot alias regions and the arena cannot be checked back in while any
/// region is live.
pub struct Regions<'a> {
    /// Hidden states `[B*S, h]`; the live prefix shrinks as elimination
    /// proceeds (surviving rows are compacted in place).
    pub x: &'a mut [f32],
    /// Validity mask `[B*S]`, compacted alongside `x`.
    pub mask: &'a mut [f32],
    /// Attention-column significance `[B*S]` (paper §3.2).
    pub sig: &'a mut [f32],
    /// LayerNorm input of either encoder half `[B*S, h]`.
    pub hx: &'a mut [f32],
    pub q: &'a mut [f32],
    pub k: &'a mut [f32],
    pub v: &'a mut [f32],
    pub ctx: &'a mut [f32],
    /// Attention output projection, reused as the FFN down-projection.
    pub proj: &'a mut [f32],
    /// FFN activation `[B*P, ffn]`.
    pub a1: &'a mut [f32],
    /// Private attention head slabs (pooled path only).
    pub attn_ctx: &'a mut [f32],
    pub attn_sig: &'a mut [f32],
    pub attn_probs: &'a mut [f32],
    pub cls: &'a mut [f32],
    pub pooled: &'a mut [f32],
    pub topk_scores: &'a mut [f32],
    /// Original positions of surviving word-vectors `[B*S]`.
    pub positions: &'a mut [i32],
    pub topk_order: &'a mut [i32],
    /// Ragged prefix-sum row offsets `[B + 1]`: example `b` owns rows
    /// `row_offsets[b] .. row_offsets[b+1]` of the live `x` prefix
    /// (ragged path only; the padded path leaves it untouched).
    pub row_offsets: &'a mut [i32],
}

/// One `(batch, seq)` bucket's reusable scratch slab. Created on a
/// bucket's first request (the plan itself is computable at load time),
/// then checked out/in per forward pass with zero further allocation.
pub struct ForwardArena {
    plan: ArenaPlan,
    f32s: Vec<f32>,
    i32s: Vec<i32>,
}

impl ForwardArena {
    pub fn new(plan: ArenaPlan) -> ForwardArena {
        let f32s = vec![0f32; plan.f32_len()];
        let i32s = vec![0i32; plan.i32_len()];
        ForwardArena { plan, f32s, i32s }
    }

    pub fn plan(&self) -> &ArenaPlan {
        &self.plan
    }

    pub fn peak_bytes(&self) -> u64 {
        self.plan.peak_bytes()
    }

    /// Carve the slab into its disjoint named regions. Regions come back
    /// **dirty** (previous request's contents); consumers overwrite every
    /// prefix they read — see the leak tests.
    pub fn regions(&mut self) -> Regions<'_> {
        let p = &self.plan;
        let s = self.f32s.as_mut_slice();
        let (x, s) = s.split_at_mut(p.x);
        let (mask, s) = s.split_at_mut(p.mask);
        let (sig, s) = s.split_at_mut(p.sig);
        let (hx, s) = s.split_at_mut(p.hx);
        let (q, s) = s.split_at_mut(p.q);
        let (k, s) = s.split_at_mut(p.k);
        let (v, s) = s.split_at_mut(p.v);
        let (ctx, s) = s.split_at_mut(p.ctx);
        let (proj, s) = s.split_at_mut(p.proj);
        let (a1, s) = s.split_at_mut(p.a1);
        let (attn_ctx, s) = s.split_at_mut(p.attn_ctx);
        let (attn_sig, s) = s.split_at_mut(p.attn_sig);
        let (attn_probs, s) = s.split_at_mut(p.attn_probs);
        let (cls, s) = s.split_at_mut(p.cls);
        let (pooled, s) = s.split_at_mut(p.pooled);
        let (topk_scores, _s) = s.split_at_mut(p.topk_scores);
        let si = self.i32s.as_mut_slice();
        let (positions, si) = si.split_at_mut(p.positions);
        let (topk_order, si) = si.split_at_mut(p.topk_order);
        let (row_offsets, _si) = si.split_at_mut(p.row_offsets);
        Regions {
            x,
            mask,
            sig,
            hx,
            q,
            k,
            v,
            ctx,
            proj,
            a1,
            attn_ctx,
            attn_sig,
            attn_probs,
            cls,
            pooled,
            topk_scores,
            positions,
            topk_order,
            row_offsets,
        }
    }

    /// Fill both slabs with a sentinel — lets leak tests hand a forward
    /// pass the *worst-case* dirty arena and assert outputs still match a
    /// fresh one bit-for-bit.
    pub fn scribble(&mut self, f: f32, i: i32) {
        self.f32s.fill(f);
        self.i32s.fill(i);
    }
}

/// Convenience: plan a bucket straight from a kernel config (lanes =
/// resolved thread count).
pub fn plan_for(
    dims: &ArenaDims,
    retention: Option<&[usize]>,
    batch: usize,
    seq: usize,
    kernel: &KernelConfig,
) -> ArenaPlan {
    ArenaPlan::plan(dims, retention, batch, seq, kernel.resolved_threads())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dims() -> ArenaDims {
        ArenaDims { hidden: 8, heads: 2, ffn: 32, layers: 4 }
    }

    #[test]
    fn retention_shrinks_the_ffn_region() {
        let full = ArenaPlan::plan(&dims(), None, 2, 16, 1);
        let power = ArenaPlan::plan(&dims(), Some(&[8, 8, 4, 4]), 2, 16, 1);
        // bert: FFN sized for the full width; power: for retention[0].
        assert_eq!(full.a1, 2 * 16 * 32);
        assert_eq!(power.a1, 2 * 8 * 32);
        assert!(power.peak_bytes() < full.peak_bytes());
        // A retention entry at/above the width must not grow anything.
        let wide = ArenaPlan::plan(&dims(), Some(&[99, 8, 4, 4]), 2, 16, 1);
        assert_eq!(wide.a1, full.a1);
    }

    #[test]
    fn serial_plans_skip_the_head_slabs() {
        let serial = ArenaPlan::plan(&dims(), None, 2, 16, 1);
        let pooled = ArenaPlan::plan(&dims(), None, 2, 16, 4);
        assert_eq!(serial.attn_ctx, 0);
        assert_eq!(pooled.attn_ctx, 2 * 16 * 8);
        assert!(pooled.peak_bytes() > serial.peak_bytes());
        assert_eq!(pooled.lanes, 4);
    }

    #[test]
    fn regions_partition_the_slab_exactly() {
        let plan = ArenaPlan::plan(&dims(), Some(&[8, 8, 4, 4]), 3, 16, 2);
        let f32_len = plan.f32_len();
        let i32_len = plan.i32_len();
        let mut arena = ForwardArena::new(plan);
        assert_eq!(arena.peak_bytes(), 4 * (f32_len as u64 + i32_len as u64));
        let r = arena.regions();
        let total: usize = [
            r.x.len(),
            r.mask.len(),
            r.sig.len(),
            r.hx.len(),
            r.q.len(),
            r.k.len(),
            r.v.len(),
            r.ctx.len(),
            r.proj.len(),
            r.a1.len(),
            r.attn_ctx.len(),
            r.attn_sig.len(),
            r.attn_probs.len(),
            r.cls.len(),
            r.pooled.len(),
            r.topk_scores.len(),
        ]
        .iter()
        .sum();
        assert_eq!(total, f32_len);
        assert_eq!(r.positions.len() + r.topk_order.len() + r.row_offsets.len(), i32_len);
        assert_eq!(r.x.len(), 3 * 16 * 8);
        assert_eq!(r.attn_probs.len(), 2 * 16);
        // Ragged prefix-sum offsets: one entry per example plus the total.
        assert_eq!(r.row_offsets.len(), 3 + 1);
    }

    #[test]
    fn scribble_reaches_every_element() {
        let plan = ArenaPlan::plan(&dims(), None, 1, 4, 1);
        let mut arena = ForwardArena::new(plan);
        arena.scribble(7.25, -3);
        let r = arena.regions();
        assert!(r.x.iter().all(|&v| v == 7.25));
        assert!(r.positions.iter().all(|&v| v == -3));
    }
}
