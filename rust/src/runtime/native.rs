//! Native backend: the PoWER-BERT forward pass in pure Rust.
//!
//! Mirrors `python/compile/model.py` / `layers.py` / `kernels/ref.py`
//! operation-for-operation (pre-LN encoder halves, tanh-approximate GELU,
//! attention-column significance, stable top-k extraction between the
//! attention and FFN halves — paper §3.2, Figure 4), reading the exported
//! `weights.npz` directly. Golden-logit fixtures exported by
//! `python -m compile.golden` pin the parity to within 1e-4.
//!
//! The paper's mechanism is implemented literally:
//! * significance of word-vector `w` at encoder `j` is the attention mass
//!   flowing *into* it — the column sum of the softmax matrix over heads
//!   and non-PAD query rows (§3.2);
//! * between the attention module and the FFN, only the `retention[j]`
//!   highest-scored positions survive, CLS pinned on top and PAD below any
//!   real word, original order preserved (§3.4);
//! * a retention entry at or above the current width skips elimination
//!   (short seq buckets execute without it, as in the AOT grid).
//!
//! Execution shapes are exact — a (batch, seq) request runs as-is, so the
//! native path never re-introduces padding word-vectors at the batch
//! boundary, and every eliminated vector is compute actually saved.

use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{anyhow, bail, Context, Result};

use super::backend::{CellExecutor, CellPlan, ExecOutput, LoadedModel};
use super::engine::ModelArtifact;
use crate::tokenizer::PAD_ID;

/// Largest batch the native executor accepts in one call. Generous — the
/// loop is O(batch) with no compiled-shape constraint — but finite, so the
/// serving layer keeps splitting absurd batches instead of wedging one
/// worker on a megabatch.
pub const NATIVE_MAX_BATCH: usize = 64;

/// Score pin for CLS (never eliminated, paper §3.4) — matches model.py BIG.
const BIG: f32 = 1e6;
/// Additive mask for PAD key columns, matching kernels/ref.py.
const NEG_INF: f32 = -1e9;
const LN_EPS: f32 = 1e-6;

/// The native backend: stateless — per-variant state lives in the
/// [`NativeModel`] it loads.
#[derive(Default)]
pub struct NativeBackend;

impl NativeBackend {
    pub fn new() -> NativeBackend {
        NativeBackend
    }

    /// Build a ready-to-execute model from the host artifact.
    pub fn load(&self, art: &ModelArtifact) -> Result<LoadedModel> {
        let model = NativeModel::from_artifact(art)
            .with_context(|| format!("native load {}/{}", art.meta.dataset, art.meta.variant))?;
        Ok(LoadedModel::new(
            art.meta.clone(),
            "native",
            CellPlan::Exact { max_batch: NATIVE_MAX_BATCH, max_seq: art.meta.seq_len },
            Box::new(model),
        ))
    }
}

/// One encoder layer's weights, all row-major.
struct LayerWeights {
    wq: Vec<f32>,
    bq: Vec<f32>,
    wk: Vec<f32>,
    bk: Vec<f32>,
    wv: Vec<f32>,
    bv: Vec<f32>,
    wo: Vec<f32>,
    bo: Vec<f32>,
    ln1_g: Vec<f32>,
    ln1_b: Vec<f32>,
    w1: Vec<f32>,
    b1: Vec<f32>,
    w2: Vec<f32>,
    b2: Vec<f32>,
    ln2_g: Vec<f32>,
    ln2_b: Vec<f32>,
    ffn_size: usize,
}

/// A variant's weights in forward-pass form plus its processed-token
/// telemetry.
pub struct NativeModel {
    hidden: usize,
    heads: usize,
    num_classes: usize,
    vocab: usize,
    type_vocab: usize,
    max_pos: usize,
    retention: Option<Vec<usize>>,
    word: Vec<f32>,
    word_proj: Option<(usize, Vec<f32>)>, // (embed_factor, [E, H])
    pos: Vec<f32>,
    type_: Vec<f32>,
    embed_ln_g: Vec<f32>,
    embed_ln_b: Vec<f32>,
    layers: Vec<LayerWeights>,
    final_g: Vec<f32>,
    final_b: Vec<f32>,
    pooler_w: Vec<f32>,
    pooler_b: Vec<f32>,
    head_w: Vec<f32>,
    head_b: Vec<f32>,
    /// Word-vectors processed per encoder (FFN width after extraction),
    /// accumulated across every executed row.
    layer_tokens: Vec<AtomicU64>,
}

impl NativeModel {
    fn from_artifact(art: &ModelArtifact) -> Result<NativeModel> {
        let meta = &art.meta;
        let hidden = meta.hidden_size;
        let heads = meta.num_heads;
        if hidden == 0 || heads == 0 {
            bail!(
                "meta.json lacks hidden_size/num_heads (re-export with a current \
                 python/compile; got hidden_size={hidden}, num_heads={heads})"
            );
        }
        if hidden % heads != 0 {
            bail!("hidden_size {hidden} not divisible by num_heads {heads}");
        }
        let w = |name: &str| -> Result<(Vec<usize>, Vec<f32>)> {
            let (dims, data) = art
                .weight(name)
                .ok_or_else(|| anyhow!("weights.npz missing {name}"))?;
            Ok((dims.to_vec(), data.to_vec()))
        };
        let expect = |name: &str, dims: &[usize], want: &[usize]| -> Result<()> {
            if dims != want {
                bail!("{name}: shape {dims:?}, expected {want:?}");
            }
            Ok(())
        };

        let (word_dims, word) = w("embed/word")?;
        if word_dims.len() != 2 {
            bail!("embed/word: shape {word_dims:?}, expected rank 2");
        }
        let (vocab, embed_width) = (word_dims[0], word_dims[1]);
        let word_proj = match art.weight("embed/word_proj") {
            Some((dims, data)) => {
                expect("embed/word_proj", dims, &[embed_width, hidden])?;
                Some((embed_width, data.to_vec()))
            }
            None => {
                expect("embed/word", &word_dims, &[vocab, hidden])?;
                None
            }
        };
        let (pos_dims, pos) = w("embed/pos")?;
        if pos_dims.len() != 2 || pos_dims[1] != hidden {
            bail!("embed/pos: shape {pos_dims:?}, expected [max_len, {hidden}]");
        }
        let max_pos = pos_dims[0];
        if meta.seq_len > max_pos {
            bail!("seq_len {} exceeds position table {max_pos}", meta.seq_len);
        }
        let (type_dims, type_) = w("embed/type")?;
        if type_dims.len() != 2 || type_dims[1] != hidden {
            bail!("embed/type: shape {type_dims:?}, expected [type_vocab, {hidden}]");
        }
        let type_vocab = type_dims[0];
        let (g_dims, embed_ln_g) = w("embed/ln_g")?;
        expect("embed/ln_g", &g_dims, &[hidden])?;
        let (b_dims, embed_ln_b) = w("embed/ln_b")?;
        expect("embed/ln_b", &b_dims, &[hidden])?;

        let mut layers = Vec::with_capacity(meta.num_layers);
        for j in 0..meta.num_layers {
            // ALBERT-style shared parameters export only layers/0.
            let jj = if art.weight(&format!("layers/{j}/wq")).is_some() { j } else { 0 };
            let lw = |suffix: &str, want: &[usize]| -> Result<Vec<f32>> {
                let name = format!("layers/{jj}/{suffix}");
                let (dims, data) = w(&name)?;
                expect(&name, &dims, want)?;
                Ok(data)
            };
            let (w1_dims, w1) = w(&format!("layers/{jj}/w1"))?;
            if w1_dims.len() != 2 || w1_dims[0] != hidden {
                bail!("layers/{jj}/w1: shape {w1_dims:?}, expected [{hidden}, ffn]");
            }
            let ffn_size = w1_dims[1];
            layers.push(LayerWeights {
                wq: lw("wq", &[hidden, hidden])?,
                bq: lw("bq", &[hidden])?,
                wk: lw("wk", &[hidden, hidden])?,
                bk: lw("bk", &[hidden])?,
                wv: lw("wv", &[hidden, hidden])?,
                bv: lw("bv", &[hidden])?,
                wo: lw("wo", &[hidden, hidden])?,
                bo: lw("bo", &[hidden])?,
                ln1_g: lw("ln1_g", &[hidden])?,
                ln1_b: lw("ln1_b", &[hidden])?,
                w1,
                b1: lw("b1", &[ffn_size])?,
                w2: lw("w2", &[ffn_size, hidden])?,
                b2: lw("b2", &[hidden])?,
                ln2_g: lw("ln2_g", &[hidden])?,
                ln2_b: lw("ln2_b", &[hidden])?,
                ffn_size,
            });
        }
        if layers.is_empty() {
            bail!("meta.json declares no encoder layers");
        }

        let (fg_dims, final_g) = w("final_ln/g")?;
        expect("final_ln/g", &fg_dims, &[hidden])?;
        let (fb_dims, final_b) = w("final_ln/b")?;
        expect("final_ln/b", &fb_dims, &[hidden])?;
        let (pw_dims, pooler_w) = w("pooler/w")?;
        expect("pooler/w", &pw_dims, &[hidden, hidden])?;
        let (pb_dims, pooler_b) = w("pooler/b")?;
        expect("pooler/b", &pb_dims, &[hidden])?;
        let (hw_dims, head_w) = w("head/w")?;
        if hw_dims.len() != 2 || hw_dims[0] != hidden {
            bail!("head/w: shape {hw_dims:?}, expected [{hidden}, classes]");
        }
        let num_classes = hw_dims[1];
        let (hb_dims, head_b) = w("head/b")?;
        expect("head/b", &hb_dims, &[num_classes])?;

        let n_layers = layers.len();
        Ok(NativeModel {
            hidden,
            heads,
            num_classes,
            vocab,
            type_vocab,
            max_pos,
            retention: meta.retention.clone(),
            word,
            word_proj,
            pos,
            type_,
            embed_ln_g,
            embed_ln_b,
            layers,
            final_g,
            final_b,
            pooler_w,
            pooler_b,
            head_w,
            head_b,
            layer_tokens: (0..n_layers).map(|_| AtomicU64::new(0)).collect(),
        })
    }

    /// Forward one example of `seq` tokens. Returns the logits and, when
    /// `want_trace`, the per-layer surviving original positions
    /// ([L, seq], -1-padded).
    fn forward_one(
        &self,
        tokens: &[i32],
        segments: &[i32],
        seq: usize,
        want_trace: bool,
    ) -> Result<(Vec<f32>, Option<Vec<i32>>)> {
        let h = self.hidden;
        let heads = self.heads;
        let d = h / heads;
        let n_layers = self.layers.len();
        if seq > self.max_pos {
            bail!("seq {seq} exceeds position table {}", self.max_pos);
        }

        // Valid-position mask: 1.0 for real tokens, 0.0 for PAD.
        let mut mask: Vec<f32> = tokens
            .iter()
            .map(|&t| if t == PAD_ID { 0.0 } else { 1.0 })
            .collect();

        // Embedding lookup + LN.
        let mut x = vec![0f32; seq * h];
        for i in 0..seq {
            let tok = tokens[i];
            if tok < 0 || tok as usize >= self.vocab {
                bail!("token id {tok} outside vocab of {}", self.vocab);
            }
            let seg = segments[i];
            if seg < 0 || seg as usize >= self.type_vocab {
                bail!("segment id {seg} outside type vocab of {}", self.type_vocab);
            }
            let row = &mut x[i * h..(i + 1) * h];
            match &self.word_proj {
                None => {
                    let wrow = &self.word[tok as usize * h..(tok as usize + 1) * h];
                    row.copy_from_slice(wrow);
                }
                Some((e, proj)) => {
                    // Factorized embedding: word[tok] (E) @ proj (E x H).
                    let wrow = &self.word[tok as usize * e..(tok as usize + 1) * e];
                    for (k, &wv) in wrow.iter().enumerate() {
                        let prow = &proj[k * h..(k + 1) * h];
                        for (c, &pv) in prow.iter().enumerate() {
                            row[c] += wv * pv;
                        }
                    }
                }
            }
            let prow = &self.pos[i * h..(i + 1) * h];
            let trow = &self.type_[seg as usize * h..(seg as usize + 1) * h];
            for c in 0..h {
                row[c] += prow[c] + trow[c];
            }
        }
        layer_norm(&mut x, h, &self.embed_ln_g, &self.embed_ln_b);

        // Original positions of surviving word-vectors (Figure 8 trace).
        let mut positions: Vec<i32> = (0..seq as i32).collect();
        let mut trace = want_trace.then(|| vec![-1i32; n_layers * seq]);

        for (j, layer) in self.layers.iter().enumerate() {
            let n = x.len() / h;
            // --- attention half: x1 = x + proj(MHA(LN(x))), plus scores.
            let mut hx = x.clone();
            layer_norm(&mut hx, h, &layer.ln1_g, &layer.ln1_b);
            let q = matmul_bias(&hx, n, h, &layer.wq, h, &layer.bq);
            let k = matmul_bias(&hx, n, h, &layer.wk, h, &layer.bk);
            let v = matmul_bias(&hx, n, h, &layer.wv, h, &layer.bv);

            let scale = 1.0 / (d as f32).sqrt();
            let mut sig = vec![0f32; n];
            let mut ctx = vec![0f32; n * h];
            let mut probs = vec![0f32; n];
            for a in 0..heads {
                let off = a * d;
                for i in 0..n {
                    let qi = &q[i * h + off..i * h + off + d];
                    // Scaled dot-product logits with PAD keys masked out.
                    let mut maxv = f32::NEG_INFINITY;
                    for jj in 0..n {
                        let kj = &k[jj * h + off..jj * h + off + d];
                        let mut dot = 0f32;
                        for t in 0..d {
                            dot += qi[t] * kj[t];
                        }
                        let logit = if mask[jj] > 0.0 { dot * scale } else { NEG_INF };
                        probs[jj] = logit;
                        if logit > maxv {
                            maxv = logit;
                        }
                    }
                    let mut denom = 0f32;
                    for p in probs.iter_mut() {
                        *p = (*p - maxv).exp();
                        denom += *p;
                    }
                    let inv = 1.0 / denom;
                    let qmask = mask[i];
                    let crow = &mut ctx[i * h + off..i * h + off + d];
                    for jj in 0..n {
                        let p = probs[jj] * inv;
                        // Column sums over heads and non-PAD query rows:
                        // the paper's significance score (§3.2).
                        sig[jj] += qmask * p;
                        let vj = &v[jj * h + off..jj * h + off + d];
                        for t in 0..d {
                            crow[t] += p * vj[t];
                        }
                    }
                }
            }
            let proj = matmul_bias(&ctx, n, h, &layer.wo, h, &layer.bo);
            let mut x1 = x;
            for (xv, pv) in x1.iter_mut().zip(proj.iter()) {
                *xv += pv;
            }

            // --- extract layer (between attention and FFN, §3.2/Fig 4).
            if let Some(keep) = self.retention.as_ref().and_then(|r| r.get(j)).copied() {
                // Guard a malformed manifest: at least CLS always survives
                // (derive_retention clamps to >= 1 on the export side).
                let keep = keep.max(1);
                if keep < n {
                    let idx = topk_keep_indices(&sig, &mask, keep);
                    let mut nx = vec![0f32; keep * h];
                    let mut nmask = vec![0f32; keep];
                    let mut npos = vec![0i32; keep];
                    for (slot, &src) in idx.iter().enumerate() {
                        nx[slot * h..(slot + 1) * h]
                            .copy_from_slice(&x1[src * h..(src + 1) * h]);
                        nmask[slot] = mask[src];
                        npos[slot] = positions[src];
                    }
                    x1 = nx;
                    mask = nmask;
                    positions = npos;
                }
            }
            let n = x1.len() / h;
            self.layer_tokens[j].fetch_add(n as u64, Ordering::Relaxed);
            if let Some(tr) = trace.as_mut() {
                tr[j * seq..j * seq + n].copy_from_slice(&positions);
            }

            // --- FFN half: x = x1 + FFN(LN(x1)).
            let mut h2 = x1.clone();
            layer_norm(&mut h2, h, &layer.ln2_g, &layer.ln2_b);
            let mut a1 = matmul_bias(&h2, n, h, &layer.w1, layer.ffn_size, &layer.b1);
            for vv in a1.iter_mut() {
                *vv = gelu(*vv);
            }
            let a2 = matmul_bias(&a1, n, layer.ffn_size, &layer.w2, h, &layer.b2);
            x = x1;
            for (xv, av) in x.iter_mut().zip(a2.iter()) {
                *xv += av;
            }
        }

        // --- pooler + classifier head from the CLS vector.
        layer_norm(&mut x, h, &self.final_g, &self.final_b);
        let cls = &x[..h];
        let mut pooled = vec![0f32; h];
        for (c, p) in pooled.iter_mut().enumerate() {
            let mut acc = self.pooler_b[c];
            for (kk, &xv) in cls.iter().enumerate() {
                acc += xv * self.pooler_w[kk * h + c];
            }
            *p = acc.tanh();
        }
        let mut logits = vec![0f32; self.num_classes];
        for (c, l) in logits.iter_mut().enumerate() {
            let mut acc = self.head_b[c];
            for (kk, &pv) in pooled.iter().enumerate() {
                acc += pv * self.head_w[kk * self.num_classes + c];
            }
            *l = acc;
        }
        Ok((logits, trace))
    }
}

impl CellExecutor for NativeModel {
    fn execute(
        &self,
        tokens: &[i32],
        segments: &[i32],
        batch: usize,
        seq: usize,
        want_trace: bool,
    ) -> Result<ExecOutput> {
        if tokens.len() != batch * seq || segments.len() != batch * seq {
            bail!("native execute: expected {batch}x{seq} tokens, got {}", tokens.len());
        }
        let n_layers = self.layers.len();
        let mut logits = Vec::with_capacity(batch * self.num_classes);
        let mut kept = want_trace.then(|| Vec::with_capacity(batch * n_layers * seq));
        for r in 0..batch {
            let (row_logits, row_trace) = self.forward_one(
                &tokens[r * seq..(r + 1) * seq],
                &segments[r * seq..(r + 1) * seq],
                seq,
                want_trace,
            )?;
            logits.extend_from_slice(&row_logits);
            if let (Some(acc), Some(tr)) = (kept.as_mut(), row_trace) {
                acc.extend_from_slice(&tr);
            }
        }
        Ok(ExecOutput { logits, num_classes: self.num_classes, kept })
    }

    fn layer_tokens(&self) -> Option<Vec<u64>> {
        Some(
            self.layer_tokens
                .iter()
                .map(|c| c.load(Ordering::Relaxed))
                .collect(),
        )
    }
}

/// Indices of the `keep` highest-scored positions in original (ascending)
/// order. Scores: significance for real words, -1.0 for PAD (below any
/// real column sum, which is >= 0), CLS pinned to the top. The sort is
/// stable, so ties (e.g. between PAD columns) resolve to the lowest
/// original index — matching jnp.argsort in model.py exactly, which the
/// golden-logit parity fixtures depend on.
fn topk_keep_indices(sig: &[f32], mask: &[f32], keep: usize) -> Vec<usize> {
    let n = sig.len();
    let mut scores: Vec<f32> = (0..n)
        .map(|i| if mask[i] > 0.0 { sig[i] } else { -1.0 })
        .collect();
    scores[0] = BIG;
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| scores[b].total_cmp(&scores[a]));
    order.truncate(keep);
    order.sort_unstable();
    order
}

/// Row-wise LayerNorm over `h`-wide rows, in place.
fn layer_norm(x: &mut [f32], h: usize, gamma: &[f32], beta: &[f32]) {
    for row in x.chunks_exact_mut(h) {
        let mut mean = 0f32;
        for &v in row.iter() {
            mean += v;
        }
        mean /= h as f32;
        let mut var = 0f32;
        for &v in row.iter() {
            let dv = v - mean;
            var += dv * dv;
        }
        var /= h as f32;
        let inv = 1.0 / (var + LN_EPS).sqrt();
        for (c, v) in row.iter_mut().enumerate() {
            *v = (*v - mean) * inv * gamma[c] + beta[c];
        }
    }
}

/// `x [n, k] @ w [k, m] + b [m]`, row-major.
fn matmul_bias(x: &[f32], n: usize, k: usize, w: &[f32], m: usize, b: &[f32]) -> Vec<f32> {
    let mut out = vec![0f32; n * m];
    for i in 0..n {
        let xrow = &x[i * k..(i + 1) * k];
        let orow = &mut out[i * m..(i + 1) * m];
        orow.copy_from_slice(b);
        for (kk, &xv) in xrow.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wrow = &w[kk * m..(kk + 1) * m];
            for (c, &wv) in wrow.iter().enumerate() {
                orow[c] += xv * wv;
            }
        }
    }
    out
}

/// Tanh-approximate GELU, matching `jax.nn.gelu(..., approximate=True)`.
fn gelu(x: f32) -> f32 {
    const SQRT_2_OVER_PI: f32 = 0.797_884_56;
    0.5 * x * (1.0 + (SQRT_2_OVER_PI * (x + 0.044_715 * x * x * x)).tanh())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn topk_pins_cls_and_sinks_pad() {
        // 6 positions, PADs at 4/5; keep 3 -> CLS + the two best real.
        let sig = vec![0.1, 2.0, 0.5, 1.5, 9.0, 9.0];
        let mask = vec![1.0, 1.0, 1.0, 1.0, 0.0, 0.0];
        assert_eq!(topk_keep_indices(&sig, &mask, 3), vec![0, 1, 3]);
        // Keep beyond the real count: PAD ties resolve to ascending index.
        assert_eq!(topk_keep_indices(&sig, &mask, 5), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn layer_norm_normalizes_rows() {
        let mut x = vec![1.0, 2.0, 3.0, 4.0, -1.0, 0.0, 1.0, 2.0];
        let g = vec![1.0; 4];
        let b = vec![0.0; 4];
        layer_norm(&mut x, 4, &g, &b);
        for row in x.chunks_exact(4) {
            let mean: f32 = row.iter().sum::<f32>() / 4.0;
            let var: f32 = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 4.0;
            assert!(mean.abs() < 1e-5, "mean {mean}");
            assert!((var - 1.0).abs() < 1e-3, "var {var}");
        }
    }

    #[test]
    fn matmul_bias_small_case() {
        // [1,2;3,4] @ [1,0;0,1] + [10, 20]
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let w = vec![1.0, 0.0, 0.0, 1.0];
        let b = vec![10.0, 20.0];
        assert_eq!(matmul_bias(&x, 2, 2, &w, 2, &b), vec![11.0, 22.0, 13.0, 24.0]);
    }

    #[test]
    fn gelu_matches_reference_points() {
        assert!(gelu(0.0).abs() < 1e-7);
        assert!((gelu(1.0) - 0.841_192).abs() < 1e-4);
        assert!((gelu(-1.0) + 0.158_808).abs() < 1e-4);
        assert!((gelu(3.0) - 2.995_9).abs() < 1e-3);
    }
}
