//! Native backend: the PoWER-BERT forward pass in pure Rust.
//!
//! Mirrors `python/compile/model.py` / `layers.py` / `kernels/ref.py`
//! operation-for-operation (pre-LN encoder halves, tanh-approximate GELU,
//! attention-column significance, stable top-k extraction between the
//! attention and FFN halves — paper §3.2, Figure 4), reading the exported
//! `weights.npz` directly. Golden-logit fixtures exported by
//! `python -m compile.golden` pin the parity to within 1e-4.
//!
//! The paper's mechanism is implemented literally:
//! * significance of word-vector `w` at encoder `j` is the attention mass
//!   flowing *into* it — the column sum of the softmax matrix over heads
//!   and non-PAD query rows (§3.2);
//! * between the attention module and the FFN, only the `retention[j]`
//!   highest-scored positions survive, CLS pinned on top and PAD below any
//!   real word, original order preserved (§3.4);
//! * a retention entry at or above the current width skips elimination
//!   (short seq buckets execute without it, as in the AOT grid).
//!
//! Execution shapes are exact — a (batch, seq) request runs as-is, so the
//! native path never re-introduces padding word-vectors at the batch
//! boundary, and every eliminated vector is compute actually saved.
//!
//! # Steady-state execution
//!
//! The hot loops live in [`kernels`](super::kernels): weights are packed
//! into column panels once at [`NativeBackend::load`], and the whole
//! batch flows through **batch-level** kernel calls — every projection is
//! one `[batch * n_j, k]` GEMM where `n_j` is the per-layer surviving
//! word-vector count, so elimination literally shrinks the GEMM shapes
//! layer by layer. Two further pieces make the per-request path
//! steady-state:
//!
//! * parallel kernels dispatch to the worker's persistent
//!   [`KernelPool`](super::kernels::pool::KernelPool) (shared via the
//!   backend's [`KernelExec`]) instead of spawning threads per call;
//! * every transient buffer comes from a per-`(batch, seq)`-bucket
//!   [`ForwardArena`](super::arena::ForwardArena), planned from the
//!   retention schedule and reused across requests — after a bucket's
//!   first request, `forward_batch` performs **zero heap allocations**
//!   (`tests/alloc_steady_state.rs` enforces this with a counting
//!   allocator; the kept-trace debug path is exempt). Surviving rows are
//!   compacted in place, so the arena's live region shrinks layer by
//!   layer exactly as elimination does.
//!
//! # Ragged per-example execution
//!
//! Two forward paths share the arena and the kernels:
//!
//! * [`NativeModel::forward_batch`] — the **padded oracle**: every extract
//!   layer keeps one width for the whole batch (under an adaptive
//!   threshold, the batch max of the per-example demanded widths), so the
//!   batch stays rectangular. Bit-exact, golden-pinned, selectable with
//!   `--ragged off`.
//! * `forward_batch_ragged` — the **default**: each example compacts to
//!   its *own* demanded width at every extract layer, held in a
//!   row-offset ragged layout, so GEMM rows and attention tasks equal
//!   Σ kept_b instead of batch · max_b kept_b. Under a fixed schedule the
//!   two paths are bit-identical (`tests/ragged.rs`); under an active
//!   threshold the ragged path does strictly less work on mixed-demand
//!   batches (`benches/native.rs::bench_ragged`).
//!
//! See `benches/native.rs` for the measured kernel, dispatch and
//! allocation numbers, and `docs/ARCHITECTURE.md` for the cost model and
//! the per-bucket peak-bytes formula.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, bail, Context, Result};

use super::arena::{ArenaDims, ArenaPlan, ForwardArena};
use super::backend::{CellExecutor, CellPlan, ExecOutput, LoadedModel, MemoryStats};
use super::engine::ModelArtifact;
use super::kernels::{
    active_isa,
    attention::{masked_attention, masked_attention_ragged, AttnScratch},
    gemm::{PackedLinear, RaggedRows},
    layer_norm, KernelConfig, KernelExec,
};
use crate::tokenizer::PAD_ID;

/// Largest batch the native executor accepts in one call. Generous — the
/// loop is O(batch) with no compiled-shape constraint — but finite, so the
/// serving layer keeps splitting absurd batches instead of wedging one
/// worker on a megabatch.
pub const NATIVE_MAX_BATCH: usize = 64;

/// Examples per internal `forward_batch` call: `execute` chunks larger
/// batches so each arena stays bounded by the chunk, not by
/// [`NATIVE_MAX_BATCH`] — on a BERT-base scale export that is tens of MB
/// instead of ~1 GB per worker. Eight examples give the GEMMs hundreds of
/// rows at full width, enough to amortize packing and blocking; it also
/// keeps the set of distinct arena buckets (and hence resident slabs)
/// small.
const NATIVE_EXEC_CHUNK: usize = 8;

/// Score pin for CLS (never eliminated, paper §3.4) — matches model.py BIG.
const BIG: f32 = 1e6;

/// The native backend: stateless per request — per-variant state lives in
/// the [`NativeModel`] it loads; the kernel config and the persistent
/// kernel pool live in a [`KernelExec`] shared with every loaded model.
pub struct NativeBackend {
    exec: Arc<KernelExec>,
}

impl Default for NativeBackend {
    fn default() -> Self {
        NativeBackend::new()
    }
}

impl NativeBackend {
    /// Backend on the session-default kernel config
    /// (`$POWERBERT_KERNEL_*` or defaults).
    pub fn new() -> NativeBackend {
        NativeBackend::with_config(KernelConfig::from_env())
    }

    /// Backend with an explicit kernel config (thread count, block sizes).
    /// Spawns (and parks) the persistent kernel pool sized from it.
    pub fn with_config(cfg: KernelConfig) -> NativeBackend {
        NativeBackend { exec: Arc::new(KernelExec::new(cfg)) }
    }

    /// Backend sharing an existing exec (pool + config).
    pub fn with_exec(exec: Arc<KernelExec>) -> NativeBackend {
        NativeBackend { exec }
    }

    /// The steady-state execution resources this backend hands to every
    /// model it loads.
    pub fn exec(&self) -> &Arc<KernelExec> {
        &self.exec
    }

    /// Build a ready-to-execute model from the host artifact. This is
    /// where the weight matrices are packed into the blocked kernel's
    /// panel layout — once per load, not per call — and where the arena
    /// peak bytes of every declared `(batch, seq)` cell are planned from
    /// the retention schedule.
    pub fn load(&self, art: &ModelArtifact) -> Result<LoadedModel> {
        let model = NativeModel::load(art, self.exec.clone())
            .with_context(|| format!("native load {}/{}", art.meta.dataset, art.meta.variant))?;
        let dims = model.arena_dims();
        let lanes = self.exec.lanes();
        let arena: Vec<((usize, usize), u64)> = art
            .meta
            .grid_cells()
            .iter()
            .map(|&(b, s)| {
                let chunk = b.min(NATIVE_EXEC_CHUNK);
                let plan = ArenaPlan::plan(&dims, model.retention.as_deref(), chunk, s, lanes);
                ((b, s), plan.peak_bytes())
            })
            .collect();
        if let Some(peak) = arena.iter().map(|&(_, b)| b).max() {
            crate::debugln!(
                "native",
                "{}/{}: planned {} arena cell(s), peak {} KiB per bucket at {} lane(s)",
                art.meta.dataset,
                art.meta.variant,
                arena.len(),
                peak / 1024,
                lanes
            );
        }
        Ok(LoadedModel::new(
            art.meta.clone(),
            "native",
            CellPlan::Exact { max_batch: NATIVE_MAX_BATCH, max_seq: art.meta.seq_len, arena },
            Box::new(model),
        ))
    }
}

/// One encoder layer's weights: projections packed for the blocked GEMM,
/// biases and LayerNorm parameters raw.
struct LayerWeights {
    wq: PackedLinear,
    bq: Vec<f32>,
    wk: PackedLinear,
    bk: Vec<f32>,
    wv: PackedLinear,
    bv: Vec<f32>,
    wo: PackedLinear,
    bo: Vec<f32>,
    ln1_g: Vec<f32>,
    ln1_b: Vec<f32>,
    w1: PackedLinear,
    b1: Vec<f32>,
    w2: PackedLinear,
    b2: Vec<f32>,
    ln2_g: Vec<f32>,
    ln2_b: Vec<f32>,
    ffn_size: usize,
}

/// A variant's weights in forward-pass form plus its processed-token
/// telemetry and per-bucket arena cache.
pub struct NativeModel {
    exec: Arc<KernelExec>,
    hidden: usize,
    heads: usize,
    num_classes: usize,
    vocab: usize,
    type_vocab: usize,
    max_pos: usize,
    retention: Option<Vec<usize>>,
    word: Vec<f32>,
    word_proj: Option<(usize, Vec<f32>)>, // (embed_factor, [E, H])
    pos: Vec<f32>,
    type_: Vec<f32>,
    embed_ln_g: Vec<f32>,
    embed_ln_b: Vec<f32>,
    layers: Vec<LayerWeights>,
    final_g: Vec<f32>,
    final_b: Vec<f32>,
    pooler_w: PackedLinear,
    pooler_b: Vec<f32>,
    head_w: PackedLinear,
    head_b: Vec<f32>,
    /// Word-vectors processed per encoder (FFN width after extraction),
    /// accumulated across every executed row.
    layer_tokens: Vec<AtomicU64>,
    /// Parked arenas by `(batch, seq)` bucket: a bucket's first request
    /// plans and allocates its slab, every later request reuses it. The
    /// slot is `None` while a request has the arena checked out, so
    /// concurrent callers degrade to a fresh (dropped-after) arena rather
    /// than blocking each other.
    arenas: Mutex<HashMap<(usize, usize), Option<Box<ForwardArena>>>>,
    /// Largest per-bucket slab this model has materialized (bytes).
    arena_peak: AtomicU64,
    /// Arenas materialized (≈ distinct buckets served).
    arenas_planned: AtomicU64,
    /// Word-vector·layer counts the examples themselves demanded (each
    /// example at its own width) vs the **ghost** rows a rectangular
    /// batch-max execution adds on top. Token counts proxy FLOPs (the
    /// per-row layer cost is width-independent to first order);
    /// `eliminated_waste_ratio = ghost / kept` in the worker stats. Both
    /// paths account identically, so the ratio reports the waste the
    /// ragged path eliminates (or the padded path incurs).
    tokens_kept: AtomicU64,
    tokens_ghost: AtomicU64,
}

impl NativeModel {
    /// Bind a host artifact's weights into forward-pass form (packing
    /// every projection for the blocked kernel) on the given execution
    /// resources.
    pub fn load(art: &ModelArtifact, exec: Arc<KernelExec>) -> Result<NativeModel> {
        let meta = &art.meta;
        let hidden = meta.hidden_size;
        let heads = meta.num_heads;
        // Weight precision is fixed at pack time: panels are quantized
        // here (or kept f32); there is no per-call precision switch.
        let precision = exec.config().precision;
        if hidden == 0 || heads == 0 {
            bail!(
                "meta.json lacks hidden_size/num_heads (re-export with a current \
                 python/compile; got hidden_size={hidden}, num_heads={heads})"
            );
        }
        if hidden % heads != 0 {
            bail!("hidden_size {hidden} not divisible by num_heads {heads}");
        }
        let w = |name: &str| -> Result<(Vec<usize>, Vec<f32>)> {
            let (dims, data) = art
                .weight(name)
                .ok_or_else(|| anyhow!("weights.npz missing {name}"))?;
            Ok((dims.to_vec(), data.to_vec()))
        };
        let expect = |name: &str, dims: &[usize], want: &[usize]| -> Result<()> {
            if dims != want {
                bail!("{name}: shape {dims:?}, expected {want:?}");
            }
            Ok(())
        };

        let (word_dims, word) = w("embed/word")?;
        if word_dims.len() != 2 {
            bail!("embed/word: shape {word_dims:?}, expected rank 2");
        }
        let (vocab, embed_width) = (word_dims[0], word_dims[1]);
        let word_proj = match art.weight("embed/word_proj") {
            Some((dims, data)) => {
                expect("embed/word_proj", dims, &[embed_width, hidden])?;
                Some((embed_width, data.to_vec()))
            }
            None => {
                expect("embed/word", &word_dims, &[vocab, hidden])?;
                None
            }
        };
        let (pos_dims, pos) = w("embed/pos")?;
        if pos_dims.len() != 2 || pos_dims[1] != hidden {
            bail!("embed/pos: shape {pos_dims:?}, expected [max_len, {hidden}]");
        }
        let max_pos = pos_dims[0];
        if meta.seq_len > max_pos {
            bail!("seq_len {} exceeds position table {max_pos}", meta.seq_len);
        }
        let (type_dims, type_) = w("embed/type")?;
        if type_dims.len() != 2 || type_dims[1] != hidden {
            bail!("embed/type: shape {type_dims:?}, expected [type_vocab, {hidden}]");
        }
        let type_vocab = type_dims[0];
        let (g_dims, embed_ln_g) = w("embed/ln_g")?;
        expect("embed/ln_g", &g_dims, &[hidden])?;
        let (b_dims, embed_ln_b) = w("embed/ln_b")?;
        expect("embed/ln_b", &b_dims, &[hidden])?;

        let mut layers = Vec::with_capacity(meta.num_layers);
        for j in 0..meta.num_layers {
            // ALBERT-style shared parameters export only layers/0.
            let jj = if art.weight(&format!("layers/{j}/wq")).is_some() { j } else { 0 };
            let lw = |suffix: &str, want: &[usize]| -> Result<Vec<f32>> {
                let name = format!("layers/{jj}/{suffix}");
                let (dims, data) = w(&name)?;
                expect(&name, &dims, want)?;
                Ok(data)
            };
            // Square [h, h] projection, packed (and, under `--precision
            // int8`, per-channel quantized) for the blocked kernel.
            let proj = |suffix: &str| -> Result<PackedLinear> {
                Ok(PackedLinear::pack(&lw(suffix, &[hidden, hidden])?, hidden, hidden, precision))
            };
            let (w1_dims, w1) = w(&format!("layers/{jj}/w1"))?;
            if w1_dims.len() != 2 || w1_dims[0] != hidden {
                bail!("layers/{jj}/w1: shape {w1_dims:?}, expected [{hidden}, ffn]");
            }
            let ffn_size = w1_dims[1];
            layers.push(LayerWeights {
                wq: proj("wq")?,
                bq: lw("bq", &[hidden])?,
                wk: proj("wk")?,
                bk: lw("bk", &[hidden])?,
                wv: proj("wv")?,
                bv: lw("bv", &[hidden])?,
                wo: proj("wo")?,
                bo: lw("bo", &[hidden])?,
                ln1_g: lw("ln1_g", &[hidden])?,
                ln1_b: lw("ln1_b", &[hidden])?,
                w1: PackedLinear::pack(&w1, hidden, ffn_size, precision),
                b1: lw("b1", &[ffn_size])?,
                w2: PackedLinear::pack(&lw("w2", &[ffn_size, hidden])?, ffn_size, hidden, precision),
                b2: lw("b2", &[hidden])?,
                ln2_g: lw("ln2_g", &[hidden])?,
                ln2_b: lw("ln2_b", &[hidden])?,
                ffn_size,
            });
        }
        if layers.is_empty() {
            bail!("meta.json declares no encoder layers");
        }

        let (fg_dims, final_g) = w("final_ln/g")?;
        expect("final_ln/g", &fg_dims, &[hidden])?;
        let (fb_dims, final_b) = w("final_ln/b")?;
        expect("final_ln/b", &fb_dims, &[hidden])?;
        let (pw_dims, pooler_w) = w("pooler/w")?;
        expect("pooler/w", &pw_dims, &[hidden, hidden])?;
        let (pb_dims, pooler_b) = w("pooler/b")?;
        expect("pooler/b", &pb_dims, &[hidden])?;
        let (hw_dims, head_w) = w("head/w")?;
        if hw_dims.len() != 2 || hw_dims[0] != hidden {
            bail!("head/w: shape {hw_dims:?}, expected [{hidden}, classes]");
        }
        let num_classes = hw_dims[1];
        let (hb_dims, head_b) = w("head/b")?;
        expect("head/b", &hb_dims, &[num_classes])?;

        let n_layers = layers.len();
        Ok(NativeModel {
            exec,
            hidden,
            heads,
            num_classes,
            vocab,
            type_vocab,
            max_pos,
            retention: meta.retention.clone(),
            word,
            word_proj,
            pos,
            type_,
            embed_ln_g,
            embed_ln_b,
            layers,
            final_g,
            final_b,
            pooler_w: PackedLinear::pack(&pooler_w, hidden, hidden, precision),
            pooler_b,
            head_w: PackedLinear::pack(&head_w, hidden, num_classes, precision),
            head_b,
            layer_tokens: (0..n_layers).map(|_| AtomicU64::new(0)).collect(),
            arenas: Mutex::new(HashMap::new()),
            arena_peak: AtomicU64::new(0),
            arenas_planned: AtomicU64::new(0),
            tokens_kept: AtomicU64::new(0),
            tokens_ghost: AtomicU64::new(0),
        })
    }

    /// Output classes of the classifier head.
    pub fn num_classes(&self) -> usize {
        self.num_classes
    }

    /// Architecture quantities the arena planner needs.
    fn arena_dims(&self) -> ArenaDims {
        ArenaDims {
            hidden: self.hidden,
            heads: self.heads,
            ffn: self.layers.iter().map(|l| l.ffn_size).max().unwrap_or(0),
            layers: self.layers.len(),
        }
    }

    /// Take the bucket's parked arena, or plan + allocate one on the
    /// bucket's first request (or while a concurrent request holds it).
    fn checkout_arena(&self, batch: usize, seq: usize) -> Box<ForwardArena> {
        if let Some(slot) = self.arenas.lock().unwrap().get_mut(&(batch, seq)) {
            if let Some(arena) = slot.take() {
                return arena;
            }
        }
        let plan = ArenaPlan::plan(
            &self.arena_dims(),
            self.retention.as_deref(),
            batch,
            seq,
            self.exec.lanes(),
        );
        let arena = Box::new(ForwardArena::new(plan));
        self.arenas_planned.fetch_add(1, Ordering::Relaxed);
        self.arena_peak.fetch_max(arena.peak_bytes(), Ordering::Relaxed);
        arena
    }

    /// Park the arena for the next request of its bucket (keeping the
    /// incumbent if a concurrent request already parked one).
    fn checkin_arena(&self, arena: Box<ForwardArena>) {
        let key = (arena.plan().batch, arena.plan().seq);
        let mut map = self.arenas.lock().unwrap();
        let slot = map.entry(key).or_insert(None);
        if slot.is_none() {
            *slot = Some(arena);
        }
    }

    /// Forward `batch` examples of `seq` tokens, **appending** the
    /// `[batch, num_classes]` logits to `logits_out` — the steady-state
    /// entry point: after a `(batch, seq)` bucket's first call (which
    /// plans and allocates its arena) this performs zero heap allocations,
    /// provided `logits_out` has capacity (`tests/alloc_steady_state.rs`
    /// pins this with a counting allocator, on both execution paths).
    /// Dispatches to the ragged path unless the kernel config says
    /// `--ragged off`.
    pub fn forward_into(
        &self,
        tokens: &[i32],
        segments: &[i32],
        batch: usize,
        seq: usize,
        logits_out: &mut Vec<f32>,
    ) -> Result<()> {
        if self.exec.config().ragged {
            self.forward_batch_ragged(tokens, segments, batch, seq, logits_out, None, None, None)?;
        } else {
            self.forward_batch(tokens, segments, batch, seq, logits_out, None, None)?;
        }
        Ok(())
    }

    /// Shape and id validation shared by both forward paths. Every
    /// fallible step happens here, before the arena checkout, so an error
    /// can never strand a bucket's slab outside the cache.
    fn validate_call(
        &self,
        tokens: &[i32],
        segments: &[i32],
        batch: usize,
        seq: usize,
    ) -> Result<()> {
        if seq > self.max_pos {
            bail!("seq {seq} exceeds position table {}", self.max_pos);
        }
        if tokens.len() != batch * seq || segments.len() != batch * seq {
            bail!("native forward: expected {batch}x{seq} tokens, got {}", tokens.len());
        }
        for (&tok, &seg) in tokens.iter().zip(segments.iter()) {
            if tok < 0 || tok as usize >= self.vocab {
                bail!("token id {tok} outside vocab of {}", self.vocab);
            }
            if seg < 0 || seg as usize >= self.type_vocab {
                bail!("segment id {seg} outside type vocab of {}", self.type_vocab);
            }
        }
        Ok(())
    }

    /// Embedding lookup + mask + original positions + embedding LayerNorm,
    /// identical for both execution paths (the ragged layout starts
    /// uniform — PAD rows included — and only diverges from the padded one
    /// at the first extract layer). Arena regions arrive dirty: every row
    /// is fully written here (the factorized path zeroes before
    /// accumulating).
    fn embed(
        &self,
        tokens: &[i32],
        segments: &[i32],
        batch: usize,
        seq: usize,
        x: &mut [f32],
        mask: &mut [f32],
        positions: &mut [i32],
    ) {
        let h = self.hidden;
        for b in 0..batch {
            for i in 0..seq {
                let idx = b * seq + i;
                let tok = tokens[idx];
                let seg = segments[idx];
                mask[idx] = if tok == PAD_ID { 0.0 } else { 1.0 };
                positions[idx] = i as i32;
                let row = &mut x[idx * h..(idx + 1) * h];
                match &self.word_proj {
                    None => {
                        let wrow = &self.word[tok as usize * h..(tok as usize + 1) * h];
                        row.copy_from_slice(wrow);
                    }
                    Some((e, proj_w)) => {
                        // Factorized embedding: word[tok] (E) @ proj (E x H).
                        row.fill(0.0);
                        let wrow = &self.word[tok as usize * e..(tok as usize + 1) * e];
                        for (kk, &wv) in wrow.iter().enumerate() {
                            let prow = &proj_w[kk * h..(kk + 1) * h];
                            for (c, &pv) in prow.iter().enumerate() {
                                row[c] += wv * pv;
                            }
                        }
                    }
                }
                let prow = &self.pos[i * h..(i + 1) * h];
                let trow = &self.type_[seg as usize * h..(seg as usize + 1) * h];
                for c in 0..h {
                    row[c] += prow[c] + trow[c];
                }
            }
        }
        layer_norm(&mut x[..batch * seq * h], h, &self.embed_ln_g, &self.embed_ln_b);
    }

    /// Forward `batch` examples of `seq` tokens through batch-level kernel
    /// calls: every projection is one `[batch * n_j, k]` GEMM, where `n_j`
    /// starts at `seq` and shrinks at each extract layer — all rows of a
    /// batch keep the same count (`retention[j]`), so the batch stays
    /// rectangular through every layer. Appends the logits to
    /// `logits_out`; when `trace_out` is given, appends the per-example
    /// surviving original positions (`[batch, L, seq]`, -1-padded — the
    /// debug path, exempt from the zero-allocation contract).
    ///
    /// Every transient lives in the bucket's [`ForwardArena`]: surviving
    /// rows are compacted **in place** at each extract layer (destination
    /// row index never exceeds source row index when `keep < n`, so
    /// ascending copies never clobber unread rows), and the arena's live
    /// region shrinks layer by layer with elimination.
    ///
    /// When `threshold` carries an active attention-mass threshold
    /// (`0 < t < 1`), each extract layer keeps the **batch max** of the
    /// per-example demanded kept-set sizes
    /// ([`demanded_k`](super::adaptive::demanded_k)), clamped to the
    /// schedule entry as a ceiling — the arena plan and the uniform GEMM
    /// shapes stay valid because the adaptive width never exceeds the
    /// planned one, and the CLS/PAD pinning of [`keep_indices`] is
    /// untouched (adaptive only changes *how many* survive, never *which*
    /// ranking selects them). A threshold at or above 1.0 must be mapped
    /// to `None` by the caller; this function additionally filters it, so
    /// the fixed path is taken bit-for-bit. Returns the per-example
    /// word-vectors processed (Σ over layers of the post-extraction
    /// width — uniform across the rows of one call).
    #[allow(clippy::too_many_arguments)]
    fn forward_batch(
        &self,
        tokens: &[i32],
        segments: &[i32],
        batch: usize,
        seq: usize,
        logits_out: &mut Vec<f32>,
        mut trace_out: Option<&mut Vec<i32>>,
        threshold: Option<f32>,
    ) -> Result<u64> {
        let threshold = threshold.filter(|&t| t > 0.0 && t < 1.0);
        let h = self.hidden;
        let heads = self.heads;
        let d = h / heads;
        let n_layers = self.layers.len();
        let exec = &*self.exec;
        self.validate_call(tokens, segments, batch, seq)?;

        let trace_base = trace_out.as_deref().map_or(0, |t| t.len());
        if let Some(tr) = trace_out.as_deref_mut() {
            tr.resize(trace_base + batch * n_layers * seq, -1);
        }

        let mut arena = self.checkout_arena(batch, seq);
        let mut tokens_per_example: u64 = 0;
        let mut kept_acc: u64 = 0;
        let mut ghost_acc: u64 = 0;
        {
            let super::arena::Regions {
                x,
                mask,
                sig,
                hx,
                q,
                k,
                v,
                ctx,
                proj,
                a1,
                attn_ctx,
                attn_sig,
                attn_probs,
                cls,
                pooled,
                topk_scores,
                positions,
                topk_order,
                row_offsets,
            } = arena.regions();

            self.embed(tokens, segments, batch, seq, x, mask, positions);

            // The padded path repurposes the (otherwise idle) ragged
            // offset region as per-example ideal-width scratch: under an
            // adaptive threshold it tracks what a ragged execution would
            // have kept, feeding the ghost-row accounting below.
            for w in row_offsets[..batch].iter_mut() {
                *w = seq as i32;
            }

            // Surviving word-vectors per example — uniform across the batch.
            let mut n = seq;
            for (j, layer) in self.layers.iter().enumerate() {
                let rows = batch * n;
                let rh = rows * h;
                // --- attention half: x = x + proj(MHA(LN(x))), plus scores.
                hx[..rh].copy_from_slice(&x[..rh]);
                layer_norm(&mut hx[..rh], h, &layer.ln1_g, &layer.ln1_b);
                layer.wq.matmul_bias(&hx[..rh], rows, &layer.bq, exec, &mut q[..rh]);
                layer.wk.matmul_bias(&hx[..rh], rows, &layer.bk, exec, &mut k[..rh]);
                layer.wv.matmul_bias(&hx[..rh], rows, &layer.bv, exec, &mut v[..rh]);

                let scratch = AttnScratch {
                    ctx_heads: &mut attn_ctx[..],
                    sig_heads: &mut attn_sig[..],
                    probs: &mut attn_probs[..],
                };
                masked_attention(
                    &q[..rh],
                    &k[..rh],
                    &v[..rh],
                    &mask[..rows],
                    batch,
                    n,
                    heads,
                    d,
                    exec,
                    scratch,
                    &mut ctx[..rh],
                    &mut sig[..rows],
                );
                layer.wo.matmul_bias(&ctx[..rh], rows, &layer.bo, exec, &mut proj[..rh]);
                for (xv, pv) in x[..rh].iter_mut().zip(proj[..rh].iter()) {
                    *xv += pv;
                }

                // --- extract layer (between attention and FFN, §3.2/Fig 4):
                // in-place compaction of the surviving rows.
                if let Some(keep) = self.retention.as_ref().and_then(|r| r.get(j)).copied() {
                    // Guard a malformed manifest: at least CLS always survives
                    // (derive_retention clamps to >= 1 on the export side).
                    let mut keep = keep.max(1);
                    if let Some(t) = threshold {
                        // Adaptive retention: the batch executes at the max
                        // per-example demanded kept-set size, with the
                        // schedule entry as a ceiling (so the arena plan —
                        // sized from the schedule — stays an upper bound).
                        // demanded_k borrows the top-k score scratch; it is
                        // fully consumed before keep_indices reuses it.
                        let mut demanded = 1usize;
                        for b in 0..batch {
                            let d_b = super::adaptive::demanded_k(
                                &sig[b * n..(b + 1) * n],
                                &mask[b * n..(b + 1) * n],
                                t,
                                &mut topk_scores[..],
                            );
                            demanded = demanded.max(d_b);
                            // Per-example ideal width: what this example
                            // alone would keep (ghost accounting only —
                            // execution still uses the batch max).
                            let ideal = (row_offsets[b] as usize).min(keep.min(d_b.max(1)));
                            row_offsets[b] = ideal as i32;
                        }
                        keep = keep.min(demanded);
                    }
                    if keep < n {
                        for b in 0..batch {
                            let kept = keep_indices(
                                &sig[b * n..(b + 1) * n],
                                &mask[b * n..(b + 1) * n],
                                keep,
                                &mut topk_scores[..],
                                &mut topk_order[..],
                            );
                            for (slot, &src_i) in kept.iter().enumerate() {
                                let dst = b * keep + slot;
                                let src = b * n + src_i as usize;
                                // dst <= src always (keep < n and kept
                                // indices ascend), so ascending copies
                                // never clobber an unread source row.
                                if dst != src {
                                    x.copy_within(src * h..(src + 1) * h, dst * h);
                                    mask[dst] = mask[src];
                                    positions[dst] = positions[src];
                                }
                            }
                        }
                        n = keep;
                    }
                }
                self.layer_tokens[j].fetch_add((batch * n) as u64, Ordering::Relaxed);
                tokens_per_example += n as u64;
                let kept: u64 = if threshold.is_some() {
                    row_offsets[..batch]
                        .iter()
                        .map(|&w| (w as usize).min(n) as u64)
                        .sum()
                } else {
                    // Fixed schedule: every example demands the schedule
                    // width, so the rectangular batch carries no ghosts.
                    (batch * n) as u64
                };
                kept_acc += kept;
                ghost_acc += (batch * n) as u64 - kept;
                if let Some(tr) = trace_out.as_deref_mut() {
                    for b in 0..batch {
                        let row = trace_base + (b * n_layers + j) * seq;
                        tr[row..row + n].copy_from_slice(&positions[b * n..(b + 1) * n]);
                    }
                }

                // --- FFN half: x = x + FFN(LN(x)), GELU fused into the
                // first GEMM's epilogue; `proj` doubles as the
                // down-projection output.
                let rows = batch * n;
                let rh = rows * h;
                hx[..rh].copy_from_slice(&x[..rh]);
                layer_norm(&mut hx[..rh], h, &layer.ln2_g, &layer.ln2_b);
                let rf = rows * layer.ffn_size;
                layer.w1.matmul_bias_gelu(&hx[..rh], rows, &layer.b1, exec, &mut a1[..rf]);
                layer.w2.matmul_bias(&a1[..rf], rows, &layer.b2, exec, &mut proj[..rh]);
                for (xv, av) in x[..rh].iter_mut().zip(proj[..rh].iter()) {
                    *xv += av;
                }
            }

            // --- pooler + classifier head from each example's CLS vector
            // (row 0 of its block — pinned there by the extract layer).
            layer_norm(&mut x[..batch * n * h], h, &self.final_g, &self.final_b);
            for b in 0..batch {
                cls[b * h..(b + 1) * h].copy_from_slice(&x[b * n * h..b * n * h + h]);
            }
            self.pooler_w.matmul_bias_tanh(
                &cls[..batch * h],
                batch,
                &self.pooler_b,
                exec,
                &mut pooled[..batch * h],
            );
            let base = logits_out.len();
            logits_out.resize(base + batch * self.num_classes, 0.0);
            self.head_w.matmul_bias(
                &pooled[..batch * h],
                batch,
                &self.head_b,
                exec,
                &mut logits_out[base..],
            );
        }
        self.tokens_kept.fetch_add(kept_acc, Ordering::Relaxed);
        self.tokens_ghost.fetch_add(ghost_acc, Ordering::Relaxed);
        self.checkin_arena(arena);
        Ok(tokens_per_example)
    }

    /// Ragged forward: the default path. Where [`Self::forward_batch`]
    /// executes every example at the batch-max width, this one compacts
    /// each example to its **own** demanded width at every extract layer,
    /// holding the batch in a row-offset ragged layout — one contiguous
    /// `[Σ kept_b, hidden]` prefix of the arena's `x` region plus a
    /// `batch + 1` prefix-sum offset table (see `docs/ARCHITECTURE.md`
    /// § "Ragged execution"):
    ///
    /// * every projection stays **one** GEMM over the concatenated live
    ///   rows ([`PackedLinear::matmul_bias_ragged`]) — elimination shrinks
    ///   the row count to Σ kept_b instead of `batch · max_b kept_b`;
    /// * attention runs per-(example, head) tasks over the offset table
    ///   ([`masked_attention_ragged`]) with the fixed ascending merge, so
    ///   results stay bit-identical for any thread count;
    /// * survivors compact **in place** in one ascending interleaved
    ///   pass: `dst = new_off[b] + slot ≤ src = old_off[b] + src_i`
    ///   always (`new_off[b] ≤ old_off[b]`, kept indices ascend), so no
    ///   copy clobbers an unread source row, and the offset table
    ///   rewrites itself in the same pass (`off[b]` is read before it is
    ///   overwritten).
    ///
    /// Under a fixed schedule (no threshold) every example keeps the same
    /// count and this path is **bit-identical** to the padded oracle —
    /// same GEMM row blocks, same attention task slabs, same merge order
    /// (`tests/ragged.rs` pins zero argmax flips on the committed
    /// goldens). Under an active threshold each example's rows match a
    /// batch-of-one padded run of that example
    /// (`tests/prop_kernels.rs`).
    ///
    /// When `per_row` is given, appends each example's processed
    /// word-vector count (Σ over layers of its *own* post-extraction
    /// width). Returns the batch total of the same.
    #[allow(clippy::too_many_arguments)]
    fn forward_batch_ragged(
        &self,
        tokens: &[i32],
        segments: &[i32],
        batch: usize,
        seq: usize,
        logits_out: &mut Vec<f32>,
        mut trace_out: Option<&mut Vec<i32>>,
        threshold: Option<f32>,
        mut per_row: Option<&mut Vec<u64>>,
    ) -> Result<u64> {
        let threshold = threshold.filter(|&t| t > 0.0 && t < 1.0);
        let h = self.hidden;
        let heads = self.heads;
        let d = h / heads;
        let n_layers = self.layers.len();
        let exec = &*self.exec;
        self.validate_call(tokens, segments, batch, seq)?;

        let trace_base = trace_out.as_deref().map_or(0, |t| t.len());
        if let Some(tr) = trace_out.as_deref_mut() {
            tr.resize(trace_base + batch * n_layers * seq, -1);
        }
        let per_row_base = per_row.as_deref().map_or(0, |p| p.len());
        if let Some(pr) = per_row.as_deref_mut() {
            pr.resize(per_row_base + batch, 0);
        }

        let mut arena = self.checkout_arena(batch, seq);
        let mut tokens_total: u64 = 0;
        let mut kept_acc: u64 = 0;
        let mut ghost_acc: u64 = 0;
        {
            let super::arena::Regions {
                x,
                mask,
                sig,
                hx,
                q,
                k,
                v,
                ctx,
                proj,
                a1,
                attn_ctx,
                attn_sig,
                attn_probs,
                cls,
                pooled,
                topk_scores,
                positions,
                topk_order,
                row_offsets,
            } = arena.regions();

            self.embed(tokens, segments, batch, seq, x, mask, positions);
            // The layout starts uniform — PAD rows included, exactly like
            // the padded path — so fixed-schedule runs stay bit-identical
            // and the per-layer token telemetry matches the oracle.
            for (b, off) in row_offsets[..batch + 1].iter_mut().enumerate() {
                *off = (b * seq) as i32;
            }

            for (j, layer) in self.layers.iter().enumerate() {
                let total = row_offsets[batch] as usize;
                let rh = total * h;
                // --- attention half over the concatenated live rows.
                hx[..rh].copy_from_slice(&x[..rh]);
                layer_norm(&mut hx[..rh], h, &layer.ln1_g, &layer.ln1_b);
                let hx_r = RaggedRows::new(&hx[..rh], &row_offsets[..batch + 1], h);
                layer.wq.matmul_bias_ragged(hx_r, &layer.bq, exec, &mut q[..rh]);
                layer.wk.matmul_bias_ragged(hx_r, &layer.bk, exec, &mut k[..rh]);
                layer.wv.matmul_bias_ragged(hx_r, &layer.bv, exec, &mut v[..rh]);

                let scratch = AttnScratch {
                    ctx_heads: &mut attn_ctx[..],
                    sig_heads: &mut attn_sig[..],
                    probs: &mut attn_probs[..],
                };
                masked_attention_ragged(
                    &q[..rh],
                    &k[..rh],
                    &v[..rh],
                    &mask[..total],
                    &row_offsets[..batch + 1],
                    heads,
                    d,
                    exec,
                    scratch,
                    &mut ctx[..rh],
                    &mut sig[..total],
                );
                let ctx_r = RaggedRows::new(&ctx[..rh], &row_offsets[..batch + 1], h);
                layer.wo.matmul_bias_ragged(ctx_r, &layer.bo, exec, &mut proj[..rh]);
                for (xv, pv) in x[..rh].iter_mut().zip(proj[..rh].iter()) {
                    *xv += pv;
                }

                // --- extract layer, per-example widths: one ascending
                // interleaved pass compacts survivors and rewrites the
                // offset table in place.
                let schedule_keep = self.retention.as_ref().and_then(|r| r.get(j)).copied();
                let mut dst_base = 0usize;
                let mut max_width = 0usize;
                for b in 0..batch {
                    let src_base = row_offsets[b] as usize;
                    let n_b = row_offsets[b + 1] as usize - src_base;
                    let mut keep_b = n_b;
                    if let Some(keep) = schedule_keep {
                        let mut want = keep.max(1);
                        if let Some(t) = threshold {
                            let d_b = super::adaptive::demanded_k(
                                &sig[src_base..src_base + n_b],
                                &mask[src_base..src_base + n_b],
                                t,
                                &mut topk_scores[..],
                            );
                            want = want.min(d_b.max(1));
                        }
                        keep_b = want.min(n_b);
                    }
                    if keep_b < n_b {
                        let kept = keep_indices(
                            &sig[src_base..src_base + n_b],
                            &mask[src_base..src_base + n_b],
                            keep_b,
                            &mut topk_scores[..],
                            &mut topk_order[..],
                        );
                        for (slot, &src_i) in kept.iter().enumerate() {
                            let dst = dst_base + slot;
                            let src = src_base + src_i as usize;
                            if dst != src {
                                x.copy_within(src * h..(src + 1) * h, dst * h);
                                mask[dst] = mask[src];
                                positions[dst] = positions[src];
                            }
                        }
                    } else if dst_base != src_base {
                        // This example keeps all its rows but earlier
                        // examples shrank: shift the whole block left.
                        x.copy_within(src_base * h..(src_base + n_b) * h, dst_base * h);
                        mask.copy_within(src_base..src_base + n_b, dst_base);
                        positions.copy_within(src_base..src_base + n_b, dst_base);
                    }
                    row_offsets[b] = dst_base as i32;
                    if let Some(tr) = trace_out.as_deref_mut() {
                        let row = trace_base + (b * n_layers + j) * seq;
                        tr[row..row + keep_b]
                            .copy_from_slice(&positions[dst_base..dst_base + keep_b]);
                    }
                    if let Some(pr) = per_row.as_deref_mut() {
                        pr[per_row_base + b] += keep_b as u64;
                    }
                    dst_base += keep_b;
                    max_width = max_width.max(keep_b);
                }
                row_offsets[batch] = dst_base as i32;
                self.layer_tokens[j].fetch_add(dst_base as u64, Ordering::Relaxed);
                tokens_total += dst_base as u64;
                kept_acc += dst_base as u64;
                ghost_acc += (batch * max_width) as u64 - dst_base as u64;

                // --- FFN half over the (possibly narrower) live rows.
                let total = row_offsets[batch] as usize;
                let rh = total * h;
                hx[..rh].copy_from_slice(&x[..rh]);
                layer_norm(&mut hx[..rh], h, &layer.ln2_g, &layer.ln2_b);
                let rf = total * layer.ffn_size;
                let hx_r = RaggedRows::new(&hx[..rh], &row_offsets[..batch + 1], h);
                layer.w1.matmul_bias_gelu_ragged(hx_r, &layer.b1, exec, &mut a1[..rf]);
                let a1_r = RaggedRows::new(&a1[..rf], &row_offsets[..batch + 1], layer.ffn_size);
                layer.w2.matmul_bias_ragged(a1_r, &layer.b2, exec, &mut proj[..rh]);
                for (xv, av) in x[..rh].iter_mut().zip(proj[..rh].iter()) {
                    *xv += av;
                }
            }

            // --- pooler + classifier head from each example's CLS vector
            // (row 0 of its ragged block — pinned there by the extract
            // layer).
            let total = row_offsets[batch] as usize;
            layer_norm(&mut x[..total * h], h, &self.final_g, &self.final_b);
            for b in 0..batch {
                let off = row_offsets[b] as usize;
                cls[b * h..(b + 1) * h].copy_from_slice(&x[off * h..off * h + h]);
            }
            self.pooler_w.matmul_bias_tanh(
                &cls[..batch * h],
                batch,
                &self.pooler_b,
                exec,
                &mut pooled[..batch * h],
            );
            let base = logits_out.len();
            logits_out.resize(base + batch * self.num_classes, 0.0);
            self.head_w.matmul_bias(
                &pooled[..batch * h],
                batch,
                &self.head_b,
                exec,
                &mut logits_out[base..],
            );
        }
        self.tokens_kept.fetch_add(kept_acc, Ordering::Relaxed);
        self.tokens_ghost.fetch_add(ghost_acc, Ordering::Relaxed);
        self.checkin_arena(arena);
        Ok(tokens_total)
    }
}

impl CellExecutor for NativeModel {
    fn execute(
        &self,
        tokens: &[i32],
        segments: &[i32],
        batch: usize,
        seq: usize,
        want_trace: bool,
        threshold: Option<f32>,
    ) -> Result<ExecOutput> {
        if tokens.len() != batch * seq || segments.len() != batch * seq {
            bail!("native execute: expected {batch}x{seq} tokens, got {}", tokens.len());
        }
        let n_layers = self.layers.len();
        let ragged = self.exec.config().ragged;
        let mut logits = Vec::with_capacity(batch * self.num_classes);
        let mut kept = want_trace.then(|| Vec::with_capacity(batch * n_layers * seq));
        let mut tokens_per_row = Vec::with_capacity(batch);
        let mut r = 0;
        while r < batch {
            let chunk = NATIVE_EXEC_CHUNK.min(batch - r);
            if ragged {
                // The ragged path reports each row's own width sum.
                self.forward_batch_ragged(
                    &tokens[r * seq..(r + chunk) * seq],
                    &segments[r * seq..(r + chunk) * seq],
                    chunk,
                    seq,
                    &mut logits,
                    kept.as_mut(),
                    threshold,
                    Some(&mut tokens_per_row),
                )?;
            } else {
                let per_example = self.forward_batch(
                    &tokens[r * seq..(r + chunk) * seq],
                    &segments[r * seq..(r + chunk) * seq],
                    chunk,
                    seq,
                    &mut logits,
                    kept.as_mut(),
                    threshold,
                )?;
                // Uniform within a chunk (the batch-max execution rule),
                // so every row of the chunk reports the chunk's width sum.
                tokens_per_row.extend(std::iter::repeat(per_example).take(chunk));
            }
            r += chunk;
        }
        Ok(ExecOutput {
            logits,
            num_classes: self.num_classes,
            kept,
            tokens_per_row: Some(tokens_per_row),
        })
    }

    fn layer_tokens(&self) -> Option<Vec<u64>> {
        Some(
            self.layer_tokens
                .iter()
                .map(|c| c.load(Ordering::Relaxed))
                .collect(),
        )
    }

    fn memory_stats(&self) -> Option<MemoryStats> {
        Some(MemoryStats {
            arena_peak_bytes: self.arena_peak.load(Ordering::Relaxed),
            arena_buckets: self.arenas_planned.load(Ordering::Relaxed),
            pool_threads: self.exec.lanes() as u64,
            pool_jobs: self.exec.pool().jobs(),
            precision: self.exec.config().precision.as_str(),
            isa: active_isa(),
            tokens_kept: self.tokens_kept.load(Ordering::Relaxed),
            tokens_ghost: self.tokens_ghost.load(Ordering::Relaxed),
        })
    }
}

/// Indices of the `keep` highest-scored positions, in original (ascending)
/// order, computed entirely in the arena's `scores`/`order` scratch (no
/// allocation, no stable sort — stability is replaced by an explicit
/// ascending-index tiebreak, which selects the identical set and order).
///
/// This is the enforcement site of the paper's §3.4 pinning invariant
/// (the property `rust/tests` asserts is *established here*):
/// * **CLS survives every extract layer**: position 0's score is
///   overwritten with `BIG` = 1e6, above any attainable column sum
///   (significance is bounded by `heads × seq`), so the classifier's
///   readout vector can never be eliminated.
/// * **PAD sinks below any real word**: masked positions score -1.0,
///   strictly below every real column sum (those are ≥ 0), so a PAD
///   survives only when `keep` exceeds the number of real tokens.
/// * Ties (e.g. between PAD columns) resolve to the lowest original index
///   — matching `jnp.argsort` in `model.py` exactly, which the
///   golden-logit parity fixtures depend on.
fn keep_indices<'a>(
    sig: &[f32],
    mask: &[f32],
    keep: usize,
    scores: &mut [f32],
    order: &'a mut [i32],
) -> &'a [i32] {
    let n = sig.len();
    let scores = &mut scores[..n];
    for (i, &s) in sig.iter().enumerate() {
        scores[i] = if mask[i] > 0.0 { s } else { -1.0 };
    }
    scores[0] = BIG;
    let (order, _) = order.split_at_mut(n);
    for (i, o) in order.iter_mut().enumerate() {
        *o = i as i32;
    }
    order.sort_unstable_by(|&a, &b| {
        scores[b as usize]
            .total_cmp(&scores[a as usize])
            .then_with(|| a.cmp(&b))
    });
    let (kept, _) = order.split_at_mut(keep);
    kept.sort_unstable();
    kept
}

#[cfg(test)]
mod tests {
    use super::*;

    fn topk(sig: &[f32], mask: &[f32], keep: usize) -> Vec<i32> {
        let mut scores = vec![0f32; sig.len()];
        let mut order = vec![0i32; sig.len()];
        keep_indices(sig, mask, keep, &mut scores, &mut order).to_vec()
    }

    #[test]
    fn topk_pins_cls_and_sinks_pad() {
        // 6 positions, PADs at 4/5; keep 3 -> CLS + the two best real.
        let sig = vec![0.1, 2.0, 0.5, 1.5, 9.0, 9.0];
        let mask = vec![1.0, 1.0, 1.0, 1.0, 0.0, 0.0];
        assert_eq!(topk(&sig, &mask, 3), vec![0, 1, 3]);
        // Keep beyond the real count: PAD ties resolve to ascending index.
        assert_eq!(topk(&sig, &mask, 5), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn topk_scratch_is_reusable_across_widths() {
        // The scratch persists across (layer, example) calls of shrinking
        // width — exactly how the forward pass reuses the arena regions.
        let mut scores = vec![f32::NAN; 8];
        let mut order = vec![i32::MIN; 8];
        let sig = vec![0.0, 3.0, 1.0, 2.0];
        let mask = vec![1.0; 4];
        assert_eq!(keep_indices(&sig, &mask, 2, &mut scores, &mut order), &[0, 1]);
        // Narrower follow-up call (as after an extract layer) still works,
        // with the stale tail of the scratch ignored.
        let sig2 = vec![0.0, 0.5];
        let mask2 = vec![1.0; 2];
        assert_eq!(keep_indices(&sig2, &mask2, 1, &mut scores, &mut order), &[0]);
    }

    #[test]
    fn topk_ties_resolve_to_lowest_index() {
        // Equal real scores: the unstable sort's explicit tiebreak must
        // reproduce the old stable sort's choice (lowest original index).
        let sig = vec![0.0, 1.0, 1.0, 1.0, 1.0];
        let mask = vec![1.0; 5];
        assert_eq!(topk(&sig, &mask, 3), vec![0, 1, 2]);
    }
}
