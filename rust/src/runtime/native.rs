//! Native backend: the PoWER-BERT forward pass in pure Rust.
//!
//! Mirrors `python/compile/model.py` / `layers.py` / `kernels/ref.py`
//! operation-for-operation (pre-LN encoder halves, tanh-approximate GELU,
//! attention-column significance, stable top-k extraction between the
//! attention and FFN halves — paper §3.2, Figure 4), reading the exported
//! `weights.npz` directly. Golden-logit fixtures exported by
//! `python -m compile.golden` pin the parity to within 1e-4.
//!
//! The paper's mechanism is implemented literally:
//! * significance of word-vector `w` at encoder `j` is the attention mass
//!   flowing *into* it — the column sum of the softmax matrix over heads
//!   and non-PAD query rows (§3.2);
//! * between the attention module and the FFN, only the `retention[j]`
//!   highest-scored positions survive, CLS pinned on top and PAD below any
//!   real word, original order preserved (§3.4);
//! * a retention entry at or above the current width skips elimination
//!   (short seq buckets execute without it, as in the AOT grid).
//!
//! Execution shapes are exact — a (batch, seq) request runs as-is, so the
//! native path never re-introduces padding word-vectors at the batch
//! boundary, and every eliminated vector is compute actually saved.
//!
//! The hot loops live in [`kernels`](super::kernels): weights are packed
//! into column panels once at [`NativeBackend::load`] time, and the whole
//! batch flows through **batch-level** kernel calls — every projection is
//! one `[batch * n_j, k]` GEMM where `n_j` is the per-layer surviving
//! word-vector count, so elimination literally shrinks the GEMM shapes
//! layer by layer (the paper's compute-∝-word-vectors claim, visible in
//! the kernel shapes themselves). See `benches/native.rs` for the measured
//! kernel and end-to-end numbers, and `docs/ARCHITECTURE.md` for the cost
//! model.

use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{anyhow, bail, Context, Result};

use super::backend::{CellExecutor, CellPlan, ExecOutput, LoadedModel};
use super::engine::ModelArtifact;
use super::kernels::{attention::masked_attention, gemm::PackedGemm, layer_norm, KernelConfig};
use crate::tokenizer::PAD_ID;

/// Largest batch the native executor accepts in one call. Generous — the
/// loop is O(batch) with no compiled-shape constraint — but finite, so the
/// serving layer keeps splitting absurd batches instead of wedging one
/// worker on a megabatch.
pub const NATIVE_MAX_BATCH: usize = 64;

/// Examples per internal `forward_batch` call: `execute` chunks larger
/// batches so the per-layer transient buffers (`[chunk * n_j, ffn]` for
/// the FFN activation and `[chunk * n_j, h]` for QKV/ctx/proj) stay
/// bounded by the chunk, not by [`NATIVE_MAX_BATCH`] — on a BERT-base
/// scale export that is tens of MB instead of ~1 GB per worker. Eight
/// examples give the GEMMs hundreds of rows at full width, enough to
/// amortize packing and blocking.
const NATIVE_EXEC_CHUNK: usize = 8;

/// Score pin for CLS (never eliminated, paper §3.4) — matches model.py BIG.
const BIG: f32 = 1e6;

/// The native backend: stateless per request — per-variant state lives in
/// the [`NativeModel`] it loads, kernel tuning in its [`KernelConfig`].
#[derive(Default)]
pub struct NativeBackend {
    cfg: KernelConfig,
}

impl NativeBackend {
    /// Backend on the session-default kernel config
    /// (`$POWERBERT_KERNEL_*` or defaults).
    pub fn new() -> NativeBackend {
        NativeBackend::with_config(KernelConfig::from_env())
    }

    /// Backend with an explicit kernel config (thread count, block sizes).
    pub fn with_config(cfg: KernelConfig) -> NativeBackend {
        NativeBackend { cfg }
    }

    /// Build a ready-to-execute model from the host artifact. This is
    /// where the weight matrices are packed into the blocked kernel's
    /// panel layout — once per load, not per call.
    pub fn load(&self, art: &ModelArtifact) -> Result<LoadedModel> {
        let model = NativeModel::from_artifact(art, self.cfg.clone())
            .with_context(|| format!("native load {}/{}", art.meta.dataset, art.meta.variant))?;
        Ok(LoadedModel::new(
            art.meta.clone(),
            "native",
            CellPlan::Exact { max_batch: NATIVE_MAX_BATCH, max_seq: art.meta.seq_len },
            Box::new(model),
        ))
    }
}

/// One encoder layer's weights: projections packed for the blocked GEMM,
/// biases and LayerNorm parameters raw.
struct LayerWeights {
    wq: PackedGemm,
    bq: Vec<f32>,
    wk: PackedGemm,
    bk: Vec<f32>,
    wv: PackedGemm,
    bv: Vec<f32>,
    wo: PackedGemm,
    bo: Vec<f32>,
    ln1_g: Vec<f32>,
    ln1_b: Vec<f32>,
    w1: PackedGemm,
    b1: Vec<f32>,
    w2: PackedGemm,
    b2: Vec<f32>,
    ln2_g: Vec<f32>,
    ln2_b: Vec<f32>,
    ffn_size: usize,
}

/// A variant's weights in forward-pass form plus its processed-token
/// telemetry.
pub struct NativeModel {
    cfg: KernelConfig,
    hidden: usize,
    heads: usize,
    num_classes: usize,
    vocab: usize,
    type_vocab: usize,
    max_pos: usize,
    retention: Option<Vec<usize>>,
    word: Vec<f32>,
    word_proj: Option<(usize, Vec<f32>)>, // (embed_factor, [E, H])
    pos: Vec<f32>,
    type_: Vec<f32>,
    embed_ln_g: Vec<f32>,
    embed_ln_b: Vec<f32>,
    layers: Vec<LayerWeights>,
    final_g: Vec<f32>,
    final_b: Vec<f32>,
    pooler_w: PackedGemm,
    pooler_b: Vec<f32>,
    head_w: PackedGemm,
    head_b: Vec<f32>,
    /// Word-vectors processed per encoder (FFN width after extraction),
    /// accumulated across every executed row.
    layer_tokens: Vec<AtomicU64>,
}

impl NativeModel {
    fn from_artifact(art: &ModelArtifact, cfg: KernelConfig) -> Result<NativeModel> {
        let meta = &art.meta;
        let hidden = meta.hidden_size;
        let heads = meta.num_heads;
        if hidden == 0 || heads == 0 {
            bail!(
                "meta.json lacks hidden_size/num_heads (re-export with a current \
                 python/compile; got hidden_size={hidden}, num_heads={heads})"
            );
        }
        if hidden % heads != 0 {
            bail!("hidden_size {hidden} not divisible by num_heads {heads}");
        }
        let w = |name: &str| -> Result<(Vec<usize>, Vec<f32>)> {
            let (dims, data) = art
                .weight(name)
                .ok_or_else(|| anyhow!("weights.npz missing {name}"))?;
            Ok((dims.to_vec(), data.to_vec()))
        };
        let expect = |name: &str, dims: &[usize], want: &[usize]| -> Result<()> {
            if dims != want {
                bail!("{name}: shape {dims:?}, expected {want:?}");
            }
            Ok(())
        };

        let (word_dims, word) = w("embed/word")?;
        if word_dims.len() != 2 {
            bail!("embed/word: shape {word_dims:?}, expected rank 2");
        }
        let (vocab, embed_width) = (word_dims[0], word_dims[1]);
        let word_proj = match art.weight("embed/word_proj") {
            Some((dims, data)) => {
                expect("embed/word_proj", dims, &[embed_width, hidden])?;
                Some((embed_width, data.to_vec()))
            }
            None => {
                expect("embed/word", &word_dims, &[vocab, hidden])?;
                None
            }
        };
        let (pos_dims, pos) = w("embed/pos")?;
        if pos_dims.len() != 2 || pos_dims[1] != hidden {
            bail!("embed/pos: shape {pos_dims:?}, expected [max_len, {hidden}]");
        }
        let max_pos = pos_dims[0];
        if meta.seq_len > max_pos {
            bail!("seq_len {} exceeds position table {max_pos}", meta.seq_len);
        }
        let (type_dims, type_) = w("embed/type")?;
        if type_dims.len() != 2 || type_dims[1] != hidden {
            bail!("embed/type: shape {type_dims:?}, expected [type_vocab, {hidden}]");
        }
        let type_vocab = type_dims[0];
        let (g_dims, embed_ln_g) = w("embed/ln_g")?;
        expect("embed/ln_g", &g_dims, &[hidden])?;
        let (b_dims, embed_ln_b) = w("embed/ln_b")?;
        expect("embed/ln_b", &b_dims, &[hidden])?;

        let mut layers = Vec::with_capacity(meta.num_layers);
        for j in 0..meta.num_layers {
            // ALBERT-style shared parameters export only layers/0.
            let jj = if art.weight(&format!("layers/{j}/wq")).is_some() { j } else { 0 };
            let lw = |suffix: &str, want: &[usize]| -> Result<Vec<f32>> {
                let name = format!("layers/{jj}/{suffix}");
                let (dims, data) = w(&name)?;
                expect(&name, &dims, want)?;
                Ok(data)
            };
            // Square [h, h] projection, packed for the blocked kernel.
            let proj = |suffix: &str| -> Result<PackedGemm> {
                Ok(PackedGemm::pack(&lw(suffix, &[hidden, hidden])?, hidden, hidden))
            };
            let (w1_dims, w1) = w(&format!("layers/{jj}/w1"))?;
            if w1_dims.len() != 2 || w1_dims[0] != hidden {
                bail!("layers/{jj}/w1: shape {w1_dims:?}, expected [{hidden}, ffn]");
            }
            let ffn_size = w1_dims[1];
            layers.push(LayerWeights {
                wq: proj("wq")?,
                bq: lw("bq", &[hidden])?,
                wk: proj("wk")?,
                bk: lw("bk", &[hidden])?,
                wv: proj("wv")?,
                bv: lw("bv", &[hidden])?,
                wo: proj("wo")?,
                bo: lw("bo", &[hidden])?,
                ln1_g: lw("ln1_g", &[hidden])?,
                ln1_b: lw("ln1_b", &[hidden])?,
                w1: PackedGemm::pack(&w1, hidden, ffn_size),
                b1: lw("b1", &[ffn_size])?,
                w2: PackedGemm::pack(&lw("w2", &[ffn_size, hidden])?, ffn_size, hidden),
                b2: lw("b2", &[hidden])?,
                ln2_g: lw("ln2_g", &[hidden])?,
                ln2_b: lw("ln2_b", &[hidden])?,
                ffn_size,
            });
        }
        if layers.is_empty() {
            bail!("meta.json declares no encoder layers");
        }

        let (fg_dims, final_g) = w("final_ln/g")?;
        expect("final_ln/g", &fg_dims, &[hidden])?;
        let (fb_dims, final_b) = w("final_ln/b")?;
        expect("final_ln/b", &fb_dims, &[hidden])?;
        let (pw_dims, pooler_w) = w("pooler/w")?;
        expect("pooler/w", &pw_dims, &[hidden, hidden])?;
        let (pb_dims, pooler_b) = w("pooler/b")?;
        expect("pooler/b", &pb_dims, &[hidden])?;
        let (hw_dims, head_w) = w("head/w")?;
        if hw_dims.len() != 2 || hw_dims[0] != hidden {
            bail!("head/w: shape {hw_dims:?}, expected [{hidden}, classes]");
        }
        let num_classes = hw_dims[1];
        let (hb_dims, head_b) = w("head/b")?;
        expect("head/b", &hb_dims, &[num_classes])?;

        let n_layers = layers.len();
        Ok(NativeModel {
            cfg,
            hidden,
            heads,
            num_classes,
            vocab,
            type_vocab,
            max_pos,
            retention: meta.retention.clone(),
            word,
            word_proj,
            pos,
            type_,
            embed_ln_g,
            embed_ln_b,
            layers,
            final_g,
            final_b,
            pooler_w: PackedGemm::pack(&pooler_w, hidden, hidden),
            pooler_b,
            head_w: PackedGemm::pack(&head_w, hidden, num_classes),
            head_b,
            layer_tokens: (0..n_layers).map(|_| AtomicU64::new(0)).collect(),
        })
    }

    /// Forward `batch` examples of `seq` tokens through batch-level kernel
    /// calls: every projection is one `[batch * n_j, k]` GEMM, where `n_j`
    /// starts at `seq` and shrinks at each extract layer — all rows of a
    /// batch keep the same count (`retention[j]`), so the batch stays
    /// rectangular through every layer. Returns the logits and, when
    /// `want_trace`, the per-example surviving original positions
    /// (`[batch, L, seq]`, -1-padded).
    fn forward_batch(
        &self,
        tokens: &[i32],
        segments: &[i32],
        batch: usize,
        seq: usize,
        want_trace: bool,
    ) -> Result<(Vec<f32>, Option<Vec<i32>>)> {
        let h = self.hidden;
        let heads = self.heads;
        let d = h / heads;
        let n_layers = self.layers.len();
        let cfg = &self.cfg;
        if seq > self.max_pos {
            bail!("seq {seq} exceeds position table {}", self.max_pos);
        }

        // Valid-position mask: 1.0 for real tokens, 0.0 for PAD.
        let mut mask: Vec<f32> = tokens
            .iter()
            .map(|&t| if t == PAD_ID { 0.0 } else { 1.0 })
            .collect();

        // Embedding lookup + LN over all batch rows.
        let mut x = vec![0f32; batch * seq * h];
        for b in 0..batch {
            for i in 0..seq {
                let tok = tokens[b * seq + i];
                if tok < 0 || tok as usize >= self.vocab {
                    bail!("token id {tok} outside vocab of {}", self.vocab);
                }
                let seg = segments[b * seq + i];
                if seg < 0 || seg as usize >= self.type_vocab {
                    bail!("segment id {seg} outside type vocab of {}", self.type_vocab);
                }
                let row = &mut x[(b * seq + i) * h..(b * seq + i + 1) * h];
                match &self.word_proj {
                    None => {
                        let wrow = &self.word[tok as usize * h..(tok as usize + 1) * h];
                        row.copy_from_slice(wrow);
                    }
                    Some((e, proj)) => {
                        // Factorized embedding: word[tok] (E) @ proj (E x H).
                        let wrow = &self.word[tok as usize * e..(tok as usize + 1) * e];
                        for (kk, &wv) in wrow.iter().enumerate() {
                            let prow = &proj[kk * h..(kk + 1) * h];
                            for (c, &pv) in prow.iter().enumerate() {
                                row[c] += wv * pv;
                            }
                        }
                    }
                }
                let prow = &self.pos[i * h..(i + 1) * h];
                let trow = &self.type_[seg as usize * h..(seg as usize + 1) * h];
                for c in 0..h {
                    row[c] += prow[c] + trow[c];
                }
            }
        }
        layer_norm(&mut x, h, &self.embed_ln_g, &self.embed_ln_b);

        // Original positions of surviving word-vectors (Figure 8 trace),
        // per example.
        let mut positions: Vec<i32> = (0..batch).flat_map(|_| 0..seq as i32).collect();
        let mut trace = want_trace.then(|| vec![-1i32; batch * n_layers * seq]);
        // Extract-layer scratch, reused across every layer and example
        // (rather than two fresh allocations per (row, layer)).
        let mut topk = TopK::with_capacity(seq);

        // Surviving word-vectors per example — uniform across the batch.
        let mut n = seq;
        for (j, layer) in self.layers.iter().enumerate() {
            let rows = batch * n;
            // --- attention half: x1 = x + proj(MHA(LN(x))), plus scores.
            let mut hx = x.clone();
            layer_norm(&mut hx, h, &layer.ln1_g, &layer.ln1_b);
            let mut q = vec![0f32; rows * h];
            layer.wq.matmul_bias(&hx, rows, &layer.bq, cfg, &mut q);
            let mut k = vec![0f32; rows * h];
            layer.wk.matmul_bias(&hx, rows, &layer.bk, cfg, &mut k);
            let mut v = vec![0f32; rows * h];
            layer.wv.matmul_bias(&hx, rows, &layer.bv, cfg, &mut v);

            let mut ctx = vec![0f32; rows * h];
            let mut sig = vec![0f32; rows];
            masked_attention(&q, &k, &v, &mask, batch, n, heads, d, cfg, &mut ctx, &mut sig);
            let mut proj = vec![0f32; rows * h];
            layer.wo.matmul_bias(&ctx, rows, &layer.bo, cfg, &mut proj);
            let mut x1 = x;
            for (xv, pv) in x1.iter_mut().zip(proj.iter()) {
                *xv += pv;
            }

            // --- extract layer (between attention and FFN, §3.2/Fig 4).
            if let Some(keep) = self.retention.as_ref().and_then(|r| r.get(j)).copied() {
                // Guard a malformed manifest: at least CLS always survives
                // (derive_retention clamps to >= 1 on the export side).
                let keep = keep.max(1);
                if keep < n {
                    let mut nx = vec![0f32; batch * keep * h];
                    let mut nmask = vec![0f32; batch * keep];
                    let mut npos = vec![0i32; batch * keep];
                    for b in 0..batch {
                        let idx = topk.keep_indices(
                            &sig[b * n..(b + 1) * n],
                            &mask[b * n..(b + 1) * n],
                            keep,
                        );
                        for (slot, &src) in idx.iter().enumerate() {
                            let dst = b * keep + slot;
                            let s = b * n + src;
                            nx[dst * h..(dst + 1) * h].copy_from_slice(&x1[s * h..(s + 1) * h]);
                            nmask[dst] = mask[s];
                            npos[dst] = positions[s];
                        }
                    }
                    x1 = nx;
                    mask = nmask;
                    positions = npos;
                    n = keep;
                }
            }
            self.layer_tokens[j].fetch_add((batch * n) as u64, Ordering::Relaxed);
            if let Some(tr) = trace.as_mut() {
                for b in 0..batch {
                    tr[(b * n_layers + j) * seq..(b * n_layers + j) * seq + n]
                        .copy_from_slice(&positions[b * n..(b + 1) * n]);
                }
            }

            // --- FFN half: x = x1 + FFN(LN(x1)), GELU fused into the
            // first GEMM's epilogue.
            let rows = batch * n;
            let mut h2 = x1.clone();
            layer_norm(&mut h2, h, &layer.ln2_g, &layer.ln2_b);
            let mut a1 = vec![0f32; rows * layer.ffn_size];
            layer.w1.matmul_bias_gelu(&h2, rows, &layer.b1, cfg, &mut a1);
            let mut a2 = vec![0f32; rows * h];
            layer.w2.matmul_bias(&a1, rows, &layer.b2, cfg, &mut a2);
            x = x1;
            for (xv, av) in x.iter_mut().zip(a2.iter()) {
                *xv += av;
            }
        }

        // --- pooler + classifier head from each example's CLS vector
        // (row 0 of its block — pinned there by the extract layer).
        layer_norm(&mut x, h, &self.final_g, &self.final_b);
        let mut cls = vec![0f32; batch * h];
        for b in 0..batch {
            cls[b * h..(b + 1) * h].copy_from_slice(&x[b * n * h..b * n * h + h]);
        }
        let mut pooled = vec![0f32; batch * h];
        self.pooler_w.matmul_bias_tanh(&cls, batch, &self.pooler_b, cfg, &mut pooled);
        let mut logits = vec![0f32; batch * self.num_classes];
        self.head_w.matmul_bias(&pooled, batch, &self.head_b, cfg, &mut logits);
        Ok((logits, trace))
    }
}

impl CellExecutor for NativeModel {
    fn execute(
        &self,
        tokens: &[i32],
        segments: &[i32],
        batch: usize,
        seq: usize,
        want_trace: bool,
    ) -> Result<ExecOutput> {
        if tokens.len() != batch * seq || segments.len() != batch * seq {
            bail!("native execute: expected {batch}x{seq} tokens, got {}", tokens.len());
        }
        let n_layers = self.layers.len();
        let mut logits = Vec::with_capacity(batch * self.num_classes);
        let mut kept = want_trace.then(|| Vec::with_capacity(batch * n_layers * seq));
        let mut r = 0;
        while r < batch {
            let chunk = NATIVE_EXEC_CHUNK.min(batch - r);
            let (chunk_logits, chunk_trace) = self.forward_batch(
                &tokens[r * seq..(r + chunk) * seq],
                &segments[r * seq..(r + chunk) * seq],
                chunk,
                seq,
                want_trace,
            )?;
            logits.extend_from_slice(&chunk_logits);
            if let (Some(acc), Some(tr)) = (kept.as_mut(), chunk_trace) {
                acc.extend_from_slice(&tr);
            }
            r += chunk;
        }
        Ok(ExecOutput { logits, num_classes: self.num_classes, kept })
    }

    fn layer_tokens(&self) -> Option<Vec<u64>> {
        Some(
            self.layer_tokens
                .iter()
                .map(|c| c.load(Ordering::Relaxed))
                .collect(),
        )
    }
}

/// Scratch for the extract layer's top-k selection: the score and index
/// buffers persist across every (layer, example) of a forward pass instead
/// of being reallocated per call.
struct TopK {
    scores: Vec<f32>,
    order: Vec<usize>,
}

impl TopK {
    fn with_capacity(cap: usize) -> TopK {
        TopK { scores: Vec::with_capacity(cap), order: Vec::with_capacity(cap) }
    }

    /// Indices of the `keep` highest-scored positions, returned in
    /// original (ascending) order.
    ///
    /// This is the enforcement site of the paper's §3.4 pinning invariant
    /// (the property `rust/tests` asserts is *established here*):
    /// * **CLS survives every extract layer**: position 0's score is
    ///   overwritten with `BIG` = 1e6, above any attainable column sum
    ///   (significance is bounded by `heads × seq`), so the classifier's
    ///   readout vector can never be eliminated.
    /// * **PAD sinks below any real word**: masked positions score -1.0,
    ///   strictly below every real column sum (those are ≥ 0), so a PAD
    ///   survives only when `keep` exceeds the number of real tokens.
    /// * The sort is stable, so ties (e.g. between PAD columns) resolve to
    ///   the lowest original index — matching `jnp.argsort` in `model.py`
    ///   exactly, which the golden-logit parity fixtures depend on.
    fn keep_indices(&mut self, sig: &[f32], mask: &[f32], keep: usize) -> &[usize] {
        let n = sig.len();
        self.scores.clear();
        for (i, &s) in sig.iter().enumerate() {
            self.scores.push(if mask[i] > 0.0 { s } else { -1.0 });
        }
        self.scores[0] = BIG;
        self.order.clear();
        self.order.extend(0..n);
        let scores = &self.scores;
        self.order.sort_by(|&a, &b| scores[b].total_cmp(&scores[a]));
        self.order.truncate(keep);
        self.order.sort_unstable();
        &self.order
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn topk_pins_cls_and_sinks_pad() {
        // 6 positions, PADs at 4/5; keep 3 -> CLS + the two best real.
        let sig = vec![0.1, 2.0, 0.5, 1.5, 9.0, 9.0];
        let mask = vec![1.0, 1.0, 1.0, 1.0, 0.0, 0.0];
        let mut topk = TopK::with_capacity(sig.len());
        assert_eq!(topk.keep_indices(&sig, &mask, 3), &[0, 1, 3]);
        // Keep beyond the real count: PAD ties resolve to ascending index.
        assert_eq!(topk.keep_indices(&sig, &mask, 5), &[0, 1, 2, 3, 4]);
    }

    #[test]
    fn topk_scratch_is_reusable_across_widths() {
        let mut topk = TopK::with_capacity(8);
        let sig = vec![0.0, 3.0, 1.0, 2.0];
        let mask = vec![1.0; 4];
        assert_eq!(topk.keep_indices(&sig, &mask, 2), &[0, 1]);
        // Narrower follow-up call (as after an extract layer) still works.
        let sig2 = vec![0.0, 0.5];
        let mask2 = vec![1.0; 2];
        assert_eq!(topk.keep_indices(&sig2, &mask2, 1), &[0]);
    }
}
