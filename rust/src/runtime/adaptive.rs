//! Adaptive compute: per-request dynamic retention.
//!
//! PoWER-BERT compiles one retention schedule per variant. The native
//! backend, however, already computes per-example attention-column
//! significance at every encoder — the same signal the schedule was
//! derived from offline. This module turns that signal into a *runtime*
//! dial (the TR-BERT / Latency-Adjustable-Transformer scenario):
//!
//! * [`RetentionPolicy`] — `Fixed` replays the compiled schedule;
//!   `AttentionMass { threshold }` lets each example demand the smallest
//!   kept-set whose cumulative significance mass reaches `threshold` of
//!   its row's total mass ([`demanded_k`]).
//! * **Execution rule** — the default *ragged* path gives every example
//!   exactly its demanded k (clamped to the compiled schedule as a
//!   ceiling), so compute equals tokens kept; the padded oracle
//!   (`--ragged off`) instead executes the whole batch at the *maximum*
//!   demanded k, keeping the batch rectangular. Either way the CLS/PAD
//!   pinning invariant is enforced unchanged by `keep_indices`, and —
//!   because adaptive widths never exceed the schedule — every
//!   preplanned `ForwardArena` slab stays valid.
//! * [`ParetoTable`] — the machine-readable output of the offline
//!   calibration pass (`eval --calibrate-pareto`): threshold → dev
//!   metric, mean tokens processed, estimated latency. The coordinator
//!   router loads `pareto.json` from the variant's artifact directory
//!   and maps request SLAs (`compute: "full" | "balanced" | "fast"` or
//!   an explicit threshold) to an operating point on that frontier.
//!
//! A threshold ≥ 1.0 is *defined* as the fixed schedule: the executor
//! short-circuits to the non-adaptive code path, so `threshold: 1.0`
//! reproduces fixed-schedule logits bit for bit (no float summation
//! order divergence — asserted by `rust/tests/adaptive.rs`).

use std::path::Path;

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// How a native cell picks each encoder's kept-set size.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RetentionPolicy {
    /// The variant's compiled retention schedule, exactly.
    Fixed,
    /// Per-example demanded k from cumulative attention mass, clamped to
    /// the compiled schedule; the batch runs at the per-batch max.
    AttentionMass { threshold: f32 },
}

impl RetentionPolicy {
    /// The effective significance threshold: `None` means the fixed
    /// schedule (including `AttentionMass` at threshold ≥ 1.0, which is
    /// defined to be the schedule — see the module docs).
    pub fn threshold(&self) -> Option<f32> {
        match *self {
            RetentionPolicy::Fixed => None,
            RetentionPolicy::AttentionMass { threshold } if threshold >= 1.0 => None,
            RetentionPolicy::AttentionMass { threshold } => Some(threshold.max(0.0)),
        }
    }
}

/// Smallest k whose cumulative significance mass reaches `threshold` of
/// the row's total mass, over the real (non-PAD) positions of one
/// example at the current width `n = sig.len()`.
///
/// `scratch` must hold at least `n` floats (the caller's top-k score
/// region — this function is on the zero-allocation steady-state path).
/// Mass is taken from the raw significance scores: PAD positions
/// (mask == 0) contribute nothing and are never demanded. The result is
/// in `1..=n`; degenerate rows (no mass) demand 1 (CLS survives). The
/// caller still clamps to the compiled schedule and pins CLS/PAD via
/// `keep_indices` — this function only sizes the kept set.
pub fn demanded_k(sig: &[f32], mask: &[f32], threshold: f32, scratch: &mut [f32]) -> usize {
    let n = sig.len();
    debug_assert_eq!(mask.len(), n);
    debug_assert!(scratch.len() >= n);
    if n == 0 {
        return 1;
    }
    if threshold >= 1.0 {
        return n;
    }
    let mut real = 0usize;
    let mut total = 0f64;
    for i in 0..n {
        if mask[i] > 0.0 {
            let s = sig[i].max(0.0);
            scratch[real] = s;
            real += 1;
            total += s as f64;
        }
    }
    if real == 0 || total <= 0.0 || threshold <= 0.0 {
        return 1;
    }
    scratch[..real].sort_unstable_by(|a, b| b.total_cmp(a));
    let target = threshold as f64 * total;
    let mut cum = 0f64;
    for (k, &s) in scratch[..real].iter().enumerate() {
        cum += s as f64;
        if cum >= target {
            return k + 1;
        }
    }
    real.max(1)
}

/// One calibrated operating point: a threshold and what it buys.
#[derive(Debug, Clone, PartialEq)]
pub struct ParetoPoint {
    /// Attention-mass threshold (1.0 = the fixed schedule).
    pub threshold: f64,
    /// Dev-set metric at this threshold (the variant's `metric` kind).
    pub metric: f64,
    /// Mean word-vectors processed per example (Σ over encoders).
    pub mean_tokens: f64,
    /// Mean measured latency per example during calibration, µs. A
    /// calibration-machine number — treat as relative, not absolute.
    pub est_latency_us: f64,
}

/// The accuracy–latency frontier emitted by `eval --calibrate-pareto`
/// and loaded by the router from `<variant dir>/pareto.json`.
///
/// Wire format (machine-readable, schema 1):
/// ```json
/// {"schema": 1, "dataset": "sst2", "variant": "power-default",
///  "metric": "accuracy", "examples": 128,
///  "points": [{"threshold": 1.0, "metric": 0.7266,
///              "mean_tokens": 104.0, "est_latency_us": 180.0}, ...]}
/// ```
#[derive(Debug, Clone, Default, PartialEq)]
pub struct ParetoTable {
    /// Points sorted by descending threshold (full compute first).
    pub points: Vec<ParetoPoint>,
}

impl ParetoTable {
    pub fn new(mut points: Vec<ParetoPoint>) -> ParetoTable {
        points.sort_by(|a, b| b.threshold.total_cmp(&a.threshold));
        ParetoTable { points }
    }

    /// Parse the `points` list out of a calibration JSON document.
    pub fn from_json(j: &Json) -> Result<ParetoTable> {
        let arr = j
            .get("points")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("pareto table has no points array"))?;
        let mut points = Vec::with_capacity(arr.len());
        for p in arr {
            let f = |k: &str| {
                p.get(k)
                    .and_then(Json::as_f64)
                    .ok_or_else(|| anyhow!("pareto point missing {k:?}"))
            };
            points.push(ParetoPoint {
                threshold: f("threshold")?,
                metric: f("metric")?,
                mean_tokens: f("mean_tokens")?,
                est_latency_us: f("est_latency_us")?,
            });
        }
        Ok(ParetoTable::new(points))
    }

    pub fn load(path: &Path) -> Result<ParetoTable> {
        let j = Json::parse_file(path).with_context(|| format!("read {}", path.display()))?;
        ParetoTable::from_json(&j).with_context(|| format!("parse {}", path.display()))
    }

    /// The points list as JSON (the caller wraps it with dataset/variant
    /// identity fields).
    pub fn points_json(&self) -> Json {
        Json::Arr(
            self.points
                .iter()
                .map(|p| {
                    let mut m = std::collections::BTreeMap::new();
                    m.insert("threshold".to_string(), Json::Num(p.threshold));
                    m.insert("metric".to_string(), Json::Num(p.metric));
                    m.insert("mean_tokens".to_string(), Json::Num(p.mean_tokens));
                    m.insert("est_latency_us".to_string(), Json::Num(p.est_latency_us));
                    Json::Obj(m)
                })
                .collect(),
        )
    }

    /// The full-compute reference point (threshold ≥ 1.0), if calibrated.
    pub fn full(&self) -> Option<&ParetoPoint> {
        self.points.iter().find(|p| p.threshold >= 1.0)
    }

    /// Cheapest point that matches full-compute accuracy: minimum mean
    /// tokens among points whose metric is ≥ the full point's (absent a
    /// full point, ≥ the best metric in the table).
    pub fn balanced(&self) -> Option<&ParetoPoint> {
        let floor = self
            .full()
            .map(|p| p.metric)
            .or_else(|| self.points.iter().map(|p| p.metric).max_by(f64::total_cmp))?;
        self.points
            .iter()
            .filter(|p| p.metric >= floor)
            .min_by(|a, b| {
                a.mean_tokens
                    .total_cmp(&b.mean_tokens)
                    // Tie on tokens -> prefer the higher (safer) threshold.
                    .then(b.threshold.total_cmp(&a.threshold))
            })
    }

    /// Minimum-tokens point, accuracy be damned — the `"fast"` SLA.
    pub fn fastest(&self) -> Option<&ParetoPoint> {
        self.points.iter().min_by(|a, b| {
            a.mean_tokens.total_cmp(&b.mean_tokens).then(b.metric.total_cmp(&a.metric))
        })
    }

    /// Calibrated fraction of full-schedule word-vectors a batch at
    /// `threshold` actually processes: `mean_tokens(point) /
    /// mean_tokens(full)`, in `(0, 1]`. The point is resolved
    /// conservatively — the smallest calibrated threshold **at or above**
    /// the requested one (more tokens than a lower point would predict),
    /// falling back to the nearest below when the request exceeds every
    /// calibrated point. `None` when the table lacks a usable full
    /// reference, a threshold ≥ 1.0 is the full schedule by definition
    /// (ratio 1.0). This is what seeds the router's per-threshold latency
    /// prior so SLA routing doesn't price a fast-tier batch at
    /// full-schedule cost.
    pub fn tokens_ratio_at(&self, threshold: f64) -> Option<f64> {
        let full = self.full().filter(|p| p.mean_tokens > 0.0)?;
        if threshold >= 1.0 {
            return Some(1.0);
        }
        // Points are sorted by descending threshold: the last one still at
        // or above the request is the tightest conservative match.
        let point = self
            .points
            .iter()
            .filter(|p| p.threshold >= threshold)
            .min_by(|a, b| a.threshold.total_cmp(&b.threshold))
            .or_else(|| {
                self.points
                    .iter()
                    .max_by(|a, b| a.threshold.total_cmp(&b.threshold))
            })?;
        Some((point.mean_tokens / full.mean_tokens).clamp(f64::MIN_POSITIVE, 1.0))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn policy_threshold_clamps_and_short_circuits() {
        assert_eq!(RetentionPolicy::Fixed.threshold(), None);
        assert_eq!(RetentionPolicy::AttentionMass { threshold: 1.0 }.threshold(), None);
        assert_eq!(RetentionPolicy::AttentionMass { threshold: 1.5 }.threshold(), None);
        assert_eq!(
            RetentionPolicy::AttentionMass { threshold: 0.9 }.threshold(),
            Some(0.9)
        );
    }

    #[test]
    fn demanded_k_concentrated_vs_uniform_mass() {
        let mut scratch = [0f32; 8];
        // One dominant position: tiny k satisfies a high threshold.
        let sig = [10.0, 0.1, 0.1, 0.1];
        let mask = [1.0f32; 4];
        assert_eq!(demanded_k(&sig, &mask, 0.9, &mut scratch), 1);
        // Uniform mass: k scales with the threshold.
        let sig = [1.0f32; 4];
        assert_eq!(demanded_k(&sig, &mask, 0.5, &mut scratch), 2);
        assert_eq!(demanded_k(&sig, &mask, 0.75, &mut scratch), 3);
    }

    #[test]
    fn demanded_k_ignores_pad_and_handles_degenerates() {
        let mut scratch = [0f32; 8];
        let sig = [1.0, 5.0, 3.0, 9.0];
        let mask = [1.0, 1.0, 0.0, 0.0]; // last two are PAD
        // PAD mass excluded: total = 6, top real = 5 -> k=1 at 0.8 of 6? 5 < 4.8 is false -> 1
        assert_eq!(demanded_k(&sig, &mask, 0.8, &mut scratch), 1);
        assert_eq!(demanded_k(&sig, &mask, 0.9, &mut scratch), 2);
        // All PAD / zero mass / nonpositive threshold -> 1 (CLS survives).
        assert_eq!(demanded_k(&sig, &[0.0; 4], 0.5, &mut scratch), 1);
        assert_eq!(demanded_k(&[0.0; 4], &mask, 0.5, &mut scratch), 1);
        assert_eq!(demanded_k(&sig, &mask, 0.0, &mut scratch), 1);
        // Threshold >= 1.0 demands full width.
        assert_eq!(demanded_k(&sig, &mask, 1.0, &mut scratch), 4);
    }

    #[test]
    fn demanded_k_is_monotone_in_threshold() {
        let mut scratch = [0f32; 16];
        let sig = [3.0, 0.5, 2.0, 0.1, 1.0, 0.7, 0.2, 0.9];
        let mask = [1.0f32; 8];
        let mut last = 0usize;
        for t in [0.1, 0.3, 0.5, 0.7, 0.9, 0.99] {
            let k = demanded_k(&sig, &mask, t, &mut scratch);
            assert!(k >= last, "k not monotone at threshold {t}");
            assert!(k >= 1 && k <= 8);
            last = k;
        }
    }

    #[test]
    fn pareto_selection_rules() {
        let table = ParetoTable::new(vec![
            ParetoPoint { threshold: 1.0, metric: 0.72, mean_tokens: 104.0, est_latency_us: 200.0 },
            ParetoPoint { threshold: 0.95, metric: 0.72, mean_tokens: 80.0, est_latency_us: 160.0 },
            ParetoPoint { threshold: 0.8, metric: 0.70, mean_tokens: 50.0, est_latency_us: 110.0 },
            ParetoPoint { threshold: 0.5, metric: 0.61, mean_tokens: 20.0, est_latency_us: 60.0 },
        ]);
        assert_eq!(table.full().unwrap().threshold, 1.0);
        // balanced: equal accuracy to full, fewer tokens.
        assert_eq!(table.balanced().unwrap().threshold, 0.95);
        assert_eq!(table.fastest().unwrap().threshold, 0.5);
        // Round-trip through JSON.
        let mut m = std::collections::BTreeMap::new();
        m.insert("points".to_string(), table.points_json());
        let back = ParetoTable::from_json(&Json::Obj(m)).unwrap();
        assert_eq!(back, table);
    }

    #[test]
    fn tokens_ratio_scales_with_threshold() {
        let table = ParetoTable::new(vec![
            ParetoPoint { threshold: 1.0, metric: 0.72, mean_tokens: 104.0, est_latency_us: 200.0 },
            ParetoPoint { threshold: 0.95, metric: 0.72, mean_tokens: 80.0, est_latency_us: 160.0 },
            ParetoPoint { threshold: 0.6, metric: 0.64, mean_tokens: 30.0, est_latency_us: 80.0 },
        ]);
        // Exact calibrated points resolve to their own ratios.
        assert!((table.tokens_ratio_at(0.95).unwrap() - 80.0 / 104.0).abs() < 1e-12);
        assert!((table.tokens_ratio_at(0.6).unwrap() - 30.0 / 104.0).abs() < 1e-12);
        // Between points: conservative — the tighter (higher) threshold's
        // ratio, never the cheaper one below.
        assert!((table.tokens_ratio_at(0.7).unwrap() - 80.0 / 104.0).abs() < 1e-12);
        // At or above 1.0 is the full schedule.
        assert_eq!(table.tokens_ratio_at(1.0), Some(1.0));
        // Below every sub-full point: the cheapest calibrated point is
        // still the conservative at-or-above match.
        assert!((table.tokens_ratio_at(0.1).unwrap() - 30.0 / 104.0).abs() < 1e-12);
        // No full reference -> no ratio.
        let nofull = ParetoTable::new(vec![ParetoPoint {
            threshold: 0.5,
            metric: 0.6,
            mean_tokens: 20.0,
            est_latency_us: 50.0,
        }]);
        assert_eq!(nofull.tokens_ratio_at(0.5), None);
    }

    #[test]
    fn pareto_empty_and_missing_points() {
        let t = ParetoTable::default();
        assert!(t.full().is_none() && t.balanced().is_none() && t.fastest().is_none());
        assert!(ParetoTable::from_json(&Json::Obj(Default::default())).is_err());
    }
}
