//! Native microkernels: the hot loops of the pure-Rust forward pass.
//!
//! The [`native`](super::native) backend used to run matmul, attention and
//! the FFN as naive scalar triple loops. This module replaces them with
//! small, cache-aware kernels so the measured speedup-vs-retention curve
//! reflects elimination against a competently optimized dense baseline
//! (the bar TR-BERT/DeeBERT-style systems report against), not against an
//! artificially slow one:
//!
//! * [`gemm::PackedGemm`] — weights pretransposed **once at load time**
//!   into column panels, then a register-tiled, depth-blocked
//!   `out = x @ w + bias` with optional fused GELU/tanh epilogues (the FFN
//!   and pooler never materialize a pre-activation buffer).
//! * [`attention::masked_attention`] — the scaled-dot-product attention +
//!   attention-column significance accumulation (paper §3.2), parallel
//!   across `(batch row, head)` tasks on the persistent pool.
//! * [`layer_norm`] / [`gelu`] — the row-wise epilogue primitives, shared
//!   with the kernels' fused paths.
//! * [`pool::KernelPool`] — the persistent worker pool parallel kernels
//!   dispatch to. Workers are spawned once per [`KernelExec`] (i.e. once
//!   per engine worker) and parked between jobs, so `threads > 1` no
//!   longer pays a per-call spawn — the cost that used to dominate small
//!   `(batch, seq)` buckets.
//!
//! Every kernel is **deterministic for any thread count**: parallel tasks
//! write disjoint output ranges and reductions run serially in a fixed
//! order, so logits are bit-identical at `threads = 1, 2, 4, …` — which is
//! what lets the golden-parity fixtures pin the parallel path too. The
//! pooled, scoped-reference and serial paths are additionally pinned
//! bit-identical to *each other* by `tests/prop_kernels.rs`.
//!
//! # Examples
//!
//! ```
//! use powerbert::runtime::kernels::{gemm::PackedGemm, KernelConfig, KernelExec};
//!
//! // w is row-major [k=2, m=3]; packing happens once, at model load.
//! let w = PackedGemm::pack(&[1., 0., 2., 0., 1., 3.], 2, 3);
//! // The exec (config + persistent pool) is built once per engine worker.
//! let exec = KernelExec::new(KernelConfig::default());
//! let mut out = vec![0f32; 3];
//! // x is one row of k=2: [10, 100] @ w + bias.
//! w.matmul_bias(&[10., 100.], 1, &[0.5, 0.5, 0.5], &exec, &mut out);
//! assert_eq!(out, vec![10.5, 100.5, 320.5]);
//! ```

use std::sync::atomic::{AtomicU64, Ordering};

pub mod attention;
pub mod gemm;
pub mod pool;

/// Numeric precision of the packed weight panels. Activations, biases and
/// LayerNorm parameters are always f32 — [`Int8`](Precision::Int8) selects
/// per-output-channel symmetric weight quantization at pack time (model
/// load), with the i8×f32 dot rescaled per channel in the kernel epilogue.
/// See `docs/ARCHITECTURE.md` § "Precision & ISA dispatch" for the
/// quantization scheme and the tolerance contract.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Precision {
    /// Full-precision packed panels — the golden-parity reference (1e-4).
    #[default]
    F32,
    /// Per-output-channel symmetric int8 weight panels
    /// (`scale_c = max|w[:, c]| / 127`), f32 activations.
    Int8,
}

impl Precision {
    /// Parse a CLI/env spelling. Accepts `f32`/`fp32` and `int8`/`i8`.
    pub fn parse(s: &str) -> Option<Precision> {
        match s.trim().to_ascii_lowercase().as_str() {
            "f32" | "fp32" | "float32" => Some(Precision::F32),
            "int8" | "i8" => Some(Precision::Int8),
            _ => None,
        }
    }

    /// Canonical spelling, as reported by `stats`/`hello` and the bench
    /// tables.
    pub fn as_str(self) -> &'static str {
        match self {
            Precision::F32 => "f32",
            Precision::Int8 => "int8",
        }
    }
}

impl std::fmt::Display for Precision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Whether the vectorized (AVX2 + FMA) inner kernels are compiled in
/// *and* supported by this CPU. `false` whenever the `simd` cargo feature
/// is off, the target is not x86_64, or the CPU lacks AVX2/FMA — every
/// kernel then runs the scalar path, which stays the correctness oracle.
#[inline]
pub fn simd_active() -> bool {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        std::arch::is_x86_feature_detected!("avx2") && std::arch::is_x86_feature_detected!("fma")
    }
    #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
    {
        false
    }
}

/// The instruction set the kernel inner loops dispatch to, as reported by
/// `stats`/`hello` and the bench tables: `"avx2+fma"` when
/// [`simd_active`], else `"scalar"`.
pub fn active_isa() -> &'static str {
    if simd_active() {
        "avx2+fma"
    } else {
        "scalar"
    }
}

/// Tuning knobs for the native microkernels, threaded from the CLI /
/// coordinator [`Config`](crate::coordinator::Config) down to every kernel
/// call. The defaults are safe on any machine; none of the knobs affect
/// results (kernels are deterministic for any setting — only wall-clock
/// changes).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct KernelConfig {
    /// Threads per kernel call. `1` is fully serial (the default: the
    /// execution pool already parallelizes across workers, so intra-op
    /// threads are opt-in); `0` resolves to one per available core.
    ///
    /// `threads > 1` sizes the engine worker's persistent
    /// [`pool::KernelPool`]: its `threads - 1` workers are spawned once,
    /// at [`KernelExec`] construction, and parked between kernel calls —
    /// parallel invocations dispatch task lists instead of spawning
    /// threads, so the old per-call spawn cost (which dominated small
    /// `(batch, seq)` buckets) is paid once per worker lifetime.
    pub threads: usize,
    /// Depth (k) block: how many rows of a packed weight panel stream
    /// through the registers per pass. A panel slab of `kc * 8` floats
    /// must stay L1-resident while it is reused across every row tile;
    /// the default (256 → 8 KiB per panel) leaves room for the x rows.
    pub kc: usize,
    /// Row block: rows of `x` (the GEMM's `n` dimension) per parallel
    /// task, i.e. the granularity the GEMM splits work across threads at.
    pub mc: usize,
    /// Weight-panel precision ([`Precision::F32`] default). Unlike the
    /// blocking knobs this one **does** change results — within the
    /// documented int8 tolerance — and it takes effect at model load
    /// (panels are quantized while packing), not per call.
    pub precision: Precision,
    /// Minimum kernel work (in floating-point operations: `2nkm` for a
    /// GEMM, `4·batch·heads·n²·d` for attention) below which a parallel
    /// exec runs the serial path anyway. Even the persistent pool's
    /// park/wake handoff costs a few microseconds per lane — on the quick
    /// bundles' small cells (~0.5 MFLOP) that is a measurable fraction of
    /// the kernel itself, and on truly tiny cells it *dominates*
    /// (`BENCH_native.json` measured the per-call-spawn scoped path at
    /// 0.29× serial there). Like the blocking knobs this never changes
    /// results, only which driver computes them. `0` disables the
    /// fallback (always parallelize when `threads > 1`) — what the kernel
    /// property tests set to keep exercising the parallel drivers on
    /// deliberately tiny shapes.
    pub min_parallel_flops: u64,
    /// Ragged per-example batch execution (default **on**). When set, the
    /// native forward compacts every example to its *own* demanded width
    /// at each extract layer (row-offset ragged layout, see
    /// `docs/ARCHITECTURE.md` § "Ragged execution") instead of executing
    /// the whole batch at the per-batch maximum width — compute equals
    /// tokens kept. Under a fixed retention schedule (no adaptive
    /// threshold) all widths coincide and the ragged path is bit-identical
    /// to the padded one; under an active threshold each example's result
    /// equals a batch-of-one padded run of that example. `false` restores
    /// the padded batch-max oracle (`--ragged off`).
    pub ragged: bool,
}

impl Default for KernelConfig {
    fn default() -> Self {
        KernelConfig {
            threads: 1,
            kc: 256,
            mc: 64,
            precision: Precision::F32,
            min_parallel_flops: 250_000,
            ragged: true,
        }
    }
}

impl KernelConfig {
    /// Session default: `$POWERBERT_KERNEL_THREADS` / `_KC` / `_MC` when
    /// set (and parseable), else [`KernelConfig::default`]. Mirrors
    /// [`BackendKind::from_env`](super::BackendKind::from_env) so CI and
    /// tests can pin kernel behaviour without threading flags everywhere.
    pub fn from_env() -> KernelConfig {
        let var = |name: &str| std::env::var(name).ok().and_then(|v| v.parse::<usize>().ok());
        let mut c = KernelConfig::default();
        if let Some(t) = var("POWERBERT_KERNEL_THREADS") {
            c.threads = t;
        }
        if let Some(kc) = var("POWERBERT_KERNEL_KC") {
            c.kc = kc.max(1);
        }
        if let Some(mc) = var("POWERBERT_KERNEL_MC") {
            c.mc = mc.max(1);
        }
        if let Some(p) = std::env::var("POWERBERT_KERNEL_PRECISION")
            .ok()
            .and_then(|v| Precision::parse(&v))
        {
            c.precision = p;
        }
        if let Some(f) = std::env::var("POWERBERT_KERNEL_MIN_PARALLEL_FLOPS")
            .ok()
            .and_then(|v| v.parse::<u64>().ok())
        {
            c.min_parallel_flops = f;
        }
        if let Ok(v) = std::env::var("POWERBERT_KERNEL_RAGGED") {
            match v.trim().to_ascii_lowercase().as_str() {
                "0" | "off" | "false" | "no" => c.ragged = false,
                "1" | "on" | "true" | "yes" => c.ragged = true,
                _ => {}
            }
        }
        c
    }

    /// Explicit thread count, for tests and benches.
    pub fn with_threads(mut self, threads: usize) -> KernelConfig {
        self.threads = threads;
        self
    }

    /// Explicit weight-panel precision, for tests and benches.
    pub fn with_precision(mut self, precision: Precision) -> KernelConfig {
        self.precision = precision;
        self
    }

    /// Explicit small-shape serial-fallback threshold, for tests and
    /// benches (`0` = always parallelize).
    pub fn with_min_parallel_flops(mut self, flops: u64) -> KernelConfig {
        self.min_parallel_flops = flops;
        self
    }

    /// Explicit ragged-execution toggle, for tests, benches and the
    /// `--ragged on|off` CLI flag (`true` is the default).
    pub fn with_ragged(mut self, ragged: bool) -> KernelConfig {
        self.ragged = ragged;
        self
    }

    /// The configured thread count with `0` resolved to one lane per
    /// available core — the size of the persistent pool a [`KernelExec`]
    /// builds from this config.
    pub fn resolved_threads(&self) -> usize {
        if self.threads == 0 {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
        } else {
            self.threads
        }
    }

    /// The thread count a kernel actually uses for `tasks` independent
    /// units of work: `threads` resolved (`0` → core count) and clamped so
    /// no lane is engaged without a task.
    pub fn effective_threads(&self, tasks: usize) -> usize {
        self.resolved_threads().clamp(1, tasks.max(1))
    }
}

/// Steady-state execution resources of one engine worker: the kernel
/// tuning knobs plus the persistent [`pool::KernelPool`] sized from them.
/// Built once per [`EngineWorker`](crate::runtime::EngineWorker) (by its
/// `NativeBackend`) and shared via `Arc` with every model the worker
/// loads, so the pool's threads live exactly as long as the last model
/// that can dispatch to them — kernel calls can never observe a dead
/// pool, and coordinator drain joins the pool after the backlog finishes.
pub struct KernelExec {
    cfg: KernelConfig,
    pool: pool::KernelPool,
}

impl KernelExec {
    /// Exec on an explicit config; spawns (and parks) the pool workers.
    pub fn new(cfg: KernelConfig) -> KernelExec {
        let pool = pool::KernelPool::new(cfg.resolved_threads());
        KernelExec { cfg, pool }
    }

    /// Exec on the session-default config (`$POWERBERT_KERNEL_*` or
    /// defaults — single-threaded unless overridden).
    pub fn from_env() -> KernelExec {
        KernelExec::new(KernelConfig::from_env())
    }

    pub fn config(&self) -> &KernelConfig {
        &self.cfg
    }

    pub fn pool(&self) -> &pool::KernelPool {
        &self.pool
    }

    /// Total lanes (pool workers + the calling thread).
    pub fn lanes(&self) -> usize {
        self.pool.size()
    }

    /// Lanes a kernel should split `tasks` units of work across — the
    /// same clamp the scoped path applied, so pooled chunking (and hence
    /// bit-exact results) matches it for any config.
    pub fn threads_for(&self, tasks: usize) -> usize {
        self.cfg.effective_threads(tasks).min(self.pool.size())
    }

    /// [`KernelExec::threads_for`] plus the small-shape fallback: a call
    /// totalling fewer than `min_parallel_flops` floating-point operations
    /// runs serially even on a multi-threaded exec, because the pool
    /// handoff would cost a measurable fraction of the kernel itself.
    /// This is *the* dispatch decision of the pooled drivers; `1` means
    /// the serial fast path runs.
    pub fn threads_for_work(&self, tasks: usize, flops: u64) -> usize {
        if self.cfg.min_parallel_flops > 0 && flops < self.cfg.min_parallel_flops {
            return 1;
        }
        self.threads_for(tasks)
    }

    /// The driver [`KernelExec::threads_for_work`] will pick, as a bench/
    /// stats label: `"serial"` or `"pooled"`.
    pub fn chosen_path(&self, tasks: usize, flops: u64) -> &'static str {
        if self.threads_for_work(tasks, flops) <= 1 {
            "serial"
        } else {
            "pooled"
        }
    }
}

impl Default for KernelExec {
    fn default() -> Self {
        KernelExec::new(KernelConfig::default())
    }
}

/// Work floor for the *scoped* (per-call `thread::scope` spawn) drivers,
/// composed with `min_parallel_flops` as a max. A spawned thread costs
/// ~50µs of create/join on this class of hardware — ~1.4 MFLOP of serial
/// GEMM at the measured ~27 GFLOP/s — so a scoped split below a few MFLOP
/// is guaranteed negative (the 0.29×-of-serial row in `BENCH_native.json`
/// that motivated the threshold). The pooled drivers don't use this floor:
/// their handoff is orders of magnitude cheaper.
pub const SCOPED_SPAWN_FLOPS: u64 = 4_000_000;

/// Serial-vs-parallel decision for the scoped drivers: like
/// [`KernelExec::threads_for_work`] but floored at [`SCOPED_SPAWN_FLOPS`].
/// Public so the dispatch bench can report the path production would pick.
pub fn scoped_threads_for_work(cfg: &KernelConfig, tasks: usize, flops: u64) -> usize {
    let floor = cfg.min_parallel_flops.max(SCOPED_SPAWN_FLOPS);
    if cfg.min_parallel_flops > 0 && flops < floor {
        return 1;
    }
    cfg.effective_threads(tasks)
}

/// Total floating-point operations of an `[n, k] @ [k, m]` GEMM — the
/// work estimate the dispatch threshold compares against.
#[inline]
pub fn gemm_flops(n: usize, k: usize, m: usize) -> u64 {
    2 * n as u64 * k as u64 * m as u64
}

/// Work estimate for masked attention over `batch` examples of `n` rows:
/// the two `[n, n] x [n, d]`-shaped products per (example, head), i.e.
/// `4·batch·heads·n²·d` (softmax/masking are lower-order).
#[inline]
pub fn attention_flops(batch: usize, heads: usize, n: usize, d: usize) -> u64 {
    4 * batch as u64 * heads as u64 * (n as u64 * n as u64) * d as u64
}

/// [`attention_flops`] for a ragged batch: per-example widths `n_b` come
/// from the row-offset table, so the estimate is `Σ_b 4·heads·n_b²·d` —
/// the exact work the ragged driver performs (no ghost rows).
#[inline]
pub fn ragged_attention_flops(offsets: &[i32], heads: usize, d: usize) -> u64 {
    let mut total = 0u64;
    for w in offsets.windows(2) {
        let n_b = (w[1] - w[0]) as u64;
        total += n_b * n_b;
    }
    4 * heads as u64 * total * d as u64
}

/// Cumulative OS threads spawned by the kernel layer (pool workers at
/// construction + every scoped-path thread). `benches/native.rs` reports
/// the per-call delta — the number the pool exists to drive to zero.
static THREAD_SPAWNS: AtomicU64 = AtomicU64::new(0);

pub(crate) fn note_spawns(n: u64) {
    THREAD_SPAWNS.fetch_add(n, Ordering::Relaxed);
}

/// Total kernel-layer thread spawns since process start (stats/bench).
pub fn thread_spawns() -> u64 {
    THREAD_SPAWNS.load(Ordering::Relaxed)
}

/// Row-wise LayerNorm over `h`-wide rows, in place. `x.len()` must be a
/// multiple of `h`; `gamma`/`beta` are `[h]`.
pub fn layer_norm(x: &mut [f32], h: usize, gamma: &[f32], beta: &[f32]) {
    const LN_EPS: f32 = 1e-6;
    assert!(x.len() % h == 0 && gamma.len() == h && beta.len() == h, "layer_norm shapes");
    for row in x.chunks_exact_mut(h) {
        let mut mean = 0f32;
        for &v in row.iter() {
            mean += v;
        }
        mean /= h as f32;
        let mut var = 0f32;
        for &v in row.iter() {
            let dv = v - mean;
            var += dv * dv;
        }
        var /= h as f32;
        let inv = 1.0 / (var + LN_EPS).sqrt();
        for (c, v) in row.iter_mut().enumerate() {
            *v = (*v - mean) * inv * gamma[c] + beta[c];
        }
    }
}

/// Tanh-approximate GELU, matching `jax.nn.gelu(..., approximate=True)` —
/// the activation the golden fixtures were exported with.
#[inline(always)]
pub fn gelu(x: f32) -> f32 {
    const SQRT_2_OVER_PI: f32 = 0.797_884_56;
    0.5 * x * (1.0 + (SQRT_2_OVER_PI * (x + 0.044_715 * x * x * x)).tanh())
}

/// Split `tasks` units of work into at most `threads` contiguous ranges,
/// first ranges no smaller than later ones. Shared by the GEMM (rows) and
/// attention ((batch, head) pairs) parallel drivers.
pub(crate) fn task_ranges(tasks: usize, threads: usize) -> Vec<std::ops::Range<usize>> {
    let threads = threads.clamp(1, tasks.max(1));
    let per = tasks.div_ceil(threads);
    let mut out = Vec::with_capacity(threads);
    let mut start = 0;
    while start < tasks {
        let end = (start + per).min(tasks);
        out.push(start..end);
        start = end;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layer_norm_normalizes_rows() {
        let mut x = vec![1.0, 2.0, 3.0, 4.0, -1.0, 0.0, 1.0, 2.0];
        let g = vec![1.0; 4];
        let b = vec![0.0; 4];
        layer_norm(&mut x, 4, &g, &b);
        for row in x.chunks_exact(4) {
            let mean: f32 = row.iter().sum::<f32>() / 4.0;
            let var: f32 = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 4.0;
            assert!(mean.abs() < 1e-5, "mean {mean}");
            assert!((var - 1.0).abs() < 1e-3, "var {var}");
        }
    }

    #[test]
    fn gelu_matches_reference_points() {
        assert!(gelu(0.0).abs() < 1e-7);
        assert!((gelu(1.0) - 0.841_192).abs() < 1e-4);
        assert!((gelu(-1.0) + 0.158_808).abs() < 1e-4);
        assert!((gelu(3.0) - 2.995_9).abs() < 1e-3);
    }

    #[test]
    fn task_ranges_cover_exactly() {
        for tasks in [0usize, 1, 2, 7, 16, 33] {
            for threads in [1usize, 2, 3, 4, 9] {
                let ranges = task_ranges(tasks, threads);
                assert!(ranges.len() <= threads);
                let covered: usize = ranges.iter().map(|r| r.len()).sum();
                assert_eq!(covered, tasks, "tasks={tasks} threads={threads}");
                for w in ranges.windows(2) {
                    assert_eq!(w[0].end, w[1].start);
                    assert!(w[0].len() >= w[1].len());
                }
            }
        }
    }

    #[test]
    fn precision_parses_and_reports() {
        assert_eq!(Precision::parse("f32"), Some(Precision::F32));
        assert_eq!(Precision::parse("FP32"), Some(Precision::F32));
        assert_eq!(Precision::parse("int8"), Some(Precision::Int8));
        assert_eq!(Precision::parse("I8"), Some(Precision::Int8));
        assert_eq!(Precision::parse("fp16"), None);
        assert_eq!(Precision::Int8.to_string(), "int8");
        assert_eq!(KernelConfig::default().precision, Precision::F32);
        // Whatever the build/CPU, the reported ISA must be one of the two
        // dispatchable kernels, and it must agree with `simd_active`.
        assert_eq!(active_isa(), if simd_active() { "avx2+fma" } else { "scalar" });
    }

    #[test]
    fn effective_threads_resolves_auto_and_clamps() {
        let cfg = KernelConfig::default().with_threads(8);
        assert_eq!(cfg.effective_threads(3), 3);
        assert_eq!(cfg.effective_threads(100), 8);
        assert_eq!(cfg.effective_threads(0), 1);
        let auto = KernelConfig::default().with_threads(0);
        assert!(auto.effective_threads(64) >= 1);
    }
}
