//! Blocked, weight-pretransposed `x @ w + bias` — the kernel behind every
//! projection in the native forward pass (QKV, attention output, both FFN
//! halves, pooler, classifier head).
//!
//! # Shape contract
//!
//! `x` is row-major `[n, k]`, the weight is row-major `[k, m]` at pack
//! time, `bias` is `[m]`, `out` is row-major `[n, m]` and fully
//! overwritten. `n` varies per call (it is `batch * surviving
//! word-vectors`, so elimination shrinks it layer by layer); `k`/`m` are
//! fixed per weight and validated on every call.
//!
//! # Why blocked + packed
//!
//! The naive loop ([`matmul_bias_ref`]) walks `w` row-major and
//! read-modify-writes the whole `out` row once per `k` step — `O(k · m)`
//! memory traffic per row of `x` against registers doing one multiply per
//! load. This kernel restructures the loop nest three ways:
//!
//! * **Pack once, at load time**: the weight is repacked into column
//!   panels of [`NR`] — `panel[p][kk*NR + j] = w[kk, p*NR + j]` — so the
//!   inner loop streams the panel contiguously regardless of `m`, and the
//!   transpose cost is paid once per model load, not per call.
//! * **Register tiling**: an [`MR`]`×`[`NR`] accumulator tile lives in
//!   registers across the whole depth loop; `out` is touched exactly once
//!   per `kc` block instead of once per `k` step.
//! * **Depth blocking** ([`KernelConfig::kc`]): the panel slab reused
//!   across every row tile is bounded to stay L1-resident when `k` is
//!   large (BERT-base FFN: `k = 3072`).
//!
//! Epilogues (bias, GELU, tanh) are fused into the tile writeback, so the
//! FFN's activation never materializes a separate pre-activation pass.
//!
//! Accumulation order is `k`-ascending within a block and blocks ascending
//! — the same order for every thread count (rows are data-parallel), so
//! results are deterministic under [`KernelConfig::threads`].
//!
//! Parallel calls dispatch the same fixed-order row-chunk task list to the
//! engine worker's persistent [`pool::KernelPool`](super::pool::KernelPool)
//! instead of spawning scoped threads per invocation; the old scoped path
//! is kept as [`PackedGemm::matmul_bias_scoped`] — the bench's old-vs-new
//! dispatch baseline and the property tests' bit-exactness oracle.
//!
//! # SIMD and precision tiers
//!
//! With the `simd` cargo feature on x86_64, the per-tile inner loops are
//! re-expressed with explicit AVX2/FMA intrinsics (one `NR`-wide register
//! per tile row, [`simd::gelu_ps`]/[`simd::tanh_ps`] polynomial epilogues)
//! and selected **at runtime** via `is_x86_feature_detected!` — see
//! [`super::simd_active`]. Dispatch happens inside [`PackedGemm::rows`],
//! below the serial/pooled/scoped split, so all three drivers stay
//! bit-identical to each other at any thread count. The scalar kernel
//! remains the correctness oracle: the SIMD path must track it within
//! `1e-5` relative (FMA re-rounds, and the vector GELU/tanh use a
//! `~2e-7`-accurate Cephes-style polynomial instead of libm), exposed
//! directly as [`PackedGemm::matmul_bias_scalar`].
//!
//! [`PackedGemmI8`] is the int8 tier: per-output-channel symmetric
//! quantization of the packed panels (`q = round(w / s_c)`, `s_c =
//! max|w[:,c]| / 127`) at pack time. Activations stay f32; the kernel
//! does an i8×f32 dot with a single per-channel rescale in the writeback
//! (`out = acc · s_c + bias`), which is exact across `kc` depth blocks
//! because `s_c` is constant per column. [`PackedLinear`] is the
//! precision-dispatch wrapper the model stores, chosen once at load from
//! [`KernelConfig::precision`].

use super::pool::Shards;
use super::{gelu, task_ranges, KernelConfig, KernelExec, Precision};

/// Rows of `x` per register tile.
pub const MR: usize = 4;
/// Columns of `w` per packed panel (and per register tile).
pub const NR: usize = 8;

/// What the tile writeback applies after adding the bias.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Epilogue {
    /// `out = x @ w + bias`
    None,
    /// `out = gelu(x @ w + bias)` — the FFN's fused activation.
    Gelu,
    /// `out = tanh(x @ w + bias)` — the pooler's fused activation.
    Tanh,
}

/// A weight matrix packed for the blocked kernel: column panels of [`NR`],
/// built once at model-load time (see module docs for the layout).
pub struct PackedGemm {
    k: usize,
    m: usize,
    /// `ceil(m / NR)` panels of `k * NR` floats each; the last panel is
    /// zero-padded past column `m`, so ragged widths run the full-speed
    /// tile and the writeback simply drops the padding columns.
    panels: Vec<f32>,
}

impl PackedGemm {
    /// Pack a row-major `[k, m]` weight. Panics if `w.len() != k * m`.
    pub fn pack(w: &[f32], k: usize, m: usize) -> PackedGemm {
        assert_eq!(w.len(), k * m, "pack: weight is not [k={k}, m={m}]");
        let np = m.div_ceil(NR);
        let mut panels = vec![0f32; np * k * NR];
        for p in 0..np {
            let cols = (m - p * NR).min(NR);
            let panel = &mut panels[p * k * NR..(p + 1) * k * NR];
            for kk in 0..k {
                let src = &w[kk * m + p * NR..kk * m + p * NR + cols];
                panel[kk * NR..kk * NR + cols].copy_from_slice(src);
            }
        }
        PackedGemm { k, m, panels }
    }

    /// Input width (`k`) this weight contracts over.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Output width (`m`).
    pub fn m(&self) -> usize {
        self.m
    }

    /// Bytes held by the packed panels (zero-padding included).
    pub fn panel_bytes(&self) -> usize {
        self.panels.len() * std::mem::size_of::<f32>()
    }

    /// `out = x @ w + bias` over `n` rows.
    pub fn matmul_bias(
        &self,
        x: &[f32],
        n: usize,
        bias: &[f32],
        exec: &KernelExec,
        out: &mut [f32],
    ) {
        self.run(x, n, bias, exec, Epilogue::None, out);
    }

    /// `out = gelu(x @ w + bias)` — fused FFN half.
    pub fn matmul_bias_gelu(
        &self,
        x: &[f32],
        n: usize,
        bias: &[f32],
        exec: &KernelExec,
        out: &mut [f32],
    ) {
        self.run(x, n, bias, exec, Epilogue::Gelu, out);
    }

    /// `out = tanh(x @ w + bias)` — fused pooler.
    pub fn matmul_bias_tanh(
        &self,
        x: &[f32],
        n: usize,
        bias: &[f32],
        exec: &KernelExec,
        out: &mut [f32],
    ) {
        self.run(x, n, bias, exec, Epilogue::Tanh, out);
    }

    /// Forced-scalar serial `out = x @ w + bias`: bypasses both the thread
    /// pool and the SIMD runtime dispatch. This is the correctness oracle
    /// the SIMD path is measured against (≤ 1e-5 relative, see module
    /// docs) and the "scalar" baseline row in `benches/native.rs`.
    pub fn matmul_bias_scalar(&self, x: &[f32], n: usize, bias: &[f32], kc: usize, out: &mut [f32]) {
        let (k, m) = (self.k, self.m);
        assert_eq!(x.len(), n * k, "matmul: x is not [n={n}, k={k}]");
        assert_eq!(bias.len(), m, "matmul: bias is not [m={m}]");
        assert_eq!(out.len(), n * m, "matmul: out is not [n={n}, m={m}]");
        self.rows_scalar(x, n, bias, kc, Epilogue::None, out);
    }

    fn run(
        &self,
        x: &[f32],
        n: usize,
        bias: &[f32],
        exec: &KernelExec,
        ep: Epilogue,
        out: &mut [f32],
    ) {
        let (k, m) = (self.k, self.m);
        assert_eq!(x.len(), n * k, "matmul: x is not [n={n}, k={k}]");
        assert_eq!(bias.len(), m, "matmul: bias is not [m={m}]");
        assert_eq!(out.len(), n * m, "matmul: out is not [n={n}, m={m}]");
        if n == 0 {
            return;
        }
        let cfg = exec.config();
        // Parallel split over rows: each lane owns contiguous row ranges
        // of x and out, at mc-row task granularity. Row results never
        // depend on the split, so any thread count is deterministic.
        let mc = cfg.mc.max(1);
        let tasks = n.div_ceil(mc);
        // Work-size dispatch: small shapes (quick-bundle cells are ~0.5
        // MFLOP) fall back to serial rather than paying the pool handoff.
        let threads = exec.threads_for_work(tasks, super::gemm_flops(n, k, m));
        if threads <= 1 {
            // Serial fast path — the serving default; untouched by the
            // pool machinery.
            self.rows(x, n, bias, cfg.kc, ep, out);
            return;
        }
        // The same fixed-order row-chunk list the scoped path built via
        // `task_ranges`, expressed in closed form so dispatch allocates
        // nothing: chunk t covers mc-tasks [t*per, (t+1)*per).
        let per = tasks.div_ceil(threads);
        let chunks = tasks.div_ceil(per);
        let out_shards = Shards::new(out);
        exec.pool().run(chunks, &|t| {
            let row0 = t * per * mc;
            let rows = ((t + 1) * per * mc).min(n) - row0;
            // SAFETY: chunk ranges [row0*m, (row0+rows)*m) partition `out`
            // pairwise-disjointly by construction.
            let chunk = unsafe { out_shards.slice(row0 * m, rows * m) };
            self.rows(&x[row0 * k..(row0 + rows) * k], rows, bias, cfg.kc, ep, chunk);
        });
    }

    /// The pre-pool parallel driver: scoped threads spawned per call over
    /// the identical row-chunk list (bias epilogue only). Kept as the
    /// dispatch-cost baseline for `benches/native.rs` and the bit-exactness
    /// oracle for `tests/prop_kernels.rs` — results must equal
    /// [`PackedGemm::matmul_bias`] bit-for-bit at any thread count.
    pub fn matmul_bias_scoped(
        &self,
        x: &[f32],
        n: usize,
        bias: &[f32],
        cfg: &KernelConfig,
        out: &mut [f32],
    ) {
        let (k, m) = (self.k, self.m);
        assert_eq!(x.len(), n * k, "matmul: x is not [n={n}, k={k}]");
        assert_eq!(bias.len(), m, "matmul: bias is not [m={m}]");
        assert_eq!(out.len(), n * m, "matmul: out is not [n={n}, m={m}]");
        if n == 0 {
            return;
        }
        let mc = cfg.mc.max(1);
        let tasks = n.div_ceil(mc);
        // Scoped spawns cost ~1.4 MFLOP-equivalents each, so this path
        // applies the higher SCOPED_SPAWN_FLOPS floor (the 0.29×-of-serial
        // small-cell row in BENCH_native.json was exactly this driver).
        let threads = super::scoped_threads_for_work(cfg, tasks, super::gemm_flops(n, k, m));
        if threads <= 1 {
            self.rows(x, n, bias, cfg.kc, Epilogue::None, out);
            return;
        }
        let ranges = task_ranges(tasks, threads);
        super::note_spawns(ranges.len() as u64);
        let ep = Epilogue::None;
        std::thread::scope(|s| {
            let mut rest = out;
            let mut handles = Vec::with_capacity(ranges.len());
            for r in &ranges {
                let row0 = r.start * mc;
                let rows = (r.end * mc).min(n) - row0;
                let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(rows * m);
                rest = tail;
                let xs = &x[row0 * k..(row0 + rows) * k];
                handles.push(s.spawn(move || self.rows(xs, rows, bias, cfg.kc, ep, chunk)));
            }
            // Propagate panics out of the scope deterministically.
            for h in handles {
                h.join().expect("gemm worker panicked");
            }
        });
    }

    /// ISA dispatch for a contiguous row range. Sits *below* the
    /// serial/pooled/scoped drivers so every driver takes the same kernel
    /// at the same time — thread count never changes which ISA ran.
    fn rows(&self, x: &[f32], n: usize, bias: &[f32], kc: usize, ep: Epilogue, out: &mut [f32]) {
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        if super::simd_active() {
            // SAFETY: `simd_active()` checked avx2+fma on this CPU.
            unsafe { self.rows_avx2(x, n, bias, kc, ep, out) };
            return;
        }
        self.rows_scalar(x, n, bias, kc, ep, out);
    }

    /// Serial blocked scalar kernel over a contiguous row range.
    fn rows_scalar(
        &self,
        x: &[f32],
        n: usize,
        bias: &[f32],
        kc: usize,
        ep: Epilogue,
        out: &mut [f32],
    ) {
        let (k, m) = (self.k, self.m);
        let kc = kc.max(1);
        let np = m.div_ceil(NR);
        let mut kb = 0;
        while kb < k {
            let kb_end = (kb + kc).min(k);
            let first = kb == 0;
            let last = kb_end == k;
            let mut rb = 0;
            while rb < n {
                let rm = (n - rb).min(MR);
                for p in 0..np {
                    let panel = &self.panels[p * k * NR + kb * NR..p * k * NR + kb_end * NR];
                    let mut acc = [[0f32; NR]; MR];
                    if rm == MR {
                        // Full tile: fixed-trip loops so the accumulators
                        // stay in registers and the NR loop vectorizes.
                        for (kk, wrow) in panel.chunks_exact(NR).enumerate() {
                            let kabs = kb + kk;
                            for (r, accr) in acc.iter_mut().enumerate() {
                                let xv = x[(rb + r) * k + kabs];
                                for c in 0..NR {
                                    accr[c] += xv * wrow[c];
                                }
                            }
                        }
                    } else {
                        for (kk, wrow) in panel.chunks_exact(NR).enumerate() {
                            let kabs = kb + kk;
                            for (r, accr) in acc.iter_mut().enumerate().take(rm) {
                                let xv = x[(rb + r) * k + kabs];
                                for c in 0..NR {
                                    accr[c] += xv * wrow[c];
                                }
                            }
                        }
                    }
                    let cols = (m - p * NR).min(NR);
                    for (r, accr) in acc.iter().enumerate().take(rm) {
                        let orow = &mut out[(rb + r) * m + p * NR..(rb + r) * m + p * NR + cols];
                        for (c, o) in orow.iter_mut().enumerate() {
                            let mut v = accr[c] + if first { bias[p * NR + c] } else { *o };
                            if last {
                                v = match ep {
                                    Epilogue::None => v,
                                    Epilogue::Gelu => gelu(v),
                                    Epilogue::Tanh => v.tanh(),
                                };
                            }
                            *o = v;
                        }
                    }
                }
                rb += rm;
            }
            kb = kb_end;
        }
    }
}

/// A weight matrix quantized to int8 at pack time: the same [`NR`]-column
/// panel layout as [`PackedGemm`], with one symmetric per-output-channel
/// scale (`s_c = max|w[:,c]| / 127`, all-zero columns get `s = 1`). The
/// kernel contracts f32 activations against the i8 panel and applies the
/// per-channel rescale once in the tile writeback — exact across depth
/// blocks because the scale is constant per column. Measured end-to-end
/// drift on the bundled models is ≤ 2e-4 on golden logits (documented
/// test tolerance 5e-3) with kept-token traces identical to f32.
pub struct PackedGemmI8 {
    k: usize,
    m: usize,
    /// `ceil(m / NR)` panels of `k * NR` quantized weights; padding
    /// columns are zero, like the f32 layout.
    panels: Vec<i8>,
    /// Per-output-channel dequantization scales, `ceil(m / NR) * NR` long
    /// so the writeback indexes it panel-relative; padding entries are
    /// `1.0` (they multiply zero accumulators, never divide).
    scales: Vec<f32>,
}

impl PackedGemmI8 {
    /// Quantize + pack a row-major `[k, m]` weight.
    pub fn pack(w: &[f32], k: usize, m: usize) -> PackedGemmI8 {
        assert_eq!(w.len(), k * m, "pack: weight is not [k={k}, m={m}]");
        let np = m.div_ceil(NR);
        let mut scales = vec![1f32; np * NR];
        for (c, sc) in scales.iter_mut().enumerate().take(m) {
            let mut maxabs = 0f32;
            for kk in 0..k {
                maxabs = maxabs.max(w[kk * m + c].abs());
            }
            if maxabs > 0.0 {
                *sc = maxabs / 127.0;
            }
        }
        let mut panels = vec![0i8; np * k * NR];
        for p in 0..np {
            let cols = (m - p * NR).min(NR);
            let panel = &mut panels[p * k * NR..(p + 1) * k * NR];
            for kk in 0..k {
                for cc in 0..cols {
                    let c = p * NR + cc;
                    let q = (w[kk * m + c] / scales[c]).round().clamp(-127.0, 127.0);
                    panel[kk * NR + cc] = q as i8;
                }
            }
        }
        PackedGemmI8 { k, m, panels, scales }
    }

    /// Input width (`k`) this weight contracts over.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Output width (`m`).
    pub fn m(&self) -> usize {
        self.m
    }

    /// Bytes held by the quantized panels plus their scales.
    pub fn panel_bytes(&self) -> usize {
        self.panels.len() + self.scales.len() * std::mem::size_of::<f32>()
    }

    /// `out = x @ dequant(w) + bias` over `n` rows.
    pub fn matmul_bias(
        &self,
        x: &[f32],
        n: usize,
        bias: &[f32],
        exec: &KernelExec,
        out: &mut [f32],
    ) {
        self.run(x, n, bias, exec, Epilogue::None, out);
    }

    /// `out = gelu(x @ dequant(w) + bias)`.
    pub fn matmul_bias_gelu(
        &self,
        x: &[f32],
        n: usize,
        bias: &[f32],
        exec: &KernelExec,
        out: &mut [f32],
    ) {
        self.run(x, n, bias, exec, Epilogue::Gelu, out);
    }

    /// `out = tanh(x @ dequant(w) + bias)`.
    pub fn matmul_bias_tanh(
        &self,
        x: &[f32],
        n: usize,
        bias: &[f32],
        exec: &KernelExec,
        out: &mut [f32],
    ) {
        self.run(x, n, bias, exec, Epilogue::Tanh, out);
    }

    fn run(
        &self,
        x: &[f32],
        n: usize,
        bias: &[f32],
        exec: &KernelExec,
        ep: Epilogue,
        out: &mut [f32],
    ) {
        let (k, m) = (self.k, self.m);
        assert_eq!(x.len(), n * k, "matmul: x is not [n={n}, k={k}]");
        assert_eq!(bias.len(), m, "matmul: bias is not [m={m}]");
        assert_eq!(out.len(), n * m, "matmul: out is not [n={n}, m={m}]");
        if n == 0 {
            return;
        }
        // Identical closed-form row-chunk dispatch to the f32 kernel —
        // see PackedGemm::run (including the small-shape serial fallback);
        // only the inner kernel differs.
        let cfg = exec.config();
        let mc = cfg.mc.max(1);
        let tasks = n.div_ceil(mc);
        let threads = exec.threads_for_work(tasks, super::gemm_flops(n, k, m));
        if threads <= 1 {
            self.rows(x, n, bias, cfg.kc, ep, out);
            return;
        }
        let per = tasks.div_ceil(threads);
        let chunks = tasks.div_ceil(per);
        let out_shards = Shards::new(out);
        exec.pool().run(chunks, &|t| {
            let row0 = t * per * mc;
            let rows = ((t + 1) * per * mc).min(n) - row0;
            // SAFETY: chunk ranges [row0*m, (row0+rows)*m) partition `out`
            // pairwise-disjointly by construction.
            let chunk = unsafe { out_shards.slice(row0 * m, rows * m) };
            self.rows(&x[row0 * k..(row0 + rows) * k], rows, bias, cfg.kc, ep, chunk);
        });
    }

    /// ISA dispatch for a contiguous row range (see [`PackedGemm::rows`]).
    fn rows(&self, x: &[f32], n: usize, bias: &[f32], kc: usize, ep: Epilogue, out: &mut [f32]) {
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        if super::simd_active() {
            // SAFETY: `simd_active()` checked avx2+fma on this CPU.
            unsafe { self.rows_avx2(x, n, bias, kc, ep, out) };
            return;
        }
        self.rows_scalar(x, n, bias, kc, ep, out);
    }

    /// Serial blocked scalar i8×f32 kernel: the accumulator tile is f32,
    /// weights widen lane-wise from i8, and the per-channel scale lands
    /// once in the writeback.
    fn rows_scalar(
        &self,
        x: &[f32],
        n: usize,
        bias: &[f32],
        kc: usize,
        ep: Epilogue,
        out: &mut [f32],
    ) {
        let (k, m) = (self.k, self.m);
        let kc = kc.max(1);
        let np = m.div_ceil(NR);
        let mut kb = 0;
        while kb < k {
            let kb_end = (kb + kc).min(k);
            let first = kb == 0;
            let last = kb_end == k;
            let mut rb = 0;
            while rb < n {
                let rm = (n - rb).min(MR);
                for p in 0..np {
                    let panel = &self.panels[p * k * NR + kb * NR..p * k * NR + kb_end * NR];
                    let scales = &self.scales[p * NR..(p + 1) * NR];
                    let mut acc = [[0f32; NR]; MR];
                    for (kk, wrow) in panel.chunks_exact(NR).enumerate() {
                        let kabs = kb + kk;
                        for (r, accr) in acc.iter_mut().enumerate().take(rm) {
                            let xv = x[(rb + r) * k + kabs];
                            for c in 0..NR {
                                accr[c] += xv * f32::from(wrow[c]);
                            }
                        }
                    }
                    let cols = (m - p * NR).min(NR);
                    for (r, accr) in acc.iter().enumerate().take(rm) {
                        let orow = &mut out[(rb + r) * m + p * NR..(rb + r) * m + p * NR + cols];
                        for (c, o) in orow.iter_mut().enumerate() {
                            let mut v =
                                accr[c] * scales[c] + if first { bias[p * NR + c] } else { *o };
                            if last {
                                v = match ep {
                                    Epilogue::None => v,
                                    Epilogue::Gelu => gelu(v),
                                    Epilogue::Tanh => v.tanh(),
                                };
                            }
                            *o = v;
                        }
                    }
                }
                rb += rm;
            }
            kb = kb_end;
        }
    }
}

/// The precision-dispatch wrapper the native model stores for every
/// projection: packed once at load time from [`KernelConfig::precision`],
/// then called through the same `matmul_bias*` surface regardless of tier.
pub enum PackedLinear {
    /// Full-precision packed panels.
    F32(PackedGemm),
    /// Per-channel symmetric int8 panels (f32 activations).
    Int8(PackedGemmI8),
}

impl PackedLinear {
    /// Pack a row-major `[k, m]` weight at the requested precision.
    pub fn pack(w: &[f32], k: usize, m: usize, precision: Precision) -> PackedLinear {
        match precision {
            Precision::F32 => PackedLinear::F32(PackedGemm::pack(w, k, m)),
            Precision::Int8 => PackedLinear::Int8(PackedGemmI8::pack(w, k, m)),
        }
    }

    /// Input width (`k`) this weight contracts over.
    pub fn k(&self) -> usize {
        match self {
            PackedLinear::F32(g) => g.k(),
            PackedLinear::Int8(g) => g.k(),
        }
    }

    /// Output width (`m`).
    pub fn m(&self) -> usize {
        match self {
            PackedLinear::F32(g) => g.m(),
            PackedLinear::Int8(g) => g.m(),
        }
    }

    /// Which tier this weight was packed at.
    pub fn precision(&self) -> Precision {
        match self {
            PackedLinear::F32(_) => Precision::F32,
            PackedLinear::Int8(_) => Precision::Int8,
        }
    }

    /// Bytes held by the packed panels (plus scales for int8).
    pub fn panel_bytes(&self) -> usize {
        match self {
            PackedLinear::F32(g) => g.panel_bytes(),
            PackedLinear::Int8(g) => g.panel_bytes(),
        }
    }

    /// `out = x @ w + bias` over `n` rows.
    pub fn matmul_bias(
        &self,
        x: &[f32],
        n: usize,
        bias: &[f32],
        exec: &KernelExec,
        out: &mut [f32],
    ) {
        match self {
            PackedLinear::F32(g) => g.matmul_bias(x, n, bias, exec, out),
            PackedLinear::Int8(g) => g.matmul_bias(x, n, bias, exec, out),
        }
    }

    /// `out = gelu(x @ w + bias)` — fused FFN half.
    pub fn matmul_bias_gelu(
        &self,
        x: &[f32],
        n: usize,
        bias: &[f32],
        exec: &KernelExec,
        out: &mut [f32],
    ) {
        match self {
            PackedLinear::F32(g) => g.matmul_bias_gelu(x, n, bias, exec, out),
            PackedLinear::Int8(g) => g.matmul_bias_gelu(x, n, bias, exec, out),
        }
    }

    /// `out = tanh(x @ w + bias)` — fused pooler.
    pub fn matmul_bias_tanh(
        &self,
        x: &[f32],
        n: usize,
        bias: &[f32],
        exec: &KernelExec,
        out: &mut [f32],
    ) {
        match self {
            PackedLinear::F32(g) => g.matmul_bias_tanh(x, n, bias, exec, out),
            PackedLinear::Int8(g) => g.matmul_bias_tanh(x, n, bias, exec, out),
        }
    }

    /// Ragged driver: `out = x @ w + bias` over the **concatenated kept
    /// rows** of a ragged batch — one GEMM per projection, whatever the
    /// per-example widths. The packed microkernels are oblivious to
    /// example boundaries (rows are data-parallel), so the whole ragged
    /// batch runs as a single `[Σ kept_b, k]` GEMM: elimination shrinks
    /// the GEMM's *row count exactly*, and a ragged call is bit-identical
    /// to the padded call on the same row content (same mc chunking over
    /// the same total row count).
    pub fn matmul_bias_ragged(
        &self,
        x: RaggedRows<'_>,
        bias: &[f32],
        exec: &KernelExec,
        out: &mut [f32],
    ) {
        debug_assert_eq!(x.width(), self.k(), "ragged matmul: row width != k");
        self.matmul_bias(x.data(), x.total_rows(), bias, exec, out);
    }

    /// Ragged driver with the fused GELU epilogue — the FFN's first half
    /// over concatenated kept rows.
    pub fn matmul_bias_gelu_ragged(
        &self,
        x: RaggedRows<'_>,
        bias: &[f32],
        exec: &KernelExec,
        out: &mut [f32],
    ) {
        debug_assert_eq!(x.width(), self.k(), "ragged matmul: row width != k");
        self.matmul_bias_gelu(x.data(), x.total_rows(), bias, exec, out);
    }
}

/// Row-offset ragged view over a batch of per-example row blocks: one
/// contiguous `[total_rows, width]` buffer plus `examples + 1` prefix-sum
/// row offsets — example `b` owns rows `offsets[b] .. offsets[b+1]`.
///
/// This is the activation layout of the native backend's ragged execution
/// path (see `docs/ARCHITECTURE.md` § "Ragged execution"): after each
/// extract layer every example is compacted to its *own* kept width, so
/// `total_rows = Σ kept_b` and the GEMM/attention work is exactly the
/// tokens kept — no ghost rows padded up to a per-batch maximum.
///
/// Offsets are `i32` (the arena's integer slab element) — `total_rows` is
/// bounded by `batch × seq ≤ 64 × 512`, far inside range.
#[derive(Clone, Copy)]
pub struct RaggedRows<'a> {
    data: &'a [f32],
    offsets: &'a [i32],
    width: usize,
}

impl<'a> RaggedRows<'a> {
    /// View `data` as `offsets.len() - 1` examples of `width`-wide rows.
    /// Panics unless offsets start at 0, are non-decreasing, and
    /// `data.len() == offsets.last() * width`.
    pub fn new(data: &'a [f32], offsets: &'a [i32], width: usize) -> RaggedRows<'a> {
        assert!(offsets.len() >= 2, "ragged: offsets needs >= 2 entries (batch + 1)");
        assert_eq!(offsets[0], 0, "ragged: offsets must start at 0");
        assert!(
            offsets.windows(2).all(|w| w[0] <= w[1]),
            "ragged: offsets must be non-decreasing"
        );
        let total = *offsets.last().unwrap() as usize;
        assert_eq!(data.len(), total * width, "ragged: data is not [total_rows, width]");
        RaggedRows { data, offsets, width }
    }

    /// Number of examples (`offsets.len() - 1`).
    pub fn examples(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Total concatenated rows (`Σ kept_b` — the ragged GEMM's `n`).
    pub fn total_rows(&self) -> usize {
        *self.offsets.last().unwrap() as usize
    }

    /// Row width (the GEMM's `k`).
    pub fn width(&self) -> usize {
        self.width
    }

    /// Row range of example `b`.
    pub fn rows(&self, b: usize) -> std::ops::Range<usize> {
        self.offsets[b] as usize..self.offsets[b + 1] as usize
    }

    /// Example `b`'s rows as a contiguous `[kept_b, width]` slice.
    pub fn example(&self, b: usize) -> &'a [f32] {
        let r = self.rows(b);
        &self.data[r.start * self.width..r.end * self.width]
    }

    /// The whole concatenated `[total_rows, width]` buffer.
    pub fn data(&self) -> &'a [f32] {
        self.data
    }

    /// The prefix-sum row-offset table (`examples + 1` entries).
    pub fn offsets(&self) -> &'a [i32] {
        self.offsets
    }
}

/// The naive reference `x [n, k] @ w [k, m] + b [m]` (row-major) — the
/// pre-kernel implementation, kept as the correctness oracle for the
/// property tests and the "old" side of the bench's old-vs-new table.
pub fn matmul_bias_ref(x: &[f32], n: usize, k: usize, w: &[f32], m: usize, b: &[f32]) -> Vec<f32> {
    let mut out = vec![0f32; n * m];
    for i in 0..n {
        let xrow = &x[i * k..(i + 1) * k];
        let orow = &mut out[i * m..(i + 1) * m];
        orow.copy_from_slice(b);
        for (kk, &xv) in xrow.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wrow = &w[kk * m..(kk + 1) * m];
            for (c, &wv) in wrow.iter().enumerate() {
                orow[c] += xv * wv;
            }
        }
    }
    out
}

/// Explicit AVX2/FMA microkernels and the vector transcendental epilogues
/// they fuse. Compiled only under `--features simd` on x86_64; every entry
/// point carries `#[target_feature(enable = "avx2,fma")]` and must be
/// reached through a [`super::simd_active`] runtime check — the scalar
/// kernels above remain the oracle and the fallback everywhere else.
///
/// `exp_ps`/`tanh_ps`/`gelu_ps` use the classic Cephes f32 expansion
/// (range-reduce by `log2(e)`, degree-5 polynomial, exponent reassembly
/// via integer bit-twiddling). Measured max relative error vs libm:
/// `exp` 2.0e-7, `tanh` 1.2e-7, `gelu` 1.6e-7 — far inside the kernel's
/// documented 1e-5 SIMD-vs-scalar tolerance.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
pub(crate) mod simd {
    use super::{gelu, Epilogue, PackedGemm, PackedGemmI8, MR, NR};
    use std::arch::x86_64::*;

    /// Vectorized `e^x`, clamped to x ∈ [-87, 88] (beyond which f32
    /// saturates to 0 / inf anyway).
    ///
    /// # Safety
    /// Requires AVX2 + FMA (guard with [`super::super::simd_active`]).
    #[inline]
    #[target_feature(enable = "avx2", enable = "fma")]
    pub(crate) unsafe fn exp_ps(x: __m256) -> __m256 {
        let x = _mm256_min_ps(_mm256_max_ps(x, _mm256_set1_ps(-87.0)), _mm256_set1_ps(88.0));
        // n = floor(x * log2(e) + 0.5); f = x - n*ln2 in two-part precision.
        let z = _mm256_floor_ps(_mm256_fmadd_ps(
            x,
            _mm256_set1_ps(1.442_695_04),
            _mm256_set1_ps(0.5),
        ));
        let f = _mm256_fnmadd_ps(
            z,
            _mm256_set1_ps(-2.121_944_4e-4),
            _mm256_fnmadd_ps(z, _mm256_set1_ps(0.693_359_375), x),
        );
        // Degree-5 polynomial for e^f on the reduced range.
        let mut y = _mm256_set1_ps(1.987_569_15e-4);
        y = _mm256_fmadd_ps(y, f, _mm256_set1_ps(1.398_199_95e-3));
        y = _mm256_fmadd_ps(y, f, _mm256_set1_ps(8.333_451_9e-3));
        y = _mm256_fmadd_ps(y, f, _mm256_set1_ps(4.166_579_6e-2));
        y = _mm256_fmadd_ps(y, f, _mm256_set1_ps(1.666_666_55e-1));
        y = _mm256_fmadd_ps(y, f, _mm256_set1_ps(5.000_000_1e-1));
        let f2 = _mm256_mul_ps(f, f);
        y = _mm256_add_ps(_mm256_fmadd_ps(y, f2, f), _mm256_set1_ps(1.0));
        // Reassemble 2^n into the exponent field; z is integral and in
        // [-126, 127] after the clamp, so the shift cannot overflow.
        let pow2n = _mm256_castsi256_ps(_mm256_slli_epi32(
            _mm256_add_epi32(_mm256_cvtps_epi32(z), _mm256_set1_epi32(127)),
            23,
        ));
        _mm256_mul_ps(y, pow2n)
    }

    /// Vectorized `tanh(x)` via `1 - 2 / (e^{2|x|} + 1)` with the sign
    /// reapplied, so it saturates monotonically to ±1.
    ///
    /// # Safety
    /// Requires AVX2 + FMA (guard with [`super::super::simd_active`]).
    #[inline]
    #[target_feature(enable = "avx2", enable = "fma")]
    pub(crate) unsafe fn tanh_ps(x: __m256) -> __m256 {
        let sign_bit = _mm256_set1_ps(-0.0);
        let ax = _mm256_andnot_ps(sign_bit, x);
        let e = exp_ps(_mm256_min_ps(_mm256_add_ps(ax, ax), _mm256_set1_ps(88.0)));
        let t = _mm256_sub_ps(
            _mm256_set1_ps(1.0),
            _mm256_div_ps(_mm256_set1_ps(2.0), _mm256_add_ps(e, _mm256_set1_ps(1.0))),
        );
        // t >= 0 here; OR-ing the argument's sign bit is copysign.
        _mm256_or_ps(t, _mm256_and_ps(sign_bit, x))
    }

    /// Vectorized tanh-approximation GELU matching [`super::gelu`]'s
    /// constants: `0.5 x (1 + tanh(√(2/π) (x + 0.044715 x³)))`.
    ///
    /// # Safety
    /// Requires AVX2 + FMA (guard with [`super::super::simd_active`]).
    #[inline]
    #[target_feature(enable = "avx2", enable = "fma")]
    pub(crate) unsafe fn gelu_ps(x: __m256) -> __m256 {
        let x3 = _mm256_mul_ps(_mm256_mul_ps(x, x), x);
        let inner = _mm256_mul_ps(
            _mm256_set1_ps(0.797_884_56),
            _mm256_fmadd_ps(_mm256_set1_ps(0.044_715), x3, x),
        );
        let t = tanh_ps(inner);
        _mm256_mul_ps(
            _mm256_mul_ps(_mm256_set1_ps(0.5), x),
            _mm256_add_ps(_mm256_set1_ps(1.0), t),
        )
    }

    /// Horizontal sum of all 8 lanes.
    ///
    /// # Safety
    /// Requires AVX2 (guard with [`super::super::simd_active`]).
    #[inline]
    #[target_feature(enable = "avx2", enable = "fma")]
    pub(crate) unsafe fn hsum_ps(v: __m256) -> f32 {
        let s = _mm_add_ps(_mm256_castps256_ps128(v), _mm256_extractf128_ps(v, 1));
        let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
        let s = _mm_add_ss(s, _mm_shuffle_ps(s, s, 1));
        _mm_cvtss_f32(s)
    }

    /// Shared vector writeback: `acc + (bias | out)`, optional epilogue,
    /// store — the full-panel fast path for both precisions.
    ///
    /// # Safety
    /// Requires AVX2 + FMA; `optr` must point at `NR` writable floats and
    /// `bptr` at `NR` readable floats.
    #[inline]
    #[target_feature(enable = "avx2", enable = "fma")]
    unsafe fn writeback_ps(
        acc: __m256,
        bptr: *const f32,
        optr: *mut f32,
        first: bool,
        last: bool,
        ep: Epilogue,
    ) {
        let base = if first { _mm256_loadu_ps(bptr) } else { _mm256_loadu_ps(optr) };
        let mut v = _mm256_add_ps(acc, base);
        if last {
            v = match ep {
                Epilogue::None => v,
                Epilogue::Gelu => gelu_ps(v),
                Epilogue::Tanh => tanh_ps(v),
            };
        }
        _mm256_storeu_ps(optr, v);
    }

    /// Ragged-last-panel writeback: spill the vector accumulator and run
    /// the scalar epilogue on the `cols` live columns. Column raggedness
    /// is a property of the weight, not the row split, so this choice is
    /// identical for every thread count.
    fn writeback_tail(
        acc: [f32; NR],
        bias: &[f32],
        orow: &mut [f32],
        first: bool,
        last: bool,
        ep: Epilogue,
    ) {
        for (c, o) in orow.iter_mut().enumerate() {
            let mut v = acc[c] + if first { bias[c] } else { *o };
            if last {
                v = match ep {
                    Epilogue::None => v,
                    Epilogue::Gelu => gelu(v),
                    Epilogue::Tanh => v.tanh(),
                };
            }
            *o = v;
        }
    }

    impl PackedGemm {
        /// AVX2/FMA twin of [`PackedGemm::rows_scalar`]: one `NR`-wide
        /// register per tile row, FMA across the depth block, vector
        /// bias + epilogue writeback on full panels.
        ///
        /// # Safety
        /// Requires AVX2 + FMA (guard with [`super::super::simd_active`]).
        #[target_feature(enable = "avx2", enable = "fma")]
        pub(super) unsafe fn rows_avx2(
            &self,
            x: &[f32],
            n: usize,
            bias: &[f32],
            kc: usize,
            ep: Epilogue,
            out: &mut [f32],
        ) {
            let (k, m) = (self.k, self.m);
            let kc = kc.max(1);
            let np = m.div_ceil(NR);
            let mut kb = 0;
            while kb < k {
                let kb_end = (kb + kc).min(k);
                let first = kb == 0;
                let last = kb_end == k;
                let mut rb = 0;
                while rb < n {
                    let rm = (n - rb).min(MR);
                    for p in 0..np {
                        let panel = &self.panels[p * k * NR + kb * NR..p * k * NR + kb_end * NR];
                        let mut acc = [_mm256_setzero_ps(); MR];
                        for (kk, wrow) in panel.chunks_exact(NR).enumerate() {
                            let kabs = kb + kk;
                            let wv = _mm256_loadu_ps(wrow.as_ptr());
                            for (r, a) in acc.iter_mut().enumerate().take(rm) {
                                let xv = _mm256_set1_ps(x[(rb + r) * k + kabs]);
                                *a = _mm256_fmadd_ps(xv, wv, *a);
                            }
                        }
                        let cols = (m - p * NR).min(NR);
                        if cols == NR {
                            for (r, a) in acc.iter().enumerate().take(rm) {
                                let optr = out.as_mut_ptr().add((rb + r) * m + p * NR);
                                writeback_ps(*a, bias.as_ptr().add(p * NR), optr, first, last, ep);
                            }
                        } else {
                            for (r, a) in acc.iter().enumerate().take(rm) {
                                let mut lane = [0f32; NR];
                                _mm256_storeu_ps(lane.as_mut_ptr(), *a);
                                let o0 = (rb + r) * m + p * NR;
                                writeback_tail(
                                    lane,
                                    &bias[p * NR..p * NR + cols],
                                    &mut out[o0..o0 + cols],
                                    first,
                                    last,
                                    ep,
                                );
                            }
                        }
                    }
                    rb += rm;
                }
                kb = kb_end;
            }
        }
    }

    impl PackedGemmI8 {
        /// AVX2/FMA twin of [`PackedGemmI8::rows_scalar`]: widen 8 i8
        /// weights to an f32 register per depth step, FMA against the
        /// broadcast activation, rescale per channel in the writeback.
        ///
        /// # Safety
        /// Requires AVX2 + FMA (guard with [`super::super::simd_active`]).
        #[target_feature(enable = "avx2", enable = "fma")]
        pub(super) unsafe fn rows_avx2(
            &self,
            x: &[f32],
            n: usize,
            bias: &[f32],
            kc: usize,
            ep: Epilogue,
            out: &mut [f32],
        ) {
            let (k, m) = (self.k, self.m);
            let kc = kc.max(1);
            let np = m.div_ceil(NR);
            let mut kb = 0;
            while kb < k {
                let kb_end = (kb + kc).min(k);
                let first = kb == 0;
                let last = kb_end == k;
                let mut rb = 0;
                while rb < n {
                    let rm = (n - rb).min(MR);
                    for p in 0..np {
                        let panel = &self.panels[p * k * NR + kb * NR..p * k * NR + kb_end * NR];
                        let sv = _mm256_loadu_ps(self.scales.as_ptr().add(p * NR));
                        let mut acc = [_mm256_setzero_ps(); MR];
                        for (kk, wrow) in panel.chunks_exact(NR).enumerate() {
                            let kabs = kb + kk;
                            let wq = _mm_loadl_epi64(wrow.as_ptr() as *const __m128i);
                            let wv = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(wq));
                            for (r, a) in acc.iter_mut().enumerate().take(rm) {
                                let xv = _mm256_set1_ps(x[(rb + r) * k + kabs]);
                                *a = _mm256_fmadd_ps(xv, wv, *a);
                            }
                        }
                        let cols = (m - p * NR).min(NR);
                        if cols == NR {
                            for (r, a) in acc.iter().enumerate().take(rm) {
                                let optr = out.as_mut_ptr().add((rb + r) * m + p * NR);
                                let scaled = _mm256_mul_ps(*a, sv);
                                writeback_ps(
                                    scaled,
                                    bias.as_ptr().add(p * NR),
                                    optr,
                                    first,
                                    last,
                                    ep,
                                );
                            }
                        } else {
                            for (r, a) in acc.iter().enumerate().take(rm) {
                                let mut lane = [0f32; NR];
                                _mm256_storeu_ps(lane.as_mut_ptr(), _mm256_mul_ps(*a, sv));
                                let o0 = (rb + r) * m + p * NR;
                                writeback_tail(
                                    lane,
                                    &bias[p * NR..p * NR + cols],
                                    &mut out[o0..o0 + cols],
                                    first,
                                    last,
                                    ep,
                                );
                            }
                        }
                    }
                    rb += rm;
                }
                kb = kb_end;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: &[f32], b: &[f32], tol: f32) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert!((x - y).abs() <= tol * (1.0 + y.abs()), "[{i}] {x} vs {y}");
        }
    }

    #[test]
    fn matches_reference_on_identity() {
        // [1,2;3,4] @ I + [10, 20]
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let w = vec![1.0, 0.0, 0.0, 1.0];
        let b = vec![10.0, 20.0];
        let packed = PackedGemm::pack(&w, 2, 2);
        let mut out = vec![0f32; 4];
        packed.matmul_bias(&x, 2, &b, &KernelExec::default(), &mut out);
        assert_eq!(out, vec![11.0, 22.0, 13.0, 24.0]);
        assert_eq!(out, matmul_bias_ref(&x, 2, 2, &w, 2, &b));
    }

    #[test]
    fn ragged_shapes_match_reference() {
        // Deliberately not multiples of MR/NR, with kc forcing two blocks.
        let (n, k, m) = (5usize, 7usize, 11usize);
        let x: Vec<f32> = (0..n * k).map(|i| ((i * 37 % 19) as f32 - 9.0) * 0.1).collect();
        let w: Vec<f32> = (0..k * m).map(|i| ((i * 53 % 23) as f32 - 11.0) * 0.05).collect();
        let b: Vec<f32> = (0..m).map(|i| i as f32 * 0.01).collect();
        let exec = KernelExec::new(KernelConfig { threads: 1, kc: 3, mc: 2, ..KernelConfig::default() });
        let packed = PackedGemm::pack(&w, k, m);
        let mut out = vec![0f32; n * m];
        packed.matmul_bias(&x, n, &b, &exec, &mut out);
        close(&out, &matmul_bias_ref(&x, n, k, &w, m, &b), 1e-5);
    }

    #[test]
    fn pooled_and_scoped_threads_are_bit_identical() {
        let (n, k, m) = (13usize, 9usize, 17usize);
        let x: Vec<f32> = (0..n * k).map(|i| (i as f32).sin()).collect();
        let w: Vec<f32> = (0..k * m).map(|i| (i as f32).cos()).collect();
        let b = vec![0.25f32; m];
        let packed = PackedGemm::pack(&w, k, m);
        let mut serial = vec![0f32; n * m];
        let serial_exec =
            KernelExec::new(KernelConfig { threads: 1, kc: 4, mc: 3, ..KernelConfig::default() });
        packed.matmul_bias(&x, n, &b, &serial_exec, &mut serial);
        for threads in [2usize, 4, 7] {
            // min_parallel_flops: 0 — this test exists to run the parallel
            // drivers on a tiny shape, so the small-shape fallback is off.
            let cfg = KernelConfig {
                threads,
                kc: 4,
                mc: 3,
                min_parallel_flops: 0,
                ..KernelConfig::default()
            };
            let mut pooled = vec![0f32; n * m];
            packed.matmul_bias(&x, n, &b, &KernelExec::new(cfg.clone()), &mut pooled);
            assert_eq!(serial, pooled, "pooled differs at threads={threads}");
            let mut scoped = vec![0f32; n * m];
            packed.matmul_bias_scoped(&x, n, &b, &cfg, &mut scoped);
            assert_eq!(serial, scoped, "scoped differs at threads={threads}");
        }
    }

    #[test]
    fn fused_epilogues_match_mapped_reference() {
        let (n, k, m) = (3usize, 6usize, 10usize);
        let x: Vec<f32> = (0..n * k).map(|i| ((i % 7) as f32 - 3.0) * 0.3).collect();
        let w: Vec<f32> = (0..k * m).map(|i| ((i % 5) as f32 - 2.0) * 0.2).collect();
        let b = vec![0.1f32; m];
        let exec = KernelExec::default();
        let packed = PackedGemm::pack(&w, k, m);
        let plain = matmul_bias_ref(&x, n, k, &w, m, &b);
        let mut out = vec![0f32; n * m];
        packed.matmul_bias_gelu(&x, n, &b, &exec, &mut out);
        close(&out, &plain.iter().map(|&v| gelu(v)).collect::<Vec<_>>(), 1e-5);
        packed.matmul_bias_tanh(&x, n, &b, &exec, &mut out);
        close(&out, &plain.iter().map(|v| v.tanh()).collect::<Vec<_>>(), 1e-5);
    }

    #[test]
    fn degenerate_blocks_are_clamped_not_zero_output() {
        // mc = 0 / kc = 0 must clamp to 1, not silently leave `out` all
        // zeros (every parallel range would otherwise cover zero rows).
        let (n, k, m) = (9usize, 5usize, 6usize);
        let x: Vec<f32> = (0..n * k).map(|i| (i as f32).sin()).collect();
        let w: Vec<f32> = (0..k * m).map(|i| (i as f32).cos()).collect();
        let b = vec![1.0f32; m];
        let packed = PackedGemm::pack(&w, k, m);
        let want = matmul_bias_ref(&x, n, k, &w, m, &b);
        for cfg in [
            KernelConfig {
                threads: 4,
                kc: 256,
                mc: 0,
                min_parallel_flops: 0,
                ..KernelConfig::default()
            },
            KernelConfig { threads: 1, kc: 0, mc: 0, ..KernelConfig::default() },
        ] {
            let mut out = vec![0f32; n * m];
            packed.matmul_bias(&x, n, &b, &KernelExec::new(cfg), &mut out);
            close(&out, &want, 1e-5);
        }
    }

    #[test]
    fn zero_rows_is_a_no_op() {
        let packed = PackedGemm::pack(&[1.0, 2.0], 1, 2);
        let mut out = vec![];
        packed.matmul_bias(&[], 0, &[0.0, 0.0], &KernelExec::default(), &mut out);
        assert!(out.is_empty());
        assert_eq!((packed.k(), packed.m()), (1, 2));
    }

    #[test]
    fn scalar_oracle_matches_dispatched_serial_when_simd_off() {
        let (n, k, m) = (6usize, 9usize, 10usize);
        let x: Vec<f32> = (0..n * k).map(|i| (i as f32 * 0.7).sin()).collect();
        let w: Vec<f32> = (0..k * m).map(|i| (i as f32 * 0.3).cos()).collect();
        let b: Vec<f32> = (0..m).map(|i| i as f32 * 0.05).collect();
        let packed = PackedGemm::pack(&w, k, m);
        let mut scalar = vec![0f32; n * m];
        packed.matmul_bias_scalar(&x, n, &b, 4, &mut scalar);
        close(&scalar, &matmul_bias_ref(&x, n, k, &w, m, &b), 1e-5);
        if !super::super::simd_active() {
            let exec =
                KernelExec::new(KernelConfig { threads: 1, kc: 4, ..KernelConfig::default() });
            let mut out = vec![0f32; n * m];
            packed.matmul_bias(&x, n, &b, &exec, &mut out);
            assert_eq!(scalar, out, "scalar oracle must BE the serial path with simd off");
        }
    }

    /// Quantization error per weight is ≤ s_c/2, so per output element the
    /// int8 path may drift from f32 by at most `0.5 · s_c · Σ|x_row|` (plus
    /// f32 accumulation noise). Assert that analytic bound on ragged shapes.
    #[test]
    fn int8_tracks_f32_within_quantization_error() {
        let (n, k, m) = (7usize, 13usize, 19usize);
        let x: Vec<f32> = (0..n * k).map(|i| ((i * 31 % 17) as f32 - 8.0) * 0.11).collect();
        let w: Vec<f32> = (0..k * m).map(|i| ((i * 43 % 29) as f32 - 14.0) * 0.07).collect();
        let b: Vec<f32> = (0..m).map(|i| (i as f32 - 9.0) * 0.02).collect();
        let exec = KernelExec::new(KernelConfig { threads: 1, kc: 5, mc: 3, ..KernelConfig::default() });
        let qt = PackedGemmI8::pack(&w, k, m);
        let mut qout = vec![0f32; n * m];
        qt.matmul_bias(&x, n, &b, &exec, &mut qout);
        let want = matmul_bias_ref(&x, n, k, &w, m, &b);
        for i in 0..n {
            let sum_abs: f32 = x[i * k..(i + 1) * k].iter().map(|v| v.abs()).sum();
            for c in 0..m {
                let bound = 0.5 * qt.scales[c] * sum_abs + 1e-4 * (1.0 + want[i * m + c].abs());
                let got = qout[i * m + c];
                let exp = want[i * m + c];
                assert!(
                    (got - exp).abs() <= bound,
                    "[{i},{c}] int8 {got} vs f32 {exp}, bound {bound}"
                );
            }
        }
    }

    /// With power-of-two per-channel scales and integer-multiple weights,
    /// quantization is lossless and rescaling commutes with f32 rounding —
    /// the int8 kernel must then be BIT-identical to the f32 kernel. A
    /// strong check on panel layout, padding, and writeback indexing.
    #[test]
    fn int8_power_of_two_scales_are_bit_exact() {
        let (n, k, m) = (6usize, 11usize, 13usize);
        const S: f32 = 1.0 / 128.0;
        let mut w = vec![0f32; k * m];
        for c in 0..m {
            for kk in 0..k {
                // Pin each column's maxabs to exactly 127·2⁻⁷ so the
                // computed scale is exactly 2⁻⁷.
                let q: i32 = if kk == 0 {
                    if c % 2 == 0 { 127 } else { -127 }
                } else {
                    (((kk * 7 + c * 3) % 255) as i32) - 127
                };
                w[kk * m + c] = q as f32 * S;
            }
        }
        let x: Vec<f32> = (0..n * k).map(|i| ((i * 23 % 13) as f32 - 6.0) * 0.4).collect();
        let b: Vec<f32> = (0..m).map(|i| i as f32 * 0.1).collect();
        for threads in [1usize, 3] {
            let exec = KernelExec::new(KernelConfig {
                threads,
                kc: 4,
                mc: 2,
                min_parallel_flops: 0,
                ..KernelConfig::default()
            });
            let ft = PackedGemm::pack(&w, k, m);
            let qt = PackedGemmI8::pack(&w, k, m);
            assert!(qt.scales[..m].iter().all(|&s| s == S), "scales must be exactly 2^-7");
            let mut fout = vec![0f32; n * m];
            let mut qout = vec![0f32; n * m];
            ft.matmul_bias_gelu(&x, n, &b, &exec, &mut fout);
            qt.matmul_bias_gelu(&x, n, &b, &exec, &mut qout);
            assert_eq!(fout, qout, "int8 must be bit-exact at threads={threads}");
        }
    }

    #[test]
    fn int8_pooled_matches_serial_bit_exactly() {
        let (n, k, m) = (14usize, 9usize, 17usize);
        let x: Vec<f32> = (0..n * k).map(|i| (i as f32 * 0.9).sin()).collect();
        let w: Vec<f32> = (0..k * m).map(|i| (i as f32 * 0.2).cos()).collect();
        let b = vec![0.5f32; m];
        let qt = PackedGemmI8::pack(&w, k, m);
        let mut serial = vec![0f32; n * m];
        let exec1 =
            KernelExec::new(KernelConfig { threads: 1, kc: 4, mc: 3, ..KernelConfig::default() });
        qt.matmul_bias(&x, n, &b, &exec1, &mut serial);
        for threads in [2usize, 5] {
            let exec = KernelExec::new(KernelConfig {
                threads,
                kc: 4,
                mc: 3,
                min_parallel_flops: 0,
                ..KernelConfig::default()
            });
            let mut pooled = vec![0f32; n * m];
            qt.matmul_bias(&x, n, &b, &exec, &mut pooled);
            assert_eq!(serial, pooled, "int8 pooled differs at threads={threads}");
        }
    }

    #[test]
    fn packed_linear_dispatches_by_precision() {
        let (k, m) = (5usize, 9usize);
        let w: Vec<f32> = (0..k * m).map(|i| (i as f32 - 20.0) * 0.03).collect();
        let f = PackedLinear::pack(&w, k, m, Precision::F32);
        let q = PackedLinear::pack(&w, k, m, Precision::Int8);
        assert_eq!(f.precision(), Precision::F32);
        assert_eq!(q.precision(), Precision::Int8);
        assert_eq!((f.k(), f.m()), (k, m));
        assert_eq!((q.k(), q.m()), (k, m));
        // Int8 panels are ~4x smaller (1 byte/weight + f32 scales).
        assert!(q.panel_bytes() < f.panel_bytes());
        let x: Vec<f32> = (0..2 * k).map(|i| i as f32 * 0.1).collect();
        let b = vec![0.0f32; m];
        let exec = KernelExec::default();
        let (mut fo, mut qo) = (vec![0f32; 2 * m], vec![0f32; 2 * m]);
        f.matmul_bias(&x, 2, &b, &exec, &mut fo);
        q.matmul_bias(&x, 2, &b, &exec, &mut qo);
        close(&qo, &fo, 1e-2);
    }

    /// SIMD-vs-scalar contract (compiled only with `--features simd`;
    /// skips gracefully on hardware without AVX2+FMA).
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    mod simd_tests {
        use super::*;

        #[test]
        fn simd_matches_scalar_oracle_on_ragged_shapes() {
            if !crate::runtime::kernels::simd_active() {
                return;
            }
            // Includes shapes with remainder rows (n % MR != 0) and a
            // ragged last panel (m % NR != 0).
            for (n, k, m) in [(1usize, 8usize, 8usize), (5, 7, 11), (13, 33, 24), (4, 16, 30)] {
                let x: Vec<f32> = (0..n * k).map(|i| (i as f32 * 0.37).sin()).collect();
                let w: Vec<f32> = (0..k * m).map(|i| (i as f32 * 0.19).cos()).collect();
                let b: Vec<f32> = (0..m).map(|i| (i as f32 - 4.0) * 0.1).collect();
                let packed = PackedGemm::pack(&w, k, m);
                let mut scalar = vec![0f32; n * m];
                packed.matmul_bias_scalar(&x, n, &b, 5, &mut scalar);
                let exec = KernelExec::new(KernelConfig {
                    threads: 1,
                    kc: 5,
                    ..KernelConfig::default()
                });
                let mut simd = vec![0f32; n * m];
                packed.matmul_bias(&x, n, &b, &exec, &mut simd);
                close(&simd, &scalar, 1e-5);
            }
        }

        #[test]
        fn simd_epilogues_match_scalar_within_tolerance() {
            if !crate::runtime::kernels::simd_active() {
                return;
            }
            let (n, k, m) = (6usize, 10usize, 16usize);
            let x: Vec<f32> = (0..n * k).map(|i| ((i % 9) as f32 - 4.0) * 0.25).collect();
            let w: Vec<f32> = (0..k * m).map(|i| ((i % 7) as f32 - 3.0) * 0.15).collect();
            let b = vec![0.2f32; m];
            let packed = PackedGemm::pack(&w, k, m);
            let exec = KernelExec::default();
            let plain = matmul_bias_ref(&x, n, k, &w, m, &b);
            let mut out = vec![0f32; n * m];
            packed.matmul_bias_gelu(&x, n, &b, &exec, &mut out);
            close(&out, &plain.iter().map(|&v| gelu(v)).collect::<Vec<_>>(), 1e-5);
            packed.matmul_bias_tanh(&x, n, &b, &exec, &mut out);
            close(&out, &plain.iter().map(|v| v.tanh()).collect::<Vec<_>>(), 1e-5);
        }

        #[test]
        fn simd_transcendentals_track_libm() {
            if !crate::runtime::kernels::simd_active() {
                return;
            }
            use std::arch::x86_64::*;
            let xs: Vec<f32> = (-400..400).map(|i| i as f32 * 0.025).collect();
            for chunk in xs.chunks_exact(8) {
                // SAFETY: simd_active() checked avx2+fma above.
                unsafe {
                    let v = _mm256_loadu_ps(chunk.as_ptr());
                    let mut got = [0f32; 8];
                    _mm256_storeu_ps(got.as_mut_ptr(), simd::exp_ps(v));
                    for (g, &x) in got.iter().zip(chunk) {
                        let want = x.exp();
                        assert!((g - want).abs() <= 1e-5 * (1.0 + want.abs()), "exp({x})");
                    }
                    _mm256_storeu_ps(got.as_mut_ptr(), simd::tanh_ps(v));
                    for (g, &x) in got.iter().zip(chunk) {
                        let want = x.tanh();
                        assert!((g - want).abs() <= 1e-5 * (1.0 + want.abs()), "tanh({x})");
                    }
                    _mm256_storeu_ps(got.as_mut_ptr(), simd::gelu_ps(v));
                    for (g, &x) in got.iter().zip(chunk) {
                        let want = gelu(x);
                        assert!((g - want).abs() <= 1e-5 * (1.0 + want.abs()), "gelu({x})");
                    }
                }
            }
        }

        #[test]
        fn simd_is_thread_deterministic() {
            if !crate::runtime::kernels::simd_active() {
                return;
            }
            let (n, k, m) = (21usize, 12usize, 18usize);
            let x: Vec<f32> = (0..n * k).map(|i| (i as f32 * 0.51).sin()).collect();
            let w: Vec<f32> = (0..k * m).map(|i| (i as f32 * 0.13).cos()).collect();
            let b = vec![0.1f32; m];
            let packed = PackedGemm::pack(&w, k, m);
            let mut serial = vec![0f32; n * m];
            let exec1 = KernelExec::new(KernelConfig {
                threads: 1,
                kc: 4,
                mc: 2,
                ..KernelConfig::default()
            });
            packed.matmul_bias(&x, n, &b, &exec1, &mut serial);
            for threads in [2usize, 4, 7] {
                let exec = KernelExec::new(KernelConfig {
                    threads,
                    kc: 4,
                    mc: 2,
                    min_parallel_flops: 0,
                    ..KernelConfig::default()
                });
                let mut pooled = vec![0f32; n * m];
                packed.matmul_bias(&x, n, &b, &exec, &mut pooled);
                assert_eq!(serial, pooled, "simd pooled differs at threads={threads}");
            }
        }
    }
}
