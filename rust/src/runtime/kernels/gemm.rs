//! Blocked, weight-pretransposed `x @ w + bias` — the kernel behind every
//! projection in the native forward pass (QKV, attention output, both FFN
//! halves, pooler, classifier head).
//!
//! # Shape contract
//!
//! `x` is row-major `[n, k]`, the weight is row-major `[k, m]` at pack
//! time, `bias` is `[m]`, `out` is row-major `[n, m]` and fully
//! overwritten. `n` varies per call (it is `batch * surviving
//! word-vectors`, so elimination shrinks it layer by layer); `k`/`m` are
//! fixed per weight and validated on every call.
//!
//! # Why blocked + packed
//!
//! The naive loop ([`matmul_bias_ref`]) walks `w` row-major and
//! read-modify-writes the whole `out` row once per `k` step — `O(k · m)`
//! memory traffic per row of `x` against registers doing one multiply per
//! load. This kernel restructures the loop nest three ways:
//!
//! * **Pack once, at load time**: the weight is repacked into column
//!   panels of [`NR`] — `panel[p][kk*NR + j] = w[kk, p*NR + j]` — so the
//!   inner loop streams the panel contiguously regardless of `m`, and the
//!   transpose cost is paid once per model load, not per call.
//! * **Register tiling**: an [`MR`]`×`[`NR`] accumulator tile lives in
//!   registers across the whole depth loop; `out` is touched exactly once
//!   per `kc` block instead of once per `k` step.
//! * **Depth blocking** ([`KernelConfig::kc`]): the panel slab reused
//!   across every row tile is bounded to stay L1-resident when `k` is
//!   large (BERT-base FFN: `k = 3072`).
//!
//! Epilogues (bias, GELU, tanh) are fused into the tile writeback, so the
//! FFN's activation never materializes a separate pre-activation pass.
//!
//! Accumulation order is `k`-ascending within a block and blocks ascending
//! — the same order for every thread count (rows are data-parallel), so
//! results are deterministic under [`KernelConfig::threads`].
//!
//! Parallel calls dispatch the same fixed-order row-chunk task list to the
//! engine worker's persistent [`pool::KernelPool`](super::pool::KernelPool)
//! instead of spawning scoped threads per invocation; the old scoped path
//! is kept as [`PackedGemm::matmul_bias_scoped`] — the bench's old-vs-new
//! dispatch baseline and the property tests' bit-exactness oracle.

use super::pool::Shards;
use super::{gelu, task_ranges, KernelConfig, KernelExec};

/// Rows of `x` per register tile.
pub const MR: usize = 4;
/// Columns of `w` per packed panel (and per register tile).
pub const NR: usize = 8;

/// What the tile writeback applies after adding the bias.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Epilogue {
    /// `out = x @ w + bias`
    None,
    /// `out = gelu(x @ w + bias)` — the FFN's fused activation.
    Gelu,
    /// `out = tanh(x @ w + bias)` — the pooler's fused activation.
    Tanh,
}

/// A weight matrix packed for the blocked kernel: column panels of [`NR`],
/// built once at model-load time (see module docs for the layout).
pub struct PackedGemm {
    k: usize,
    m: usize,
    /// `ceil(m / NR)` panels of `k * NR` floats each; the last panel is
    /// zero-padded past column `m`, so ragged widths run the full-speed
    /// tile and the writeback simply drops the padding columns.
    panels: Vec<f32>,
}

impl PackedGemm {
    /// Pack a row-major `[k, m]` weight. Panics if `w.len() != k * m`.
    pub fn pack(w: &[f32], k: usize, m: usize) -> PackedGemm {
        assert_eq!(w.len(), k * m, "pack: weight is not [k={k}, m={m}]");
        let np = m.div_ceil(NR);
        let mut panels = vec![0f32; np * k * NR];
        for p in 0..np {
            let cols = (m - p * NR).min(NR);
            let panel = &mut panels[p * k * NR..(p + 1) * k * NR];
            for kk in 0..k {
                let src = &w[kk * m + p * NR..kk * m + p * NR + cols];
                panel[kk * NR..kk * NR + cols].copy_from_slice(src);
            }
        }
        PackedGemm { k, m, panels }
    }

    /// Input width (`k`) this weight contracts over.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Output width (`m`).
    pub fn m(&self) -> usize {
        self.m
    }

    /// `out = x @ w + bias` over `n` rows.
    pub fn matmul_bias(
        &self,
        x: &[f32],
        n: usize,
        bias: &[f32],
        exec: &KernelExec,
        out: &mut [f32],
    ) {
        self.run(x, n, bias, exec, Epilogue::None, out);
    }

    /// `out = gelu(x @ w + bias)` — fused FFN half.
    pub fn matmul_bias_gelu(
        &self,
        x: &[f32],
        n: usize,
        bias: &[f32],
        exec: &KernelExec,
        out: &mut [f32],
    ) {
        self.run(x, n, bias, exec, Epilogue::Gelu, out);
    }

    /// `out = tanh(x @ w + bias)` — fused pooler.
    pub fn matmul_bias_tanh(
        &self,
        x: &[f32],
        n: usize,
        bias: &[f32],
        exec: &KernelExec,
        out: &mut [f32],
    ) {
        self.run(x, n, bias, exec, Epilogue::Tanh, out);
    }

    fn run(
        &self,
        x: &[f32],
        n: usize,
        bias: &[f32],
        exec: &KernelExec,
        ep: Epilogue,
        out: &mut [f32],
    ) {
        let (k, m) = (self.k, self.m);
        assert_eq!(x.len(), n * k, "matmul: x is not [n={n}, k={k}]");
        assert_eq!(bias.len(), m, "matmul: bias is not [m={m}]");
        assert_eq!(out.len(), n * m, "matmul: out is not [n={n}, m={m}]");
        if n == 0 {
            return;
        }
        let cfg = exec.config();
        // Parallel split over rows: each lane owns contiguous row ranges
        // of x and out, at mc-row task granularity. Row results never
        // depend on the split, so any thread count is deterministic.
        let mc = cfg.mc.max(1);
        let tasks = n.div_ceil(mc);
        let threads = exec.threads_for(tasks);
        if threads <= 1 {
            // Serial fast path — the serving default; untouched by the
            // pool machinery.
            self.rows(x, n, bias, cfg.kc, ep, out);
            return;
        }
        // The same fixed-order row-chunk list the scoped path built via
        // `task_ranges`, expressed in closed form so dispatch allocates
        // nothing: chunk t covers mc-tasks [t*per, (t+1)*per).
        let per = tasks.div_ceil(threads);
        let chunks = tasks.div_ceil(per);
        let out_shards = Shards::new(out);
        exec.pool().run(chunks, &|t| {
            let row0 = t * per * mc;
            let rows = ((t + 1) * per * mc).min(n) - row0;
            // SAFETY: chunk ranges [row0*m, (row0+rows)*m) partition `out`
            // pairwise-disjointly by construction.
            let chunk = unsafe { out_shards.slice(row0 * m, rows * m) };
            self.rows(&x[row0 * k..(row0 + rows) * k], rows, bias, cfg.kc, ep, chunk);
        });
    }

    /// The pre-pool parallel driver: scoped threads spawned per call over
    /// the identical row-chunk list (bias epilogue only). Kept as the
    /// dispatch-cost baseline for `benches/native.rs` and the bit-exactness
    /// oracle for `tests/prop_kernels.rs` — results must equal
    /// [`PackedGemm::matmul_bias`] bit-for-bit at any thread count.
    pub fn matmul_bias_scoped(
        &self,
        x: &[f32],
        n: usize,
        bias: &[f32],
        cfg: &KernelConfig,
        out: &mut [f32],
    ) {
        let (k, m) = (self.k, self.m);
        assert_eq!(x.len(), n * k, "matmul: x is not [n={n}, k={k}]");
        assert_eq!(bias.len(), m, "matmul: bias is not [m={m}]");
        assert_eq!(out.len(), n * m, "matmul: out is not [n={n}, m={m}]");
        if n == 0 {
            return;
        }
        let mc = cfg.mc.max(1);
        let tasks = n.div_ceil(mc);
        let threads = cfg.effective_threads(tasks);
        if threads <= 1 {
            self.rows(x, n, bias, cfg.kc, Epilogue::None, out);
            return;
        }
        let ranges = task_ranges(tasks, threads);
        super::note_spawns(ranges.len() as u64);
        let ep = Epilogue::None;
        std::thread::scope(|s| {
            let mut rest = out;
            let mut handles = Vec::with_capacity(ranges.len());
            for r in &ranges {
                let row0 = r.start * mc;
                let rows = (r.end * mc).min(n) - row0;
                let (chunk, tail) = std::mem::take(&mut rest).split_at_mut(rows * m);
                rest = tail;
                let xs = &x[row0 * k..(row0 + rows) * k];
                handles.push(s.spawn(move || self.rows(xs, rows, bias, cfg.kc, ep, chunk)));
            }
            // Propagate panics out of the scope deterministically.
            for h in handles {
                h.join().expect("gemm worker panicked");
            }
        });
    }

    /// Serial blocked kernel over a contiguous row range.
    fn rows(&self, x: &[f32], n: usize, bias: &[f32], kc: usize, ep: Epilogue, out: &mut [f32]) {
        let (k, m) = (self.k, self.m);
        let kc = kc.max(1);
        let np = m.div_ceil(NR);
        let mut kb = 0;
        while kb < k {
            let kb_end = (kb + kc).min(k);
            let first = kb == 0;
            let last = kb_end == k;
            let mut rb = 0;
            while rb < n {
                let rm = (n - rb).min(MR);
                for p in 0..np {
                    let panel = &self.panels[p * k * NR + kb * NR..p * k * NR + kb_end * NR];
                    let mut acc = [[0f32; NR]; MR];
                    if rm == MR {
                        // Full tile: fixed-trip loops so the accumulators
                        // stay in registers and the NR loop vectorizes.
                        for (kk, wrow) in panel.chunks_exact(NR).enumerate() {
                            let kabs = kb + kk;
                            for (r, accr) in acc.iter_mut().enumerate() {
                                let xv = x[(rb + r) * k + kabs];
                                for c in 0..NR {
                                    accr[c] += xv * wrow[c];
                                }
                            }
                        }
                    } else {
                        for (kk, wrow) in panel.chunks_exact(NR).enumerate() {
                            let kabs = kb + kk;
                            for (r, accr) in acc.iter_mut().enumerate().take(rm) {
                                let xv = x[(rb + r) * k + kabs];
                                for c in 0..NR {
                                    accr[c] += xv * wrow[c];
                                }
                            }
                        }
                    }
                    let cols = (m - p * NR).min(NR);
                    for (r, accr) in acc.iter().enumerate().take(rm) {
                        let orow = &mut out[(rb + r) * m + p * NR..(rb + r) * m + p * NR + cols];
                        for (c, o) in orow.iter_mut().enumerate() {
                            let mut v = accr[c] + if first { bias[p * NR + c] } else { *o };
                            if last {
                                v = match ep {
                                    Epilogue::None => v,
                                    Epilogue::Gelu => gelu(v),
                                    Epilogue::Tanh => v.tanh(),
                                };
                            }
                            *o = v;
                        }
                    }
                }
                rb += rm;
            }
            kb = kb_end;
        }
    }
}

/// The naive reference `x [n, k] @ w [k, m] + b [m]` (row-major) — the
/// pre-kernel implementation, kept as the correctness oracle for the
/// property tests and the "old" side of the bench's old-vs-new table.
pub fn matmul_bias_ref(x: &[f32], n: usize, k: usize, w: &[f32], m: usize, b: &[f32]) -> Vec<f32> {
    let mut out = vec![0f32; n * m];
    for i in 0..n {
        let xrow = &x[i * k..(i + 1) * k];
        let orow = &mut out[i * m..(i + 1) * m];
        orow.copy_from_slice(b);
        for (kk, &xv) in xrow.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let wrow = &w[kk * m..(kk + 1) * m];
            for (c, &wv) in wrow.iter().enumerate() {
                orow[c] += xv * wv;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: &[f32], b: &[f32], tol: f32) {
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert!((x - y).abs() <= tol * (1.0 + y.abs()), "[{i}] {x} vs {y}");
        }
    }

    #[test]
    fn matches_reference_on_identity() {
        // [1,2;3,4] @ I + [10, 20]
        let x = vec![1.0, 2.0, 3.0, 4.0];
        let w = vec![1.0, 0.0, 0.0, 1.0];
        let b = vec![10.0, 20.0];
        let packed = PackedGemm::pack(&w, 2, 2);
        let mut out = vec![0f32; 4];
        packed.matmul_bias(&x, 2, &b, &KernelExec::default(), &mut out);
        assert_eq!(out, vec![11.0, 22.0, 13.0, 24.0]);
        assert_eq!(out, matmul_bias_ref(&x, 2, 2, &w, 2, &b));
    }

    #[test]
    fn ragged_shapes_match_reference() {
        // Deliberately not multiples of MR/NR, with kc forcing two blocks.
        let (n, k, m) = (5usize, 7usize, 11usize);
        let x: Vec<f32> = (0..n * k).map(|i| ((i * 37 % 19) as f32 - 9.0) * 0.1).collect();
        let w: Vec<f32> = (0..k * m).map(|i| ((i * 53 % 23) as f32 - 11.0) * 0.05).collect();
        let b: Vec<f32> = (0..m).map(|i| i as f32 * 0.01).collect();
        let exec = KernelExec::new(KernelConfig { threads: 1, kc: 3, mc: 2 });
        let packed = PackedGemm::pack(&w, k, m);
        let mut out = vec![0f32; n * m];
        packed.matmul_bias(&x, n, &b, &exec, &mut out);
        close(&out, &matmul_bias_ref(&x, n, k, &w, m, &b), 1e-6);
    }

    #[test]
    fn pooled_and_scoped_threads_are_bit_identical() {
        let (n, k, m) = (13usize, 9usize, 17usize);
        let x: Vec<f32> = (0..n * k).map(|i| (i as f32).sin()).collect();
        let w: Vec<f32> = (0..k * m).map(|i| (i as f32).cos()).collect();
        let b = vec![0.25f32; m];
        let packed = PackedGemm::pack(&w, k, m);
        let mut serial = vec![0f32; n * m];
        let serial_exec = KernelExec::new(KernelConfig { threads: 1, kc: 4, mc: 3 });
        packed.matmul_bias(&x, n, &b, &serial_exec, &mut serial);
        for threads in [2usize, 4, 7] {
            let cfg = KernelConfig { threads, kc: 4, mc: 3 };
            let mut pooled = vec![0f32; n * m];
            packed.matmul_bias(&x, n, &b, &KernelExec::new(cfg.clone()), &mut pooled);
            assert_eq!(serial, pooled, "pooled differs at threads={threads}");
            let mut scoped = vec![0f32; n * m];
            packed.matmul_bias_scoped(&x, n, &b, &cfg, &mut scoped);
            assert_eq!(serial, scoped, "scoped differs at threads={threads}");
        }
    }

    #[test]
    fn fused_epilogues_match_mapped_reference() {
        let (n, k, m) = (3usize, 6usize, 10usize);
        let x: Vec<f32> = (0..n * k).map(|i| ((i % 7) as f32 - 3.0) * 0.3).collect();
        let w: Vec<f32> = (0..k * m).map(|i| ((i % 5) as f32 - 2.0) * 0.2).collect();
        let b = vec![0.1f32; m];
        let exec = KernelExec::default();
        let packed = PackedGemm::pack(&w, k, m);
        let plain = matmul_bias_ref(&x, n, k, &w, m, &b);
        let mut out = vec![0f32; n * m];
        packed.matmul_bias_gelu(&x, n, &b, &exec, &mut out);
        close(&out, &plain.iter().map(|&v| gelu(v)).collect::<Vec<_>>(), 1e-6);
        packed.matmul_bias_tanh(&x, n, &b, &exec, &mut out);
        close(&out, &plain.iter().map(|v| v.tanh()).collect::<Vec<_>>(), 1e-6);
    }

    #[test]
    fn degenerate_blocks_are_clamped_not_zero_output() {
        // mc = 0 / kc = 0 must clamp to 1, not silently leave `out` all
        // zeros (every parallel range would otherwise cover zero rows).
        let (n, k, m) = (9usize, 5usize, 6usize);
        let x: Vec<f32> = (0..n * k).map(|i| (i as f32).sin()).collect();
        let w: Vec<f32> = (0..k * m).map(|i| (i as f32).cos()).collect();
        let b = vec![1.0f32; m];
        let packed = PackedGemm::pack(&w, k, m);
        let want = matmul_bias_ref(&x, n, k, &w, m, &b);
        for cfg in [
            KernelConfig { threads: 4, kc: 256, mc: 0 },
            KernelConfig { threads: 1, kc: 0, mc: 0 },
        ] {
            let mut out = vec![0f32; n * m];
            packed.matmul_bias(&x, n, &b, &KernelExec::new(cfg), &mut out);
            close(&out, &want, 1e-6);
        }
    }

    #[test]
    fn zero_rows_is_a_no_op() {
        let packed = PackedGemm::pack(&[1.0, 2.0], 1, 2);
        let mut out = vec![];
        packed.matmul_bias(&[], 0, &[0.0, 0.0], &KernelExec::default(), &mut out);
        assert!(out.is_empty());
        assert_eq!((packed.k(), packed.m()), (1, 2));
    }
}
