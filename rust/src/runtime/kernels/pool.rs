//! Persistent kernel worker pool: the steady-state replacement for
//! per-call scoped thread spawns.
//!
//! `--kernel-threads > 1` used to spawn OS threads on **every** kernel
//! invocation. The spawn + join cost (~tens of µs per thread) is invariant
//! to elimination, so on the small `(batch, seq)` buckets the coordinator
//! produces it could exceed the arithmetic it parallelized — exactly the
//! regime PoWER-BERT shrinks layers into. A [`KernelPool`] spawns its
//! workers **once**, when its owning [`KernelExec`](super::KernelExec) is
//! created (at [`EngineWorker`](crate::runtime::EngineWorker) creation
//! for native workers), and parks them on a condvar between jobs, with a
//! short spin phase so back-to-back kernel calls hand off fast.
//!
//! # Execution model
//!
//! [`KernelPool::run`]`(tasks, f)` executes `f(0), f(1), …, f(tasks - 1)`
//! exactly once each and returns when all are done. The calling thread is
//! lane 0 and participates; parked workers claim task indices from a
//! shared atomic counter. Kernels submit the **same fixed-order task lists
//! the scoped-thread paths use** — contiguous row chunks for the GEMM,
//! `(batch row, head)` ranges for attention — and every task writes a
//! disjoint output range, so results are bit-identical whichever lane runs
//! which task (and identical to the scoped and serial paths; the property
//! tests in `tests/prop_kernels.rs` pin all three against each other).
//!
//! # Lifecycle and shutdown ordering
//!
//! The pool lives inside a [`KernelExec`](super::KernelExec) owned by the
//! worker's `NativeBackend` and shared (via `Arc`) with every
//! [`NativeModel`](crate::runtime::native::NativeModel) it loads. On
//! coordinator drain the executor queues close first, each worker finishes
//! its backlog, and the pool's threads are joined by [`Drop`] when the
//! last model holding the `Arc` goes away — so no kernel can ever observe
//! a dead pool.
//!
//! # Examples
//!
//! ```
//! use powerbert::runtime::kernels::pool::KernelPool;
//! use std::sync::atomic::{AtomicU64, Ordering};
//!
//! let pool = KernelPool::new(2); // caller lane + 1 parked worker
//! let hits: Vec<AtomicU64> = (0..8).map(|_| AtomicU64::new(0)).collect();
//! pool.run(8, &|i| {
//!     hits[i].fetch_add(1, Ordering::Relaxed);
//! });
//! assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
//! ```

use std::any::Any;
use std::marker::PhantomData;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Iterations a parked worker spins watching for a new job before it
/// blocks on the condvar, and the caller spins waiting for stragglers
/// before it blocks. Sized for "another kernel call is coming right
/// behind this one" — the steady serving state — while still parking
/// within a few tens of microseconds when the pool goes idle.
const SPIN: u32 = 4_096;

/// One published job: a type-erased borrow of the caller's task closure.
///
/// The `'static` here is a lie told to the type system only: `run` does
/// not return until every lane has finished with the job and the slot is
/// cleared, so the reference never outlives the frame that owns the
/// closure (same containment argument as `std::thread::scope`).
#[derive(Clone, Copy)]
struct Job {
    task: &'static (dyn Fn(usize) + Sync),
    tasks: usize,
}

struct State {
    job: Option<Job>,
    shutdown: bool,
}

struct Shared {
    state: Mutex<State>,
    /// Wakes parked workers (new job or shutdown).
    work: Condvar,
    /// Wakes a caller waiting for straggler lanes.
    done: Condvar,
    /// Bumped (under the state lock) for every published job and at
    /// shutdown; workers spin on it before parking.
    epoch: AtomicU64,
    /// Next unclaimed task index of the current job.
    next: AtomicUsize,
    /// Tasks of the current job not yet completed.
    pending: AtomicUsize,
    /// Pool workers currently inside a job's claim loop.
    active: AtomicUsize,
    /// Cumulative parallel jobs dispatched (stats; serial fast-path runs
    /// are not counted — they never touch the pool machinery).
    jobs: AtomicU64,
    /// Cumulative tasks executed across all lanes (stats).
    tasks_done: AtomicU64,
    /// A task of the current job panicked: remaining tasks are skipped
    /// (still drained through `pending`) and the caller re-raises after
    /// the job is fully retired — so an unwinding task can neither wedge
    /// the pool nor leave the erased closure borrow published.
    job_panicked: AtomicBool,
    /// First panic payload of the current job, re-thrown by the caller.
    panic_payload: Mutex<Option<Box<dyn Any + Send + 'static>>>,
}

impl Shared {
    /// Run task `i`, catching an unwind: every claimed task must retire
    /// through `pending` exactly once — the invariant both the caller's
    /// completion wait and the closure's borrow containment rest on —
    /// so panics are parked and re-raised by the caller, never unwound
    /// through the claim loop.
    fn run_task(&self, task: &(dyn Fn(usize) + Sync), i: usize) {
        if !self.job_panicked.load(Ordering::Relaxed) {
            if let Err(payload) = catch_unwind(AssertUnwindSafe(|| task(i))) {
                self.job_panicked.store(true, Ordering::Relaxed);
                let mut slot = self.panic_payload.lock().unwrap();
                if slot.is_none() {
                    *slot = Some(payload);
                }
            }
        }
        self.tasks_done.fetch_add(1, Ordering::Relaxed);
        self.pending.fetch_sub(1, Ordering::Release);
    }
}

/// A fixed-size pool of parked kernel workers. See the module docs for
/// the execution model; construction spawns `threads - 1` OS threads (the
/// caller is always lane 0), `Drop` joins them.
pub struct KernelPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    size: usize,
    /// One job at a time: concurrent `run` calls (two models sharing one
    /// worker's pool) serialize at job granularity, which is what makes
    /// the next/pending counters single-job state.
    run_lock: Mutex<()>,
}

impl KernelPool {
    /// Pool with `threads` lanes total (clamped to at least 1). `threads
    /// - 1` workers are spawned and parked; lane 0 is whoever calls
    /// [`KernelPool::run`].
    pub fn new(threads: usize) -> KernelPool {
        let size = threads.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(State { job: None, shutdown: false }),
            work: Condvar::new(),
            done: Condvar::new(),
            epoch: AtomicU64::new(0),
            next: AtomicUsize::new(0),
            pending: AtomicUsize::new(0),
            active: AtomicUsize::new(0),
            jobs: AtomicU64::new(0),
            tasks_done: AtomicU64::new(0),
            job_panicked: AtomicBool::new(false),
            panic_payload: Mutex::new(None),
        });
        let mut workers = Vec::with_capacity(size - 1);
        for i in 1..size {
            let sh = shared.clone();
            let handle = std::thread::Builder::new()
                .name(format!("pb-kernel-{i}"))
                .spawn(move || worker_loop(&sh))
                .expect("spawn kernel pool worker");
            workers.push(handle);
        }
        super::note_spawns(workers.len() as u64);
        KernelPool { shared, workers, size, run_lock: Mutex::new(()) }
    }

    /// Lanes including the calling thread.
    pub fn size(&self) -> usize {
        self.size
    }

    /// Parallel jobs dispatched since construction (stats).
    pub fn jobs(&self) -> u64 {
        self.shared.jobs.load(Ordering::Relaxed)
    }

    /// Tasks executed since construction, across all lanes (stats).
    pub fn tasks_done(&self) -> u64 {
        self.shared.tasks_done.load(Ordering::Relaxed)
    }

    /// Execute `f(0) .. f(tasks - 1)`, each exactly once, across the
    /// caller and the parked workers; returns when every task completed.
    /// Tasks must be safe to run concurrently (in the kernels: each task
    /// writes a disjoint output range). With no pool workers (`size` 1)
    /// or a single task this degenerates to a serial loop on the caller.
    ///
    /// # Panics
    ///
    /// If a task panics — on any lane — remaining tasks are skipped, the
    /// job is still fully retired (so the pool stays healthy and the
    /// closure borrow stays contained), and the first panic payload is
    /// re-raised here on the caller, mirroring `std::thread::scope`.
    pub fn run(&self, tasks: usize, f: &(dyn Fn(usize) + Sync)) {
        if tasks == 0 {
            return;
        }
        if self.workers.is_empty() || tasks == 1 {
            for i in 0..tasks {
                f(i);
            }
            self.shared.tasks_done.fetch_add(tasks as u64, Ordering::Relaxed);
            return;
        }
        let _job_guard = self.run_lock.lock().unwrap();
        // SAFETY: lifetime erasure only — the reference is dereferenced
        // exclusively between the publish below and the job-slot clear at
        // the bottom of this function, and we do not return until
        // `pending` and `active` are both zero with the slot cleared
        // under the lock. Task panics cannot break the containment:
        // every lane runs tasks through `Shared::run_task`, which catches
        // unwinds and always retires the claim, and the caller's own
        // claim loop cannot unwind before the completion wait.
        #[allow(clippy::useless_transmute, clippy::missing_transmute_annotations)]
        let task: &'static (dyn Fn(usize) + Sync) =
            unsafe { std::mem::transmute::<&(dyn Fn(usize) + Sync), _>(f) };
        self.shared.job_panicked.store(false, Ordering::Relaxed);
        *self.shared.panic_payload.lock().unwrap() = None;
        self.shared.next.store(0, Ordering::Relaxed);
        self.shared.pending.store(tasks, Ordering::Relaxed);
        self.shared.jobs.fetch_add(1, Ordering::Relaxed);
        {
            let mut st = self.shared.state.lock().unwrap();
            st.job = Some(Job { task, tasks });
            self.shared.epoch.fetch_add(1, Ordering::Release);
        }
        self.shared.work.notify_all();

        // Lane 0: claim and run tasks like any worker.
        loop {
            let i = self.shared.next.fetch_add(1, Ordering::Relaxed);
            if i >= tasks {
                break;
            }
            self.shared.run_task(f, i);
        }

        // Wait for straggler lanes: spin first (tasks are typically tens
        // of microseconds), then park on `done`. The final re-check runs
        // under the state lock, which also serializes against late worker
        // pick-ups (workers gate on `pending > 0` under the same lock),
        // so the job slot is never cleared while a lane can still claim.
        let finished = || {
            self.shared.pending.load(Ordering::Acquire) == 0
                && self.shared.active.load(Ordering::Acquire) == 0
        };
        let mut spins = 0u32;
        while !finished() && spins < SPIN {
            std::hint::spin_loop();
            spins += 1;
        }
        {
            let mut st = self.shared.state.lock().unwrap();
            while !finished() {
                st = self.shared.done.wait(st).unwrap();
            }
            st.job = None;
        }
        // The job is fully retired and the slot cleared; now (and only
        // now) a task panic may propagate to the caller. Release the job
        // lock *before* unwinding — dropping it mid-panic would poison
        // the mutex and wedge every later `run` (the state is clean: the
        // next job fully re-initializes the counters and slots).
        if self.shared.job_panicked.load(Ordering::Relaxed) {
            let payload = self.shared.panic_payload.lock().unwrap().take();
            if let Some(payload) = payload {
                drop(_job_guard);
                resume_unwind(payload);
            }
        }
    }
}

impl Drop for KernelPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.epoch.fetch_add(1, Ordering::Release);
        }
        self.shared.work.notify_all();
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &Shared) {
    let mut seen = 0u64;
    loop {
        // Spin briefly for a new epoch before parking: back-to-back
        // kernel calls (the steady serving state) hand off without a
        // futex round-trip.
        let mut spins = 0u32;
        while shared.epoch.load(Ordering::Acquire) == seen && spins < SPIN {
            std::hint::spin_loop();
            spins += 1;
        }
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                let e = shared.epoch.load(Ordering::Acquire);
                if e != seen {
                    seen = e;
                    // Only join jobs that still have unfinished work: once
                    // `pending` hits zero the caller may clear the slot
                    // and return, so joining a finished job (and touching
                    // its closure) would race the borrow it erases.
                    if let Some(j) = st.job {
                        if shared.pending.load(Ordering::Acquire) > 0 {
                            shared.active.fetch_add(1, Ordering::AcqRel);
                            break j;
                        }
                    }
                }
                st = shared.work.wait(st).unwrap();
            }
        };
        loop {
            let i = shared.next.fetch_add(1, Ordering::Relaxed);
            if i >= job.tasks {
                break;
            }
            // run_task catches task panics, so a worker always retires
            // its claims and survives to serve the next job.
            shared.run_task(job.task, i);
        }
        shared.active.fetch_sub(1, Ordering::AcqRel);
        let _st = shared.state.lock().unwrap();
        shared.done.notify_all();
    }
}

/// Shared-access view of a mutable slice for lanes writing **disjoint**
/// ranges: the pool hands every lane the same `Fn` closure, so the
/// closure cannot hold `&mut` state — disjointness is structural (task
/// index → fixed output range) and this wrapper carries the pointer
/// across the `Sync` boundary.
pub(crate) struct Shards<'a, T> {
    ptr: *mut T,
    len: usize,
    _marker: PhantomData<&'a mut [T]>,
}

unsafe impl<T: Send> Send for Shards<'_, T> {}
unsafe impl<T: Send> Sync for Shards<'_, T> {}

impl<'a, T> Shards<'a, T> {
    pub fn new(slice: &'a mut [T]) -> Shards<'a, T> {
        Shards { ptr: slice.as_mut_ptr(), len: slice.len(), _marker: PhantomData }
    }

    /// The sub-slice `[start, start + len)`.
    ///
    /// # Safety
    ///
    /// Concurrent callers must use pairwise-disjoint ranges; the range
    /// must lie within the original slice (checked, panics otherwise).
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice(&self, start: usize, len: usize) -> &mut [T] {
        assert!(
            start.checked_add(len).is_some_and(|end| end <= self.len),
            "shard [{start}, {start}+{len}) outside slab of {}",
            self.len
        );
        std::slice::from_raw_parts_mut(self.ptr.add(start), len)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn runs_every_task_exactly_once() {
        for threads in [1usize, 2, 4] {
            let pool = KernelPool::new(threads);
            for tasks in [0usize, 1, 3, 17, 64] {
                let hits: Vec<AtomicU64> = (0..tasks).map(|_| AtomicU64::new(0)).collect();
                pool.run(tasks, &|i| {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                });
                for (i, h) in hits.iter().enumerate() {
                    assert_eq!(h.load(Ordering::Relaxed), 1, "task {i} at {threads} threads");
                }
            }
        }
    }

    #[test]
    fn back_to_back_jobs_reuse_the_same_workers() {
        let pool = KernelPool::new(3);
        let total = AtomicU64::new(0);
        for _ in 0..50 {
            pool.run(8, &|_| {
                total.fetch_add(1, Ordering::Relaxed);
            });
        }
        assert_eq!(total.load(Ordering::Relaxed), 400);
        assert_eq!(pool.tasks_done(), 400);
        assert_eq!(pool.jobs(), 50);
        assert_eq!(pool.size(), 3);
    }

    #[test]
    fn disjoint_writes_land_in_order() {
        let pool = KernelPool::new(4);
        let mut out = vec![0u64; 257];
        let shards = Shards::new(&mut out[..]);
        pool.run(257, &|i| {
            let cell = unsafe { shards.slice(i, 1) };
            cell[0] = i as u64 * 3;
        });
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i as u64 * 3);
        }
    }

    #[test]
    fn serial_pool_runs_inline() {
        let pool = KernelPool::new(1);
        let mut out = vec![0u8; 5];
        let shards = Shards::new(&mut out[..]);
        pool.run(5, &|i| unsafe { shards.slice(i, 1)[0] = 1 });
        assert!(out.iter().all(|&v| v == 1));
        assert_eq!(pool.jobs(), 0, "inline runs never touch the pool machinery");
    }

    #[test]
    fn panicking_task_propagates_without_wedging_the_pool() {
        let pool = KernelPool::new(3);
        let caught = catch_unwind(AssertUnwindSafe(|| {
            pool.run(16, &|i| {
                if i == 3 {
                    panic!("task 3 exploded");
                }
            });
        }));
        let payload = caught.expect_err("task panic must propagate to the caller");
        let msg = payload.downcast_ref::<&str>().copied().unwrap_or("");
        assert!(msg.contains("task 3 exploded"), "wrong payload: {msg:?}");
        // The pool survives: workers retired their claims and serve the
        // next job normally.
        let hits: Vec<AtomicU64> = (0..8).map(|_| AtomicU64::new(0)).collect();
        pool.run(8, &|i| {
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    #[should_panic(expected = "outside slab")]
    fn shards_bounds_are_checked() {
        let mut out = vec![0u8; 4];
        let shards = Shards::new(&mut out[..]);
        unsafe {
            let _ = shards.slice(3, 2);
        }
    }
}
