//! Masked multi-head attention + attention-column significance, parallel
//! across `(batch row, head)` tasks.
//!
//! # Shape contract
//!
//! `q`/`k`/`v` are row-major `[batch * n, h]` (already projected; heads
//! live in `h = heads * d` interleaved column blocks, head `a` at columns
//! `[a*d, (a+1)*d)`). `mask` is `[batch * n]` with `1.0` for real tokens
//! and `0.0` for PAD. Outputs: `ctx` (`[batch * n, h]`, overwritten) and
//! `sig` (`[batch * n]`, overwritten) — `sig[b, j]` is the paper's §3.2
//! significance of word-vector `j` in example `b`: the softmax column sum
//! over all heads and non-PAD query rows, exactly what the extract layer
//! ranks by.
//!
//! # Parallel structure
//!
//! The natural unit is one `(example, head)` pair: its softmax rows and
//! its `[n, d]` slice of the context are independent of every other pair.
//! Under `threads > 1`, each task writes a private contiguous `ctx`/`sig`
//! slab, and a serial merge then interleaves the head slabs back into
//! `[n, h]` rows and sums significance **in ascending head order**. The
//! serial path (the serving default) skips the slabs and writes head
//! stripes in place, folding per-head significance partials in the same
//! ascending-head association — so results are bit-identical for any
//! [`KernelConfig::threads`].
//!
//! # Steady state
//!
//! Parallel tasks dispatch to the engine worker's persistent
//! [`pool::KernelPool`](super::pool::KernelPool) (not per-call scoped
//! threads), and every scratch buffer — the private head slabs and the
//! per-lane softmax rows — comes from a caller-provided [`AttnScratch`],
//! carved out of the forward pass's
//! [`ForwardArena`](crate::runtime::arena::ForwardArena). After warmup
//! the kernel allocates nothing. The pre-pool implementation survives as
//! [`masked_attention_scoped`]: the dispatch-cost baseline for
//! `benches/native.rs` and the bit-exactness oracle for
//! `tests/prop_kernels.rs`.
//!
//! # ISA dispatch
//!
//! With the `simd` feature on AVX2/FMA hardware, the per-`(example, head)`
//! task body swaps to an AVX2 variant of [`attend_one`]: the q·k score
//! dot and the context `p · v` accumulation run 8 lanes wide, while the
//! softmax max/exp/normalize row stays scalar — it is `O(n)` against the
//! two `O(n·d)` loops, and keeping it scalar keeps the probability mass
//! identical to the oracle's. Dispatch sits *inside* the task body (below
//! the serial/pooled/scoped split), so all three drivers remain
//! bit-identical to each other at any thread count, and the whole kernel
//! tracks the scalar oracle within the documented 1e-5.

use super::pool::Shards;
use super::{task_ranges, KernelConfig, KernelExec};

/// Additive mask for PAD key columns, matching `python/compile/kernels`.
const NEG_INF: f32 = -1e9;

/// Borrowed scratch for one [`masked_attention`] call, usually carved out
/// of the forward pass's arena (see
/// [`ForwardArena`](crate::runtime::arena::ForwardArena)); tests and
/// standalone callers can borrow one from an [`AttnScratchBuf`].
///
/// Capacity contract for a `(batch, n, heads, d)` call under `lanes`
/// pool lanes (asserted at the call):
/// * serial (`threads <= 1`): `sig_heads.len() >= n`, `probs.len() >= n`
///   (`ctx_heads` unused, may be empty);
/// * pooled: `ctx_heads.len() >= batch*heads*n*d`,
///   `sig_heads.len() >= batch*heads*n`, `probs.len() >= lanes*n`.
pub struct AttnScratch<'a> {
    /// Private per-task context slabs (`[n, d]` per `(example, head)`).
    pub ctx_heads: &'a mut [f32],
    /// Private per-task significance partials (serial path: the single
    /// per-head fold buffer).
    pub sig_heads: &'a mut [f32],
    /// Per-lane softmax row.
    pub probs: &'a mut [f32],
}

/// Owned backing store for an [`AttnScratch`] — the standalone-caller
/// (tests, benches) counterpart of the arena's carved regions.
pub struct AttnScratchBuf {
    ctx_heads: Vec<f32>,
    sig_heads: Vec<f32>,
    probs: Vec<f32>,
}

impl AttnScratchBuf {
    /// Buffers sized for a `(batch, n, heads, d)` call at up to `lanes`
    /// pool lanes (1 = serial).
    pub fn for_shape(batch: usize, n: usize, heads: usize, d: usize, lanes: usize) -> Self {
        AttnScratchBuf {
            ctx_heads: vec![0.0; batch * heads * n * d],
            sig_heads: vec![0.0; (batch * heads * n).max(n)],
            probs: vec![0.0; lanes.max(1) * n],
        }
    }

    pub fn scratch(&mut self) -> AttnScratch<'_> {
        AttnScratch {
            ctx_heads: &mut self.ctx_heads,
            sig_heads: &mut self.sig_heads,
            probs: &mut self.probs,
        }
    }
}

/// Scaled-dot-product attention with PAD masking over `batch` independent
/// examples of `n` word-vectors; accumulates the attention-column
/// significance scores alongside the context. See the module docs for the
/// shape contract and [`AttnScratch`] for the scratch contract.
#[allow(clippy::too_many_arguments)]
pub fn masked_attention(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    mask: &[f32],
    batch: usize,
    n: usize,
    heads: usize,
    d: usize,
    exec: &KernelExec,
    scratch: AttnScratch<'_>,
    ctx: &mut [f32],
    sig: &mut [f32],
) {
    let h = heads * d;
    let rows = batch * n;
    assert_eq!(q.len(), rows * h, "attention: q is not [batch*n, h]");
    assert_eq!(k.len(), rows * h, "attention: k is not [batch*n, h]");
    assert_eq!(v.len(), rows * h, "attention: v is not [batch*n, h]");
    assert_eq!(mask.len(), rows, "attention: mask is not [batch*n]");
    assert_eq!(ctx.len(), rows * h, "attention: ctx is not [batch*n, h]");
    assert_eq!(sig.len(), rows, "attention: sig is not [batch*n]");
    if rows == 0 {
        return;
    }

    let tasks = batch * heads;
    // Work-size dispatch, mirroring PackedGemm::run: small attention
    // shapes run serial rather than paying the pool handoff per head.
    let threads = exec.threads_for_work(tasks, super::attention_flops(batch, heads, n, d));
    if threads <= 1 {
        // Serial fast path — the serving default (`threads: 1`): write
        // each head's context stripe straight into `ctx` (heads touch
        // disjoint columns) and fold per-head significance partials into
        // `sig` in ascending head order. The fold association matches the
        // parallel merge below exactly, so serial and parallel results
        // stay bit-identical.
        assert!(scratch.probs.len() >= n, "attention scratch: probs < n");
        assert!(scratch.sig_heads.len() >= n, "attention scratch: sig_heads < n");
        ctx.fill(0.0);
        sig.fill(0.0);
        let probs = &mut scratch.probs[..n];
        let head_sig = &mut scratch.sig_heads[..n];
        for b in 0..batch {
            let ctx_ex = &mut ctx[b * n * h..(b + 1) * n * h];
            for a in 0..heads {
                head_sig.fill(0.0);
                let off = a * d;
                attend_one(q, k, v, mask, b * n, a, n, h, d, ctx_ex, h, off, head_sig, probs);
                for (sv, &pv) in sig[b * n..(b + 1) * n].iter_mut().zip(head_sig.iter()) {
                    *sv += pv;
                }
            }
        }
        return;
    }

    // Per-task private slabs: ctx_heads[t] is [n, d] for task t = b*heads+a,
    // sig_heads[t] is [n]. Same total footprint as ctx itself. Both
    // accumulate, so the used prefixes are re-zeroed every call (the
    // arena hands them back dirty by design).
    let nd = n * d;
    // The same fixed-order (batch row, head) range list the scoped path
    // built via `task_ranges`, in closed form: lane chunk t covers tasks
    // [t*per, (t+1)*per).
    let per = tasks.div_ceil(threads);
    let chunks = tasks.div_ceil(per);
    assert!(scratch.ctx_heads.len() >= tasks * nd, "attention scratch: ctx_heads too small");
    assert!(scratch.sig_heads.len() >= tasks * n, "attention scratch: sig_heads too small");
    assert!(scratch.probs.len() >= chunks * n, "attention scratch: probs < lanes * n");
    let ctx_heads = &mut scratch.ctx_heads[..tasks * nd];
    let sig_heads = &mut scratch.sig_heads[..tasks * n];
    ctx_heads.fill(0.0);
    sig_heads.fill(0.0);
    let ctx_shards = Shards::new(ctx_heads);
    let sig_shards = Shards::new(sig_heads);
    let probs_shards = Shards::new(&mut scratch.probs[..chunks * n]);
    exec.pool().run(chunks, &|t| {
        let t0 = t * per;
        let t1 = ((t + 1) * per).min(tasks);
        // SAFETY: chunk t exclusively owns tasks [t0, t1) — slab ranges
        // are pairwise disjoint across chunks — and probs lane t.
        let probs = unsafe { probs_shards.slice(t * n, n) };
        for task in t0..t1 {
            let (b, a) = (task / heads, task % heads);
            let ctx_part = unsafe { ctx_shards.slice(task * nd, nd) };
            let sig_part = unsafe { sig_shards.slice(task * n, n) };
            attend_one(q, k, v, mask, b * n, a, n, h, d, ctx_part, d, 0, sig_part, probs);
        }
    });

    // Serial merge in fixed (example, head) order: interleave the head
    // slabs into [n, h] rows and sum significance head-ascending.
    let ctx_heads = &scratch.ctx_heads[..tasks * nd];
    let sig_heads = &scratch.sig_heads[..tasks * n];
    merge_head_slabs(ctx_heads, sig_heads, batch, n, heads, d, ctx, sig);
}

/// Ragged masked attention: the same kernel over a row-offset ragged
/// batch. Example `b` owns absolute rows `offsets[b] .. offsets[b+1]` of
/// `q`/`k`/`v`/`mask`/`ctx`/`sig` (see
/// [`RaggedRows`](super::gemm::RaggedRows)); its attention runs over its
/// own `n_b` rows only, so eliminated word-vectors cost nothing — the
/// task list is per-example `(row-range, head)` pairs and no task ever
/// touches another example's (or a ghost) row.
///
/// Determinism contract: identical to [`masked_attention`] — tasks write
/// private slabs at prefix-sum offsets (`Σ` over preceding `(example,
/// head)` pairs of `n_b·d`), the merge interleaves them in ascending
/// `(example, head)` order, and the serial path folds in the same
/// association. When every `n_b` equals `n` the slab offsets, chunking
/// and fold order degenerate to exactly the rectangular driver's, so a
/// uniform-width ragged call is **bit-identical** to [`masked_attention`]
/// on the same rows.
///
/// Scratch capacity (asserted): serial — `sig_heads`/`probs` at least
/// `max_b n_b`; pooled — `ctx_heads >= total_rows * heads * d`,
/// `sig_heads >= total_rows * heads`, `probs >= chunks * max_b n_b`. The
/// rectangular arena regions (sized at `batch * seq`) are always enough,
/// since `total_rows <= batch * seq`.
#[allow(clippy::too_many_arguments)]
pub fn masked_attention_ragged(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    mask: &[f32],
    offsets: &[i32],
    heads: usize,
    d: usize,
    exec: &KernelExec,
    scratch: AttnScratch<'_>,
    ctx: &mut [f32],
    sig: &mut [f32],
) {
    let h = heads * d;
    assert!(offsets.len() >= 2, "ragged attention: offsets needs batch + 1 entries");
    assert_eq!(offsets[0], 0, "ragged attention: offsets must start at 0");
    let batch = offsets.len() - 1;
    let rows = *offsets.last().unwrap() as usize;
    assert_eq!(q.len(), rows * h, "ragged attention: q is not [total_rows, h]");
    assert_eq!(k.len(), rows * h, "ragged attention: k is not [total_rows, h]");
    assert_eq!(v.len(), rows * h, "ragged attention: v is not [total_rows, h]");
    assert_eq!(mask.len(), rows, "ragged attention: mask is not [total_rows]");
    assert_eq!(ctx.len(), rows * h, "ragged attention: ctx is not [total_rows, h]");
    assert_eq!(sig.len(), rows, "ragged attention: sig is not [total_rows]");
    if rows == 0 {
        return;
    }
    let max_n = (0..batch)
        .map(|b| (offsets[b + 1] - offsets[b]) as usize)
        .max()
        .unwrap_or(0);

    let tasks = batch * heads;
    let threads =
        exec.threads_for_work(tasks, super::ragged_attention_flops(offsets, heads, d));
    if threads <= 1 {
        assert!(scratch.probs.len() >= max_n, "ragged attention scratch: probs < max width");
        assert!(
            scratch.sig_heads.len() >= max_n,
            "ragged attention scratch: sig_heads < max width"
        );
        ctx.fill(0.0);
        sig.fill(0.0);
        let probs = &mut scratch.probs[..max_n];
        let head_sig = &mut scratch.sig_heads[..max_n];
        for b in 0..batch {
            let base = offsets[b] as usize;
            let n_b = offsets[b + 1] as usize - base;
            if n_b == 0 {
                continue;
            }
            let ctx_ex = &mut ctx[base * h..(base + n_b) * h];
            for a in 0..heads {
                head_sig[..n_b].fill(0.0);
                let off = a * d;
                attend_one(
                    q,
                    k,
                    v,
                    mask,
                    base,
                    a,
                    n_b,
                    h,
                    d,
                    ctx_ex,
                    h,
                    off,
                    &mut head_sig[..n_b],
                    &mut probs[..n_b],
                );
                for (sv, &pv) in sig[base..base + n_b].iter_mut().zip(head_sig.iter()) {
                    *sv += pv;
                }
            }
        }
        return;
    }

    // Pooled path: task t = b*heads + a owns a private [n_b, d] context
    // slab and [n_b] significance partial at the ragged prefix-sum offset
    // (offsets[b]*heads + a*n_b) — pairwise disjoint across tasks, and
    // equal to the rectangular task*n_b*d layout when widths are uniform.
    let per = tasks.div_ceil(threads);
    let chunks = tasks.div_ceil(per);
    assert!(
        scratch.ctx_heads.len() >= rows * h,
        "ragged attention scratch: ctx_heads too small"
    );
    assert!(
        scratch.sig_heads.len() >= rows * heads,
        "ragged attention scratch: sig_heads too small"
    );
    assert!(
        scratch.probs.len() >= chunks * max_n,
        "ragged attention scratch: probs < chunks * max width"
    );
    let ctx_heads = &mut scratch.ctx_heads[..rows * h];
    let sig_heads = &mut scratch.sig_heads[..rows * heads];
    ctx_heads.fill(0.0);
    sig_heads.fill(0.0);
    let ctx_shards = Shards::new(ctx_heads);
    let sig_shards = Shards::new(sig_heads);
    let probs_shards = Shards::new(&mut scratch.probs[..chunks * max_n]);
    exec.pool().run(chunks, &|t| {
        let t0 = t * per;
        let t1 = ((t + 1) * per).min(tasks);
        // SAFETY: chunk t exclusively owns tasks [t0, t1) — ragged slab
        // ranges are pairwise disjoint across tasks — and probs lane t.
        let probs = unsafe { probs_shards.slice(t * max_n, max_n) };
        for task in t0..t1 {
            let (b, a) = (task / heads, task % heads);
            let base = offsets[b] as usize;
            let n_b = offsets[b + 1] as usize - base;
            if n_b == 0 {
                continue;
            }
            let slab = base * heads + a * n_b;
            let ctx_part = unsafe { ctx_shards.slice(slab * d, n_b * d) };
            let sig_part = unsafe { sig_shards.slice(slab, n_b) };
            let probs_b = &mut probs[..n_b];
            attend_one(q, k, v, mask, base, a, n_b, h, d, ctx_part, d, 0, sig_part, probs_b);
        }
    });

    // Serial merge in fixed ascending (example, head) order — the ragged
    // counterpart of `merge_head_slabs`.
    let ctx_heads = &scratch.ctx_heads[..rows * h];
    let sig_heads = &scratch.sig_heads[..rows * heads];
    sig.fill(0.0);
    for b in 0..batch {
        let base = offsets[b] as usize;
        let n_b = offsets[b + 1] as usize - base;
        for a in 0..heads {
            let slab = base * heads + a * n_b;
            let part = &ctx_heads[slab * d..(slab + n_b) * d];
            let off = a * d;
            for i in 0..n_b {
                ctx[(base + i) * h + off..(base + i) * h + off + d]
                    .copy_from_slice(&part[i * d..(i + 1) * d]);
            }
            let spart = &sig_heads[slab..slab + n_b];
            for (sv, &pv) in sig[base..base + n_b].iter_mut().zip(spart) {
                *sv += pv;
            }
        }
    }
}

/// The fixed-order merge shared by the pooled and scoped drivers:
/// interleaves private `[n, d]` head slabs into `[n, h]` context rows and
/// folds significance partials head-ascending (the association that keeps
/// every thread count bit-identical to the serial path).
#[allow(clippy::too_many_arguments)]
fn merge_head_slabs(
    ctx_heads: &[f32],
    sig_heads: &[f32],
    batch: usize,
    n: usize,
    heads: usize,
    d: usize,
    ctx: &mut [f32],
    sig: &mut [f32],
) {
    let h = heads * d;
    let nd = n * d;
    sig.fill(0.0);
    for b in 0..batch {
        for a in 0..heads {
            let t = b * heads + a;
            let part = &ctx_heads[t * nd..(t + 1) * nd];
            let off = a * d;
            for i in 0..n {
                ctx[(b * n + i) * h + off..(b * n + i) * h + off + d]
                    .copy_from_slice(&part[i * d..(i + 1) * d]);
            }
            let spart = &sig_heads[t * n..(t + 1) * n];
            for (sv, &pv) in sig[b * n..(b + 1) * n].iter_mut().zip(spart) {
                *sv += pv;
            }
        }
    }
}

/// The pre-pool driver: scoped threads spawned per call over the identical
/// `(batch row, head)` range list, with self-allocated slabs. Kept as the
/// dispatch-cost baseline for `benches/native.rs` and the bit-exactness
/// oracle for `tests/prop_kernels.rs` — results must equal
/// [`masked_attention`] bit-for-bit at any thread count.
#[allow(clippy::too_many_arguments)]
pub fn masked_attention_scoped(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    mask: &[f32],
    batch: usize,
    n: usize,
    heads: usize,
    d: usize,
    cfg: &KernelConfig,
    ctx: &mut [f32],
    sig: &mut [f32],
) {
    let h = heads * d;
    let rows = batch * n;
    assert_eq!(q.len(), rows * h, "attention: q is not [batch*n, h]");
    assert_eq!(k.len(), rows * h, "attention: k is not [batch*n, h]");
    assert_eq!(v.len(), rows * h, "attention: v is not [batch*n, h]");
    assert_eq!(mask.len(), rows, "attention: mask is not [batch*n]");
    assert_eq!(ctx.len(), rows * h, "attention: ctx is not [batch*n, h]");
    assert_eq!(sig.len(), rows, "attention: sig is not [batch*n]");
    if rows == 0 {
        return;
    }

    let tasks = batch * heads;
    // Per-call spawns are floored harder than the pooled path — see
    // SCOPED_SPAWN_FLOPS.
    let threads =
        super::scoped_threads_for_work(cfg, tasks, super::attention_flops(batch, heads, n, d));
    if threads <= 1 {
        ctx.fill(0.0);
        sig.fill(0.0);
        let mut probs = vec![0f32; n];
        let mut head_sig = vec![0f32; n];
        for b in 0..batch {
            let ctx_ex = &mut ctx[b * n * h..(b + 1) * n * h];
            for a in 0..heads {
                head_sig.fill(0.0);
                let off = a * d;
                attend_one(
                    q,
                    k,
                    v,
                    mask,
                    b * n,
                    a,
                    n,
                    h,
                    d,
                    ctx_ex,
                    h,
                    off,
                    &mut head_sig,
                    &mut probs,
                );
                for (sv, &pv) in sig[b * n..(b + 1) * n].iter_mut().zip(head_sig.iter()) {
                    *sv += pv;
                }
            }
        }
        return;
    }

    let nd = n * d;
    let mut ctx_heads = vec![0f32; tasks * nd];
    let mut sig_heads = vec![0f32; tasks * n];
    let run_task = |t: usize, ctx_part: &mut [f32], sig_part: &mut [f32], probs: &mut [f32]| {
        let (b, a) = (t / heads, t % heads);
        attend_one(q, k, v, mask, b * n, a, n, h, d, ctx_part, d, 0, sig_part, probs);
    };
    let ranges = task_ranges(tasks, threads);
    super::note_spawns(ranges.len() as u64);
    std::thread::scope(|s| {
        let mut ctx_rest = &mut ctx_heads[..];
        let mut sig_rest = &mut sig_heads[..];
        for r in ranges {
            let take = r.len();
            let (ctx_chunk, ct) = std::mem::take(&mut ctx_rest).split_at_mut(take * nd);
            ctx_rest = ct;
            let (sig_chunk, st) = std::mem::take(&mut sig_rest).split_at_mut(take * n);
            sig_rest = st;
            let run = &run_task;
            s.spawn(move || {
                let mut probs = vec![0f32; n];
                let slabs = ctx_chunk.chunks_exact_mut(nd).zip(sig_chunk.chunks_exact_mut(n));
                for (i, (ctx_part, sig_part)) in slabs.enumerate() {
                    run(r.start + i, ctx_part, sig_part, &mut probs);
                }
            });
        }
    });

    merge_head_slabs(&ctx_heads, &sig_heads, batch, n, heads, d, ctx, sig);
}

/// One `(example, head)` task: softmax over the example's keys for every
/// query row. The example's rows start at absolute row `base` of
/// `q`/`k`/`v`/`mask` — `b * n` for a rectangular batch, the example's
/// ragged row offset for [`masked_attention_ragged`] — and span `n` rows.
/// The head's context goes to `ctx_out` — `n` rows of `ctx_stride`
/// floats, this head's `d`-wide stripe starting at `ctx_off` (a private
/// `[n, d]` slab has stride `d`, offset 0; in-place writing into a full
/// `[n, h]` block has stride `h`, offset `a * d`). Significance column
/// sums are **accumulated** into `sig_part` (`[n]`, caller-zeroed);
/// `probs` is an `[n]` scratch row.
#[allow(clippy::too_many_arguments)]
fn attend_one(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    mask: &[f32],
    base: usize,
    a: usize,
    n: usize,
    h: usize,
    d: usize,
    ctx_out: &mut [f32],
    ctx_stride: usize,
    ctx_off: usize,
    sig_part: &mut [f32],
    probs: &mut [f32],
) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if super::simd_active() {
        // SAFETY: `simd_active()` checked avx2+fma on this CPU.
        unsafe {
            attend_one_avx2(
                q, k, v, mask, base, a, n, h, d, ctx_out, ctx_stride, ctx_off, sig_part, probs,
            )
        };
        return;
    }
    attend_one_scalar(
        q, k, v, mask, base, a, n, h, d, ctx_out, ctx_stride, ctx_off, sig_part, probs,
    );
}

/// Scalar task body — the correctness oracle the AVX2 variant is measured
/// against (same loop nest, one lane at a time).
#[allow(clippy::too_many_arguments)]
fn attend_one_scalar(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    mask: &[f32],
    base: usize,
    a: usize,
    n: usize,
    h: usize,
    d: usize,
    ctx_out: &mut [f32],
    ctx_stride: usize,
    ctx_off: usize,
    sig_part: &mut [f32],
    probs: &mut [f32],
) {
    let scale = 1.0 / (d as f32).sqrt();
    let off = a * d;
    let emask = &mask[base..base + n];
    for i in 0..n {
        let qi = &q[(base + i) * h + off..(base + i) * h + off + d];
        // Scaled dot-product logits with PAD keys masked out; running max
        // for the numerically-stable softmax.
        let mut maxv = f32::NEG_INFINITY;
        for jj in 0..n {
            let kj = &k[(base + jj) * h + off..(base + jj) * h + off + d];
            let mut dot = 0f32;
            for t in 0..d {
                dot += qi[t] * kj[t];
            }
            let logit = if emask[jj] > 0.0 { dot * scale } else { NEG_INF };
            probs[jj] = logit;
            if logit > maxv {
                maxv = logit;
            }
        }
        let mut denom = 0f32;
        for p in probs.iter_mut() {
            *p = (*p - maxv).exp();
            denom += *p;
        }
        let inv = 1.0 / denom;
        // Column sums over non-PAD query rows only: PAD queries must not
        // vote on which word-vectors survive (paper §3.2).
        let qmask = emask[i];
        let crow = &mut ctx_out[i * ctx_stride + ctx_off..i * ctx_stride + ctx_off + d];
        for jj in 0..n {
            let p = probs[jj] * inv;
            sig_part[jj] += qmask * p;
            let vj = &v[(base + jj) * h + off..(base + jj) * h + off + d];
            for t in 0..d {
                crow[t] += p * vj[t];
            }
        }
    }
}

/// AVX2/FMA task body: 8-lane q·k dot (FMA + horizontal sum, scalar
/// remainder past `d - d % 8`) and 8-lane `p · v` context accumulation;
/// the softmax max/exp/normalize row is shared verbatim with the scalar
/// oracle. See the module's "ISA dispatch" section for the tolerance
/// contract.
///
/// # Safety
/// Requires AVX2 + FMA (guard with [`super::simd_active`]).
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
#[allow(clippy::too_many_arguments)]
#[target_feature(enable = "avx2", enable = "fma")]
unsafe fn attend_one_avx2(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    mask: &[f32],
    base: usize,
    a: usize,
    n: usize,
    h: usize,
    d: usize,
    ctx_out: &mut [f32],
    ctx_stride: usize,
    ctx_off: usize,
    sig_part: &mut [f32],
    probs: &mut [f32],
) {
    use super::gemm::simd::hsum_ps;
    use std::arch::x86_64::*;

    let scale = 1.0 / (d as f32).sqrt();
    let off = a * d;
    let emask = &mask[base..base + n];
    let dv = d - d % 8;
    for i in 0..n {
        let qi = &q[(base + i) * h + off..(base + i) * h + off + d];
        let mut maxv = f32::NEG_INFINITY;
        for jj in 0..n {
            let kj = &k[(base + jj) * h + off..(base + jj) * h + off + d];
            let mut acc = _mm256_setzero_ps();
            let mut t = 0;
            while t < dv {
                acc = _mm256_fmadd_ps(
                    _mm256_loadu_ps(qi.as_ptr().add(t)),
                    _mm256_loadu_ps(kj.as_ptr().add(t)),
                    acc,
                );
                t += 8;
            }
            let mut dot = hsum_ps(acc);
            for t in dv..d {
                dot += qi[t] * kj[t];
            }
            let logit = if emask[jj] > 0.0 { dot * scale } else { NEG_INF };
            probs[jj] = logit;
            if logit > maxv {
                maxv = logit;
            }
        }
        let mut denom = 0f32;
        for p in probs.iter_mut() {
            *p = (*p - maxv).exp();
            denom += *p;
        }
        let inv = 1.0 / denom;
        let qmask = emask[i];
        let crow = &mut ctx_out[i * ctx_stride + ctx_off..i * ctx_stride + ctx_off + d];
        for jj in 0..n {
            let p = probs[jj] * inv;
            sig_part[jj] += qmask * p;
            let vj = &v[(base + jj) * h + off..(base + jj) * h + off + d];
            let pv = _mm256_set1_ps(p);
            let mut t = 0;
            while t < dv {
                let c = _mm256_loadu_ps(crow.as_ptr().add(t));
                let vjv = _mm256_loadu_ps(vj.as_ptr().add(t));
                _mm256_storeu_ps(crow.as_mut_ptr().add(t), _mm256_fmadd_ps(pv, vjv, c));
                t += 8;
            }
            for t in dv..d {
                crow[t] += p * vj[t];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn rand_vec(len: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..len).map(|_| (rng.f64() * 2.0 - 1.0) as f32).collect()
    }

    #[test]
    fn pad_keys_get_zero_significance_and_probs_sum_to_one() {
        let (batch, n, heads, d) = (2usize, 5usize, 2usize, 4usize);
        let h = heads * d;
        let q = rand_vec(batch * n * h, 1);
        let k = rand_vec(batch * n * h, 2);
        let v = rand_vec(batch * n * h, 3);
        // Example 0: last two positions PAD; example 1: all real.
        let mut mask = vec![1f32; batch * n];
        mask[3] = 0.0;
        mask[4] = 0.0;
        let mut ctx = vec![0f32; batch * n * h];
        let mut sig = vec![0f32; batch * n];
        let exec = KernelExec::default();
        let mut buf = AttnScratchBuf::for_shape(batch, n, heads, d, exec.lanes());
        masked_attention(
            &q,
            &k,
            &v,
            &mask,
            batch,
            n,
            heads,
            d,
            &exec,
            buf.scratch(),
            &mut ctx,
            &mut sig,
        );
        // PAD keys receive (numerically) zero attention mass.
        assert!(sig[3].abs() < 1e-6 && sig[4].abs() < 1e-6, "PAD sig {sig:?}");
        // Per example, total significance = heads * (# real query rows):
        // each real query row distributes probability mass 1 per head.
        let real0: f32 = sig[..n].iter().sum();
        assert!((real0 - (heads * 3) as f32).abs() < 1e-4, "example 0 mass {real0}");
        let real1: f32 = sig[n..].iter().sum();
        assert!((real1 - (heads * n) as f32).abs() < 1e-4, "example 1 mass {real1}");
        assert!(ctx.iter().all(|c| c.is_finite()));
    }

    #[test]
    fn pooled_and_scoped_thread_counts_are_bit_identical() {
        let (batch, n, heads, d) = (3usize, 7usize, 2usize, 3usize);
        let h = heads * d;
        let q = rand_vec(batch * n * h, 10);
        let k = rand_vec(batch * n * h, 11);
        let v = rand_vec(batch * n * h, 12);
        let mut mask = vec![1f32; batch * n];
        mask[6] = 0.0;
        mask[13] = 0.0;
        let mut ctx1 = vec![0f32; batch * n * h];
        let mut sig1 = vec![0f32; batch * n];
        let exec1 = KernelExec::new(KernelConfig::default().with_threads(1));
        let mut buf1 = AttnScratchBuf::for_shape(batch, n, heads, d, 1);
        masked_attention(
            &q,
            &k,
            &v,
            &mask,
            batch,
            n,
            heads,
            d,
            &exec1,
            buf1.scratch(),
            &mut ctx1,
            &mut sig1,
        );
        for threads in [2usize, 4, 5] {
            // Threshold off: the whole point is to exercise the parallel
            // drivers on a deliberately tiny shape.
            let cfg =
                KernelConfig::default().with_threads(threads).with_min_parallel_flops(0);
            let exec = KernelExec::new(cfg.clone());
            let mut buf = AttnScratchBuf::for_shape(batch, n, heads, d, exec.lanes());
            let mut ctx_t = vec![0f32; batch * n * h];
            let mut sig_t = vec![0f32; batch * n];
            masked_attention(
                &q,
                &k,
                &v,
                &mask,
                batch,
                n,
                heads,
                d,
                &exec,
                buf.scratch(),
                &mut ctx_t,
                &mut sig_t,
            );
            assert_eq!(ctx1, ctx_t, "pooled ctx differs at threads={threads}");
            assert_eq!(sig1, sig_t, "pooled sig differs at threads={threads}");
            let mut ctx_s = vec![0f32; batch * n * h];
            let mut sig_s = vec![0f32; batch * n];
            masked_attention_scoped(
                &q, &k, &v, &mask, batch, n, heads, d, &cfg, &mut ctx_s, &mut sig_s,
            );
            assert_eq!(ctx1, ctx_s, "scoped ctx differs at threads={threads}");
            assert_eq!(sig1, sig_s, "scoped sig differs at threads={threads}");
        }
    }

    #[test]
    fn dirty_scratch_does_not_leak_into_results() {
        // The arena hands attention its scratch without zeroing — the
        // kernel must fully re-initialize whatever prefixes it uses.
        let (batch, n, heads, d) = (2usize, 5usize, 3usize, 2usize);
        let h = heads * d;
        let q = rand_vec(batch * n * h, 21);
        let k = rand_vec(batch * n * h, 22);
        let v = rand_vec(batch * n * h, 23);
        let mask = vec![1f32; batch * n];
        let exec =
            KernelExec::new(KernelConfig::default().with_threads(3).with_min_parallel_flops(0));
        let mut clean = AttnScratchBuf::for_shape(batch, n, heads, d, exec.lanes());
        let mut ctx_a = vec![0f32; batch * n * h];
        let mut sig_a = vec![0f32; batch * n];
        masked_attention(
            &q,
            &k,
            &v,
            &mask,
            batch,
            n,
            heads,
            d,
            &exec,
            clean.scratch(),
            &mut ctx_a,
            &mut sig_a,
        );
        let mut dirty = AttnScratchBuf::for_shape(batch, n, heads, d, exec.lanes());
        {
            let s = dirty.scratch();
            s.ctx_heads.fill(f32::NAN);
            s.sig_heads.fill(-7.5);
            s.probs.fill(f32::INFINITY);
        }
        let mut ctx_b = vec![f32::NAN; batch * n * h];
        let mut sig_b = vec![f32::NAN; batch * n];
        masked_attention(
            &q,
            &k,
            &v,
            &mask,
            batch,
            n,
            heads,
            d,
            &exec,
            dirty.scratch(),
            &mut ctx_b,
            &mut sig_b,
        );
        assert_eq!(ctx_a, ctx_b, "dirty scratch leaked into ctx");
        assert_eq!(sig_a, sig_b, "dirty scratch leaked into sig");
    }
}
