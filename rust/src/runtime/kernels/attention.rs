//! Masked multi-head attention + attention-column significance, parallel
//! across `(batch row, head)` tasks.
//!
//! # Shape contract
//!
//! `q`/`k`/`v` are row-major `[batch * n, h]` (already projected; heads
//! live in `h = heads * d` interleaved column blocks, head `a` at columns
//! `[a*d, (a+1)*d)`). `mask` is `[batch * n]` with `1.0` for real tokens
//! and `0.0` for PAD. Outputs: `ctx` (`[batch * n, h]`, overwritten) and
//! `sig` (`[batch * n]`, overwritten) — `sig[b, j]` is the paper's §3.2
//! significance of word-vector `j` in example `b`: the softmax column sum
//! over all heads and non-PAD query rows, exactly what the extract layer
//! ranks by.
//!
//! # Parallel structure
//!
//! The natural unit is one `(example, head)` pair: its softmax rows and
//! its `[n, d]` slice of the context are independent of every other pair.
//! Under `threads > 1`, each task writes a private contiguous `ctx`/`sig`
//! slab (so tasks can be handed to scoped threads with plain
//! `split_at_mut`, no locks and no unsafe), and a serial merge then
//! interleaves the head slabs back into `[n, h]` rows and sums
//! significance **in ascending head order**. The serial path (the serving
//! default) skips the slabs and writes head stripes in place, folding
//! per-head significance partials in the same ascending-head association
//! — so results are bit-identical for any [`KernelConfig::threads`].

use super::{task_ranges, KernelConfig};

/// Additive mask for PAD key columns, matching `python/compile/kernels`.
const NEG_INF: f32 = -1e9;

/// Scaled-dot-product attention with PAD masking over `batch` independent
/// examples of `n` word-vectors; accumulates the attention-column
/// significance scores alongside the context. See the module docs for the
/// shape contract.
#[allow(clippy::too_many_arguments)]
pub fn masked_attention(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    mask: &[f32],
    batch: usize,
    n: usize,
    heads: usize,
    d: usize,
    cfg: &KernelConfig,
    ctx: &mut [f32],
    sig: &mut [f32],
) {
    let h = heads * d;
    let rows = batch * n;
    assert_eq!(q.len(), rows * h, "attention: q is not [batch*n, h]");
    assert_eq!(k.len(), rows * h, "attention: k is not [batch*n, h]");
    assert_eq!(v.len(), rows * h, "attention: v is not [batch*n, h]");
    assert_eq!(mask.len(), rows, "attention: mask is not [batch*n]");
    assert_eq!(ctx.len(), rows * h, "attention: ctx is not [batch*n, h]");
    assert_eq!(sig.len(), rows, "attention: sig is not [batch*n]");
    if rows == 0 {
        return;
    }

    let tasks = batch * heads;
    let threads = cfg.effective_threads(tasks);
    if threads <= 1 {
        // Serial fast path — the serving default (`threads: 1`): write
        // each head's context stripe straight into `ctx` (heads touch
        // disjoint columns) and fold per-head significance partials into
        // `sig` in ascending head order. The fold association matches the
        // parallel merge below exactly, so serial and parallel results
        // stay bit-identical.
        ctx.fill(0.0);
        sig.fill(0.0);
        let mut probs = vec![0f32; n];
        let mut head_sig = vec![0f32; n];
        for b in 0..batch {
            let ctx_ex = &mut ctx[b * n * h..(b + 1) * n * h];
            for a in 0..heads {
                head_sig.fill(0.0);
                let off = a * d;
                attend_one(q, k, v, mask, b, a, n, h, d, ctx_ex, h, off, &mut head_sig, &mut probs);
                for (sv, &pv) in sig[b * n..(b + 1) * n].iter_mut().zip(head_sig.iter()) {
                    *sv += pv;
                }
            }
        }
        return;
    }

    // Per-task private slabs: ctx_heads[t] is [n, d] for task t = b*heads+a,
    // sig_heads[t] is [n]. Same total footprint as ctx itself.
    let nd = n * d;
    let mut ctx_heads = vec![0f32; tasks * nd];
    let mut sig_heads = vec![0f32; tasks * n];
    let run_task = |t: usize, ctx_part: &mut [f32], sig_part: &mut [f32], probs: &mut [f32]| {
        let (b, a) = (t / heads, t % heads);
        attend_one(q, k, v, mask, b, a, n, h, d, ctx_part, d, 0, sig_part, probs);
    };
    let ranges = task_ranges(tasks, threads);
    std::thread::scope(|s| {
        let mut ctx_rest = &mut ctx_heads[..];
        let mut sig_rest = &mut sig_heads[..];
        for r in ranges {
            let take = r.len();
            let (ctx_chunk, ct) = std::mem::take(&mut ctx_rest).split_at_mut(take * nd);
            ctx_rest = ct;
            let (sig_chunk, st) = std::mem::take(&mut sig_rest).split_at_mut(take * n);
            sig_rest = st;
            let run = &run_task;
            s.spawn(move || {
                let mut probs = vec![0f32; n];
                let slabs = ctx_chunk.chunks_exact_mut(nd).zip(sig_chunk.chunks_exact_mut(n));
                for (i, (ctx_part, sig_part)) in slabs.enumerate() {
                    run(r.start + i, ctx_part, sig_part, &mut probs);
                }
            });
        }
    });

    // Serial merge in fixed (example, head) order: interleave the head
    // slabs into [n, h] rows and sum significance head-ascending.
    sig.fill(0.0);
    for b in 0..batch {
        for a in 0..heads {
            let t = b * heads + a;
            let part = &ctx_heads[t * nd..(t + 1) * nd];
            let off = a * d;
            for i in 0..n {
                ctx[(b * n + i) * h + off..(b * n + i) * h + off + d]
                    .copy_from_slice(&part[i * d..(i + 1) * d]);
            }
            let spart = &sig_heads[t * n..(t + 1) * n];
            for (sv, &pv) in sig[b * n..(b + 1) * n].iter_mut().zip(spart) {
                *sv += pv;
            }
        }
    }
}

/// One `(example, head)` task: softmax over the example's keys for every
/// query row. The head's context goes to `ctx_out` — `n` rows of
/// `ctx_stride` floats, this head's `d`-wide stripe starting at `ctx_off`
/// (a private `[n, d]` slab has stride `d`, offset 0; in-place writing
/// into a full `[n, h]` block has stride `h`, offset `a * d`).
/// Significance column sums are **accumulated** into `sig_part` (`[n]`,
/// caller-zeroed); `probs` is an `[n]` scratch row.
#[allow(clippy::too_many_arguments)]
fn attend_one(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    mask: &[f32],
    b: usize,
    a: usize,
    n: usize,
    h: usize,
    d: usize,
    ctx_out: &mut [f32],
    ctx_stride: usize,
    ctx_off: usize,
    sig_part: &mut [f32],
    probs: &mut [f32],
) {
    let scale = 1.0 / (d as f32).sqrt();
    let base = b * n;
    let off = a * d;
    let emask = &mask[base..base + n];
    for i in 0..n {
        let qi = &q[(base + i) * h + off..(base + i) * h + off + d];
        // Scaled dot-product logits with PAD keys masked out; running max
        // for the numerically-stable softmax.
        let mut maxv = f32::NEG_INFINITY;
        for jj in 0..n {
            let kj = &k[(base + jj) * h + off..(base + jj) * h + off + d];
            let mut dot = 0f32;
            for t in 0..d {
                dot += qi[t] * kj[t];
            }
            let logit = if emask[jj] > 0.0 { dot * scale } else { NEG_INF };
            probs[jj] = logit;
            if logit > maxv {
                maxv = logit;
            }
        }
        let mut denom = 0f32;
        for p in probs.iter_mut() {
            *p = (*p - maxv).exp();
            denom += *p;
        }
        let inv = 1.0 / denom;
        // Column sums over non-PAD query rows only: PAD queries must not
        // vote on which word-vectors survive (paper §3.2).
        let qmask = emask[i];
        let crow = &mut ctx_out[i * ctx_stride + ctx_off..i * ctx_stride + ctx_off + d];
        for jj in 0..n {
            let p = probs[jj] * inv;
            sig_part[jj] += qmask * p;
            let vj = &v[(base + jj) * h + off..(base + jj) * h + off + d];
            for t in 0..d {
                crow[t] += p * vj[t];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prng::Rng;

    fn rand_vec(len: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..len).map(|_| (rng.f64() * 2.0 - 1.0) as f32).collect()
    }

    #[test]
    fn pad_keys_get_zero_significance_and_probs_sum_to_one() {
        let (batch, n, heads, d) = (2usize, 5usize, 2usize, 4usize);
        let h = heads * d;
        let q = rand_vec(batch * n * h, 1);
        let k = rand_vec(batch * n * h, 2);
        let v = rand_vec(batch * n * h, 3);
        // Example 0: last two positions PAD; example 1: all real.
        let mut mask = vec![1f32; batch * n];
        mask[3] = 0.0;
        mask[4] = 0.0;
        let mut ctx = vec![0f32; batch * n * h];
        let mut sig = vec![0f32; batch * n];
        let cfg = KernelConfig::default();
        masked_attention(&q, &k, &v, &mask, batch, n, heads, d, &cfg, &mut ctx, &mut sig);
        // PAD keys receive (numerically) zero attention mass.
        assert!(sig[3].abs() < 1e-6 && sig[4].abs() < 1e-6, "PAD sig {sig:?}");
        // Per example, total significance = heads * (# real query rows):
        // each real query row distributes probability mass 1 per head.
        let real0: f32 = sig[..n].iter().sum();
        assert!((real0 - (heads * 3) as f32).abs() < 1e-4, "example 0 mass {real0}");
        let real1: f32 = sig[n..].iter().sum();
        assert!((real1 - (heads * n) as f32).abs() < 1e-4, "example 1 mass {real1}");
        assert!(ctx.iter().all(|c| c.is_finite()));
    }

    #[test]
    fn thread_counts_are_bit_identical() {
        let (batch, n, heads, d) = (3usize, 7usize, 2usize, 3usize);
        let h = heads * d;
        let q = rand_vec(batch * n * h, 10);
        let k = rand_vec(batch * n * h, 11);
        let v = rand_vec(batch * n * h, 12);
        let mut mask = vec![1f32; batch * n];
        mask[6] = 0.0;
        mask[13] = 0.0;
        let mut ctx1 = vec![0f32; batch * n * h];
        let mut sig1 = vec![0f32; batch * n];
        let cfg1 = KernelConfig::default().with_threads(1);
        masked_attention(&q, &k, &v, &mask, batch, n, heads, d, &cfg1, &mut ctx1, &mut sig1);
        for threads in [2usize, 4, 5] {
            let mut ctx_t = vec![0f32; batch * n * h];
            let mut sig_t = vec![0f32; batch * n];
            let cfg = KernelConfig::default().with_threads(threads);
            masked_attention(&q, &k, &v, &mask, batch, n, heads, d, &cfg, &mut ctx_t, &mut sig_t);
            assert_eq!(ctx1, ctx_t, "ctx differs at threads={threads}");
            assert_eq!(sig1, sig_t, "sig differs at threads={threads}");
        }
    }
}
