//! Pluggable inference backends.
//!
//! A backend turns a host [`ModelArtifact`](super::engine::ModelArtifact)
//! into something that can execute `(batch, seq)` cells with the variant's
//! weights resident per worker. Two implementations exist:
//!
//! * [`pjrt`](super::pjrt) — compiles the exported HLO text through a PJRT
//!   client and keeps weights as device buffers (the seed path; requires
//!   the real xla-rs bindings, the vendored stub returns `Unavailable`).
//! * [`native`](super::native) — a pure-Rust BERT encoder with the paper's
//!   progressive word-vector elimination, reading `weights.npz` directly.
//!   Zero XLA dependencies: the whole serving stack runs on a bare
//!   toolchain, and `cargo test` exercises real inference on the committed
//!   artifacts.
//!
//! [`LoadedModel`] is the backend-agnostic handle the rest of the stack
//! (scheduler, eval, benches) talks to: it owns cell selection and batch
//! padding and delegates raw execution to a [`CellExecutor`].

use std::fmt;

use anyhow::{anyhow, bail, Result};

use super::artifact::VariantMeta;
use crate::tokenizer::PAD_ID;

/// Which inference backend to run a worker on.
///
/// Selected per deployment via `--backend` / `$POWERBERT_BACKEND`; the
/// coordinator hands the choice to every pool worker and seeds the
/// router's latency priors from it. Native-kernel tuning rides alongside
/// in [`KernelConfig`](super::kernels::KernelConfig).
///
/// # Examples
///
/// ```
/// use powerbert::runtime::BackendKind;
///
/// assert_eq!(BackendKind::parse("native"), Some(BackendKind::Native));
/// assert_eq!(BackendKind::parse("tpu"), None);
/// assert_eq!(BackendKind::Auto.to_string(), "auto");
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Prefer PJRT, fall back to the native backend when the XLA runtime
    /// is unavailable (e.g. the vendored stub) — the default.
    Auto,
    /// XLA PJRT: compile exported HLO, execute on the PJRT device.
    Pjrt,
    /// Pure-Rust forward pass with progressive word-vector elimination.
    Native,
}

impl BackendKind {
    pub fn parse(s: &str) -> Option<BackendKind> {
        match s {
            "auto" => Some(BackendKind::Auto),
            "pjrt" | "xla" => Some(BackendKind::Pjrt),
            "native" | "rust" => Some(BackendKind::Native),
            _ => None,
        }
    }

    /// Session default: `$POWERBERT_BACKEND` when set (and valid), else
    /// `Auto`. Lets CI pin `native` without threading a flag through every
    /// test binary.
    pub fn from_env() -> BackendKind {
        std::env::var("POWERBERT_BACKEND")
            .ok()
            .and_then(|v| BackendKind::parse(&v))
            .unwrap_or(BackendKind::Auto)
    }

    /// Cold-start latency prior for the router, in microseconds per
    /// aggregate word-vector per batch row (paper §4.2: compute is
    /// proportional to word-vectors processed). Measured execution times
    /// replace this within a few batches; only the per-backend ordering
    /// matters. The native scalar loop is slower per token than the
    /// XLA-compiled kernels, so it starts from a higher prior — and `auto`
    /// may resolve to native at load time, so it seeds the conservative
    /// value (overestimating cold-start latency keeps SLA routing safe;
    /// measurements correct it either way).
    pub fn latency_prior_us_per_word_vector(self) -> f64 {
        match self {
            BackendKind::Pjrt => 25.0,
            BackendKind::Native | BackendKind::Auto => 60.0,
        }
    }
}

impl fmt::Display for BackendKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            BackendKind::Auto => "auto",
            BackendKind::Pjrt => "pjrt",
            BackendKind::Native => "native",
        })
    }
}

/// Raw output of executing one cell.
pub struct ExecOutput {
    /// Row-major [batch, num_classes] over the *executed* batch (padding
    /// rows included; the caller slices to the real row count).
    pub logits: Vec<f32>,
    pub num_classes: usize,
    /// Kept-position trace [batch, num_layers, seq], -1-padded — present
    /// when the executor can trace elimination (native power variants and
    /// PJRT debug bundles).
    pub kept: Option<Vec<i32>>,
    /// Word-vectors processed per batch row (Σ over encoder layers of the
    /// post-extraction width — the paper's aggregate word-vector count,
    /// per example). Native backend only; the adaptive retention path
    /// makes this vary with the input.
    pub tokens_per_row: Option<Vec<u64>>,
}

/// Steady-state memory/dispatch counters of one loaded model's executor
/// (native backend): what `stats` consumers read to confirm the runtime
/// has stopped allocating and spawning per request.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MemoryStats {
    /// Largest per-bucket scratch arena materialized, in bytes (the
    /// per-bucket peak is planned from the retention schedule at load;
    /// see `docs/ARCHITECTURE.md` for the formula).
    pub arena_peak_bytes: u64,
    /// Arenas materialized — ≈ distinct `(batch, seq)` buckets served.
    pub arena_buckets: u64,
    /// Kernel-pool lanes (persistent workers + the calling thread).
    pub pool_threads: u64,
    /// Parallel kernel jobs dispatched to the pool since worker start.
    pub pool_jobs: u64,
    /// Weight precision the executor's panels were packed at ("f32" /
    /// "int8"); empty when the backend does not report one.
    pub precision: &'static str,
    /// Instruction set the kernels dispatched to ("scalar" / "avx2+fma");
    /// empty when the backend does not report one.
    pub isa: &'static str,
    /// Word-vector·layer counts the examples themselves demanded (each at
    /// its own adaptive width) since load — token counts proxy FLOPs.
    pub tokens_kept: u64,
    /// Ghost rows a rectangular batch-max execution adds on top of
    /// `tokens_kept`: waste the ragged path eliminates (or the padded
    /// path incurs). `eliminated_waste_ratio = tokens_ghost / tokens_kept`.
    pub tokens_ghost: u64,
}

/// One variant loaded on one backend worker: executes rectangular
/// (batch, seq) token grids. Deliberately not `Send` — PJRT state is
/// thread-pinned, and workers own their models.
pub trait CellExecutor {
    /// Execute `tokens`/`segments` of shape [batch, seq]. `threshold`, when
    /// active (`0 < t < 1`), selects per-example adaptive retention
    /// ([`adaptive`](super::adaptive)): each extract layer keeps the batch
    /// max of the demanded kept-set sizes, with the compiled schedule as a
    /// ceiling. Backends without adaptive support ignore it (they execute
    /// the fixed schedule).
    fn execute(
        &self,
        tokens: &[i32],
        segments: &[i32],
        batch: usize,
        seq: usize,
        want_trace: bool,
        threshold: Option<f32>,
    ) -> Result<ExecOutput>;

    /// Cumulative word-vectors processed per encoder layer since load
    /// (native backend only): the paper's aggregate word-vector count,
    /// measured rather than derived from the retention config.
    fn layer_tokens(&self) -> Option<Vec<u64>> {
        None
    }

    /// Steady-state memory/dispatch counters (native backend only).
    fn memory_stats(&self) -> Option<MemoryStats> {
        None
    }
}

/// How a backend maps a requested (rows, seq) onto executable shapes.
pub enum CellPlan {
    /// Fixed compiled cells, ascending `(seq, batch)`; requests are padded
    /// up to the smallest cell that fits (PJRT: one executable per cell).
    Grid(Vec<(usize, usize)>),
    /// Any shape up to the caps executes exactly — no padding at all
    /// (native: the forward loop takes its shapes at runtime). The plan
    /// carries the scratch-arena peak bytes of every declared `(batch,
    /// seq)` cell, computed from the retention schedule at load time —
    /// the memory the steady-state executor will hold resident per
    /// bucket, known before the first request arrives (logged per worker
    /// at load; see [`LoadedModel::arena_cells`]).
    Exact {
        max_batch: usize,
        max_seq: usize,
        /// `((batch, seq), peak_bytes)` per declared grid cell, where
        /// `peak_bytes` is what *executing* that cell keeps resident —
        /// the native executor chunks batches internally, so this is the
        /// peak of the chunked plan, not of a monolithic `batch` slab.
        arena: Vec<((usize, usize), u64)>,
    },
}

/// Smallest compiled cell that fits `n` rows of `seq` tokens. `cells` must
/// be ascending `(seq, batch)` pairs; the search prefers the narrowest seq
/// bucket, then the smallest batch bucket within it (falling through to
/// wider seq rows when no batch there fits). Returns `(batch, seq)`.
pub fn pick_cell(cells: &[(usize, usize)], n: usize, seq: usize) -> Option<(usize, usize)> {
    cells
        .iter()
        .find(|&&(s, b)| s >= seq && b >= n)
        .map(|&(s, b)| (b, s))
}

/// Output of one forward execution.
#[derive(Debug, Clone)]
pub struct Logits {
    /// Row-major [batch, num_classes].
    pub values: Vec<f32>,
    pub batch: usize,
    pub num_classes: usize,
}

impl Logits {
    /// Row `i`'s scores, or `None` when `i` is out of range.
    pub fn try_row(&self, i: usize) -> Option<&[f32]> {
        let start = i.checked_mul(self.num_classes)?;
        let end = start.checked_add(self.num_classes)?;
        if i >= self.batch || end > self.values.len() {
            return None;
        }
        Some(&self.values[start..end])
    }

    /// Row `i`'s scores; an out-of-range index yields an empty slice
    /// rather than panicking a worker thread.
    pub fn row(&self, i: usize) -> &[f32] {
        self.try_row(i).unwrap_or(&[])
    }

    pub fn argmax(&self, i: usize) -> usize {
        // total_cmp: NaN logits (a poisoned model is a serving reality)
        // must not panic the executor; NaN sorts below every real value.
        // An out-of-range row is empty and settles on class 0.
        self.row(i)
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(j, _)| j)
            .unwrap_or(0)
    }
}

/// A loaded model variant on one worker, backend-agnostic: cell selection
/// and padding here, raw execution behind the [`CellExecutor`].
pub struct LoadedModel {
    pub meta: VariantMeta,
    backend: &'static str,
    plan: CellPlan,
    exec: Box<dyn CellExecutor>,
}

impl LoadedModel {
    pub fn new(
        meta: VariantMeta,
        backend: &'static str,
        plan: CellPlan,
        exec: Box<dyn CellExecutor>,
    ) -> LoadedModel {
        LoadedModel { meta, backend, plan, exec }
    }

    /// Which backend executes this model ("pjrt" | "native").
    pub fn backend_name(&self) -> &'static str {
        self.backend
    }

    /// Largest executable batch size across all seq buckets.
    pub fn max_batch(&self) -> usize {
        match &self.plan {
            CellPlan::Grid(cells) => cells.iter().map(|&(_, b)| b).max().unwrap_or(1),
            CellPlan::Exact { max_batch, .. } => *max_batch,
        }
    }

    /// Executable (batch, seq) cells. For an exact-shape backend this is
    /// the artifact's declared grid (the shapes the serving layer batches
    /// to), not an enumeration of every runnable shape.
    pub fn cells(&self) -> Vec<(usize, usize)> {
        match &self.plan {
            CellPlan::Grid(cells) => cells.iter().map(|&(s, b)| (b, s)).collect(),
            CellPlan::Exact { .. } => self.meta.grid_cells(),
        }
    }

    /// Smallest executable (batch, seq) cell that fits `n` rows of `seq`
    /// tokens; `None` when `n` exceeds every batch bucket. Exact-shape
    /// backends return `(n, seq)` itself — nothing is ever padded.
    pub fn cell_for(&self, n: usize, seq: usize) -> Option<(usize, usize)> {
        match &self.plan {
            CellPlan::Grid(cells) => pick_cell(cells, n, seq),
            CellPlan::Exact { max_batch, max_seq, .. } => {
                (n > 0 && n <= *max_batch && seq <= *max_seq).then_some((n, seq))
            }
        }
    }

    /// Planned scratch-arena peak bytes per declared `(batch, seq)` cell
    /// (exact-shape backends; empty for grid backends). Computed from the
    /// retention schedule at load time, before any request has run — the
    /// number a capacity planner multiplies by workers × buckets.
    pub fn arena_cells(&self) -> &[((usize, usize), u64)] {
        match &self.plan {
            CellPlan::Grid(_) => &[],
            CellPlan::Exact { arena, .. } => arena,
        }
    }

    /// Steady-state memory/dispatch counters of the underlying executor
    /// (native backend only).
    pub fn memory_stats(&self) -> Option<MemoryStats> {
        self.exec.memory_stats()
    }

    /// Smallest batch bucket that fits `n` rows at the full sequence
    /// length (`None` when `n` is too large for every bucket).
    pub fn bucket_for(&self, n: usize) -> Option<usize> {
        self.cell_for(n, self.meta.seq_len).map(|(b, _)| b)
    }

    /// Distinct compiled batch sizes, ascending.
    pub fn batch_sizes(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self.cells().iter().map(|&(b, _)| b).collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Distinct compiled seq buckets, ascending.
    pub fn seq_buckets(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self.cells().iter().map(|&(_, s)| s).collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Cumulative per-layer word-vector counts (native backend only).
    pub fn layer_tokens(&self) -> Option<Vec<u64>> {
        self.exec.layer_tokens()
    }

    /// Run a forward pass over rows of the full sequence length (the seed's
    /// original entry point — byte-identical on single-seq bundles).
    pub fn infer(&self, tokens: &[i32], segments: &[i32], n: usize) -> Result<Logits> {
        self.infer_at(tokens, segments, n, self.meta.seq_len)
    }

    /// Run a forward pass. `tokens`/`segments` are row-major [n, seq]; the
    /// smallest executable (batch, seq) cell that fits is chosen, rows are
    /// padded to its batch bucket and columns to its seq bucket (exact
    /// backends execute the shape as-is). Errors (rather than silently
    /// truncating) when `n` exceeds every batch bucket or `seq` every seq
    /// bucket.
    pub fn infer_at(
        &self,
        tokens: &[i32],
        segments: &[i32],
        n: usize,
        seq: usize,
    ) -> Result<Logits> {
        if n == 0 {
            bail!("infer: empty batch");
        }
        if tokens.len() != n * seq || segments.len() != n * seq {
            bail!("infer: expected {}x{} tokens, got {}", n, seq, tokens.len());
        }
        let (bucket, seq_bucket) = self.cell_for(n, seq).ok_or_else(|| {
            anyhow!(
                "infer: batch of {n} rows at seq {seq} fits no executable cell of {}/{} \
                 (max batch {}, seq buckets {:?}) — split the batch upstream",
                self.meta.dataset,
                self.meta.variant,
                self.max_batch(),
                self.seq_buckets(),
            )
        })?;
        let out = if n == bucket && seq == seq_bucket {
            self.exec.execute(tokens, segments, bucket, seq_bucket, false, None)?
        } else {
            let (t, s) = pad_rows(tokens, segments, n, seq, bucket, seq_bucket);
            self.exec.execute(&t, &s, bucket, seq_bucket, false, None)?
        };
        let nc = out.num_classes;
        if out.logits.len() < n * nc {
            bail!(
                "backend returned {} logits for a {bucket}x{nc} batch",
                out.logits.len()
            );
        }
        Ok(Logits { values: out.logits[..n * nc].to_vec(), batch: n, num_classes: nc })
    }

    /// Whether this model can execute per-request adaptive retention: the
    /// native executor with a retention schedule (the schedule is the
    /// adaptive ceiling, so a variant without one has nothing to adapt).
    pub fn supports_adaptive(&self) -> bool {
        self.backend == "native" && self.meta.retention.is_some()
    }

    /// [`infer_at`](Self::infer_at) with an optional attention-mass
    /// threshold (see [`adaptive`](super::adaptive)). Returns the logits
    /// plus, when the backend measures it, the per-row word-vectors
    /// processed (sliced to the real `n` rows). `None`, a threshold ≥ 1.0
    /// or a non-adaptive backend all execute the fixed schedule —
    /// bit-for-bit the same logits as [`infer_at`](Self::infer_at).
    pub fn infer_adaptive_at(
        &self,
        tokens: &[i32],
        segments: &[i32],
        n: usize,
        seq: usize,
        threshold: Option<f32>,
    ) -> Result<(Logits, Option<Vec<u64>>)> {
        if n == 0 {
            bail!("infer: empty batch");
        }
        if tokens.len() != n * seq || segments.len() != n * seq {
            bail!("infer: expected {}x{} tokens, got {}", n, seq, tokens.len());
        }
        let threshold = threshold.filter(|&t| t > 0.0 && t < 1.0);
        let (bucket, seq_bucket) = self.cell_for(n, seq).ok_or_else(|| {
            anyhow!(
                "infer: batch of {n} rows at seq {seq} fits no executable cell of {}/{} \
                 (max batch {}, seq buckets {:?}) — split the batch upstream",
                self.meta.dataset,
                self.meta.variant,
                self.max_batch(),
                self.seq_buckets(),
            )
        })?;
        let out = if n == bucket && seq == seq_bucket {
            self.exec.execute(tokens, segments, bucket, seq_bucket, false, threshold)?
        } else {
            let (t, s) = pad_rows(tokens, segments, n, seq, bucket, seq_bucket);
            self.exec.execute(&t, &s, bucket, seq_bucket, false, threshold)?
        };
        let nc = out.num_classes;
        if out.logits.len() < n * nc {
            bail!(
                "backend returned {} logits for a {bucket}x{nc} batch",
                out.logits.len()
            );
        }
        let tokens_per_row = out.tokens_per_row.map(|mut t| {
            t.truncate(n);
            t
        });
        Ok((
            Logits { values: out.logits[..n * nc].to_vec(), batch: n, num_classes: nc },
            tokens_per_row,
        ))
    }

    /// Forward pass plus the kept-positions trace [n, L, N] (i32, rows
    /// right-padded with -1). Served natively for any variant with a
    /// retention config, and by PJRT debug bundles (2-tuple graphs).
    pub fn infer_with_trace(
        &self,
        tokens: &[i32],
        segments: &[i32],
        n: usize,
    ) -> Result<(Logits, Vec<i32>)> {
        self.infer_with_trace_adaptive(tokens, segments, n, None)
    }

    /// [`infer_with_trace`](Self::infer_with_trace) under an optional
    /// adaptive attention-mass threshold — the debug window the property
    /// tests use to assert that adaptive kept-sets stay bounded by the
    /// schedule and that CLS/PAD pinning holds at any threshold.
    pub fn infer_with_trace_adaptive(
        &self,
        tokens: &[i32],
        segments: &[i32],
        n: usize,
        threshold: Option<f32>,
    ) -> Result<(Logits, Vec<i32>)> {
        let threshold = threshold.filter(|&t| t > 0.0 && t < 1.0);
        let seq = self.meta.seq_len;
        if tokens.len() != n * seq || segments.len() != n * seq {
            bail!("infer_with_trace: expected {}x{} tokens, got {}", n, seq, tokens.len());
        }
        let (bucket, seq_bucket) = self.cell_for(n, seq).ok_or_else(|| {
            anyhow!(
                "infer_with_trace: batch of {n} rows exceeds the largest bucket {}",
                self.max_batch()
            )
        })?;
        let out = if n == bucket && seq == seq_bucket {
            self.exec.execute(tokens, segments, bucket, seq_bucket, true, threshold)?
        } else {
            let (t, s) = pad_rows(tokens, segments, n, seq, bucket, seq_bucket);
            self.exec.execute(&t, &s, bucket, seq_bucket, true, threshold)?
        };
        let kept = out.kept.ok_or_else(|| {
            anyhow!(
                "{}/{} provides no elimination trace on the {} backend \
                 (need a retention config or a debug bundle)",
                self.meta.dataset,
                self.meta.variant,
                self.backend
            )
        })?;
        let nc = out.num_classes;
        if out.logits.len() < n * nc {
            bail!(
                "backend returned {} logits for a {bucket}x{nc} batch",
                out.logits.len()
            );
        }
        Ok((
            Logits { values: out.logits[..n * nc].to_vec(), batch: n, num_classes: nc },
            kept,
        ))
    }
}

/// Pad `n` rows of `seq` tokens/segments out to a [bucket, seq_bucket]
/// rectangle: PAD tokens on the right of each row, PAD rows at the bottom.
pub(crate) fn pad_rows(
    tokens: &[i32],
    segments: &[i32],
    n: usize,
    seq: usize,
    bucket: usize,
    seq_bucket: usize,
) -> (Vec<i32>, Vec<i32>) {
    let mut t = vec![PAD_ID; bucket * seq_bucket];
    let mut s = vec![0i32; bucket * seq_bucket];
    for i in 0..n {
        t[i * seq_bucket..i * seq_bucket + seq].copy_from_slice(&tokens[i * seq..(i + 1) * seq]);
        s[i * seq_bucket..i * seq_bucket + seq].copy_from_slice(&segments[i * seq..(i + 1) * seq]);
    }
    (t, s)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_ignores_nan() {
        // Row 0 has a NaN — must not panic, and the NaN must never win.
        let l = Logits {
            values: vec![f32::NAN, 0.2, 0.9, 0.7, 0.1, 0.3],
            batch: 2,
            num_classes: 3,
        };
        assert_eq!(l.argmax(0), 2);
        assert_eq!(l.argmax(1), 0);
        // An all-NaN row settles on a valid index rather than panicking.
        let all_nan = Logits { values: vec![f32::NAN; 3], batch: 1, num_classes: 3 };
        assert!(all_nan.argmax(0) < 3);
    }

    #[test]
    fn out_of_range_row_is_empty_not_panic() {
        let l = Logits { values: vec![0.1, 0.9], batch: 1, num_classes: 2 };
        assert_eq!(l.try_row(0), Some(&[0.1, 0.9][..]));
        assert_eq!(l.try_row(1), None);
        assert_eq!(l.row(1), &[] as &[f32]);
        assert_eq!(l.row(usize::MAX), &[] as &[f32]);
        assert_eq!(l.argmax(7), 0);
        // A short values buffer (malformed executor output) is also caught.
        let short = Logits { values: vec![0.5], batch: 2, num_classes: 2 };
        assert_eq!(short.row(0), &[] as &[f32]);
    }

    #[test]
    fn pick_cell_prefers_narrow_seq_then_small_batch() {
        // Grid: seq 16 with batches {1, 8}, seq 64 with batches {1, 8, 32}.
        let cells = vec![(16, 1), (16, 8), (64, 1), (64, 8), (64, 32)];
        assert_eq!(pick_cell(&cells, 1, 10), Some((1, 16)));
        assert_eq!(pick_cell(&cells, 5, 16), Some((8, 16)));
        // Batch 20 fits no seq-16 bucket -> falls through to the 64 row.
        assert_eq!(pick_cell(&cells, 20, 10), Some((32, 64)));
        assert_eq!(pick_cell(&cells, 8, 40), Some((8, 64)));
        // Oversize in either dimension: no cell.
        assert_eq!(pick_cell(&cells, 33, 10), None);
        assert_eq!(pick_cell(&cells, 1, 100), None);
    }

    #[test]
    fn pad_rows_pads_columns_and_rows() {
        let tokens = vec![2, 5, 3, 2, 6, 3];
        let segs = vec![0, 0, 0, 0, 1, 1];
        let (t, s) = pad_rows(&tokens, &segs, 2, 3, 4, 5);
        assert_eq!(t.len(), 20);
        assert_eq!(&t[0..5], &[2, 5, 3, PAD_ID, PAD_ID]);
        assert_eq!(&t[5..10], &[2, 6, 3, PAD_ID, PAD_ID]);
        assert!(t[10..].iter().all(|&x| x == PAD_ID));
        assert_eq!(&s[5..10], &[0, 1, 1, 0, 0]);
        assert!(s[10..].iter().all(|&x| x == 0));
    }

    #[test]
    fn backend_kind_parses_and_displays() {
        assert_eq!(BackendKind::parse("pjrt"), Some(BackendKind::Pjrt));
        assert_eq!(BackendKind::parse("native"), Some(BackendKind::Native));
        assert_eq!(BackendKind::parse("auto"), Some(BackendKind::Auto));
        assert_eq!(BackendKind::parse("tpu"), None);
        assert_eq!(BackendKind::Native.to_string(), "native");
        assert!(
            BackendKind::Native.latency_prior_us_per_word_vector()
                > BackendKind::Pjrt.latency_prior_us_per_word_vector()
        );
    }
}
