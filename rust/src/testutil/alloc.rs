//! Counting test allocator: a [`System`]-backed `GlobalAlloc` that tallies
//! every allocation, so tests and benches can assert (or report) the heap
//! traffic of a code path. Install it in a test/bench **binary** with:
//!
//! ```ignore
//! #[global_allocator]
//! static ALLOC: powerbert::testutil::alloc::CountingAlloc =
//!     powerbert::testutil::alloc::CountingAlloc::new();
//! ```
//!
//! `tests/alloc_steady_state.rs` uses it to prove the native forward pass
//! performs **zero** steady-state heap allocations after a bucket's warmup
//! call; `benches/native.rs` uses it for the allocation-bytes-per-call
//! column of the kernels table. Counters are process-global (allocations
//! from any thread count), which is exactly what a zero-allocation
//! assertion wants: a pool worker allocating on the hot path must fail the
//! test just like the calling thread would.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCS: AtomicU64 = AtomicU64::new(0);
static ALLOC_BYTES: AtomicU64 = AtomicU64::new(0);

/// Allocation counters at a point in time; subtract two snapshots to get
/// the traffic of the code in between.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AllocSnapshot {
    /// Allocation events (alloc, alloc_zeroed, realloc).
    pub count: u64,
    /// Bytes requested by those events.
    pub bytes: u64,
}

impl AllocSnapshot {
    /// Counters accumulated since `earlier`.
    pub fn since(&self, earlier: &AllocSnapshot) -> AllocSnapshot {
        AllocSnapshot {
            count: self.count.saturating_sub(earlier.count),
            bytes: self.bytes.saturating_sub(earlier.bytes),
        }
    }
}

/// Current process-wide counters. Meaningful only in binaries that
/// installed [`CountingAlloc`] as the global allocator (otherwise both
/// stay zero).
pub fn snapshot() -> AllocSnapshot {
    AllocSnapshot {
        count: ALLOCS.load(Ordering::Relaxed),
        bytes: ALLOC_BYTES.load(Ordering::Relaxed),
    }
}

/// The counting allocator. Delegates everything to [`System`]; the only
/// overhead on the alloc path is two relaxed atomic adds.
pub struct CountingAlloc;

impl CountingAlloc {
    pub const fn new() -> CountingAlloc {
        CountingAlloc
    }
}

impl Default for CountingAlloc {
    fn default() -> Self {
        CountingAlloc::new()
    }
}

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(layout.size() as u64, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        ALLOC_BYTES.fetch_add(new_size as u64, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}
