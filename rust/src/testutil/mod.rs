//! Mini property-testing harness (proptest is not in the offline vendor
//! set): seeded random case generation with failure reporting. Shrinking is
//! deliberately simple — on failure the harness re-runs the failing seed
//! with progressively smaller size hints and reports the smallest failure.

pub mod prop;
