//! Mini property-testing harness (proptest is not in the offline vendor
//! set): seeded random case generation with failure reporting. Shrinking is
//! deliberately simple — on failure the harness re-runs the failing seed
//! with progressively smaller size hints and reports the smallest failure.
//! Plus [`alloc`]: a counting global allocator for zero-allocation
//! regression tests and the bench's allocation-bytes columns.

pub mod alloc;
pub mod prop;

/// Gate for PJRT/artifact-dependent integration tests: true when the AOT
/// bundle is present, otherwise prints a visible SKIP notice and returns
/// false so `cargo test -q` stays green on a fresh clone. Set
/// `POWERBERT_REQUIRE_ARTIFACTS=1` (artifact-equipped CI) to turn a missing
/// bundle into a panic instead of a skip.
pub fn artifacts_available() -> bool {
    let root = crate::runtime::default_root();
    let ok = root.join("vocab.json").exists()
        && crate::runtime::Registry::scan(&root)
            .map(|r| !r.datasets.is_empty())
            .unwrap_or(false);
    if !ok {
        let msg = format!(
            "SKIP: no artifacts at {} — run `make artifacts` (or set POWERBERT_ARTIFACTS)",
            root.display()
        );
        if std::env::var("POWERBERT_REQUIRE_ARTIFACTS").is_ok_and(|v| v == "1") {
            panic!("POWERBERT_REQUIRE_ARTIFACTS=1 but artifacts are missing: {msg}");
        }
        eprintln!("{msg}");
    }
    ok
}
