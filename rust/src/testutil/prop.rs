//! `forall(cases, |rng, size| ...)` — seeded random property testing.
//!
//! Usage:
//! ```no_run
//! use powerbert::testutil::prop::forall;
//! forall("sorted stays sorted", 200, |rng, size| {
//!     let mut v: Vec<u64> = (0..size).map(|_| rng.below(1000)).collect();
//!     v.sort();
//!     assert!(v.windows(2).all(|w| w[0] <= w[1]));
//! });
//! ```

use crate::util::prng::Rng;

/// Runs `prop` for `cases` seeded cases with growing size hints (1..=64).
/// On panic, retries the same seed at smaller sizes to report the smallest
/// failing size, then re-panics with the seed for reproduction.
pub fn forall<F>(name: &str, cases: u64, prop: F)
where
    F: Fn(&mut Rng, usize) + std::panic::RefUnwindSafe,
{
    for case in 0..cases {
        let seed = 0x5EED_0000 + case;
        let size = 1 + (case as usize * 7) % 64;
        let result = std::panic::catch_unwind(|| {
            let mut rng = Rng::new(seed);
            prop(&mut rng, size);
        });
        if result.is_err() {
            // Simple shrink: find the smallest size that still fails.
            let mut smallest = size;
            for s in 1..size {
                let r = std::panic::catch_unwind(|| {
                    let mut rng = Rng::new(seed);
                    prop(&mut rng, s);
                });
                if r.is_err() {
                    smallest = s;
                    break;
                }
            }
            panic!(
                "property {name:?} failed: seed={seed:#x} size={size} (smallest failing size {smallest})"
            );
        }
    }
}

/// Random vector helper.
pub fn vec_u64(rng: &mut Rng, len: usize, bound: u64) -> Vec<u64> {
    (0..len).map(|_| rng.below(bound.max(1))).collect()
}

/// Random f64 vector in [0, bound).
pub fn vec_f64(rng: &mut Rng, len: usize, bound: f64) -> Vec<f64> {
    (0..len).map(|_| rng.f64() * bound).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall("reverse twice is identity", 50, |rng, size| {
            let v = vec_u64(rng, size, 100);
            let mut w = v.clone();
            w.reverse();
            w.reverse();
            assert_eq!(v, w);
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_reports() {
        forall("impossible", 10, |rng, size| {
            let v = vec_u64(rng, size.max(3), 10);
            assert!(v.iter().sum::<u64>() > 1000, "sums are small");
        });
    }
}
