//! `PowerClient` — first-class typed client for wire protocol v2.
//!
//! Speaks the multiplexed dialect of [`crate::coordinator::protocol`] over
//! one TCP connection: client-assigned request ids, any number of requests
//! in flight, completions matched by id in whatever order the server
//! finishes them. A background reader thread parses incoming frames and
//! routes them through a pending map to per-request channels; [`Ticket`]
//! is the await handle. The request vocabulary ([`Input`], [`Sla`],
//! [`Response`]) is exactly the coordinator's own — what you'd pass to
//! [`crate::coordinator::Client::classify`] in process, you pass here over
//! the wire.
//!
//! ```no_run
//! use powerbert::client::PowerClient;
//! use powerbert::coordinator::{Input, Sla};
//!
//! let client = PowerClient::connect("127.0.0.1:7878").unwrap();
//! println!("serving {:?} on {}", client.hello().datasets, client.hello().backend);
//! // Blocking call:
//! let resp = client
//!     .classify("sst2", Input::Text { a: "pos_1 filler_2".into(), b: None }, Sla::default())
//!     .unwrap();
//! // Pipelined: submit many, then wait — responses stream back out of order.
//! let tickets: Vec<_> = (0..32)
//!     .map(|_| {
//!         client
//!             .submit("sst2", Input::Text { a: "pos_1".into(), b: None }, Sla::default())
//!             .unwrap()
//!     })
//!     .collect();
//! for t in tickets {
//!     println!("label {}", t.wait().unwrap().label);
//! }
//! # let _ = resp;
//! ```

use std::collections::{BTreeMap, HashMap};
use std::io::{BufRead, BufReader, Write};
use std::net::{Shutdown, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};

use crate::coordinator::protocol::{self, ErrorCode, PROTOCOL_VERSION};
use crate::coordinator::{Input, Response, Sla};
use crate::util::json::Json;

/// Client-side error, mirroring the wire protocol's structured codes.
#[derive(Debug, Clone)]
pub enum ClientError {
    /// Socket-level failure (connect, read, write).
    Io(String),
    /// The server sent something this client cannot interpret.
    Protocol(String),
    /// The server answered with a structured v2 error frame.
    Server { code: ErrorCode, message: String },
    /// The connection closed with requests still in flight.
    Disconnected,
}

impl ClientError {
    /// The wire error code, when the server reported one.
    pub fn code(&self) -> Option<ErrorCode> {
        match self {
            ClientError::Server { code, .. } => Some(*code),
            _ => None,
        }
    }
}

impl std::fmt::Display for ClientError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ClientError::Io(e) => write!(f, "io error: {e}"),
            ClientError::Protocol(e) => write!(f, "protocol error: {e}"),
            ClientError::Server { code, message } => write!(f, "server error [{code}]: {message}"),
            ClientError::Disconnected => write!(f, "connection closed"),
        }
    }
}

impl std::error::Error for ClientError {}

/// One variant as advertised in the hello frame / `variants` command.
#[derive(Debug, Clone)]
pub struct VariantInfo {
    pub variant: String,
    pub kind: String,
    pub metric: String,
    pub dev_metric: Option<f64>,
    pub seq_len: usize,
    pub num_classes: usize,
    /// Σ word-vectors kept across layers — the paper's cost proxy; lower
    /// is faster at equal seq_len.
    pub aggregate_word_vectors: usize,
    pub retention: Option<Vec<usize>>,
    /// Whether the variant carries a calibrated Pareto table — the named
    /// compute tiers (`balanced`/`fast`) resolve to measured operating
    /// points instead of degrading to the fixed schedule.
    pub adaptive_calibrated: bool,
}

impl VariantInfo {
    fn parse(j: &Json) -> Result<VariantInfo, ClientError> {
        let s = |k: &str| {
            j.get(k)
                .and_then(Json::as_str)
                .map(String::from)
                .ok_or_else(|| ClientError::Protocol(format!("variant entry missing {k:?}")))
        };
        Ok(VariantInfo {
            variant: s("variant")?,
            kind: s("kind")?,
            metric: s("metric")?,
            dev_metric: j.get("dev_metric").and_then(Json::as_f64),
            seq_len: j.get("seq_len").and_then(Json::as_usize).unwrap_or(0),
            num_classes: j.get("num_classes").and_then(Json::as_usize).unwrap_or(0),
            aggregate_word_vectors: j
                .get("aggregate_word_vectors")
                .and_then(Json::as_usize)
                .unwrap_or(0),
            retention: j.get("retention").and_then(Json::as_arr).map(|a| {
                a.iter().filter_map(Json::as_usize).collect()
            }),
            adaptive_calibrated: j
                .get("adaptive_calibrated")
                .and_then(Json::as_bool)
                .unwrap_or(false),
        })
    }
}

/// Artifact-repository status as advertised in the `hello`/`stats` frames
/// and echoed by the admin commands (`reload`, `add-variant`).
#[derive(Debug, Clone, Default)]
pub struct RepoInfo {
    /// Manifest revision of the live snapshot (0 = unmanaged bundle).
    pub revision: u64,
    /// Monotonic swap counter; bumps on every successful hot reload.
    pub generation: u64,
    /// Whether the manifest signature verified against the trusted key.
    pub signed: bool,
    /// Manifest-listed files that hashed clean at the last verification.
    pub verified_files: u64,
    /// Datasets excluded because a file of theirs failed verification.
    pub excluded: Vec<String>,
    /// Datasets the live snapshot serves (present on admin replies).
    pub datasets: Vec<String>,
}

impl RepoInfo {
    fn parse(j: &Json) -> RepoInfo {
        let strs = |k: &str| {
            j.get(k)
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(|s| s.as_str().map(String::from)).collect())
                .unwrap_or_default()
        };
        RepoInfo {
            revision: j.get("revision").and_then(Json::as_u64).unwrap_or(0),
            generation: j.get("generation").and_then(Json::as_u64).unwrap_or(0),
            signed: j.get("signed").and_then(Json::as_bool).unwrap_or(false),
            verified_files: j.get("verified_files").and_then(Json::as_u64).unwrap_or(0),
            excluded: strs("excluded"),
            datasets: strs("datasets"),
        }
    }
}

/// Server capabilities from the hello frame: everything needed to pick a
/// dataset, variant, and SLA without out-of-band knowledge.
#[derive(Debug, Clone)]
pub struct ServerInfo {
    pub proto: u64,
    pub server: String,
    /// The server's *configured* backend selection (`pjrt`, `native`, or
    /// `auto`). `auto` resolves per variant at load time on the server, so
    /// it is reported as-is rather than as a guessed resolution.
    pub backend: String,
    /// Native weight precision the workers pack at ("f32" / "int8"); empty
    /// when the server predates the field.
    pub precision: String,
    /// Instruction set the server's kernels dispatch to ("scalar" /
    /// "avx2+fma"); empty when the server predates the field.
    pub isa: String,
    /// Whether native workers execute the ragged per-example path (compute
    /// = Σ kept tokens rather than the padded batch-max rectangle); false
    /// when the server predates the field or runs `--ragged off`.
    pub ragged: bool,
    pub datasets: Vec<String>,
    pub variants: BTreeMap<String, Vec<VariantInfo>>,
    pub seq_buckets: Vec<usize>,
    pub max_connections: usize,
    /// Requests the server allows in flight on one connection before it
    /// answers `overloaded`; the useful ceiling for pipeline depth.
    pub max_inflight_per_connection: usize,
    /// Connection edge the server runs ("threads" / "epoll"); empty when
    /// the server predates the field.
    pub edge: String,
    /// Whether the server understands the v2 `compute` field (per-request
    /// adaptive retention); false when the server predates it.
    pub adaptive: bool,
    /// Artifact-repository status (revision, signature, exclusions);
    /// `None` when the server predates the repo capability.
    pub repo: Option<RepoInfo>,
}

impl ServerInfo {
    fn parse(j: &Json) -> Result<ServerInfo, ClientError> {
        let proto = j
            .get("proto")
            .and_then(Json::as_u64)
            .ok_or_else(|| ClientError::Protocol("hello missing proto".into()))?;
        let datasets = j
            .get("datasets")
            .and_then(Json::as_arr)
            .map(|a| a.iter().filter_map(|d| d.as_str().map(String::from)).collect())
            .unwrap_or_default();
        let mut variants = BTreeMap::new();
        if let Some(m) = j.get("variants").and_then(Json::as_obj) {
            for (ds, list) in m {
                let parsed = list
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(VariantInfo::parse)
                    .collect::<Result<Vec<_>, _>>()?;
                variants.insert(ds.clone(), parsed);
            }
        }
        Ok(ServerInfo {
            proto,
            server: j.get("server").and_then(Json::as_str).unwrap_or("").to_string(),
            backend: j.get("backend").and_then(Json::as_str).unwrap_or("").to_string(),
            precision: j.get("precision").and_then(Json::as_str).unwrap_or("").to_string(),
            isa: j.get("isa").and_then(Json::as_str).unwrap_or("").to_string(),
            ragged: j.get("ragged").and_then(Json::as_bool).unwrap_or(false),
            datasets,
            variants,
            seq_buckets: j
                .get("seq_buckets")
                .and_then(Json::as_arr)
                .map(|a| a.iter().filter_map(Json::as_usize).collect())
                .unwrap_or_default(),
            max_connections: j.get("max_connections").and_then(Json::as_usize).unwrap_or(0),
            max_inflight_per_connection: j
                .get("max_inflight_per_connection")
                .and_then(Json::as_usize)
                .unwrap_or(0),
            edge: j.get("edge").and_then(Json::as_str).unwrap_or("").to_string(),
            adaptive: j.get("adaptive").and_then(Json::as_bool).unwrap_or(false),
            repo: j.get("repo").map(RepoInfo::parse),
        })
    }
}

/// Structured server statistics (`stats` command). Headline figures are
/// typed; the full per-variant breakdown stays available as JSON.
#[derive(Debug, Clone)]
pub struct ServerStats {
    pub uptime_secs: f64,
    /// Executed tokens per real token across all variants (1.0 = none).
    pub padding_waste: f64,
    pub connections_current: usize,
    pub connections_max: usize,
    /// Connection edge the server runs ("threads" / "epoll").
    pub edge: String,
    /// Open fds of the server process vs its `RLIMIT_NOFILE` soft limit —
    /// the fd-pressure gauge (None where the server has no procfs).
    pub fd_open: Option<u64>,
    pub fd_limit: Option<u64>,
    /// Bytes buffered in the epoll edge's per-connection read/write
    /// buffers (zero on the threads edge).
    pub read_buffer_bytes: u64,
    pub write_buffer_bytes: u64,
    /// Cumulative partial-write stalls (EPOLLOUT registrations).
    pub epollout_stalls: u64,
    /// Connections currently read-paused by write backpressure.
    pub reads_paused: u64,
    /// The complete stats object (per-variant histograms, workers, ...).
    pub raw: Json,
}

/// Routing state shared between the caller side and the reader thread.
struct Shared {
    /// In-flight request id -> reply channel. The reader thread removes
    /// and fulfils entries as frames arrive, in any order.
    pending: Mutex<HashMap<u64, Sender<Result<Json, ClientError>>>>,
    /// Set once when the connection dies; every later call fails fast.
    dead: Mutex<Option<ClientError>>,
}

impl Shared {
    /// Fail every in-flight request and remember why.
    fn poison(&self, err: ClientError) {
        {
            let mut dead = self.dead.lock().unwrap();
            if dead.is_none() {
                *dead = Some(err.clone());
            }
        }
        for (_, tx) in self.pending.lock().unwrap().drain() {
            let _ = tx.send(Err(err.clone()));
        }
    }
}

/// Await handle for one pipelined request.
pub struct Ticket {
    id: u64,
    rx: Receiver<Result<Json, ClientError>>,
}

impl Ticket {
    /// The client-assigned request id (echoed by the server).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Block until this request's frame arrives, in completion order —
    /// other tickets of the same connection resolve independently.
    pub fn wait(self) -> Result<Response, ClientError> {
        let frame = self.rx.recv().map_err(|_| ClientError::Disconnected)?;
        decode_reply(self.id, frame)
    }

    /// Non-blocking poll: `Some` once the response has arrived (consume
    /// the ticket's result without waiting behind older tickets), `None`
    /// while still in flight. After `Some`, the ticket is spent — drop it.
    pub fn poll(&mut self) -> Option<Result<Response, ClientError>> {
        match self.rx.try_recv() {
            Ok(frame) => Some(decode_reply(self.id, frame)),
            Err(std::sync::mpsc::TryRecvError::Empty) => None,
            Err(std::sync::mpsc::TryRecvError::Disconnected) => {
                Some(Err(ClientError::Disconnected))
            }
        }
    }
}

/// Decode a routed reply frame into the typed response.
fn decode_reply(id: u64, frame: Result<Json, ClientError>) -> Result<Response, ClientError> {
    let frame = frame?;
    reply_error(&frame)?;
    let result = frame
        .get("result")
        .ok_or_else(|| ClientError::Protocol("reply frame has no result".into()))?;
    protocol::response_from_payload(id, result).map_err(ClientError::Protocol)
}

/// Extract a structured error from a reply frame, if it carries one.
fn reply_error(frame: &Json) -> Result<(), ClientError> {
    let Some(e) = frame.get("error") else { return Ok(()) };
    // v2 shape: {"code": ..., "message": ...}; v1 shape: a bare string.
    if let Some(msg) = e.as_str() {
        let code = frame
            .get("code")
            .and_then(Json::as_str)
            .map(ErrorCode::parse)
            .unwrap_or(ErrorCode::Other);
        return Err(ClientError::Server { code, message: msg.to_string() });
    }
    let code = e
        .get("code")
        .and_then(Json::as_str)
        .map(ErrorCode::parse)
        .unwrap_or(ErrorCode::Other);
    let message = e.get("message").and_then(Json::as_str).unwrap_or("").to_string();
    Err(ClientError::Server { code, message })
}

/// Typed client for a PoWER-BERT serving endpoint (wire protocol v2).
pub struct PowerClient {
    writer: Mutex<TcpStream>,
    shared: Arc<Shared>,
    next_id: AtomicU64,
    info: ServerInfo,
}

impl PowerClient {
    /// Connect, perform the hello handshake, and start the background
    /// reader. Fails if the endpoint does not speak protocol v2.
    pub fn connect(addr: impl ToSocketAddrs) -> Result<PowerClient, ClientError> {
        let stream = TcpStream::connect(addr).map_err(|e| ClientError::Io(e.to_string()))?;
        let mut read_half =
            BufReader::new(stream.try_clone().map_err(|e| ClientError::Io(e.to_string()))?);

        // Handshake runs synchronously before the reader thread exists:
        // id 0 is reserved for it and never reused.
        let mut writer = stream;
        let hello = protocol::cmd_frame(0, "hello", None).to_string();
        writer
            .write_all(hello.as_bytes())
            .and_then(|()| writer.write_all(b"\n"))
            .and_then(|()| writer.flush())
            .map_err(|e| ClientError::Io(e.to_string()))?;
        let mut line = String::new();
        read_half
            .read_line(&mut line)
            .map_err(|e| ClientError::Io(e.to_string()))?;
        if line.is_empty() {
            return Err(ClientError::Disconnected);
        }
        let frame = Json::parse(line.trim())
            .map_err(|e| ClientError::Protocol(format!("bad hello reply: {e}")))?;
        reply_error(&frame)?;
        let info = ServerInfo::parse(
            frame
                .get("hello")
                .ok_or_else(|| ClientError::Protocol("hello reply has no hello payload".into()))?,
        )?;
        if info.proto != PROTOCOL_VERSION {
            return Err(ClientError::Protocol(format!(
                "server speaks protocol {} (want {PROTOCOL_VERSION})",
                info.proto
            )));
        }

        let shared = Arc::new(Shared {
            pending: Mutex::new(HashMap::new()),
            dead: Mutex::new(None),
        });
        let reader_shared = shared.clone();
        std::thread::Builder::new()
            .name("pb-client-reader".into())
            .spawn(move || reader_loop(read_half, reader_shared))
            .map_err(|e| ClientError::Io(e.to_string()))?;

        Ok(PowerClient {
            writer: Mutex::new(writer),
            shared,
            next_id: AtomicU64::new(1),
            info,
        })
    }

    /// Server capabilities captured during the connect handshake.
    pub fn hello(&self) -> &ServerInfo {
        &self.info
    }

    /// Submit one request; returns immediately with a [`Ticket`]. Any
    /// number of tickets may be outstanding — this is what fills the
    /// server's `(batch, seq)` buckets from a single connection.
    pub fn submit(&self, dataset: &str, input: Input, sla: Sla) -> Result<Ticket, ClientError> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let rx = self.register(id)?;
        let frame = protocol::request_frame(id, dataset, &input, &sla, true);
        if let Err(e) = self.send_line(&frame.to_string()) {
            self.unregister(id);
            return Err(e);
        }
        Ok(Ticket { id, rx })
    }

    /// Submit and block for the response.
    pub fn classify(
        &self,
        dataset: &str,
        input: Input,
        sla: Sla,
    ) -> Result<Response, ClientError> {
        self.submit(dataset, input, sla)?.wait()
    }

    /// Submit many inputs as one `{"v":2,"batch":[...]}` frame — the
    /// server enqueues them back-to-back so the dynamic batcher sees them
    /// as a unit — and block until all have resolved. Responses come back
    /// in input order; the first error wins.
    pub fn classify_batch(
        &self,
        dataset: &str,
        inputs: Vec<Input>,
        sla: &Sla,
    ) -> Result<Vec<Response>, ClientError> {
        let mut entries = Vec::with_capacity(inputs.len());
        let mut tickets = Vec::with_capacity(inputs.len());
        for input in &inputs {
            let id = self.next_id.fetch_add(1, Ordering::Relaxed);
            let rx = self.register(id)?;
            entries.push(protocol::request_frame(id, dataset, input, sla, false));
            tickets.push(Ticket { id, rx });
        }
        if let Err(e) = self.send_line(&protocol::batch_frame(entries).to_string()) {
            for t in &tickets {
                self.unregister(t.id);
            }
            return Err(e);
        }
        tickets.into_iter().map(Ticket::wait).collect()
    }

    /// Structured server statistics, including connection counts.
    pub fn stats(&self) -> Result<ServerStats, ClientError> {
        let frame = self.command("stats", None)?;
        let stats = frame
            .get("stats")
            .ok_or_else(|| ClientError::Protocol("stats reply has no stats payload".into()))?;
        let f = |k: &str| stats.get(k).and_then(Json::as_f64).unwrap_or(0.0);
        let conn = |k: &str| {
            stats
                .get("connections")
                .and_then(|c| c.get(k))
                .and_then(Json::as_usize)
                .unwrap_or(0)
        };
        let cu64 = |k: &str| {
            stats
                .get("connections")
                .and_then(|c| c.get(k))
                .and_then(Json::as_u64)
        };
        Ok(ServerStats {
            uptime_secs: f("uptime_secs"),
            padding_waste: f("padding_waste"),
            connections_current: conn("current"),
            connections_max: conn("max"),
            edge: stats
                .get("connections")
                .and_then(|c| c.get("edge"))
                .and_then(Json::as_str)
                .unwrap_or("")
                .to_string(),
            fd_open: cu64("fd_open"),
            fd_limit: cu64("fd_limit"),
            read_buffer_bytes: cu64("read_buffer_bytes").unwrap_or(0),
            write_buffer_bytes: cu64("write_buffer_bytes").unwrap_or(0),
            epollout_stalls: cu64("epollout_stalls").unwrap_or(0),
            reads_paused: cu64("reads_paused").unwrap_or(0),
            raw: stats.clone(),
        })
    }

    /// Routable variants of a dataset, with their dev metrics and costs.
    pub fn variants(&self, dataset: &str) -> Result<Vec<VariantInfo>, ClientError> {
        let frame = self.command("variants", Some(dataset))?;
        frame
            .get("variants")
            .and_then(Json::as_arr)
            .ok_or_else(|| ClientError::Protocol("variants reply has no list".into()))?
            .iter()
            .map(VariantInfo::parse)
            .collect()
    }

    /// Re-fetch the server's capabilities with a live `hello` command.
    /// Unlike [`PowerClient::hello`] (captured once at connect), this
    /// reflects hot reloads that happened since.
    pub fn fetch_hello(&self) -> Result<ServerInfo, ClientError> {
        let frame = self.command("hello", None)?;
        ServerInfo::parse(
            frame
                .get("hello")
                .ok_or_else(|| ClientError::Protocol("hello reply has no hello payload".into()))?,
        )
    }

    /// Ask the server to re-verify its artifact root and atomically swap
    /// in the new snapshot (`cmd:"reload"`). Blocks until the verify +
    /// swap completes; in-flight requests finish on the old snapshot.
    pub fn reload(&self) -> Result<RepoInfo, ClientError> {
        let frame = self.admin_command("reload", None, None)?;
        frame
            .get("reload")
            .map(RepoInfo::parse)
            .ok_or_else(|| ClientError::Protocol("reload reply has no payload".into()))
    }

    /// Reload and confirm that `dataset/variant` is served afterwards
    /// (`cmd:"add-variant"`) — the hot path for dropping a new exported
    /// bundle into the artifact root of a running server.
    pub fn add_variant(&self, dataset: &str, variant: &str) -> Result<RepoInfo, ClientError> {
        let frame = self.admin_command("add-variant", Some(dataset), Some(variant))?;
        frame
            .get("add_variant")
            .map(RepoInfo::parse)
            .ok_or_else(|| ClientError::Protocol("add-variant reply has no payload".into()))
    }

    fn command(&self, cmd: &str, dataset: Option<&str>) -> Result<Json, ClientError> {
        self.roundtrip(|id| protocol::cmd_frame(id, cmd, dataset))
    }

    fn admin_command(
        &self,
        cmd: &str,
        dataset: Option<&str>,
        variant: Option<&str>,
    ) -> Result<Json, ClientError> {
        self.roundtrip(|id| protocol::admin_frame(id, cmd, dataset, variant))
    }

    /// Send one command frame and block for its routed reply.
    fn roundtrip(&self, build: impl FnOnce(u64) -> Json) -> Result<Json, ClientError> {
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let rx = self.register(id)?;
        let frame = build(id);
        if let Err(e) = self.send_line(&frame.to_string()) {
            self.unregister(id);
            return Err(e);
        }
        let frame = rx.recv().map_err(|_| ClientError::Disconnected)??;
        reply_error(&frame)?;
        Ok(frame)
    }

    /// Register a pending entry *before* writing the request — the reply
    /// could otherwise race the bookkeeping. Insert-then-check ordering
    /// closes the race against `Shared::poison`: a poison that runs after
    /// the insert drains our entry (the ticket resolves to the error), and
    /// one that ran before it is observed by the dead-check here.
    fn register(&self, id: u64) -> Result<Receiver<Result<Json, ClientError>>, ClientError> {
        let (tx, rx) = channel();
        self.shared.pending.lock().unwrap().insert(id, tx);
        if let Some(e) = self.shared.dead.lock().unwrap().clone() {
            self.shared.pending.lock().unwrap().remove(&id);
            return Err(e);
        }
        Ok(rx)
    }

    fn unregister(&self, id: u64) {
        self.shared.pending.lock().unwrap().remove(&id);
    }

    fn send_line(&self, line: &str) -> Result<(), ClientError> {
        let mut w = self.writer.lock().unwrap();
        w.write_all(line.as_bytes())
            .and_then(|()| w.write_all(b"\n"))
            .and_then(|()| w.flush())
            .map_err(|e| ClientError::Io(e.to_string()))
    }
}

impl Drop for PowerClient {
    fn drop(&mut self) {
        // Unblock the reader thread; in-flight tickets resolve to
        // Disconnected rather than hanging.
        if let Ok(w) = self.writer.lock() {
            let _ = w.shutdown(Shutdown::Both);
        }
    }
}

fn reader_loop(mut reader: BufReader<TcpStream>, shared: Arc<Shared>) {
    let mut line = String::new();
    loop {
        line.clear();
        match reader.read_line(&mut line) {
            Ok(0) => {
                shared.poison(ClientError::Disconnected);
                return;
            }
            Ok(_) => {}
            Err(e) => {
                shared.poison(ClientError::Io(e.to_string()));
                return;
            }
        }
        let trimmed = line.trim();
        if trimmed.is_empty() {
            continue;
        }
        let frame = match Json::parse(trimmed) {
            Ok(j) => j,
            Err(e) => {
                shared.poison(ClientError::Protocol(format!("unparseable frame: {e}")));
                return;
            }
        };
        match frame.get("id").and_then(Json::as_u64) {
            Some(id) => {
                if let Some(tx) = shared.pending.lock().unwrap().remove(&id) {
                    let _ = tx.send(Ok(frame));
                }
                // No pending entry: a reply to an abandoned request; drop.
            }
            None => {
                // A frame without an id cannot be routed: it is a
                // connection-level error (e.g. the capacity shed notice or
                // a bad_json verdict on something this client sent).
                if let Err(e) = reply_error(&frame) {
                    shared.poison(e);
                    return;
                }
                // Anything else unroutable is ignored for forward compat.
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reply_error_reads_both_shapes() {
        let v2 = Json::parse(r#"{"v":2,"id":1,"error":{"code":"overloaded","message":"q"}}"#)
            .unwrap();
        match reply_error(&v2).unwrap_err() {
            ClientError::Server { code, message } => {
                assert_eq!(code, ErrorCode::Overloaded);
                assert_eq!(message, "q");
            }
            other => panic!("wrong error: {other:?}"),
        }
        let v1 = Json::parse(r#"{"error":"server at connection capacity","code":"overloaded"}"#)
            .unwrap();
        match reply_error(&v1).unwrap_err() {
            ClientError::Server { code, .. } => assert_eq!(code, ErrorCode::Overloaded),
            other => panic!("wrong error: {other:?}"),
        }
        let ok = Json::parse(r#"{"v":2,"id":1,"result":{}}"#).unwrap();
        assert!(reply_error(&ok).is_ok());
    }

    #[test]
    fn server_info_parses_hello_payload() {
        let j = Json::parse(
            r#"{"proto":2,"server":"powerbert/0.1.0","backend":"native",
                "datasets":["sst2"],
                "variants":{"sst2":[{"variant":"bert","kind":"bert","metric":"accuracy",
                  "dev_metric":0.91,"seq_len":64,"num_classes":2,
                  "aggregate_word_vectors":768}]},
                "precision":"int8","isa":"avx2+fma","adaptive":true,"ragged":true,
                "seq_buckets":[16,32],"max_connections":256}"#,
        )
        .unwrap();
        let info = ServerInfo::parse(&j).unwrap();
        assert_eq!(info.proto, 2);
        assert_eq!(info.datasets, vec!["sst2".to_string()]);
        assert_eq!(info.seq_buckets, vec![16, 32]);
        assert_eq!(info.max_connections, 256);
        assert_eq!(info.precision, "int8");
        assert_eq!(info.isa, "avx2+fma");
        assert!(info.adaptive);
        assert!(info.ragged);
        let vs = &info.variants["sst2"];
        assert_eq!(vs[0].variant, "bert");
        assert_eq!(vs[0].dev_metric, Some(0.91));
        assert!(vs[0].retention.is_none());
        // Absent flag (older server) parses as uncalibrated, not an error.
        assert!(!vs[0].adaptive_calibrated);
    }

    #[test]
    fn poison_fails_pending_and_future() {
        let shared = Shared {
            pending: Mutex::new(HashMap::new()),
            dead: Mutex::new(None),
        };
        let (tx, rx) = channel();
        shared.pending.lock().unwrap().insert(7, tx);
        shared.poison(ClientError::Disconnected);
        assert!(matches!(rx.recv().unwrap(), Err(ClientError::Disconnected)));
        assert!(shared.dead.lock().unwrap().is_some());
        assert!(shared.pending.lock().unwrap().is_empty());
    }
}
