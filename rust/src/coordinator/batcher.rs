//! Dynamic batcher: groups requests by (dataset, variant, seq-bucket) into
//! batches, flushing when a batch reaches the target size or the oldest
//! member has waited `max_wait` (size-or-deadline policy).
//!
//! Keying on the seq bucket — the tokenizer's true token count rounded up
//! to the nearest configured bucket — is what keeps a batch of tweets from
//! being padded out to the one essay that arrived with them: each flushed
//! batch executes at the smallest (batch, seq) cell that fits it.
//!
//! The batcher itself is a pure data structure (no threads), which is what
//! makes its invariants property-testable: the scheduler drives it from the
//! coordinator's front loop.

use std::collections::{HashMap, VecDeque};
use std::time::{Duration, Instant};

use super::request::Job;

/// Batching policy knobs.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// Flush as soon as a queue holds this many rows (usually the largest
    /// compiled bucket of the variant).
    pub max_batch: usize,
    /// Flush any queue whose oldest request has waited this long.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 32, max_wait: Duration::from_millis(5) }
    }
}

/// What a batch queue is keyed by: one model variant at one seq bucket and
/// one adaptive operating point. Jobs under different keys never share a
/// batch, so a flushed batch is homogeneous in the executable it needs,
/// its row length, *and* its retention threshold — the threshold is a
/// batch-level execution parameter (one retention decision per extract
/// layer), so a `fast` request co-batched with a `full` one would execute
/// at the full operating point; they are kept apart instead. Under ragged
/// execution a homogeneous fast-tier batch then really does pay only its
/// own Σ kept word-vectors.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct BatchKey {
    /// "dataset/variant"
    pub variant: String,
    /// Row length the member jobs are encoded to.
    pub seq: usize,
    /// Adaptive attention-mass threshold as raw bits (`f32::to_bits`) so
    /// the key stays `Eq + Hash`; `None` = the fixed schedule.
    pub threshold: Option<u32>,
    /// Repository snapshot generation the member jobs were routed under
    /// (0 = unversioned). Keying on it means a batch never mixes weights
    /// from before and after a hot reload, even for the same variant name.
    pub rev: u64,
}

impl BatchKey {
    pub fn new(variant: impl Into<String>, seq: usize) -> BatchKey {
        BatchKey { variant: variant.into(), seq, threshold: None, rev: 0 }
    }

    /// Key for a specific adaptive operating point.
    pub fn with_threshold(
        variant: impl Into<String>,
        seq: usize,
        threshold: Option<f32>,
    ) -> BatchKey {
        BatchKey { variant: variant.into(), seq, threshold: threshold.map(f32::to_bits), rev: 0 }
    }

    /// Key pinned to a repository snapshot generation.
    pub fn with_revision(
        variant: impl Into<String>,
        seq: usize,
        threshold: Option<f32>,
        rev: u64,
    ) -> BatchKey {
        BatchKey { variant: variant.into(), seq, threshold: threshold.map(f32::to_bits), rev }
    }

    /// The threshold back as a float (`None` = fixed schedule).
    pub fn threshold_f32(&self) -> Option<f32> {
        self.threshold.map(f32::from_bits)
    }
}

impl std::fmt::Display for BatchKey {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}@s{}", self.variant, self.seq)?;
        if let Some(t) = self.threshold_f32() {
            write!(f, "@t{t:.3}")?;
        }
        if self.rev > 0 {
            write!(f, "@g{}", self.rev)?;
        }
        Ok(())
    }
}

/// A flushed batch, ready for the executor.
pub struct Batch {
    pub key: BatchKey,
    pub jobs: Vec<Job>,
}

impl Batch {
    pub fn len(&self) -> usize {
        self.jobs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.jobs.is_empty()
    }
}

struct Queue {
    jobs: VecDeque<Job>,
    oldest: Option<Instant>,
    max_batch: usize,
}

/// The dynamic batcher. `push` adds a job; `due` / `flush_due` yield batches.
pub struct Batcher {
    policy: BatchPolicy,
    queues: HashMap<BatchKey, Queue>,
    /// Per-variant max batch override (largest compiled bucket) — shared by
    /// every seq bucket of the variant.
    bucket_caps: HashMap<String, usize>,
    /// Calibrated kept-token cost ratio per (variant, threshold-bits):
    /// the fraction of full-schedule word-vectors a batch at that
    /// operating point actually processes (`pareto.json` tokens ratios).
    cost_ratios: HashMap<String, HashMap<Option<u32>, f64>>,
    pending: usize,
}

impl Batcher {
    pub fn new(policy: BatchPolicy) -> Batcher {
        Batcher {
            policy,
            queues: HashMap::new(),
            bucket_caps: HashMap::new(),
            cost_ratios: HashMap::new(),
            pending: 0,
        }
    }

    /// Register the largest compiled bucket for a variant key, capping its
    /// batch size (padding past the largest bucket would waste compute).
    pub fn set_bucket_cap(&mut self, key: &str, cap: usize) {
        self.bucket_caps.insert(key.to_string(), cap);
    }

    /// Seed the calibrated kept-token cost ratio for one adaptive
    /// operating point of a variant (from its `pareto.json`). Queues at
    /// that threshold flush at a row capacity scaled by the inverse ratio:
    /// batch cost is priced as predicted total kept tokens, not rows ×
    /// seq, so under ragged execution a fast-tier batch fills to the same
    /// predicted token cost a full-schedule batch would.
    pub fn set_cost_ratio(&mut self, key: &str, threshold: Option<f32>, ratio: f64) {
        self.cost_ratios
            .entry(key.to_string())
            .or_default()
            .insert(threshold.map(f32::to_bits), ratio);
    }

    pub fn pending(&self) -> usize {
        self.pending
    }

    fn max_batch_for(&self, key: &BatchKey) -> usize {
        let cap = self
            .bucket_caps
            .get(&key.variant)
            .copied()
            .unwrap_or(self.policy.max_batch);
        // Token-cost capacity: a queue whose operating point keeps only
        // `ratio` of the word-vectors can take `1/ratio` times the rows
        // for the same predicted kept-token cost. The policy cap stays a
        // hard row ceiling (arena slabs are planned per batch row).
        let ratio = self
            .cost_ratios
            .get(&key.variant)
            .and_then(|m| m.get(&key.threshold))
            .copied()
            .unwrap_or(1.0)
            .clamp(f64::MIN_POSITIVE, 1.0);
        let scaled = ((cap as f64 / ratio) as usize).max(cap);
        scaled.min(self.policy.max_batch).max(1)
    }

    /// Add a job; returns a batch immediately if the queue reached capacity.
    pub fn push(&mut self, key: BatchKey, job: Job, now: Instant) -> Option<Batch> {
        let cap = self.max_batch_for(&key);
        let q = self.queues.entry(key.clone()).or_insert_with(|| Queue {
            jobs: VecDeque::new(),
            oldest: None,
            max_batch: cap,
        });
        q.max_batch = cap;
        if q.jobs.is_empty() {
            q.oldest = Some(now);
        }
        q.jobs.push_back(job);
        self.pending += 1;
        if q.jobs.len() >= cap {
            return self.take(&key, cap);
        }
        None
    }

    fn take(&mut self, key: &BatchKey, n: usize) -> Option<Batch> {
        let q = self.queues.get_mut(key)?;
        let take = n.min(q.jobs.len());
        if take == 0 {
            return None;
        }
        let jobs: Vec<Job> = q.jobs.drain(..take).collect();
        self.pending -= jobs.len();
        q.oldest = if q.jobs.is_empty() { None } else { Some(Instant::now()) };
        Some(Batch { key: key.clone(), jobs })
    }

    /// Earliest deadline across queues (None when idle) — lets the caller
    /// sleep exactly until the next flush is due.
    pub fn next_deadline(&self) -> Option<Instant> {
        self.queues
            .values()
            .filter_map(|q| q.oldest)
            .map(|t| t + self.policy.max_wait)
            .min()
    }

    /// Flush every queue whose deadline has passed (or all non-empty queues
    /// when `force`), oldest deadline first — under load the request that
    /// has waited longest is the first onto an executor.
    pub fn flush_due(&mut self, now: Instant, force: bool) -> Vec<Batch> {
        let mut due: Vec<(Option<Instant>, BatchKey)> = self
            .queues
            .iter()
            .filter(|(_, q)| {
                !q.jobs.is_empty()
                    && (force
                        || q.oldest
                            .map(|t| now.duration_since(t) >= self.policy.max_wait)
                            .unwrap_or(false)
                    || q.jobs.len() >= q.max_batch)
            })
            .map(|(k, q)| (q.oldest, k.clone()))
            .collect();
        due.sort();
        let mut out = Vec::new();
        for (_, k) in due {
            // Drain the whole queue in bucket-sized chunks.
            while let Some(b) = {
                let cap = self.max_batch_for(&k);
                let nonempty = self.queues.get(&k).map(|q| !q.jobs.is_empty()).unwrap_or(false);
                if nonempty {
                    self.take(&k, cap)
                } else {
                    None
                }
            } {
                out.push(b);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{Input, ReplySink, Request, Sla};
    use std::sync::mpsc::channel;

    fn job(id: u64) -> Job {
        let (tx, _rx) = channel();
        Job {
            req: Request {
                id,
                dataset: "sst2".into(),
                input: Input::Text { a: String::new(), b: None },
                sla: Sla::default(),
                submitted: Instant::now(),
            },
            variant: "bert".into(),
            tokens: vec![0; 4],
            segments: vec![0; 4],
            seq: 4,
            real_len: 3,
            threshold: None,
            compute: None,
            snap: None,
            reply: ReplySink::Oneshot(tx),
        }
    }

    fn key(k: &str) -> BatchKey {
        BatchKey::new(k, 4)
    }

    #[test]
    fn flushes_at_capacity() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 3, max_wait: Duration::from_secs(10) });
        let now = Instant::now();
        assert!(b.push(key("k"), job(1), now).is_none());
        assert!(b.push(key("k"), job(2), now).is_none());
        let batch = b.push(key("k"), job(3), now).expect("flush at cap");
        assert_eq!(batch.len(), 3);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn flushes_on_deadline() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 10, max_wait: Duration::from_millis(1) });
        let t0 = Instant::now();
        b.push(key("k"), job(1), t0);
        assert!(b.flush_due(t0, false).is_empty(), "not due yet");
        let later = t0 + Duration::from_millis(2);
        let out = b.flush_due(later, false);
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].len(), 1);
    }

    #[test]
    fn force_flush_drains_everything() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 4, max_wait: Duration::from_secs(10) });
        let now = Instant::now();
        for i in 0..10 {
            b.push(key("a"), job(i), now);
        }
        // 10 jobs: push flushed two full batches of 4 already (at i=3, i=7)
        let out = b.flush_due(now, true);
        let total: usize = out.iter().map(Batch::len).sum();
        assert_eq!(total + 8, 10);
        assert_eq!(b.pending(), 0);
    }

    #[test]
    fn respects_bucket_cap() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 32, max_wait: Duration::from_secs(1) });
        b.set_bucket_cap("k", 2);
        let now = Instant::now();
        assert!(b.push(key("k"), job(1), now).is_none());
        let batch = b.push(key("k"), job(2), now).unwrap();
        assert_eq!(batch.len(), 2);
    }

    #[test]
    fn deadline_tracking() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(5) });
        assert!(b.next_deadline().is_none());
        let now = Instant::now();
        b.push(key("k"), job(1), now);
        let d = b.next_deadline().unwrap();
        assert!(d >= now + Duration::from_millis(5));
    }

    #[test]
    fn seq_buckets_do_not_share_batches() {
        // Same variant, two seq buckets: capacity fills independently and
        // flushed batches stay homogeneous per bucket.
        let mut b = Batcher::new(BatchPolicy { max_batch: 2, max_wait: Duration::from_secs(10) });
        let now = Instant::now();
        assert!(b.push(BatchKey::new("k", 16), job(1), now).is_none());
        assert!(b.push(BatchKey::new("k", 64), job(2), now).is_none());
        let full = b.push(BatchKey::new("k", 16), job(3), now).expect("seq-16 full");
        assert_eq!(full.key.seq, 16);
        assert_eq!(full.len(), 2);
        assert_eq!(b.pending(), 1, "seq-64 job still queued");
        let rest = b.flush_due(now, true);
        assert_eq!(rest.len(), 1);
        assert_eq!(rest[0].key.seq, 64);
    }

    #[test]
    fn operating_points_do_not_share_batches() {
        // Same variant and seq bucket, different thresholds: a fast job
        // must never ride (and pay for) a full-compute batch.
        let mut b = Batcher::new(BatchPolicy { max_batch: 2, max_wait: Duration::from_secs(10) });
        let now = Instant::now();
        let fixed = BatchKey::with_threshold("k", 16, None);
        let fast = BatchKey::with_threshold("k", 16, Some(0.6));
        assert_eq!(fixed, BatchKey::new("k", 16));
        assert_ne!(fixed, fast);
        assert_eq!(fast.threshold_f32(), Some(0.6));
        assert!(b.push(fixed.clone(), job(1), now).is_none());
        assert!(b.push(fast.clone(), job(2), now).is_none());
        let full = b.push(fixed.clone(), job(3), now).expect("fixed queue full");
        assert_eq!(full.key, fixed);
        assert_eq!(full.len(), 2);
        assert_eq!(b.pending(), 1, "fast job still queued");
        let rest = b.flush_due(now, true);
        assert_eq!(rest.len(), 1);
        assert_eq!(rest[0].key, fast);
        assert_eq!(format!("{fast}"), "k@s16@t0.600");
    }

    #[test]
    fn snapshot_generations_do_not_share_batches() {
        // Same variant/seq/threshold before and after a hot reload: jobs
        // routed under different repository generations must never share a
        // batch (they may point at different weights).
        let mut b = Batcher::new(BatchPolicy { max_batch: 2, max_wait: Duration::from_secs(10) });
        let now = Instant::now();
        let old = BatchKey::with_revision("k", 16, None, 1);
        let new = BatchKey::with_revision("k", 16, None, 2);
        assert_ne!(old, new);
        assert_eq!(format!("{new}"), "k@s16@g2");
        assert!(b.push(old.clone(), job(1), now).is_none());
        assert!(b.push(new.clone(), job(2), now).is_none());
        let full = b.push(old.clone(), job(3), now).expect("old-generation queue full");
        assert_eq!(full.key, old);
        assert_eq!(b.pending(), 1, "new-generation job still queued");
    }

    #[test]
    fn cost_ratio_scales_fast_tier_capacity_not_fixed_schedule() {
        // Fast tier keeps 25% of the word-vectors: four times the rows fit
        // the same predicted kept-token cost, so the fast queue flushes at
        // 8 while the fixed-schedule queue still flushes at the bucket cap.
        let mut b = Batcher::new(BatchPolicy { max_batch: 32, max_wait: Duration::from_secs(10) });
        b.set_bucket_cap("k", 2);
        b.set_cost_ratio("k", Some(0.6), 0.25);
        let now = Instant::now();
        let fixed = BatchKey::with_threshold("k", 16, None);
        let fast = BatchKey::with_threshold("k", 16, Some(0.6));
        assert!(b.push(fixed.clone(), job(1), now).is_none());
        let full = b.push(fixed, job(2), now).expect("fixed flushes at bucket cap");
        assert_eq!(full.len(), 2);
        for i in 0..7 {
            assert!(b.push(fast.clone(), job(10 + i), now).is_none(), "job {i} queued");
        }
        let batch = b.push(fast, job(17), now).expect("fast flushes at scaled cap");
        assert_eq!(batch.len(), 8);
        // The policy max stays a hard row ceiling even at extreme ratios.
        b.set_cost_ratio("k", Some(0.4), 0.001);
        let tiny = BatchKey::with_threshold("k", 16, Some(0.4));
        let mut flushed = None;
        for i in 0..32 {
            flushed = b.push(tiny.clone(), job(100 + i), now);
            if flushed.is_some() {
                break;
            }
        }
        assert_eq!(flushed.expect("policy cap flush").len(), 32);
    }

    #[test]
    fn bucket_cap_applies_across_seq_buckets_of_a_variant() {
        let mut b = Batcher::new(BatchPolicy { max_batch: 32, max_wait: Duration::from_secs(1) });
        b.set_bucket_cap("k", 2);
        let now = Instant::now();
        assert!(b.push(BatchKey::new("k", 16), job(1), now).is_none());
        assert!(b.push(BatchKey::new("k", 64), job(2), now).is_none());
        let batch = b.push(BatchKey::new("k", 64), job(3), now).expect("seq-64 at cap");
        assert_eq!(batch.key.seq, 64);
        assert_eq!(batch.len(), 2);
    }
}
