//! Serving metrics: per-variant latency histograms, throughput counters,
//! batch-occupancy tracking. Shared between the executor thread (writer)
//! and the router (reader — uses measured latency for SLA decisions).

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::Instant;

use crate::util::stats::LatencyHistogram;

#[derive(Debug, Default, Clone)]
pub struct VariantStats {
    pub requests: u64,
    pub batches: u64,
    pub batched_rows: u64,
    pub errors: u64,
    pub queue: LatencyHistogram,
    pub exec: LatencyHistogram,
    pub total: LatencyHistogram,
    /// Mean model-execution time per *batch*, by bucket size.
    pub exec_by_bucket: HashMap<usize, (u64 /*count*/, u64 /*sum_us*/)>,
}

impl VariantStats {
    pub fn mean_batch_occupancy(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_rows as f64 / self.batches as f64
        }
    }

    /// Measured mean exec time for the bucket that would serve one request.
    pub fn exec_estimate_us(&self, bucket: usize) -> Option<f64> {
        self.exec_by_bucket
            .get(&bucket)
            .filter(|(c, _)| *c > 0)
            .map(|(c, s)| *s as f64 / *c as f64)
    }
}

/// Process-wide metrics hub.
#[derive(Debug, Default)]
pub struct MetricsHub {
    inner: Mutex<HashMap<String, VariantStats>>,
    started: Option<Instant>,
}

impl MetricsHub {
    pub fn new() -> Self {
        MetricsHub { inner: Mutex::new(HashMap::new()), started: Some(Instant::now()) }
    }

    pub fn record_batch(&self, key: &str, bucket: usize, rows: usize, exec_us: u64) {
        let mut m = self.inner.lock().unwrap();
        let s = m.entry(key.to_string()).or_default();
        s.batches += 1;
        s.batched_rows += rows as u64;
        s.exec.record_us(exec_us);
        let e = s.exec_by_bucket.entry(bucket).or_insert((0, 0));
        e.0 += 1;
        e.1 += exec_us;
    }

    pub fn record_request(&self, key: &str, queue_us: u64, total_us: u64) {
        let mut m = self.inner.lock().unwrap();
        let s = m.entry(key.to_string()).or_default();
        s.requests += 1;
        s.queue.record_us(queue_us);
        s.total.record_us(total_us);
    }

    pub fn record_error(&self, key: &str) {
        let mut m = self.inner.lock().unwrap();
        m.entry(key.to_string()).or_default().errors += 1;
    }

    pub fn snapshot(&self, key: &str) -> Option<VariantStats> {
        self.inner.lock().unwrap().get(key).cloned()
    }

    pub fn snapshot_all(&self) -> Vec<(String, VariantStats)> {
        let m = self.inner.lock().unwrap();
        let mut v: Vec<_> = m.iter().map(|(k, s)| (k.clone(), s.clone())).collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }

    pub fn uptime_secs(&self) -> f64 {
        self.started.map(|s| s.elapsed().as_secs_f64()).unwrap_or(0.0)
    }

    /// Human-readable report (the `powerbert stats` CLI output).
    pub fn report(&self) -> String {
        let mut out = String::new();
        for (key, s) in self.snapshot_all() {
            out.push_str(&format!(
                "{key}: {} reqs, {} batches (mean occupancy {:.1}), errors {}\n  \
                 queue p50/p99: {}/{} us  exec p50/p99: {}/{} us  total p50/p99: {}/{} us\n",
                s.requests,
                s.batches,
                s.mean_batch_occupancy(),
                s.errors,
                s.queue.quantile_us(0.5),
                s.queue.quantile_us(0.99),
                s.exec.quantile_us(0.5),
                s.exec.quantile_us(0.99),
                s.total.quantile_us(0.5),
                s.total.quantile_us(0.99),
            ));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let h = MetricsHub::new();
        h.record_batch("sst2/bert", 8, 5, 1200);
        h.record_request("sst2/bert", 100, 1500);
        h.record_request("sst2/bert", 200, 1700);
        let s = h.snapshot("sst2/bert").unwrap();
        assert_eq!(s.requests, 2);
        assert_eq!(s.batches, 1);
        assert!((s.mean_batch_occupancy() - 5.0).abs() < 1e-9);
        assert!(s.exec_estimate_us(8).unwrap() > 0.0);
        assert!(h.report().contains("sst2/bert"));
    }

    #[test]
    fn errors_counted() {
        let h = MetricsHub::new();
        h.record_error("x/y");
        assert_eq!(h.snapshot("x/y").unwrap().errors, 1);
    }
}
