//! Serving metrics: per-variant latency histograms, throughput counters,
//! batch-occupancy and padding-waste tracking, per-worker utilisation.
//! Shared between the executor workers (writers) and the router (reader —
//! uses measured latency per (batch, seq) cell for SLA decisions).

use std::collections::{BTreeMap, HashMap};
use std::sync::Mutex;
use std::time::Instant;

use crate::runtime::MemoryStats;
use crate::util::json::Json;
use crate::util::stats::LatencyHistogram;

#[derive(Debug, Default, Clone)]
pub struct VariantStats {
    pub requests: u64,
    pub batches: u64,
    pub batched_rows: u64,
    pub errors: u64,
    /// Tokens actually carried by requests (true lengths, pre-padding).
    pub real_tokens: u64,
    /// Tokens executed: Σ batch_bucket × seq_bucket over batches. The ratio
    /// padded/real is the serving-side analog of the paper's word-vector
    /// count — 1.0 means the hardware only ever saw real tokens.
    pub padded_tokens: u64,
    pub queue: LatencyHistogram,
    pub exec: LatencyHistogram,
    pub total: LatencyHistogram,
    /// Mean model-execution time per *batch*, by (batch, seq) cell.
    pub exec_by_cell: HashMap<(usize, usize), (u64 /*count*/, u64 /*sum_us*/)>,
    /// Word-vectors actually processed across encoders (native backend
    /// only; Σ per-row measurements). Under adaptive retention this is the
    /// compute actually spent.
    pub tokens_processed: u64,
    /// Word-vectors the *fixed* schedule would have charged the same rows —
    /// the denominator of the adaptive-savings ratio.
    pub tokens_full: u64,
    /// Operating-point histogram: resolved compute echo (`"full"`,
    /// `"balanced@0.950"`, ...) -> requests served at it.
    pub compute_points: BTreeMap<String, u64>,
}

impl VariantStats {
    pub fn mean_batch_occupancy(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batched_rows as f64 / self.batches as f64
        }
    }

    /// Executed tokens per real token (>= 1.0; 1.0 = zero padding waste).
    pub fn padding_waste(&self) -> f64 {
        if self.real_tokens == 0 {
            1.0
        } else {
            self.padded_tokens as f64 / self.real_tokens as f64
        }
    }

    /// Fraction of fixed-schedule word-vectors actually processed (1.0 =
    /// no adaptive savings; < 1.0 once adaptive requests land).
    pub fn tokens_processed_ratio(&self) -> f64 {
        if self.tokens_full == 0 {
            1.0
        } else {
            self.tokens_processed as f64 / self.tokens_full as f64
        }
    }

    /// Measured mean exec time of one (batch, seq) cell.
    pub fn exec_estimate_us(&self, batch: usize, seq: usize) -> Option<f64> {
        self.exec_by_cell
            .get(&(batch, seq))
            .filter(|(c, _)| *c > 0)
            .map(|(c, s)| *s as f64 / *c as f64)
    }

    /// Measured exec time per executed token for a batch bucket, averaged
    /// over every seq cell of that bucket it has run at. Lets the router
    /// extrapolate an unmeasured (batch, seq) cell from measured siblings
    /// by the token ratio (cost ∝ tokens processed, paper §4.2) instead of
    /// letting cheap short-bucket batches masquerade as full-seq cost.
    pub fn exec_us_per_token(&self, batch: usize) -> Option<f64> {
        let (sum_us, tokens): (u64, u64) = self
            .exec_by_cell
            .iter()
            .filter(|((b, _), _)| *b == batch)
            .fold((0, 0), |(us, tok), ((_, s), (c, ss))| {
                (us + ss, tok + c * (batch * s) as u64)
            });
        if tokens > 0 {
            Some(sum_us as f64 / tokens as f64)
        } else {
            None
        }
    }
}

/// Per-executor-worker counters (pool utilisation and skew), plus the
/// steady-state memory gauges: the scratch-arena footprint and kernel-pool
/// occupancy `stats`/`cmd:hello` consumers read to confirm the worker has
/// stopped allocating and spawning per request.
#[derive(Debug, Default, Clone)]
pub struct WorkerStats {
    pub batches: u64,
    pub rows: u64,
    pub busy_us: u64,
    /// Largest per-bucket scratch arena resident on this worker (bytes),
    /// max over every model it serves.
    pub arena_peak_bytes: u64,
    /// Largest arena count of any one model on this worker (≈ distinct
    /// `(batch, seq)` buckets that model has served) — a gauge of bucket
    /// spread, not a total across co-loaded models.
    pub arena_buckets: u64,
    /// Kernel-pool lanes (persistent workers + the dispatching thread).
    pub pool_threads: u64,
    /// Parallel kernel jobs dispatched to the pool since worker start.
    pub pool_jobs: u64,
    /// Weight precision this worker's models were packed at ("f32" /
    /// "int8"); empty until the first memory snapshot arrives.
    pub precision: &'static str,
    /// Instruction set the worker's kernels dispatch to ("scalar" /
    /// "avx2+fma"); empty until the first memory snapshot arrives.
    pub isa: &'static str,
    /// Word-vectors this worker avoided processing thanks to adaptive
    /// retention (fixed-schedule cost minus measured cost, summed).
    pub tokens_saved: u64,
    /// Word-vector·layer counts the worker's examples themselves demanded
    /// (each at its own adaptive width) — the FLOP-proxy denominator of
    /// [`WorkerStats::eliminated_waste_ratio`].
    pub tokens_kept: u64,
    /// Ghost rows a rectangular batch-max execution adds on top of
    /// `tokens_kept`: the waste ragged execution eliminates (or the
    /// padded oracle incurs).
    pub tokens_ghost: u64,
}

impl WorkerStats {
    /// Ghost-token FLOPs per kept-token FLOP (token counts proxy FLOPs):
    /// 0.0 means compute equals tokens kept; under the padded oracle with
    /// adaptive thresholds it reports the batch-max overhead instead.
    pub fn eliminated_waste_ratio(&self) -> f64 {
        if self.tokens_kept == 0 {
            0.0
        } else {
            self.tokens_ghost as f64 / self.tokens_kept as f64
        }
    }
}

/// Process-wide metrics hub.
#[derive(Debug, Default)]
pub struct MetricsHub {
    inner: Mutex<HashMap<String, VariantStats>>,
    workers: Mutex<Vec<WorkerStats>>,
    started: Option<Instant>,
}

impl MetricsHub {
    pub fn new() -> Self {
        MetricsHub {
            inner: Mutex::new(HashMap::new()),
            workers: Mutex::new(Vec::new()),
            started: Some(Instant::now()),
        }
    }

    /// Record one executed batch: `cell` is the compiled (batch, seq) cell
    /// it ran at, `rows` the real requests inside, `real_tokens` their
    /// summed true lengths.
    pub fn record_batch(
        &self,
        key: &str,
        cell: (usize, usize),
        rows: usize,
        real_tokens: usize,
        exec_us: u64,
    ) {
        let mut m = self.inner.lock().unwrap();
        let s = m.entry(key.to_string()).or_default();
        s.batches += 1;
        s.batched_rows += rows as u64;
        s.real_tokens += real_tokens as u64;
        s.padded_tokens += (cell.0 * cell.1) as u64;
        s.exec.record_us(exec_us);
        let e = s.exec_by_cell.entry(cell).or_insert((0, 0));
        e.0 += 1;
        e.1 += exec_us;
    }

    /// Credit an executed batch to a pool worker.
    pub fn record_worker(&self, worker: usize, rows: usize, busy_us: u64) {
        let mut w = self.workers.lock().unwrap();
        if w.len() <= worker {
            w.resize(worker + 1, WorkerStats::default());
        }
        let s = &mut w[worker];
        s.batches += 1;
        s.rows += rows as u64;
        s.busy_us += busy_us;
    }

    /// Record a worker's steady-state memory/dispatch gauges. Arena peak
    /// and bucket counts are max'd across the worker's model snapshots;
    /// pool counters take the newest reading (monotonic at the source).
    pub fn record_worker_memory(&self, worker: usize, mem: &MemoryStats) {
        let mut w = self.workers.lock().unwrap();
        if w.len() <= worker {
            w.resize(worker + 1, WorkerStats::default());
        }
        let s = &mut w[worker];
        s.arena_peak_bytes = s.arena_peak_bytes.max(mem.arena_peak_bytes);
        s.arena_buckets = s.arena_buckets.max(mem.arena_buckets);
        s.pool_threads = mem.pool_threads;
        s.pool_jobs = s.pool_jobs.max(mem.pool_jobs);
        if !mem.precision.is_empty() {
            s.precision = mem.precision;
        }
        if !mem.isa.is_empty() {
            s.isa = mem.isa;
        }
        s.tokens_kept = s.tokens_kept.max(mem.tokens_kept);
        s.tokens_ghost = s.tokens_ghost.max(mem.tokens_ghost);
    }

    /// Record one request's adaptive-compute outcome: the operating point
    /// that served it (`None` = fixed schedule, counted as `"full"`), the
    /// word-vectors it actually paid and what the fixed schedule would
    /// have charged.
    pub fn record_adaptive(&self, key: &str, point: Option<&str>, processed: u64, full: u64) {
        let mut m = self.inner.lock().unwrap();
        let s = m.entry(key.to_string()).or_default();
        s.tokens_processed += processed;
        s.tokens_full += full;
        *s.compute_points.entry(point.unwrap_or("full").to_string()).or_insert(0) += 1;
    }

    /// Credit word-vectors a pool worker skipped via adaptive retention.
    pub fn record_worker_tokens_saved(&self, worker: usize, saved: u64) {
        let mut w = self.workers.lock().unwrap();
        if w.len() <= worker {
            w.resize(worker + 1, WorkerStats::default());
        }
        w[worker].tokens_saved += saved;
    }

    pub fn record_request(&self, key: &str, queue_us: u64, total_us: u64) {
        let mut m = self.inner.lock().unwrap();
        let s = m.entry(key.to_string()).or_default();
        s.requests += 1;
        s.queue.record_us(queue_us);
        s.total.record_us(total_us);
    }

    pub fn record_error(&self, key: &str) {
        let mut m = self.inner.lock().unwrap();
        m.entry(key.to_string()).or_default().errors += 1;
    }

    pub fn snapshot(&self, key: &str) -> Option<VariantStats> {
        self.inner.lock().unwrap().get(key).cloned()
    }

    pub fn snapshot_all(&self) -> Vec<(String, VariantStats)> {
        let m = self.inner.lock().unwrap();
        let mut v: Vec<_> = m.iter().map(|(k, s)| (k.clone(), s.clone())).collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v
    }

    pub fn worker_snapshot(&self) -> Vec<WorkerStats> {
        self.workers.lock().unwrap().clone()
    }

    /// Aggregate padding waste across every variant (padded/real tokens).
    pub fn total_padding_waste(&self) -> f64 {
        let m = self.inner.lock().unwrap();
        let (real, padded) = m
            .values()
            .fold((0u64, 0u64), |(r, p), s| (r + s.real_tokens, p + s.padded_tokens));
        if real == 0 {
            1.0
        } else {
            padded as f64 / real as f64
        }
    }

    pub fn uptime_secs(&self) -> f64 {
        self.started.map(|s| s.elapsed().as_secs_f64()).unwrap_or(0.0)
    }

    /// Structured snapshot for the protocol v2 `stats` command — the same
    /// numbers as [`MetricsHub::report`], machine-readable instead of a
    /// preformatted blob.
    pub fn to_json(&self) -> Json {
        let hist = |h: &LatencyHistogram| {
            let mut m = BTreeMap::new();
            m.insert("p50_us".to_string(), Json::UInt(h.quantile_us(0.5)));
            m.insert("p90_us".to_string(), Json::UInt(h.quantile_us(0.9)));
            m.insert("p99_us".to_string(), Json::UInt(h.quantile_us(0.99)));
            m.insert("mean_us".to_string(), Json::Num(h.mean_us()));
            Json::Obj(m)
        };
        let mut variants = BTreeMap::new();
        for (key, s) in self.snapshot_all() {
            let mut v = BTreeMap::new();
            v.insert("requests".to_string(), Json::UInt(s.requests));
            v.insert("batches".to_string(), Json::UInt(s.batches));
            v.insert("errors".to_string(), Json::UInt(s.errors));
            v.insert("mean_batch_occupancy".to_string(), Json::Num(s.mean_batch_occupancy()));
            v.insert("padding_waste".to_string(), Json::Num(s.padding_waste()));
            v.insert("real_tokens".to_string(), Json::UInt(s.real_tokens));
            v.insert("padded_tokens".to_string(), Json::UInt(s.padded_tokens));
            v.insert("tokens_processed".to_string(), Json::UInt(s.tokens_processed));
            v.insert("tokens_full".to_string(), Json::UInt(s.tokens_full));
            v.insert(
                "tokens_processed_ratio".to_string(),
                Json::Num(s.tokens_processed_ratio()),
            );
            let points: BTreeMap<String, Json> = s
                .compute_points
                .iter()
                .map(|(p, c)| (p.clone(), Json::UInt(*c)))
                .collect();
            v.insert("compute_points".to_string(), Json::Obj(points));
            v.insert("queue".to_string(), hist(&s.queue));
            v.insert("exec".to_string(), hist(&s.exec));
            v.insert("total".to_string(), hist(&s.total));
            variants.insert(key, Json::Obj(v));
        }
        let workers = self
            .worker_snapshot()
            .into_iter()
            .map(|w| {
                let mut m = BTreeMap::new();
                m.insert("batches".to_string(), Json::UInt(w.batches));
                m.insert("rows".to_string(), Json::UInt(w.rows));
                m.insert("busy_us".to_string(), Json::UInt(w.busy_us));
                m.insert("arena_peak_bytes".to_string(), Json::UInt(w.arena_peak_bytes));
                m.insert("arena_buckets".to_string(), Json::UInt(w.arena_buckets));
                m.insert("pool_threads".to_string(), Json::UInt(w.pool_threads));
                m.insert("pool_jobs".to_string(), Json::UInt(w.pool_jobs));
                m.insert("precision".to_string(), Json::Str(w.precision.to_string()));
                m.insert("isa".to_string(), Json::Str(w.isa.to_string()));
                m.insert("tokens_saved".to_string(), Json::UInt(w.tokens_saved));
                m.insert("tokens_kept".to_string(), Json::UInt(w.tokens_kept));
                m.insert("tokens_ghost".to_string(), Json::UInt(w.tokens_ghost));
                m.insert(
                    "eliminated_waste_ratio".to_string(),
                    Json::Num(w.eliminated_waste_ratio()),
                );
                Json::Obj(m)
            })
            .collect();
        let mut top = BTreeMap::new();
        top.insert("uptime_secs".to_string(), Json::Num(self.uptime_secs()));
        top.insert("padding_waste".to_string(), Json::Num(self.total_padding_waste()));
        top.insert("variants".to_string(), Json::Obj(variants));
        top.insert("workers".to_string(), Json::Arr(workers));
        Json::Obj(top)
    }

    /// Human-readable report (the `powerbert stats` CLI output).
    pub fn report(&self) -> String {
        let mut out = String::new();
        for (key, s) in self.snapshot_all() {
            out.push_str(&format!(
                "{key}: {} reqs, {} batches (mean occupancy {:.1}, padding waste {:.2}x), errors {}\n  \
                 queue p50/p99: {}/{} us  exec p50/p99: {}/{} us  total p50/p99: {}/{} us\n",
                s.requests,
                s.batches,
                s.mean_batch_occupancy(),
                s.padding_waste(),
                s.errors,
                s.queue.quantile_us(0.5),
                s.queue.quantile_us(0.99),
                s.exec.quantile_us(0.5),
                s.exec.quantile_us(0.99),
                s.total.quantile_us(0.5),
                s.total.quantile_us(0.99),
            ));
            if s.tokens_full > 0 {
                out.push_str(&format!(
                    "  adaptive: {} / {} word-vectors ({:.1}% of fixed schedule)",
                    s.tokens_processed,
                    s.tokens_full,
                    100.0 * s.tokens_processed_ratio(),
                ));
                let points: Vec<String> = s
                    .compute_points
                    .iter()
                    .map(|(p, c)| format!("{p}:{c}"))
                    .collect();
                if !points.is_empty() {
                    out.push_str(&format!("  points [{}]", points.join(" ")));
                }
                out.push('\n');
            }
        }
        let workers = self.worker_snapshot();
        if !workers.is_empty() {
            let uptime = self.uptime_secs().max(1e-9);
            for (i, w) in workers.iter().enumerate() {
                out.push_str(&format!(
                    "worker {i}: {} batches, {} rows, busy {:.1}% of uptime, \
                     arena peak {:.1} KiB over {} bucket(s), pool {} lane(s) / {} jobs, \
                     {} @ {}\n",
                    w.batches,
                    w.rows,
                    100.0 * (w.busy_us as f64 / 1e6) / uptime,
                    w.arena_peak_bytes as f64 / 1024.0,
                    w.arena_buckets,
                    w.pool_threads,
                    w.pool_jobs,
                    if w.precision.is_empty() { "f32" } else { w.precision },
                    if w.isa.is_empty() { "scalar" } else { w.isa },
                ));
                if w.tokens_saved > 0 {
                    out.push_str(&format!(
                        "  worker {i} adaptive savings: {} word-vectors\n",
                        w.tokens_saved
                    ));
                }
                if w.tokens_kept > 0 {
                    out.push_str(&format!(
                        "  worker {i} ragged: {} kept / {} ghost word-vectors \
                         (eliminated waste {:.3}x)\n",
                        w.tokens_kept,
                        w.tokens_ghost,
                        w.eliminated_waste_ratio(),
                    ));
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let h = MetricsHub::new();
        h.record_batch("sst2/bert", (8, 64), 5, 5 * 20, 1200);
        h.record_request("sst2/bert", 100, 1500);
        h.record_request("sst2/bert", 200, 1700);
        let s = h.snapshot("sst2/bert").unwrap();
        assert_eq!(s.requests, 2);
        assert_eq!(s.batches, 1);
        assert!((s.mean_batch_occupancy() - 5.0).abs() < 1e-9);
        assert!(s.exec_estimate_us(8, 64).unwrap() > 0.0);
        assert!(s.exec_estimate_us(8, 32).is_none());
        // 1200us over an (8, 64) cell = 512 executed tokens.
        assert!((s.exec_us_per_token(8).unwrap() - 1200.0 / 512.0).abs() < 1e-9);
        assert!(s.exec_us_per_token(1).is_none());
        assert!(h.report().contains("sst2/bert"));
    }

    #[test]
    fn padding_waste_tracks_cell_vs_real_tokens() {
        let h = MetricsHub::new();
        // 4 rows of ~10 real tokens executed at an (8, 64) cell: the
        // hardware saw 512 tokens for 40 real ones.
        h.record_batch("sst2/bert", (8, 64), 4, 40, 900);
        let s = h.snapshot("sst2/bert").unwrap();
        assert!((s.padding_waste() - 512.0 / 40.0).abs() < 1e-9);
        // A snug (4, 16) cell for the same traffic is far cheaper.
        h.record_batch("sst2/power", (4, 16), 4, 40, 300);
        let p = h.snapshot("sst2/power").unwrap();
        assert!((p.padding_waste() - 64.0 / 40.0).abs() < 1e-9);
        assert!(h.total_padding_waste() > 1.0);
    }

    #[test]
    fn worker_stats_accumulate() {
        let h = MetricsHub::new();
        h.record_worker(1, 8, 500);
        h.record_worker(1, 4, 250);
        h.record_worker(0, 2, 100);
        let w = h.worker_snapshot();
        assert_eq!(w.len(), 2);
        assert_eq!(w[1].batches, 2);
        assert_eq!(w[1].rows, 12);
        assert_eq!(w[0].busy_us, 100);
        assert!(h.report().contains("worker 0"));
    }

    #[test]
    fn worker_memory_gauges_track_peak_and_latest() {
        let h = MetricsHub::new();
        h.record_worker_memory(
            0,
            &MemoryStats {
                arena_peak_bytes: 4096,
                arena_buckets: 1,
                pool_threads: 4,
                pool_jobs: 10,
                precision: "f32",
                isa: "scalar",
                tokens_kept: 100,
                tokens_ghost: 20,
            },
        );
        // A smaller later snapshot must not shrink the peak; pool jobs
        // advance to the newest reading.
        h.record_worker_memory(
            0,
            &MemoryStats {
                arena_peak_bytes: 1024,
                arena_buckets: 3,
                pool_threads: 4,
                pool_jobs: 25,
                precision: "f32",
                isa: "scalar",
                tokens_kept: 300,
                tokens_ghost: 60,
            },
        );
        let w = h.worker_snapshot();
        assert_eq!(w[0].arena_peak_bytes, 4096);
        assert_eq!(w[0].arena_buckets, 3);
        assert_eq!(w[0].pool_threads, 4);
        assert_eq!(w[0].pool_jobs, 25);
        assert_eq!(w[0].precision, "f32");
        assert_eq!(w[0].isa, "scalar");
        assert_eq!(w[0].tokens_kept, 300);
        assert_eq!(w[0].tokens_ghost, 60);
        assert!((w[0].eliminated_waste_ratio() - 0.2).abs() < 1e-9);
        // Surfaced both in the human report and the structured stats.
        h.record_worker(0, 1, 10);
        assert!(h.report().contains("pool 4 lane(s)"));
        let json = h.to_json().to_string();
        assert!(json.contains("arena_peak_bytes"), "stats json lacks arena gauge: {json}");
        assert!(json.contains("precision"), "stats json lacks precision: {json}");
        assert!(json.contains("isa"), "stats json lacks isa: {json}");
        assert!(
            json.contains("eliminated_waste_ratio"),
            "stats json lacks waste ratio: {json}"
        );
    }

    #[test]
    fn adaptive_gauges_accumulate() {
        let h = MetricsHub::new();
        // Two balanced requests paying 80/104 each, one fixed at full cost.
        h.record_adaptive("sst2/power-default", Some("balanced@0.950"), 80, 104);
        h.record_adaptive("sst2/power-default", Some("balanced@0.950"), 80, 104);
        h.record_adaptive("sst2/power-default", None, 104, 104);
        let s = h.snapshot("sst2/power-default").unwrap();
        assert_eq!(s.tokens_processed, 264);
        assert_eq!(s.tokens_full, 312);
        assert!((s.tokens_processed_ratio() - 264.0 / 312.0).abs() < 1e-9);
        assert_eq!(s.compute_points.get("balanced@0.950"), Some(&2));
        assert_eq!(s.compute_points.get("full"), Some(&1));
        h.record_worker_tokens_saved(0, 48);
        h.record_worker_tokens_saved(0, 2);
        assert_eq!(h.worker_snapshot()[0].tokens_saved, 50);
        // Surfaced in both outputs.
        h.record_worker(0, 1, 10);
        let rep = h.report();
        assert!(rep.contains("adaptive"), "report lacks adaptive line: {rep}");
        let json = h.to_json().to_string();
        assert!(json.contains("tokens_processed_ratio"), "stats json: {json}");
        assert!(json.contains("compute_points"), "stats json: {json}");
        assert!(json.contains("tokens_saved"), "stats json: {json}");
    }

    #[test]
    fn errors_counted() {
        let h = MetricsHub::new();
        h.record_error("x/y");
        assert_eq!(h.snapshot("x/y").unwrap().errors, 1);
    }
}
